"""Gradient compression for the cross-pod (pure-DP) reduction.

Under pjit global-array semantics the gradient all-reduce is implicit, so
compression is applied as a value-level quantize→dequantize transform on the
gradients *before* the optimizer: this models the numerics of compressed
collectives exactly, while the byte saving on the wire is reported
analytically in the roofline (collective_bytes × compression ratio).

Both schemes keep **error feedback** state so the compression error is
re-injected next step (required for convergence at high compression).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ef_init(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


# ---------------------------------------------------------------------------
# int8 per-tensor quantization
# ---------------------------------------------------------------------------

def _q8(g: jax.Array) -> jax.Array:
    gf = g.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(gf)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
    return q.astype(jnp.float32) * scale


def compress_int8(grads, ef):
    """Returns (decompressed grads, new error-feedback state)."""
    def one(g, e):
        gf = g.astype(jnp.float32) + e
        deq = _q8(gf)
        return deq, gf - deq
    flat = jax.tree.map(one, grads, ef)
    out = jax.tree.map(lambda t: t[0], flat, is_leaf=lambda x: isinstance(x, tuple))
    new_ef = jax.tree.map(lambda t: t[1], flat, is_leaf=lambda x: isinstance(x, tuple))
    return out, new_ef


# ---------------------------------------------------------------------------
# top-k sparsification (per tensor)
# ---------------------------------------------------------------------------

def compress_topk(grads, ef, ratio: float = 0.05):
    """Keep the largest-|g| `ratio` fraction per tensor; error feedback."""
    def one(g, e):
        gf = g.astype(jnp.float32) + e
        flat = gf.reshape(-1)
        k = max(1, int(flat.shape[0] * ratio))
        thresh = jax.lax.top_k(jnp.abs(flat), k)[0][-1]
        kept = jnp.where(jnp.abs(gf) >= thresh, gf, 0.0)
        return kept, gf - kept
    flat = jax.tree.map(one, grads, ef)
    out = jax.tree.map(lambda t: t[0], flat, is_leaf=lambda x: isinstance(x, tuple))
    new_ef = jax.tree.map(lambda t: t[1], flat, is_leaf=lambda x: isinstance(x, tuple))
    return out, new_ef


def wire_bytes_ratio(scheme: str, topk_ratio: float = 0.05) -> float:
    """Bytes-on-the-wire ratio vs f32 all-reduce (for roofline accounting)."""
    if scheme == "int8":
        return 0.25
    if scheme == "topk":
        return topk_ratio * 2.0     # value + index per kept entry
    return 1.0
