"""Sharded checkpointing with reshard-on-restore (the migration substrate).

Checkpoints are written as one ``.npz`` (path-keyed leaves) + a JSON
manifest, atomically (tmp + rename). ``load`` device_puts every leaf with
the *target* mesh's shardings — restoring onto a different mesh **is** the
elastic reshard that implements the paper's container migration. Saves can
run on a background thread (async checkpointing), and ``CheckpointManager``
keeps a bounded history + a ``latest`` pointer for crash recovery.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Optional

import numpy as np

import jax


def _flatten(tree) -> dict:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out[key] = leaf
    return out


def save(path: str, state: Any, *, step: int = 0, extra: Optional[dict] = None) -> dict:
    """Write state to ``path`` (directory). Returns timing info."""
    t0 = time.perf_counter()
    os.makedirs(path, exist_ok=True)
    flat = _flatten(state)
    host = {}
    for k, v in flat.items():
        a = np.asarray(v)
        if a.dtype.name == "bfloat16":      # np.savez cannot store bf16
            a = a.view(np.uint16)
        host[k] = a
    t_gather = time.perf_counter() - t0
    tmp = os.path.join(path, ".tmp.npz")
    np.savez(tmp, **host)
    os.replace(tmp, os.path.join(path, "state.npz"))
    manifest = {
        "step": int(step),
        "keys": {k: {"shape": list(v.shape), "dtype": str(v.dtype)} for k, v in host.items()},
        "bytes": int(sum(v.nbytes for v in host.values())),
        "extra": extra or {},
    }
    with open(os.path.join(path, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    t_total = time.perf_counter() - t0
    return {"gather_s": t_gather, "write_s": t_total - t_gather,
            "total_s": t_total, "bytes": manifest["bytes"]}


def load(path: str, abstract_state: Any, *, shardings: Any = None) -> Any:
    """Restore a state tree; device_put with (possibly different-mesh) shardings.

    ``abstract_state`` fixes the tree structure + shapes; ``shardings`` (same
    tree of NamedShardings, or None) is the target placement — pass the NEW
    mesh's shardings to reshard elastically.
    """
    with np.load(os.path.join(path, "state.npz")) as z:
        data = {k: z[k] for k in z.files}
    flat, treedef = jax.tree_util.tree_flatten_with_path(abstract_state)
    sh_flat = None
    if shardings is not None:
        sh_flat = treedef.flatten_up_to(shardings)
    leaves = []
    for idx, (pathk, leaf) in enumerate(flat):
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in pathk)
        if key not in data:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        arr = data[key]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"{key}: checkpoint shape {arr.shape} != expected {leaf.shape}")
        if (np.dtype(leaf.dtype).name == "bfloat16"
                and arr.dtype == np.uint16):
            arr = arr.view("bfloat16")      # stored as raw bits
        else:
            arr = arr.astype(leaf.dtype)
        if sh_flat is not None and sh_flat[idx] is not None:
            leaves.append(jax.device_put(arr, sh_flat[idx]))
        else:
            leaves.append(jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, leaves)


def manifest(path: str) -> dict:
    with open(os.path.join(path, "manifest.json")) as f:
        return json.load(f)


class CheckpointManager:
    """Bounded checkpoint history + async saves + latest-pointer recovery."""

    def __init__(self, root: str, keep: int = 3, async_save: bool = True):
        self.root = root
        self.keep = keep
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        self._last_info: Optional[dict] = None
        os.makedirs(root, exist_ok=True)

    def step_dir(self, step: int) -> str:
        return os.path.join(self.root, f"step_{step:08d}")

    def all_steps(self) -> list:
        out = []
        for d in os.listdir(self.root):
            if d.startswith("step_") and os.path.exists(
                    os.path.join(self.root, d, "manifest.json")):
                out.append(int(d.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def last_info(self) -> Optional[dict]:
        """Info dict of the most recent completed save (waits for an
        in-flight async save first). The public accessor for what
        `save()` recorded — callers must not reach into `_last_info`."""
        self.wait()
        return self._last_info

    def save(self, step: int, state: Any,
             extra: Optional[dict] = None) -> Optional[dict]:
        """Write a checkpoint; returns its info dict for synchronous
        saves (async saves return None — use `last_info()` after
        `wait()`, which also covers the sync case)."""
        self.wait()
        # snapshot to host synchronously (cheap vs write), write async
        host = jax.tree.map(np.asarray, state)

        def work():
            self._last_info = save(self.step_dir(step), host, step=step, extra=extra)
            self._gc()

        if self.async_save:
            self._thread = threading.Thread(target=work, daemon=True)
            self._thread.start()
            return None
        work()
        return self._last_info

    def restore(self, abstract_state: Any, *, step: Optional[int] = None,
                shardings: Any = None) -> tuple:
        self.wait()
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.root}")
        state = load(self.step_dir(step), abstract_state, shardings=shardings)
        return state, step

    def _gc(self):
        steps = self.all_steps()
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(self.step_dir(s), ignore_errors=True)
