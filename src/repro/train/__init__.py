"""Training stack: optimizer, loop, checkpointing, data, compression."""
