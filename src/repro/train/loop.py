"""Training loop: state construction, jit'd train_step, grad accumulation.

``make_train_step`` builds the pure step function that the dry-run lowers
and the CarbonAwareTrainer drives. State = {params, opt{m,v}, step [, ef]}.
State specs derive from the model's ParamSpec tree, so dry-run abstractions
and shardings for the optimizer state come for free.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.config import OptimizerConfig, TrainConfig
from repro.models.api import Model
from repro.models.params import ParamSpec, abstract_params, is_spec, param_pspecs
from repro.train import compression as COMP
from repro.train import optimizer as OPT


# ---------------------------------------------------------------------------
# State specs / construction
# ---------------------------------------------------------------------------

def state_specs(model: Model, opt_cfg: OptimizerConfig) -> dict:
    pspecs = model.specs()
    f32 = lambda s: dataclasses.replace(s, dtype="float32", init="zeros")
    out = {
        "params": pspecs,
        "opt": {"m": jax.tree.map(f32, pspecs, is_leaf=is_spec),
                "v": jax.tree.map(f32, pspecs, is_leaf=is_spec)},
        "step": ParamSpec((), (), init="zeros", dtype="int32"),
    }
    if opt_cfg.compression != "none":
        out["ef"] = jax.tree.map(f32, pspecs, is_leaf=is_spec)
    return out


def init_state(model: Model, opt_cfg: OptimizerConfig, key: jax.Array) -> dict:
    params = model.init(key)
    state = {"params": params, "opt": OPT.adamw_init(params),
             "step": jnp.zeros((), jnp.int32)}
    if opt_cfg.compression != "none":
        state["ef"] = COMP.ef_init(params)
    return state


def abstract_state(model: Model, opt_cfg: OptimizerConfig) -> dict:
    return abstract_params(state_specs(model, opt_cfg))


def state_pspecs(model: Model, opt_cfg: OptimizerConfig, mesh,
                 overrides=None) -> dict:
    return param_pspecs(state_specs(model, opt_cfg), mesh, overrides)


# ---------------------------------------------------------------------------
# Train step
# ---------------------------------------------------------------------------

def make_train_step(model: Model, cfg: TrainConfig) -> Callable:
    opt_cfg = cfg.optimizer
    update = OPT.UPDATES[opt_cfg.name]

    def loss_of(params, batch):
        loss, metrics = model.loss(params, batch, remat=cfg.remat)
        return loss, metrics

    def compute_grads(params, batch):
        if cfg.microbatch and cfg.microbatch < cfg.global_batch:
            n_micro = cfg.global_batch // cfg.microbatch
            split = lambda x: x.reshape((n_micro, cfg.microbatch) + x.shape[1:])
            micro = jax.tree.map(split, batch)

            def acc_fn(carry, mb):
                (loss, metrics), g = jax.value_and_grad(loss_of, has_aux=True)(params, mb)
                carry_g, carry_l = carry
                return (jax.tree.map(jnp.add, carry_g, g), carry_l + loss), metrics

            zero_g = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (gsum, lsum), metrics = jax.lax.scan(acc_fn, (zero_g, jnp.zeros(())), micro)
            grads = jax.tree.map(lambda g: g / n_micro, gsum)
            metrics = jax.tree.map(lambda m: m[-1], metrics)
            return (lsum / n_micro, metrics), grads
        return jax.value_and_grad(loss_of, has_aux=True)(params, batch)

    def train_step(state: dict, batch: dict):
        (loss, metrics), grads = compute_grads(state["params"], batch)
        new_state = dict(state)
        if opt_cfg.compression == "int8":
            grads, new_state["ef"] = COMP.compress_int8(grads, state["ef"])
        elif opt_cfg.compression == "topk":
            grads, new_state["ef"] = COMP.compress_topk(grads, state["ef"],
                                                        opt_cfg.topk_ratio)
        new_p, new_opt, opt_metrics = update(
            opt_cfg, grads, state["opt"], state["params"], state["step"])
        new_state.update({"params": new_p, "opt": new_opt,
                          "step": state["step"] + 1})
        out_metrics = {"loss": loss, **metrics, **opt_metrics}
        return new_state, out_metrics

    return train_step


# ---------------------------------------------------------------------------
# Simple driver (single-process; the carbon-aware driver wraps this)
# ---------------------------------------------------------------------------

def run(model: Model, cfg: TrainConfig, data_iter, *, mesh=None,
        state: Optional[dict] = None,
        step_callback: Optional[Callable] = None) -> dict:
    """Train for cfg.steps; returns final state. step_callback gets telemetry."""
    from repro.data.pipeline import shard_batch

    key = jax.random.PRNGKey(cfg.seed)
    if state is None:
        state = init_state(model, cfg.optimizer, key)
    step_fn = jax.jit(make_train_step(model, cfg), donate_argnums=(0,))

    history = []
    it = iter(data_iter)
    for i in range(cfg.steps):
        batch = shard_batch(next(it), mesh)
        t0 = time.perf_counter()
        state, metrics = step_fn(state, batch)
        metrics = {k: float(v) for k, v in metrics.items()}
        dt = time.perf_counter() - t0
        metrics["step_time_s"] = dt
        metrics["tokens"] = cfg.global_batch * cfg.seq_len
        history.append(metrics)
        if step_callback is not None:
            step_callback(i, state, metrics)
        if cfg.log_every and i % cfg.log_every == 0:
            print(f"step {i:5d} loss {metrics['loss']:.4f} "
                  f"({dt*1e3:.0f} ms)", flush=True)
    return {"state": state, "history": history}
