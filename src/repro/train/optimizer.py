"""Hand-rolled AdamW + LR schedules (no optax in this environment).

Optimizer state mirrors the parameter tree (same shapes/shardings), so the
model's ParamSpec tree provides dry-run abstractions and PartitionSpecs for
``m``/``v`` for free.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.config import OptimizerConfig


# ---------------------------------------------------------------------------
# LR schedules
# ---------------------------------------------------------------------------

def lr_at(cfg: OptimizerConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    if cfg.warmup_steps > 0:
        warm = jnp.minimum(step / cfg.warmup_steps, 1.0)
    else:
        warm = 1.0
    frac = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    if cfg.schedule == "cosine":
        decay = 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
    elif cfg.schedule == "linear":
        decay = 1.0 - frac
    elif cfg.schedule == "constant":
        decay = 1.0
    else:
        raise ValueError(cfg.schedule)
    return cfg.lr * warm * decay


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------

def adamw_init(params):
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {"m": jax.tree.map(zeros, params), "v": jax.tree.map(zeros, params)}


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(sum(leaves))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads), norm


def adamw_update(cfg: OptimizerConfig, grads, opt_state, params, step):
    """Returns (new_params, new_opt_state, metrics). All f32 master math."""
    if cfg.grad_clip > 0:
        grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    else:
        grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        gnorm = global_norm(grads)
    lr = lr_at(cfg, step)
    t = step.astype(jnp.float32) + 1.0
    bc1 = 1.0 - cfg.b1 ** t
    bc2 = 1.0 - cfg.b2 ** t

    def upd(p, g, m, v):
        m = cfg.b1 * m + (1.0 - cfg.b1) * g
        v = cfg.b2 * v + (1.0 - cfg.b2) * jnp.square(g)
        mhat = m / bc1
        vhat = v / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(opt_state["m"])
    flat_v = treedef.flatten_up_to(opt_state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v}, {"grad_norm": gnorm, "lr": lr}


# ---------------------------------------------------------------------------
# SGD (baseline optimizer)
# ---------------------------------------------------------------------------

def sgd_update(cfg: OptimizerConfig, grads, opt_state, params, step):
    lr = lr_at(cfg, step)
    if cfg.grad_clip > 0:
        grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    else:
        gnorm = global_norm(grads)
    mom = jax.tree.map(
        lambda m, g: 0.9 * m + g.astype(jnp.float32), opt_state["m"], grads)
    new_p = jax.tree.map(
        lambda p, m: (p.astype(jnp.float32) - lr * m).astype(p.dtype), params, mom)
    return new_p, {"m": mom, "v": opt_state["v"]}, {"grad_norm": gnorm, "lr": lr}


UPDATES = {"adamw": adamw_update, "sgd": sgd_update}
