"""jit'd dispatch wrappers around the Pallas kernels.

``impl`` semantics (every op):
  - "auto":    Pallas on TPU backends, pure-JAX elsewhere (chunked/assoc forms
               whose memory behaviour mirrors the kernels — used by dry-runs).
  - "pallas":  force the Pallas kernel (compiled on TPU, interpret on CPU).
  - "ref":     force the materializing oracle (tests / small shapes).
  - "chunked"/"assoc": force the pure-JAX blocked form.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import ref as R


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------

def mha(q: jax.Array, k: jax.Array, v: jax.Array, *,
        causal: bool = True, window: int = 0, q_offset=0, kv_len=None,
        kv_positions=None, impl: str = "auto",
        interpret: Optional[bool] = None) -> jax.Array:
    """GQA attention. q (B,Sq,Hq,Dh); k,v (B,Skv,Hkv,Dh)."""
    B, Sq, Hq, Dh = q.shape
    Skv = k.shape[1]
    if impl == "auto":
        if Sq == 1 or kv_positions is not None:
            impl = "ref"            # decode: single-row einsum is optimal
        elif _on_tpu() and isinstance(q_offset, int) and q_offset == 0 and kv_len is None:
            impl = "pallas"
        elif Sq * Skv > 1024 * 1024:
            impl = "chunked"        # large prefill/train on CPU: bounded temps
        else:
            impl = "ref"
    if impl == "pallas":
        from repro.kernels import flash_attention as FA
        return FA.flash_attention(q, k, v, causal=causal, window=window,
                                  interpret=bool(interpret) if interpret is not None
                                  else not _on_tpu())
    if impl == "chunked":
        if isinstance(q_offset, int) and q_offset == 0 and kv_len is None:
            # self-attention: flash path (custom VJP — train-memory safe)
            return R.attention_flash(q, k, v, causal=causal, window=window)
        return R.attention_chunked(q, k, v, causal=causal, window=window,
                                   q_offset=q_offset, kv_len=kv_len)
    if impl == "ref":
        return R.attention_ref(q, k, v, causal=causal, window=window,
                               q_offset=q_offset, kv_len=kv_len,
                               kv_positions=kv_positions)
    raise ValueError(f"unknown attention impl {impl!r}")


# ---------------------------------------------------------------------------
# Mamba-2 SSD
# ---------------------------------------------------------------------------

def ssd(x: jax.Array, dt: jax.Array, a_log: jax.Array, b: jax.Array,
        c: jax.Array, d: jax.Array, *, h0=None, chunk: int = 256,
        impl: str = "auto", interpret: Optional[bool] = None):
    """SSD scan. Returns (y, h_final). See kernels.ref.ssd_ref for semantics."""
    if impl == "auto":
        impl = "pallas" if (_on_tpu() and b.shape[2] == 1) else "chunked"
    if impl == "pallas":
        from repro.kernels import ssd_scan as SS
        return SS.ssd_pallas(x, dt, a_log, b, c, d, h0=h0, chunk=chunk,
                             interpret=bool(interpret) if interpret is not None
                             else not _on_tpu())
    if impl == "chunked":
        return R.ssd_chunked(x, dt, a_log, b, c, d, h0=h0, chunk=chunk)
    if impl == "ref":
        return R.ssd_ref(x, dt, a_log, b, c, d, h0=h0)
    raise ValueError(f"unknown ssd impl {impl!r}")


def ssd_decode_step(x, dt, a_log, b, c, d, h):
    """Single-token SSD update. x (B,H,P), dt (B,H), b,c (B,G,N), h (B,H,P,N)."""
    Hh = x.shape[1]
    rep = Hh // b.shape[1]
    a = -jnp.exp(a_log.astype(jnp.float32))
    bt = jnp.repeat(b, rep, axis=1).astype(jnp.float32)
    ct = jnp.repeat(c, rep, axis=1).astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    da = jnp.exp(dtf * a[None, :])
    h = h.astype(jnp.float32) * da[..., None, None] + jnp.einsum(
        "bhp,bhn,bh->bhpn", x.astype(jnp.float32), bt, dtf)
    y = jnp.einsum("bhpn,bhn->bhp", h, ct)
    y = y + x.astype(jnp.float32) * d.astype(jnp.float32)[None, :, None]
    return y.astype(x.dtype), h


# ---------------------------------------------------------------------------
# RG-LRU
# ---------------------------------------------------------------------------

def rglru(x: jax.Array, r: jax.Array, i: jax.Array, lam: jax.Array, *,
          h0=None, impl: str = "auto", interpret: Optional[bool] = None):
    """Gated linear recurrence. Returns (h_seq, h_final)."""
    if impl == "auto":
        impl = "pallas" if _on_tpu() else "assoc"
    if impl == "pallas":
        from repro.kernels import rglru_scan as RG
        return RG.rglru_pallas(x, r, i, lam, h0=h0,
                               interpret=bool(interpret) if interpret is not None
                               else not _on_tpu())
    if impl == "assoc":
        return R.rglru_assoc(x, r, i, lam, h0=h0)
    if impl == "ref":
        return R.rglru_ref(x, r, i, lam, h0=h0)
    raise ValueError(f"unknown rglru impl {impl!r}")


def rglru_decode_step(x, r, i, lam, h):
    """Single-token RG-LRU update. x,r,i (B,W); h (B,W)."""
    log_a_base = -R.RGLRU_C * jax.nn.softplus(lam.astype(jnp.float32))
    rg = jax.nn.sigmoid(r.astype(jnp.float32))
    ig = jax.nn.sigmoid(i.astype(jnp.float32))
    log_a = log_a_base[None, :] * rg
    a = jnp.exp(log_a)
    beta = jnp.sqrt(-jnp.expm1(2.0 * log_a))
    h = a * h.astype(jnp.float32) + beta * (ig * x.astype(jnp.float32))
    return h.astype(x.dtype), h


# ---------------------------------------------------------------------------
# Causal depthwise conv1d
# ---------------------------------------------------------------------------

def causal_conv1d(x, w, b=None, state=None):
    return R.causal_conv1d_ref(x, w, b, state)


def conv1d_decode_step(x, w, b, state):
    """x (B,C) one step; state (B,K-1,C). Returns (y (B,C), new state)."""
    K = w.shape[0]
    xs = jnp.concatenate([state.astype(x.dtype), x[:, None, :]], axis=1)  # (B,K,C)
    y = jnp.einsum("bkc,kc->bc", xs.astype(jnp.float32), w.astype(jnp.float32))
    if b is not None:
        y = y + b.astype(jnp.float32)
    return y.astype(x.dtype), xs[:, 1:]
