"""Pallas TPU kernel for the RG-LRU gated linear recurrence.

The gates (a, βix) are elementwise and fuse fine under XLA, so they are
computed *outside* the kernel; the kernel is the irreducibly sequential
part: h_t = a_t ⊙ h_{t-1} + gx_t over time, vectorized across the width
lanes. Grid: (batch, width_blocks, time_blocks) with the hidden state in
VMEM scratch across time blocks; within a block a fori_loop steps the
recurrence on (1, bw) vectors (VPU work; this layer is bandwidth-bound).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pallas_compat import compiler_params

from repro.kernels.ref import RGLRU_C


def _kernel(a_ref, gx_ref, h0_ref, y_ref, hlast_ref, h_ref, *,
            bt: int, nt: int):
    ti = pl.program_id(2)

    @pl.when(ti == 0)
    def _init():
        h_ref[...] = h0_ref[...].astype(jnp.float32)

    def step(t, h):
        a_t = a_ref[0, t, :].astype(jnp.float32)
        gx_t = gx_ref[0, t, :].astype(jnp.float32)
        h = a_t * h + gx_t
        y_ref[0, t, :] = h.astype(y_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, bt, step, h_ref[0, :])
    h_ref[0, :] = h

    @pl.when(ti == nt - 1)
    def _final():
        hlast_ref[...] = h_ref[...].astype(hlast_ref.dtype)


def rglru_scan_pallas(a: jax.Array, gx: jax.Array, h0: jax.Array, *,
                      block_t: int = 128, block_w: int = 512,
                      interpret: bool = False):
    """Raw scan: h_t = a_t*h_{t-1} + gx_t. a,gx (B,S,W); h0 (B,W) f32.

    Returns (h_seq (B,S,W) in gx.dtype, h_last (B,W) f32).
    """
    B, S, W = a.shape
    bt = min(block_t, S)
    assert S % bt == 0, (S, bt)
    bw = min(block_w, W)
    assert W % bw == 0, (W, bw)
    nt, nw = S // bt, W // bw

    kernel = functools.partial(_kernel, bt=bt, nt=nt)
    y, h_last = pl.pallas_call(
        kernel,
        grid=(B, nw, nt),
        in_specs=[
            pl.BlockSpec((1, bt, bw), lambda b, wi, ti: (b, ti, wi)),
            pl.BlockSpec((1, bt, bw), lambda b, wi, ti: (b, ti, wi)),
            pl.BlockSpec((1, bw), lambda b, wi, ti: (b, wi)),
        ],
        out_specs=[
            pl.BlockSpec((1, bt, bw), lambda b, wi, ti: (b, ti, wi)),
            pl.BlockSpec((1, bw), lambda b, wi, ti: (b, wi)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, S, W), gx.dtype),
            jax.ShapeDtypeStruct((B, W), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((1, bw), jnp.float32)],
        compiler_params=compiler_params(
            ("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(a, gx, h0)
    return y, h_last


def rglru_pallas(x: jax.Array, r: jax.Array, i: jax.Array, lam: jax.Array, *,
                 h0: Optional[jax.Array] = None, interpret: bool = False):
    """Full RG-LRU (gates outside, scan kernel inside). Same semantics as
    ``ref.rglru_ref``: returns (h_seq (B,S,W), h_final (B,W) f32)."""
    B, S, W = x.shape
    log_a_base = -RGLRU_C * jax.nn.softplus(lam.astype(jnp.float32))
    rg = jax.nn.sigmoid(r.astype(jnp.float32))
    ig = jax.nn.sigmoid(i.astype(jnp.float32))
    log_a = log_a_base[None, None, :] * rg
    a = jnp.exp(log_a)
    beta = jnp.sqrt(-jnp.expm1(2.0 * log_a))
    gx = beta * (ig * x.astype(jnp.float32))
    h0f = (jnp.zeros((B, W), jnp.float32) if h0 is None
           else h0.astype(jnp.float32))
    y, h_last = rglru_scan_pallas(a.astype(x.dtype), gx.astype(jnp.float32),
                                  h0f, interpret=interpret)
    return y.astype(x.dtype), h_last
