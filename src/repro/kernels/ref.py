"""Pure-jnp oracles for every Pallas kernel (and the model fallback paths).

These are the *semantic ground truth*: the Pallas kernels in this package are
validated against these functions (interpret=True on CPU) across shape/dtype
sweeps, and the model code uses them directly on non-TPU backends.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)


# ---------------------------------------------------------------------------
# Attention (GQA, causal / local-window, offset for decode)
# ---------------------------------------------------------------------------

def _attn_mask(sq: int, skv: int, q_offset, kv_len, causal: bool, window: int,
               kv_positions=None):
    """(sq, skv) boolean mask of allowed attention edges (True = keep)."""
    q_pos = q_offset + jnp.arange(sq)[:, None]          # (sq, 1)
    if kv_positions is None:
        kv_pos = jnp.arange(skv)[None, :]               # (1, skv)
    else:
        kv_pos = jnp.asarray(kv_positions)[None, :]     # ring buffers etc.
    mask = jnp.ones((sq, skv), dtype=bool)
    if causal:
        mask &= kv_pos <= q_pos
    if window and window > 0:
        mask &= kv_pos > q_pos - window
    if kv_len is not None:
        mask &= kv_pos < kv_len
    return mask


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool = True, window: int = 0,
                  q_offset=0, kv_len=None, kv_positions=None,
                  scale: Optional[float] = None) -> jax.Array:
    """Materializing GQA attention.

    q: (B, Sq, Hq, Dh); k, v: (B, Skv, Hkv, Dh); Hq % Hkv == 0.
    q_offset: absolute position of q[0] (static or traced scalar).
    kv_len:   number of valid KV entries (for partially-filled caches).
    kv_positions: (Skv,) absolute positions of KV entries (ring buffers).
    """
    B, Sq, Hq, Dh = q.shape
    _, Skv, Hkv, _ = k.shape
    G = Hq // Hkv
    scale = scale if scale is not None else Dh ** -0.5
    qg = q.reshape(B, Sq, Hkv, G, Dh).astype(jnp.float32) * scale
    kf = k.astype(jnp.float32)
    logits = jnp.einsum("bqhgd,bkhd->bhgqk", qg, kf)     # (B,Hkv,G,Sq,Skv)
    mask = _attn_mask(Sq, Skv, q_offset, kv_len, causal, window, kv_positions)
    logits = jnp.where(mask[None, None, None], logits, NEG_INF)
    w = jax.nn.softmax(logits, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", w, v.astype(jnp.float32))
    return o.reshape(B, Sq, Hq, Dh).astype(q.dtype)


def attention_chunked(q: jax.Array, k: jax.Array, v: jax.Array, *,
                      causal: bool = True, window: int = 0,
                      q_offset=0, kv_len=None,
                      scale: Optional[float] = None,
                      q_block: int = 512, kv_block: int = 1024) -> jax.Array:
    """Online-softmax (flash-style) attention in pure JAX.

    Bounded temporaries: scans q blocks (outer) x kv blocks (inner carry).
    This is the lowering used for large-shape dry-runs — it mirrors the memory
    behaviour of the Pallas kernel instead of materializing (Sq, Skv) logits.
    """
    B, Sq, Hq, Dh = q.shape
    _, Skv, Hkv, _ = k.shape
    G = Hq // Hkv
    scale = scale if scale is not None else Dh ** -0.5
    qb = min(q_block, Sq)
    kb = min(kv_block, Skv)
    # pad to block multiples
    sq_p = -(-Sq // qb) * qb
    skv_p = -(-Skv // kb) * kb
    qp = jnp.pad(q, ((0, 0), (0, sq_p - Sq), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, skv_p - Skv), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, skv_p - Skv), (0, 0), (0, 0)))
    kv_len_eff = jnp.asarray(Skv if kv_len is None else kv_len, jnp.int32)

    nq, nk = sq_p // qb, skv_p // kb
    qblocks = qp.reshape(B, nq, qb, Hkv, G, Dh).astype(jnp.float32) * scale
    kblocks = kp.reshape(B, nk, kb, Hkv, Dh).astype(jnp.float32)
    vblocks = vp.reshape(B, nk, kb, Hkv, Dh).astype(jnp.float32)

    def q_step(_, qi):
        qblk, qidx = qi                                   # (B,qb,Hkv,G,Dh)
        q_pos = q_offset + qidx * qb + jnp.arange(qb)

        def kv_step(carry, ki):
            m, l, acc = carry
            kblk, vblk, kidx = ki
            kv_pos = kidx * kb + jnp.arange(kb)
            logits = jnp.einsum("bqhgd,bkhd->bhgqk", qblk, kblk)
            mask = jnp.ones((qb, kb), bool)
            if causal:
                mask &= kv_pos[None, :] <= q_pos[:, None]
            if window and window > 0:
                mask &= kv_pos[None, :] > q_pos[:, None] - window
            mask &= kv_pos[None, :] < kv_len_eff
            # additive (qb,kb) bias: a broadcast `where` would be hoisted and
            # stacked across scan iterations at (nq,nk,B,H,G,qb,kb)
            logits = logits + jnp.where(mask, 0.0, NEG_INF)
            m_new = jnp.maximum(m, logits.max(-1, keepdims=True))
            p = jnp.exp(logits - m_new)
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(-1, keepdims=True)
            acc_new = acc * corr + jnp.einsum("bhgqk,bkhd->bhgqd", p, vblk)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, Hkv, G, qb, 1), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, Hkv, G, qb, 1), jnp.float32)
        a0 = jnp.zeros((B, Hkv, G, qb, Dh), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0),
            (kblocks.swapaxes(0, 1), vblocks.swapaxes(0, 1), jnp.arange(nk)))
        out = acc / jnp.maximum(l, 1e-37)                 # (B,Hkv,G,qb,Dh)
        return None, out.transpose(0, 3, 1, 2, 4)         # (B,qb,Hkv,G,Dh)

    _, outs = jax.lax.scan(q_step, None,
                           (qblocks.swapaxes(0, 1), jnp.arange(nq)))
    out = outs.transpose(1, 0, 2, 3, 4, 5).reshape(B, sq_p, Hq, Dh)
    return out[:, :Sq].astype(q.dtype)


# ---------------------------------------------------------------------------
# Flash attention with custom VJP (pure JAX): backward recomputes per-block
# probabilities instead of saving them (the flash-attention insight). This is
# what makes large-seq *training* memory-feasible; `attention_chunked` alone
# would stack S^2 residuals during scan differentiation.
# ---------------------------------------------------------------------------

def _flash_fwd_inner(q, k, v, causal, window, scale, q_block, kv_block, kv_valid):
    """Returns (out, lse). Shapes as attention_chunked; no padding support
    beyond block multiples (wrapper pads)."""
    B, Sq, Hq, Dh = q.shape
    _, Skv, Hkv, _ = k.shape
    G = Hq // Hkv
    qb, kb = q_block, kv_block
    nq, nk = Sq // qb, Skv // kb
    qf = q.reshape(B, nq, qb, Hkv, G, Dh).astype(jnp.float32) * scale
    kf = k.reshape(B, nk, kb, Hkv, Dh).astype(jnp.float32)
    vf = v.reshape(B, nk, kb, Hkv, Dh).astype(jnp.float32)

    def q_step(_, qi):
        qblk, qidx = qi
        q_pos = qidx * qb + jnp.arange(qb)

        def kv_step(carry, ki):
            m, l, acc = carry
            kblk, vblk, kidx = ki
            kv_pos = kidx * kb + jnp.arange(kb)
            s = jnp.einsum("bqhgd,bkhd->bhgqk", qblk, kblk)
            mask = kv_pos[None, :] < kv_valid
            if causal:
                mask &= kv_pos[None, :] <= q_pos[:, None]
            if window and window > 0:
                mask &= kv_pos[None, :] > q_pos[:, None] - window
            s = s + jnp.where(mask, 0.0, NEG_INF)
            m_new = jnp.maximum(m, s.max(-1, keepdims=True))
            p = jnp.exp(s - m_new)
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(-1, keepdims=True)
            acc_new = acc * corr + jnp.einsum("bhgqk,bkhd->bhgqd", p, vblk)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, Hkv, G, qb, 1), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, Hkv, G, qb, 1), jnp.float32)
        a0 = jnp.zeros((B, Hkv, G, qb, Dh), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0),
            (kf.swapaxes(0, 1), vf.swapaxes(0, 1), jnp.arange(nk)))
        out = acc / jnp.maximum(l, 1e-37)
        lse = (m + jnp.log(jnp.maximum(l, 1e-37)))[..., 0]   # (B,Hkv,G,qb)
        return None, (out.transpose(0, 3, 1, 2, 4), lse)

    _, (outs, lses) = jax.lax.scan(q_step, None,
                                   (qf.swapaxes(0, 1), jnp.arange(nq)))
    out = outs.transpose(1, 0, 2, 3, 4, 5).reshape(B, Sq, Hq, Dh)
    lse = lses.transpose(1, 2, 3, 0, 4).reshape(B, Hkv, G, Sq)
    return out.astype(q.dtype), lse


def _flash_bwd_inner(q, k, v, out, lse, dout, causal, window, scale,
                     q_block, kv_block, kv_valid):
    B, Sq, Hq, Dh = q.shape
    _, Skv, Hkv, _ = k.shape
    G = Hq // Hkv
    qb, kb = q_block, kv_block
    nq, nk = Sq // qb, Skv // kb
    qf = q.reshape(B, nq, qb, Hkv, G, Dh).astype(jnp.float32)
    kf = k.reshape(B, nk, kb, Hkv, Dh).astype(jnp.float32)
    vf = v.reshape(B, nk, kb, Hkv, Dh).astype(jnp.float32)
    of = out.reshape(B, nq, qb, Hkv, G, Dh).astype(jnp.float32)
    dof = dout.reshape(B, nq, qb, Hkv, G, Dh).astype(jnp.float32)
    lsef = lse.reshape(B, Hkv, G, nq, qb)
    # D_i = rowsum(dout * out)
    delta = jnp.einsum("bnqhgd,bnqhgd->bhgnq", dof, of)

    def q_step(carry, qi):
        dk_all, dv_all = carry                             # (nk,B,kb,Hkv,Dh)
        qblk, oblk, doblk, lseblk, dblk, qidx = qi
        q_pos = qidx * qb + jnp.arange(qb)

        def kv_step(carry_in, ki):
            dk_all, dv_all, dq = carry_in
            kidx = ki
            kblk = jax.lax.dynamic_index_in_dim(kf, kidx, 1, keepdims=False)
            vblk = jax.lax.dynamic_index_in_dim(vf, kidx, 1, keepdims=False)
            kv_pos = kidx * kb + jnp.arange(kb)
            s = jnp.einsum("bqhgd,bkhd->bhgqk", qblk * scale, kblk)
            mask = kv_pos[None, :] < kv_valid
            if causal:
                mask &= kv_pos[None, :] <= q_pos[:, None]
            if window and window > 0:
                mask &= kv_pos[None, :] > q_pos[:, None] - window
            s = s + jnp.where(mask, 0.0, NEG_INF)
            p = jnp.exp(s - lseblk[..., None])              # (B,Hkv,G,qb,kb)
            dv_c = jnp.einsum("bhgqk,bqhgd->bkhd", p, doblk)
            dp = jnp.einsum("bqhgd,bkhd->bhgqk", doblk, vblk)
            ds = p * (dp - dblk[..., None])
            dq = dq + jnp.einsum("bhgqk,bkhd->bqhgd", ds, kblk) * scale
            dk_c = jnp.einsum("bhgqk,bqhgd->bkhd", ds, qblk) * scale
            dk_all = dk_all.at[kidx].add(dk_c)
            dv_all = dv_all.at[kidx].add(dv_c)
            return (dk_all, dv_all, dq), None

        dq0 = jnp.zeros((B, qb, Hkv, G, Dh), jnp.float32)
        (dk_all, dv_all, dq), _ = jax.lax.scan(
            kv_step, (dk_all, dv_all, dq0), jnp.arange(nk))
        return (dk_all, dv_all), dq

    dk0 = jnp.zeros((nk, B, kb, Hkv, Dh), jnp.float32)
    dv0 = jnp.zeros((nk, B, kb, Hkv, Dh), jnp.float32)
    (dk, dv), dqs = jax.lax.scan(
        q_step, (dk0, dv0),
        (qf.swapaxes(0, 1), of.swapaxes(0, 1), dof.swapaxes(0, 1),
         lsef.transpose(3, 0, 1, 2, 4), delta.transpose(3, 0, 1, 2, 4),
         jnp.arange(nq)))
    dq = dqs.transpose(1, 0, 2, 3, 4, 5).reshape(B, Sq, Hq, Dh).astype(q.dtype)
    dk = dk.transpose(1, 0, 2, 3, 4).reshape(B, Skv, Hkv, Dh).astype(k.dtype)
    dv = dv.transpose(1, 0, 2, 3, 4).reshape(B, Skv, Hkv, Dh).astype(v.dtype)
    return dq, dk, dv


def attention_flash(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int = 0,
                    scale: Optional[float] = None,
                    q_block: int = 512, kv_block: int = 1024) -> jax.Array:
    """Flash attention (pure JAX, custom VJP). Self-attention only
    (Sq == positions of KV), used by train/prefill paths."""
    B, Sq, Hq, Dh = q.shape
    _, Skv, Hkv, _ = k.shape
    scale = scale if scale is not None else Dh ** -0.5
    qb = min(q_block, Sq)
    kb = min(kv_block, Skv)
    sq_p = -(-Sq // qb) * qb
    skv_p = -(-Skv // kb) * kb
    qp = jnp.pad(q, ((0, 0), (0, sq_p - Sq), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, skv_p - Skv), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, skv_p - Skv), (0, 0), (0, 0)))
    @jax.custom_vjp
    def f(q, k, v):
        out, _ = _flash_fwd_inner(q, k, v, causal, window, scale, qb, kb, Skv)
        return out

    def f_fwd(q, k, v):
        out, lse = _flash_fwd_inner(q, k, v, causal, window, scale, qb, kb, Skv)
        return out, (q, k, v, out, lse)

    def f_bwd(res, dout):
        q, k, v, out, lse = res
        return _flash_bwd_inner(q, k, v, out, lse, dout, causal, window,
                                scale, qb, kb, Skv)

    f.defvjp(f_fwd, f_bwd)
    out = f(qp, kp, vp)
    return out[:, :Sq]


# ---------------------------------------------------------------------------
# Mamba-2 SSD (state-space duality)
# ---------------------------------------------------------------------------

def ssd_ref(x: jax.Array, dt: jax.Array, a_log: jax.Array,
            b: jax.Array, c: jax.Array, d: jax.Array,
            h0: Optional[jax.Array] = None):
    """Exact sequential SSD recurrence (the oracle).

    x:  (B, S, H, P)   head inputs
    dt: (B, S, H)      softplus'd timestep (>0)
    a_log: (H,)        A = -exp(a_log)
    b, c: (B, S, G, N) input/output projections (G groups, H % G == 0)
    d:  (H,)           skip
    h0: (B, H, P, N)   initial state
    returns (y (B,S,H,P), h_final (B,H,P,N))
    """
    B, S, H, P = x.shape
    G, N = b.shape[2], b.shape[3]
    rep = H // G
    a = -jnp.exp(a_log.astype(jnp.float32))              # (H,)
    bh = jnp.repeat(b, rep, axis=2).astype(jnp.float32)  # (B,S,H,N)
    ch = jnp.repeat(c, rep, axis=2).astype(jnp.float32)
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    h = jnp.zeros((B, H, P, N), jnp.float32) if h0 is None else h0.astype(jnp.float32)

    def step(h, inp):
        xt, dtt, bt, ct = inp                            # (B,H,P),(B,H),(B,H,N),(B,H,N)
        da = jnp.exp(dtt * a)                            # (B,H)
        h = h * da[..., None, None] + jnp.einsum(
            "bhp,bhn,bh->bhpn", xt, bt, dtt)
        y = jnp.einsum("bhpn,bhn->bhp", h, ct)
        return h, y

    h, ys = jax.lax.scan(step, h, (xf.swapaxes(0, 1), dtf.swapaxes(0, 1),
                                   bh.swapaxes(0, 1), ch.swapaxes(0, 1)))
    y = ys.swapaxes(0, 1) + xf * d.astype(jnp.float32)[None, None, :, None]
    return y.astype(x.dtype), h


def _segsum(t: jax.Array) -> jax.Array:
    """Stable segment-sum: out[..., i, j] = sum_{j < m <= i} t[..., m].

    Lower-triangular (i >= j) entries valid, others -inf.
    """
    n = t.shape[-1]
    cs = jnp.cumsum(t, axis=-1)
    out = cs[..., :, None] - cs[..., None, :]
    ii = jnp.arange(n)
    mask = ii[:, None] >= ii[None, :]
    return jnp.where(mask, out, -jnp.inf)


def ssd_chunked(x: jax.Array, dt: jax.Array, a_log: jax.Array,
                b: jax.Array, c: jax.Array, d: jax.Array,
                h0: Optional[jax.Array] = None, chunk: int = 256):
    """Chunked SSD (Mamba-2 paper alg.): intra-chunk dense + inter-chunk scan.

    Same signature/semantics as ``ssd_ref``; this is the form the Pallas
    kernel mirrors (MXU-friendly per-chunk matmuls, sequential chunk carry).
    """
    B, S, H, P = x.shape
    G, N = b.shape[2], b.shape[3]
    rep = H // G
    Q = min(chunk, S)
    assert S % Q == 0, f"seq {S} not divisible by chunk {Q}"
    nc = S // Q
    a = -jnp.exp(a_log.astype(jnp.float32))              # (H,)

    xf = x.reshape(B, nc, Q, H, P).astype(jnp.float32)
    dtf = dt.reshape(B, nc, Q, H).astype(jnp.float32)
    bh = jnp.repeat(b, rep, axis=2).reshape(B, nc, Q, H, N).astype(jnp.float32)
    ch = jnp.repeat(c, rep, axis=2).reshape(B, nc, Q, H, N).astype(jnp.float32)

    da = dtf * a[None, None, None, :]                    # (B,nc,Q,H) decay log per step
    cum = jnp.cumsum(da, axis=2)                         # inclusive cumsum within chunk
    seg = _segsum(da.transpose(0, 1, 3, 2))              # (B,nc,H,Q,Q)
    L = jnp.exp(seg)

    # intra-chunk (diagonal blocks): Y_d[q] = sum_{k<=q} C_q·B_k L[q,k] dt_k x_k
    scores = jnp.einsum("bcqhn,bckhn->bchqk", ch, bh)
    m = scores * L
    y_diag = jnp.einsum("bchqk,bckh,bckhp->bcqhp", m, dtf, xf)

    # per-chunk input states: S_c = sum_k exp(cum_end - cum_k) dt_k B_k x_k
    decay_to_end = jnp.exp(cum[:, :, -1:, :] - cum)      # (B,nc,Q,H)
    states = jnp.einsum("bckh,bckh,bckhn,bckhp->bchpn", decay_to_end, dtf, bh, xf)

    # inter-chunk recurrence over chunk states
    chunk_decay = jnp.exp(cum[:, :, -1, :])              # (B,nc,H)

    def chunk_step(h, inp):
        s_c, dec = inp                                   # (B,H,P,N), (B,H)
        h_out = h                                        # state entering this chunk
        h = h * dec[..., None, None] + s_c
        return h, h_out

    hinit = jnp.zeros((B, H, P, N), jnp.float32) if h0 is None else h0.astype(jnp.float32)
    h_final, h_in = jax.lax.scan(
        chunk_step, hinit, (states.swapaxes(0, 1), chunk_decay.swapaxes(0, 1)))
    h_in = h_in.swapaxes(0, 1)                           # (B,nc,H,P,N) state entering chunk

    # off-diagonal contribution: Y_off[q] = C_q · (exp(cum_q) * h_in)
    y_off = jnp.einsum("bcqhn,bchpn,bcqh->bcqhp", ch, h_in, jnp.exp(cum))

    y = (y_diag + y_off).reshape(B, S, H, P)
    y = y + x.astype(jnp.float32) * d.astype(jnp.float32)[None, None, :, None]
    return y.astype(x.dtype), h_final


# ---------------------------------------------------------------------------
# RG-LRU (RecurrentGemma / Griffin)
# ---------------------------------------------------------------------------

RGLRU_C = 8.0


def rglru_ref(x: jax.Array, r: jax.Array, i: jax.Array, lam: jax.Array,
              h0: Optional[jax.Array] = None):
    """Exact sequential RG-LRU: the oracle.

    x, r, i: (B, S, W) — input, recurrence gate (pre-sigmoid), input gate
    (pre-sigmoid); lam: (W,) Λ parameter.
    a_t = exp(-c · softplus(Λ) · σ(r_t));  h_t = a_t h_{t-1} + √(1-a_t²)·(σ(i_t)·x_t)
    returns (h (B,S,W), h_final (B,W))
    """
    B, S, W = x.shape
    log_a_base = -RGLRU_C * jax.nn.softplus(lam.astype(jnp.float32))  # (W,)
    rg = jax.nn.sigmoid(r.astype(jnp.float32))
    ig = jax.nn.sigmoid(i.astype(jnp.float32))
    log_a = log_a_base[None, None, :] * rg               # (B,S,W)
    a = jnp.exp(log_a)
    # sqrt(1 - a^2) computed stably: sqrt(-expm1(2 log a))
    beta = jnp.sqrt(-jnp.expm1(2.0 * log_a))
    gx = beta * (ig * x.astype(jnp.float32))
    h = jnp.zeros((B, W), jnp.float32) if h0 is None else h0.astype(jnp.float32)

    def step(h, inp):
        at, gxt = inp
        h = at * h + gxt
        return h, h

    h_final, hs = jax.lax.scan(step, h, (a.swapaxes(0, 1), gx.swapaxes(0, 1)))
    return hs.swapaxes(0, 1).astype(x.dtype), h_final


def rglru_assoc(x: jax.Array, r: jax.Array, i: jax.Array, lam: jax.Array,
                h0: Optional[jax.Array] = None):
    """Associative-scan RG-LRU (log-depth; the fast pure-JAX path)."""
    B, S, W = x.shape
    log_a_base = -RGLRU_C * jax.nn.softplus(lam.astype(jnp.float32))
    rg = jax.nn.sigmoid(r.astype(jnp.float32))
    ig = jax.nn.sigmoid(i.astype(jnp.float32))
    log_a = log_a_base[None, None, :] * rg
    a = jnp.exp(log_a)
    beta = jnp.sqrt(-jnp.expm1(2.0 * log_a))
    gx = beta * (ig * x.astype(jnp.float32))
    if h0 is not None:
        # fold h0 into the first element: h_1 = a_1 h0 + gx_1
        gx = gx.at[:, 0].add(a[:, 0] * h0.astype(jnp.float32))

    def combine(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a1 * a2, a2 * b1 + b2

    aa, hh = jax.lax.associative_scan(combine, (a, gx), axis=1)
    return hh.astype(x.dtype), hh[:, -1]


# ---------------------------------------------------------------------------
# Causal depthwise conv1d (mamba2 / recurrentgemma frontends)
# ---------------------------------------------------------------------------

def causal_conv1d_ref(x: jax.Array, w: jax.Array, b: Optional[jax.Array] = None,
                      state: Optional[jax.Array] = None):
    """Depthwise causal conv. x: (B,S,C), w: (K,C), state: (B,K-1,C) history.

    Returns (y (B,S,C), new_state (B,K-1,C)).
    """
    B, S, C = x.shape
    K = w.shape[0]
    hist = jnp.zeros((B, K - 1, C), x.dtype) if state is None else state.astype(x.dtype)
    xp = jnp.concatenate([hist, x], axis=1)              # (B, S+K-1, C)
    y = jnp.zeros((B, S, C), jnp.float32)
    for k in range(K):
        y = y + xp[:, k:k + S].astype(jnp.float32) * w[k].astype(jnp.float32)
    if b is not None:
        y = y + b.astype(jnp.float32)
    new_state = xp[:, S:]                                # last K-1 inputs
    return y.astype(x.dtype), new_state
