"""Pallas TPU kernel for the Mamba-2 SSD chunked scan (G=1 groups).

Grid: (batch, head_blocks, chunks); chunks are the innermost sequential axis
so the (bh, P, N) SSD state lives in VMEM scratch across chunks. Per chunk
the kernel runs the dense intra-chunk form (MXU matmuls over Q×Q decay-
masked scores) and one state update — mirroring ``ref.ssd_chunked``.

VMEM per step (defaults Q=128, bh=8, P=64, N=128): x 64 KB + b/c 64 KB +
state 256 KB f32 + Q×Q scores 64 KB ≈ well under budget.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pallas_compat import compiler_params


def _kernel(x_ref, dt_ref, alog_ref, b_ref, c_ref, d_ref, y_ref, hout_ref,
            h_ref, *, nc: int, Q: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    x = x_ref[0].astype(jnp.float32)                    # (Q, bh, P)
    dt = dt_ref[0].astype(jnp.float32)                  # (Q, bh)
    a = -jnp.exp(alog_ref[...].astype(jnp.float32))    # (bh,)
    b = b_ref[0].astype(jnp.float32)                    # (Q, N)
    c = c_ref[0].astype(jnp.float32)                    # (Q, N)
    d = d_ref[...].astype(jnp.float32)                  # (bh,)

    da = dt * a[None, :]                                # (Q, bh)
    cum = jnp.cumsum(da, axis=0)                        # inclusive
    # intra-chunk: scores (Q,Q) shared across heads (G=1)
    scores = jax.lax.dot_general(c, b, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    seg = cum.T[:, :, None] - cum.T[:, None, :]         # (bh, Q, Q)
    qi = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
    ki = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    tri = (qi >= ki)[None]
    L = jnp.where(tri, jnp.exp(seg), 0.0)
    m = scores[None] * L * dt.T[:, None, :]             # (bh, Q, Q)
    y_diag = jnp.einsum("hqk,khp->qhp", m, x,
                        preferred_element_type=jnp.float32)
    # inter-chunk: contribution of incoming state
    h = h_ref[...]                                      # (bh, P, N) f32
    y_off = jnp.einsum("qn,hpn,qh->qhp", c, h, jnp.exp(cum),
                       preferred_element_type=jnp.float32)
    # state update
    decay_to_end = jnp.exp(cum[-1:, :] - cum)           # (Q, bh)
    s_new = jnp.einsum("kh,kn,khp->hpn", dt * decay_to_end, b, x,
                       preferred_element_type=jnp.float32)
    h_ref[...] = h * jnp.exp(cum[-1, :])[:, None, None] + s_new

    y = y_diag + y_off + x * d[None, :, None]
    y_ref[0] = y.astype(y_ref.dtype)
    hout_ref[0] = h_ref[...].astype(hout_ref.dtype)


def ssd_pallas(x: jax.Array, dt: jax.Array, a_log: jax.Array,
               b: jax.Array, c: jax.Array, d: jax.Array, *,
               h0: Optional[jax.Array] = None, chunk: int = 128,
               block_heads: int = 8, interpret: bool = False):
    """Same semantics as ``ref.ssd_chunked`` restricted to G=1, h0=None.

    x (B,S,H,P); dt (B,S,H); a_log,d (H,); b,c (B,S,1,N).
    Returns (y (B,S,H,P), h_final (B,H,P,N) f32).
    """
    assert b.shape[2] == 1, "pallas ssd kernel supports G=1 (mamba2)"
    assert h0 is None, "h0 handled by the jnp path"
    B, S, H, P = x.shape
    N = b.shape[3]
    Q = min(chunk, S)
    assert S % Q == 0, (S, Q)
    nc = S // Q
    bh = min(block_heads, H)
    assert H % bh == 0, (H, bh)
    nh = H // bh
    b2 = b[:, :, 0, :]
    c2 = c[:, :, 0, :]

    kernel = functools.partial(_kernel, nc=nc, Q=Q)
    y, h_final = pl.pallas_call(
        kernel,
        grid=(B, nh, nc),
        in_specs=[
            pl.BlockSpec((1, Q, bh, P), lambda bi, hi, ci: (bi, ci, hi, 0)),
            pl.BlockSpec((1, Q, bh), lambda bi, hi, ci: (bi, ci, hi)),
            pl.BlockSpec((bh,), lambda bi, hi, ci: (hi,)),
            pl.BlockSpec((1, Q, N), lambda bi, hi, ci: (bi, ci, 0)),
            pl.BlockSpec((1, Q, N), lambda bi, hi, ci: (bi, ci, 0)),
            pl.BlockSpec((bh,), lambda bi, hi, ci: (hi,)),
        ],
        out_specs=[
            pl.BlockSpec((1, Q, bh, P), lambda bi, hi, ci: (bi, ci, hi, 0)),
            pl.BlockSpec((1, bh, P, N), lambda bi, hi, ci: (bi, hi, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, S, H, P), x.dtype),
            jax.ShapeDtypeStruct((B, H, P, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((bh, P, N), jnp.float32)],
        compiler_params=compiler_params(
            ("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x, dt, a_log, b2, c2, d)
    return y, h_final
