"""Version portability for Pallas TPU compiler params.

The params class was renamed across jax releases
(``pltpu.TPUCompilerParams`` -> ``pltpu.CompilerParams``); referencing
either name directly breaks on the other side of the rename (an
AttributeError at trace time, even in interpret mode). Every
``pl.pallas_call`` in this repo routes through this helper instead.
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu


def compiler_params(dimension_semantics: tuple):
    cls = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams
    return cls(dimension_semantics=dimension_semantics)
