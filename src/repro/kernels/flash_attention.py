"""Pallas TPU flash attention (forward) with explicit BlockSpec VMEM tiling.

Grid: (batch, q_heads, q_blocks, kv_blocks); the kv dimension is the
innermost ("arbitrary") axis so the online-softmax state lives in VMEM
scratch across kv steps. GQA is expressed in the k/v index maps (h // G).
Causal / local-window blocks that cannot contribute are skipped via
``pl.when`` (MXU work saved; the block loads are bounded by the BlockSpec).

Validated against ``ref.attention_ref`` in interpret mode on CPU; on TPU the
same kernel compiles to MXU matmuls with bq×Dh + 2·bk×Dh + bq×bk VMEM
residency per step (defaults: bq=bk=256, Dh≤256 → ≤ ~1.2 MB ≪ 16 MB VMEM).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pallas_compat import compiler_params

from repro.kernels.ref import NEG_INF


def _kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
            causal: bool, window: int, kv_len: int, scale: float,
            bq: int, bk: int, nk: int):
    i = pl.program_id(2)
    j = pl.program_id(3)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_start = i * bq
    k_start = j * bk
    needed = k_start < kv_len
    if causal:
        needed &= k_start <= q_start + bq - 1
    if window and window > 0:
        needed &= (k_start + bk - 1) > q_start - window

    @pl.when(needed)
    def _body():
        qb = q_ref[0, :, 0, :].astype(jnp.float32) * scale      # (bq, Dh)
        kb = k_ref[0, :, 0, :].astype(jnp.float32)              # (bk, Dh)
        vb = v_ref[0, :, 0, :].astype(jnp.float32)
        s = jax.lax.dot_general(qb, kb, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)  # (bq, bk)
        q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kv_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = kv_pos < kv_len
        if causal:
            mask &= kv_pos <= q_pos
        if window and window > 0:
            mask &= kv_pos > q_pos - window
        s = s + jnp.where(mask, 0.0, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(-1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + p.sum(-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            p, vb, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(j == nk - 1)
    def _finalize():
        out = acc_ref[...] / jnp.maximum(l_ref[...], 1e-37)
        o_ref[0, :, 0, :] = out.astype(o_ref.dtype)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int = 0,
                    scale: Optional[float] = None,
                    block_q: int = 256, block_kv: int = 256,
                    interpret: bool = False) -> jax.Array:
    """q (B,Sq,Hq,Dh); k,v (B,Skv,Hkv,Dh) -> (B,Sq,Hq,Dh)."""
    B, Sq, Hq, Dh = q.shape
    _, Skv, Hkv, _ = k.shape
    assert Hq % Hkv == 0
    G = Hq // Hkv
    scale = scale if scale is not None else Dh ** -0.5
    bq = min(block_q, Sq)
    bk = min(block_kv, Skv)
    sq_p = -(-Sq // bq) * bq
    skv_p = -(-Skv // bk) * bk
    qp = jnp.pad(q, ((0, 0), (0, sq_p - Sq), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, skv_p - Skv), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, skv_p - Skv), (0, 0), (0, 0)))
    nq, nk = sq_p // bq, skv_p // bk

    kernel = functools.partial(_kernel, causal=causal, window=window,
                               kv_len=Skv, scale=scale, bq=bq, bk=bk, nk=nk)
    out = pl.pallas_call(
        kernel,
        grid=(B, Hq, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, 1, Dh), lambda b, h, i, j: (b, i, h, 0)),
            pl.BlockSpec((1, bk, 1, Dh), lambda b, h, i, j: (b, j, h // G, 0)),
            pl.BlockSpec((1, bk, 1, Dh), lambda b, h, i, j: (b, j, h // G, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, 1, Dh), lambda b, h, i, j: (b, i, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, sq_p, Hq, Dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, Dh), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
        ],
        compiler_params=compiler_params(
            ("parallel", "parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(qp, kp, vp)
    return out[:, :Sq]
