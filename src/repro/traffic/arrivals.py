"""Per-region request-arrival generation for synthetic user populations.

A `UserPopulation` maps users -> home region -> diurnal phase:
each region gets a user count (largest-remainder split of `n_users`
over `region_weights`), each user draws a lognormal mean request rate
(so a few heavy users coexist with a long light tail), and the region's
aggregate stream is the per-user total shaped by

  - a time-zone-shifted diurnal sinusoid (peak at 15:00 *local* time,
    amplitude set by `peak_to_trough`), and
  - the same AR(1)+burst minutes-scale noise the Azure-like utilization
    generator uses (`repro.workload.azure_like.ar1_burst_factors`) —
    the paper's point that workload swings faster than carbon.

Only the (T, R) aggregate ever materializes: per-user draws are summed
in chunks, so `n_users=10**6` costs a few hundred ms and O(chunk)
scratch regardless of horizon.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import numpy as np

from repro.workload.azure_like import ar1_burst_factors

_PEAK_HOUR_LOCAL = 15.0      # diurnal peak at 15:00 local time


@dataclass(frozen=True)
class UserPopulation:
    """Spec for a synthetic user population spread over R regions."""
    n_users: int = 1_000_000
    n_regions: int = 3
    region_weights: Optional[tuple] = None   # default: uniform
    tz_offset_h: Optional[tuple] = None      # default: evenly spread over 24h
    req_per_user_day: float = 50.0
    rate_lognorm_sigma: float = 1.0          # per-user rate spread (log space)
    peak_to_trough: float = 3.0              # diurnal peak/trough ratio
    cov: float = 0.25                        # AR(1)+burst noise volatility
    normalize: bool = True                   # pin per-region totals exactly
    seed: int = 0

    def weights(self) -> np.ndarray:
        if self.region_weights is None:
            return np.full(self.n_regions, 1.0 / self.n_regions)
        w = np.asarray(self.region_weights, dtype=np.float64)
        if w.shape != (self.n_regions,) or w.min() < 0 or w.sum() <= 0:
            raise ValueError(f"region_weights {self.region_weights!r} "
                             f"invalid for n_regions={self.n_regions}")
        return w / w.sum()

    def tz_offsets(self) -> np.ndarray:
        if self.tz_offset_h is None:
            return np.arange(self.n_regions) * (24.0 / self.n_regions)
        tz = np.asarray(self.tz_offset_h, dtype=np.float64)
        if tz.shape != (self.n_regions,):
            raise ValueError(f"tz_offset_h needs {self.n_regions} entries")
        return tz

    def user_counts(self) -> np.ndarray:
        """Largest-remainder split of n_users over the region weights."""
        quota = self.weights() * self.n_users
        counts = np.floor(quota).astype(np.int64)
        short = self.n_users - int(counts.sum())
        if short:
            order = np.argsort(-(quota - counts), kind="stable")
            counts[order[:short]] += 1
        return counts


@dataclass
class ArrivalTensor:
    """(T, R) requests-per-epoch plus the population facts behind it."""
    requests: np.ndarray         # (T, R) requests arriving per epoch
    users: np.ndarray            # (R,) user counts
    tz_offset_h: np.ndarray      # (R,)
    req_per_day: np.ndarray      # (R,) aggregate daily request totals
    interval_s: float

    @property
    def n_users(self) -> int:
        return int(self.users.sum())

    @property
    def offered_total(self) -> float:
        return float(self.requests.sum())


def _diurnal_shape(T: int, interval_s: float, tz: np.ndarray,
                   peak_to_trough: float) -> np.ndarray:
    """(T, R) mean-1 sinusoid peaking at 15:00 local time per region."""
    amp = (peak_to_trough - 1.0) / (peak_to_trough + 1.0)
    hours = np.arange(T, dtype=np.float64) * interval_s / 3600.0
    local = hours[:, None] + tz[None, :]
    phase = 2.0 * np.pi * (local - _PEAK_HOUR_LOCAL) / 24.0
    return np.maximum(1.0 + amp * np.cos(phase), 0.05)


def request_matrix(pop: UserPopulation, T: int, interval_s: float = 300.0,
                   chunk: int = 200_000) -> ArrivalTensor:
    """Aggregate the population's request streams to (T, R) per-epoch
    counts. Per-user mean rates are lognormal with the -sigma^2/2
    correction (population mean stays `req_per_user_day`), summed in
    `chunk`-sized blocks; with `normalize` each region's noisy shape is
    rescaled to mean 1 so the horizon total is exactly
    `users[r] * req_per_user_day * days`."""
    rng = np.random.default_rng(pop.seed)
    users = pop.user_counts()
    R = pop.n_regions
    sig = pop.rate_lognorm_sigma
    mu = np.log(max(pop.req_per_user_day, 1e-12)) - 0.5 * sig ** 2
    req_day = np.zeros(R)
    for r in range(R):
        remaining = int(users[r])
        while remaining > 0:
            k = min(chunk, remaining)
            req_day[r] += float(np.exp(rng.normal(mu, sig, k)).sum())
            remaining -= k

    tz = pop.tz_offsets()
    shape = _diurnal_shape(T, interval_s, tz, pop.peak_to_trough)
    noise = ar1_burst_factors(rng, T, np.full(R, max(pop.cov, 0.02)))
    factors = shape * noise
    if pop.normalize:
        factors = factors / np.maximum(factors.mean(axis=0), 1e-12)
    requests = req_day[None, :] * (interval_s / 86400.0) * factors
    return ArrivalTensor(requests=requests, users=users, tz_offset_h=tz,
                         req_per_day=req_day, interval_s=float(interval_s))
