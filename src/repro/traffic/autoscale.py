"""Replica autoscaling under a carbon cap (CarbonScaler-style greedy).

Each epoch, routed load per region is converted to a replica count:

  - `need = ceil(load / cap1)` replicas would serve everything
    (`cap1 = throughput_rps * interval_s` requests per replica-epoch);
  - ramp limits (`max_step`) and floors/ceilings (`min_replicas`,
    `max_replicas`) bound the reachable range `[lo, hi]` around the
    previous count; replicas up to `lo` are *mandatory* (they run
    regardless of carbon);
  - with a `budget_g_per_epoch` carbon cap, the *optional* replicas
    (`lo < k <= desired`) across all regions compete by marginal
    carbon-efficiency: replica k of region r serves marginal work
    `w(r,k) = clip(load_r - (k-1)*cap1, 0, cap1)` at marginal grams
    `g(r,k)` from its utilization-dependent power draw; the greedy
    flattens the (R, K) table, sorts by efficiency `w/g` descending
    (stable, so ties keep region-major replica order and per-region
    prefixes stay valid) and admits down the list while the running
    `cumsum` of grams fits under the cap — the CarbonScaler allocation
    (PAPERS.md), as a sort + cumsum instead of a loop.

`autoscale` is the vectorized implementation (one (R, K) table per
epoch); `autoscale_scalar` is the pure-Python reference. All reductions
that feed threshold comparisons are left folds in both (running sums vs
`np.cumsum`), so the two are bit-identical — pinned <=1e-9 by the tests.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import numpy as np


@dataclass(frozen=True)
class ReplicaConfig:
    """Per-region replica fleet spec (homogeneous replicas)."""
    throughput_rps: float = 100.0     # requests/s one replica serves
    base_w: float = 60.0              # idle power per replica
    peak_w: float = 120.0             # full-utilization power per replica
    max_replicas: int = 64            # per-region ceiling (K of the table)
    min_replicas: int = 1             # per-region floor (always running)
    max_step: int = 8                 # max replica delta per epoch
    budget_g_per_epoch: Optional[float] = None   # fleet-wide carbon cap

    def __post_init__(self):
        if self.min_replicas > self.max_replicas:
            raise ValueError("min_replicas exceeds max_replicas")
        if self.max_step < 0 or self.max_replicas < 1:
            raise ValueError("max_step must be >= 0, max_replicas >= 1")
        if self.throughput_rps <= 0:
            raise ValueError("throughput_rps must be positive")

    def cap1(self, interval_s: float) -> float:
        """Requests one replica serves in one epoch."""
        return self.throughput_rps * interval_s

    def max_capacity(self, interval_s: float) -> float:
        """Requests-per-epoch ceiling of a fully scaled region."""
        return self.max_replicas * self.cap1(interval_s)


@dataclass
class AutoscaleResult:
    replicas: np.ndarray      # (T, R) int64 replica counts
    served: np.ndarray        # (T, R) requests served
    dropped: np.ndarray       # (T, R) routed load beyond replica capacity
    emissions_g: np.ndarray   # (T, R) replica-fleet emissions
    cap1: float               # requests per replica-epoch

    @property
    def replica_epochs(self) -> float:
        return float(self.replicas.sum())


def autoscale(routed, carbon, cfg: ReplicaConfig,
              interval_s: float = 300.0) -> AutoscaleResult:
    """Vectorized autoscaler: one (R, K) marginal table per epoch."""
    routed = np.asarray(routed, dtype=np.float64)
    carbon = np.asarray(carbon, dtype=np.float64)
    if routed.shape != carbon.shape or routed.ndim != 2:
        raise ValueError(f"routed {routed.shape} / carbon {carbon.shape} "
                         f"must both be (T, R)")
    T, R = routed.shape
    dt = float(interval_s)
    cap1 = cfg.cap1(dt)
    span = cfg.peak_w - cfg.base_w
    K = cfg.max_replicas
    k_idx = np.arange(1, K + 1, dtype=np.float64)[None, :]   # (1, K)
    reg_of = np.repeat(np.arange(R), K)                      # flat -> region

    replicas = np.zeros((T, R), dtype=np.int64)
    served = np.zeros((T, R))
    dropped = np.zeros((T, R))
    emissions = np.zeros((T, R))
    prev = np.full(R, float(cfg.min_replicas))
    for t in range(T):
        load = routed[t]
        c = carbon[t]
        need = np.ceil(load / cap1)
        lo = np.maximum(float(cfg.min_replicas), prev - cfg.max_step)
        hi = np.minimum(float(cfg.max_replicas), prev + cfg.max_step)
        desired = np.minimum(np.maximum(need, lo), hi)
        if cfg.budget_g_per_epoch is None:
            n = desired
        else:
            w = np.clip(load[:, None] - (k_idx - 1.0) * cap1, 0.0, cap1)
            g = ((cfg.base_w + span * (w / cap1))
                 * dt / 3600.0 * c[:, None] / 1000.0)
            mand = k_idx <= lo[:, None]
            opt = (k_idx > lo[:, None]) & (k_idx <= desired[:, None])
            mand_flat = np.where(mand, g, 0.0).ravel()
            mand_g = float(np.cumsum(mand_flat)[-1]) if mand_flat.size else 0.0
            # zero-gram entries (carbon intensity 0) are free: admit them
            # first (-inf score) instead of dividing — w/tiny overflows
            free = g <= 0.0
            eff = w / np.where(free, 1.0, g)
            score = np.where(opt, np.where(free, -np.inf, -eff),
                             np.inf).ravel()
            order = np.argsort(score, kind="stable")
            gs = np.where(opt, g, 0.0).ravel()[order]
            cum = np.cumsum(gs)
            admit = (opt.ravel()[order]
                     & (mand_g + cum <= cfg.budget_g_per_epoch))
            counts = np.bincount(reg_of[order[admit]], minlength=R)
            n = lo + counts
        srv = np.minimum(load, n * cap1)
        pw = n * cfg.base_w + span * (srv / cap1)
        replicas[t] = n.astype(np.int64)
        served[t] = srv
        dropped[t] = load - srv
        emissions[t] = pw * dt / 3600.0 * c / 1000.0
        prev = n
    return AutoscaleResult(replicas=replicas, served=served, dropped=dropped,
                           emissions_g=emissions, cap1=cap1)


def autoscale_scalar(routed, carbon, cfg: ReplicaConfig,
                     interval_s: float = 300.0) -> AutoscaleResult:
    """Pure-Python reference autoscaler (parity <=1e-9 with
    `autoscale`; replica counts identical)."""
    routed = np.asarray(routed, dtype=np.float64)
    carbon = np.asarray(carbon, dtype=np.float64)
    T, R = routed.shape
    dt = float(interval_s)
    cap1 = cfg.cap1(dt)
    span = cfg.peak_w - cfg.base_w
    K = cfg.max_replicas

    replicas = np.zeros((T, R), dtype=np.int64)
    served = np.zeros((T, R))
    dropped = np.zeros((T, R))
    emissions = np.zeros((T, R))
    prev = [float(cfg.min_replicas)] * R
    for t in range(T):
        lo, hi, desired = [], [], []
        for r in range(R):
            load = float(routed[t, r])
            need = float(np.ceil(load / cap1))
            lo_r = max(float(cfg.min_replicas), prev[r] - cfg.max_step)
            hi_r = min(float(cfg.max_replicas), prev[r] + cfg.max_step)
            lo.append(lo_r)
            hi.append(hi_r)
            desired.append(min(max(need, lo_r), hi_r))
        if cfg.budget_g_per_epoch is None:
            n = list(desired)
        else:
            w_tab, g_tab, score = {}, {}, {}
            mand_g = 0.0
            opt_flat = []
            for r in range(R):
                load = float(routed[t, r])
                c = float(carbon[t, r])
                for k in range(1, K + 1):
                    w = min(max(load - (k - 1.0) * cap1, 0.0), cap1)
                    g = ((cfg.base_w + span * (w / cap1))
                         * dt / 3600.0 * c / 1000.0)
                    i = r * K + (k - 1)
                    w_tab[i], g_tab[i] = w, g
                    if k <= lo[r]:
                        mand_g += g
                    is_opt = lo[r] < k <= desired[r]
                    opt_flat.append(is_opt)
                    # same zero-gram guard as the vectorized path
                    eff = 0.0 if g <= 0.0 else w / g
                    sc = -np.inf if g <= 0.0 else -eff
                    score[i] = sc if is_opt else np.inf
            order = sorted(range(R * K), key=lambda i: score[i])
            counts = [0] * R
            cum = 0.0
            for i in order:
                cum += g_tab[i] if opt_flat[i] else 0.0
                if opt_flat[i] and mand_g + cum <= cfg.budget_g_per_epoch:
                    counts[i // K] += 1
            n = [lo[r] + counts[r] for r in range(R)]
        for r in range(R):
            load = float(routed[t, r])
            c = float(carbon[t, r])
            srv = min(load, n[r] * cap1)
            pw = n[r] * cfg.base_w + span * (srv / cap1)
            replicas[t, r] = int(n[r])
            served[t, r] = srv
            dropped[t, r] = load - srv
            emissions[t, r] = pw * dt / 3600.0 * c / 1000.0
        prev = list(n)
    return AutoscaleResult(replicas=replicas, served=served, dropped=dropped,
                           emissions_g=emissions, cap1=cap1)
