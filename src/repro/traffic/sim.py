"""The traffic pipeline: requests -> routing -> autoscaling -> metrics.

`simulate_traffic` runs a (T, R) request tensor through the
SLO-constrained router (capacity = each region's fully scaled replica
fleet) and the carbon-capped autoscaler, and returns a `TrafficResult`
with the serving ledger: served/dropped requests, SLO violations,
replica-fleet emissions and carbon-per-request. `demand_mod()` turns
the per-region serving load into the (T, R) demand-modulation matrix
the fleet backends multiply into container demand
(`sweep_population(..., traffic=TrafficConfig(...))`).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

import numpy as np

from repro.traffic.arrivals import UserPopulation
from repro.traffic.autoscale import ReplicaConfig, autoscale, autoscale_scalar
from repro.traffic.routing import (RoutingConfig, latency_from_timezones,
                                   route, route_scalar)


@dataclass(frozen=True)
class TrafficConfig:
    """Everything the traffic layers need, bundled for `sweep_population`."""
    population: UserPopulation = field(default_factory=UserPopulation)
    routing: RoutingConfig = field(default_factory=RoutingConfig)
    replicas: ReplicaConfig = field(default_factory=ReplicaConfig)
    latency_ms: Optional[tuple] = None   # (R, R) rows; default from tz
    demand_gain: float = 1.0             # container-demand coupling gain

    def latency_matrix(self) -> np.ndarray:
        if self.latency_ms is not None:
            lat = np.asarray(self.latency_ms, dtype=np.float64)
            R = self.population.n_regions
            if lat.shape != (R, R):
                raise ValueError(f"latency_ms shape {lat.shape}; "
                                 f"expected ({R}, {R})")
            return lat
        return latency_from_timezones(self.population.tz_offsets())


@dataclass
class TrafficResult:
    """Serving ledger for one traffic run (all per source/serving region)."""
    requests: np.ndarray       # (T, R) offered demand per source region
    routed: np.ndarray         # (T, R) load arriving per serving region
    replicas: np.ndarray       # (T, R) int64 replica counts
    served: np.ndarray         # (T, R) requests served per serving region
    dropped_route: np.ndarray  # (T, R) dropped at routing (no capacity)
    dropped_cap: np.ndarray    # (T, R) dropped at serving (ramp/budget)
    violations: np.ndarray     # (T, R) served outside SLO, per source
    emissions_g: np.ndarray    # (T, R) replica-fleet emissions
    max_capacity: float        # requests/epoch of a fully scaled region
    interval_s: float

    @property
    def offered_total(self) -> float:
        return float(self.requests.sum())

    @property
    def served_total(self) -> float:
        return float(self.served.sum())

    @property
    def dropped_total(self) -> float:
        return float(self.dropped_route.sum() + self.dropped_cap.sum())

    @property
    def violation_total(self) -> float:
        return float(self.violations.sum())

    @property
    def emissions_total_g(self) -> float:
        return float(self.emissions_g.sum())

    @property
    def drop_rate(self) -> float:
        return self.dropped_total / max(self.offered_total, 1e-12)

    @property
    def violation_rate(self) -> float:
        """SLO-violating fraction of offered requests."""
        return self.violation_total / max(self.offered_total, 1e-12)

    @property
    def carbon_per_request_g(self) -> float:
        return self.emissions_total_g / max(self.served_total, 1e-12)

    def demand_mod(self, gain: float = 1.0) -> np.ndarray:
        """(T, R) container-demand multiplier: each region's serving
        load as a fraction of its fully scaled capacity, times `gain`."""
        return gain * self.served / self.max_capacity

    def summary(self) -> dict:
        return {
            "traffic_offered": self.offered_total,
            "traffic_served": self.served_total,
            "traffic_dropped": self.dropped_total,
            "traffic_slo_violations": self.violation_total,
            "traffic_violation_rate": self.violation_rate,
            "traffic_drop_rate": self.drop_rate,
            "traffic_emissions_g": self.emissions_total_g,
            "traffic_carbon_per_request_g": self.carbon_per_request_g,
            "traffic_replica_epochs": float(self.replicas.sum()),
        }


def simulate_traffic(requests, region_intensity, cfg: TrafficConfig,
                     interval_s: float = 300.0,
                     backend: str = "numpy") -> TrafficResult:
    """Route + autoscale a (T, R) request tensor against the per-region
    carbon-intensity matrix. `backend` picks the vectorized kernels
    ("numpy") or the pure-Python references ("scalar"); the pair is
    parity-pinned <=1e-9."""
    requests = np.asarray(requests, dtype=np.float64)
    region_intensity = np.asarray(region_intensity, dtype=np.float64)
    if requests.shape != region_intensity.shape or requests.ndim != 2:
        raise ValueError(f"requests {requests.shape} / region intensity "
                         f"{region_intensity.shape} must both be (T, R)")
    R = requests.shape[1]
    if R != cfg.population.n_regions:
        raise ValueError(f"traffic population spans "
                         f"{cfg.population.n_regions} regions but the "
                         f"request tensor has {R} columns")
    lat = cfg.latency_matrix()
    cap = cfg.replicas.max_capacity(interval_s)
    if backend == "numpy":
        route_fn, scale_fn = route, autoscale
    elif backend == "scalar":
        route_fn, scale_fn = route_scalar, autoscale_scalar
    else:
        raise ValueError(f"unknown traffic backend {backend!r}")
    rt = route_fn(requests, cap, region_intensity, lat, cfg.routing)
    asr = scale_fn(rt.routed, region_intensity, cfg.replicas, interval_s)
    return TrafficResult(
        requests=requests, routed=rt.routed, replicas=asr.replicas,
        served=asr.served, dropped_route=rt.dropped, dropped_cap=asr.dropped,
        violations=rt.violations, emissions_g=asr.emissions_g,
        max_capacity=cap, interval_s=float(interval_s))
