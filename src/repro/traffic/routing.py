"""SLO-constrained carbon-aware request routing (CASPER-style).

Each epoch, each source region's request demand is split across serving
regions by greedy water-filling: regions are ranked per source by the
policy key (carbon intensity for ``policy="carbon"``, network latency
for ``policy="latency"``) with SLO-infeasible regions pushed after all
feasible ones, then rank-by-rank each serving region admits its
requesters in source-index order up to remaining capacity. With
``spill=True`` leftovers overflow into SLO-infeasible regions (served,
but counted as SLO violations); otherwise they are dropped.

`route_scalar` is the pure-Python per-epoch reference; `route` is the
vectorized kernel (one pass over all T epochs, O(R^2) small-array
rounds). Both compute admission from the *cumulative-wants* form

    take_s = min(want_s, max(avail - cum_before_s, 0))

with the exclusive prefix sum taken as a shifted inclusive `cumsum`
(a left fold in both implementations), so the two are bit-identical —
the 1e-9 parity the tests and the `traffic_sweep` benchmark gate pin.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

_BIG = 1e9        # rank offset pushing SLO-infeasible regions last


@dataclass(frozen=True)
class RoutingConfig:
    slo_ms: float = 150.0
    policy: str = "carbon"     # "carbon" | "latency"
    spill: bool = True         # serve leftovers out-of-SLO (else drop)


@dataclass
class RouteResult:
    flows: np.ndarray        # (T, S, R) requests routed source -> serving
    routed: np.ndarray       # (T, R) load arriving at each serving region
    dropped: np.ndarray      # (T, S) requests no region could take
    violations: np.ndarray   # (T, S) requests served outside the SLO
    feasible: np.ndarray     # (S, R) SLO-feasibility mask

    @property
    def offered(self) -> float:
        return float(self.flows.sum() + self.dropped.sum())


def latency_from_timezones(tz_offset_h, base_ms: float = 20.0,
                           ms_per_hour: float = 15.0) -> np.ndarray:
    """(R, R) latency matrix from time-zone offsets: base RTT plus a
    term in the circular hour distance (a stand-in for geographic
    distance — regions 12h apart are antipodal)."""
    tz = np.asarray(tz_offset_h, dtype=np.float64)
    d = np.abs(tz[:, None] - tz[None, :]) % 24.0
    d = np.minimum(d, 24.0 - d)
    return base_ms + ms_per_hour * d


def _check_inputs(demand, capacity, carbon, latency):
    demand = np.asarray(demand, dtype=np.float64)
    if demand.ndim == 1:
        demand = demand[None, :]
    T, S = demand.shape
    latency = np.asarray(latency, dtype=np.float64)
    if latency.shape != (S, S):
        raise ValueError(f"latency matrix shape {latency.shape}; "
                         f"expected ({S}, {S})")
    carbon = np.asarray(carbon, dtype=np.float64)
    if carbon.shape != (T, S):
        raise ValueError(f"carbon matrix shape {carbon.shape}; "
                         f"expected ({T}, {S})")
    capacity = np.broadcast_to(
        np.asarray(capacity, dtype=np.float64), (S,)).copy()
    if not np.all(np.isfinite(capacity)) or capacity.min() < 0:
        raise ValueError("capacity must be finite and non-negative")
    return demand, capacity, carbon, latency, T, S


def _score(carbon_row, latency, feas, policy):
    """(S, R) preference score: policy key + big infeasibility offset."""
    if policy == "carbon":
        key = np.broadcast_to(carbon_row[None, :], latency.shape)
    elif policy == "latency":
        key = latency
    else:
        raise ValueError(f"unknown routing policy {policy!r}")
    return key + np.where(feas, 0.0, _BIG)


def route(demand, capacity, carbon, latency,
          cfg: RoutingConfig = RoutingConfig()) -> RouteResult:
    """Vectorized router over all T epochs at once."""
    demand, capacity, carbon, latency, T, S = _check_inputs(
        demand, capacity, carbon, latency)
    feas = latency <= cfg.slo_ms                        # (S, R)
    n_feas = feas.sum(axis=1)                           # (S,)

    flows = np.zeros((T, S, S))
    remaining = demand.copy()                           # (T, S)
    avail = np.broadcast_to(capacity[None, :], (T, S)).copy()
    avail0 = avail.copy()

    # per-source preference ranks (carbon keys vary over T, so the
    # argsort is per epoch; latency keys are epoch-invariant)
    offs = np.where(feas, 0.0, _BIG)                    # (S, R)
    if cfg.policy == "carbon":
        score = carbon[:, None, :] + offs[None, :, :]
    else:
        score = np.broadcast_to((latency + offs)[None, :, :],
                                (T, S, S)).copy()
    pref = np.argsort(score, axis=2, kind="stable")     # (T, S, R)

    for k in range(S):
        choice = pref[:, :, k]                          # (T, S)
        requesting = (np.ones((T, S), dtype=bool) if cfg.spill
                      else (k < n_feas)[None, :] & np.ones((T, 1), dtype=bool))
        for r in range(S):
            m = (choice == r) & requesting
            want = np.where(m, remaining, 0.0)          # (T, S)
            cum = np.cumsum(want, axis=1)
            cum_before = np.concatenate(
                [np.zeros((T, 1)), cum[:, :-1]], axis=1)
            take = np.minimum(want,
                              np.maximum(avail[:, r:r + 1] - cum_before, 0.0))
            flows[:, :, r] += take
            remaining = remaining - take
            avail[:, r] = np.maximum(avail[:, r] - cum[:, -1], 0.0)
    routed = avail0 - avail                             # (T, R)
    violations = (flows * (~feas)[None, :, :]).sum(axis=2)
    return RouteResult(flows=flows, routed=routed, dropped=remaining,
                       violations=violations, feasible=feas)


def route_scalar(demand, capacity, carbon, latency,
                 cfg: RoutingConfig = RoutingConfig()) -> RouteResult:
    """Pure-Python per-epoch reference router (same arithmetic as
    `route`, loop-by-loop; the parity tests pin <=1e-9)."""
    demand, capacity, carbon, latency, T, S = _check_inputs(
        demand, capacity, carbon, latency)
    feas = latency <= cfg.slo_ms
    n_feas = feas.sum(axis=1)

    flows = np.zeros((T, S, S))
    dropped = np.zeros((T, S))
    routed = np.zeros((T, S))
    for t in range(T):
        remaining = [float(demand[t, s]) for s in range(S)]
        avail = [float(capacity[r]) for r in range(S)]
        prefs = []
        for s in range(S):
            sc = [(float(carbon[t, r]) if cfg.policy == "carbon"
                   else float(latency[s, r]))
                  + (0.0 if feas[s, r] else _BIG) for r in range(S)]
            prefs.append(sorted(range(S), key=lambda r: sc[r]))
        for k in range(S):
            for r in range(S):
                cum_before = 0.0
                takes = []
                for s in range(S):
                    requesting = cfg.spill or k < n_feas[s]
                    want = (remaining[s]
                            if prefs[s][k] == r and requesting else 0.0)
                    take = min(want, max(avail[r] - cum_before, 0.0))
                    cum_before += want
                    takes.append((s, take))
                for s, take in takes:
                    flows[t, s, r] += take
                    remaining[s] -= take
                avail[r] = max(avail[r] - cum_before, 0.0)
        for s in range(S):
            dropped[t, s] = remaining[s]
            routed[t, s] = float(capacity[s]) - avail[s]
    violations = (flows * (~feas)[None, :, :]).sum(axis=2)
    return RouteResult(flows=flows, routed=routed, dropped=dropped,
                       violations=violations, feasible=feas)
