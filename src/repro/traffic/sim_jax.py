"""JAX traffic step: routing + autoscaling as one pure scan step.

`traffic_step` is the per-epoch routing + autoscaling update as a pure
function on (R,)-shaped arrays with a static `TrafficSpec` — small
enough to fold straight into the fleet backend's `lax.scan` epoch step
(`repro.core.fleet_jax._fleet_scan`), which is how
`sweep_population(..., backend="jax", traffic=...)` keeps the N=1M
placed sweep free of (T, N) intermediates: the scan carries only the
(R,) replica vector extra, and each epoch's demand modulation is an
R-way select over the epoch's (R,) mod row.

`simulate_traffic_jax` scans the same step standalone and returns the
usual `TrafficResult` — parity with the NumPy pipeline is pinned <=1e-6
by tests/test_traffic_jax.py (replica counts match exactly). The
arithmetic mirrors `routing.route` / `autoscale.autoscale` term for
term; the only float drift is XLA's `cumsum`/reduction association.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import numpy as np

from repro.traffic.sim import TrafficConfig, TrafficResult

try:
    import jax
    import jax.numpy as jnp
    from jax import lax
    from jax.experimental import enable_x64
    HAS_JAX = True
except ImportError:                                    # pragma: no cover
    HAS_JAX = False
    jax = jnp = lax = enable_x64 = None

_BIG = 1e9


class TrafficSpec(NamedTuple):
    """Hashable static spec for `traffic_step` (jit static arg)."""
    feas: tuple            # R rows of R bools (SLO feasibility)
    n_feas: tuple          # feasible-region count per source
    lat: tuple             # R rows of R floats
    policy: str
    spill: bool
    thru: float
    base_w: float
    peak_w: float
    kmax: int
    min_rep: int
    max_step: int
    budget: Optional[float]
    gain: float
    dt: float
    R: int

    @classmethod
    def from_config(cls, cfg: TrafficConfig,
                    interval_s: float) -> "TrafficSpec":
        lat = cfg.latency_matrix()
        feas = lat <= cfg.routing.slo_ms
        rc = cfg.replicas
        return cls(
            feas=tuple(tuple(bool(x) for x in row) for row in feas),
            n_feas=tuple(int(x) for x in feas.sum(axis=1)),
            lat=tuple(tuple(float(x) for x in row) for row in lat),
            policy=cfg.routing.policy, spill=bool(cfg.routing.spill),
            thru=float(rc.throughput_rps), base_w=float(rc.base_w),
            peak_w=float(rc.peak_w), kmax=int(rc.max_replicas),
            min_rep=int(rc.min_replicas), max_step=int(rc.max_step),
            budget=(None if rc.budget_g_per_epoch is None
                    else float(rc.budget_g_per_epoch)),
            gain=float(cfg.demand_gain), dt=float(interval_s),
            R=int(cfg.population.n_regions))

    @property
    def cap1(self) -> float:
        return self.thru * self.dt

    @property
    def max_capacity(self) -> float:
        return self.kmax * self.cap1


def traffic_step(spec: TrafficSpec, rep0, req_row, c_row):
    """One epoch: route `req_row` by the carbon row, autoscale replicas.

    Returns ``(rep1, (mod, routed, served, drop_route, drop_cap, viol,
    emis))`` — all (R,) f64 except the carry `rep1`. Pure; trace-safe
    inside any surrounding scan.
    """
    R = spec.R
    feas = np.asarray(spec.feas, dtype=bool)
    offs = np.where(feas, 0.0, _BIG)                   # static (R, R)
    lat = np.asarray(spec.lat, dtype=np.float64)
    cap1 = spec.cap1
    cap = spec.max_capacity

    # ---- routing: greedy water-filling in preference-rank rounds ----
    if spec.policy == "carbon":
        score = c_row[None, :] + offs
    else:
        score = jnp.asarray(lat + offs)
    pref = jnp.argsort(score, axis=1)                  # stable by default
    remaining = req_row
    avail = jnp.full(R, cap, dtype=jnp.float64)
    viol = jnp.zeros(R, dtype=jnp.float64)
    for k in range(R):
        choice = pref[:, k]
        if spec.spill:
            requesting = np.ones(R, dtype=bool)
        else:
            requesting = np.array([k < spec.n_feas[s] for s in range(R)])
        for r in range(R):
            m = (choice == r) & requesting
            want = jnp.where(m, remaining, 0.0)
            cum = jnp.cumsum(want)
            cum_before = jnp.concatenate(
                [jnp.zeros(1, dtype=jnp.float64), cum[:-1]])
            take = jnp.minimum(want,
                               jnp.maximum(avail[r] - cum_before, 0.0))
            # infeasible (source, r) pairs are static: spilled service
            viol = viol + take * (~feas[:, r]).astype(np.float64)
            remaining = remaining - take
            avail = avail.at[r].set(jnp.maximum(avail[r] - cum[-1], 0.0))
    routed = cap - avail
    drop_route = remaining

    # ---- autoscaling: CarbonScaler greedy over the (R, K) table ----
    need = jnp.ceil(routed / cap1)
    lo = jnp.maximum(float(spec.min_rep), rep0 - spec.max_step)
    hi = jnp.minimum(float(spec.kmax), rep0 + spec.max_step)
    desired = jnp.minimum(jnp.maximum(need, lo), hi)
    span = spec.peak_w - spec.base_w
    if spec.budget is None:
        n = desired
    else:
        K = spec.kmax
        k_idx = np.arange(1, K + 1, dtype=np.float64)[None, :]
        reg_of = np.repeat(np.arange(R), K)
        w = jnp.clip(routed[:, None] - (k_idx - 1.0) * cap1, 0.0, cap1)
        g = ((spec.base_w + span * (w / cap1))
             * spec.dt / 3600.0 * c_row[:, None] / 1000.0)
        mand = k_idx <= lo[:, None]
        opt = (k_idx > lo[:, None]) & (k_idx <= desired[:, None])
        mand_g = jnp.cumsum(jnp.where(mand, g, 0.0).ravel())[-1]
        # zero-gram guard: free entries admitted first, no overflow div
        freeg = g <= 0.0
        eff = w / jnp.where(freeg, 1.0, g)
        score2 = jnp.where(opt, jnp.where(freeg, -jnp.inf, -eff),
                           jnp.inf).ravel()
        order = jnp.argsort(score2)                    # stable by default
        gs = jnp.where(opt, g, 0.0).ravel()[order]
        cum_g = jnp.cumsum(gs)
        admit = opt.ravel()[order] & (mand_g + cum_g <= spec.budget)
        reg_sorted = jnp.asarray(reg_of)[order]
        counts = jnp.sum(admit[:, None]
                         & (reg_sorted[:, None] == np.arange(R)[None, :]),
                         axis=0)
        n = lo + counts
    served = jnp.minimum(routed, n * cap1)
    drop_cap = routed - served
    pw = n * spec.base_w + span * (served / cap1)
    emis = pw * spec.dt / 3600.0 * c_row / 1000.0
    mod = spec.gain * served / cap
    return n, (mod, routed, served, drop_route, drop_cap, viol, emis)


def simulate_traffic_jax(requests, region_intensity, cfg: TrafficConfig,
                         interval_s: float = 300.0) -> TrafficResult:
    """Standalone scan of `traffic_step` over all T epochs (float64)."""
    if not HAS_JAX:
        raise ImportError("simulate_traffic_jax requires jax; use "
                          "repro.traffic.sim.simulate_traffic")
    requests = np.asarray(requests, dtype=np.float64)
    region_intensity = np.asarray(region_intensity, dtype=np.float64)
    spec = TrafficSpec.from_config(cfg, interval_s)
    R = spec.R
    if requests.shape != region_intensity.shape or requests.ndim != 2 \
            or requests.shape[1] != R:
        raise ValueError(f"requests {requests.shape} / intensity "
                         f"{region_intensity.shape} must be (T, {R})")

    def step(rep, x):
        req_row, c_row = x
        rep1, outs = traffic_step(spec, rep, req_row, c_row)
        return rep1, outs + (rep1,)

    with enable_x64():
        rep0 = jnp.full(R, float(spec.min_rep), dtype=jnp.float64)
        _, ys = jax.jit(lambda xs: lax.scan(step, rep0, xs))(
            (jnp.asarray(requests), jnp.asarray(region_intensity)))
        _, routed, served, drop_route, drop_cap, viol, emis, reps = (
            np.asarray(y) for y in ys)
    return TrafficResult(
        requests=requests, routed=routed,
        replicas=np.rint(reps).astype(np.int64),
        served=served, dropped_route=drop_route, dropped_cap=drop_cap,
        violations=viol, emissions_g=emis,
        max_capacity=spec.max_capacity, interval_s=float(interval_s))
