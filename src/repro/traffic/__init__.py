"""Carbon-aware traffic subsystem: demand -> routing -> provisioning.

Three layers over the placed fleet (CASPER + CarbonScaler, see
PAPERS.md):

  - `arrivals`: per-region request-arrival generation for >=1M synthetic
    users — time-zone-shifted diurnal shape x AR(1)+burst noise,
    aggregated to a (T, R) requests-per-epoch tensor;
  - `routing`: SLO-constrained request routing — each epoch, each source
    region's demand is water-filled across SLO-feasible serving regions
    in carbon (or latency) order, scalar reference and vectorized kernel
    pinned to 1e-9 parity;
  - `autoscale`: replica provisioning under a carbon cap — marginal
    replicas admitted by marginal carbon-efficiency (the CarbonScaler
    greedy: sort + cumsum over an (R, K) efficiency table);
  - `sim`: the pipeline (`TrafficConfig`, `simulate_traffic`) and its
    coupling into `sweep_population(..., traffic=...)`;
  - `sim_jax`: the same epoch step as a pure JAX function, folded into
    the fleet backend's `lax.scan` (all (R,)/(R, R) carries).
"""
from repro.traffic.arrivals import ArrivalTensor, UserPopulation, request_matrix
from repro.traffic.autoscale import AutoscaleResult, ReplicaConfig, autoscale
from repro.traffic.routing import (RouteResult, RoutingConfig,
                                   latency_from_timezones, route, route_scalar)
from repro.traffic.sim import TrafficConfig, TrafficResult, simulate_traffic

__all__ = [
    "ArrivalTensor", "UserPopulation", "request_matrix",
    "RouteResult", "RoutingConfig", "latency_from_timezones", "route",
    "route_scalar",
    "AutoscaleResult", "ReplicaConfig", "autoscale",
    "TrafficConfig", "TrafficResult", "simulate_traffic",
]
