"""CarbonAwareTrainer: live Carbon Containers enforcement on a JAX job.

Wraps an ElasticJob the way lxcc wraps lxc (paper §3.1.1): beyond the
carbon target, ε, and policy variant, training code is untouched. Each
monitoring interval the trainer:

  1. aggregates step telemetry -> MFU utilization -> power (linear model)
     -> C(t) = p(t)·c(t),
  2. asks the enforcement policy for an action,
  3. applies it: duty-cycling the step loop (vertical scaling), elastic
     checkpoint/reshard/restore onto a different slice (migration), or
     checkpoint + idle (suspend/resume).

A virtual clock (sim_seconds_per_step) lets CPU demos exercise hours of
carbon-intensity variation in seconds; with the default wall clock it runs
in real time on hardware.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Optional, Sequence

from repro.carbon.intensity import CarbonIntensityProvider
from repro.cluster.slices import SliceFamily
from repro.config import CarbonConfig
from repro.core.container import ContainerState, PlantModel
from repro.core.elastic import ElasticJob
from repro.core.policy import Action, CarbonContainerPolicy
from repro.power.telemetry import TelemetryWindow, StepTelemetry


@dataclass
class IntervalLog:
    t: float
    carbon_intensity: float
    util: float
    power_w: float
    carbon_rate: float
    slice_name: str
    duty: float
    suspended: bool
    action: str


@dataclass
class CarbonAwareTrainer:
    job: ElasticJob
    family: SliceFamily
    slice_devices: Sequence[Sequence]        # devices per family slice
    carbon: CarbonIntensityProvider
    cfg: CarbonConfig
    step_flops: float                        # analytic FLOPs per train step
    step_tokens: int
    peak_flops_per_chip: float = 197e12
    sim_seconds_per_step: float = 0.0        # 0 -> wall clock
    policy: Optional[CarbonContainerPolicy] = None
    logs: list = field(default_factory=list)

    def __post_init__(self):
        if self.policy is None:
            self.policy = CarbonContainerPolicy(variant=self.cfg.policy)
        self.state = ContainerState(slice_idx=self.family.baseline_idx)
        self.telemetry = TelemetryWindow(window_s=self.cfg.interval_s)
        self._t = 0.0
        self._last_decision_t = -1e18

    # ------------------------------------------------------------------
    def _now(self) -> float:
        return self._t

    def _advance(self, dt: float):
        self._t += dt

    def _chips(self) -> int:
        s = self.family[self.state.slice_idx]
        return max(s.chips, 1)

    def _demand_estimate(self) -> float:
        """Workload intensity in baseline-slice units from telemetry."""
        util = self.telemetry.utilization(self._chips(),
                                          self.peak_flops_per_chip)
        s = self.family[self.state.slice_idx]
        # throttled at the duty quota means demand >= what we observe
        d = util * s.multiple
        if self.state.duty < 1.0 and util >= 0.95 * self.state.duty:
            d = max(d, s.multiple)       # optimistic doubling rule (§3.1.2)
        return d

    # ------------------------------------------------------------------
    def run(self, data_iter, n_steps: int,
            on_interval: Optional[Callable] = None) -> dict:
        import time as _time
        it = iter(data_iter)
        steps_done = 0
        while steps_done < n_steps:
            if self.state.suspended:
                self._advance(self.cfg.interval_s)
                self._maybe_enforce(force=True)
                continue
            t_wall = _time.perf_counter()
            metrics = self.job.train_step(next(it))
            wall_dt = _time.perf_counter() - t_wall
            step_dt = (self.sim_seconds_per_step or wall_dt)
            # vertical scaling: duty-cycle the step loop
            idle_dt = step_dt * (1.0 / max(self.state.duty, 1e-3) - 1.0) \
                if self.state.duty < 1.0 else 0.0
            self._advance(step_dt + idle_dt)
            self.telemetry.record(StepTelemetry(
                t=self._now(), step_time_s=step_dt + idle_dt,
                tokens=self.step_tokens, flops=self.step_flops,
                duty=self.state.duty))
            steps_done += 1
            self._maybe_enforce()
            if on_interval and self.logs:
                on_interval(self.logs[-1], metrics)
        return {"steps": steps_done, "logs": self.logs,
                "migrations": self.job.migrations}

    # ------------------------------------------------------------------
    def _maybe_enforce(self, force: bool = False):
        if not force and (self._now() - self._last_decision_t
                          < self.cfg.interval_s):
            return
        self._last_decision_t = self._now()
        c = self.carbon.intensity(self._now())
        demand = self._demand_estimate()
        self.state.observe_demand(demand)
        action: Action = self.policy.decide(
            self.family, self.state, demand, c,
            self.cfg.target_rate, self.cfg.epsilon)
        self._apply(action, c, demand)

    def _apply(self, action: Action, c: float, demand: float):
        st = self.state
        name = self.family[st.slice_idx].name
        if action.kind == "suspend":
            if not st.suspended:
                self.job.suspend()
            st.suspended = True
        elif action.kind == "resume":
            if st.suspended:
                st.slice_idx = action.target_slice or st.slice_idx
                self.job.resume(self.slice_devices[st.slice_idx])
            st.suspended = False
            st.duty = max(action.duty, 0.05)
        elif action.kind == "migrate":
            st.dwell = 0
            st.slice_idx = action.target_slice
            st.duty = max(action.duty, 0.05)
            self.job.migrate(self.slice_devices[st.slice_idx])
        else:
            st.duty = max(action.duty, 0.05)
        st.dwell += 1
        s = self.family[st.slice_idx]
        util = min(demand / s.multiple, st.duty) if not st.suspended else 0.0
        power = 0.0 if st.suspended else s.power.power(util)
        self.logs.append(IntervalLog(
            t=self._now(), carbon_intensity=c, util=util, power_w=power,
            carbon_rate=PlantModel.rate(power, c), slice_name=s.name,
            duty=st.duty, suspended=st.suspended, action=action.kind))
