"""Carbon Container state + plant model.

``PlantModel`` is the shared physics both the simulator and the live
trainer use: given a slice, a duty-cycle quota, workload demand (in
baseline-capacity units) and grid carbon-intensity, it yields served work,
power, and the carbon emissions rate C(t) = p(t)·c(t) (paper §3.1.2).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

from repro.cluster.slices import Slice, SliceFamily


@dataclass
class Step:
    """One monitoring-interval outcome."""
    served: float            # work served, baseline-capacity units
    throttled: float         # unmet demand, baseline-capacity units
    power_w: float
    carbon_rate: float       # g CO2e / hr
    util: float              # utilization of the current slice


class PlantModel:
    """Work/power/carbon response of a container on a slice."""

    @staticmethod
    def run(s: Slice, duty: float, demand: float, c_intensity: float) -> Step:
        cap = s.multiple * max(0.0, min(duty, 1.0))
        served = min(demand, cap)
        util = served / s.multiple if s.multiple > 0 else 0.0
        power = s.power.power(util)
        return Step(served=served, throttled=max(0.0, demand - served),
                    power_w=power, carbon_rate=power * c_intensity / 1000.0,
                    util=util)

    @staticmethod
    def idle_power(s: Slice) -> float:
        return s.power.base_w

    @staticmethod
    def rate(power_w: float, c_intensity: float) -> float:
        return power_w * c_intensity / 1000.0


@dataclass
class ContainerState:
    slice_idx: int
    duty: float = 1.0
    suspended: bool = False
    migrating_s: float = 0.0            # remaining migration downtime
    migrate_target: Optional[int] = None
    dwell: int = 0                      # intervals since last migration
    # accounting
    emissions_g: float = 0.0
    energy_wh: float = 0.0
    work_done: float = 0.0
    time_on_slice_s: dict = field(default_factory=dict)
    migrations: int = 0
    suspended_s: float = 0.0
    throttled_integral: float = 0.0     # ∫ (demand-served) dt, baseline units·s
    demand_integral: float = 0.0
    elapsed_s: float = 0.0
    demand_window: list = field(default_factory=list)   # last N intervals

    def observe_demand(self, d: float, n: int = 6):
        self.demand_window.append(d)
        if len(self.demand_window) > n:
            self.demand_window.pop(0)

    @property
    def recent_peak(self) -> float:
        return max(self.demand_window) if self.demand_window else 0.0


@dataclass
class CarbonContainer:
    """The lxcc-facing object: a registered container with a carbon target.

    Mirrors the paper's interface: a target rate, an ε threshold, a policy
    variant, and transparent enforcement — the wrapped application only
    supplies workload demand (or real step telemetry via the trainer).
    """
    family: SliceFamily
    target_rate: float                  # C_target, g/hr
    epsilon: float = 0.05
    policy: object = None               # set by factory
    state: ContainerState = None

    def __post_init__(self):
        if self.state is None:
            self.state = ContainerState(slice_idx=self.family.baseline_idx)

    def set_target(self, rate: float):
        self.target_rate = rate

    @property
    def current_slice(self) -> Slice:
        return self.family[self.state.slice_idx]
