"""Carbon Containers core (the paper's contribution).

- policy.py        §3.2 enforcement policies (energy-efficiency/performance)
                   + the evaluated baselines (agnostic, suspend/resume,
                   vertical-scaling-only)
- container.py     the lxcc-like container object + plant model
- simulator.py     trace-driven large-scale evaluation (Figs 10-17)
- fleet.py         vectorized fleet simulator (N containers per sweep)
- carbon_aware_trainer.py  live enforcement on a JAX training job
- elastic.py       checkpoint -> reshard -> restore slice migration
"""
from repro.core.container import CarbonContainer, ContainerState, PlantModel
from repro.core.policy import (CarbonAgnosticPolicy, CarbonContainerPolicy,
                               SuspendResumePolicy, VScaleOnlyPolicy)
from repro.core.simulator import (SimConfig, SimResult, simulate,
                                  sweep_population)
from repro.core.fleet import FleetResult, FleetSimulator

__all__ = ["CarbonContainer", "ContainerState", "PlantModel",
           "CarbonContainerPolicy", "CarbonAgnosticPolicy",
           "SuspendResumePolicy", "VScaleOnlyPolicy",
           "SimConfig", "SimResult", "simulate", "sweep_population",
           "FleetSimulator", "FleetResult"]
