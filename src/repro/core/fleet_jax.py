"""JAX backend for the fleet simulator: jit/scan sweeps, device-resident.

`repro.core.fleet.FleetSimulator` advances the whole (N,) fleet per
monitoring interval with NumPy array state, but every epoch still
round-trips through the Python interpreter (~100 array-op dispatches per
step), which caps sweeps at low-thousands of containers. This module
ports the decision kernels and the epoch loop to JAX:

  - each policy's `decide_batch` masking scheme becomes a pure function
    on (N,) arrays, mirroring the NumPy kernels term-for-term;
  - the epoch loop becomes one `jax.lax.scan` over time with the whole
    fleet state as the carry, so a full run compiles to a single XLA
    computation with no per-step Python dispatch;
  - everything runs float64 (`jax.experimental.enable_x64`, scoped so
    the f32 model/kernel suites are untouched) and device-resident: one
    host->device push of the inputs, one device->host pull of the final
    state.

Branchy NumPy fast paths (`if np.count_nonzero(...)` gates, the
compacted `_best_fit_up_batch` walk, the closed-form dispatch for
state-free policies) are pure optimizations — executing the gated block
with an all-False mask is a no-op — so the scan step simply evaluates
every branch masked. The three `dwell` update branches in the NumPy loop
likewise collapse to one rule: dwell += ((kind >= 0) & (kind !=
K_MIGRATE)) after the migration-done reset. Clamps the NumPy path keeps
but documents as identities (duty and utilization already lie in [0, 1])
are elided.

XLA:CPU performance notes (measured via the fleet_sweep_jax benchmark):
XLA's CPU pipeline has no multi-output loop fusion, so a value consumed
by k downstream fusion roots gets its whole producer chain *duplicated*
k times — a naive port of the step (one big chain feeding ~15 carry
outputs) re-evaluates the entire decision cascade per output and runs
slower than NumPy. Gathers fare no better: a slice-table gather inside
the decision chain fragments the surrounding fusion and costs ~20x a
fused select. Three techniques recover the speedup:

  - static LUTs (`_lutf`/`_luti`): the slice family is tiny and static,
    so every table lookup compiles to a select chain over per-slice
    literals — fully fusible and SIMD-friendly, no gathers anywhere;
  - `_pack` stage boundaries: `optimization_barrier` around a row-stack
    force-materializes shared intermediates (the barrier stops
    slice-of-concat forwarding and is itself stripped late, leaving a
    plain materialized buffer); downstream fusions read rows instead of
    recomputing chains;
  - packed carry: the scan carry is three arrays (f64 accumulators +
    f64 dynamics + i32 state) instead of ~14, and all accumulator
    updates land in a single stacked add, keeping the number of fusion
    roots — and hence chain duplication — small.

Results come back as the same `FleetResult` dataclass; parity against
the NumPy backend is pinned to 1e-6 by `tests/test_fleet_jax.py` (and
the NumPy backend stays pinned to the scalar loop at 1e-9, anchoring
the chain).
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple, Optional, Sequence

import numpy as np

from repro.cluster.migration import MigrationCostModel
from repro.cluster.slices import SliceFamily
from repro.core.fleet import (FleetResult, _aggregate_sweep_rows,
                              _elastic_budget_series, _prepare_energy,
                              _prepare_run_inputs, _prepare_sweep_inputs,
                              _prepare_traffic, _PEAK_WINDOW)
from repro.core.policy import K_MIGRATE, K_RESUME, K_STAY, K_SUSPEND
from repro.core.simulator import SimConfig

try:
    import jax
    import jax.numpy as jnp
    from jax import lax
    from jax.experimental import enable_x64
    HAS_JAX = True
except ImportError:                                    # pragma: no cover
    HAS_JAX = False
    jax = jnp = lax = enable_x64 = None

# rows of the packed scan carry (see _fleet_scan): acc carries the four
# raw f64 sums, dyni carries i32 state then interval counters
_ACC_ROWS = 4            # sum(power*c), sum(power), sum(served), sum(thr)
_I_SLICE, _I_MT, _I_DWELL, _I_MIGS, _I_SUS, _I_SUSCNT = range(6)
_MIN_SHARD_COLS = 1024   # don't shard fleets smaller than this per device


def _require_jax():
    if not HAS_JAX:
        raise ImportError("backend='jax' requires jax; install jax[cpu] "
                          "or use backend='fleet'")


# CPU-tuned XLA flags: the legacy CPU runtime sidesteps the thunk
# executor's per-kernel dispatch overhead inside scans, and multiple
# host devices let `FleetSimulatorJax.run` shard the container axis
# across cores (shards double as cache blocks, so more shards than
# cores still helps large fleets).
_CPU_XLA_FLAGS = ("--xla_cpu_use_thunk_runtime=false",
                  "--xla_force_host_platform_device_count=4")


def ensure_cpu_xla_flags():
    """Append the CPU-tuned XLA flags to XLA_FLAGS unless the caller
    already set them (explicit user settings win). Must run before the
    first XLA backend initialization — i.e. before any jax computation,
    not necessarily before `import jax` — to take effect. The benchmark
    harness and the `--jax-sweep` demo call this; library users export
    the flags themselves (see README "Backends")."""
    import os
    flags = os.environ.get("XLA_FLAGS", "")
    for f in _CPU_XLA_FLAGS:
        if f.split("=")[0] not in flags:
            flags = (flags + " " + f).strip()
    os.environ["XLA_FLAGS"] = flags


class _TablesS(NamedTuple):
    """FamilyTables as a hashable constant (jit static arg): per-slice
    values become Python tuples so every lookup compiles to a select
    chain over literals instead of a fusion-breaking gather."""
    base_w: tuple
    peak_w: tuple
    multiple: tuple
    bw_gbps: tuple
    next_smaller: tuple
    next_larger: tuple
    n_slices: int
    smallest: int
    baseline_idx: int
    well_formed: bool

    @classmethod
    def from_tables(cls, t) -> "_TablesS":
        return cls(base_w=tuple(float(x) for x in t.base_w),
                   peak_w=tuple(float(x) for x in t.peak_w),
                   multiple=tuple(float(x) for x in t.multiple),
                   bw_gbps=tuple(float(x) for x in t.bw_gbps),
                   next_smaller=tuple(int(x) for x in t.next_smaller),
                   next_larger=tuple(int(x) for x in t.next_larger),
                   n_slices=len(t.multiple),
                   smallest=int(t.smallest),
                   baseline_idx=int(t.baseline_idx),
                   well_formed=bool(t.well_formed))


def _lutf(vals: tuple, idx):
    """Float table lookup as a select chain over literals (`idx` must
    already be clamped into range)."""
    out = jnp.full(idx.shape, vals[0], dtype=jnp.float64)
    for s in range(1, len(vals)):
        out = jnp.where(idx == s, vals[s], out)
    return out


def _luti(vals: tuple, idx):
    """Integer table lookup as a select chain over literals."""
    out = jnp.full(idx.shape, vals[0], dtype=jnp.int32)
    for s in range(1, len(vals)):
        out = jnp.where(idx == s, vals[s], out)
    return out


def _pack(*rows):
    """Force-materialize a group of same-dtype (N,) rows as one (R, N)
    buffer. The `optimization_barrier` keeps algebraic simplification
    from forwarding `pack[r]` back to the un-materialized producer; XLA
    strips the barrier itself after that, so what remains is a plain
    concatenate fusion evaluated once. Consumers index rows instead of
    re-deriving them (XLA:CPU would otherwise clone the whole producer
    chain into every consumer fusion)."""
    return lax.optimization_barrier(jnp.stack(rows))


# ---------------------------------------------------------------------------
# Decision kernels (staged ports of the policies' decide_batch)
# ---------------------------------------------------------------------------

def _policy_spec(policy) -> tuple:
    """Hashable kernel spec for a policy instance (jit cache key)."""
    from repro.core.policy import (CarbonAgnosticPolicy,
                                   CarbonContainerPolicy,
                                   SuspendResumePolicy)
    if type(policy) is CarbonAgnosticPolicy:
        return ("agnostic",)
    if type(policy) is SuspendResumePolicy:
        return ("suspend_resume",)
    if type(policy) is CarbonContainerPolicy:
        return ("cc", policy.variant, bool(policy.allow_migration),
                int(policy.min_dwell), float(policy.idle_margin))
    raise TypeError(
        f"backend='jax' has no decision kernel for {type(policy).__name__}; "
        f"stock policies only (use backend='fleet' for custom policies)")


def _nl_chain(tabs: _TablesS, i: int) -> list:
    """Static next-larger chain upward from slice i (exclusive)."""
    chain = []
    k = tabs.next_larger[i]
    while k >= 0:
        chain.append(k)
        k = tabs.next_larger[k]
    return chain


def _best_fit_up_j(tabs: _TablesS, i0, demand, budget):
    """`_best_fit_up_batch`, statically unrolled: the walk's visit order
    is a compile-time property of the slice family, so per-slice
    fit/serve predicates are computed once against literals and the
    per-start-slice outcome is a nested select — no table lookups at
    all. Runs full-width (no `active0` compaction): the walk has no side
    effects, so callers mask its result (`k_up >= 0` only consulted
    where the scalar path would have walked)."""
    S = tabs.n_slices
    # per-slice predicates against literals (shared by every chain)
    fits = []
    geq = []
    for s in range(S):
        u_s = jnp.minimum(demand / tabs.multiple[s], 1.0)
        pw_s = (tabs.base_w[s]
                + (tabs.peak_w[s] - tabs.base_w[s]) * u_s)
        fits.append(pw_s <= budget)
        geq.append(demand <= tabs.multiple[s])
    res = jnp.full(demand.shape, -1, dtype=jnp.int32)
    for i in range(S):
        chain = _nl_chain(tabs, i)
        if not chain:
            continue
        # walk outcome from start i, built from the chain's end backward:
        # at k: not fits -> -1; fits and (serves | last) -> k; else next
        last = chain[-1]
        r = jnp.where(fits[last], last, -1)
        for k in reversed(chain[:-1]):
            r = jnp.where(fits[k], jnp.where(geq[k], k, r), -1)
        res = jnp.where(i0 == i, r, res)
    return res.astype(jnp.int32)


def _decide_cc(spec, tabs, i0, sus, dwell, peak_r, d, c, budget):
    """CarbonContainerPolicy.decide_batch, staged.

    Mask priority == scalar control flow, exactly as the NumPy kernel
    (whose `decided` bookkeeping resolves to the disjoint branch masks
    used here). Shared float quantities and the expensive branch masks
    are `_pack`-materialized so the kind/duty/target select chains stay
    shallow.
    """
    _, variant, can_mig, min_dwell, idle_margin = spec
    base_i = _lutf(tabs.base_w, i0)
    peak_i = _lutf(tabs.peak_w, i0)
    mult_i = _lutf(tabs.multiple, i0)
    ns = _luti(tabs.next_smaller, i0)
    has_j = ns >= 0
    jj = jnp.maximum(ns, 0)
    base_j = _lutf(tabs.base_w, jj)
    peak_j = _lutf(tabs.peak_w, jj)
    mult_j = _lutf(tabs.multiple, jj)
    span_i = peak_i - base_i
    span_j = peak_j - base_j

    # --- stage 1: shared float quantities --------------------------------
    u_cap_i = jnp.minimum(1.0, (budget - base_i) / span_i)
    if not tabs.well_formed:
        u_cap_i = jnp.where(peak_i <= base_i, 1.0, u_cap_i)
    u_cap_i = jnp.where(budget <= base_i, 0.0, u_cap_i)
    u_cap_j = jnp.minimum(1.0, (budget - base_j) / span_j)
    if not tabs.well_formed:
        u_cap_j = jnp.where(peak_j <= base_j, 1.0, u_cap_j)
    u_cap_j = jnp.where(budget <= base_j, 0.0, u_cap_j)
    u_need_i = jnp.minimum(d / mult_i, 1.0)
    b_j0 = tabs.base_w[tabs.smallest]
    p_j0 = tabs.peak_w[tabs.smallest]
    u_cap_j0 = jnp.minimum(1.0, (budget - b_j0) / (p_j0 - b_j0))
    if not tabs.well_formed:
        u_cap_j0 = jnp.where(p_j0 <= b_j0, 1.0, u_cap_j0)
    u_cap_j0 = jnp.where(budget <= b_j0, 0.0, u_cap_j0)
    pw_need_i = base_i + span_i * u_need_i
    # materialize every LUT-bearing quantity the mask and duty chains
    # read more than once (a re-evaluated chain re-evaluates its LUTs)
    f1 = _pack(u_cap_i, u_cap_j, u_need_i, u_cap_j0, mult_i, mult_j,
               base_j, peak_j, pw_need_i, base_i, span_i)
    (u_cap_i, u_cap_j, u_need_i, u_cap_j0, mult_i, mult_j, base_j,
     peak_j, pw_need_i, base_i, span_i) = (f1[r] for r in range(11))
    span_j = peak_j - base_j

    if variant == "energy" and can_mig:
        k_up = _best_fit_up_j(tabs, i0, d, budget)

    # --- stage 2: branch masks, in scalar return order -------------------
    resume_ok = sus & (b_j0 <= budget) & (u_cap_j0 > 0.0)
    base_over = base_i > budget
    over = (pw_need_i > budget) | base_over
    hard = over & (base_over | (u_cap_i <= 0.0)) & ~sus
    soft = over & ~hard & ~sus
    if can_mig:
        # soft: emissions/throttle comparison on the next-smaller slice
        q_new = u_cap_i
        throttle_i = jnp.maximum(0.0, d - mult_i * q_new)
        u_qi = jnp.minimum(q_new, u_need_i)
        c_i = (base_i + span_i * u_qi) * c / 1000.0
        u_j = jnp.minimum(jnp.minimum(d / mult_j, u_cap_j), 1.0)
        throttle_j = jnp.maximum(0.0, d - mult_j * u_j)
        c_j = (base_j + span_j * u_j) * c / 1000.0
        s1 = (soft & has_j & (c_j < c_i)
              & (throttle_j <= throttle_i + 1e-12))
    else:
        s1 = jnp.zeros(d.shape, dtype=bool)
    below = ~over & ~sus
    if variant == "energy":
        if can_mig:
            can_idle = dwell >= min_dwell
            peak = jnp.maximum(peak_r, d)
            u_jp = peak / mult_j
            pw_jp = base_j + span_j * jnp.minimum(u_jp, 1.0)
            e1 = (below & can_idle & has_j
                  & (u_jp <= jnp.minimum(u_cap_j, 0.9))
                  & (pw_jp < (1.0 - idle_margin) * pw_need_i))
            throttled = below & ~e1 & (d > mult_i * u_cap_i)
            m1 = _pack(*(m.astype(jnp.int32)
                         for m in (resume_ok, hard, soft, s1, e1,
                                   throttled, has_j)),
                       k_up, jj)
            resume_ok, hard, soft, s1, e1, throttled, has_j = (
                m1[r] > 0 for r in range(7))
            k_up = m1[7]
            jj = m1[8]
            e2 = throttled & (k_up >= 0)
        else:
            e1 = e2 = jnp.zeros(d.shape, dtype=bool)
            m1 = _pack(resume_ok, hard, soft, s1)
            resume_ok, hard, soft, s1 = (m1[r] for r in range(4))
        # (the ~can_mig cascade below never reads jj/has_j)
    else:
        if can_mig:
            # performance: climb next-larger while the candidate fits
            # 0.9x budget — statically unrolled like _best_fit_up_j;
            # `k_idx` tracks the last accepted slice (the scalar loop's
            # `k`), `k_is_set` <=> k != i
            climbing = below & (dwell >= min_dwell)
            ok = []
            for s in range(tabs.n_slices):
                u_n = jnp.minimum(d / tabs.multiple[s], 1.0)
                pw_n = (tabs.base_w[s]
                        + (tabs.peak_w[s] - tabs.base_w[s]) * u_n)
                ok.append(pw_n <= 0.9 * budget)
            k_is_set = jnp.zeros(d.shape, dtype=bool)
            k_idx = jnp.zeros(d.shape, dtype=jnp.int32)
            for i in range(tabs.n_slices):
                chain = _nl_chain(tabs, i)
                if not chain:
                    continue
                reach = climbing
                k_i = jnp.full(d.shape, -1, dtype=jnp.int32)
                for s in chain:
                    reach = reach & ok[s]
                    k_i = jnp.where(reach, s, k_i)
                here = i0 == i
                k_idx = jnp.where(here & (k_i >= 0), k_i, k_idx)
                k_is_set = k_is_set | (here & (k_i >= 0))
            p1 = below & k_is_set
        else:
            p1 = jnp.zeros(d.shape, dtype=bool)
            k_idx = jnp.zeros(d.shape, dtype=jnp.int32)
        m1 = _pack(*(m.astype(jnp.int32)
                     for m in (resume_ok, hard, soft, s1, p1, has_j)),
                   k_idx, jj)
        resume_ok, hard, soft, s1, p1, has_j = (m1[r] > 0
                                                for r in range(6))
        k_idx = m1[6]
        jj = m1[7]

    # --- stage 3: kind / duty / target from materialized masks -----------
    kind = jnp.full(d.shape, K_STAY, dtype=jnp.int32)
    duty = jnp.zeros(d.shape, dtype=jnp.float64)
    tgt = jnp.full(d.shape, -1, dtype=jnp.int32)
    kind = jnp.where(resume_ok, K_RESUME, kind)
    kind = jnp.where(sus & ~resume_ok, K_SUSPEND, kind)
    duty = jnp.where(resume_ok, u_cap_j0, duty)
    tgt = jnp.where(resume_ok, tabs.smallest, tgt)
    if can_mig:
        h1 = hard & has_j & (base_j <= budget)
        h_mig = hard & has_j
        h3 = hard & ~has_j & (i0 == tabs.smallest)
        kind = jnp.where(h_mig, K_MIGRATE, kind)
        kind = jnp.where(h3, K_SUSPEND, kind)
        duty = jnp.where(h1, u_cap_j, duty)
        tgt = jnp.where(h_mig, jj, tgt)
        kind = jnp.where(s1, K_MIGRATE, kind)
        duty = jnp.where(s1, u_cap_j, duty)
        tgt = jnp.where(s1, jj, tgt)
    else:
        kind = jnp.where(hard, K_SUSPEND, kind)
    duty = jnp.where(soft & ~s1, u_cap_i, duty)        # stay at q_new
    if variant == "energy":
        rest = ~sus & ~hard & ~soft
        if can_mig:
            kind = jnp.where(e1 | e2, K_MIGRATE, kind)
            duty = jnp.where(e1, u_cap_j, duty)
            duty = jnp.where(e2, 1.0, duty)
            tgt = jnp.where(e1, jj, tgt)
            tgt = jnp.where(e2, k_up, tgt)
            rest = rest & ~e1 & ~e2
        duty = jnp.where(rest, u_cap_i, duty)
    else:
        rest = ~sus & ~hard & ~soft
        kind = jnp.where(p1, K_MIGRATE, kind)
        duty = jnp.where(p1, 1.0, duty)
        tgt = jnp.where(p1, k_idx, tgt)
        duty = jnp.where(rest & ~p1, u_cap_i, duty)
    return kind, duty, tgt


def _decide_sr(spec, tabs, i0, sus, dwell, peak, d, c, budget):
    b = tabs.baseline_idx
    base_b = tabs.base_w[b]
    span_b = tabs.peak_w[b] - base_b
    u = jnp.minimum(d / tabs.multiple[b], 1.0)
    pw = base_b + span_b * u
    # over <=> rate(power) > (1-eps)*target; the hoisted SR budget row
    # carries the (1-eps)*target rate threshold (see _fleet_scan)
    over = pw * c / 1000.0 > budget
    kind = jnp.where(over, K_SUSPEND,
                     jnp.where(sus, K_RESUME, K_STAY)).astype(jnp.int32)
    duty = jnp.ones(d.shape, dtype=jnp.float64)
    tgt = jnp.where(kind == K_RESUME, b, -1).astype(jnp.int32)
    return kind, duty, tgt


def _decide_agnostic(spec, tabs, i0, sus, dwell, peak, d, c, budget):
    # baseline server: migrate back if ever off the baseline slice
    off_base = i0 != tabs.baseline_idx
    kind = jnp.where(off_base, K_MIGRATE, K_STAY).astype(jnp.int32)
    duty = jnp.ones(d.shape, dtype=jnp.float64)
    tgt = jnp.where(off_base, tabs.baseline_idx, -1).astype(jnp.int32)
    return kind, duty, tgt


_DECIDERS = {"agnostic": _decide_agnostic, "suspend_resume": _decide_sr,
             "cc": _decide_cc}


# ---------------------------------------------------------------------------
# The scan: whole (N,) fleet state as the carry, one step per epoch
# ---------------------------------------------------------------------------

@partial(jax.jit if HAS_JAX else lambda f, **kw: f,
         static_argnames=("spec", "srs", "record", "tabs", "dt", "mig",
                          "cmode", "n_rep", "R", "traffic", "energy"))
def _fleet_scan(demand, cmat, targets, eps, state_gb, req_mat=None,
                solar_mat=None, up_mat=None, obs_mat=None, gap_vec=None, *,
                spec: tuple, srs: bool, record: bool, tabs: _TablesS,
                dt: float, mig: tuple, cmode: str = "dense", n_rep: int = 1,
                R: int = 0, traffic=None, energy=None):
    """One XLA computation: scan the staged epoch step over time.

    The carry is three packed arrays — f64 accumulators (6 + S + 1 rows:
    emissions, energy, work, throttled, demand, suspended_s, then
    time-on-slice columns), f64 dynamics (duty, migrating_s), and i32
    state (slice, migrate_target, dwell, migrations, suspended) — so the
    step has few fusion roots (see module docstring).

    Scale hardening (the N=1M placed sweep): nothing (T, N)-shaped is
    hoisted. The per-interval power budgets and the rolling
    _PEAK_WINDOW demand max — previously precomputed as (T, N)
    matrices, 2.3 GB each at N=1M/T=288 f64 — are computed inside the
    step (the budget is elementwise in the epoch's carbon row; the peak
    reads a (W-1, N) demand-window carry). Both are the exact same
    float expressions as the hoisted forms, so backend parity is
    untouched.

    `cmode` selects the carbon layout: "dense" takes `cmat` as the
    (T,) or (T, N) intensity matrix; "indexed" takes `cmat` as a
    `(region_mat (T, R) f64, codes (T, n_cols) int32)` pair and derives
    each epoch's per-container intensity with an R-way select chain —
    at fleet scale the (T, N) f64 matrix becomes a (T, n_cols) int32
    code matrix. `n_rep > 1` (indexed mode only) tiles the compact
    demand/code columns n_rep times *inside the step*, for
    target-sweep fleets whose columns repeat the same traces: the
    logical fleet is N = n_cols * n_rep wide but only compact inputs
    ever exist on host or in HBM.

    `traffic` (a static `repro.traffic.sim_jax.TrafficSpec`; indexed
    mode only, with `req_mat` the (T, R) request tensor in xs) folds the
    traffic subsystem into the same scan: each step routes the epoch's
    request row by the carbon row, autoscales the per-region replica
    fleets (an (R,) replica-count carry), and modulates each compact
    demand column by its region's serving load before the n_rep tiling
    — all carries stay (R,)/(R, R)-shaped, nothing (T, N). A fifth
    accumulator row sums the modulated demand so `work_demanded` can be
    recovered without re-materializing it on host.

    `energy` (a static `repro.energy.supply.EnergySpec`; indexed mode
    only, with `solar_mat`/`up_mat` the (T, R) solar-generation and
    grid-up tensors in xs) folds the virtual energy supply into the
    same scan: each step sums the compact columns into the (R,)
    per-region flexible load, advances the battery state of charge (an
    (R,) carry) through `repro.energy.supply_jax.energy_step`, clamps
    each column's demand by its region's virtual-cap fraction, and
    swaps the carbon row for the delivered mix's effective intensity —
    all before the n_rep tiling, pinned after the traffic modulation
    (demand_scale -> traffic -> energy, same layer order as the fleet
    backend). Reuses the traffic path's extra accumulator row for
    `work_demanded`.

    `obs_mat` (optional xs tensor) splits the signal plane from the
    billing plane: decision kernels and their per-epoch power budgets
    consume the *observed* intensity row — (T, R) in indexed mode
    (selected through the same R-way chain, and with the energy fold
    scaled onto the delivered mix by the per-region observed/true
    ratio), (T,) or (T, N) dense — while emissions stay billed at the
    true feed. The traffic fold routes on the observed row too (the
    router is a controller). `gap_vec` (optional (T,) xs vector) marks
    power-telemetry outage epochs; an extra accumulator row sums the
    gap epochs' emissions (`unmetered_g`).

    Returns the final carry tuple (+ optional (T, N) power/served series).
    """
    if cmode == "indexed":
        region_mat, codes = cmat
        n_cols = demand.shape[1]
        N = n_cols * n_rep
    else:
        assert n_rep == 1, "n_rep tiling requires indexed carbon"
        assert traffic is None, "traffic fold requires indexed carbon"
        assert energy is None, "energy fold requires indexed carbon"
        N = demand.shape[1]
    if traffic is not None:
        from repro.traffic.sim_jax import traffic_step
    if energy is not None:
        from repro.energy.supply_jax import energy_step
    S = tabs.n_slices
    decide = _DECIDERS[spec[0]]
    suspend_r = spec[0] == "suspend_resume"
    (sb, spg, rb, rpg, cpg, dpg, ratio, default_bw, extra) = mig

    # only the energy variant's idle-migration rule reads the rolling
    # demand peak (ContainerState.recent_peak); others skip the window
    # carry entirely
    use_peak = spec[0] == "cc" and spec[1] == "energy" and spec[2]
    # SuspendResumePolicy compares emission rates: its (epoch-invariant)
    # budget is the (1-eps)*target rate threshold, hoisted once
    sr_budget = ((1.0 - eps) * targets if suspend_r
                 else jnp.zeros((), dtype=jnp.float64))

    has_obs = obs_mat is not None
    has_gap = gap_vec is not None
    tos_cols = jnp.arange(S + 1, dtype=jnp.int32)
    n_acc = (_ACC_ROWS
             + (1 if (traffic is not None or energy is not None) else 0)
             + (1 if has_gap else 0))
    acc0 = jnp.zeros((n_acc, N), dtype=jnp.float64)
    rep0 = (jnp.full(R, float(traffic.min_rep), dtype=jnp.float64)
            if traffic is not None else None)
    soc0 = (jnp.full(R, energy.soc0_wh, dtype=jnp.float64)
            if energy is not None else None)
    dynf0 = jnp.stack([jnp.ones(N, dtype=jnp.float64),       # duty
                       jnp.zeros(N, dtype=jnp.float64)])     # migrating_s
    dyni0 = jnp.concatenate(
        [jnp.stack([jnp.full(N, tabs.baseline_idx, dtype=jnp.int32),
                    jnp.full(N, -1, dtype=jnp.int32),    # migrate_target
                    jnp.full(N, 10 ** 6, dtype=jnp.int32),  # dwell
                    jnp.zeros(N, dtype=jnp.int32),       # migrations
                    jnp.zeros(N, dtype=jnp.int32)]),     # suspended
         # interval counters: suspended + per-slice occupancy (exact:
         # k * dt == dt summed k times for integral dt-multiples)
         jnp.zeros((S + 2, N), dtype=jnp.int32)])
    # zero-padded demand window (rolling peak includes the current
    # interval; exact because demand >= 0)
    win0 = (jnp.zeros((_PEAK_WINDOW - 1, N), dtype=jnp.float64)
            if use_peak else None)

    def step(st, x):
        if energy is not None:
            soc = st[-1]
            st = st[:-1]
        if traffic is not None:
            rep = st[-1]
            st = st[:-1]
        # observed-feed / gap xs ride at the tail: pop them first
        g = None
        if has_gap:
            g = x[-1]
            x = x[:-1]
        obs_row = None
        if has_obs:
            obs_row = x[-1]
            x = x[:-1]
        if cmode == "indexed":
            if energy is not None:
                sol_row, up_row = x[-2], x[-1]
                x = x[:-2]
            if traffic is not None:
                d, code, c_row, req = x
                # route this epoch's requests by the carbon row, scale
                # the replica fleets; the serving loads modulate demand
                # (the router is a controller: it sees the observed feed)
                rep1, t_outs = traffic_step(
                    traffic, rep, req, obs_row if has_obs else c_row)
                mod_row = t_outs[0]
                mod = jnp.full(code.shape, mod_row[0], dtype=jnp.float64)
                for r in range(1, R):
                    mod = jnp.where(code == r, mod_row[r], mod)
                d = d * mod
            else:
                d, code, c_row = x
            if energy is not None:
                # virtual energy supply: the compact columns sum into
                # the (R,) flexible-load row (linear in demand, see
                # repro.energy.supply), one battery/solar/grid step
                # advances the (R,) SoC carry, and the cap fraction +
                # effective intensity come back through the same R-way
                # selects as the carbon row
                load_row = jnp.stack(
                    [jnp.sum(jnp.where(code == r, d, 0.0))
                     for r in range(R)]) * energy.load_coef
                c_raw = c_row           # true grid row, pre-delivered-mix
                soc1, e_outs = energy_step(energy, soc, load_row,
                                           sol_row, c_row, up_row)
                cap_row, c_row = e_outs[5], e_outs[6]
                if has_obs:
                    # the controller observes the delivered mix through
                    # the degraded feed: scale the effective intensity
                    # by the per-region observed/true grid ratio (same
                    # floats as the fleet backend's ceff_obs_reg)
                    raw_safe = jnp.where(c_raw > 0.0, c_raw, 1.0)
                    obs_row = c_row * jnp.where(
                        c_raw > 0.0, obs_row / raw_safe, 1.0)
                capsel = jnp.full(code.shape, cap_row[0],
                                  dtype=jnp.float64)
                for r in range(1, R):
                    capsel = jnp.where(code == r, cap_row[r], capsel)
                d = d * capsel
            # R-way select chain over the epoch's (R,) region row — the
            # compact-width analogue of gathering region_mat[t, codes[t]]
            c = jnp.full(code.shape, c_row[0], dtype=jnp.float64)
            for r in range(1, R):
                c = jnp.where(code == r, c_row[r], c)
            if has_obs:
                c_dec = jnp.full(code.shape, obs_row[0], dtype=jnp.float64)
                for r in range(1, R):
                    c_dec = jnp.where(code == r, obs_row[r], c_dec)
            if n_rep > 1:
                d = jnp.tile(d, n_rep)
                c = jnp.tile(c, n_rep)
                if has_obs:
                    c_dec = jnp.tile(c_dec, n_rep)
        else:
            d, c = x
            if has_obs:
                c_dec = obs_row
        if not has_obs:
            c_dec = c
        if use_peak:
            acc, dynf, dyni, win = st
            peak = d
            for k in range(_PEAK_WINDOW - 1):
                peak = jnp.maximum(peak, win[k])
            win1 = jnp.concatenate([win[1:], d[None, :]], axis=0)
        else:
            acc, dynf, dyni = st
            peak = jnp.zeros((), dtype=jnp.float64)
        # per-interval power budget (policy._budget_batch, elementwise
        # in the epoch's carbon values — same floats as the hoisted
        # (T, N) form)
        if spec[0] == "agnostic":
            budget = jnp.zeros((), dtype=jnp.float64)
        elif suspend_r:
            budget = sr_budget
        else:
            c_safe = jnp.where(c_dec <= 0.0, 1.0, c_dec)
            budget = jnp.where(c_dec <= 0.0, jnp.inf,
                               (1.0 - eps) * targets * 1000.0 / c_safe)
        i0 = dyni[_I_SLICE]
        mt0 = dyni[_I_MT]
        dwell0 = dyni[_I_DWELL]
        sus = dyni[_I_SUS] > 0
        duty0 = dynf[0]
        migr_s0 = dynf[1]
        migm = migr_s0 > 0.0

        kind, dy, tg = decide(spec, tabs, i0, sus, dwell0, peak, d, c_dec,
                              budget)
        kind = jnp.where(migm, -1, kind)
        dstc = jnp.where(kind == K_MIGRATE, tg, 0)
        dstc_m = jnp.where(migm, mt0, 0)
        di = _pack(kind, tg, dstc, dstc_m)
        kind, tg, dstc, dstc_m = di[0], di[1], di[2], di[3]

        m_sus = kind == K_SUSPEND
        m_res = kind == K_RESUME
        m_stay = kind == K_STAY
        m_mig = kind == K_MIGRATE

        base_i = _lutf(tabs.base_w, i0)
        base_dm = _lutf(tabs.base_w, dstc_m)    # in-flight migration dst
        base_dst = _lutf(tabs.base_w, dstc)     # newly decided dst

        # stop-and-copy time (MigrationCostModel, same term order incl.
        # the zero-bandwidth fallback) + post-decision slice + duty
        bw = jnp.maximum(_lutf(tabs.bw_gbps, i0), _lutf(tabs.bw_gbps, dstc))
        bw = jnp.where(bw == 0.0, default_bw, bw)
        mig_s = (sb + spg * state_gb) + (rb + rpg * state_gb)
        mig_s = mig_s + (cpg + dpg) * state_gb
        mig_s = mig_s + (state_gb / ratio) / bw
        mig_s = mig_s + extra
        duty1 = jnp.where(m_res | m_stay | m_mig, dy, duty0)
        pf = _pack(mig_s, duty1, base_i)
        mig_s, duty, base_i = pf[0], pf[1], pf[2]
        has_t = m_res & (tg >= 0)
        longm = m_mig & (mig_s >= dt)
        subm = m_mig & ~longm
        idx1 = jnp.where(subm | has_t, tg, i0)

        # ---- plant step for running containers ----------------------
        mult_c = _lutf(tabs.multiple, idx1)
        base_c = _lutf(tabs.base_w, idx1)
        peak_c = _lutf(tabs.peak_w, idx1)
        cap = mult_c * duty                     # duty in [0,1]: clamp elided
        srv = jnp.minimum(d, cap)
        util = srv / mult_c
        pw = base_c + (peak_c - base_c) * util
        down = jnp.minimum(mig_s, dt) / dt
        p_mig = base_i + base_dst
        full = m_res | m_stay
        power = jnp.where(migm, base_i + base_dm, 0.0)
        if not srs:
            power = jnp.where(m_sus, base_i, power)
        power = jnp.where(longm, p_mig, power)
        power = jnp.where(full, pw, power)
        power = jnp.where(subm, down * p_mig + (1.0 - down) * pw, power)
        served = jnp.where(full, srv, 0.0)
        served = jnp.where(subm, (1.0 - down) * srv, served)
        ps = _pack(power, served)
        power, served = ps[0], ps[1]

        # ---- fused accounting (scalar _account, reassociated) --------
        # accumulate raw per-step sums; the loop-invariant dt/3600/1000
        # scalings apply once after the scan. Time-on-slice and
        # suspended time are interval *counters* (i32) scaled by dt at
        # the end. Both reassociations shift results by ~1e-13 relative
        # — far inside the backend's 1e-6 parity budget.
        suspended1 = jnp.where(m_sus, True, sus)
        suspended1 = jnp.where(m_res, False, suspended1)
        tos_col = jnp.where(suspended1, S, idx1)
        rows = [power * c,                              # -> emissions_g
                power,                                  # -> energy_wh
                served,                                 # -> work_done
                jnp.maximum(0.0, d - served)]           # -> throttled
        if traffic is not None or energy is not None:
            rows.append(d)                              # -> work_demanded
        if has_gap:
            # telemetry outage: emissions happen but the meter is blind
            rows.append(rows[0] * g)                    # -> unmetered_g
        contribs = jnp.stack(rows)
        acc1 = acc + contribs

        # ---- migration progress + dwell (after accounting) ----------
        migr1 = jnp.where(longm, mig_s - dt, migr_s0)
        migr2 = jnp.where(migm, migr1 - dt, migr1)
        done = migm & (migr2 <= 0.0)
        slice2 = jnp.where(done, mt0, idx1)
        mt1 = jnp.where(longm, tg, mt0)
        mt2 = jnp.where(done, -1, mt1)
        dwell1 = jnp.where(subm, 0, dwell0)
        dwell1 = jnp.where(done, 0, dwell1)
        dwell2 = dwell1 + ((kind >= 0) & (kind != K_MIGRATE))
        migs2 = dyni[_I_MIGS] + m_mig
        dynf1 = jnp.stack([duty, migr2])
        dyni1 = jnp.concatenate(
            [jnp.stack([slice2, mt2, dwell2, migs2,
                        suspended1.astype(jnp.int32),
                        dyni[_I_SUSCNT] + m_sus]),       # suspended count
             dyni[_I_SUSCNT + 1:]
             + (tos_col[None, :] == tos_cols[:, None])])
        ys = (power, served) if record else None
        st1 = ((acc1, dynf1, dyni1, win1) if use_peak
               else (acc1, dynf1, dyni1))
        if traffic is not None:
            st1 = st1 + (rep1,)
        if energy is not None:
            st1 = st1 + (soc1,)
        return st1, ys

    st0 = ((acc0, dynf0, dyni0, win0) if use_peak
           else (acc0, dynf0, dyni0))
    if traffic is not None:
        st0 = st0 + (rep0,)
    if energy is not None:
        st0 = st0 + (soc0,)
    if cmode == "indexed":
        xs = (demand, codes, region_mat)
        if traffic is not None:
            xs = xs + (req_mat,)
        if energy is not None:
            xs = xs + (solar_mat, up_mat)
    else:
        xs = (demand, cmat)
    if has_obs:
        xs = xs + (obs_mat,)
    if has_gap:
        xs = xs + (gap_vec,)
    carry, ys = lax.scan(step, st0, xs)
    return carry[:3], ys


class FleetSimulatorJax:
    """Drop-in JAX counterpart of `FleetSimulator`: same `run` signature
    (minus custom-policy support), same `FleetResult` out, one XLA
    computation per (policy, shape) pair. First call per signature
    compiles; steady-state calls are device-resident end-to-end."""

    def __init__(self, family: SliceFamily, interval_s: float = 300.0,
                 suspend_releases_slice: bool = True,
                 migration: Optional[MigrationCostModel] = None):
        _require_jax()
        self.family = family
        self.tables = family.tables()
        self.interval_s = float(interval_s)
        self.suspend_releases_slice = suspend_releases_slice
        self.mig = migration or MigrationCostModel()
        self._tabs = _TablesS.from_tables(self.tables)

    def _mig_spec(self) -> tuple:
        m = self.mig
        return (m.suspend_base_s, m.suspend_per_gb_s, m.resume_base_s,
                m.resume_per_gb_s, m.compress_per_gb_s,
                m.decompress_per_gb_s, m.compression_ratio,
                m.transfer_gbps, m.restore_extra_s)

    def run(self, policy, demand, carbon, targets, epsilon=0.05,
            state_gb=1.0, demand_scale=1.0, record: bool = False,
            n_rep: int = 1, traffic=None, energy=None,
            carbon_obs=None, power_gap=None) -> FleetResult:
        """Advance the fleet; same contract as `FleetSimulator.run`, plus
        the memory-lean indexed-carbon form: `carbon` may be a
        ``(region_mat (T, R), codes (T, n_cols) int)`` pair — a
        placement plan's region-intensity table plus per-epoch region
        codes — in which case `demand` is the compact (T, n_cols)
        matrix and ``n_rep`` tiles its columns inside the scan step to
        the logical fleet width N = n_cols * n_rep (targets/epsilon/
        state_gb are full-N). No (T, N) array exists on host or device.

        `traffic` (indexed-carbon runs only) is a ``(TrafficSpec,
        req_mat (T, R))`` pair: the scan then also routes + autoscales
        the request tensor each epoch and modulates container demand by
        the per-region serving load (see `_fleet_scan`).

        `energy` (indexed-carbon runs only) is an ``(EnergySpec,
        solar_mat (T, R), grid_up (T, R))`` triple: the scan then also
        advances the virtual energy supply each epoch, clamping demand
        by the per-region virtual-cap fraction and billing emissions at
        the delivered mix's effective intensity (see `_fleet_scan`).

        `carbon_obs` splits the signal plane from the billing plane
        (see `_fleet_scan`): the policy decides — and budgets — on the
        observed intensity while emissions stay billed at `carbon`.
        Indexed runs take a (T, R) observed region matrix; dense runs a
        (T,) or (T, N) observed matrix. `power_gap` is a (T,) 0/1
        vector of power-telemetry outage epochs; the result then
        carries `unmetered_g`, the emissions accrued while the meter
        was blind.
        """
        spec = _policy_spec(policy)
        t = self.tables
        dt = self.interval_s
        indexed = isinstance(carbon, tuple)
        if traffic is not None and not indexed:
            raise ValueError("traffic fold requires indexed carbon "
                             "(region_mat, codes)")
        if energy is not None and not indexed:
            raise ValueError("energy fold requires indexed carbon "
                             "(region_mat, codes)")
        if indexed:
            region_mat, codes = carbon
            demand = np.asarray(demand, dtype=np.float64)
            if demand.ndim != 2:
                raise ValueError("indexed-carbon run needs (T, n_cols) "
                                 "demand")
            if demand_scale is not None and np.any(
                    np.asarray(demand_scale) != 1.0):
                demand = demand * demand_scale
            if demand.size and demand.min() < 0.0:
                raise ValueError("fleet demand must be non-negative")
            T, n_cols = demand.shape
            N = n_cols * int(n_rep)
            region_mat = np.asarray(region_mat, dtype=np.float64)
            codes = np.asarray(codes, dtype=np.int32)
            if region_mat.ndim != 2 or region_mat.shape[0] != T:
                raise ValueError(f"region matrix shape {region_mat.shape}"
                                 f" does not match demand (T={T})")
            if codes.shape != (T, n_cols):
                raise ValueError(f"region codes shape {codes.shape} does "
                                 f"not match demand {(T, n_cols)}")
            R = region_mat.shape[1]
            t_spec = req_mat = None
            if traffic is not None:
                t_spec, req_mat = traffic
                req_mat = np.asarray(req_mat, dtype=np.float64)
                if req_mat.shape != (T, R):
                    raise ValueError(f"traffic request tensor shape "
                                     f"{req_mat.shape}; expected {(T, R)}")
            e_spec = solar_mat = up_mat = None
            if energy is not None:
                e_spec, solar_mat, up_mat = energy
                solar_mat = np.asarray(solar_mat, dtype=np.float64)
                up_mat = np.asarray(up_mat, dtype=np.float64)
                if solar_mat.shape != (T, R) or up_mat.shape != (T, R):
                    raise ValueError(
                        f"energy solar/grid-up tensor shapes "
                        f"{solar_mat.shape} / {up_mat.shape}; expected "
                        f"{(T, R)}")
            targets = np.broadcast_to(
                np.asarray(targets, dtype=np.float64), (N,))
            epsilon = np.broadcast_to(
                np.asarray(epsilon, dtype=np.float64), (N,))
            state_gb = np.broadcast_to(
                np.asarray(state_gb, dtype=np.float64), (N,))
        else:
            if n_rep != 1:
                raise ValueError("n_rep tiling requires indexed carbon")
            (demand, cmat, targets, epsilon, state_gb, T, N) = \
                _prepare_run_inputs(demand, carbon, targets, epsilon,
                                    state_gb, demand_scale, self.interval_s)
            R = 0
        if carbon_obs is not None:
            carbon_obs = np.asarray(carbon_obs, dtype=np.float64)
            if indexed:
                if carbon_obs.shape != (T, R):
                    raise ValueError(f"observed carbon shape "
                                     f"{carbon_obs.shape}; indexed runs "
                                     f"need the (T, R) region form "
                                     f"{(T, R)}")
            elif carbon_obs.shape not in ((T,), (T, N)):
                raise ValueError(f"observed carbon shape "
                                 f"{carbon_obs.shape} does not match "
                                 f"(T,)={T,} or (T, N)={(T, N)}")
        if power_gap is not None:
            power_gap = np.asarray(power_gap, dtype=np.float64)
            if power_gap.shape != (T,):
                raise ValueError(f"power-gap vector shape "
                                 f"{power_gap.shape}; expected {(T,)}")

        # container-parallel sharding: containers never interact, so the
        # fleet splits into contiguous column shards dispatched to the
        # host's XLA devices (jax dispatch is async — shards execute
        # concurrently, one thread pool per device). Results concatenate
        # bit-identically to the unsharded run. Multiple host devices
        # come from XLA_FLAGS=--xla_force_host_platform_device_count=K.
        # Indexed runs shard over rep blocks (the compact columns are
        # shared, so column shards would re-push them per device anyway).
        devices = jax.devices()
        if indexed:
            n_sh = max(1, min(len(devices), int(n_rep),
                              N // _MIN_SHARD_COLS or 1))
        else:
            n_sh = max(1, min(len(devices), N // _MIN_SHARD_COLS))
        kw = dict(spec=spec, srs=self.suspend_releases_slice,
                  record=record, tabs=self._tabs, dt=dt,
                  mig=self._mig_spec())
        with enable_x64():
            outs = []
            for s in range(n_sh):
                dev = devices[s]
                if indexed:
                    lo_r = s * n_rep // n_sh
                    hi_r = (s + 1) * n_rep // n_sh
                    lo, hi = lo_r * n_cols, hi_r * n_cols
                    cm = (jax.device_put(region_mat, dev),
                          jax.device_put(codes, dev))
                    dm = jax.device_put(demand, dev)
                    rq = (jax.device_put(req_mat, dev)
                          if traffic is not None else None)
                    sm = (jax.device_put(solar_mat, dev)
                          if energy is not None else None)
                    um = (jax.device_put(up_mat, dev)
                          if energy is not None else None)
                    ob = (jax.device_put(carbon_obs, dev)
                          if carbon_obs is not None else None)
                    gp = (jax.device_put(power_gap, dev)
                          if power_gap is not None else None)
                    outs.append(_fleet_scan(
                        dm, cm,
                        jax.device_put(targets[lo:hi], dev),
                        jax.device_put(epsilon[lo:hi], dev),
                        jax.device_put(state_gb[lo:hi], dev), rq, sm, um,
                        ob, gp,
                        cmode="indexed", n_rep=hi_r - lo_r, R=R,
                        traffic=t_spec, energy=e_spec, **kw))
                else:
                    lo = s * N // n_sh
                    hi = (s + 1) * N // n_sh
                    cm = cmat if cmat.ndim == 1 else cmat[:, lo:hi]
                    ob = None
                    if carbon_obs is not None:
                        ob = (carbon_obs if carbon_obs.ndim == 1
                              else carbon_obs[:, lo:hi])
                        ob = jax.device_put(ob, dev)
                    gp = (jax.device_put(power_gap, dev)
                          if power_gap is not None else None)
                    outs.append(_fleet_scan(
                        jax.device_put(demand[:, lo:hi], dev),
                        jax.device_put(cm, dev),
                        jax.device_put(targets[lo:hi], dev),
                        jax.device_put(epsilon[lo:hi], dev),
                        jax.device_put(state_gb[lo:hi], dev),
                        obs_mat=ob, gap_vec=gp, **kw))
            acc = np.concatenate(
                [jax.device_get(o[0][0]) for o in outs], axis=1)
            dyni = np.concatenate(
                [jax.device_get(o[0][2]) for o in outs], axis=1)
            ys = None
            if record:
                ys = tuple(np.concatenate(
                    [jax.device_get(o[1][k]) for o in outs], axis=1)
                    for k in range(2))

        elapsed = float(np.cumsum(np.full(T, dt))[-1]) if T else 0.0
        if traffic is not None or energy is not None:
            # host demand is pre-modulation/pre-cap: the scan's fifth
            # accumulator row carries the effective per-container sums
            work_dem = acc[_ACC_ROWS] * dt
        else:
            work_dem = demand.sum(axis=0) * dt
            if indexed and n_rep > 1:
                work_dem = np.tile(work_dem, n_rep)
        # loop-invariant scalings deferred out of the scan (see
        # _fleet_scan's accounting note); term order mirrors _account
        return FleetResult(
            emissions_g=acc[0] / 1000.0 * dt / 3600.0,
            energy_wh=acc[1] * dt / 3600.0,
            work_done=acc[2] * dt,
            work_demanded=work_dem,
            throttled_integral=acc[3] * dt,
            migrations=dyni[_I_MIGS].astype(np.int64),
            suspended_s=dyni[_I_SUSCNT].astype(np.float64) * dt,
            elapsed_s=np.full(N, elapsed),
            time_on_slice_s=np.ascontiguousarray(
                dyni[_I_SUSCNT + 1:].T.astype(np.float64)) * dt,
            slice_names=t.names + ("suspended",),
            baseline_cap=float(t.multiple[t.baseline_idx]),
            power_series=ys[0] if record else None,
            served_series=ys[1] if record else None,
            unmetered_g=(acc[-1] / 1000.0 * dt / 3600.0
                         if power_gap is not None else None),
        )


# ---------------------------------------------------------------------------
# Population sweep on the JAX path (backend="jax" in sweep_population)
# ---------------------------------------------------------------------------

def sweep_population_jax(policies: dict, family: SliceFamily, traces,
                         carbon, targets: Sequence[float],
                         cfg_base: SimConfig,
                         demand_scale: float = 1.0,
                         placement=None, traffic=None,
                         elasticity=None, energy=None,
                         admission_impl: str = "auto",
                         faults=None) -> list:
    """JAX-backed `sweep_population`: one device-resident scan per policy
    over all (target x trace) columns, same aggregate rows, same order,
    as the fleet backend (parity pinned <= 1e-6 by the test suite).

    With `placement`, the shared region plan is computed by the JAX
    placement kernel (`repro.cluster.placement_jax.plan_jax`) on the
    real n_tr-column fleet, exactly as the fleet backend does with the
    NumPy planner — and the sweep takes the memory-lean path: compact
    (T, n_tr) demand plus the plan's (region_intensity, assign-codes)
    indexed carbon, tiled to the logical n_tr*n_tg fleet *inside* the
    scan step, so no (T, N) matrix is ever materialized (the fleet
    backend's tiled form is ~2.3 GB per matrix at N=1M). The indexed
    select reproduces the gathered matrix bit-exactly, so sweep parity
    with the fleet backend is unchanged. `admission_impl` is forwarded
    to `plan_jax` ("auto" | "xla" | "pallas").

    With `faults` (a `repro.robustness.FaultPlan`), the observed/true
    split is materialized host-side by the *shared* prologue — the jax
    planner threads the same seeded migration-failure mask, the scan
    decides on the (T, R) observed region matrix (R-way selected in
    step, so still nothing (T, N)) while billing the true one, and
    power-telemetry gaps accrue `unmetered_g` — so the degraded
    signals are identical to the fleet backend's by construction.
    """
    _require_jax()

    def _plan(eng, demand_plan, flt):
        from repro.cluster.placement_jax import plan_jax
        return plan_jax(eng, demand_plan, state_gb=cfg_base.state_gb,
                        admission_impl=admission_impl, faults=flt)

    compact = placement is not None
    (demand_one, tgt_one, carbon, plan, n_tr, n_tg, grid_up, fault_ctx) = \
        _prepare_sweep_inputs(traces, carbon, targets, cfg_base,
                              demand_scale, placement, _plan,
                              tile=not compact, energy=energy,
                              faults=faults)
    n_rep = 1
    carbon_obs = None
    gap_vec = fault_ctx.gap_vec if fault_ctx is not None else None
    if compact:
        if fault_ctx is None:
            carbon = (plan.region_intensity, plan.assign.astype(np.int32))
        else:
            # bill at the TRUE region intensities; the plan's own table
            # (region_intensity) IS the observed feed under faults and
            # becomes the scan's decision signal
            carbon = (fault_ctx.true_reg, plan.assign.astype(np.int32))
            carbon_obs = plan.region_intensity
        n_rep = n_tg
    elif fault_ctx is not None:
        obs = fault_ctx.obs_reg
        carbon_obs = np.tile(obs, (1, n_tg)) if obs.ndim == 2 else obs

    traffic_summary = None
    run_traffic = None
    mod_cols = None
    T = demand_one.shape[0]
    if traffic is not None:
        from repro.traffic.sim_jax import TrafficSpec
        arr, tres = _prepare_traffic(traffic, plan, T, cfg_base.interval_s)
        traffic_summary = tres.summary()
        if elasticity is None:
            # the in-scan traffic_step fold drives the demand modulation
            # on device; the serving-ledger row metrics come from the
            # (tiny, (T, R)) NumPy pipeline — parity between the two is
            # pinned <=1e-6 by the jax traffic tests
            run_traffic = (TrafficSpec.from_config(traffic,
                                                   cfg_base.interval_s),
                           arr.requests)
        if elasticity is not None or energy is not None:
            # the host-side compact pipeline (energy supply load,
            # elasticity forecasters) needs the modulation as host
            # floats — same gather as the fleet backend (with
            # elasticity this also keeps the level counts exact, not
            # just 1e-6-close)
            mod = tres.demand_mod(traffic.demand_gain)
            mod_cols = mod[np.arange(T)[:, None], plan.assign[:T]]

    # compact host pipeline, pinned layer order (see the fleet backend):
    # demand_scale -> traffic -> energy -> elasticity
    comp = None
    if energy is not None or elasticity is not None:
        comp = demand_one                       # compact (T, n_tr)
        if demand_scale is not None and np.any(
                np.asarray(demand_scale) != 1.0):
            comp = comp * demand_scale
        if mod_cols is not None:
            comp = comp * mod_cols

    energy_summary = None
    run_energy = None
    ela_forecast = None
    if fault_ctx is not None and compact:
        # controller-side forecast feed: the observed grid (overridden
        # below onto the delivered mix when the energy layer is on)
        ela_forecast = plan.region_intensity
    if energy is not None:
        spec_e, sres, solar_mat, cap_cols, ceff_cols = _prepare_energy(
            energy, family, plan, comp, T, cfg_base.interval_s, grid_up,
            region_mat=(fault_ctx.true_reg if fault_ctx is not None
                        else None))
        energy_summary = sres.summary()
        if elasticity is None:
            # in-scan fold: the scan re-derives the supply ledger on
            # device from the (traffic-modulated) demand and applies
            # cap/c_eff per epoch; the energy_* row metrics above come
            # from the shared host simulation (the two agree <=1e-6,
            # pinned by the energy tests). Under faults the raw
            # observed grid rides along as obs_mat and the step scales
            # it onto the delivered mix by the observed/true ratio.
            run_energy = (spec_e, solar_mat, grid_up)
        else:
            # with elasticity downstream the cap must land *before* the
            # demand forecasters — host-applied, same floats as the
            # fleet backend; billing (and the carbon forecast) switch
            # to the delivered mix's effective intensity
            comp = comp * cap_cols
            carbon = (sres.c_eff, plan.assign.astype(np.int32))
            if fault_ctx is not None:
                # observed delivered mix: true effective intensity
                # scaled by the per-region observed/true grid ratio —
                # same host floats as the fleet backend
                tr = fault_ctx.true_reg[:T]
                safe = np.where(tr > 0.0, tr, 1.0)
                ratio = np.where(tr > 0.0,
                                 fault_ctx.obs_reg[:T] / safe, 1.0)
                carbon_obs = sres.c_eff * ratio
                ela_forecast = carbon_obs

    elastic_summary = None
    if elasticity is not None:
        if plan is None:
            raise ValueError("elasticity requires placement")
        from repro.core.elasticity_jax import simulate_elastic_jax
        # separate compact-width scan (NOT folded into the sharded fleet
        # scan — the (N·K,) argsort would run once per device shard);
        # its served demand is what the fleet below advances on. With
        # energy on, `carbon` is the (c_eff, codes) indexed pair, so
        # both the actual intensity and its forecast see the delivered
        # mix — exactly like the fleet backend's ceff_reg forecast.
        eres = simulate_elastic_jax(comp, carbon, elasticity,
                                    cfg_base.interval_s,
                                    budget_series=_elastic_budget_series(
                                        plan, T, elasticity,
                                        cfg_base.interval_s),
                                    carbon_forecast=ela_forecast)
        demand_one = eres.demand_served()
        demand_scale = 1.0          # already applied ahead of the layer
        elastic_summary = eres.summary()

    sim = FleetSimulatorJax(
        family, interval_s=cfg_base.interval_s,
        suspend_releases_slice=cfg_base.suspend_releases_slice)
    results = {}
    for name, mk_policy in policies.items():
        results[name] = (sim.run(mk_policy(), demand_one, carbon, tgt_one,
                                 epsilon=cfg_base.epsilon,
                                 state_gb=cfg_base.state_gb,
                                 demand_scale=demand_scale,
                                 n_rep=n_rep, traffic=run_traffic,
                                 energy=run_energy,
                                 carbon_obs=carbon_obs,
                                 power_gap=gap_vec), 0)
    fault_summary = None
    if fault_ctx is not None:
        fault_summary = fault_ctx.signal.summary()
        if plan is not None and plan.failed_migrations is not None:
            fault_summary["fault_failed_migrations_mean"] = float(
                np.mean(plan.failed_migrations))
    return _aggregate_sweep_rows(policies, results, targets, n_tr, plan,
                                 traffic_summary, elastic_summary,
                                 energy_summary, fault_summary)
