"""JAX backend for the per-container elasticity layer.

One jitted `lax.scan` over epochs runs the (N, K) CarbonScaler greedy
of `repro.core.elasticity` at fleet scale: float64 (scoped
`enable_x64`), per-epoch temporaries only (N,)/(N, K) — nothing
(T, N) is materialized on device beyond the input/output streams.

Carbon comes either dense (T, N) or as the placed fleet's
`(region_mat (T, R), codes (T, N) int32)` pair; the indexed form
derives each epoch's per-container intensity with the same R-way
select chain as `repro.core.fleet_jax._fleet_scan`, which reproduces
the host gather bit-exactly. Both forecasts are precomputed host-side
by the same `repro.carbon.forecast` functions the NumPy backend uses —
carbon on the tiny (T, R) region matrix when indexed, demand on the
(T, N) matrix (one extra demand-sized xs stream; the scan itself
carries nothing (T, N)) — so estimates, greedy scores, and allocated
level counts are bit-identical to the NumPy backend by construction.

The scan runs separately from the fleet scan on purpose: the fleet
scan executes once per device shard, and duplicating the (N·K,)
argsort per shard would multiply the dominant cost by the shard
count. Instead this scan runs once at compact width and its served
demand feeds the (unchanged) sharded fleet scan.
"""
from __future__ import annotations

import numpy as np

try:
    import jax
    import jax.numpy as jnp
    from jax import lax
    from jax.experimental import enable_x64
    HAS_JAX = True
except ImportError:                                    # pragma: no cover
    jax = jnp = lax = enable_x64 = None
    HAS_JAX = False

from repro.carbon.forecast import forecast_series
from repro.core.elasticity import (ElasticityConfig, ElasticResult,
                                   shaped_budget_series)

_SCAN_CACHE: dict = {}


def _spec_key(cfg: ElasticityConfig, interval_s: float):
    return (cfg.k_levels, cfg.unit_capacity, cfg.base_w, cfg.peak_w,
            cfg.min_level, cfg.max_step, cfg.budget_g_per_epoch,
            cfg.forecast, cfg.rho, float(interval_s))


def _build_scan(cfg: ElasticityConfig, interval_s: float, n: int,
                R, record: bool):
    """Jitted epoch scan for one (config, width, carbon-layout)."""
    dt = float(interval_s)
    capw = cfg.capw(dt)
    span = cfg.peak_w - cfg.base_w
    K = cfg.k_levels
    budget = cfg.budget_g_per_epoch
    indexed = R is not None
    k_idx = np.arange(1, K + 1, dtype=np.float64)[None, :]
    con_of = np.repeat(np.arange(n), K)

    def emis_g(lev, work_w, chat):
        pw = lev * cfg.base_w + span * (work_w / capw)
        return jnp.sum(pw * dt / 3600.0 * chat / 1000.0)

    def step(st, x):
        prev, backlog, scal = st
        if indexed:
            d, dhat, bud, code, c_row, chat_row = x
            # R-way select chain (bit-exact vs host gather, same idiom
            # as _fleet_scan)
            c = jnp.full(code.shape, c_row[0], dtype=jnp.float64)
            chat = jnp.full(code.shape, chat_row[0], dtype=jnp.float64)
            for r in range(1, R):
                c = jnp.where(code == r, c_row[r], c)
                chat = jnp.where(code == r, chat_row[r], chat)
        else:
            d, dhat, bud, c, chat = x

        want = dhat * dt + backlog
        need = jnp.ceil(want / capw)
        lo = jnp.maximum(float(cfg.min_level), prev - cfg.max_step)
        hi = jnp.minimum(float(cfg.k_levels), prev + cfg.max_step)
        desired = jnp.minimum(jnp.maximum(need, lo), hi)
        if budget is None:
            alloc = desired
        else:
            w = jnp.clip(want[:, None] - (k_idx - 1.0) * capw, 0.0, capw)
            g = ((cfg.base_w + span * (w / capw))
                 * dt / 3600.0 * chat[:, None] / 1000.0)
            mand = k_idx <= lo[:, None]
            opt = (k_idx > lo[:, None]) & (k_idx <= desired[:, None])
            mand_g = jnp.cumsum(jnp.where(mand, g, 0.0).ravel())[-1]
            # zero-gram guard: free levels first, no overflow division
            freeg = g <= 0.0
            eff = w / jnp.where(freeg, 1.0, g)
            score = jnp.where(opt, jnp.where(freeg, -jnp.inf, -eff),
                              jnp.inf).ravel()
            order = jnp.argsort(score)                 # stable by default
            gs = jnp.where(opt, g, 0.0).ravel()[order]
            cum = jnp.cumsum(gs)
            admit = opt.ravel()[order] & (mand_g + cum <= bud)
            counts = jnp.zeros(n, dtype=jnp.float64).at[
                jnp.asarray(con_of)[order]].add(admit.astype(jnp.float64))
            alloc = lo + counts

        offered = d * dt
        est_w = jnp.minimum(want, alloc * capw)
        srv = jnp.minimum(offered + backlog, alloc * capw)
        backlog = backlog + offered - srv
        est_step = emis_g(alloc, est_w, chat)
        act_step = emis_g(alloc, srv, c)
        if budget is None:
            viol = jnp.zeros((), dtype=jnp.float64)
        else:
            mand_w = jnp.minimum(want, lo * capw)
            mand_total = emis_g(lo, mand_w, chat)
            viol = (est_step
                    > jnp.maximum(bud, mand_total) + 1e-9).astype(
                        jnp.float64)
        # scalar accumulators: est_g, act_g, viol, level_epochs
        scal = scal + jnp.stack([est_step, act_step, viol,
                                 jnp.sum(alloc)])
        ys = (srv / dt, alloc.astype(jnp.int32)) if record else srv / dt
        return (alloc, backlog, scal), ys

    def scan_fn(xs):
        st0 = (jnp.full(n, float(cfg.min_level), dtype=jnp.float64),
               jnp.zeros(n, dtype=jnp.float64),
               jnp.zeros(4, dtype=jnp.float64))
        return lax.scan(step, st0, xs)

    return jax.jit(scan_fn)


def _budget_array(budget_series, cfg: ElasticityConfig, dt: float,
                  T: int, signal_fn):
    """(T,) per-epoch budgets for the scan (zeros when uncapped).

    The scan's no-budget branch is static, so the placeholder zeros are
    never read. Shaped budgets are computed host-side — same helper,
    same floats as the NumPy backend.
    """
    if budget_series is not None:
        bud = np.asarray(budget_series, dtype=np.float64)
        if bud.shape != (T,):
            raise ValueError(f"budget_series must be ({T},); "
                             f"got {bud.shape}")
        return bud
    if cfg.budget_g_per_epoch is None:
        return np.zeros(T, dtype=np.float64)
    if cfg.shape_budget:
        return shaped_budget_series(signal_fn(), cfg, dt)
    return np.full(T, float(cfg.budget_g_per_epoch))


def simulate_elastic_jax(demand, carbon, cfg: ElasticityConfig,
                         interval_s: float = 300.0,
                         record: bool = False,
                         budget_series=None,
                         carbon_forecast=None) -> ElasticResult:
    """JAX port of `repro.core.elasticity.simulate_elastic`.

    demand : (T, N) demand rate (host array)
    carbon : dense (T, N), or `(region_mat (T, R), codes (T, N))` for
             the placed-fleet indexed layout
    With `record=False` the per-epoch levels are not streamed out
    (`ElasticResult.levels` is empty) — the summary totals still
    include them via an in-scan accumulator.
    `budget_series` overrides the per-epoch budgets (see
    `simulate_elastic`); when omitted and `cfg.shape_budget` is set it
    is derived host-side from the mean-over-containers carbon signal,
    matching the NumPy backend bit for bit.
    `carbon_forecast` overrides the matrix the carbon forecaster runs
    on — the scaler then plans against that signal while billing
    `carbon` (the observed/true split under signal-plane faults):
    (T, R) region form in indexed mode, (T, N) dense otherwise. The
    fleet backend forecasts the very same matrix host-side, so the two
    stay bit-identical (forecast-then-gather on both).
    """
    if not HAS_JAX:
        raise ImportError("simulate_elastic_jax requires jax; use "
                          "repro.core.elasticity.simulate_elastic")
    demand = np.asarray(demand, dtype=np.float64)
    if demand.ndim != 2:
        raise ValueError(f"demand must be (T, N); got {demand.shape}")
    T, n = demand.shape
    dt = float(interval_s)
    period = max(1, int(round(24 * 3600.0 / dt)))
    fmode = {"oracle": "oracle", "persistence": "persistence",
             "forecast": "diurnal_ar1"}[cfg.forecast]
    dhat = forecast_series(demand, fmode, period_steps=period, rho=cfg.rho)

    indexed = isinstance(carbon, tuple)
    if indexed:
        region_mat, codes = carbon
        region_mat = np.asarray(region_mat, dtype=np.float64)
        codes = np.asarray(codes, dtype=np.int32)
        if region_mat.ndim != 2 or region_mat.shape[0] != T \
                or codes.shape != (T, n):
            raise ValueError(f"indexed carbon shapes {region_mat.shape} / "
                             f"{codes.shape} do not match demand (T={T}, "
                             f"N={n})")
        R = region_mat.shape[1]
        fc_src = region_mat
        if carbon_forecast is not None:
            fc_src = np.asarray(carbon_forecast, dtype=np.float64)
            if fc_src.shape != region_mat.shape:
                raise ValueError(f"carbon_forecast shape {fc_src.shape} "
                                 f"must match the region matrix "
                                 f"{region_mat.shape}")
        chat_reg = forecast_series(fc_src, fmode, period_steps=period,
                                   rho=cfg.rho)
        bud = _budget_array(budget_series, cfg, dt, T, lambda:
                            region_mat[np.arange(T)[:, None],
                                       codes].mean(axis=1))
        xs = (demand, dhat, bud, codes, region_mat, chat_reg)
    else:
        carbon = np.asarray(carbon, dtype=np.float64)
        if carbon.shape != demand.shape:
            raise ValueError(f"carbon {carbon.shape} must match demand "
                             f"{demand.shape}")
        R = None
        fc_src = carbon
        if carbon_forecast is not None:
            fc_src = np.asarray(carbon_forecast, dtype=np.float64)
            if fc_src.shape != carbon.shape:
                raise ValueError(f"carbon_forecast shape {fc_src.shape} "
                                 f"must match carbon {carbon.shape}")
        chat = forecast_series(fc_src, fmode, period_steps=period,
                               rho=cfg.rho)
        bud = _budget_array(budget_series, cfg, dt, T,
                            lambda: carbon.mean(axis=1))
        xs = (demand, dhat, bud, carbon, chat)

    key = (_spec_key(cfg, dt), T, n, R, bool(record))
    fn = _SCAN_CACHE.get(key)
    with enable_x64():
        if fn is None:
            fn = _build_scan(cfg, dt, n, R, record)
            _SCAN_CACHE[key] = fn
        dev = jax.devices()[0]
        xs_dev = tuple(jax.device_put(a, dev) for a in xs)
        (prev, backlog, scal), ys = fn(xs_dev)
        served_rate = np.asarray((ys[0] if record else ys))
        levels = (np.asarray(ys[1], dtype=np.int64) if record
                  else np.zeros((0, n), dtype=np.int64))
        backlog = np.asarray(backlog)
        scal = np.asarray(scal)

    return ElasticResult(levels=levels, served_w=served_rate * dt,
                         offered_w=demand * dt, backlog=backlog,
                         est_emissions_g=float(scal[0]),
                         emissions_g=float(scal[1]),
                         cap_violations=int(round(float(scal[2]))),
                         interval_s=dt,
                         level_epochs=int(round(float(scal[3]))))
