"""Vectorized fleet simulator: N Carbon Containers advanced in lockstep.

The scalar `repro.core.simulator.simulate` runs one pure-Python loop per
container, which makes population sweeps (paper Figs 11-16) and
CarbonScaler/Ecovisor-style fleet studies prohibitively slow. This module
advances a whole fleet per monitoring interval using NumPy array state.

Array-state layout
------------------
`FleetState` holds one `(N,)` array per scalar `ContainerState` field:

    slice_idx      int64   current slice (index into the FamilyTables)
    duty           f64     duty-cycle quota set by the last decision
    suspended      bool    container released / idle-parked
    migrating_s    f64     remaining stop-and-copy downtime (0 = none)
    migrate_target int64   destination slice while migrating (-1 = none)
    dwell          int64   intervals since the last migration
    emissions_g, energy_wh, work_done, throttled_integral,
    demand_integral, suspended_s, elapsed_s           f64 accumulators
    migrations     int64
    time_on_slice_s  (N, S+1) f64; column S counts suspended time
    recent_peak    f64     rolling W-interval demand peak (precomputed as a
                           (T, N) sliding-window-max matrix before the loop)

Decision-kernel masking scheme
------------------------------
Each policy exposes `decide_batch(tables, state, demand, c, target, eps)`
returning `(kind, duty, target_slice)` arrays; branchy scalar `decide`
logic becomes boolean masks applied in scalar-return order (a `decided`
mask freezes containers that already matched an earlier return site, so
mask priority == scalar control flow). The step function then partitions
the fleet into {migrating, suspend, resume, migrate, stay} masks, computes
power/served per partition with the precomputed per-slice (base_w, peak_w,
multiple) lookup tables, and applies one fused accounting update.

Every arithmetic expression mirrors the scalar path term-for-term, so an
N=1 fleet reproduces `simulate()` bit-for-bit (see tests/test_fleet.py).
Per-container heterogeneity is first-class: `targets`, `epsilon`,
`state_gb` broadcast per container, `demand` is `(T, N)`, and `carbon`
accepts a `(T, N)` matrix for mixed-region (stacked-trace) fleets.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Sequence

import numpy as np

from repro.cluster.migration import MigrationCostModel
from repro.cluster.slices import SliceFamily
from repro.core.policy import (K_MIGRATE, K_RESUME, K_STAY, K_SUSPEND,
                               _budget_batch)
from repro.core.simulator import SimConfig, SimResult

_PEAK_WINDOW = 6          # ContainerState.observe_demand default (n=6)


@dataclass
class FleetState:
    """(N,)-array mirror of `ContainerState` (see module docstring)."""
    slice_idx: np.ndarray
    duty: np.ndarray
    suspended: np.ndarray
    migrating_s: np.ndarray
    migrate_target: np.ndarray
    dwell: np.ndarray
    emissions_g: np.ndarray
    energy_wh: np.ndarray
    work_done: np.ndarray
    throttled_integral: np.ndarray
    demand_integral: np.ndarray
    suspended_s: np.ndarray
    elapsed_s: np.ndarray
    migrations: np.ndarray
    time_on_slice_s: np.ndarray
    recent_peak: np.ndarray              # rolling-window demand peak

    @classmethod
    def init(cls, n: int, n_slices: int, baseline_idx: int) -> "FleetState":
        z = lambda: np.zeros(n, dtype=np.float64)
        return cls(
            slice_idx=np.full(n, baseline_idx, dtype=np.int64),
            duty=np.ones(n, dtype=np.float64),
            suspended=np.zeros(n, dtype=bool),
            migrating_s=z(),
            migrate_target=np.full(n, -1, dtype=np.int64),
            dwell=np.full(n, 10 ** 6, dtype=np.int64),   # as simulate() seeds
            emissions_g=z(), energy_wh=z(), work_done=z(),
            throttled_integral=z(), demand_integral=z(),
            suspended_s=z(), elapsed_s=z(),
            migrations=np.zeros(n, dtype=np.int64),
            time_on_slice_s=np.zeros((n, n_slices + 1), dtype=np.float64),
            recent_peak=z(),
        )


@dataclass
class FleetResult:
    """Per-container result arrays; `result(i)` extracts a scalar SimResult."""
    emissions_g: np.ndarray
    energy_wh: np.ndarray
    work_done: np.ndarray
    work_demanded: np.ndarray
    throttled_integral: np.ndarray
    migrations: np.ndarray
    suspended_s: np.ndarray
    elapsed_s: np.ndarray
    time_on_slice_s: np.ndarray          # (N, S+1); last column = suspended
    slice_names: tuple                   # S names + ("suspended",)
    baseline_cap: float
    power_series: Optional[np.ndarray] = None   # (T, N) when record=True
    served_series: Optional[np.ndarray] = None  # (T, N) when record=True
    unmetered_g: Optional[np.ndarray] = None    # (N,) emissions billed during
    #                                             power-telemetry gaps

    @property
    def n(self) -> int:
        return self.emissions_g.shape[0]

    @property
    def hours(self) -> np.ndarray:
        return self.elapsed_s / 3600.0

    @property
    def avg_carbon_rate(self) -> np.ndarray:
        return self.emissions_g / np.maximum(self.hours, 1e-12)

    @property
    def avg_throttle_pct(self) -> np.ndarray:
        return (100.0 * self.throttled_integral
                / np.maximum(self.elapsed_s, 1e-9) / self.baseline_cap)

    @property
    def suspended_frac(self) -> np.ndarray:
        return self.suspended_s / np.maximum(self.elapsed_s, 1e-9)

    def time_on_slice(self, i: int) -> dict:
        el = max(float(self.elapsed_s[i]), 1e-9)
        return {name: float(s) / el
                for name, s in zip(self.slice_names, self.time_on_slice_s[i])
                if s > 0.0}

    def result(self, i: int) -> SimResult:
        hours = float(self.elapsed_s[i]) / 3600.0
        el = max(float(self.elapsed_s[i]), 1e-9)
        return SimResult(
            avg_carbon_rate=float(self.emissions_g[i]) / max(hours, 1e-12),
            avg_throttle_pct=100.0 * float(self.throttled_integral[i]) / el
            / self.baseline_cap,
            work_done=float(self.work_done[i]),
            work_demanded=float(self.work_demanded[i]),
            energy_kwh=float(self.energy_wh[i]) / 1000.0,
            migrations=int(self.migrations[i]),
            suspended_frac=float(self.suspended_s[i]) / el,
            time_on_slice=self.time_on_slice(i),
            emissions_g=float(self.emissions_g[i]),
            hours=hours,
            series=None,
        )

    def results(self) -> list:
        return [self.result(i) for i in range(self.n)]


def _carbon_matrix(carbon, T: int, interval_s: float):
    """(T,) or (T, N) intensity values at each interval start."""
    if isinstance(carbon, np.ndarray):
        return carbon
    t = np.arange(T, dtype=np.float64) * interval_s
    if hasattr(carbon, "intensity_series"):
        return carbon.intensity_series(t)
    return np.array([carbon.intensity(float(x)) for x in t])


def _prepare_run_inputs(demand, carbon, targets, epsilon, state_gb,
                        demand_scale, interval_s: float):
    """Shared `run()` prologue for the fleet and jax backends: demand
    shaping/validation, carbon-matrix resolution, per-container
    broadcasts. One implementation so the two backends can never drift
    on what inputs they accept."""
    demand = np.asarray(demand, dtype=np.float64)
    if demand.ndim == 1:
        demand = demand[:, None]
    T, N = demand.shape
    if demand_scale is not None and np.any(np.asarray(demand_scale) != 1.0):
        demand = demand * demand_scale
    if demand.size and demand.min() < 0.0:
        raise ValueError("fleet demand must be non-negative")
    cmat = _carbon_matrix(carbon, T, interval_s)
    if cmat.ndim not in (1, 2) or cmat.shape[0] != T or (
            cmat.ndim == 2 and cmat.shape[1] != N):
        raise ValueError(f"carbon matrix shape {cmat.shape} does not "
                         f"match demand (T={T}, N={N}); expected (T,) "
                         f"or (T, N)")
    targets = np.broadcast_to(np.asarray(targets, dtype=np.float64), (N,))
    epsilon = np.broadcast_to(np.asarray(epsilon, dtype=np.float64), (N,))
    state_gb = np.broadcast_to(np.asarray(state_gb, dtype=np.float64), (N,))
    return demand, cmat, targets, epsilon, state_gb, T, N


class _LoopScratch:
    """Preallocated per-epoch temporaries for `FleetSimulator._loop`.

    The stepping loop previously allocated ~20 fresh (N,) arrays every
    epoch (masks, gathers, plant-step temps); reusing fixed buffers via
    ufunc `out=` keeps the arithmetic and its term order identical (the
    1e-9 scalar-parity suite pins this) while removing the allocator
    from the hot loop. Measured effect (see the fleet_sweep benchmark
    notes in benchmarks/figs.py): ~6-8% wall-clock at N~5000, neutral at
    N~500 — NumPy's small-block cache already amortizes most allocation,
    so only the single-pass ufunc-out rewrites pay; `np.take(..., out=)`
    needs mode="clip" to match fancy indexing's fast path, and rewrites
    that would split one `np.where` into two passes are kept as-is.
    """

    __slots__ = ("migm", "i1", "m1", "m2", "m3", "m4", "m5", "m6",
                 "m7", "m8", "m9", "f1", "f2", "f3", "f4", "f5", "f6",
                 "f7", "f8", "f9", "f10", "f11", "f12")

    def __init__(self, n: int):
        for name in ("migm", "m1", "m2", "m3", "m4", "m5", "m6", "m7",
                     "m8", "m9"):
            setattr(self, name, np.empty(n, dtype=bool))
        self.i1 = np.empty(n, dtype=np.int64)
        for name in ("f1", "f2", "f3", "f4", "f5", "f6", "f7", "f8", "f9",
                     "f10", "f11", "f12"):
            setattr(self, name, np.empty(n, dtype=np.float64))


class FleetSimulator:
    """Advance N containers under one policy with array state.

    Usage::

        sim = FleetSimulator(paper_family())
        res = sim.run(policy, demand,          # (T, N) utilization matrix
                      carbon,                  # provider | (T,) | (T, N)
                      targets=45.0)            # scalar or (N,)
    """

    def __init__(self, family: SliceFamily, interval_s: float = 300.0,
                 suspend_releases_slice: bool = True,
                 migration: Optional[MigrationCostModel] = None):
        self.family = family
        self.tables = family.tables()
        self.interval_s = float(interval_s)
        self.suspend_releases_slice = suspend_releases_slice
        self.mig = migration or MigrationCostModel()

    # -- inputs -----------------------------------------------------------

    def _carbon_matrix(self, carbon, T: int):
        """(T,) or (T, N) intensity values at each interval start."""
        return _carbon_matrix(carbon, T, self.interval_s)

    # -- main loop --------------------------------------------------------

    def run(self, policy, demand, carbon, targets, epsilon=0.05,
            state_gb=1.0, demand_scale=1.0, record: bool = False,
            carbon_obs=None, power_gap=None) -> FleetResult:
        """`carbon_obs` (optional (T,) or (T, N) matrix) splits the
        signal plane from the billing plane: decision kernels (and
        their precomputed power budgets) consume the *observed*
        intensity while emissions stay billed at the true `carbon` —
        see `repro.robustness`. `power_gap` (optional (T,) 0/1 vector)
        marks power-telemetry outage epochs; emissions during gaps are
        still billed but also accumulated into
        `FleetResult.unmetered_g` (the meter saw nothing)."""
        t = self.tables
        dt = self.interval_s
        (demand, cmat, targets, epsilon, state_gb, T, N) = \
            _prepare_run_inputs(demand, carbon, targets, epsilon, state_gb,
                                demand_scale, self.interval_s)
        if carbon_obs is not None:
            carbon_obs = np.asarray(carbon_obs, dtype=np.float64)
            if carbon_obs.shape not in ((T,), (T, N)):
                raise ValueError(f"carbon_obs shape {carbon_obs.shape} "
                                 f"does not match (T={T},) or (T, N={N})")
        gap = None
        if power_gap is not None:
            gap = np.asarray(power_gap, dtype=np.float64)
            if gap.shape != (T,):
                raise ValueError(f"power_gap shape {gap.shape} != (T={T},)")
        cf = _closed_form_kind(policy)
        if cf is not None:
            return self._run_closed_form(cf, demand, cmat, targets, epsilon,
                                         record, cmat_obs=carbon_obs,
                                         gap=gap)
        n_slices = len(t.multiple)
        st = FleetState.init(N, n_slices, t.baseline_idx)
        rows = np.arange(N)
        power_series = np.zeros((T, N)) if record else None
        served_series = np.zeros((T, N)) if record else None
        power = np.zeros(N)
        served = np.zeros(N)
        scratch = _LoopScratch(N)
        unmet = np.zeros(N) if gap is not None else None

        # loop-invariant precomputations (hoisted out of the time loop):
        # rolling-window demand peaks (ContainerState.recent_peak) ...
        peak_mat = demand.copy()
        for k in range(1, _PEAK_WINDOW):
            np.maximum(peak_mat[k:], demand[:-k], out=peak_mat[k:])
        # ... per-interval power budgets for the decision kernels (from
        # the observed feed — the controller has no other signal) ...
        cmat2 = cmat if cmat.ndim == 2 else cmat[:, None]
        if carbon_obs is not None:
            cmat2 = (carbon_obs if carbon_obs.ndim == 2
                     else carbon_obs[:, None])
        budget_mat = _budget_batch(targets[None, :], cmat2, epsilon[None, :])
        # ... and the demand-integral increments
        ddt_mat = demand * dt

        with np.errstate(divide="ignore", invalid="ignore"):
            self._loop(policy, st, demand, cmat, targets, epsilon, state_gb,
                       budget_mat, peak_mat, ddt_mat, power_series,
                       served_series, power, served, rows, T, N, n_slices,
                       scratch, cmat_obs=carbon_obs, gap=gap, unmet=unmet)
        # elapsed accumulates dt once per interval for every container;
        # hoisted out of the loop as the identical sequential sum
        st.elapsed_s.fill(float(np.cumsum(np.full(T, dt))[-1]) if T else 0.0)

        return FleetResult(
            emissions_g=st.emissions_g,
            energy_wh=st.energy_wh,
            work_done=st.work_done,
            work_demanded=st.demand_integral,
            throttled_integral=st.throttled_integral,
            migrations=st.migrations,
            suspended_s=st.suspended_s,
            elapsed_s=st.elapsed_s,
            time_on_slice_s=st.time_on_slice_s,
            slice_names=t.names + ("suspended",),
            baseline_cap=float(t.multiple[t.baseline_idx]),
            power_series=power_series,
            served_series=served_series,
            unmetered_g=unmet,
        )

    def _loop(self, policy, st, demand, cmat, targets, epsilon, state_gb,
              budget_mat, peak_mat, ddt_mat, power_series, served_series,
              power, served, rows, T, N, n_slices, scratch,
              cmat_obs=None, gap=None, unmet=None):
        t = self.tables
        dt = self.interval_s
        record = power_series is not None
        c_is_mat = cmat.ndim == 2
        obs_is_mat = cmat_obs is not None and cmat_obs.ndim == 2
        sc = scratch
        for n in range(T):
            d = demand[n]
            c = cmat[n] if c_is_mat else float(cmat[n])
            if cmat_obs is None:
                c_dec = c
            else:
                c_dec = cmat_obs[n] if obs_is_mat else float(cmat_obs[n])
            st.demand_integral += ddt_mat[n]
            st.recent_peak = peak_mat[n]

            power.fill(0.0)
            served.fill(0.0)

            # ---- migration in progress: both slices powered, no work ----
            migm = np.greater(st.migrating_s, 0.0, out=sc.migm)
            any_mig = np.count_nonzero(migm)
            if any_mig:
                dstc = np.where(migm, st.migrate_target, 0)
                np.take(t.base_w, st.slice_idx, out=sc.f1, mode="clip")
                np.take(t.base_w, dstc, out=sc.f2, mode="clip")
                np.add(sc.f1, sc.f2, out=sc.f1)
                np.copyto(power, sc.f1, where=migm)

            kind, dy, tg = policy.decide_batch(t, st, d, c_dec, targets,
                                               epsilon, budget=budget_mat[n])
            # fold the migrating containers out of `kind` so the per-action
            # masks below need no separate `& act` (copy, not in-place:
            # decide_batch's return stays the policy's to reuse)
            if any_mig:
                kind = np.where(migm, -1, kind)
            counts = np.bincount(np.maximum(kind, 0, out=sc.i1),
                                 minlength=4)

            # ---- suspend ------------------------------------------------
            if counts[K_SUSPEND]:
                m_sus = np.equal(kind, K_SUSPEND, out=sc.m1)
                st.suspended[m_sus] = True
                st.suspended_s[m_sus] += dt
                if not self.suspend_releases_slice:
                    power[m_sus] = t.base_w[st.slice_idx[m_sus]]

            # ---- resume (joins the run path below) ----------------------
            m_res = None
            if counts[K_RESUME]:
                m_res = np.equal(kind, K_RESUME, out=sc.m2)
                st.suspended[m_res] = False
                has_t = np.greater_equal(tg, 0, out=sc.m3)
                np.logical_and(m_res, has_t, out=has_t)
                st.slice_idx[has_t] = tg[has_t]
                np.copyto(st.duty, dy, where=m_res)

            m_stay = np.equal(kind, K_STAY, out=sc.m4)
            np.copyto(st.duty, dy, where=m_stay)

            # ---- migrate ------------------------------------------------
            subm = None
            if counts[K_MIGRATE]:
                m_mig = np.equal(kind, K_MIGRATE, out=sc.m5)
                st.migrations[m_mig] += 1
                dstc = np.where(m_mig, tg, 0)
                bw = np.maximum(np.take(t.bw_gbps, st.slice_idx, out=sc.f1, mode="clip"),
                                np.take(t.bw_gbps, dstc, out=sc.f2, mode="clip"),
                                out=sc.f1)
                mig_s = self.mig.stop_and_copy_time_batch(state_gb, bw)
                down = np.divide(np.minimum(mig_s, dt, out=sc.f2), dt,
                                 out=sc.f2)
                p_mig = np.add(np.take(t.base_w, st.slice_idx, out=sc.f3, mode="clip"),
                               np.take(t.base_w, dstc, out=sc.f4, mode="clip"),
                               out=sc.f3)
                np.copyto(st.duty, dy, where=m_mig)
                longm = np.greater_equal(mig_s, dt, out=sc.m6)
                np.logical_and(m_mig, longm, out=longm)
                # long migration: whole interval down, src slice accounted
                np.copyto(st.migrate_target, tg, where=longm)
                np.copyto(st.migrating_s, np.subtract(mig_s, dt, out=sc.f4),
                          where=longm)
                np.copyto(power, p_mig, where=longm)
                # sub-interval: rest of the interval served on the dest
                subm = np.logical_and(m_mig, np.logical_not(longm, out=sc.m7),
                                      out=sc.m7)
                if not np.count_nonzero(subm):
                    subm = None
                else:
                    np.copyto(st.slice_idx, tg, where=subm)
                    st.dwell[subm] = 0

            # ---- plant step for running containers ----------------------
            if m_res is None:
                full = m_stay
            else:
                full = np.logical_or(m_res, m_stay, out=sc.m8)
            if subm is not None or np.count_nonzero(full):
                mult_cur = np.take(t.multiple, st.slice_idx, out=sc.f5, mode="clip")
                base_cur = np.take(t.base_w, st.slice_idx, out=sc.f6, mode="clip")
                cap = np.multiply(
                    mult_cur,
                    np.minimum(np.maximum(st.duty, 0.0, out=sc.f7), 1.0,
                               out=sc.f7),
                    out=sc.f7)
                srv = np.minimum(d, cap, out=sc.f8)
                util = np.divide(srv, mult_cur, out=sc.f9)
                #    in [0, 1]: demand >= 0, duty clipped -> the scalar
                #    path's util clamp is an identity
                pw = np.take(t.peak_w, st.slice_idx, out=sc.f10, mode="clip")
                np.subtract(pw, base_cur, out=pw)
                np.multiply(pw, util, out=pw)
                np.add(base_cur, pw, out=pw)
                np.copyto(power, pw, where=full)
                np.copyto(served, srv, where=full)
                if subm is not None:
                    # down * p_mig + (1 - down) * pw, built in scratch
                    np.subtract(1.0, down, out=sc.f11)
                    np.multiply(sc.f11, pw, out=sc.f11)
                    np.multiply(down, p_mig, out=sc.f12)
                    np.add(sc.f12, sc.f11, out=sc.f12)
                    np.copyto(power, sc.f12, where=subm)
                    np.subtract(1.0, down, out=sc.f11)
                    np.multiply(sc.f11, srv, out=sc.f11)
                    np.copyto(served, sc.f11, where=subm)

            # ---- fused accounting (scalar _account, vectorized) ---------
            st.energy_wh += np.divide(np.multiply(power, dt, out=sc.f1),
                                      3600.0, out=sc.f1)
            np.multiply(power, c, out=sc.f2)
            np.divide(sc.f2, 1000.0, out=sc.f2)
            np.multiply(sc.f2, dt, out=sc.f2)
            np.divide(sc.f2, 3600.0, out=sc.f2)
            st.emissions_g += sc.f2
            if unmet is not None and gap[n] > 0.0:
                # telemetry outage: emissions happen but the meter is
                # blind — bill them AND tally the unmetered share
                unmet += sc.f2
            st.work_done += np.multiply(served, dt, out=sc.f3)
            np.subtract(d, served, out=sc.f4)
            np.maximum(0.0, sc.f4, out=sc.f4)
            st.throttled_integral += np.multiply(sc.f4, dt, out=sc.f4)
            tos_col = np.where(st.suspended, n_slices, st.slice_idx)
            st.time_on_slice_s[rows, tos_col] += dt
            if record:
                power_series[n] = power
                served_series[n] = served

            # ---- migration progress + dwell (after accounting) ----------
            if any_mig:
                st.migrating_s[migm] -= dt
                done = np.less_equal(st.migrating_s, 0.0, out=sc.m9)
                np.logical_and(migm, done, out=done)
                st.slice_idx[done] = st.migrate_target[done]
                st.migrate_target[done] = -1
                st.dwell[done] = 0
            if counts[K_MIGRATE]:
                st.dwell[(kind >= 0) & (kind != K_MIGRATE)] += 1
            elif any_mig:
                st.dwell[kind >= 0] += 1
            else:
                st.dwell += 1

    # -- closed-form fast path for state-free policies --------------------

    def _run_closed_form(self, cf: str, demand, cmat, targets, epsilon,
                         record: bool, cmat_obs=None, gap=None
                         ) -> FleetResult:
        """Whole-(T, N)-matrix evaluation for policies whose per-interval
        outcome does not depend on simulation state.

        CarbonAgnosticPolicy never leaves the baseline slice; for
        SuspendResumePolicy the suspension state each interval equals its
        (state-independent) over-target predicate — evaluated on the
        *observed* intensity when `cmat_obs` is given, while emissions
        stay billed at the true `cmat`. Accumulators use np.cumsum
        (sequential adds) so results stay bit-identical to the stepping
        loop.
        """
        t = self.tables
        dt = self.interval_s
        T, N = demand.shape
        b = t.baseline_idx
        mult_b = t.multiple[b]
        base_b = t.base_w[b]
        span_b = t.peak_w[b] - base_b
        c2 = cmat if cmat.ndim == 2 else cmat[:, None]
        if cmat_obs is None:
            c2_obs = c2
        else:
            c2_obs = cmat_obs if cmat_obs.ndim == 2 else cmat_obs[:, None]

        srv = np.minimum(demand, mult_b)     # duty 1.0 on the baseline slice
        util = srv / mult_b
        pw = base_b + span_b * util          # util in [0, 1] (demand >= 0)
        n_slices = len(t.multiple)
        tos = np.zeros((N, n_slices + 1), dtype=np.float64)
        suspended_s = np.zeros(N, dtype=np.float64)
        migrations = np.zeros(N, dtype=np.int64)
        elapsed = float(np.cumsum(np.full(T, dt))[-1]) if T else 0.0
        elapsed_s = np.full(N, elapsed)

        parts = []                           # step matrices to accumulate
        if cf == "suspend_resume":
            # over <=> rate(power(u)) > (1-eps)*target, u == util bitwise
            # (predicate on the observed feed; billing stays on c2)
            over = pw * c2_obs / 1000.0 > (1.0 - epsilon) * targets
            p_sus = 0.0 if self.suspend_releases_slice else base_b
            power = np.where(over, p_sus, pw)
            served = np.where(over, 0.0, srv)
            # accumulate dt (not elapsed - suspended) for bit-parity with
            # the scalar loop's per-interval accumulation at any dt
            parts.append(np.where(over, dt, 0.0))
            parts.append(np.where(over, 0.0, dt))
        else:                                # carbon-agnostic
            power = pw
            served = srv
            tos[:, b] = elapsed_s

        def _chain(a, *ops):         # in-place op chain: same term order,
            for f, v in ops:         # fewer (T, N) temporaries
                f(a, v, out=a)
            return a

        parts = [_chain(power * c2, (np.divide, 1000.0), (np.multiply, dt),
                        (np.divide, 3600.0)),
                 _chain(power * dt, (np.divide, 3600.0)),
                 served * dt,
                 demand * dt,
                 _chain(np.maximum(0.0, demand - served),
                        (np.multiply, dt))] + parts
        if gap is not None:
            # unmetered emissions: the per-epoch emission part masked to
            # the telemetry-gap epochs, accumulated in the same walk
            parts.append(parts[0] * gap[:, None])
        # sequential per-row accumulation (== the stepping loop's add order,
        # hence bit-identical); one fused (T, k*N) walk
        stacked = np.concatenate(parts, axis=1)
        acc = np.zeros(stacked.shape[1], dtype=np.float64)
        for row in stacked:
            acc += row
        emis, energy, work, dem, thr = (acc[k * N:(k + 1) * N]
                                        for k in range(5))
        k_next = 5
        if cf == "suspend_resume":
            suspended_s = acc[5 * N:6 * N]
            tos[:, n_slices] = suspended_s
            tos[:, b] = acc[6 * N:7 * N]
            k_next = 7
        unmetered = (acc[k_next * N:(k_next + 1) * N] if gap is not None
                     else None)

        return FleetResult(
            emissions_g=emis,
            energy_wh=energy,
            work_done=work,
            work_demanded=dem,
            throttled_integral=thr,
            migrations=migrations,
            suspended_s=suspended_s,
            elapsed_s=elapsed_s,
            time_on_slice_s=tos,
            slice_names=t.names + ("suspended",),
            baseline_cap=float(t.multiple[t.baseline_idx]),
            power_series=power if record else None,
            served_series=served if record else None,
            unmetered_g=unmetered,
        )


def _closed_form_kind(policy) -> Optional[str]:
    """Exact-type dispatch: subclasses may override decide(), so only the
    stock baseline policies take the closed-form path."""
    from repro.core.policy import (CarbonAgnosticPolicy,
                                   SuspendResumePolicy)
    if type(policy) is CarbonAgnosticPolicy:
        return "agnostic"
    if type(policy) is SuspendResumePolicy:
        return "suspend_resume"
    return None


# ---------------------------------------------------------------------------
# Multi-policy batching: dispatch decide_batch over contiguous column blocks
# ---------------------------------------------------------------------------

class _StateView:
    """Sliced view of a FleetState for one policy's column block."""

    __slots__ = ("slice_idx", "suspended", "dwell", "recent_peak")

    def __init__(self, st: FleetState, sl: slice):
        self.slice_idx = st.slice_idx[sl]
        self.suspended = st.suspended[sl]
        self.dwell = st.dwell[sl]
        self.recent_peak = st.recent_peak[sl]


class BlockPolicy:
    """Compose several policies into one fleet, each owning a contiguous
    column block. Lets a whole (policy x target x trace) sweep advance in a
    single FleetSimulator.run, amortizing per-step overhead across all
    policies (containers never interact, so results are unchanged)."""

    def __init__(self, blocks):
        self.blocks = list(blocks)        # [(policy, slice), ...]

    def decide_batch(self, t, state, demand, c, target, eps, budget=None):
        n = demand.shape[0]
        kind = np.empty(n, dtype=np.int64)
        duty = np.empty(n, dtype=np.float64)
        tgt = np.empty(n, dtype=np.int64)
        for pol, sl in self.blocks:
            c_b = c[sl] if isinstance(c, np.ndarray) else c
            b_b = budget[sl] if budget is not None else None
            k, dy, tg = pol.decide_batch(t, _StateView(state, sl),
                                         demand[sl], c_b, target[sl], eps[sl],
                                         budget=b_b)
            kind[sl] = k
            duty[sl] = dy
            tgt[sl] = tg
        return kind, duty, tgt


# ---------------------------------------------------------------------------
# Population sweep on the fleet path (backend="fleet" in sweep_population)
# ---------------------------------------------------------------------------

class _FaultContext:
    """Materialized signal-plane faults for one sweep (host-side, shared
    verbatim by the fleet and jax backends so degraded signals are
    identical by construction): the degraded `ObservedSignal`, the
    observed and true (T, R) region matrices (or (T, n_tr) dense
    matrices on placement-free sweeps), and the (T,) power-telemetry
    gap vector (None when the plan has no gaps)."""

    __slots__ = ("signal", "obs_reg", "true_reg", "gap_vec", "faults")

    def __init__(self, signal, obs_reg, true_reg, gap_vec, faults):
        self.signal = signal
        self.obs_reg = obs_reg
        self.true_reg = true_reg
        self.gap_vec = gap_vec
        self.faults = faults


def _prepare_sweep_inputs(traces, carbon, targets, cfg_base, demand_scale,
                          placement, plan_fn, tile: bool = True,
                          energy=None, faults=None):
    """Shared sweep prologue for the fleet and jax backends (one
    implementation so the two can never drift on what sweeps they
    accept): stack the equal-length traces into the policy-block demand
    matrix, tile targets, and — with a placement engine — compute the
    shared region plan on the real n_tr-column fleet via `plan_fn` and
    substitute the planned per-container carbon matrix. Returns
    (demand_one, tgt_one, carbon, plan, n_tr, n_tg, grid_up, fault_ctx).

    With ``faults`` (a `repro.robustness.FaultPlan`), the *planner*
    (and via `plan.region_intensity` every downstream controller layer
    — traffic routing, elastic budgets/forecasts) consumes the degraded
    observed feed, while the returned billing `carbon` is gathered from
    the TRUE region matrix; `plan_fn` receives the fault plan so the
    planner threads the seeded migration-failure mask. ``fault_ctx``
    carries the observed/true split for the caller.

    With ``tile=False`` (the jax backend's memory-lean placed sweep)
    the demand matrix stays compact — (T, n_tr), NOT target-tiled —
    and the planned carbon matrix is not materialized (``carbon`` comes
    back as None; the caller feeds the plan's indexed form to the
    simulator instead). At the N=1M target (n_tr=100k x n_tg=10,
    T=288) the tiled (T, N) f64 matrices are ~2.3 GB apiece on the
    host; the compact path never builds them.

    With ``energy`` (a `repro.energy.EnergyConfig`; requires
    `placement`), the grid-event layer perturbs the engine's (T, R)
    region-intensity matrix *before planning* — shocks multiply the
    grid intensity the planner (and the traffic/elasticity layers,
    via `plan.region_intensity`) consume — and the (T, R) `grid_up`
    outage mask is returned for the supply simulation."""
    if isinstance(traces, np.ndarray) and traces.ndim == 2:
        stack = np.asarray(traces, dtype=np.float64)   # (T, n_tr) direct
    else:
        traces = [np.asarray(tr, dtype=np.float64) for tr in traces]
        lengths = {len(tr) for tr in traces}
        if len(lengths) != 1:
            raise ValueError("fleet backend needs equal-length traces; "
                             f"got lengths {sorted(lengths)}")
        stack = np.stack(traces, axis=1)               # (T, n_tr)
    n_tr = stack.shape[1]
    n_tg = len(targets)
    demand_one = np.tile(stack, (1, n_tg)) if tile else stack
    tgt_one = np.repeat(np.asarray(targets, dtype=np.float64), n_tr)

    plan = None
    grid_up = None
    fault_ctx = None
    T = stack.shape[0]
    if energy is not None and placement is None:
        raise ValueError("energy=EnergyConfig(...) requires a placement "
                         "engine (placement=...): the supply side — "
                         "solar, battery, grid events — is per region")
    if placement is not None:
        if float(placement.interval_s) != float(cfg_base.interval_s):
            raise ValueError(
                f"placement engine plans on interval_s="
                f"{placement.interval_s} but the sweep simulates at "
                f"interval_s={cfg_base.interval_s}; construct the engine "
                f"with the sweep's interval")
        import copy
        if energy is not None:
            from repro.energy.supply import event_matrices
            raw = placement._region_matrix(T)
            shock_mult, grid_up = event_matrices(energy.events, T,
                                                 placement.n_regions)
            placement = copy.copy(placement)
            placement.regions = raw * shock_mult
        if faults is not None:
            from repro.robustness.degrade import observe_intensity
            from repro.robustness.faults import power_gap_vector
            # TRUE regional signal (post grid shocks — those are
            # physical); the controller plane sees the degraded feed
            true_reg = placement._region_matrix(T)
            signal = observe_intensity(true_reg, faults,
                                       cfg_base.interval_s)
            placement = copy.copy(placement)
            placement.regions = signal.observed
            fault_ctx = _FaultContext(signal, signal.observed, true_reg,
                                      power_gap_vector(faults, T), faults)
        demand_plan = stack
        if demand_scale is not None and np.any(
                np.asarray(demand_scale) != 1.0):
            demand_plan = stack * demand_scale
        plan = plan_fn(placement, demand_plan, faults)
        if tile:
            if fault_ctx is None:
                carbon = np.tile(plan.carbon_matrix(), (1, n_tg))
            else:
                # bill at the TRUE intensity of each planned region;
                # the plan's own matrix is the observed feed
                dense_true = fault_ctx.true_reg[np.arange(T)[:, None],
                                                plan.assign[:T]]
                carbon = np.tile(dense_true, (1, n_tg))
        else:
            carbon = None
    elif faults is not None:
        from repro.robustness.degrade import observe_intensity
        from repro.robustness.faults import power_gap_vector
        if carbon is None:
            raise ValueError("faults without a placement engine need an "
                             "explicit carbon signal to degrade")
        true_mat = _carbon_matrix(carbon, T, cfg_base.interval_s)
        true2 = true_mat if true_mat.ndim == 2 else true_mat[:, None]
        signal = observe_intensity(true2, faults, cfg_base.interval_s)
        obs = (signal.observed if true_mat.ndim == 2
               else signal.observed[:, 0])
        fault_ctx = _FaultContext(signal, obs, true_mat,
                                  power_gap_vector(faults, T), faults)
        carbon = true_mat
    return (demand_one, tgt_one, carbon, plan, n_tr, n_tg, grid_up,
            fault_ctx)


def _prepare_traffic(traffic, plan, T: int, interval_s: float):
    """Shared traffic prologue for the fleet and jax sweep backends:
    generate the population's (T, R) request tensor and run the NumPy
    traffic pipeline against the plan's region-intensity table. Returns
    (ArrivalTensor, TrafficResult). Requires a placement plan — the
    traffic layers are per *region*, so without a region assignment
    there is nothing to route between."""
    from repro.traffic.arrivals import request_matrix
    from repro.traffic.sim import simulate_traffic
    if plan is None:
        raise ValueError("traffic=TrafficConfig(...) requires a placement "
                         "engine (placement=...): routing and autoscaling "
                         "are per region")
    R = plan.n_regions
    if traffic.population.n_regions != R:
        raise ValueError(f"traffic population spans "
                         f"{traffic.population.n_regions} regions but the "
                         f"placement engine has {R}")
    arr = request_matrix(traffic.population, T, interval_s)
    res = simulate_traffic(arr.requests, plan.region_intensity[:T], traffic,
                           interval_s)
    return arr, res


def _prepare_energy(energy, family, plan, comp, T: int, interval_s: float,
                    grid_up, region_mat=None):
    """Shared energy prologue for the fleet and jax sweep backends: run
    the host supply simulation on the compact fleet's per-region
    flexible load and gather the two per-container signals. Returns
    ``(spec, SupplyResult, solar (T, R), cap_cols (T, n_tr),
    ceff_cols (T, n_tr))``.

    `comp` is the compact (T, n_tr) demand *after* demand_scale and the
    traffic modulation (pinned layer order: demand_scale -> traffic ->
    energy -> elasticity). The region load is the fleet's flexible
    power, linear in demand (see repro.energy.supply docstring), so
    enforcing the virtual cap by scaling demand with `cap_frac` lands
    exactly on the supplied power. Both backends call this one helper —
    the supply ledger and the `energy_*` row metrics are bit-identical
    across backends; only the *application* of cap_frac/c_eff differs
    (host gather on the fleet path, in-scan fold on the jax path).

    `region_mat` overrides the (T, R) grid intensity the *physical*
    supply runs on — under signal-plane faults the plan's matrix is the
    degraded observed feed, but electrons mix at the TRUE intensity."""
    from repro.energy.supply import (EnergySpec, flex_w_per_unit,
                                     simulate_supply, solar_series)
    R = plan.n_regions
    n_tr = comp.shape[1]
    spec = EnergySpec.from_config(energy, n_tr, R, interval_s,
                                  flex_w_per_unit(family))
    solar = solar_series(energy.solar, T, R, interval_s, spec.solar_peak_w)
    assign = plan.assign[:T]
    load = np.zeros((T, R), dtype=np.float64)
    for r in range(R):
        # where= keeps the per-region reduction temp at one bool mask
        # (matters at the N=100k scale gate)
        np.sum(comp, axis=1, where=(assign == r), out=load[:, r])
    load *= spec.load_coef
    grid_c = (plan.region_intensity[:T] if region_mat is None
              else region_mat[:T])
    sres = simulate_supply(load, solar, grid_c, grid_up, spec)
    rows = np.arange(T)[:, None]
    cap_cols = sres.cap_frac[rows, assign]
    ceff_cols = sres.c_eff[rows, assign]
    return spec, sres, solar, cap_cols, ceff_cols


def sweep_population_fleet(policies: dict, family: SliceFamily, traces,
                           carbon, targets: Sequence[float],
                           cfg_base: SimConfig,
                           demand_scale: float = 1.0,
                           placement=None, traffic=None,
                           elasticity=None, energy=None,
                           faults=None) -> list:
    """Fleet-backed `sweep_population`: batches every (policy x target x
    trace) combination into ONE FleetSimulator.run call (policy-major
    column blocks via BlockPolicy) and emits the same aggregate rows, in
    the same order, as the scalar backend.

    With `placement` (a `repro.cluster.placement.PlacementEngine`), each
    trace column is first assigned a region per epoch by the placement
    layer — the plan is computed once on the real n_tr-column fleet (so
    engine capacity applies to the actual containers, not a
    target-duplicated copy) and shared by every (policy, target) block,
    so all combinations are compared under the same region schedule —
    and the planned per-container carbon matrix replaces `carbon`. Rows
    then also carry `placement_migrations_mean` and
    `placement_overhead_g_mean`.

    With `traffic` (a `repro.traffic.TrafficConfig`; requires
    `placement`), a request population is routed and autoscaled over
    the plan's regions first, and each container's demand is modulated
    by its region's serving load (`TrafficResult.demand_mod`). Rows
    then also carry the `traffic_*` serving metrics.

    With `elasticity` (a `repro.core.elasticity.ElasticityConfig`;
    requires `placement`), the per-container CarbonScaler level
    allocation runs over the scaled + traffic-modulated compact demand
    before the fleet simulation; the fleet then advances on each
    container's *served* demand (unserved work deferred through the
    backlog) and rows carry the `elastic_*` metrics.

    With `energy` (a `repro.energy.EnergyConfig`; requires
    `placement`), the per-region virtual energy supply — solar +
    battery + event-perturbed grid — runs over the compact fleet's
    flexible load: grid-intensity shocks perturb the matrix the
    planner/traffic/elasticity layers consume, each container's demand
    is clamped by its region's virtual power-cap fraction, the fleet
    (and the elasticity layer) bill emissions at the delivered mix's
    *effective* intensity, and rows carry the `energy_*` supply
    metrics. Order is pinned — demand_scale, then traffic, then
    energy, then elasticity — and shared with the jax backend so the
    parity chain holds with all layers on.

    With `faults` (a `repro.robustness.FaultPlan`), every controller
    layer — decision kernels, placement planner, traffic routing,
    elastic budgets and forecasts — consumes the degraded *observed*
    carbon feed while emissions stay billed at the true one; planned
    migrations fail per the seeded mask (stop-and-copy paid, container
    stays put, capped-backoff retry) and power-telemetry gaps accrue
    `unmetered_g`. Rows gain the `fault_*` summaries.
    """
    (demand_one, tgt_one, carbon, plan, n_tr, n_tg, grid_up, fault_ctx) = \
        _prepare_sweep_inputs(traces, carbon, targets, cfg_base,
                              demand_scale, placement,
                              lambda eng, d, flt: eng.plan(
                                  d, state_gb=cfg_base.state_gb, faults=flt),
                              energy=energy, faults=faults)
    per_pol = n_tr * n_tg
    T = demand_one.shape[0]
    gap_vec = fault_ctx.gap_vec if fault_ctx is not None else None
    carbon_obs = None
    if fault_ctx is not None:
        if plan is not None:
            # plan.carbon_matrix() gathers plan.region_intensity — which
            # IS the observed feed under faults
            carbon_obs = np.tile(plan.carbon_matrix(), (1, n_tg))
        else:
            obs = fault_ctx.obs_reg
            carbon_obs = np.tile(obs, (1, n_tg)) if obs.ndim == 2 else obs

    traffic_summary = None
    mod_cols = None
    if traffic is not None:
        _, tres = _prepare_traffic(traffic, plan, T, cfg_base.interval_s)
        mod = tres.demand_mod(traffic.demand_gain)       # (T, R)
        mod_cols = mod[np.arange(T)[:, None], plan.assign[:T]]   # (T, n_tr)
        traffic_summary = tres.summary()
        if elasticity is None and energy is None:
            demand_one = demand_one * np.tile(mod_cols, (1, n_tg))

    # compact pipeline for the energy/elasticity layers: scale + traffic
    # modulation applied once at (T, n_tr) width, layers in pinned order
    comp = None
    if energy is not None or elasticity is not None:
        comp = demand_one[:, :n_tr]
        if demand_scale is not None and np.any(
                np.asarray(demand_scale) != 1.0):
            comp = comp * demand_scale
        if mod_cols is not None:
            comp = comp * mod_cols

    energy_summary = None
    ceff_reg = None
    if energy is not None:
        _, sres, _, cap_cols, ceff_cols = _prepare_energy(
            energy, family, plan, comp, T, cfg_base.interval_s, grid_up,
            region_mat=(fault_ctx.true_reg if fault_ctx is not None
                        else None))
        energy_summary = sres.summary()
        comp = comp * cap_cols              # enforce the virtual cap
        carbon = np.tile(ceff_cols, (1, n_tg))   # bill the delivered mix
        ceff_reg = sres.c_eff               # forecast the delivered mix too
        if fault_ctx is not None:
            # the controller observes the delivered mix through the same
            # degraded feed: scale the true effective intensity by the
            # per-region observed/true grid ratio
            tr = fault_ctx.true_reg[:T]
            safe = np.where(tr > 0.0, tr, 1.0)
            ratio = np.where(tr > 0.0,
                             fault_ctx.obs_reg[:T] / safe, 1.0)
            ceff_obs_reg = sres.c_eff * ratio
            rows_t = np.arange(T)[:, None]
            carbon_obs = np.tile(ceff_obs_reg[rows_t, plan.assign[:T]],
                                 (1, n_tg))
            ceff_reg = ceff_obs_reg         # controller-side forecast feed

    elastic_summary = None
    if elasticity is not None:
        if plan is None:
            raise ValueError("elasticity requires placement")
        from repro.core.elasticity import simulate_elastic
        eres = simulate_elastic(
            comp, carbon[:, :n_tr], elasticity, cfg_base.interval_s,
            carbon_forecast=_elastic_carbon_forecast(
                plan, T, elasticity, cfg_base.interval_s,
                region_mat=ceff_reg),
            budget_series=_elastic_budget_series(
                plan, T, elasticity, cfg_base.interval_s))
        demand_one = np.tile(eres.demand_served(), (1, n_tg))
        demand_scale = 1.0          # already applied ahead of the layer
        elastic_summary = eres.summary()
    elif energy is not None:
        demand_one = np.tile(comp, (1, n_tg))
        demand_scale = 1.0          # already applied ahead of the layer

    sim = FleetSimulator(family, interval_s=cfg_base.interval_s,
                         suspend_releases_slice=cfg_base.suspend_releases_slice)
    run_kw = dict(epsilon=cfg_base.epsilon, state_gb=cfg_base.state_gb,
                  demand_scale=demand_scale, carbon_obs=carbon_obs,
                  power_gap=gap_vec)

    # state-free policies go straight through the closed-form path; the
    # stateful rest share one stepping run via BlockPolicy column blocks
    results = {}                          # name -> (FleetResult, col offset)
    loop_pols = []
    for name, mk_policy in policies.items():
        pol = mk_policy()
        if _closed_form_kind(pol) is not None:
            results[name] = (sim.run(pol, demand_one, carbon, tgt_one,
                                     **run_kw), 0)
        else:
            loop_pols.append((name, pol))
    if len(loop_pols) == 1:                   # skip block-dispatch overhead
        name, pol = loop_pols[0]
        results[name] = (sim.run(pol, demand_one, carbon, tgt_one,
                                 **run_kw), 0)
    elif loop_pols:
        blocks = [(pol, slice(p * per_pol, (p + 1) * per_pol))
                  for p, (_, pol) in enumerate(loop_pols)]
        demand = np.tile(demand_one, (1, len(loop_pols)))
        tgt_vec = np.tile(tgt_one, len(loop_pols))
        carbon_blk = carbon
        if isinstance(carbon, np.ndarray) and carbon.ndim == 2:
            carbon_blk = np.tile(carbon, (1, len(loop_pols)))
        blk_kw = dict(run_kw)
        if isinstance(carbon_obs, np.ndarray) and carbon_obs.ndim == 2:
            blk_kw["carbon_obs"] = np.tile(carbon_obs, (1, len(loop_pols)))
        res = sim.run(BlockPolicy(blocks), demand, carbon_blk, tgt_vec,
                      **blk_kw)
        for p, (name, _) in enumerate(loop_pols):
            results[name] = (res, p * per_pol)

    fault_summary = None
    if fault_ctx is not None:
        fault_summary = fault_ctx.signal.summary()
        if plan is not None and plan.failed_migrations is not None:
            fault_summary["fault_failed_migrations_mean"] = float(
                np.mean(plan.failed_migrations))
    return _aggregate_sweep_rows(policies, results, targets, n_tr, plan,
                                 traffic_summary, elastic_summary,
                                 energy_summary, fault_summary)


def _elastic_carbon_forecast(plan, T: int, elasticity, interval_s: float,
                             region_mat=None) -> np.ndarray:
    """(T, n_tr) carbon estimates for the elasticity layer: forecast on
    the plan's compact (T, R) region matrix, then gather per container.
    The jax backend forecasts the same region matrix and applies its
    R-way select in-scan, so the two see bit-identical estimates
    (forecast-then-gather, never gather-then-forecast — containers
    migrate between regions mid-trace). `region_mat` overrides the
    forecast signal: with the energy layer on, the scaler plans against
    the delivered mix's (T, R) effective intensity — the series it is
    actually billed at — not the raw grid."""
    from repro.carbon.forecast import forecast_series
    cmode = {"oracle": "oracle", "persistence": "persistence",
             "forecast": "diurnal_ar1"}[elasticity.forecast]
    period = max(1, int(round(24 * 3600.0 / float(interval_s))))
    reg = plan.region_intensity if region_mat is None else region_mat
    chat_reg = forecast_series(reg, cmode,
                               period_steps=period, rho=elasticity.rho)
    return chat_reg[np.arange(T)[:, None], plan.assign[:T]]


def _elastic_budget_series(plan, T: int, elasticity, interval_s: float):
    """Shared shaped-budget series for the sweep backends (or None).

    The shaping signal is the placed fleet's mean carbon intensity,
    gathered from the plan exactly as written here; the jax sweep calls
    this same helper so both backends hand `shaped_budget_series` the
    same (T,) floats and allocate identical level counts."""
    if not elasticity.shape_budget or elasticity.budget_g_per_epoch is None:
        return None
    from repro.core.elasticity import shaped_budget_series
    dense = plan.region_intensity[np.arange(T)[:, None], plan.assign[:T]]
    return shaped_budget_series(dense.mean(axis=1), elasticity, interval_s)


def _aggregate_sweep_rows(policies: dict, results: dict, targets, n_tr: int,
                          plan=None, traffic_summary=None,
                          elastic_summary=None, energy_summary=None,
                          fault_summary=None) -> list:
    """Fold per-container FleetResult arrays into the sweep's aggregate
    rows. `results` maps policy name -> (FleetResult, column offset);
    shared by the fleet and jax sweep backends so the two emit the same
    rows in the same order. Aggregation is sliced-array arithmetic, not
    per-container Python loops — at fleet scale (N >= 5000) the loop
    version costs tens of milliseconds, which is real money against the
    jax backend's steady-state sweep times."""
    # hoist the whole-fleet derived arrays out of the per-target loop
    # (the avg_* properties rebuild (N,) arrays on every access)
    derived = {}
    for name, (res, off) in results.items():
        if id(res) not in derived:
            el = np.maximum(res.elapsed_s, 1e-9)[:, None]
            tos = res.time_on_slice_s
            derived[id(res)] = (res.avg_carbon_rate, res.avg_throttle_pct,
                                res.suspended_frac,
                                np.where(tos > 0.0, tos / el, 0.0))
    rows = []
    for ti, target in enumerate(targets):
        for name in policies:
            res, off = results[name]
            rates_a, thr_a, susp_a, tos_fr = derived[id(res)]
            sl = slice(off + ti * n_tr, off + (ti + 1) * n_tr)
            rates = rates_a[sl]
            thr = thr_a[sl]
            # time_on_slice, aggregated: mean over containers of the
            # per-container fraction, counting only containers that
            # spent time there (res.time_on_slice(i)'s `if s > 0` rule)
            fracs = tos_fr[sl].sum(axis=0) / n_tr
            slice_time = {k: float(v)
                          for k, v in zip(res.slice_names, fracs)
                          if v != 0.0}
            row = {
                "policy": name, "target": target,
                "carbon_rate_mean": float(np.mean(rates)),
                "carbon_rate_std": float(np.std(rates)),
                "throttle_mean": float(np.mean(thr)),
                "throttle_std": float(np.std(thr)),
                "migrations_mean": float(np.mean(res.migrations[sl])),
                "suspended_frac_mean": float(np.mean(susp_a[sl])),
                "time_on_slice": slice_time,
            }
            if plan is not None:
                # one shared n_tr-column plan: identical per target
                row["placement_migrations_mean"] = float(
                    np.mean(plan.migrations))
                row["placement_overhead_g_mean"] = float(
                    np.mean(plan.overhead_g))
            if traffic_summary is not None:
                # the traffic layer runs once on the shared plan, ahead
                # of the policy/target fan-out: identical per row
                row.update(traffic_summary)
            if elastic_summary is not None:
                # same sharing as traffic: one elastic pass per sweep
                row.update(elastic_summary)
            if energy_summary is not None:
                # one supply simulation per sweep, shared by backends
                row.update(energy_summary)
            if fault_summary is not None:
                # degraded-signal + failed-migration summaries; one
                # observation pass per sweep, shared by backends
                row.update(fault_summary)
                if res.unmetered_g is not None:
                    row["fault_unmetered_g_mean"] = float(
                        np.mean(res.unmetered_g[sl]))
            rows.append(row)
    return rows
