"""Carbon enforcement policies (paper §3.2) + evaluation baselines (§5.1.2).

All policies share one decision interface:

    decide(family, state, demand, c_intensity, target, eps) -> Action

``demand`` is workload intensity in baseline-capacity units (the paper's
normalized utilization; >1 means the job would use more than the baseline
server). Decisions are taken once per monitoring interval (5 min default).

The general policy (§3.2.1), faithfully:
  - trigger when C(t) comes within ε of C_target;
  - first vertically scale down (cheapest mechanism); in parallel estimate
    C_j on the next-smaller slice and migrate when the smaller slice emits
    less *and* throttles no more than the scaled-down larger slice;
  - suspend only when the smallest slice, fully scaled down, still exceeds
    the target (its baseload floor);
  - scale up / migrate up when below target and throttled.

Energy-efficiency variant (§3.2.2): additionally migrates down whenever a
smaller slice serves the current demand unthrottled with less power — even
when far below the carbon target.

Performance variant (§3.2.3): never migrates down for efficiency; instead
scales *up* toward the largest slice whose at-demand emissions stay within
ε of the target, holding reserve capacity for bursts.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import numpy as np

from repro.cluster.slices import FamilyTables, SliceFamily
from repro.core.container import ContainerState, PlantModel


# ---------------------------------------------------------------------------
# Actions
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class Action:
    kind: str                       # stay | migrate | suspend | resume
    duty: float = 1.0
    target_slice: Optional[int] = None


# integer action codes for the vectorized (fleet) decision kernels
K_STAY, K_MIGRATE, K_SUSPEND, K_RESUME = 0, 1, 2, 3


def _power_budget_w(target: float, c_intensity: float, eps: float) -> float:
    """Max power keeping C = p*c/1000 <= (1-eps)*target."""
    if c_intensity <= 0:
        return float("inf")
    return (1.0 - eps) * target * 1000.0 / c_intensity


# ---------------------------------------------------------------------------
# Vectorized building blocks (fleet path)
#
# Each helper mirrors its scalar counterpart term-for-term so that a fleet
# of N containers advances bit-identically to N scalar simulations.
# ---------------------------------------------------------------------------

def _budget_batch(target, c, eps):
    """Vectorized `_power_budget_w` over per-container (target, c, eps)."""
    c_safe = np.where(c <= 0.0, 1.0, c)
    return np.where(c <= 0.0, np.inf, (1.0 - eps) * target * 1000.0 / c_safe)


def _power_batch(t: FamilyTables, idx, util):
    """LinearPowerModel.power for slice indices `idx` at `util`."""
    b = t.base_w[idx]
    u = np.minimum(np.maximum(util, 0.0), 1.0)
    return b + (t.peak_w[idx] - b) * u


def _util_for_power_batch(t: FamilyTables, idx, watts):
    """LinearPowerModel.util_for_power for slice indices `idx`."""
    b = t.base_w[idx]
    p = t.peak_w[idx]
    with np.errstate(divide="ignore", invalid="ignore"):
        u = np.minimum(1.0, (watts - b) / (p - b))
    u = np.where(p <= b, 1.0, u)
    return np.where(watts <= b, 0.0, u)


def _best_fit_up_batch(t: FamilyTables, i, demand, budget, active0=None):
    """Vectorized `_best_fit_up`: smallest larger slice serving `demand`
    within `budget`, walking the same next-larger chain as the scalar loop
    (including its give-up-on-first-overbudget semantics). Returns -1 where
    no fit exists. `active0` restricts the walk to the (typically sparse)
    subset of containers that need it — the walk then runs compacted."""
    res = np.full(i.shape, -1, dtype=np.int64)
    if active0 is not None:
        idx = np.flatnonzero(active0)
        if idx.size == 0:
            return res
        sub = _best_fit_up_batch(t, i[idx], demand[idx], budget[idx])
        res[idx] = sub
        return res
    k = t.next_larger[i]
    active = k >= 0
    kk = np.where(active, k, 0)
    for _ in range(len(t.multiple)):
        if not np.count_nonzero(active):
            break
        u_k = np.minimum(demand / t.multiple[kk], 1.0)
        fits = _power_batch(t, kk, u_k) <= budget
        nl_k = t.next_larger[kk]
        final = fits & ((demand <= t.multiple[kk]) | (nl_k < 0))
        res = np.where(active & final, kk, res)
        cont = active & fits & ~final          # demand > capacity, larger exists
        kk = np.where(cont, nl_k, kk)
        active = cont
    return res


# ---------------------------------------------------------------------------
# The Carbon Containers policy (both variants)
# ---------------------------------------------------------------------------

@dataclass
class CarbonContainerPolicy:
    variant: str = "energy"          # energy | performance
    allow_migration: bool = True
    min_dwell: int = 2               # intervals between migrations (anti-thrash)
    idle_margin: float = 0.02        # EE idle-migration power improvement margin

    def decide(self, family: SliceFamily, state: ContainerState,
               demand: float, c: float, target: float, eps: float) -> Action:
        budget_w = _power_budget_w(target, c, eps)
        i = state.slice_idx
        s_i = family[i]
        # efficiency-motivated moves wait out the dwell (anti-thrash);
        # enforcement- and throttle-motivated moves react immediately
        can_migrate = self.allow_migration
        can_migrate_idle = (self.allow_migration and state.dwell >= self.min_dwell)

        # --- suspended: resume when the smallest slice fits the budget ----
        if state.suspended:
            j = family.smallest()
            s_j = family[j]
            u_cap_j = s_j.power.util_for_power(budget_w)
            if s_j.power.base_w <= budget_w and u_cap_j > 0.0:
                return Action("resume", duty=u_cap_j, target_slice=j)
            return Action("suspend")

        u_cap_i = s_i.power.util_for_power(budget_w)       # duty cap on i
        u_need_i = min(demand / s_i.multiple, 1.0)         # duty to serve demand

        # --- over / near target: enforce (§3.2.1) --------------------------
        if (s_i.power.power(u_need_i) > budget_w) or (s_i.power.base_w > budget_w):
            if s_i.power.base_w > budget_w or u_cap_i <= 0.0:
                # even idle exceeds the budget on this slice
                j = family.next_smaller(i) if can_migrate else None
                if j is not None:
                    s_j = family[j]
                    if s_j.power.base_w <= budget_w:
                        u_cap_j = s_j.power.util_for_power(budget_w)
                        return Action("migrate", duty=max(u_cap_j, 0.0),
                                      target_slice=j)
                    # fall through toward smallest
                    return Action("migrate", duty=0.0, target_slice=j)
                if i == family.smallest() or not self.allow_migration:
                    return Action("suspend")
                return Action("stay", duty=0.0)
            # vertical scale down to the cap; consider the next-smaller slice
            q_new = u_cap_i
            throttle_i = max(0.0, demand - s_i.multiple * q_new)
            c_i = PlantModel.rate(s_i.power.power(min(q_new, u_need_i)), c)
            j = family.next_smaller(i) if can_migrate else None
            if j is not None:
                s_j = family[j]
                u_cap_j = s_j.power.util_for_power(budget_w)
                u_j = min(demand / s_j.multiple, u_cap_j, 1.0)
                throttle_j = max(0.0, demand - s_j.multiple * u_j)
                c_j = PlantModel.rate(s_j.power.power(u_j), c)
                # paper: migrate when the smaller slice emits less and
                # throttles no more than the vertically-scaled larger slice
                if c_j < c_i and throttle_j <= throttle_i + 1e-12:
                    return Action("migrate", duty=max(u_cap_j, 0.0),
                                  target_slice=j)
            return Action("stay", duty=q_new)

        # --- below target ---------------------------------------------------
        if self.variant == "energy":
            # migrate down when a smaller slice serves the *recent peak*
            # demand unthrottled with less power (baseload amortization,
            # §3.2.2; peak-awareness is the monitor's rolling window and
            # avoids ping-pong on bursty traces)
            peak = max(state.recent_peak, demand)
            j = family.next_smaller(i) if can_migrate_idle else None
            if j is not None:
                s_j = family[j]
                u_cap_j = s_j.power.util_for_power(budget_w)
                u_j = peak / s_j.multiple
                if (u_j <= min(u_cap_j, 0.9)
                        and s_j.power.power(min(u_j, 1.0))
                        < (1.0 - self.idle_margin) * s_i.power.power(u_need_i)):
                    return Action("migrate", duty=min(1.0, max(u_cap_j, 0.0)),
                                  target_slice=j)
            # throttled on a full slice? migrate straight to the best fit
            if demand > s_i.multiple * min(u_cap_i, 1.0):
                if can_migrate:
                    k = self._best_fit_up(family, i, demand, budget_w)
                    if k is not None:
                        return Action("migrate", duty=1.0, target_slice=k)
                return Action("stay", duty=min(1.0, u_cap_i))
            return Action("stay", duty=min(1.0, u_cap_i))

        # performance variant (§3.2.3): hold capacity near the target;
        # up-moves need 10% budget headroom (hysteresis vs hourly c(t) noise)
        k = i
        while can_migrate_idle:
            nxt = family.next_larger(k)
            if nxt is None:
                break
            s_n = family[nxt]
            u_n = min(demand / s_n.multiple, 1.0)
            if s_n.power.power(u_n) <= 0.9 * budget_w:
                k = nxt
            else:
                break
        if k != i:
            return Action("migrate", duty=1.0, target_slice=k)
        return Action("stay", duty=min(1.0, u_cap_i))

    def decide_batch(self, t: FamilyTables, state, demand, c, target, eps,
                     budget=None):
        """Vectorized `decide` over N containers.

        `state` exposes (N,) arrays: slice_idx, suspended, dwell,
        recent_peak. Returns (kind, duty, target_slice) as (N,) arrays with
        kind in {K_STAY, K_MIGRATE, K_SUSPEND, K_RESUME} and target_slice
        -1 where the action carries none. Branches are resolved with masks
        in the exact order of the scalar return statements (`decided`
        tracks which containers already hit an earlier return site).
        `budget` may carry a precomputed `_budget_batch(target, c, eps)`
        row (the fleet loop hoists it out of the time loop).

        `demand` must be non-negative (FleetSimulator.run enforces this):
        inverse-power caps (u_cap_*) are in [0, 1] by construction and
        demand-derived utilizations are then in [0, 1] too, so the scalar
        path's max(., 0)/min(1., .) clamps are exact identities and elided.
        Degenerate (peak <= base) power curves divide by zero here; the
        np.where fixups keep the values correct and FleetSimulator.run
        suppresses the warnings (scalar-equivalent behaviour).
        """
        n = demand.shape[0]
        if budget is None:
            budget = _budget_batch(target, c, eps)
        i = state.slice_idx
        base_i = t.base_w[i]
        peak_i = t.peak_w[i]
        span_i = peak_i - base_i
        mult_i = t.multiple[i]
        can_mig = bool(self.allow_migration)

        # output/bookkeeping scratch, reused across calls (contents are
        # valid until the next decide_batch call on this policy object)
        sc = getattr(self, "_scratch", None)
        if sc is None or sc[0].shape[0] != n:
            sc = (np.empty(n, dtype=np.int64), np.empty(n, dtype=np.float64),
                  np.empty(n, dtype=np.int64), np.empty(n, dtype=bool))
            self._scratch = sc
        kind, duty, tgt, decided = sc
        kind.fill(K_STAY)
        duty.fill(0.0)
        tgt.fill(-1)
        decided.fill(False)

        # --- suspended: resume when the smallest slice fits the budget ----
        sus_any = np.count_nonzero(state.suspended)
        if sus_any:
            j0 = t.smallest
            u_cap_j0 = _util_for_power_batch(t, j0, budget)
            m = state.suspended & (t.base_w[j0] <= budget) & (u_cap_j0 > 0.0)
            kind[m] = K_RESUME
            np.copyto(duty, u_cap_j0, where=m)
            tgt[m] = j0
            m = state.suspended & ~m
            kind[m] = K_SUSPEND
            decided |= state.suspended

        # inline power / inverse-power on cached (base, span) gathers —
        # identical term order to LinearPowerModel.power/util_for_power
        # (for well-formed families the peak<=base fixup is an identity)
        ns = t.next_smaller[i]
        has_j = ns >= 0
        jj = np.where(has_j, ns, 0)
        base_j = t.base_w[jj]
        peak_j = t.peak_w[jj]
        span_j = peak_j - base_j
        mult_j = t.multiple[jj]
        u_cap_i = np.minimum(1.0, (budget - base_i) / span_i)
        if not t.well_formed:
            u_cap_i = np.where(peak_i <= base_i, 1.0, u_cap_i)
        u_cap_i = np.where(budget <= base_i, 0.0, u_cap_i)
        u_cap_j = np.minimum(1.0, (budget - base_j) / span_j)
        if not t.well_formed:
            u_cap_j = np.where(peak_j <= base_j, 1.0, u_cap_j)
        u_cap_j = np.where(budget <= base_j, 0.0, u_cap_j)
        u_need_i = np.minimum(demand / mult_i, 1.0)
        pw_need_i = base_i + span_i * u_need_i
        base_over = base_i > budget
        over = (pw_need_i > budget) | base_over

        # --- over target, even idle exceeds the budget on this slice ------
        hard = over & (base_over | (u_cap_i <= 0.0))
        if sus_any:
            hard &= ~decided
        if np.count_nonzero(hard):
            if can_mig:
                m = hard & has_j & (base_j <= budget)
                kind[m] = K_MIGRATE
                np.copyto(duty, u_cap_j, where=m)
                np.copyto(tgt, jj, where=m)
                decided |= m
                m = hard & has_j & ~decided        # fall through toward smallest
                kind[m] = K_MIGRATE
                np.copyto(tgt, jj, where=m)
                decided |= m
                m = hard & ~has_j & (i == t.smallest)
                kind[m] = K_SUSPEND
                decided |= m
                decided |= hard                    # remainder: stay, duty 0
            else:
                kind[hard] = K_SUSPEND
                decided |= hard

        # --- over target: vertical scale down; consider next smaller ------
        soft = over & ~decided
        q_new = u_cap_i
        if np.count_nonzero(soft):
            if can_mig:
                throttle_i = np.maximum(0.0, demand - mult_i * q_new)
                u_qi = np.minimum(q_new, u_need_i)
                c_i = (base_i + span_i * u_qi) * c / 1000.0
                u_j = np.minimum(np.minimum(demand / mult_j, u_cap_j), 1.0)
                throttle_j = np.maximum(0.0, demand - mult_j * u_j)
                c_j = (base_j + span_j * u_j) * c / 1000.0
                m = (soft & has_j & (c_j < c_i)
                     & (throttle_j <= throttle_i + 1e-12))
                kind[m] = K_MIGRATE
                np.copyto(duty, u_cap_j, where=m)
                np.copyto(tgt, jj, where=m)
                decided |= m
            m = soft & ~decided
            np.copyto(duty, q_new, where=m)        # kind stays K_STAY
            decided |= m

        below = ~decided
        if self.variant == "energy":
            if can_mig:
                can_idle = state.dwell >= self.min_dwell
                peak = np.maximum(state.recent_peak, demand)
                u_jp = peak / mult_j
                pw_jp = base_j + span_j * np.minimum(u_jp, 1.0)
                m = (below & can_idle & has_j
                     & (u_jp <= np.minimum(u_cap_j, 0.9))
                     & (pw_jp < (1.0 - self.idle_margin) * pw_need_i))
                if np.count_nonzero(m):
                    kind[m] = K_MIGRATE
                    np.copyto(duty, u_cap_j, where=m)
                    np.copyto(tgt, jj, where=m)
                    decided |= m
                throttled = below & ~decided & (demand > mult_i * u_cap_i)
                if np.count_nonzero(throttled):
                    k_up = _best_fit_up_batch(t, i, demand, budget,
                                              active0=throttled)
                    m = throttled & (k_up >= 0)
                    kind[m] = K_MIGRATE
                    duty[m] = 1.0
                    np.copyto(tgt, k_up, where=m)
                    decided |= m
            m = below & ~decided
            np.copyto(duty, u_cap_i, where=m)      # kind stays K_STAY
        else:
            # performance: climb while the larger slice fits 0.9x budget
            k = i.copy()
            climbing = below & can_mig & (state.dwell >= self.min_dwell)
            for _ in range(len(t.multiple)):
                if not np.count_nonzero(climbing):
                    break
                nxt = t.next_larger[k]
                has = climbing & (nxt >= 0)
                kk = np.where(has, nxt, 0)
                u_n = np.minimum(demand / t.multiple[kk], 1.0)
                ok = has & (_power_batch(t, kk, u_n) <= 0.9 * budget)
                k = np.where(ok, kk, k)
                climbing = ok
            m = below & (k != i)
            kind[m] = K_MIGRATE
            duty[m] = 1.0
            np.copyto(tgt, k, where=m)
            m = below & (k == i)
            np.copyto(duty, u_cap_i, where=m)      # kind stays K_STAY
        return kind, duty, tgt

    @staticmethod
    def _best_fit_up(family: SliceFamily, i: int, demand: float,
                     budget_w: float):
        """Smallest larger slice that serves `demand` within the budget."""
        k = family.next_larger(i)
        while k is not None:
            s_k = family[k]
            u_k = min(demand / s_k.multiple, 1.0)
            if s_k.power.power(u_k) <= budget_w:
                if demand <= s_k.multiple or family.next_larger(k) is None:
                    return k
                k = family.next_larger(k)
                continue
            return None
        return None


# ---------------------------------------------------------------------------
# Baselines (paper §5.1.2)
# ---------------------------------------------------------------------------

@dataclass
class CarbonAgnosticPolicy:
    """Baseline server, no scaling, no migration, never suspends."""

    def decide(self, family, state, demand, c, target, eps) -> Action:
        if state.slice_idx != family.baseline_idx:
            return Action("migrate", duty=1.0, target_slice=family.baseline_idx)
        return Action("stay", duty=1.0)

    def decide_batch(self, t: FamilyTables, state, demand, c, target, eps,
                     budget=None):
        n = demand.shape[0]
        kind = np.zeros(n, dtype=np.int64)           # default: K_STAY
        duty = np.ones(n, dtype=np.float64)
        tgt = np.full(n, -1, dtype=np.int64)
        off_base = state.slice_idx != t.baseline_idx
        if np.count_nonzero(off_base):
            kind[off_base] = K_MIGRATE
            tgt[off_base] = t.baseline_idx
        return kind, duty, tgt


@dataclass
class SuspendResumePolicy:
    """Wait-AWhile-style [34]: baseline server; suspend when emissions at the
    current demand would exceed the target, resume when they fit."""

    def decide(self, family, state, demand, c, target, eps) -> Action:
        b = family[family.baseline_idx]
        u = min(demand / b.multiple, 1.0)
        over = PlantModel.rate(b.power.power(u), c) > (1.0 - eps) * target
        if state.suspended:
            if not over:
                return Action("resume", duty=1.0,
                              target_slice=family.baseline_idx)
            return Action("suspend")
        if over:
            return Action("suspend")
        return Action("stay", duty=1.0)

    def decide_batch(self, t: FamilyTables, state, demand, c, target, eps,
                     budget=None):
        b = t.baseline_idx
        u = np.minimum(demand / t.multiple[b], 1.0)
        pw = _power_batch(t, b, u)
        over = pw * c / 1000.0 > (1.0 - eps) * target
        kind = np.where(over, K_SUSPEND,
                        np.where(state.suspended, K_RESUME, K_STAY))
        duty = np.ones(demand.shape[0], dtype=np.float64)
        tgt = np.where(kind == K_RESUME, b, -1)
        return kind, duty, tgt


def VScaleOnlyPolicy(variant: str = "energy") -> CarbonContainerPolicy:
    """Carbon Containers without migration (vertical scaling + suspend)."""
    return CarbonContainerPolicy(variant=variant, allow_migration=False)
