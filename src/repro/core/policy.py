"""Carbon enforcement policies (paper §3.2) + evaluation baselines (§5.1.2).

All policies share one decision interface:

    decide(family, state, demand, c_intensity, target, eps) -> Action

``demand`` is workload intensity in baseline-capacity units (the paper's
normalized utilization; >1 means the job would use more than the baseline
server). Decisions are taken once per monitoring interval (5 min default).

The general policy (§3.2.1), faithfully:
  - trigger when C(t) comes within ε of C_target;
  - first vertically scale down (cheapest mechanism); in parallel estimate
    C_j on the next-smaller slice and migrate when the smaller slice emits
    less *and* throttles no more than the scaled-down larger slice;
  - suspend only when the smallest slice, fully scaled down, still exceeds
    the target (its baseload floor);
  - scale up / migrate up when below target and throttled.

Energy-efficiency variant (§3.2.2): additionally migrates down whenever a
smaller slice serves the current demand unthrottled with less power — even
when far below the carbon target.

Performance variant (§3.2.3): never migrates down for efficiency; instead
scales *up* toward the largest slice whose at-demand emissions stay within
ε of the target, holding reserve capacity for bursts.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

from repro.cluster.slices import SliceFamily
from repro.core.container import ContainerState, PlantModel


# ---------------------------------------------------------------------------
# Actions
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class Action:
    kind: str                       # stay | migrate | suspend | resume
    duty: float = 1.0
    target_slice: Optional[int] = None


def _power_budget_w(target: float, c_intensity: float, eps: float) -> float:
    """Max power keeping C = p*c/1000 <= (1-eps)*target."""
    if c_intensity <= 0:
        return float("inf")
    return (1.0 - eps) * target * 1000.0 / c_intensity


# ---------------------------------------------------------------------------
# The Carbon Containers policy (both variants)
# ---------------------------------------------------------------------------

@dataclass
class CarbonContainerPolicy:
    variant: str = "energy"          # energy | performance
    allow_migration: bool = True
    min_dwell: int = 2               # intervals between migrations (anti-thrash)
    idle_margin: float = 0.02        # EE idle-migration power improvement margin

    def decide(self, family: SliceFamily, state: ContainerState,
               demand: float, c: float, target: float, eps: float) -> Action:
        budget_w = _power_budget_w(target, c, eps)
        i = state.slice_idx
        s_i = family[i]
        # efficiency-motivated moves wait out the dwell (anti-thrash);
        # enforcement- and throttle-motivated moves react immediately
        can_migrate = self.allow_migration
        can_migrate_idle = (self.allow_migration and state.dwell >= self.min_dwell)

        # --- suspended: resume when the smallest slice fits the budget ----
        if state.suspended:
            j = family.smallest()
            s_j = family[j]
            u_cap_j = s_j.power.util_for_power(budget_w)
            if s_j.power.base_w <= budget_w and u_cap_j > 0.0:
                return Action("resume", duty=u_cap_j, target_slice=j)
            return Action("suspend")

        u_cap_i = s_i.power.util_for_power(budget_w)       # duty cap on i
        u_need_i = min(demand / s_i.multiple, 1.0)         # duty to serve demand

        # --- over / near target: enforce (§3.2.1) --------------------------
        if (s_i.power.power(u_need_i) > budget_w) or (s_i.power.base_w > budget_w):
            if s_i.power.base_w > budget_w or u_cap_i <= 0.0:
                # even idle exceeds the budget on this slice
                j = family.next_smaller(i) if can_migrate else None
                if j is not None:
                    s_j = family[j]
                    if s_j.power.base_w <= budget_w:
                        u_cap_j = s_j.power.util_for_power(budget_w)
                        return Action("migrate", duty=max(u_cap_j, 0.0),
                                      target_slice=j)
                    # fall through toward smallest
                    return Action("migrate", duty=0.0, target_slice=j)
                if i == family.smallest() or not self.allow_migration:
                    return Action("suspend")
                return Action("stay", duty=0.0)
            # vertical scale down to the cap; consider the next-smaller slice
            q_new = u_cap_i
            throttle_i = max(0.0, demand - s_i.multiple * q_new)
            c_i = PlantModel.rate(s_i.power.power(min(q_new, u_need_i)), c)
            j = family.next_smaller(i) if can_migrate else None
            if j is not None:
                s_j = family[j]
                u_cap_j = s_j.power.util_for_power(budget_w)
                u_j = min(demand / s_j.multiple, u_cap_j, 1.0)
                throttle_j = max(0.0, demand - s_j.multiple * u_j)
                c_j = PlantModel.rate(s_j.power.power(u_j), c)
                # paper: migrate when the smaller slice emits less and
                # throttles no more than the vertically-scaled larger slice
                if c_j < c_i and throttle_j <= throttle_i + 1e-12:
                    return Action("migrate", duty=max(u_cap_j, 0.0),
                                  target_slice=j)
            return Action("stay", duty=q_new)

        # --- below target ---------------------------------------------------
        if self.variant == "energy":
            # migrate down when a smaller slice serves the *recent peak*
            # demand unthrottled with less power (baseload amortization,
            # §3.2.2; peak-awareness is the monitor's rolling window and
            # avoids ping-pong on bursty traces)
            peak = max(state.recent_peak, demand)
            j = family.next_smaller(i) if can_migrate_idle else None
            if j is not None:
                s_j = family[j]
                u_cap_j = s_j.power.util_for_power(budget_w)
                u_j = peak / s_j.multiple
                if (u_j <= min(u_cap_j, 0.9)
                        and s_j.power.power(min(u_j, 1.0))
                        < (1.0 - self.idle_margin) * s_i.power.power(u_need_i)):
                    return Action("migrate", duty=min(1.0, max(u_cap_j, 0.0)),
                                  target_slice=j)
            # throttled on a full slice? migrate straight to the best fit
            if demand > s_i.multiple * min(u_cap_i, 1.0):
                if can_migrate:
                    k = self._best_fit_up(family, i, demand, budget_w)
                    if k is not None:
                        return Action("migrate", duty=1.0, target_slice=k)
                return Action("stay", duty=min(1.0, u_cap_i))
            return Action("stay", duty=min(1.0, u_cap_i))

        # performance variant (§3.2.3): hold capacity near the target;
        # up-moves need 10% budget headroom (hysteresis vs hourly c(t) noise)
        k = i
        while can_migrate_idle:
            nxt = family.next_larger(k)
            if nxt is None:
                break
            s_n = family[nxt]
            u_n = min(demand / s_n.multiple, 1.0)
            if s_n.power.power(u_n) <= 0.9 * budget_w:
                k = nxt
            else:
                break
        if k != i:
            return Action("migrate", duty=1.0, target_slice=k)
        return Action("stay", duty=min(1.0, u_cap_i))

    @staticmethod
    def _best_fit_up(family: SliceFamily, i: int, demand: float,
                     budget_w: float):
        """Smallest larger slice that serves `demand` within the budget."""
        k = family.next_larger(i)
        while k is not None:
            s_k = family[k]
            u_k = min(demand / s_k.multiple, 1.0)
            if s_k.power.power(u_k) <= budget_w:
                if demand <= s_k.multiple or family.next_larger(k) is None:
                    return k
                k = family.next_larger(k)
                continue
            return None
        return None


# ---------------------------------------------------------------------------
# Baselines (paper §5.1.2)
# ---------------------------------------------------------------------------

@dataclass
class CarbonAgnosticPolicy:
    """Baseline server, no scaling, no migration, never suspends."""

    def decide(self, family, state, demand, c, target, eps) -> Action:
        if state.slice_idx != family.baseline_idx:
            return Action("migrate", duty=1.0, target_slice=family.baseline_idx)
        return Action("stay", duty=1.0)


@dataclass
class SuspendResumePolicy:
    """Wait-AWhile-style [34]: baseline server; suspend when emissions at the
    current demand would exceed the target, resume when they fit."""

    def decide(self, family, state, demand, c, target, eps) -> Action:
        b = family[family.baseline_idx]
        u = min(demand / b.multiple, 1.0)
        over = PlantModel.rate(b.power.power(u), c) > (1.0 - eps) * target
        if state.suspended:
            if not over:
                return Action("resume", duty=1.0,
                              target_slice=family.baseline_idx)
            return Action("suspend")
        if over:
            return Action("suspend")
        return Action("stay", duty=1.0)


def VScaleOnlyPolicy(variant: str = "energy") -> CarbonContainerPolicy:
    """Carbon Containers without migration (vertical scaling + suspend)."""
    return CarbonContainerPolicy(variant=variant, allow_migration=False)
