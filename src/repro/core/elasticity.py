"""Per-container elasticity: CarbonScaler marginal allocation over (N, K).

Every container in an (N,) fleet gets a discrete resource level
n_i ∈ {min_level..k_levels} ("cores"/duty levels). Each epoch the
CarbonScaler greedy allocates levels by marginal carbon efficiency:
flatten the (N, K) table of (marginal work w, marginal grams g) per
(container, level), admit mandatory levels (ramp/floor), then admit
optional levels in descending w/g order while the fleet-wide carbon
budget holds — the exact rule `repro.traffic.autoscale` applies to
replica counts, generalized from (R,) regions to (N,) containers.

Work that the allocated capacity cannot serve is *deferred*, not
dropped: a per-container backlog carries it to later (hopefully
greener) epochs, so ablations compare carbon at equal total work.

Decisions use *estimates* (ĉ, d̂) from `repro.carbon.forecast`; actual
emissions are booked with the true trace. `ElasticityConfig.forecast`
selects the estimator pair:

  - "oracle"       — truth for both (upper bound)
  - "persistence"  — last observation for both (baseline)
  - "forecast"     — diurnal_ar1 for both (exploits the known
                     diurnal + AR(1) structure of carbon traces and
                     serving demand alike)

With `shape_budget=True` the fixed per-epoch gram budget becomes a
*shaped* series (`shaped_budget_series`): the same total grams are
reallocated across epochs by the forecaster's now-vs-next-24h carbon
ratio, concentrating spend in forecasted-green hours. This is where
multi-step structure pays: a persistence forecaster believes carbon
stays flat, so its ratio is identically 1 and shaping degenerates to
the uniform budget — the measured forecast-vs-persistence savings is
exactly the value of knowing the diurnal shape.

Backends: `allocate_epoch_scalar` (pure-Python oracle),
`allocate_epoch`/`simulate_elastic` (NumPy, level counts identical,
floats <=1e-9), and `repro.core.elasticity_jax.simulate_elastic_jax`
(jitted scan, <=1e-6, counts identical). Every per-epoch array is
(N,) or (N, K); nothing (T, N) is materialized beyond the inputs the
caller already holds.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import numpy as np

from repro.carbon.forecast import forecast_series

_FORECAST_MODES = ("oracle", "persistence", "forecast")


@dataclass(frozen=True)
class ElasticityConfig:
    """Per-container vertical-scaling knobs (mirrors ReplicaConfig).

    `unit_capacity` is the demand *rate* one level serves (same units
    as the demand trace); `base_w`/`peak_w` are per-level idle/busy
    power. `budget_g_per_epoch` caps fleet-wide estimated grams per
    epoch (None = uncapped: every container gets its desired level).
    """
    k_levels: int = 4
    unit_capacity: float = 1.0
    base_w: float = 50.0
    peak_w: float = 200.0
    min_level: int = 1
    max_step: int = 1
    budget_g_per_epoch: Optional[float] = None
    forecast: str = "persistence"
    rho: float = 0.9
    # shape the fleet budget into forecasted-green hours (same total
    # grams; see shaped_budget_series)
    shape_budget: bool = False
    shape_gamma: float = 2.0

    def __post_init__(self):
        if self.k_levels < 1:
            raise ValueError("k_levels must be >= 1")
        if not (1 <= self.min_level <= self.k_levels):
            raise ValueError("need 1 <= min_level <= k_levels")
        if self.max_step < 1:
            raise ValueError("max_step must be >= 1")
        if self.unit_capacity <= 0:
            raise ValueError("unit_capacity must be > 0")
        if self.peak_w < self.base_w:
            raise ValueError("peak_w must be >= base_w")
        if self.budget_g_per_epoch is not None and self.budget_g_per_epoch < 0:
            raise ValueError("budget_g_per_epoch must be >= 0 or None")
        if self.forecast not in _FORECAST_MODES:
            raise ValueError(f"forecast must be one of {_FORECAST_MODES}")
        if self.shape_gamma <= 0:
            raise ValueError("shape_gamma must be > 0")
        if self.shape_budget and self.budget_g_per_epoch is None:
            raise ValueError("shape_budget needs a budget_g_per_epoch")

    def capw(self, interval_s: float) -> float:
        """Work (demand·s) one level serves in one epoch."""
        return self.unit_capacity * float(interval_s)


def _power_g(levels, served_frac_w, capw, c, cfg: ElasticityConfig,
             interval_s: float):
    """Grams for `levels` serving `served_frac_w` work at intensity c."""
    span = cfg.peak_w - cfg.base_w
    pw = levels * cfg.base_w + span * (served_frac_w / capw)
    return pw * float(interval_s) / 3600.0 * c / 1000.0


def allocate_epoch(want_w, chat, prev, cfg: ElasticityConfig,
                   interval_s: float, budget_g: Optional[float] = None):
    """One epoch of the (N, K) marginal-allocation greedy (NumPy).

    want_w : (N,) estimated work wanted this epoch (demand·dt+backlog)
    chat   : (N,) estimated carbon intensity (g/kWh)
    prev   : (N,) previous levels (float)
    budget_g overrides `cfg.budget_g_per_epoch` for this epoch (budget
    shaping hands each epoch its slice of the fleet budget).
    Returns (n, lo): allocated levels and the mandatory floor, both
    (N,) float64. Uses only (N,)/(N, K) temporaries.
    """
    want_w = np.asarray(want_w, dtype=np.float64)
    chat = np.asarray(chat, dtype=np.float64)
    prev = np.asarray(prev, dtype=np.float64)
    N = want_w.shape[0]
    dt = float(interval_s)
    capw = cfg.capw(dt)
    span = cfg.peak_w - cfg.base_w
    K = cfg.k_levels

    need = np.ceil(want_w / capw)
    lo = np.maximum(float(cfg.min_level), prev - cfg.max_step)
    hi = np.minimum(float(cfg.k_levels), prev + cfg.max_step)
    desired = np.minimum(np.maximum(need, lo), hi)
    budget = cfg.budget_g_per_epoch if budget_g is None else budget_g
    if budget is None:
        return desired, lo

    k_idx = np.arange(1, K + 1, dtype=np.float64)[None, :]
    w = np.clip(want_w[:, None] - (k_idx - 1.0) * capw, 0.0, capw)
    g = ((cfg.base_w + span * (w / capw))
         * dt / 3600.0 * chat[:, None] / 1000.0)
    mand = k_idx <= lo[:, None]
    opt = (k_idx > lo[:, None]) & (k_idx <= desired[:, None])
    mand_flat = np.where(mand, g, 0.0).ravel()
    mand_g = float(np.cumsum(mand_flat)[-1]) if mand_flat.size else 0.0
    # zero-gram guard: free levels sort first, no overflow division
    free = g <= 0.0
    eff = w / np.where(free, 1.0, g)
    score = np.where(opt, np.where(free, -np.inf, -eff), np.inf).ravel()
    order = np.argsort(score, kind="stable")
    gs = np.where(opt, g, 0.0).ravel()[order]
    cum = np.cumsum(gs)
    admit = opt.ravel()[order] & (mand_g + cum <= budget)
    con_of = np.repeat(np.arange(N), K)
    counts = np.bincount(con_of[order[admit]], minlength=N)
    return lo + counts, lo


def allocate_epoch_scalar(want_w, chat, prev, cfg: ElasticityConfig,
                          interval_s: float,
                          budget_g: Optional[float] = None):
    """Pure-Python reference for `allocate_epoch` (counts identical)."""
    want_w = np.asarray(want_w, dtype=np.float64)
    chat = np.asarray(chat, dtype=np.float64)
    prev = np.asarray(prev, dtype=np.float64)
    N = want_w.shape[0]
    dt = float(interval_s)
    capw = cfg.capw(dt)
    span = cfg.peak_w - cfg.base_w
    K = cfg.k_levels

    lo, hi, desired = [], [], []
    for i in range(N):
        need = float(np.ceil(want_w[i] / capw))
        lo_i = max(float(cfg.min_level), float(prev[i]) - cfg.max_step)
        hi_i = min(float(cfg.k_levels), float(prev[i]) + cfg.max_step)
        lo.append(lo_i)
        hi.append(hi_i)
        desired.append(min(max(need, lo_i), hi_i))
    budget = cfg.budget_g_per_epoch if budget_g is None else budget_g
    if budget is None:
        return np.array(desired), np.array(lo)

    g_tab, score, opt_flat = {}, {}, []
    mand_g = 0.0
    for i in range(N):
        want = float(want_w[i])
        c = float(chat[i])
        for k in range(1, K + 1):
            w = min(max(want - (k - 1.0) * capw, 0.0), capw)
            g = ((cfg.base_w + span * (w / capw))
                 * dt / 3600.0 * c / 1000.0)
            j = i * K + (k - 1)
            g_tab[j] = g
            if k <= lo[i]:
                mand_g += g
            is_opt = lo[i] < k <= desired[i]
            opt_flat.append(is_opt)
            # same zero-gram guard as the vectorized path
            sc = -np.inf if g <= 0.0 else -(w / g)
            score[j] = sc if is_opt else np.inf
    order = sorted(range(N * K), key=lambda j: score[j])
    counts = [0] * N
    cum = 0.0
    for j in order:
        cum += g_tab[j] if opt_flat[j] else 0.0
        if opt_flat[j] and mand_g + cum <= budget:
            counts[j // K] += 1
    return (np.array(lo) + np.array(counts, dtype=np.float64),
            np.array(lo))


@dataclass
class ElasticResult:
    levels: np.ndarray          # (T, N) int64 allocated levels
    served_w: np.ndarray        # (T, N) work served per epoch
    offered_w: np.ndarray       # (T, N) work offered (demand·dt)
    backlog: np.ndarray         # (N,) deferred work at the end
    est_emissions_g: float      # grams booked with forecast intensity
    emissions_g: float          # grams booked with the true intensity
    cap_violations: int         # epochs whose *estimated* total > budget
    interval_s: float
    # level-epoch total from an in-scan accumulator when the (T, N)
    # levels stream is not recorded (jax backend, record=False)
    level_epochs: Optional[int] = None

    def demand_served(self) -> np.ndarray:
        """Served work back in demand-rate units (feeds the fleet sim)."""
        return self.served_w / float(self.interval_s)

    def summary(self) -> dict:
        offered = float(self.offered_w.sum())
        served = float(self.served_w.sum())
        lev = (self.level_epochs if self.level_epochs is not None
               else int(self.levels.sum()))
        return {
            "elastic_offered_work": offered,
            "elastic_served_work": served,
            "elastic_deferred_work": float(self.backlog.sum()),
            "elastic_served_frac": served / max(offered, 1e-12),
            "elastic_level_epochs": lev,
            "elastic_est_emissions_g": float(self.est_emissions_g),
            "elastic_emissions_g": float(self.emissions_g),
            "elastic_cap_violations": int(self.cap_violations),
        }


def shaped_budget_series(carbon_signal, cfg: ElasticityConfig,
                         interval_s: float) -> np.ndarray:
    """Allocate the fleet gram budget across epochs by forecasted carbon.

    carbon_signal : (T,) fleet-level carbon intensity (e.g. the mean
    over containers, or over the placed fleet's per-container gather).
    Each epoch's share is (window_mean / nowcast)**gamma for the
    config's forecaster — "spend when now looks greener than the rest
    of the coming day" — clipped to [1/4, 4] and renormalized so the
    total equals T·budget_g_per_epoch. Persistence predicts a flat
    signal, so its ratio is identically 1 and the series is uniform:
    the unshaped baseline falls out as a special case rather than a
    separate code path.

    Callers that need cross-backend bit-exactness (fleet vs jax sweep)
    must hand *the same* (T,) signal to both — this helper is plain
    NumPy precisely so both backends can share one series.
    """
    if cfg.budget_g_per_epoch is None:
        raise ValueError("shaped_budget_series needs a budget_g_per_epoch")
    sig = np.asarray(carbon_signal, dtype=np.float64)
    if sig.ndim != 1:
        raise ValueError(f"carbon_signal must be (T,); got {sig.shape}")
    T = sig.shape[0]
    period = max(1, int(round(24 * 3600.0 / float(interval_s))))
    fmode = {"oracle": "oracle", "persistence": "persistence",
             "forecast": "diurnal_ar1"}[cfg.forecast]
    from repro.carbon.forecast import window_mean_forecast
    now = forecast_series(sig, fmode, period_steps=period, rho=cfg.rho)
    wmean = window_mean_forecast(sig, fmode, period_steps=period,
                                 rho=cfg.rho)
    share = np.clip((wmean / np.maximum(now, 1e-9)) ** cfg.shape_gamma,
                    0.25, 4.0)
    return cfg.budget_g_per_epoch * T * share / share.sum()


def _forecast_pair(demand, carbon, cfg: ElasticityConfig,
                   interval_s: float):
    """(d̂, ĉ) per the config's mode (see module doc)."""
    period = max(1, int(round(24 * 3600.0 / float(interval_s))))
    dmode = {"oracle": "oracle", "persistence": "persistence",
             "forecast": "diurnal_ar1"}[cfg.forecast]
    cmode = {"oracle": "oracle", "persistence": "persistence",
             "forecast": "diurnal_ar1"}[cfg.forecast]
    dhat = forecast_series(demand, dmode, period_steps=period, rho=cfg.rho)
    chat = forecast_series(carbon, cmode, period_steps=period, rho=cfg.rho)
    return dhat, chat


def simulate_elastic(demand, carbon, cfg: ElasticityConfig,
                     interval_s: float = 300.0, backend: str = "numpy",
                     demand_forecast=None, carbon_forecast=None,
                     budget_series=None) -> ElasticResult:
    """Run the elasticity layer over a (T, N) demand/carbon pair.

    demand : (T, N) demand rate per container
    carbon : (T, N) true carbon intensity per container (g/kWh)
    `demand_forecast`/`carbon_forecast` override the config-derived
    estimates (callers with region-level structure forecast on the
    compact (T, R) matrix and gather — see `repro.core.fleet`).
    `budget_series` overrides the per-epoch budgets; when omitted and
    `cfg.shape_budget` is set, it is derived from the mean-over-
    containers carbon signal via `shaped_budget_series`.
    """
    demand = np.asarray(demand, dtype=np.float64)
    carbon = np.asarray(carbon, dtype=np.float64)
    if demand.shape != carbon.shape or demand.ndim != 2:
        raise ValueError(f"demand {demand.shape} / carbon {carbon.shape} "
                         f"must be equal (T, N)")
    if backend not in ("numpy", "scalar"):
        raise ValueError(f"unknown backend {backend!r}")
    T, N = demand.shape
    dt = float(interval_s)
    capw = cfg.capw(dt)

    dhat = (np.asarray(demand_forecast, dtype=np.float64)
            if demand_forecast is not None else None)
    chat = (np.asarray(carbon_forecast, dtype=np.float64)
            if carbon_forecast is not None else None)
    if dhat is None or chat is None:
        d_auto, c_auto = _forecast_pair(demand, carbon, cfg, dt)
        dhat = d_auto if dhat is None else dhat
        chat = c_auto if chat is None else chat

    alloc = allocate_epoch if backend == "numpy" else allocate_epoch_scalar
    levels = np.zeros((T, N), dtype=np.int64)
    served_w = np.zeros((T, N))
    offered_w = demand * dt
    backlog = np.zeros(N, dtype=np.float64)
    prev = np.full(N, float(cfg.min_level))
    est_g = 0.0
    act_g = 0.0
    viol = 0
    if budget_series is not None:
        bud = np.asarray(budget_series, dtype=np.float64)
        if bud.shape != (T,):
            raise ValueError(f"budget_series must be ({T},); "
                             f"got {bud.shape}")
    elif cfg.shape_budget:
        bud = shaped_budget_series(carbon.mean(axis=1), cfg, dt)
    elif cfg.budget_g_per_epoch is not None:
        bud = np.full(T, float(cfg.budget_g_per_epoch))
    else:
        bud = None
    for t in range(T):
        want = dhat[t] * dt + backlog
        budget = None if bud is None else float(bud[t])
        n, lo = alloc(want, chat[t], prev, cfg, dt, budget_g=budget)
        # estimated grams for what we *planned* to serve, true grams for
        # what actually arrived (demand forecast error shows up here)
        est_w = np.minimum(want, n * capw)
        srv = np.minimum(offered_w[t] + backlog, n * capw)
        backlog = backlog + offered_w[t] - srv
        est_step = float(_power_g(n, est_w, capw, chat[t], cfg, dt).sum())
        est_g += est_step
        act_g += float(_power_g(n, srv, capw, carbon[t], cfg, dt).sum())
        if bud is not None:
            # mandatory levels may exceed the budget on their own; the
            # greedy must never push beyond max(budget, mandatory)
            mand_w = np.minimum(want, lo * capw)
            mand_total = float(_power_g(lo, mand_w, capw, chat[t], cfg,
                                        dt).sum())
            if est_step > max(budget, mand_total) + 1e-9:
                viol += 1
        levels[t] = n.astype(np.int64)
        served_w[t] = srv
        prev = n
    return ElasticResult(levels=levels, served_w=served_w,
                         offered_w=offered_w, backlog=backlog,
                         est_emissions_g=est_g, emissions_g=act_g,
                         cap_violations=viol, interval_s=dt)
