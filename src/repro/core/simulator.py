"""Trace-driven Carbon Containers simulator (paper §5.3, Figs 10-17).

Drives any policy against a (workload-intensity trace × carbon-intensity
trace) pair on a slice family, one decision per monitoring interval,
including migration downtime from the Fig.-7 cost model (both slices
powered during a stop-and-copy, no work served).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Sequence

import numpy as np

from repro.carbon.intensity import CarbonIntensityProvider
from repro.cluster.migration import MigrationCostModel
from repro.cluster.slices import SliceFamily
from repro.core.container import ContainerState, PlantModel
from repro.core.policy import Action


@dataclass
class SimConfig:
    target_rate: float                  # g CO2e/hr
    epsilon: float = 0.05
    interval_s: float = 300.0
    state_gb: float = 1.0               # migrated state footprint (Fig. 7)
    suspend_releases_slice: bool = True  # cloud-user view: release = no power
    record_series: bool = False


@dataclass
class SimResult:
    avg_carbon_rate: float              # g/hr
    avg_throttle_pct: float             # % of baseline capacity unserved
    work_done: float
    work_demanded: float
    energy_kwh: float
    migrations: int
    suspended_frac: float
    time_on_slice: dict
    emissions_g: float
    hours: float
    series: Optional[dict] = None

    @property
    def carbon_efficiency(self) -> float:
        """Work done per kg CO2e (the paper's figure of merit)."""
        return self.work_done / max(self.emissions_g / 1000.0, 1e-12)


def simulate(policy, family: SliceFamily, util_trace: Sequence[float],
             carbon: CarbonIntensityProvider, cfg: SimConfig,
             demand_scale: float = 1.0,
             migration: Optional[MigrationCostModel] = None,
             carbon_obs=None) -> SimResult:
    """`carbon_obs` (optional) splits the signal plane from the billing
    plane: the policy *decides* on the observed intensity (a provider,
    or a per-epoch sequence aligned with `util_trace`) while emissions
    are billed at the true `carbon` — the Carbon Containers controller
    only ever sees its telemetry feed, and under stale/missing samples
    the two diverge (see `repro.robustness`)."""
    mig = migration or MigrationCostModel()
    st = ContainerState(slice_idx=family.baseline_idx)
    st.dwell = 10**6
    dt = cfg.interval_s
    dt_hr = dt / 3600.0
    series: dict = {"t": [], "carbon_rate": [], "slice": [], "duty": [],
                    "util": [], "demand": [], "served": []}

    for n, demand_raw in enumerate(util_trace):
        t = n * dt
        demand = float(demand_raw) * demand_scale
        c = carbon.intensity(t)
        if carbon_obs is None:
            c_obs = c
        elif hasattr(carbon_obs, "intensity"):
            c_obs = carbon_obs.intensity(t)
        else:
            c_obs = float(carbon_obs[n])
        st.demand_integral += demand * dt
        st.elapsed_s += dt
        st.observe_demand(demand)

        # ----- migration in progress: both slices powered, no work --------
        if st.migrating_s > 0:
            src = family[st.slice_idx]
            dst = family[st.migrate_target]
            power = PlantModel.idle_power(src) + PlantModel.idle_power(dst)
            _account(st, family, power, c, served=0.0, demand=demand, dt=dt)
            st.migrating_s -= dt
            if st.migrating_s <= 0:
                st.slice_idx = st.migrate_target
                st.migrate_target = None
                st.dwell = 0
            _record(series, cfg, t, power * c / 1000.0, st, 0.0, demand, 0.0)
            continue

        action: Action = policy.decide(family, st, demand, c_obs,
                                       cfg.target_rate, cfg.epsilon)

        if action.kind == "suspend":
            st.suspended = True
            st.suspended_s += dt
            if cfg.suspend_releases_slice:
                power = 0.0
            else:
                power = PlantModel.idle_power(family[st.slice_idx])
            _account(st, family, power, c, served=0.0, demand=demand, dt=dt)
            _record(series, cfg, t, power * c / 1000.0, st, 0.0, demand, 0.0)
            st.dwell += 1
            continue

        if action.kind == "resume":
            st.suspended = False
            if action.target_slice is not None:
                st.slice_idx = action.target_slice
            st.duty = action.duty

        elif action.kind == "migrate":
            st.migrate_target = action.target_slice
            st.duty = action.duty
            st.migrations += 1
            bw = max(family[st.slice_idx].state_bw_gbps,
                     family[action.target_slice].state_bw_gbps)
            mig_s = mig.stop_and_copy_time(cfg.state_gb, transfer_gbps=bw)
            src = family[st.slice_idx]
            dst = family[action.target_slice]
            down_frac = min(mig_s, dt) / dt
            p_mig = PlantModel.idle_power(src) + PlantModel.idle_power(dst)
            if mig_s >= dt:
                # long migration: whole interval down
                st.migrating_s = mig_s - dt
                _account(st, family, p_mig, c, served=0.0, demand=demand, dt=dt)
                _record(series, cfg, t, p_mig * c / 1000.0, st, 0.0, demand, 0.0)
                continue
            # sub-interval migration: serve the rest of it on the destination
            st.slice_idx = st.migrate_target
            st.migrate_target = None
            st.dwell = 0
            step = PlantModel.run(family[st.slice_idx], st.duty, demand, c)
            power = down_frac * p_mig + (1 - down_frac) * step.power_w
            served = (1 - down_frac) * step.served
            _account(st, family, power, c, served=served, demand=demand, dt=dt)
            _record(series, cfg, t, power * c / 1000.0, st, step.util,
                    demand, served)
            continue

        else:  # stay
            st.duty = action.duty

        step = PlantModel.run(family[st.slice_idx], st.duty, demand, c)
        _account(st, family, step.power_w, c, served=step.served,
                 demand=demand, dt=dt)
        _record(series, cfg, t, step.carbon_rate, st, step.util, demand,
                step.served)
        st.dwell += 1

    hours = st.elapsed_s / 3600.0
    baseline_cap = family.baseline.multiple
    thr_pct = 100.0 * st.throttled_integral / max(st.elapsed_s, 1e-9) / baseline_cap
    return SimResult(
        avg_carbon_rate=st.emissions_g / max(hours, 1e-12),
        avg_throttle_pct=thr_pct,
        work_done=st.work_done,
        work_demanded=st.demand_integral,
        energy_kwh=st.energy_wh / 1000.0,
        migrations=st.migrations,
        suspended_frac=st.suspended_s / max(st.elapsed_s, 1e-9),
        time_on_slice={k: v / max(st.elapsed_s, 1e-9)
                       for k, v in st.time_on_slice_s.items()},
        emissions_g=st.emissions_g,
        hours=hours,
        series=series if cfg.record_series else None,
    )


def _account(st: ContainerState, family, power_w, c, served, demand, dt):
    st.energy_wh += power_w * dt / 3600.0
    st.emissions_g += power_w * c / 1000.0 * dt / 3600.0
    st.work_done += served * dt
    st.throttled_integral += max(0.0, demand - served) * dt
    name = "suspended" if st.suspended else family[st.slice_idx].name
    st.time_on_slice_s[name] = st.time_on_slice_s.get(name, 0.0) + dt


def _record(series, cfg, t, rate, st, util, demand, served):
    if not cfg.record_series:
        return
    series["t"].append(t)
    series["carbon_rate"].append(rate)
    series["slice"].append("susp" if st.suspended else st.slice_idx)
    series["duty"].append(st.duty)
    series["util"].append(util)
    series["demand"].append(demand)
    series["served"].append(served)


# ---------------------------------------------------------------------------
# Population sweep (Figs 11-16): many jobs x many targets x policies
# ---------------------------------------------------------------------------

def sweep_population(policies, family: SliceFamily = None, traces=None,
                     carbon=None, targets: Sequence[float] = None,
                     cfg_base: SimConfig = None,
                     demand_scale: float = 1.0,
                     backend: str = "scalar",
                     placement=None, traffic=None,
                     elasticity=None, energy=None, faults=None):
    """Run a population sweep: every (policy x target x trace) combination.

    Preferred surface: pass a single `repro.core.spec.SweepSpec` as the
    first argument — the per-layer configs (placement, traffic,
    elasticity, energy) and the backend compose as fields — and get a
    `repro.core.spec.SweepResult` back. The legacy kwargs surface below
    is a thin shim kept for one release (deprecated; it returns the
    bare row list):

    Returns rows: {policy, target, mean/std of carbon rate + throttle}.

    `backend="fleet"` batches all (target x trace) pairs per policy through
    the vectorized `repro.core.fleet.FleetSimulator` — same rows, same
    order, ~20-100x faster on population-scale sweeps. `backend="jax"`
    runs the same sweep through the jit/scan device-resident
    `repro.core.fleet_jax.FleetSimulatorJax` (parity with the fleet
    backend pinned to 1e-6; ~5-10x faster again at N >= 5000 containers
    once compiled).

    `placement` (fleet/jax backends only) is a
    `repro.cluster.placement.PlacementEngine`: every trace column is then
    assigned a region per epoch by the placement layer and `carbon` is
    ignored in favour of the planned per-container carbon matrix.

    `traffic` (a `repro.traffic.TrafficConfig`; requires `placement`)
    runs the request-routing + replica-autoscaling layers over the
    plan's regions first and modulates each container's demand by its
    region's serving load; rows gain the `traffic_*` serving metrics.

    `elasticity` (a `repro.core.elasticity.ElasticityConfig`; requires
    `placement`) runs the per-container CarbonScaler level allocation
    over the (scaled, traffic-modulated) demand first — the fleet then
    sees each container's *served* demand, with unserved work deferred
    to later epochs; rows gain the `elastic_*` metrics.

    `energy` (a `repro.energy.EnergyConfig`; requires `placement`) runs
    the per-region virtual energy supply — solar, battery, grid events —
    over the fleet's flexible load: demand is clamped by the virtual
    power cap, emissions are billed at the delivered mix's effective
    intensity, and rows gain the `energy_*` supply metrics.

    `faults` (a `repro.robustness.FaultPlan`; fleet/jax backends only)
    injects seeded signal-plane faults: the controller decides on a
    degraded *observed* carbon feed while emissions stay billed at the
    true one, migrations fail per the plan's mask, and power-telemetry
    gaps accrue `unmetered_g`; rows gain the `fault_*` summaries.
    """
    from repro.core.spec import SweepSpec
    if isinstance(policies, SweepSpec):
        if family is not None or traces is not None:
            raise TypeError("pass either a SweepSpec or the kwargs "
                            "surface, not both")
        return policies.run()
    if backend == "fleet":
        from repro.core.fleet import sweep_population_fleet
        return sweep_population_fleet(policies, family, traces, carbon,
                                      targets, cfg_base,
                                      demand_scale=demand_scale,
                                      placement=placement, traffic=traffic,
                                      elasticity=elasticity, energy=energy,
                                      faults=faults)
    if backend == "jax":
        from repro.core.fleet_jax import sweep_population_jax
        return sweep_population_jax(policies, family, traces, carbon,
                                    targets, cfg_base,
                                    demand_scale=demand_scale,
                                    placement=placement, traffic=traffic,
                                    elasticity=elasticity, energy=energy,
                                    faults=faults)
    if placement is not None:
        raise ValueError("placement requires backend='fleet' or 'jax'")
    if traffic is not None:
        raise ValueError("traffic requires backend='fleet' or 'jax'")
    if elasticity is not None:
        raise ValueError("elasticity requires backend='fleet' or 'jax'")
    if energy is not None:
        raise ValueError("energy requires backend='fleet' or 'jax'")
    if faults is not None:
        raise ValueError("faults requires backend='fleet' or 'jax'")
    if backend != "scalar":
        raise ValueError(f"unknown sweep backend {backend!r}")
    rows = []
    for target in targets:
        for name, mk_policy in policies.items():
            rates, thr, migs, susp = [], [], [], []
            slice_time: dict = {}
            for tr in traces:
                cfg = SimConfig(target_rate=target, epsilon=cfg_base.epsilon,
                                interval_s=cfg_base.interval_s,
                                state_gb=cfg_base.state_gb,
                                suspend_releases_slice=cfg_base.suspend_releases_slice)
                res = simulate(mk_policy(), family, tr, carbon, cfg,
                               demand_scale=demand_scale)
                rates.append(res.avg_carbon_rate)
                thr.append(res.avg_throttle_pct)
                migs.append(res.migrations)
                susp.append(res.suspended_frac)
                for k, v in res.time_on_slice.items():
                    slice_time[k] = slice_time.get(k, 0.0) + v / len(traces)
            rows.append({
                "policy": name, "target": target,
                "carbon_rate_mean": float(np.mean(rates)),
                "carbon_rate_std": float(np.std(rates)),
                "throttle_mean": float(np.mean(thr)),
                "throttle_std": float(np.std(thr)),
                "migrations_mean": float(np.mean(migs)),
                "suspended_frac_mean": float(np.mean(susp)),
                "time_on_slice": slice_time,
            })
    return rows
