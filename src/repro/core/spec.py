"""Declarative sweep surface: one `SweepSpec` for all four layers.

The kwargs surface of `repro.core.simulator.sweep_population` grew one
keyword per layer (placement, traffic, elasticity, energy) plus the
backend selector and the placement engine's own constructor arguments.
`SweepSpec` collapses that into a single declarative value — the
per-layer configs compose as fields, the placement engine can be given
either pre-built or as a `(PlacementConfig, regions)` pair resolved
here, and `run()` dispatches to the selected backend. Every backend
returns the same `SweepResult`, which wraps the aggregate rows with
uniform accessors — `col`, `violations`, `parity` — so callers (and
the benchmark gate) read gated metrics from one shape instead of
per-layer special cases.

The old kwargs path stays as a thin shim for one release:
`sweep_population(policies, family, ...)` still works and still
returns a plain list of row dicts (deprecated — new code should build
a `SweepSpec` and call `run()`, or pass the spec straight to
`sweep_population`, which then returns a `SweepResult`).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional, Sequence

import numpy as np

from repro.cluster.slices import SliceFamily
from repro.core.simulator import SimConfig

# row keys that are per-sweep metadata, not comparable metrics
_NON_METRIC = {"policy", "target", "time_on_slice"}


@dataclass
class SweepSpec:
    """Everything `sweep_population` needs, as one declarative value.

    `placement` is either a ready `PlacementEngine`, or a
    `PlacementConfig` to pair with `regions` (a list of per-region
    carbon providers or a (T, R) intensity matrix) — the engine is then
    built on `sim.interval_s`. The layer configs compose exactly as the
    kwargs did: traffic and elasticity and energy all require
    placement; `energy` additionally perturbs the grid the other
    layers see (see `repro.energy`).
    """
    policies: dict
    family: SliceFamily
    traces: Sequence
    targets: Sequence[float]
    carbon: object = None               # provider (scalar) / matrix; may be
    #                                     None when placement supplies it
    sim: SimConfig = field(
        default_factory=lambda: SimConfig(target_rate=0.0))
    demand_scale: float = 1.0
    backend: str = "fleet"
    placement: object = None            # PlacementEngine | PlacementConfig
    regions: object = None              # with a PlacementConfig placement
    region_names: Optional[Sequence[str]] = None
    traffic: object = None              # repro.traffic.TrafficConfig
    elasticity: object = None           # repro.core.elasticity.ElasticityConfig
    energy: object = None               # repro.energy.EnergyConfig
    faults: object = None               # repro.robustness.FaultPlan

    def resolve_placement(self):
        """The placement engine (building one from a config), or None."""
        if self.placement is None:
            if self.regions is not None:
                raise ValueError("SweepSpec.regions without a placement "
                                 "config; set placement=PlacementConfig(...)")
            return None
        if hasattr(self.placement, "plan"):        # pre-built engine
            if self.regions is not None:
                raise ValueError("pass either a PlacementEngine or a "
                                 "(PlacementConfig, regions) pair, not both")
            return self.placement
        if self.regions is None:
            raise ValueError("placement=PlacementConfig(...) needs "
                             "SweepSpec.regions (per-region carbon "
                             "providers or a (T, R) intensity matrix)")
        from repro.cluster.placement import PlacementEngine
        return PlacementEngine(self.family, self.regions,
                               interval_s=self.sim.interval_s,
                               config=self.placement,
                               region_names=self.region_names)

    def run(self) -> "SweepResult":
        """Execute the sweep on the selected backend."""
        from repro.core.simulator import sweep_population
        rows = sweep_population(self.policies, self.family, self.traces,
                                self.carbon, self.targets, self.sim,
                                demand_scale=self.demand_scale,
                                backend=self.backend,
                                placement=self.resolve_placement(),
                                traffic=self.traffic,
                                elasticity=self.elasticity,
                                energy=self.energy,
                                faults=self.faults)
        return SweepResult(rows=rows, backend=self.backend, spec=self)


@dataclass
class SweepResult:
    """Uniform result of a `SweepSpec` run: the per-(target, policy)
    aggregate rows — carbon rate, throttle/served work, migrations,
    plus whatever layer summaries were active (`traffic_*`,
    `elastic_*`, `energy_*`) — behind one shape. Sequence protocol
    gives back the rows, so row-level code ports by swapping the
    constructor call only."""
    rows: list
    backend: str
    spec: Optional[SweepSpec] = None

    def __len__(self):
        return len(self.rows)

    def __iter__(self):
        return iter(self.rows)

    def __getitem__(self, i):
        return self.rows[i]

    def keys(self) -> list:
        """Numeric metric keys present in every row (sorted)."""
        common = set.intersection(*(set(r) for r in self.rows))
        return sorted(k for k in common - _NON_METRIC
                      if isinstance(self.rows[0][k], (int, float, bool)))

    def col(self, key: str) -> np.ndarray:
        """One metric across the rows, in row order."""
        return np.asarray([float(r[key]) for r in self.rows])

    @property
    def violations(self) -> dict:
        """Max over rows of every `*_violations` metric (zero-keyed
        dict when no layer reported any) — the invariant surface the
        scenario matrix and the bench gate read."""
        return {k: float(self.col(k).max())
                for k in self.keys() if k.endswith("_violations")}

    def parity(self, other: "SweepResult", keys=None) -> float:
        """Max relative difference vs another run of the same sweep
        (rows matched by order; keys default to the shared numeric
        metrics) — the cross-backend parity figure the gates pin."""
        if len(other.rows) != len(self.rows):
            raise ValueError(f"row count mismatch: {len(self.rows)} vs "
                             f"{len(other.rows)}")
        if keys is None:
            keys = sorted(set(self.keys()) & set(other.keys()))
        worst = 0.0
        for a, b in zip(self.rows, other.rows):
            for k in keys:
                num = abs(float(a[k]) - float(b[k]))
                worst = max(worst, num / max(abs(float(a[k])), 1.0))
        return worst
