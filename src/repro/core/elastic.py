"""Elastic slice migration: checkpoint -> rebuild mesh -> reshard -> restore.

This is the TPU-native CRIU: the executor snapshots the training state,
constructs a mesh over the destination slice's devices, device_puts every
leaf with the *new* mesh's shardings (the reshard), and re-jits the step.
The same machinery serves fault recovery (restore on fewer devices after a
failure) and the Carbon Containers migration mechanism.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Callable, Optional

import jax
from jax.sharding import Mesh

from repro.config import TrainConfig
from repro.models.api import Model
from repro.models.params import param_shardings
from repro.train import checkpoint as CKPT
from repro.train import loop as TL


def mesh_over(devices, model_axis: int = 1) -> Mesh:
    """Mesh over an explicit device subset (data-major)."""
    n = len(devices)
    assert n % model_axis == 0, (n, model_axis)
    import numpy as np
    arr = np.array(devices).reshape(n // model_axis, model_axis)
    return Mesh(arr, ("data", "model"))


@dataclass
class ElasticJob:
    """A training job that can move between device subsets ("slices")."""

    model: Model
    cfg: TrainConfig
    ckpt_dir: str

    def __post_init__(self):
        self._mesh: Optional[Mesh] = None
        self._step_fn: Optional[Callable] = None
        self.state = None
        self.manager = CKPT.CheckpointManager(self.ckpt_dir, keep=2,
                                              async_save=False)
        self.step_idx = 0
        self.migrations = []

    # -- lifecycle -----------------------------------------------------------
    def start(self, devices, key=None):
        self._mesh = mesh_over(devices)
        with self._mesh:
            self.state = TL.init_state(self.model, self.cfg.optimizer,
                                       key if key is not None else jax.random.PRNGKey(self.cfg.seed))
            sh = self._state_shardings()
            self.state = jax.tree.map(jax.device_put, self.state, sh)
        self._rejit()

    def _state_shardings(self):
        return param_shardings(TL.state_specs(self.model, self.cfg.optimizer),
                               self._mesh)

    def _rejit(self):
        step = TL.make_train_step(self.model, self.cfg)
        self._step_fn = jax.jit(step, donate_argnums=(0,))

    # -- the enforceable interface -------------------------------------------
    def train_step(self, batch) -> dict:
        from repro.data.pipeline import shard_batch
        with self._mesh:
            batch = shard_batch(batch, self._mesh)
            self.state, metrics = self._step_fn(self.state, batch)
        self.step_idx += 1
        return {k: float(v) for k, v in metrics.items()}

    def checkpoint(self) -> dict:
        self.manager.save(self.step_idx, self.state)
        return self.manager.last_info() or {}

    def migrate(self, devices) -> dict:
        """Stop-and-copy to a new device subset; returns timing breakdown."""
        t0 = time.perf_counter()
        info = self.checkpoint()
        t1 = time.perf_counter()
        self._mesh = mesh_over(devices)
        abstract = TL.abstract_state(self.model, self.cfg.optimizer)
        self.state, _ = self.manager.restore(
            abstract, shardings=self._state_shardings())
        t2 = time.perf_counter()
        self._rejit()
        rec = {"save_s": t1 - t0, "restore_s": t2 - t1,
               "bytes": info.get("bytes", 0), "n_devices": len(devices),
               "step": self.step_idx}
        self.migrations.append(rec)
        return rec

    def suspend(self) -> dict:
        info = self.checkpoint()
        self.state = None           # release device memory
        return info

    def resume(self, devices) -> dict:
        self._mesh = mesh_over(devices)
        abstract = TL.abstract_state(self.model, self.cfg.optimizer)
        self.state, step = self.manager.restore(
            abstract, shardings=self._state_shardings())
        self.step_idx = step
        self._rejit()
        return {"resumed_at_step": step, "n_devices": len(devices)}

    # -- fault tolerance -------------------------------------------------------
    def recover_after_failure(self, surviving_devices) -> dict:
        """Node failure: restore the latest checkpoint on the survivors."""
        return self.resume(surviving_devices)
