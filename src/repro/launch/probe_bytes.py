import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Augment single-pod dry-run JSONs with flash-attention byte probes.

The flops probes use materializing reference attention (exact FLOPs, but
bytes inflated by the (Sq,Skv) logits the TPU flash kernel never writes to
HBM). This pass re-probes with the chunked/flash lowering for the memory
roofline term: matmul/projection bytes exact; attention HBM traffic is the
flash kernel's O(q+k+v+o) (its internal block loops are counted once, which
matches a kernel that streams blocks through VMEM).
"""
import json
import sys
import traceback

from repro.configs.registry import all_cells
from repro.launch import dryrun_lib as DL
from repro.launch.dryrun import DEFAULT_SAVE
from repro.launch.mesh import make_production_mesh


def main():
    save_dir = os.path.abspath(sys.argv[1] if len(sys.argv) > 1 else DEFAULT_SAVE)
    mesh = make_production_mesh(multi_pod=False)
    for arch, shape, status in all_cells():
        if status != "run":
            continue
        path = DL.cell_path(save_dir, False, arch, shape)
        if not os.path.exists(path):
            continue
        with open(path) as f:
            res = json.load(f)
        if res.get("status") != "ok" or "cost_probed_flash" in res:
            continue
        print(f"=== bytes probe {arch} x {shape} ===", flush=True)
        try:
            res["cost_probed_flash"] = DL.probe_flops(
                arch, shape, mesh, remat=res.get("remat", "full"), attn="chunked")
            with open(path, "w") as f:
                json.dump(res, f, indent=1)
        except Exception as e:
            traceback.print_exc()
            print(f"  FAIL {e!r}", flush=True)


if __name__ == "__main__":
    main()
