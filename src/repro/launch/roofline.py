"""Roofline analysis from the dry-run artifacts (single-pod, per §Roofline).

Terms per (arch × shape) cell, all in seconds-per-step on TPU v5e:

  compute_term    = HLO_FLOPs/device ÷ 197 TFLOP/s      (probed, exact: the
                    marginal-layer probes count every scan iteration)
  memory_term     = HLO_bytes/device ÷ 819 GB/s          (flash-attention
                    byte probes: no materialized S² logits)
  collective_term = wire_bytes/device ÷ 50 GB/s          (trip-count-aware
                    HLO parse, ring cost models)

Also: MODEL_FLOPS = 6·N·D (train) / 2·N·D (serve) with N = active params;
the useful-compute ratio MODEL_FLOPS/HLO_FLOPs (catches remat/dispatch
waste); the dominant term; and a per-cell bottleneck note.

Usage:
  PYTHONPATH=src python -m repro.launch.roofline [--save-dir D] [--csv out]
"""
from __future__ import annotations

import json
import os
import sys

from repro.config import parse_cli
from repro.configs.registry import all_cells
from repro.launch.dryrun_lib import HW

DEFAULT_SAVE = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                            "experiments", "dryrun")

NOTES = {
    "compute": "compute-bound: more MXU efficiency (fusion, larger blocks) "
               "or fewer redundant FLOPs (remat policy) moves it",
    "memory": "HBM-bound: reduce bytes/step (bf16 master copies, fused "
              "layers, smaller logit blocks) or raise arithmetic intensity",
    "collective": "ICI-bound: cut wire bytes (sharding that avoids gathers, "
                  "compressed grads, a2a instead of psum for MoE combine)",
}


def load_cells(save_dir: str) -> list:
    rows = []
    for arch, shape, status in all_cells():
        path = os.path.join(save_dir, "single_pod", f"{arch}__{shape}.json")
        if not os.path.exists(path):
            continue
        with open(path) as f:
            res = json.load(f)
        rows.append(res)
    return rows


def roofline_row(res: dict) -> dict:
    if res.get("status") != "ok":
        return {"arch": res["arch"], "shape": res["shape"],
                "status": res.get("reason", res.get("status"))}
    n_dev = res["devices"]
    flops_dev = (res.get("cost_probed") or res["cost_raw"])["flops"]
    bytes_dev = (res.get("cost_probed_flash")
                 or res.get("cost_probed")
                 or res["cost_raw"])["bytes_accessed"]
    wire_dev = res["collectives"]["total_wire_bytes"]
    compute_s = flops_dev / HW["peak_flops_bf16"]
    memory_s = bytes_dev / HW["hbm_bw"]
    collective_s = wire_dev / HW["ici_bw"]
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    dominant = max(terms, key=terms.get)
    model_flops_dev = res["model_flops_global"] / n_dev
    bound = max(terms.values())
    ideal = model_flops_dev / HW["peak_flops_bf16"]
    return {
        "arch": res["arch"], "shape": res["shape"], "status": "ok",
        "devices": n_dev,
        "compute_s": compute_s, "memory_s": memory_s,
        "collective_s": collective_s, "dominant": dominant,
        "model_flops_global": res["model_flops_global"],
        "useful_ratio": model_flops_dev / max(flops_dev, 1e-30),
        "roofline_fraction": ideal / max(bound, 1e-30),
        "peak_hbm_gb": res["memory"]["peak_bytes"] / 1e9,
        "fits_hbm": res["memory"]["peak_bytes"] <= HW["hbm_bytes"],
        "note": NOTES[dominant],
    }


def markdown_table(rows: list) -> str:
    hdr = ("| arch | shape | compute s | memory s | collective s | dominant "
           "| useful ratio | roofline frac | HBM GB/dev | fits |\n"
           "|---|---|---|---|---|---|---|---|---|---|")
    out = [hdr]
    for r in rows:
        if r.get("status") != "ok":
            out.append(f"| {r['arch']} | {r['shape']} | — | — | — | skipped | "
                       f"— | — | — | ({r['status'][:40]}…) |")
            continue
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3g} | "
            f"{r['memory_s']:.3g} | {r['collective_s']:.3g} | "
            f"**{r['dominant']}** | {r['useful_ratio']:.2f} | "
            f"{r['roofline_fraction']:.2f} | {r['peak_hbm_gb']:.1f} | "
            f"{'yes' if r['fits_hbm'] else 'NO'} |")
    return "\n".join(out)


def main(argv=None) -> int:
    args = parse_cli(argv if argv is not None else sys.argv[1:])
    save_dir = os.path.abspath(args.get("save-dir", DEFAULT_SAVE))
    rows = [roofline_row(r) for r in load_cells(save_dir)]
    print(markdown_table(rows))
    out_json = os.path.join(save_dir, "roofline.json")
    with open(out_json, "w") as f:
        json.dump(rows, f, indent=1)
    print(f"\nwrote {out_json} ({sum(1 for r in rows if r.get('status')=='ok')} ok rows)")
    if "csv" in args:
        import csv
        keys = ["arch", "shape", "compute_s", "memory_s", "collective_s",
                "dominant", "useful_ratio", "roofline_fraction", "peak_hbm_gb"]
        with open(args["csv"], "w", newline="") as f:
            w = csv.DictWriter(f, keys, extrasaction="ignore")
            w.writeheader()
            for r in rows:
                if r.get("status") == "ok":
                    w.writerow(r)
    return 0


if __name__ == "__main__":
    sys.exit(main())
