"""Mesh construction. Functions only — importing this never touches jax
device state (required: the dry-run sets XLA_FLAGS before first jax init)."""
from __future__ import annotations

import jax
from jax.sharding import Mesh

from repro.config import MeshConfig

# Production topology: one v5e pod = 16x16 = 256 chips; multi-pod = 2 pods.
SINGLE_POD = MeshConfig(data=16, model=16, pod=1)
MULTI_POD = MeshConfig(data=16, model=16, pod=2)


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(cfg: MeshConfig) -> Mesh:
    return jax.make_mesh(cfg.shape(), cfg.axis_names())


def make_local_mesh(data: int = 0, model: int = 1) -> Mesh:
    """Mesh over whatever devices exist (tests / CPU examples)."""
    n = len(jax.devices())
    if data == 0:
        data = n // model
    return jax.make_mesh((data, model), ("data", "model"))


def describe(mesh: Mesh) -> dict:
    return {"axes": dict(mesh.shape), "devices": mesh.devices.size}
