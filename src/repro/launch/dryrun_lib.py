"""Dry-run machinery: build + lower + compile every (arch × shape × mesh) cell.

The dry-run proves the distribution config is coherent: every cell must
``.lower().compile()`` on the production meshes with explicit in/out
shardings, and its compiled artifact yields the roofline inputs:

  - memory_analysis()      -> per-device bytes (proves it fits 16 GB HBM)
  - cost_analysis()        -> per-device FLOPs/bytes (while-bodies counted
                              once; corrected via marginal-layer probes)
  - as_text()              -> collective wire bytes (trip-count aware)

Import note: callers must set XLA_FLAGS=--xla_force_host_platform_device_count
BEFORE importing jax (dryrun.py does); this module never sets it.
"""
from __future__ import annotations

import dataclasses
import json
import os
import time
from typing import Optional

import jax
from jax.sharding import NamedSharding

from repro.config import (DECODE, ENCDEC, HYBRID, PREFILL, TRAIN,
                          OptimizerConfig, SHAPES, TrainConfig)
from repro.configs import get_arch
from repro.launch import hlo_analysis as HLO
from repro.launch.mesh import make_production_mesh
from repro.models.api import get_model
from repro.models.sharding import logical_to_pspec, rules_ctx
from repro.train import loop as TL

# TPU v5e hardware constants (per chip)
HW = {"peak_flops_bf16": 197e12, "hbm_bw": 819e9, "ici_bw": 50e9,
      "hbm_bytes": 16e9}


# ---------------------------------------------------------------------------
# Cell construction
# ---------------------------------------------------------------------------

def build_cell(arch_id: str, shape_name: str, mesh, *,
               cfg=None, remat: str = "full", rules_override=None,
               microbatch: int = 0):
    """Returns (fn, abstract_args, in_shardings, out_shardings, meta).

    ``rules_override`` remaps logical sharding axes (e.g. {"fsdp": ()} for
    pure-TP serving, {"tp": (), "batch": ("pod","data","model")} for
    pure-DP small models) — the §Perf hillclimbing lever.
    """
    spec = get_arch(arch_id)
    cfg = cfg if cfg is not None else spec.full
    shape = SHAPES[shape_name]
    model = get_model(cfg)
    ov = rules_override
    ns = lambda pspec: NamedSharding(mesh, pspec)
    input_sh = {k: ns(v)
                for k, v in model.input_pspecs(shape, mesh, ov).items()}

    if shape.kind == TRAIN:
        tcfg = TrainConfig(seq_len=shape.seq_len, global_batch=shape.global_batch,
                           remat=remat, microbatch=microbatch,
                           optimizer=OptimizerConfig())
        fn = TL.make_train_step(model, tcfg)
        state = TL.abstract_state(model, tcfg.optimizer)
        state_sh = jax.tree.map(ns, TL.state_pspecs(model, tcfg.optimizer,
                                                    mesh, ov))
        args = (state, model.input_specs(shape))
        in_sh = (state_sh, input_sh)
        out_sh = (state_sh, None)
    elif shape.kind == PREFILL:
        fn = lambda params, batch: model.prefill(params, batch)
        params = model.abstract()
        params_sh = model.shardings(mesh, ov)
        cache_sh = model.cache_shardings(shape.global_batch, shape.seq_len,
                                         mesh, ov)
        logits_sh = ns(logical_to_pspec(("batch", "tp"),
                                        (shape.global_batch, cfg.vocab_size),
                                        mesh, ov))
        args = (params, model.input_specs(shape))
        in_sh = (params_sh, input_sh)
        out_sh = (logits_sh, cache_sh)
    elif shape.kind == DECODE:
        fn = lambda params, cache, tokens: model.decode(params, cache, tokens)
        params = model.abstract()
        params_sh = model.shardings(mesh, ov)
        cache = model.abstract_cache(shape.global_batch, shape.seq_len)
        cache_sh = model.cache_shardings(shape.global_batch, shape.seq_len,
                                         mesh, ov)
        logits_sh = ns(logical_to_pspec(("batch", "tp"),
                                        (shape.global_batch, cfg.vocab_size),
                                        mesh, ov))
        args = (params, cache, model.input_specs(shape)["tokens"])
        in_sh = (params_sh, cache_sh, input_sh["tokens"])
        out_sh = (logits_sh, cache_sh)
    else:
        raise ValueError(shape.kind)

    meta = {"arch": arch_id, "shape": shape_name, "kind": shape.kind,
            "devices": int(mesh.devices.size), "remat": remat,
            "params": model.param_count()}
    return fn, args, in_sh, out_sh, meta


def lower_and_compile(arch_id: str, shape_name: str, mesh, *,
                      cfg=None, remat: str = "full", rules_override=None,
                      microbatch: int = 0):
    fn, args, in_sh, out_sh, meta = build_cell(
        arch_id, shape_name, mesh, cfg=cfg, remat=remat,
        rules_override=rules_override, microbatch=microbatch)
    t0 = time.perf_counter()
    with mesh, rules_ctx(rules_override):
        lowered = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh).lower(*args)
        t1 = time.perf_counter()
        compiled = lowered.compile()
    t2 = time.perf_counter()
    meta["lower_s"] = t1 - t0
    meta["compile_s"] = t2 - t1
    return compiled, meta


# ---------------------------------------------------------------------------
# Marginal-layer FLOPs probes (exact per-layer HLO cost, no while undercount)
# ---------------------------------------------------------------------------

def _probe_cfg(cfg, n_layers: int, n_enc: Optional[int] = None,
               attn: str = "ref"):
    kw = dict(n_layers=n_layers, scan_unroll=True, attn_impl=attn)
    if n_enc is not None:
        kw["n_enc_layers"] = n_enc
    return dataclasses.replace(cfg, **kw)


def probe_flops(arch_id: str, shape_name: str, mesh, *, remat: str = "full",
                attn: str = "ref", rules_override=None) -> dict:
    """Compile tiny-depth unrolled variants; extrapolate exact full-depth cost.

    Returns per-device {flops, bytes_accessed} for the full architecture.
    """
    spec = get_arch(arch_id)
    cfg = spec.full

    def cost_of(pcfg):
        compiled, _ = lower_and_compile(arch_id, shape_name, mesh,
                                        cfg=pcfg, remat=remat,
                                        rules_override=rules_override)
        return HLO.cost_stats(compiled)

    if cfg.family == HYBRID:
        pat = len(cfg.block_pattern)
        n_super = cfg.n_layers // pat
        n_trail = cfg.n_layers - n_super * pat
        f3 = cost_of(_probe_cfg(cfg, pat, attn=attn))
        f6 = cost_of(_probe_cfg(cfg, 2 * pat, attn=attn))
        out = {}
        f5 = cost_of(_probe_cfg(cfg, pat + n_trail, attn=attn)) if n_trail else None
        for key in ("flops", "bytes_accessed"):
            total = f3[key] + (n_super - 1) * (f6[key] - f3[key])
            if n_trail:
                total += f5[key] - f3[key]
            out[key] = total
        return out
    if cfg.family == ENCDEC:
        f11 = cost_of(_probe_cfg(cfg, 1, 1, attn=attn))
        f21 = cost_of(_probe_cfg(cfg, 2, 1, attn=attn))   # +1 decoder layer
        f12 = cost_of(_probe_cfg(cfg, 1, 2, attn=attn))   # +1 encoder layer
        return {k: f11[k] + (cfg.n_layers - 1) * (f21[k] - f11[k])
                + (cfg.n_enc_layers - 1) * (f12[k] - f11[k])
                for k in ("flops", "bytes_accessed")}
    f1 = cost_of(_probe_cfg(cfg, 1, attn=attn))
    f2 = cost_of(_probe_cfg(cfg, 2, attn=attn))
    return {k: f1[k] + (cfg.n_layers - 1) * (f2[k] - f1[k])
            for k in ("flops", "bytes_accessed")}


# ---------------------------------------------------------------------------
# Analytic model FLOPs (6ND / 2ND) for the "useful compute" ratio
# ---------------------------------------------------------------------------

def model_flops(arch_id: str, shape_name: str) -> float:
    """Global MODEL_FLOPS: 6·N_active·D for train, 2·N_active·D otherwise."""
    spec = get_arch(arch_id)
    shape = SHAPES[shape_name]
    n_active = spec.full.active_param_count()
    mult = 6.0 if shape.kind == TRAIN else 2.0
    return mult * n_active * shape.tokens_per_step


# ---------------------------------------------------------------------------
# Full cell analysis -> JSON
# ---------------------------------------------------------------------------

def analyze_cell(arch_id: str, shape_name: str, *, multi_pod: bool,
                 remat: str = "full", probes: bool = True,
                 save_dir: Optional[str] = None, verbose: bool = True) -> dict:
    spec = get_arch(arch_id)
    if shape_name in spec.skip_shapes:
        result = {"arch": arch_id, "shape": shape_name,
                  "status": "skipped", "reason": spec.skip_shapes[shape_name]}
        if save_dir:
            _save(save_dir, multi_pod, arch_id, shape_name, result)
        return result

    mesh = make_production_mesh(multi_pod=multi_pod)
    compiled, meta = lower_and_compile(arch_id, shape_name, mesh, remat=remat)
    if verbose:
        print(compiled.memory_analysis())
        print({k: v for k, v in (compiled.cost_analysis() or {}).items()
               if k in ("flops", "bytes accessed")})
    result = {**meta, "status": "ok",
              "memory": HLO.memory_stats(compiled),
              "cost_raw": HLO.cost_stats(compiled),
              "collectives": HLO.analyze_collectives(compiled.as_text()),
              "model_flops_global": model_flops(arch_id, shape_name)}
    if probes:
        result["cost_probed"] = probe_flops(arch_id, shape_name, mesh, remat=remat)
    if save_dir:
        _save(save_dir, multi_pod, arch_id, shape_name, result)
    return result


def _save(save_dir: str, multi_pod: bool, arch_id: str, shape_name: str,
          result: dict):
    sub = os.path.join(save_dir, "multi_pod" if multi_pod else "single_pod")
    os.makedirs(sub, exist_ok=True)
    with open(os.path.join(sub, f"{arch_id}__{shape_name}.json"), "w") as f:
        json.dump(result, f, indent=1)


def cell_path(save_dir: str, multi_pod: bool, arch_id: str, shape_name: str) -> str:
    sub = "multi_pod" if multi_pod else "single_pod"
    return os.path.join(save_dir, sub, f"{arch_id}__{shape_name}.json")
