"""Training launcher.

  PYTHONPATH=src python -m repro.launch.train --arch smollm-135m --smoke true \
      --steps 50 --global-batch 8 --seq-len 128 [--carbon-target 80 --region NL]

With --carbon-target the job runs inside a Carbon Container (live
enforcement: duty-cycling + elastic slice migration + suspend/resume).
"""
from __future__ import annotations

import sys
import tempfile

import jax

from repro.config import (CarbonConfig, OptimizerConfig, TrainConfig,
                          parse_cli)
from repro.configs import get_arch
from repro.data.pipeline import markov_stream
from repro.models.api import get_model
from repro.train import loop as TL


def main(argv=None) -> int:
    args = parse_cli(argv if argv is not None else sys.argv[1:])
    arch = args.get("arch", "smollm-135m")
    spec = get_arch(arch)
    cfg = spec.smoke if args.get("smoke", "true") != "false" else spec.full
    model = get_model(cfg)
    tcfg = TrainConfig(
        seq_len=int(args.get("seq-len", 128)),
        global_batch=int(args.get("global-batch", 8)),
        steps=int(args.get("steps", 50)),
        microbatch=int(args.get("microbatch", 0)),
        remat=args.get("remat", "none"),
        optimizer=OptimizerConfig(
            lr=float(args.get("lr", 1e-3)),
            warmup_steps=int(args.get("warmup", 10)),
            total_steps=int(args.get("steps", 50)),
            compression=args.get("compression", "none")),
        log_every=int(args.get("log-every", 10)),
    )
    data = markov_stream(cfg.vocab_size, tcfg.seq_len, tcfg.global_batch,
                         seed=tcfg.seed)

    if "carbon-target" in args:
        from repro.carbon.intensity import TraceProvider
        from repro.cluster.slices import tpu_v5e_family
        from repro.core.carbon_aware_trainer import CarbonAwareTrainer
        from repro.core.elastic import ElasticJob
        devs = jax.devices()
        family = tpu_v5e_family()
        # map family slices onto available devices (demo scale: slice i gets
        # 2^i devices, capped at what exists)
        n = len(devs)
        slice_devs = [devs[:max(1, min(n, 2 ** i))] for i in range(len(family))]
        ckpt = args.get("ckpt-dir", tempfile.mkdtemp(prefix="lxcc_"))
        job = ElasticJob(model, tcfg, ckpt)
        job.start(slice_devs[family.baseline_idx])
        ccfg = CarbonConfig(target_rate=float(args["carbon-target"]),
                            policy=args.get("policy", "energy"),
                            region=args.get("region", "NL"))
        step_flops = 6.0 * model.param_count() * tcfg.seq_len * tcfg.global_batch
        trainer = CarbonAwareTrainer(
            job=job, family=family, slice_devices=slice_devs,
            carbon=TraceProvider.for_region(ccfg.region),
            cfg=ccfg, step_flops=step_flops,
            step_tokens=tcfg.seq_len * tcfg.global_batch,
            sim_seconds_per_step=float(args.get("sim-step-s", 60.0)))
        out = trainer.run(data, tcfg.steps)
        print(f"done: {out['steps']} steps, {len(out['migrations'])} migrations")
        for log in out["logs"][-5:]:
            print(f"  t={log.t/3600:.1f}h slice={log.slice_name} duty={log.duty:.2f} "
                  f"C={log.carbon_rate:.0f} g/hr ({log.action})")
        return 0

    out = TL.run(model, tcfg, data)
    print(f"final loss {out['history'][-1]['loss']:.4f}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
