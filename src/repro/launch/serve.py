"""Serving launcher: batched generation on a smoke-scale model.

  PYTHONPATH=src python -m repro.launch.serve --arch smollm-135m \
      --batch 4 --prompt-len 32 --new-tokens 16
"""
from __future__ import annotations

import sys

import numpy as np

from repro.config import parse_cli
from repro.configs import get_arch
from repro.models.api import get_model
from repro.serve.engine import ServeEngine, throughput_tokens_per_s


def main(argv=None) -> int:
    args = parse_cli(argv if argv is not None else sys.argv[1:])
    arch = args.get("arch", "smollm-135m")
    spec = get_arch(arch)
    cfg = spec.smoke if args.get("smoke", "true") != "false" else spec.full
    model = get_model(cfg)
    engine = ServeEngine(model).load()
    B = int(args.get("batch", 4))
    S = int(args.get("prompt-len", 32))
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab_size, (B, S)).astype(np.int32)
    out = engine.generate(prompts, int(args.get("new-tokens", 16)),
                          duty=float(args.get("duty", 1.0)))
    tp = throughput_tokens_per_s(out["stats"])
    print(f"generated {out['tokens'].shape} tokens")
    print(f"prefill {tp['prefill_tok_s']:.0f} tok/s, decode {tp['decode_tok_s']:.0f} tok/s")
    return 0


if __name__ == "__main__":
    sys.exit(main())
