import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""§Perf hillclimbing on the three selected cells.

Each iteration: hypothesis -> change -> re-lower -> measure (collective wire
bytes + per-device HBM are exact from the compiled artifact; flops probes on
request). Results land in experiments/perf/<cell>__<tag>.json; the narrative
lives in EXPERIMENTS.md §Perf.

  PYTHONPATH=src python -m repro.launch.hillclimb [--only A1]
"""
import dataclasses
import json
import sys
import traceback

from repro.configs import get_arch
from repro.launch import dryrun_lib as DL
from repro.launch import hlo_analysis as HLO
from repro.launch.mesh import make_production_mesh

OUT = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "..",
                                   "..", "experiments", "perf"))

PURE_DP = {"tp": (), "fsdp": (), "sp": (), "expert": (), "kv_seq": (),
           "batch": ("pod", "data", "model")}
SERVE_TP = {"fsdp": ()}


def run_variant(name, arch, shape, *, cfg_kw=None, rules_override=None,
                microbatch=0, remat="full", probes=False, mesh_shape=None):
    if mesh_shape is None:
        mesh = make_production_mesh(multi_pod=False)
    else:
        import jax
        mesh = jax.make_mesh(mesh_shape, ("data", "model"))
    cfg = get_arch(arch).full
    if cfg_kw:
        cfg = dataclasses.replace(cfg, **cfg_kw)
    compiled, meta = DL.lower_and_compile(
        arch, shape, mesh, cfg=cfg, remat=remat,
        rules_override=rules_override, microbatch=microbatch)
    res = {**meta, "variant": name,
           "cfg_kw": {k: str(v) for k, v in (cfg_kw or {}).items()},
           "rules_override": {k: list(v) for k, v in (rules_override or {}).items()},
           "microbatch": microbatch,
           "memory": HLO.memory_stats(compiled),
           "cost_raw": HLO.cost_stats(compiled),
           "collectives": HLO.analyze_collectives(compiled.as_text()),
           "model_flops_global": DL.model_flops(arch, shape)}
    if probes:
        res["cost_probed"] = DL.probe_flops(arch, shape, mesh, remat=remat,
                                            rules_override=rules_override)
        res["cost_probed_flash"] = DL.probe_flops(
            arch, shape, mesh, remat=remat, attn="chunked",
            rules_override=rules_override)
    os.makedirs(OUT, exist_ok=True)
    with open(os.path.join(OUT, f"{arch}__{shape}__{name}.json"), "w") as f:
        json.dump(res, f, indent=1)
    wire = res["collectives"]["total_wire_bytes"]
    peak = res["memory"]["peak_bytes"]
    print(f"  [{name}] wire {wire/1e9:8.2f} GB/dev -> {wire/DL.HW['ici_bw']:7.3f} s  "
          f"peak HBM {peak/1e9:6.2f} GB  compile {meta['compile_s']:.0f}s",
          flush=True)
    return res


VARIANTS = {
    # --- Cell A: chameleon-34b train_4k (most collective-bound) ----------
    "A1": lambda: run_variant("A1_bf16_gather", "chameleon-34b", "train_4k"),
    "A0": lambda: run_variant("A0_f32_gather", "chameleon-34b", "train_4k",
                              cfg_kw={"cast_weights": False}),
    "A2": lambda: run_variant("A2_bf16_microbatch128", "chameleon-34b",
                              "train_4k", microbatch=128),
    "A3": lambda: run_variant("A3_bf16_mb64", "chameleon-34b", "train_4k",
                              microbatch=64),
    "A4": lambda: run_variant("A4_no_sp", "chameleon-34b", "train_4k",
                              cfg_kw={"seq_shard": False}),
    "A6": lambda: run_variant("A6_mesh64x4", "chameleon-34b", "train_4k",
                              mesh_shape=(64, 4), microbatch=128),
    "A7": lambda: run_variant("A7_mesh64x4_final", "chameleon-34b",
                              "train_4k", mesh_shape=(64, 4), microbatch=128,
                              probes=True),
    "A8": lambda: run_variant("A8_mesh128x2", "chameleon-34b", "train_4k",
                              mesh_shape=(128, 2), microbatch=128),
    "A9": lambda: run_variant("A9_mesh256x1_fsdp", "chameleon-34b",
                              "train_4k", mesh_shape=(256, 1)),
    "A10": lambda: run_variant("A10_fsdp_final", "chameleon-34b", "train_4k",
                               mesh_shape=(256, 1), probes=True),
    "B3": lambda: run_variant("B3_pure_dp_final", "smollm-135m", "train_4k",
                              rules_override=PURE_DP, remat="none",
                              probes=True),
    "A2F": lambda: run_variant("A2F_final_probe", "chameleon-34b", "train_4k",
                               microbatch=128, probes=True),
    "A5": lambda: run_variant("A5_no_sp_mb128", "chameleon-34b", "train_4k",
                              cfg_kw={"seq_shard": False}, microbatch=128),
    # --- Cell B: smollm-135m train_4k (worst roofline fraction) ----------
    "B0": lambda: run_variant("B0_baseline_sharded", "smollm-135m", "train_4k",
                              cfg_kw={"cast_weights": False}),
    "B1": lambda: run_variant("B1_pure_dp", "smollm-135m", "train_4k",
                              rules_override=PURE_DP, probes=True),
    "B2": lambda: run_variant("B2_pure_dp_nomat", "smollm-135m", "train_4k",
                              rules_override=PURE_DP, remat="none",
                              probes=True),
    "D1": lambda: run_variant("D1_dbrx_mb64", "dbrx-132b", "train_4k",
                              microbatch=64),
    "D2": lambda: run_variant("D2_dbrx_fsdp_ep", "dbrx-132b", "train_4k",
                              mesh_shape=(16, 16), microbatch=32),
    "D3": lambda: run_variant("D3_dbrx_mb16", "dbrx-132b", "train_4k",
                              microbatch=16),
    # --- Cell C: starcoder2-15b decode_32k (serving; paper-representative)
    "C0": lambda: run_variant("C0_fsdp_f32", "starcoder2-15b", "decode_32k",
                              cfg_kw={"cast_weights": False}),
    "C1": lambda: run_variant("C1_fsdp_bf16", "starcoder2-15b", "decode_32k"),
    "C2": lambda: run_variant("C2_pure_tp", "starcoder2-15b", "decode_32k",
                              rules_override=SERVE_TP,
                              cfg_kw={"param_dtype": "bfloat16"},
                              probes=True),
}


def main(argv=None):
    argv = argv if argv is not None else sys.argv[1:]
    only = None
    if "--only" in argv:
        only = argv[argv.index("--only") + 1].split(",")
    for key, fn in VARIANTS.items():
        if only and key not in only:
            continue
        print(f"=== {key} ===", flush=True)
        try:
            fn()
        except Exception as e:
            traceback.print_exc()
            print(f"  FAIL {e!r}", flush=True)


if __name__ == "__main__":
    main()
