"""Post-SPMD HLO analysis: collective bytes with while-loop trip counts.

``compiled.cost_analysis()`` does not multiply while-loop bodies by their
trip counts (scan-over-layers would undercount by ~n_layers), and it reports
no collective traffic at all. This module parses ``compiled.as_text()``:

  1. split the module into computations,
  2. record every collective op's (kind, result bytes, group size),
  3. walk the call graph from ENTRY, multiplying while bodies by the
     ``known_trip_count`` XLA annotates after loop analysis,
  4. convert to bytes-on-the-wire per device with standard ring-algorithm
     cost models.
"""
from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

COLLECTIVE_KINDS = ("all-reduce", "all-gather", "reduce-scatter",
                    "all-to-all", "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COMP_START_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s+\(.*\)\s*->\s*.+\s\{")
_OP_RE = re.compile(
    r"=\s+(\(?[\w\[\]\{\},\s\/]*?\)?)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_GROUPS_BRACE_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_CALL_RE = re.compile(r"(?:to_apply|body|condition)=%?([\w\.\-]+)")
_TRIP_RE = re.compile(r'known_trip_count[^}]*?n["\s:]+"?(\d+)')


def _shape_bytes(type_str: str) -> int:
    """Bytes of an HLO result type (handles tuples)."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _wire_bytes(kind: str, result_bytes: int, n: int) -> float:
    """Ring-algorithm bytes moved per participating device."""
    if n <= 1:
        return 0.0
    if kind == "all-reduce":
        return 2.0 * result_bytes * (n - 1) / n
    if kind == "all-gather":
        return result_bytes * (n - 1) / n
    if kind == "reduce-scatter":
        return float(result_bytes) * (n - 1)   # result is the scattered shard
    if kind == "all-to-all":
        return result_bytes * (n - 1) / n
    if kind == "collective-permute":
        return float(result_bytes)
    return 0.0


@dataclass
class Computation:
    name: str
    collectives: list = field(default_factory=list)   # (kind, bytes, group_n)
    calls: list = field(default_factory=list)         # (callee, multiplier)


def _parse_computations(text: str) -> dict:
    comps: dict[str, Computation] = {}
    cur = None
    for line in text.splitlines():
        m = _COMP_START_RE.match(line.strip()) if "{" in line else None
        if m and not line.lstrip().startswith("%constant"):
            cur = Computation(m.group(1))
            comps[cur.name] = cur
            continue
        if cur is None:
            continue
        if line.strip() == "}":
            cur = None
            continue
        om = _OP_RE.search(line)
        if om:
            result_bytes = _shape_bytes(om.group(1))
            kind = om.group(2)
            n = 0
            gb = _GROUPS_BRACE_RE.search(line)
            if gb:
                n = len(gb.group(1).split(","))
            else:
                gi = _GROUPS_IOTA_RE.search(line)
                if gi:
                    n = int(gi.group(2))       # [num_groups, group_size]
            if kind == "all-reduce" and result_bytes and "-done" not in line:
                cur.collectives.append((kind, result_bytes, max(n, 1)))
            elif kind != "all-reduce" and "-done" not in line:
                cur.collectives.append((kind, result_bytes, max(n, 1)))
        if " while(" in line:
            trip = 1
            tm = _TRIP_RE.search(line)
            if tm:
                trip = int(tm.group(1))
            bm = re.search(r"body=%?([\w\.\-]+)", line)
            if bm:
                cur.calls.append((bm.group(1), trip))
        elif "to_apply=" in line or "calls=" in line:
            for callee in _CALL_RE.findall(line):
                cur.calls.append((callee, 1))


    return comps


def analyze_collectives(text: str) -> dict:
    """Returns {total_wire_bytes, per_kind: {kind: {count, wire_bytes}}}."""
    comps = _parse_computations(text)
    entry = None
    for line in text.splitlines():
        if line.startswith("ENTRY"):
            m = re.search(r"ENTRY\s+%?([\w\.\-]+)", line)
            if m:
                entry = m.group(1)
            break
    if entry is None or entry not in comps:
        # fall back: treat every computation once
        entry_names = list(comps)
    else:
        entry_names = [entry]

    per_kind: dict = defaultdict(lambda: {"count": 0.0, "wire_bytes": 0.0,
                                          "result_bytes": 0.0})
    visiting: set = set()

    def walk(name: str, mult: float):
        if name not in comps or name in visiting:
            return
        visiting.add(name)
        c = comps[name]
        for kind, rb, n in c.collectives:
            wb = _wire_bytes(kind, rb, n)
            per_kind[kind]["count"] += mult
            per_kind[kind]["wire_bytes"] += wb * mult
            per_kind[kind]["result_bytes"] += rb * mult
        for callee, m in c.calls:
            walk(callee, mult * m)
        visiting.discard(name)

    for en in entry_names:
        walk(en, 1.0)

    total = sum(v["wire_bytes"] for v in per_kind.values())
    return {"total_wire_bytes": total,
            "per_kind": {k: dict(v) for k, v in per_kind.items()}}


def memory_stats(compiled) -> dict:
    ma = compiled.memory_analysis()
    return {
        "argument_bytes": int(ma.argument_size_in_bytes),
        "output_bytes": int(ma.output_size_in_bytes),
        "temp_bytes": int(ma.temp_size_in_bytes),
        "alias_bytes": int(ma.alias_size_in_bytes),
        "peak_bytes": int(ma.argument_size_in_bytes + ma.output_size_in_bytes
                          + ma.temp_size_in_bytes - ma.alias_size_in_bytes),
    }


def cost_stats(compiled) -> dict:
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):  # older jax: one dict per program
        ca = ca[0] if ca else {}
    get = lambda k: float(ca.get(k, 0.0) or 0.0)
    return {"flops": get("flops"),
            "transcendentals": get("transcendentals"),
            "bytes_accessed": get("bytes accessed")}
