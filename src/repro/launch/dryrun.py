import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Multi-pod dry-run entry point.

Lowers + compiles every (architecture × input shape) cell on the production
meshes — 16×16=256 chips single-pod and 2×16×16=512 chips multi-pod — with
explicit in/out shardings, prints memory/cost analyses, and records roofline
inputs to experiments/dryrun/.

The XLA_FLAGS line above MUST run before any jax import (jax locks the
device count at first init), which is why it is the first statement.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --all [--skip-existing true]
  PYTHONPATH=src python -m repro.launch.dryrun --arch mamba2-2.7b --shape long_500k --mesh both
"""
import sys
import traceback

from repro.config import SHAPES, parse_cli
from repro.configs import list_archs
from repro.configs.registry import all_cells
from repro.launch import dryrun_lib as DL

DEFAULT_SAVE = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                            "experiments", "dryrun")


def main(argv=None) -> int:
    args = parse_cli(argv if argv is not None else sys.argv[1:])
    save_dir = os.path.abspath(args.get("save-dir", DEFAULT_SAVE))
    skip_existing = args.get("skip-existing", "true").lower() != "false"
    probes = args.get("probes", "true").lower() != "false"
    remat = args.get("remat", "full")
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.get("mesh", "both")]

    if "all" in args:
        cells = [(a, s) for a, s, _ in all_cells()]
    else:
        archs = [args["arch"]] if "arch" in args else list_archs()
        shapes = [args["shape"]] if "shape" in args else list(SHAPES)
        cells = [(a, s) for a in archs for s in shapes]

    failures = []
    for multi_pod in meshes:
        # Probes (and the roofline table) are single-pod only; the multi-pod
        # pass proves the "pod" axis shards.
        cell_probes = probes and not multi_pod
        for arch_id, shape_name in cells:
            path = DL.cell_path(save_dir, multi_pod, arch_id, shape_name)
            if skip_existing and os.path.exists(path):
                print(f"[skip existing] {arch_id} x {shape_name} "
                      f"({'multi' if multi_pod else 'single'})", flush=True)
                continue
            label = f"{arch_id} x {shape_name} ({'multi' if multi_pod else 'single'}-pod)"
            print(f"=== {label} ===", flush=True)
            try:
                res = DL.analyze_cell(arch_id, shape_name, multi_pod=multi_pod,
                                      remat=remat, probes=cell_probes,
                                      save_dir=save_dir)
                if res["status"] == "ok":
                    mem = res["memory"]
                    print(f"  ok: compile {res['compile_s']:.1f}s, "
                          f"peak/device {mem['peak_bytes']/1e9:.2f} GB, "
                          f"collective wire {res['collectives']['total_wire_bytes']/1e6:.1f} MB",
                          flush=True)
                else:
                    print(f"  skipped: {res['reason']}", flush=True)
            except Exception as e:
                traceback.print_exc()
                failures.append((label, repr(e)))
                print(f"  FAIL: {e!r}", flush=True)

    print(f"\n{len(failures)} failures")
    for label, err in failures:
        print(f"  {label}: {err}")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
