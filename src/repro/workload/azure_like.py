"""Synthetic VM utilization population matched to the Azure trace analysis.

The 235 GB Azure Public Dataset is unavailable offline; this generator is
calibrated to the paper's §2.2 / Fig. 3 statistics and tested against them:

  - CoV (5-minute intervals) mixture: ~8% of VMs < 0.25, >50% > 0.4,
    ~30% > 1.0,
  - ~43% of VMs average below 10% CPU utilization,
  - variations on minutes-to-hours timescales (AR(1) + bursts).

Each VM trace is a mean-reverting log-AR(1) with Poisson bursts, rescaled
by a short fixed-point loop so the *clipped* series still hits the target
(mean, CoV).
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

INTERVAL_S = 300.0   # 5-minute readings, as in the Azure trace

# CoV bucket mixture (fractions sum to 1): [lo, hi): prob
_COV_BUCKETS = [
    ((0.02, 0.25), 0.08),
    ((0.25, 0.40), 0.42),
    ((0.40, 1.00), 0.20),
    ((1.00, 2.50), 0.30),
]


@dataclass
class VMTrace:
    util: np.ndarray          # (T,) utilization in [0, 1], 5-min interval
    target_mean: float
    target_cov: float

    @property
    def mean(self) -> float:
        return float(np.mean(self.util))

    @property
    def cov(self) -> float:
        m = max(self.mean, 1e-9)
        return float(np.std(self.util) / m)


def _draw_targets(rng: np.random.Generator) -> tuple:
    # mean utilization: lognormal-ish with ~43% below 0.10
    mean = float(np.clip(np.exp(rng.normal(np.log(0.13), 1.0)), 0.005, 0.9))
    u = rng.random()
    acc = 0.0
    for (lo, hi), p in _COV_BUCKETS:
        acc += p
        if u <= acc:
            return mean, float(rng.uniform(lo, hi))
    return mean, 0.5


def _gen_series(rng, n, mean, cov) -> np.ndarray:
    """AR(1) + bursts in log space, calibrated after clipping."""
    rho = 0.97                               # ~2.8h decorrelation at 5-min
    sigma = max(cov, 0.02)
    scale = 1.0
    for _ in range(4):                       # fixed-point on clipped stats
        eps = rng.normal(0, sigma * np.sqrt(1 - rho ** 2), n)
        x = np.zeros(n)
        for i in range(1, n):
            x[i] = rho * x[i - 1] + eps[i]
        # bursts: occasional multi-interval spikes (load surges)
        n_bursts = rng.poisson(n / 600)
        burst = np.zeros(n)
        for _ in range(n_bursts):
            s = rng.integers(0, n)
            ln = int(rng.integers(3, 24))
            burst[s:s + ln] += rng.uniform(1.0, 3.0) * sigma
        series = mean * scale * np.exp(x - 0.5 * sigma ** 2 + burst)
        series = np.clip(series, 0.0, 1.0)
        got_mean = series.mean()
        if abs(got_mean - mean) / max(mean, 1e-9) < 0.05:
            break
        scale *= mean / max(got_mean, 1e-9)
    return series


def sample_population(n_vms: int = 1000, days: int = 7,
                      seed: int = 0) -> list:
    rng = np.random.default_rng(seed)
    n = int(days * 24 * 3600 / INTERVAL_S)
    out = []
    for _ in range(n_vms):
        mean, cov = _draw_targets(rng)
        out.append(VMTrace(_gen_series(rng, n, mean, cov), mean, cov))
    return out


def _draw_targets_matrix(rng, n):
    """(n,)-vectorized `_draw_targets`: same mean distribution, same CoV
    bucket mixture (searchsorted over the cumulative bucket probs is the
    cumulative-acceptance loop)."""
    means = np.clip(np.exp(rng.normal(np.log(0.13), 1.0, n)), 0.005, 0.9)
    edges = np.cumsum([p for _, p in _COV_BUCKETS])
    b = np.minimum(np.searchsorted(edges, rng.random(n), side="left"),
                   len(_COV_BUCKETS) - 1)
    lo = np.array([rng_lo for (rng_lo, _), _ in _COV_BUCKETS])[b]
    hi = np.array([rng_hi for (_, rng_hi), _ in _COV_BUCKETS])[b]
    return means, rng.uniform(lo, hi)


def ar1_burst_factors(rng, T: int, sigma, rho: float = 0.97) -> np.ndarray:
    """(T, n) multiplicative AR(1)+burst modulation factors, mean ~1.

    The minutes-to-hours variability core shared by the Azure-like
    utilization generator below and the traffic arrival generator
    (`repro.traffic.arrivals`): a mean-reverting log-AR(1) with
    per-column volatility ``sigma`` plus Poisson multi-interval bursts,
    exponentiated with the -sigma^2/2 lognormal mean correction. Draw
    order (normal block, burst counts, starts, lens, amps) is part of
    the contract — `_gen_series_block` calls this inside its fixed-point
    loop and the calibration tests pin the resulting populations.
    """
    sigma = np.asarray(sigma, dtype=np.float64)
    n = sigma.size
    sig_eps = sigma * np.sqrt(1 - rho ** 2)
    eps = rng.normal(0.0, 1.0, (T, n)) * sig_eps
    x = np.zeros((T, n))
    for i in range(1, T):
        x[i] = rho * x[i - 1] + eps[i]
    # bursts via difference-array: +amp at start, -amp at end, cumsum
    counts = rng.poisson(T / 600, n)
    tot = int(counts.sum())
    vm = np.repeat(np.arange(n), counts)
    starts = rng.integers(0, T, tot)
    lens = rng.integers(3, 24, tot)
    amps = rng.uniform(1.0, 3.0, tot) * sigma[vm]
    bd = np.zeros((T + 1, n))
    np.add.at(bd, (starts, vm), amps)
    np.add.at(bd, (np.minimum(starts + lens, T), vm), -amps)
    burst = np.cumsum(bd[:-1], axis=0)
    return np.exp(x - 0.5 * sigma ** 2 + burst)


def _gen_series_block(rng, T, means, covs):
    """(T, n) block of AR(1)+burst series, vectorized over the VM axis.

    Statistically identical construction to `_gen_series` (same process
    parameters, same clipped fixed-point recalibration), but every
    per-VM Python loop is replaced by array ops: the AR(1) recursion
    runs over T (288 steps/day) instead of T*n, and bursts are scattered
    with a difference-array cumsum instead of per-burst slice writes.
    RNG draw *order* differs from the scalar generator, so individual
    traces differ for the same seed — the population statistics (what
    the Azure calibration tests pin) do not.
    """
    n = means.size
    sigma = np.maximum(covs, 0.02)                       # (n,)
    scale = np.ones(n)
    out = np.empty((T, n))
    done = np.zeros(n, dtype=bool)
    for _ in range(4):                       # fixed-point on clipped stats
        factors = ar1_burst_factors(rng, T, sigma)
        series = np.clip(means * scale * factors, 0.0, 1.0)
        fresh = ~done
        out[:, fresh] = series[:, fresh]
        got = series.mean(axis=0)
        done |= np.abs(got - means) / np.maximum(means, 1e-9) < 0.05
        if done.all():
            break
        scale = np.where(done, scale,
                         scale * means / np.maximum(got, 1e-9))
    return out


def sample_population_matrix(n_vms: int = 1000, days: int = 7,
                             seed: int = 0,
                             chunk: int = 20000) -> np.ndarray:
    """Vectorized `sample_population`: returns the (T, n_vms) demand
    matrix directly, generated in VM chunks so peak scratch stays a few
    (T, chunk) arrays regardless of fleet size. This is what makes the
    N=1M sweep's 100k-trace population feasible — the per-VM scalar
    generator walks ~T*n_vms*4 Python loop iterations (minutes at 100k
    VMs), the matrix path is pure array code (~seconds).
    """
    rng = np.random.default_rng(seed)
    T = int(days * 24 * 3600 / INTERVAL_S)
    out = np.empty((T, n_vms))
    for lo in range(0, n_vms, chunk):
        hi = min(lo + chunk, n_vms)
        means, covs = _draw_targets_matrix(rng, hi - lo)
        out[:, lo:hi] = _gen_series_block(rng, T, means, covs)
    return out


def population_stats(traces) -> dict:
    """Calibration stats for a population: a `sample_population` list of
    VMTrace or a `sample_population_matrix` (T, N) matrix."""
    if isinstance(traces, np.ndarray):
        means = traces.mean(axis=0)
        covs = traces.std(axis=0) / np.maximum(means, 1e-9)
    else:
        covs = np.array([t.cov for t in traces])
        means = np.array([t.mean for t in traces])
    return {
        "frac_cov_below_0.25": float((covs < 0.25).mean()),
        "frac_cov_above_0.4": float((covs > 0.4).mean()),
        "frac_cov_above_1.0": float((covs > 1.0).mean()),
        "frac_mean_below_0.10": float((means < 0.10).mean()),
    }
