"""Synthetic VM utilization population matched to the Azure trace analysis.

The 235 GB Azure Public Dataset is unavailable offline; this generator is
calibrated to the paper's §2.2 / Fig. 3 statistics and tested against them:

  - CoV (5-minute intervals) mixture: ~8% of VMs < 0.25, >50% > 0.4,
    ~30% > 1.0,
  - ~43% of VMs average below 10% CPU utilization,
  - variations on minutes-to-hours timescales (AR(1) + bursts).

Each VM trace is a mean-reverting log-AR(1) with Poisson bursts, rescaled
by a short fixed-point loop so the *clipped* series still hits the target
(mean, CoV).
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

INTERVAL_S = 300.0   # 5-minute readings, as in the Azure trace

# CoV bucket mixture (fractions sum to 1): [lo, hi): prob
_COV_BUCKETS = [
    ((0.02, 0.25), 0.08),
    ((0.25, 0.40), 0.42),
    ((0.40, 1.00), 0.20),
    ((1.00, 2.50), 0.30),
]


@dataclass
class VMTrace:
    util: np.ndarray          # (T,) utilization in [0, 1], 5-min interval
    target_mean: float
    target_cov: float

    @property
    def mean(self) -> float:
        return float(np.mean(self.util))

    @property
    def cov(self) -> float:
        m = max(self.mean, 1e-9)
        return float(np.std(self.util) / m)


def _draw_targets(rng: np.random.Generator) -> tuple:
    # mean utilization: lognormal-ish with ~43% below 0.10
    mean = float(np.clip(np.exp(rng.normal(np.log(0.13), 1.0)), 0.005, 0.9))
    u = rng.random()
    acc = 0.0
    for (lo, hi), p in _COV_BUCKETS:
        acc += p
        if u <= acc:
            return mean, float(rng.uniform(lo, hi))
    return mean, 0.5


def _gen_series(rng, n, mean, cov) -> np.ndarray:
    """AR(1) + bursts in log space, calibrated after clipping."""
    rho = 0.97                               # ~2.8h decorrelation at 5-min
    sigma = max(cov, 0.02)
    scale = 1.0
    for _ in range(4):                       # fixed-point on clipped stats
        eps = rng.normal(0, sigma * np.sqrt(1 - rho ** 2), n)
        x = np.zeros(n)
        for i in range(1, n):
            x[i] = rho * x[i - 1] + eps[i]
        # bursts: occasional multi-interval spikes (load surges)
        n_bursts = rng.poisson(n / 600)
        burst = np.zeros(n)
        for _ in range(n_bursts):
            s = rng.integers(0, n)
            ln = int(rng.integers(3, 24))
            burst[s:s + ln] += rng.uniform(1.0, 3.0) * sigma
        series = mean * scale * np.exp(x - 0.5 * sigma ** 2 + burst)
        series = np.clip(series, 0.0, 1.0)
        got_mean = series.mean()
        if abs(got_mean - mean) / max(mean, 1e-9) < 0.05:
            break
        scale *= mean / max(got_mean, 1e-9)
    return series


def sample_population(n_vms: int = 1000, days: int = 7,
                      seed: int = 0) -> list:
    rng = np.random.default_rng(seed)
    n = int(days * 24 * 3600 / INTERVAL_S)
    out = []
    for _ in range(n_vms):
        mean, cov = _draw_targets(rng)
        out.append(VMTrace(_gen_series(rng, n, mean, cov), mean, cov))
    return out


def population_stats(traces: list) -> dict:
    covs = np.array([t.cov for t in traces])
    means = np.array([t.mean for t in traces])
    return {
        "frac_cov_below_0.25": float((covs < 0.25).mean()),
        "frac_cov_above_0.4": float((covs > 0.4).mean()),
        "frac_cov_above_1.0": float((covs > 1.0).mean()),
        "frac_mean_below_0.10": float((means < 0.10).mean()),
    }
