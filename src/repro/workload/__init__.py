"""Workload-intensity traces (the Azure-trace substrate, synthesized)."""
from repro.workload.azure_like import VMTrace, sample_population
from repro.workload.replay import ReplayHarness

__all__ = ["VMTrace", "sample_population", "ReplayHarness"]
