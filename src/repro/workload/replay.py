"""Trace replay harness (the stress-ng role from the paper's §4.1).

Replays a utilization trace against any driver exposing
``apply_load(util) -> achieved_util``, and verifies tracking accuracy the
way the paper's Fig. 9 does (moving average within tolerance of target).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Sequence

import numpy as np


@dataclass
class ReplayHarness:
    interval_s: float = 300.0
    tolerance: float = 0.05       # moving-average tracking bound (Fig. 9)
    history: list = field(default_factory=list)

    def replay(self, trace: Sequence[float],
               apply_load: Callable[[float], float]) -> dict:
        achieved = []
        for u in trace:
            achieved.append(float(apply_load(float(u))))
        self.history.extend(achieved)
        if not achieved:
            # an empty trace tracks trivially (and the moving-average
            # kernel below would be 0-length)
            return {"mean_abs_err": 0.0, "ma_max_err": 0.0,
                    "within_tolerance": True, "achieved": achieved}
        tr = np.asarray(trace, dtype=np.float64)
        ac = np.asarray(achieved, dtype=np.float64)
        # moving average over 12 intervals (1 h at 5-min readings)
        k = min(12, len(ac))
        kern = np.ones(k) / k
        ma = np.convolve(ac, kern, mode="valid")
        ma_t = np.convolve(tr, kern, mode="valid")
        ma_max_err = float(np.max(np.abs(ma - ma_t))) if len(ma) else 0.0
        return {
            "mean_abs_err": float(np.mean(np.abs(ac - tr))),
            "ma_max_err": ma_max_err,
            "within_tolerance": ma_max_err <= self.tolerance,
            "achieved": achieved,
        }
