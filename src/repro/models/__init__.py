"""Model substrate: the 10 assigned architectures as composable JAX modules."""
from repro.models.api import get_model, Model

__all__ = ["get_model", "Model"]
