"""Whisper-style encoder-decoder backbone.

The conv/audio frontend is a STUB per the assignment: ``input_specs()``
supplies precomputed frame embeddings (B, enc_seq, d_model). Positions are
sinusoidal on both sides (deviation from Whisper's learned decoder
positions; noted in DESIGN.md). Projection biases are omitted (negligible).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models import layers as L
from repro.models.cache import encdec_cache_specs
from repro.models.params import ParamSpec, stack_specs
from repro.models.sharding import constrain
from repro.models.transformer import chunked_ce_loss, embed_tokens, maybe_remat, unembed


def enc_layer_specs(cfg: ModelConfig) -> dict:
    return {
        "ln1": L.norm_specs(cfg.d_model, cfg.norm_kind),
        "attn": L.attention_specs(cfg),
        "ln2": L.norm_specs(cfg.d_model, cfg.norm_kind),
        "mlp": L.mlp_specs(cfg),
    }


def dec_layer_specs(cfg: ModelConfig) -> dict:
    return {
        "ln1": L.norm_specs(cfg.d_model, cfg.norm_kind),
        "attn": L.attention_specs(cfg),
        "lnx": L.norm_specs(cfg.d_model, cfg.norm_kind),
        "xattn": L.attention_specs(cfg),
        "ln2": L.norm_specs(cfg.d_model, cfg.norm_kind),
        "mlp": L.mlp_specs(cfg),
    }


def specs(cfg: ModelConfig) -> dict:
    out = {
        "embed": ParamSpec((cfg.vocab_size, cfg.d_model), ("tp", "fsdp"), init="normal"),
        "enc_layers": stack_specs(cfg.n_enc_layers, enc_layer_specs(cfg)),
        "enc_norm": L.norm_specs(cfg.d_model, cfg.norm_kind),
        "dec_layers": stack_specs(cfg.n_layers, dec_layer_specs(cfg)),
        "final_norm": L.norm_specs(cfg.d_model, cfg.norm_kind),
    }
    if not cfg.tie_embeddings:
        out["unembed"] = ParamSpec((cfg.d_model, cfg.vocab_size), ("fsdp", "tp"),
                                   init="scaled")
    return out


def encode(cfg: ModelConfig, params: dict, frames: jax.Array,
           remat: str = "none") -> jax.Array:
    """frames (B, enc_seq, D) -> memory (B, enc_seq, D)."""
    S = frames.shape[1]
    pos = L.sinusoidal_positions(S, cfg.d_model).astype(cfg.dtype)
    x = frames.astype(cfg.dtype) + pos[None]
    x = constrain(x, ("batch", "seq", None))

    def body(x, lp):
        h = L.apply_norm(x, lp["ln1"], cfg.norm_eps)
        q, k, v = L.qkv_project(cfg, lp["attn"], h, None)
        o = L.attention(q, k, v, causal=False, impl=cfg.attn_impl)
        x = x + L.output_project(cfg, lp["attn"], o)
        x = x + L.mlp(L.apply_norm(x, lp["ln2"], cfg.norm_eps), lp["mlp"],
                      cfg.mlp_variant, jnp.dtype(cfg.dtype))
        return constrain(x, ("batch", "seq", None)), None

    enc = L.cast_tree(params["enc_layers"], cfg.dtype) if cfg.cast_weights else params["enc_layers"]
    x, _ = L.scan_layers(cfg, maybe_remat(body, remat), x, enc)
    return L.apply_norm(x, params["enc_norm"], cfg.norm_eps)


def _cross_attend(cfg, bp, x, memory=None, cached_kv=None):
    """Cross-attention: q from x, kv from encoder memory (or cache)."""
    h = L.apply_norm(x, bp["lnx"], cfg.norm_eps)
    dtype = h.dtype
    B, Sq = h.shape[0], h.shape[1]
    q = (h @ bp["xattn"]["wq"].astype(dtype)).reshape(B, Sq, cfg.n_heads, cfg.head_dim)
    if cached_kv is not None:
        k, v = cached_kv                                  # (B,Hkv,Senc,Dh)
        k, v = k.swapaxes(1, 2), v.swapaxes(1, 2)
    else:
        Se = memory.shape[1]
        k = (memory @ bp["xattn"]["wk"].astype(dtype)).reshape(B, Se, cfg.n_kv_heads, cfg.head_dim)
        v = (memory @ bp["xattn"]["wv"].astype(dtype)).reshape(B, Se, cfg.n_kv_heads, cfg.head_dim)
    o = L.attention(q, k, v, causal=False, impl=cfg.attn_impl)
    return x + L.output_project(cfg, {"wo": bp["xattn"]["wo"]}, o), (k, v)


def _decoder_embed(cfg, params, tokens, offset=0):
    B, S = tokens.shape
    x = embed_tokens(cfg, params, tokens)
    if isinstance(offset, int) and offset == 0 and S > 1:
        pos = L.sinusoidal_positions(S, cfg.d_model).astype(cfg.dtype)[None]
    else:
        # decode: single position `offset`
        full = L.sinusoidal_positions(1, cfg.d_model)  # placeholder row
        ang_pos = jnp.asarray(offset, jnp.float32)
        half = cfg.d_model // 2
        freqs = jnp.exp(-jnp.log(10000.0) * jnp.arange(half) / (half - 1))
        ang = ang_pos * freqs
        pos = jnp.concatenate([jnp.sin(ang), jnp.cos(ang)])[None, None].astype(cfg.dtype)
        del full
    return x + pos


def loss_fn(cfg: ModelConfig, params: dict, batch: dict, remat: str = "none"):
    memory = encode(cfg, params, batch["frames"], remat=remat)
    tokens = batch["tokens"]
    S = tokens.shape[1]
    x = _decoder_embed(cfg, params, tokens)
    positions = jnp.arange(S)

    def body(x, lp):
        h = L.apply_norm(x, lp["ln1"], cfg.norm_eps)
        q, k, v = L.qkv_project(cfg, lp["attn"], h, positions)
        o = L.attention(q, k, v, causal=True, impl=cfg.attn_impl)
        x = x + L.output_project(cfg, lp["attn"], o)
        x, _ = _cross_attend(cfg, lp, x, memory=memory)
        x = x + L.mlp(L.apply_norm(x, lp["ln2"], cfg.norm_eps), lp["mlp"],
                      cfg.mlp_variant, jnp.dtype(cfg.dtype))
        return constrain(x, L.residual_axes(cfg)), None

    dec = L.cast_tree(params["dec_layers"], cfg.dtype) if cfg.cast_weights else params["dec_layers"]
    x, _ = L.scan_layers(cfg, maybe_remat(body, remat), x, dec)
    x = L.apply_norm(x, params["final_norm"], cfg.norm_eps)
    loss = chunked_ce_loss(cfg, params, x, batch["labels"])
    return loss, {"ce_loss": loss}


def prefill(cfg: ModelConfig, params: dict, batch: dict,
            pad_to: int = 0):
    memory = encode(cfg, params, batch["frames"])
    tokens = batch["tokens"]
    B, S = tokens.shape
    x = _decoder_embed(cfg, params, tokens)
    positions = jnp.arange(S)

    def body(x, lp):
        h = L.apply_norm(x, lp["ln1"], cfg.norm_eps)
        q, k, v = L.qkv_project(cfg, lp["attn"], h, positions)
        o = L.attention(q, k, v, causal=True, impl=cfg.attn_impl)
        x = x + L.output_project(cfg, lp["attn"], o)
        x, (xk, xv) = _cross_attend(cfg, lp, x, memory=memory)
        x = x + L.mlp(L.apply_norm(x, lp["ln2"], cfg.norm_eps), lp["mlp"],
                      cfg.mlp_variant, jnp.dtype(cfg.dtype))
        x = constrain(x, ("batch", "seq", None))
        return x, (k.swapaxes(1, 2), v.swapaxes(1, 2),
                   xk.swapaxes(1, 2), xv.swapaxes(1, 2))

    dec = L.cast_tree(params["dec_layers"], cfg.dtype) if cfg.cast_weights else params["dec_layers"]
    x, (ck, cv, cxk, cxv) = L.scan_layers(cfg, body, x, dec)
    x = L.apply_norm(x, params["final_norm"], cfg.norm_eps)
    logits = unembed(cfg, params, x[:, -1:, :])[:, 0]
    if pad_to > S:
        pad = ((0, 0), (0, 0), (0, 0), (0, pad_to - S), (0, 0))
        ck, cv = jnp.pad(ck, pad), jnp.pad(cv, pad)
    axes = ("layers", "batch", None, "kv_seq", None)
    cache = {"k": constrain(ck, axes), "v": constrain(cv, axes),
             "ck": constrain(cxk, axes), "cv": constrain(cxv, axes),
             "pos": jnp.asarray(S, jnp.int32)}
    return logits, cache


def decode_step(cfg: ModelConfig, params: dict, cache: dict, tokens: jax.Array):
    pos = cache["pos"]
    x = _decoder_embed(cfg, params, tokens[:, None], offset=pos)

    def body(x, xs):
        lp, ck, cv, cxk, cxv = xs
        h = L.apply_norm(x, lp["ln1"], cfg.norm_eps)
        q, k, v = L.qkv_project(cfg, lp["attn"], h, None)
        ck = jax.lax.dynamic_update_slice(ck, k.swapaxes(1, 2).astype(ck.dtype),
                                          (0, 0, pos, 0))
        cv = jax.lax.dynamic_update_slice(cv, v.swapaxes(1, 2).astype(cv.dtype),
                                          (0, 0, pos, 0))
        o = L.attention(q, ck.swapaxes(1, 2), cv.swapaxes(1, 2), causal=True,
                        q_offset=pos, kv_len=pos + 1)
        x = x + L.output_project(cfg, lp["attn"], o)
        x, _ = _cross_attend(cfg, lp, x, cached_kv=(cxk, cxv))
        x = x + L.mlp(L.apply_norm(x, lp["ln2"], cfg.norm_eps), lp["mlp"],
                      cfg.mlp_variant, jnp.dtype(cfg.dtype))
        return x, (ck, cv)

    dec = L.cast_tree(params["dec_layers"], cfg.dtype) if cfg.cast_weights else params["dec_layers"]
    x, (ck, cv) = L.scan_layers(
        cfg, body, x, (dec, cache["k"], cache["v"],
                       cache["ck"], cache["cv"]), length=cfg.n_layers)
    x = L.apply_norm(x, params["final_norm"], cfg.norm_eps)
    logits = unembed(cfg, params, x)[:, 0]
    return logits, {"k": ck, "v": cv, "ck": cache["ck"], "cv": cache["cv"],
                    "pos": pos + 1}


def cache_specs(cfg: ModelConfig, batch: int, max_seq: int) -> dict:
    return encdec_cache_specs(cfg, batch, max_seq)
