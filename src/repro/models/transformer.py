"""Decoder-only transformer (dense + MoE families): train / prefill / decode.

Layers run under ``lax.scan`` over stacked parameters (small HLO, fast
compiles, natural remat boundary). The cross-entropy loss is sequence-chunked
with rematerialization so (B, S, vocab) logits are never resident at once —
required for the 200k/256k-vocab archs at train_4k scale.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.config import ModelConfig, MOE
from repro.models import layers as L
from repro.models import moe as MOE_MOD
from repro.models.cache import kv_cache_specs
from repro.models.params import ParamSpec, stack_specs
from repro.models.sharding import constrain


# ---------------------------------------------------------------------------
# Parameter specs
# ---------------------------------------------------------------------------

def layer_specs(cfg: ModelConfig) -> dict:
    out = {
        "ln1": L.norm_specs(cfg.d_model, cfg.norm_kind),
        "attn": L.attention_specs(cfg),
        "ln2": L.norm_specs(cfg.d_model, cfg.norm_kind),
    }
    if cfg.family == MOE:
        out["moe"] = MOE_MOD.moe_specs(cfg)
    else:
        out["mlp"] = L.mlp_specs(cfg)
    return out


def specs(cfg: ModelConfig) -> dict:
    out = {
        "embed": ParamSpec((cfg.vocab_size, cfg.d_model), ("tp", "fsdp"),
                           init="normal"),
        "final_norm": L.norm_specs(cfg.d_model, cfg.norm_kind),
        "layers": stack_specs(cfg.n_layers, layer_specs(cfg)),
    }
    if not cfg.tie_embeddings:
        out["unembed"] = ParamSpec((cfg.d_model, cfg.vocab_size),
                                   ("fsdp", "tp"), init="scaled")
    return out


# ---------------------------------------------------------------------------
# Shared pieces
# ---------------------------------------------------------------------------

def embed_tokens(cfg: ModelConfig, params: dict, tokens: jax.Array) -> jax.Array:
    x = jnp.take(params["embed"], tokens, axis=0).astype(cfg.dtype)
    return constrain(x, ("batch", "seq", None))


def unembed(cfg: ModelConfig, params: dict, x: jax.Array) -> jax.Array:
    dtype = jnp.dtype(cfg.dtype)
    if cfg.tie_embeddings:
        logits = x.astype(dtype) @ params["embed"].astype(dtype).T
    else:
        logits = x.astype(dtype) @ params["unembed"].astype(dtype)
    return constrain(logits, ("batch", "seq", "tp"))


def ffn(cfg: ModelConfig, lp: dict, h: jax.Array, group_axis: str = "seq"):
    if cfg.family == MOE:
        return MOE_MOD.moe_apply(cfg, lp["moe"], h, group_axis=group_axis)
    return L.mlp(h, lp["mlp"], cfg.mlp_variant, jnp.dtype(cfg.dtype)), {}


def _layer_body(cfg: ModelConfig, x, lp, positions, attn_fn, group_axis="seq"):
    h = L.apply_norm(x, lp["ln1"], cfg.norm_eps)
    q, k, v = L.qkv_project(cfg, lp["attn"], h, positions)
    o, kv_out = attn_fn(q, k, v)
    x = x + L.output_project(cfg, lp["attn"], o)
    h = L.apply_norm(x, lp["ln2"], cfg.norm_eps)
    y, aux = ffn(cfg, lp, h, group_axis)
    x = x + y
    x = constrain(x, ("batch", "seq", None))
    return x, kv_out, aux


def maybe_remat(fn, remat: str):
    if remat == "none":
        return fn
    if remat == "full":
        return jax.checkpoint(fn, policy=jax.checkpoint_policies.nothing_saveable)
    if remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims)
    raise ValueError(f"unknown remat policy {remat!r}")


# ---------------------------------------------------------------------------
# Train forward + chunked CE loss
# ---------------------------------------------------------------------------

def forward(cfg: ModelConfig, params: dict, tokens: jax.Array,
            remat: str = "none") -> jax.Array:
    """tokens (B,S) -> final hidden states (B,S,D) (pre-unembed)."""
    B, S = tokens.shape
    x = embed_tokens(cfg, params, tokens)
    positions = jnp.arange(S)

    def body(x, lp):
        def attn_fn(q, k, v):
            return L.attention(q, k, v, causal=True, impl=cfg.attn_impl), None
        x, _, aux = _layer_body(cfg, x, lp, positions, attn_fn)
        x = constrain(x, L.residual_axes(cfg))
        return x, aux.get("lb_loss", jnp.zeros((), jnp.float32))

    layers = L.cast_tree(params["layers"], cfg.dtype) if cfg.cast_weights else params["layers"]
    x, lb = L.scan_layers(cfg, maybe_remat(body, remat), x, layers)
    x = L.apply_norm(x, params["final_norm"], cfg.norm_eps)
    return x, lb.sum()


def chunked_ce_loss(cfg: ModelConfig, params: dict, x: jax.Array,
                    labels: jax.Array, block: int = 512) -> jax.Array:
    """Cross-entropy without materializing (B,S,V): scan + remat over S blocks."""
    B, S, D = x.shape
    block = min(block, S)
    if S % block:
        pad = block - S % block
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)), constant_values=-1)
        S = S + pad
    nb = S // block
    xb = x.reshape(B, nb, block, D).swapaxes(0, 1)        # (nb,B,block,D)
    lb = labels.reshape(B, nb, block).swapaxes(0, 1)

    @jax.checkpoint
    def blk(carry, inp):
        xs, ls = inp
        logits = unembed(cfg, params, xs).astype(jnp.float32)
        lse = jax.scipy.special.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(
            logits, jnp.maximum(ls, 0)[..., None], axis=-1)[..., 0]
        valid = (ls >= 0).astype(jnp.float32)
        nll_sum, n = carry
        return (nll_sum + ((lse - ll) * valid).sum(), n + valid.sum()), None

    (nll, n), _ = jax.lax.scan(blk, (jnp.zeros(()), jnp.zeros(())), (xb, lb),
                               unroll=nb if cfg.scan_unroll else 1)
    return nll / jnp.maximum(n, 1.0)


def loss_fn(cfg: ModelConfig, params: dict, batch: dict,
            remat: str = "none") -> tuple:
    x, lb_loss = forward(cfg, params, batch["tokens"], remat=remat)
    loss = chunked_ce_loss(cfg, params, x, batch["labels"])
    aux_coef = 0.01 if cfg.family == MOE else 0.0
    total = loss + aux_coef * lb_loss
    return total, {"ce_loss": loss, "lb_loss": lb_loss}


# ---------------------------------------------------------------------------
# Prefill / decode
# ---------------------------------------------------------------------------

def prefill(cfg: ModelConfig, params: dict, batch: dict,
            pad_to: int = 0) -> tuple:
    """Process full prompts; return (last-position logits (B,V), cache).

    ``pad_to``: total cache capacity (>= S) so subsequent decode steps have
    slots to write — decode at a full cache would clamp the update index.
    """
    tokens = batch["tokens"]
    B, S = tokens.shape
    x = embed_tokens(cfg, params, tokens)
    positions = jnp.arange(S)

    def body(x, lp):
        def attn_fn(q, k, v):
            o = L.attention(q, k, v, causal=True, impl=cfg.attn_impl)
            # cache layout (B, Hkv, S, Dh)
            return o, (k.swapaxes(1, 2), v.swapaxes(1, 2))
        x, kv, _ = _layer_body(cfg, x, lp, positions, attn_fn)
        return x, kv

    layers = L.cast_tree(params["layers"], cfg.dtype) if cfg.cast_weights else params["layers"]
    x, (ck, cv) = L.scan_layers(cfg, body, x, layers)
    x = L.apply_norm(x, params["final_norm"], cfg.norm_eps)
    logits = unembed(cfg, params, x[:, -1:, :])[:, 0]
    if pad_to > S:
        pad = ((0, 0), (0, 0), (0, 0), (0, pad_to - S), (0, 0))
        ck, cv = jnp.pad(ck, pad), jnp.pad(cv, pad)
    cache = {"k": constrain(ck, ("layers", "batch", None, "kv_seq", None)),
             "v": constrain(cv, ("layers", "batch", None, "kv_seq", None)),
             "pos": jnp.asarray(S, jnp.int32)}
    return logits, cache


def decode_step(cfg: ModelConfig, params: dict, cache: dict,
                tokens: jax.Array) -> tuple:
    """One decode step. tokens (B,) int32; returns (logits (B,V), new cache)."""
    B = tokens.shape[0]
    pos = cache["pos"]
    x = embed_tokens(cfg, params, tokens[:, None])
    positions = jnp.reshape(pos, (1,))

    def body(x, xs):
        lp, ck, cv = xs

        def attn_fn(q, k, v):
            k_t = k.swapaxes(1, 2)                        # (B,Hkv,1,Dh)
            v_t = v.swapaxes(1, 2)
            ck2 = jax.lax.dynamic_update_slice(ck, k_t.astype(ck.dtype), (0, 0, pos, 0))
            cv2 = jax.lax.dynamic_update_slice(cv, v_t.astype(cv.dtype), (0, 0, pos, 0))
            o = L.attention(q, ck2.swapaxes(1, 2), cv2.swapaxes(1, 2),
                            causal=True, q_offset=pos, kv_len=pos + 1)
            return o, (ck2, cv2)

        x, kv, _ = _layer_body(cfg, x, lp, positions, attn_fn, group_axis="batch")
        return x, kv

    layers = L.cast_tree(params["layers"], cfg.dtype) if cfg.cast_weights else params["layers"]
    x, (ck, cv) = L.scan_layers(cfg, body, x,
                                (layers, cache["k"], cache["v"]),
                                length=cfg.n_layers)
    x = L.apply_norm(x, params["final_norm"], cfg.norm_eps)
    logits = unembed(cfg, params, x)[:, 0]
    return logits, {"k": ck, "v": cv, "pos": pos + 1}


def cache_specs(cfg: ModelConfig, batch: int, max_seq: int) -> dict:
    return kv_cache_specs(cfg, batch, max_seq)
