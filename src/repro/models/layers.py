"""Shared layer primitives: norms, RoPE, MLP variants, attention dispatch."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.config import ModelConfig, SWIGLU, GEGLU, GELU
from repro.models.params import ParamSpec
from repro.models.sharding import constrain


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rmsnorm(x: jax.Array, w: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * (1.0 + w.astype(jnp.float32))
    return out.astype(x.dtype)


def layernorm(x: jax.Array, w: jax.Array, b: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps) * w.astype(jnp.float32) + b.astype(jnp.float32)
    return out.astype(x.dtype)


def norm_specs(d: int, kind: str = "rms") -> dict:
    if kind == "rms":
        return {"scale": ParamSpec((d,), (None,), init="zeros")}
    return {"scale": ParamSpec((d,), (None,), init="ones"),
            "bias": ParamSpec((d,), (None,), init="zeros")}


def apply_norm(x, p, eps):
    if "bias" in p:
        return layernorm(x, p["scale"], p["bias"], eps)
    return rmsnorm(x, p["scale"], eps)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope(x: jax.Array, positions: jax.Array, theta: float = 10_000.0) -> jax.Array:
    """x: (B, S, H, Dh), positions: (S,) or (B, S)."""
    B, S, H, Dh = x.shape
    half = Dh // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    if positions.ndim == 1:
        ang = positions.astype(jnp.float32)[None, :, None] * freqs[None, None, :]
    else:
        ang = positions.astype(jnp.float32)[:, :, None] * freqs[None, None, :]
    cos = jnp.cos(ang)[:, :, None, :]                    # (B,S,1,half)
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(seq: int, d: int) -> jax.Array:
    """Whisper-style fixed sinusoidal embeddings (S, d)."""
    half = d // 2
    freqs = jnp.exp(-jnp.log(10000.0) * jnp.arange(half) / (half - 1))
    ang = jnp.arange(seq)[:, None] * freqs[None, :]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------

def mlp_specs(cfg: ModelConfig, d: Optional[int] = None, f: Optional[int] = None) -> dict:
    d = d or cfg.d_model
    f = f or cfg.d_ff
    if cfg.mlp_variant in (SWIGLU, GEGLU):
        return {"wg": ParamSpec((d, f), ("fsdp", "tp"), init="scaled"),
                "wi": ParamSpec((d, f), ("fsdp", "tp"), init="scaled"),
                "wo": ParamSpec((f, d), ("tp", "fsdp"), init="scaled")}
    return {"wi": ParamSpec((d, f), ("fsdp", "tp"), init="scaled"),
            "wo": ParamSpec((f, d), ("tp", "fsdp"), init="scaled")}


def mlp(x: jax.Array, p: dict, variant: str, dtype) -> jax.Array:
    xc = x.astype(dtype)
    if variant == SWIGLU:
        h = jax.nn.silu(xc @ p["wg"].astype(dtype)) * (xc @ p["wi"].astype(dtype))
    elif variant == GEGLU:
        h = jax.nn.gelu(xc @ p["wg"].astype(dtype)) * (xc @ p["wi"].astype(dtype))
    elif variant == GELU:
        h = jax.nn.gelu(xc @ p["wi"].astype(dtype))
    else:
        raise ValueError(variant)
    h = constrain(h, ("batch", "seq", "tp"))
    return h @ p["wo"].astype(dtype)


# ---------------------------------------------------------------------------
# Attention block (projection + RoPE + kernel dispatch)
# ---------------------------------------------------------------------------

def attention_specs(cfg: ModelConfig) -> dict:
    d, hq, hkv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    out = {"wq": ParamSpec((d, hq * dh), ("fsdp", "tp"), init="scaled"),
           "wk": ParamSpec((d, hkv * dh), ("fsdp", "tp"), init="scaled"),
           "wv": ParamSpec((d, hkv * dh), ("fsdp", "tp"), init="scaled"),
           "wo": ParamSpec((hq * dh, d), ("tp", "fsdp"), init="scaled")}
    if cfg.qk_norm:
        out["qnorm"] = ParamSpec((dh,), (None,), init="zeros")
        out["knorm"] = ParamSpec((dh,), (None,), init="zeros")
    return out


def qkv_project(cfg: ModelConfig, p: dict, x: jax.Array, positions) -> tuple:
    """x: (B,S,D) -> q (B,S,Hq,Dh), k,v (B,S,Hkv,Dh), RoPE applied."""
    B, S, _ = x.shape
    dh = cfg.head_dim
    dtype = x.dtype
    q = (x @ p["wq"].astype(dtype)).reshape(B, S, cfg.n_heads, dh)
    k = (x @ p["wk"].astype(dtype)).reshape(B, S, cfg.n_kv_heads, dh)
    v = (x @ p["wv"].astype(dtype)).reshape(B, S, cfg.n_kv_heads, dh)
    if cfg.qk_norm:
        q = rmsnorm(q, p["qnorm"], cfg.norm_eps)
        k = rmsnorm(k, p["knorm"], cfg.norm_eps)
    if cfg.use_rope and positions is not None:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    q = constrain(q, ("batch", "seq", "tp", None))
    k = constrain(k, ("batch", "seq", "tp", None))
    v = constrain(v, ("batch", "seq", "tp", None))
    return q, k, v


def attention(q, k, v, *, causal=True, window=0, q_offset=0, kv_len=None,
              kv_positions=None, impl: str = "auto") -> jax.Array:
    """Dispatch to the Pallas flash kernel (TPU) or the chunked/ref path."""
    from repro.kernels import ops
    return ops.mha(q, k, v, causal=causal, window=window, q_offset=q_offset,
                   kv_len=kv_len, kv_positions=kv_positions, impl=impl)


def cast_tree(tree, dtype):
    """Cast float leaves to `dtype` *while still sharded* — inside the layer
    scan GSPMD would all-gather the f32 masters and cast after (2x wire)."""
    import jax.numpy as jnp
    dt = jnp.dtype(dtype)
    return jax.tree.map(
        lambda x: x.astype(dt) if jnp.issubdtype(x.dtype, jnp.floating) else x,
        tree)


def residual_axes(cfg: ModelConfig) -> tuple:
    """Logical axes of the residual stream between layers (train path)."""
    return ("batch", "sp" if cfg.seq_shard else "seq", None)


def scan_layers(cfg: ModelConfig, body, init, xs, length: Optional[int] = None):
    """lax.scan over stacked layers; fully unrolled when cfg.scan_unroll.

    Unrolling removes the HLO ``while`` so cost_analysis counts every layer
    (used by the dry-run's marginal-flops probes); production lowering keeps
    the rolled scan for small HLO and fast compiles.
    """
    if length is None:
        length = jax.tree.leaves(xs)[0].shape[0]
    unroll = length if cfg.scan_unroll else 1
    return jax.lax.scan(body, init, xs, unroll=unroll)


def output_project(cfg: ModelConfig, p: dict, o: jax.Array) -> jax.Array:
    B, S = o.shape[0], o.shape[1]
    o = o.reshape(B, S, cfg.n_heads * cfg.head_dim)
    return o @ p["wo"].astype(o.dtype)
