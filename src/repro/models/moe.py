"""Top-k MoE with capacity-bounded dispatch + dense grouped matmuls.

Two execution paths with identical semantics:

- **Local path** (no mesh / single device / tests): scatter-based dispatch in
  plain jnp.

- **Expert-parallel shard_map path** (production meshes): GSPMD cannot shard
  computed-index scatters (it replicates the dispatch buffers — hundreds of
  GB/device at dbrx scale), so on a mesh the whole FFN block runs under
  shard_map: each (data, model) shard routes its *local* tokens, keeps only
  the experts its model-shard owns, all-gathers the layer's expert weights
  over the FSDP ("data") axis in bf16, computes the dense grouped matmul
  locally, and combines with a psum over "model" (the EP-combine; an
  explicit all-to-all would halve this wire cost — see EXPERIMENTS §Perf).

Capacity is per (token-shard × expert) on the mesh path, per (sequence ×
expert) on the local path; overflow drops tokens (the residual connection
carries them).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.config import ModelConfig, SWIGLU, GEGLU
from repro.models.params import ParamSpec
from repro.models.sharding import _current_mesh, logical_to_pspec


def moe_specs(cfg: ModelConfig) -> dict:
    D, F, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    return {
        "router": ParamSpec((D, E), ("fsdp", None), init="scaled"),
        "wg": ParamSpec((E, D, F), ("expert", "fsdp", None), init="scaled"),
        "wi": ParamSpec((E, D, F), ("expert", "fsdp", None), init="scaled"),
        "wo": ParamSpec((E, F, D), ("expert", None, "fsdp"), init="scaled"),
    }


def _capacity(tokens: int, k: int, n_experts: int, cf: float) -> int:
    return max(int(tokens * k * cf / n_experts) + 1, k)


def _route(cfg: ModelConfig, router, x_flat):
    """x_flat (T, D) -> (weights (T,k), ids (T,k), probs (T,E)).

    bf16 matmul with f32 accumulation: casting x_flat itself to f32 would
    materialize a (T, D) f32 copy (GBs at dbrx scale)."""
    logits = jnp.matmul(x_flat, router.astype(x_flat.dtype),
                        preferred_element_type=jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    weights, ids = jax.lax.top_k(probs, cfg.top_k)
    weights = weights / jnp.maximum(weights.sum(-1, keepdims=True), 1e-9)
    return weights, ids, probs


def _expert_ffn(cfg: ModelConfig, buf, wg, wi, wo, dtype):
    """buf (E, C, D) x weights (E, D, F)/(E, F, D) -> (E, C, D)."""
    if cfg.mlp_variant in (SWIGLU, GEGLU):
        act = jax.nn.silu if cfg.mlp_variant == SWIGLU else jax.nn.gelu
        h = act(jnp.einsum("ecd,edf->ecf", buf, wg)) * jnp.einsum(
            "ecd,edf->ecf", buf, wi)
    else:
        h = jax.nn.gelu(jnp.einsum("ecd,edf->ecf", buf, wi))
    return jnp.einsum("ecf,efd->ecd", h, wo)


def _dispatch_combine_local(cfg, x_flat, ids, weights, e0, n_local, capacity,
                            ffn):
    """Scatter local tokens into per-expert buffers, run ffn, gather back.

    x_flat (T, D); ids/weights (T, k); experts [e0, e0+n_local) are local.
    Returns y (T, D) — contributions of *local* experts only.

    Dispatch/combine iterate over the k routing choices (k is small and
    static) so no (T*k, D) token copy is ever materialized, and every
    intermediate stays in the activation dtype (a single f32 promotion here
    costs GBs/device at dbrx scale).
    """
    T, D = x_flat.shape
    k = cfg.top_k
    dtype = x_flat.dtype
    local = (ids >= e0) & (ids < e0 + n_local)            # (T, k)
    e_loc = jnp.where(local, ids - e0, 0)
    # slot within expert: rank among local assignments (order: k-major)
    oh = jax.nn.one_hot(jnp.where(local, e_loc, n_local), n_local + 1,
                        dtype=jnp.int32).reshape(T * k, n_local + 1)
    slot = jnp.sum((jnp.cumsum(oh, axis=0) - oh) * oh, axis=-1).reshape(T, k)
    keep = local & (slot < capacity)
    slot_c = jnp.minimum(slot, capacity - 1)

    buf = jnp.zeros((n_local, capacity, D), dtype)
    for j in range(k):                                    # no (T*k, D) copies
        contrib = jnp.where(keep[:, j, None], x_flat, 0)
        buf = buf.at[e_loc[:, j], slot_c[:, j]].add(contrib)

    out_buf = ffn(buf)                                    # (n_local, C, D)

    y = jnp.zeros((T, D), dtype)
    for j in range(k):
        w_j = jnp.where(keep[:, j], weights[:, j], 0.0).astype(dtype)
        y = y + out_buf[e_loc[:, j], slot_c[:, j]] * w_j[:, None]
    drop_frac = 1.0 - keep.sum() / jnp.maximum(local.sum(), 1)
    return y, drop_frac


def _moe_mesh_path(cfg: ModelConfig, p: dict, x: jax.Array, mesh) -> tuple:
    B, S, D = x.shape
    E = cfg.n_experts
    dtype = x.dtype
    batch_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    n_batch = 1
    for a in batch_axes:
        n_batch *= mesh.shape[a]
    n_model = mesh.shape["model"]
    if B % n_batch or E % n_model or D % mesh.shape.get("data", 1):
        return _moe_local_path(cfg, p, x)                 # fall back (smoke)
    E_loc = E // n_model
    T_loc = (B // n_batch) * S
    capacity = _capacity(T_loc, cfg.top_k, E, cfg.capacity_factor)

    x_spec = P(batch_axes if len(batch_axes) > 1 else batch_axes[0], None, None)
    wg_spec = logical_to_pspec(("expert", "fsdp", None), p["wg"].shape, mesh)
    wo_spec = logical_to_pspec(("expert", None, "fsdp"), p["wo"].shape, mesh)

    def inner(x_loc, router, wg, wi, wo):
        Bl, Sl, _ = x_loc.shape
        x_flat = x_loc.reshape(Bl * Sl, D)
        weights, ids, probs = _route(cfg, router, x_flat)

        # aux load-balance loss (global via pmean)
        me = probs.mean(axis=0)
        ce = jnp.zeros((E,), jnp.float32).at[ids.reshape(-1)].add(
            1.0 / (x_flat.shape[0] * cfg.top_k))
        lb = E * jnp.sum(me * ce)
        lb = jax.lax.pmean(lb, batch_axes + ("model",))

        # FSDP: unshard this layer's expert weights over "data" (bf16 wire)
        if "data" in mesh.axis_names and mesh.shape["data"] > 1:
            wg_f = jax.lax.all_gather(wg.astype(dtype), "data", axis=1, tiled=True)
            wi_f = jax.lax.all_gather(wi.astype(dtype), "data", axis=1, tiled=True)
            wo_f = jax.lax.all_gather(wo.astype(dtype), "data", axis=2, tiled=True)
        else:
            wg_f, wi_f, wo_f = (w.astype(dtype) for w in (wg, wi, wo))

        e0 = jax.lax.axis_index("model") * E_loc
        ffn = lambda buf: _expert_ffn(cfg, buf, wg_f, wi_f, wo_f, dtype)
        y, drop = _dispatch_combine_local(cfg, x_flat, ids, weights, e0,
                                          E_loc, capacity, ffn)
        y = jax.lax.psum(y, "model")                      # EP combine
        drop = jax.lax.pmean(drop, batch_axes + ("model",))
        return y.reshape(Bl, Sl, D), lb, drop

    y, lb, drop = shard_map(
        inner, mesh=mesh,
        in_specs=(x_spec, P(None, None), wg_spec, wg_spec, wo_spec),
        out_specs=(x_spec, P(), P()),
        check_rep=False,
    )(x, p["router"], p["wg"], p["wi"], p["wo"])
    return y, {"lb_loss": lb, "router_dropped": drop}


def _moe_local_path(cfg: ModelConfig, p: dict, x: jax.Array) -> tuple:
    B, S, D = x.shape
    E = cfg.n_experts
    dtype = x.dtype
    x_flat = x.reshape(B * S, D)
    weights, ids, probs = _route(cfg, p["router"], x_flat)
    me = probs.mean(axis=0)
    ce = jnp.zeros((E,), jnp.float32).at[ids.reshape(-1)].add(
        1.0 / (B * S * cfg.top_k))
    lb = E * jnp.sum(me * ce)
    capacity = _capacity(B * S, cfg.top_k, E, cfg.capacity_factor)
    ffn = lambda buf: _expert_ffn(cfg, buf, p["wg"].astype(dtype),
                                  p["wi"].astype(dtype), p["wo"].astype(dtype),
                                  dtype)
    y, drop = _dispatch_combine_local(cfg, x_flat, ids, weights, 0, E,
                                      capacity, ffn)
    return y.reshape(B, S, D), {"lb_loss": lb, "router_dropped": drop}


def moe_apply(cfg: ModelConfig, p: dict, x: jax.Array, *,
              group_axis: str = "seq") -> tuple:
    """x: (B, S, D) -> (y (B, S, D), aux metrics). group_axis kept for API
    compatibility; capacity grouping is per token-shard on mesh."""
    del group_axis
    mesh = _current_mesh()
    if mesh is not None and "model" in mesh.axis_names:
        return _moe_mesh_path(cfg, p, x, mesh)
    return _moe_local_path(cfg, p, x)
