"""Mamba-2 (SSD / state-space duality) model: train / prefill / decode.

Block: in_proj -> [z | xBC | dt]; causal depthwise conv over xBC; SSD over
heads (chunked scan / Pallas kernel); gated RMSNorm; out_proj. Decode keeps
O(1) state per layer: conv history (W-1 steps) + SSD state (H, P, N).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.kernels import ops
from repro.models import layers as L
from repro.models.cache import ssm_cache_specs
from repro.models.params import ParamSpec, stack_specs
from repro.models.sharding import constrain
from repro.models.transformer import (
    chunked_ce_loss, embed_tokens, maybe_remat, unembed)


def _dims(cfg: ModelConfig):
    di = cfg.d_inner
    gn = cfg.ssm_n_groups * cfg.ssm_state
    conv_dim = di + 2 * gn
    h = cfg.ssm_n_heads
    return di, gn, conv_dim, h


def layer_specs(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    di, gn, conv_dim, h = _dims(cfg)
    return {
        "ln": L.norm_specs(d),
        "in_proj": ParamSpec((d, 2 * di + 2 * gn + h), ("fsdp", "tp"), init="scaled"),
        "conv_w": ParamSpec((cfg.ssm_conv_width, conv_dim), (None, "tp"), init="normal", scale=0.1),
        "conv_b": ParamSpec((conv_dim,), ("tp",), init="zeros"),
        "a_log": ParamSpec((h,), ("tp",), init="ssm_a"),
        "d_skip": ParamSpec((h,), ("tp",), init="ones"),
        "dt_bias": ParamSpec((h,), ("tp",), init="zeros"),
        "gnorm": ParamSpec((di,), ("tp",), init="zeros"),
        "out_proj": ParamSpec((di, d), ("tp", "fsdp"), init="scaled"),
    }


def specs(cfg: ModelConfig) -> dict:
    out = {
        "embed": ParamSpec((cfg.vocab_size, cfg.d_model), ("tp", "fsdp"), init="normal"),
        "final_norm": L.norm_specs(cfg.d_model),
        "layers": stack_specs(cfg.n_layers, layer_specs(cfg)),
    }
    if not cfg.tie_embeddings:
        out["unembed"] = ParamSpec((cfg.d_model, cfg.vocab_size), ("fsdp", "tp"),
                                   init="scaled")
    return out


def _gated_norm(y: jax.Array, z: jax.Array, w: jax.Array, eps: float) -> jax.Array:
    """RMSNormGated: rmsnorm(y * silu(z))."""
    return L.rmsnorm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype), w, eps)


def _mixer_seq(cfg: ModelConfig, lp: dict, x: jax.Array,
               conv_state=None, ssm_state=None):
    """Full-sequence mixer. x (B,S,D) -> (y (B,S,D), conv_state', ssm_state')."""
    B, S, D = x.shape
    di, gn, conv_dim, H = _dims(cfg)
    dtype = x.dtype
    proj = x @ lp["in_proj"].astype(dtype)               # (B,S,2di+2gn+h)
    z, xbc, dt_raw = jnp.split(proj, [di, di + conv_dim], axis=-1)
    xbc = constrain(xbc, ("batch", "seq", "tp"))
    xc, conv_state_new = ops.causal_conv1d(xbc, lp["conv_w"], lp["conv_b"], conv_state)
    xc = jax.nn.silu(xc.astype(jnp.float32)).astype(dtype)
    xs, b, c = jnp.split(xc, [di, di + gn], axis=-1)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + lp["dt_bias"].astype(jnp.float32))
    xh = xs.reshape(B, S, H, cfg.ssm_head_dim)
    bg = b.reshape(B, S, cfg.ssm_n_groups, cfg.ssm_state)
    cg = c.reshape(B, S, cfg.ssm_n_groups, cfg.ssm_state)
    y, ssm_state_new = ops.ssd(xh, dt, lp["a_log"], bg, cg, lp["d_skip"],
                               h0=ssm_state, chunk=cfg.ssm_chunk)
    y = y.reshape(B, S, di)
    y = _gated_norm(y, z, lp["gnorm"], cfg.norm_eps)
    return y @ lp["out_proj"].astype(dtype), conv_state_new, ssm_state_new


def _mixer_step(cfg: ModelConfig, lp: dict, x: jax.Array, conv_state, ssm_state):
    """Single-token mixer. x (B,D); states carried."""
    B, D = x.shape
    di, gn, conv_dim, H = _dims(cfg)
    dtype = x.dtype
    proj = x @ lp["in_proj"].astype(dtype)
    z, xbc, dt_raw = jnp.split(proj, [di, di + conv_dim], axis=-1)
    xc, conv_state = ops.conv1d_decode_step(xbc, lp["conv_w"], lp["conv_b"], conv_state)
    xc = jax.nn.silu(xc.astype(jnp.float32)).astype(dtype)
    xs, b, c = jnp.split(xc, [di, di + gn], axis=-1)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + lp["dt_bias"].astype(jnp.float32))
    xh = xs.reshape(B, H, cfg.ssm_head_dim)
    bg = b.reshape(B, cfg.ssm_n_groups, cfg.ssm_state)
    cg = c.reshape(B, cfg.ssm_n_groups, cfg.ssm_state)
    y, ssm_state = ops.ssd_decode_step(xh, dt, lp["a_log"], bg, cg, lp["d_skip"],
                                       ssm_state)
    y = y.reshape(B, di)
    y = _gated_norm(y[:, None, :], z[:, None, :], lp["gnorm"], cfg.norm_eps)[:, 0]
    return y @ lp["out_proj"].astype(dtype), conv_state, ssm_state


# ---------------------------------------------------------------------------
# Train / prefill / decode
# ---------------------------------------------------------------------------

def forward(cfg: ModelConfig, params: dict, tokens: jax.Array,
            remat: str = "none"):
    x = embed_tokens(cfg, params, tokens)

    def body(x, lp):
        h = L.apply_norm(x, lp["ln"], cfg.norm_eps)
        y, _, _ = _mixer_seq(cfg, lp, h)
        x = constrain(x + y, L.residual_axes(cfg))
        return x, jnp.zeros((), jnp.float32)

    layers = L.cast_tree(params["layers"], cfg.dtype) if cfg.cast_weights else params["layers"]
    x, _ = L.scan_layers(cfg, maybe_remat(body, remat), x, layers)
    x = L.apply_norm(x, params["final_norm"], cfg.norm_eps)
    return x, jnp.zeros((), jnp.float32)


def loss_fn(cfg: ModelConfig, params: dict, batch: dict, remat: str = "none"):
    x, _ = forward(cfg, params, batch["tokens"], remat=remat)
    loss = chunked_ce_loss(cfg, params, x, batch["labels"])
    return loss, {"ce_loss": loss}


def prefill(cfg: ModelConfig, params: dict, batch: dict,
            pad_to: int = 0):  # state is O(1): pad_to unused
    tokens = batch["tokens"]
    B, S = tokens.shape
    x = embed_tokens(cfg, params, tokens)

    def body(x, lp):
        h = L.apply_norm(x, lp["ln"], cfg.norm_eps)
        y, conv_s, ssm_s = _mixer_seq(cfg, lp, h)
        x = constrain(x + y, ("batch", "seq", None))
        return x, (conv_s, ssm_s)

    layers = L.cast_tree(params["layers"], cfg.dtype) if cfg.cast_weights else params["layers"]
    x, (conv_s, ssm_s) = L.scan_layers(cfg, body, x, layers)
    x = L.apply_norm(x, params["final_norm"], cfg.norm_eps)
    logits = unembed(cfg, params, x[:, -1:, :])[:, 0]
    cache = {"conv": constrain(conv_s, ("layers", "batch", None, "tp")),
             "ssm": constrain(ssm_s, ("layers", "batch", "tp", None, None)),
             "pos": jnp.asarray(S, jnp.int32)}
    return logits, cache


def decode_step(cfg: ModelConfig, params: dict, cache: dict, tokens: jax.Array):
    x = embed_tokens(cfg, params, tokens[:, None])[:, 0]  # (B,D)

    def body(x, xs):
        lp, conv_s, ssm_s = xs
        h = L.apply_norm(x, lp["ln"], cfg.norm_eps)
        y, conv_s, ssm_s = _mixer_step(cfg, lp, h, conv_s, ssm_s)
        return x + y, (conv_s, ssm_s)

    layers = L.cast_tree(params["layers"], cfg.dtype) if cfg.cast_weights else params["layers"]
    x, (conv_s, ssm_s) = L.scan_layers(
        cfg, body, x, (layers, cache["conv"], cache["ssm"]),
        length=cfg.n_layers)
    x = L.apply_norm(x, params["final_norm"], cfg.norm_eps)
    logits = unembed(cfg, params, x[:, None, :])[:, 0]
    return logits, {"conv": conv_s, "ssm": ssm_s, "pos": cache["pos"] + 1}


def cache_specs(cfg: ModelConfig, batch: int, max_seq: int) -> dict:
    del max_seq  # O(1) state
    return ssm_cache_specs(cfg, batch)
