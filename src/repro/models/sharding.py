"""Logical-axis sharding rules (MaxText-style) with divisibility-aware lowering.

Tensors are annotated with *logical* axes; ``logical_to_pspec`` maps them onto
the physical mesh, silently dropping any mesh axis that does not evenly divide
the corresponding dimension (jit in/out shardings require divisibility). This
keeps one rule table valid across all 10 archs × 4 shapes × 2 meshes.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# logical axis -> tuple of mesh axes (in order of preference)
RULES: dict[str, tuple[str, ...]] = {
    "batch": ("pod", "data"),   # data parallel (pod is pure-DP outer axis)
    "fsdp": ("data",),          # weight d_model dim: fully-sharded data parallel
    "tp": ("model",),           # tensor parallel: heads/ff/vocab/experts
    "expert": ("model",),       # expert parallel (MoE)
    "kv_seq": ("model",),       # decode KV-cache sequence dim (flash-decoding)
    "seq": (),                  # sequence: unsharded
    "sp": ("model",),           # Megatron-style sequence parallelism (residual
                                # stream between layers; gathered at attn/mlp)
    "layers": (),               # scan axis: never sharded
    None: (),
}


_TLS = threading.local()


@contextlib.contextmanager
def rules_ctx(overrides: Optional[dict]):
    """Remap logical axes for everything traced inside (constrain() included).

    The hillclimbing lever: e.g. {"tp": (), "fsdp": (), "batch":
    ("pod","data","model")} re-lowers a model pure-DP without touching
    model code.
    """
    prev = getattr(_TLS, "overrides", None)
    _TLS.overrides = dict(overrides) if overrides else None
    try:
        yield
    finally:
        _TLS.overrides = prev


def _ctx_overrides() -> Optional[dict]:
    return getattr(_TLS, "overrides", None)


def _mesh_axes_present(mesh: Mesh, axes: Sequence[str]) -> tuple[str, ...]:
    return tuple(a for a in axes if a in mesh.axis_names)


def logical_to_pspec(logical: Sequence[Optional[str]], shape: Sequence[int],
                     mesh: Mesh, overrides: Optional[dict] = None) -> P:
    """Map logical axes to a PartitionSpec valid for ``shape`` on ``mesh``."""
    rules = dict(RULES)
    ctx = _ctx_overrides()
    if ctx:
        rules.update(ctx)
    if overrides:
        rules.update(overrides)
    assert len(logical) == len(shape), (logical, shape)
    used: set[str] = set()
    spec: list = []
    for name, dim in zip(logical, shape):
        axes = _mesh_axes_present(mesh, rules.get(name, ()))
        axes = tuple(a for a in axes if a not in used)
        # drop trailing mesh axes until the shard product divides the dim
        while axes:
            prod = 1
            for a in axes:
                prod *= mesh.shape[a]
            if prod and dim % prod == 0 and dim > 0:
                break
            axes = axes[:-1]
        if axes:
            used.update(axes)
            spec.append(axes if len(axes) > 1 else axes[0])
        else:
            spec.append(None)
    return P(*spec)


def named_sharding(logical: Sequence[Optional[str]], shape: Sequence[int],
                   mesh: Mesh, overrides: Optional[dict] = None) -> NamedSharding:
    return NamedSharding(mesh, logical_to_pspec(logical, shape, mesh, overrides))


def constrain(x: jax.Array, logical: Sequence[Optional[str]],
              mesh: Optional[Mesh] = None) -> jax.Array:
    """with_sharding_constraint by logical axes; no-op outside a mesh context."""
    mesh = mesh or _current_mesh()
    if mesh is None or mesh.empty:
        return x
    spec = logical_to_pspec(logical, x.shape, mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def _current_mesh() -> Optional[Mesh]:
    try:
        from jax._src.mesh import thread_resources
        env_mesh = thread_resources.env.physical_mesh
        return None if env_mesh.empty else env_mesh
    except Exception:
        return None


def tree_pspecs(axes_tree, shape_tree, mesh: Mesh):
    """Map a tree of logical-axes tuples + matching shapes -> PartitionSpecs."""
    return jax.tree.map(
        lambda ax, sh: logical_to_pspec(ax, sh, mesh),
        axes_tree, shape_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(isinstance(a, (str, type(None))) for a in x),
    )
