"""ParamSpec trees: one declaration drives init, abstract shapes, and sharding.

Each module declares its parameters as a nested dict of ``ParamSpec`` leaves.
From that single tree we derive:
  - ``init_params``      real arrays (deterministic per-path RNG folding)
  - ``abstract_params``  ShapeDtypeStructs (dry-run: no allocation)
  - ``param_pspecs``     PartitionSpecs via the logical-axis rules
"""
from __future__ import annotations

import dataclasses
import zlib
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.sharding import logical_to_pspec


@dataclass(frozen=True)
class ParamSpec:
    shape: tuple
    axes: tuple                    # logical axes, len == len(shape)
    init: str = "normal"           # normal | zeros | ones | scaled(fan_in) | ssm_a | conv
    scale: float = 0.02
    dtype: str = "float32"

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def stack_specs(n: int, tree):
    """Prepend a scanned 'layers' axis of size n to every spec in the tree."""
    def f(s: ParamSpec) -> ParamSpec:
        return dataclasses.replace(s, shape=(n,) + s.shape, axes=("layers",) + s.axes)
    return jax.tree.map(f, tree, is_leaf=is_spec)


def _init_leaf(spec: ParamSpec, key: jax.Array) -> jax.Array:
    dtype = jnp.dtype(spec.dtype)
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, dtype)
    if spec.init == "ones":
        return jnp.ones(spec.shape, dtype)
    if spec.init == "ssm_a":  # mamba2 A_log: log uniform [1, 16)
        u = jax.random.uniform(key, spec.shape, jnp.float32, 1.0, 16.0)
        return jnp.log(u).astype(dtype)
    if spec.init == "lru_lambda":  # RG-LRU Λ: so that a^c ~ uniform(0.9, 0.999)
        u = jax.random.uniform(key, spec.shape, jnp.float32, 0.9, 0.999)
        # a = exp(-c*softplus(L)) with c=8 -> softplus(L) = -log(u)/8
        sp = -jnp.log(u) / 8.0
        return jnp.log(jnp.expm1(sp)).astype(dtype)
    if spec.init == "scaled":  # normal / sqrt(fan_in); fan_in = shape[-2]
        fan_in = spec.shape[-2] if len(spec.shape) >= 2 else spec.shape[-1]
        return (jax.random.normal(key, spec.shape, jnp.float32) / np.sqrt(fan_in)).astype(dtype)
    if spec.init == "normal":
        return (jax.random.normal(key, spec.shape, jnp.float32) * spec.scale).astype(dtype)
    raise ValueError(f"unknown init {spec.init!r}")


def _path_str(path) -> str:
    out = []
    for p in path:
        if hasattr(p, "key"):
            out.append(str(p.key))
        elif hasattr(p, "idx"):
            out.append(str(p.idx))
        else:
            out.append(str(p))
    return "/".join(out)


def init_params(spec_tree, key: jax.Array):
    """Materialize a ParamSpec tree (per-path deterministic fold_in)."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(spec_tree, is_leaf=is_spec)
    leaves = []
    for path, spec in flat:
        # stable per-path salt (str hash() is salted per process)
        salt = zlib.crc32(_path_str(path).encode()) % (2**31)
        sub = jax.random.fold_in(key, salt)
        leaves.append(_init_leaf(spec, sub))
    return jax.tree_util.tree_unflatten(treedef, leaves)


def abstract_params(spec_tree):
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, jnp.dtype(s.dtype)),
        spec_tree, is_leaf=is_spec)


def param_pspecs(spec_tree, mesh, overrides=None):
    return jax.tree.map(
        lambda s: logical_to_pspec(s.axes, s.shape, mesh, overrides),
        spec_tree, is_leaf=is_spec)


def param_shardings(spec_tree, mesh, overrides=None):
    from jax.sharding import NamedSharding
    return jax.tree.map(
        lambda s: NamedSharding(mesh, logical_to_pspec(s.axes, s.shape, mesh,
                                                       overrides)),
        spec_tree, is_leaf=is_spec)


def param_count_tree(spec_tree) -> int:
    total = 0
    for s in jax.tree.leaves(spec_tree, is_leaf=is_spec):
        n = 1
        for d in s.shape:
            n *= d
        total += n
    return total
