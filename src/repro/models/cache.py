"""Decode-state descriptors (KV caches / SSM states) as ParamSpec trees.

Caches reuse the ParamSpec machinery so abstract shapes (dry-run) and
PartitionSpecs come from the same declaration as real allocation.

KV caches are laid out (L, B, Hkv, Smax, Dh) with the *sequence* dim sharded
on the "model" axis ("kv_seq" rule) — the flash-decoding pattern: each model
shard holds a slice of history, decode attention does partial-softmax +
all-reduce of (B,Hq) stats instead of replicating the cache.
"""
from __future__ import annotations

from repro.config import ModelConfig
from repro.models.params import ParamSpec


def kv_cache_specs(cfg: ModelConfig, batch: int, max_seq: int,
                   n_layers: int = 0) -> dict:
    L = n_layers or cfg.n_layers
    kv_shape = (L, batch, cfg.n_kv_heads, max_seq, cfg.head_dim)
    kv_axes = ("layers", "batch", None, "kv_seq", None)
    return {
        "k": ParamSpec(kv_shape, kv_axes, init="zeros", dtype=cfg.dtype),
        "v": ParamSpec(kv_shape, kv_axes, init="zeros", dtype=cfg.dtype),
        "pos": ParamSpec((), (), init="zeros", dtype="int32"),
    }


def ssm_cache_specs(cfg: ModelConfig, batch: int) -> dict:
    L = cfg.n_layers
    conv_dim = cfg.d_inner + 2 * cfg.ssm_n_groups * cfg.ssm_state
    return {
        "conv": ParamSpec((L, batch, cfg.ssm_conv_width - 1, conv_dim),
                          ("layers", "batch", None, "tp"), init="zeros", dtype=cfg.dtype),
        "ssm": ParamSpec((L, batch, cfg.ssm_n_heads, cfg.ssm_head_dim, cfg.ssm_state),
                         ("layers", "batch", "tp", None, None), init="zeros",
                         dtype="float32"),
        "pos": ParamSpec((), (), init="zeros", dtype="int32"),
    }


def hybrid_cache_specs(cfg: ModelConfig, batch: int) -> dict:
    """RecurrentGemma: 12 scanned (rec,rec,attn) superlayers + 2 trailing rec."""
    n_super = cfg.n_layers // len(cfg.block_pattern)
    n_trail = cfg.n_layers - n_super * len(cfg.block_pattern)
    w = min(cfg.local_window, 1 << 30)
    lw, cw = cfg.lru_width, cfg.conv_width
    def rec_state(n):
        return {
            "h": ParamSpec((n, batch, lw), ("layers", "batch", "tp"),
                           init="zeros", dtype="float32"),
            "conv": ParamSpec((n, batch, cw - 1, lw), ("layers", "batch", None, "tp"),
                              init="zeros", dtype=cfg.dtype),
        }
    out = {
        "super": {
            "rec1": rec_state(n_super),
            "rec2": rec_state(n_super),
            "k": ParamSpec((n_super, batch, cfg.n_kv_heads, w, cfg.head_dim),
                           ("layers", "batch", None, "kv_seq", None),
                           init="zeros", dtype=cfg.dtype),
            "v": ParamSpec((n_super, batch, cfg.n_kv_heads, w, cfg.head_dim),
                           ("layers", "batch", None, "kv_seq", None),
                           init="zeros", dtype=cfg.dtype),
        },
        "pos": ParamSpec((), (), init="zeros", dtype="int32"),
    }
    if n_trail:
        out["trail"] = rec_state(n_trail)
    return out


def encdec_cache_specs(cfg: ModelConfig, batch: int, max_seq: int) -> dict:
    """Whisper: decoder self-attn cache + encoder cross-attn KV."""
    L = cfg.n_layers
    self_shape = (L, batch, cfg.n_kv_heads, max_seq, cfg.head_dim)
    cross_shape = (L, batch, cfg.n_kv_heads, cfg.enc_seq, cfg.head_dim)
    axes = ("layers", "batch", None, "kv_seq", None)
    return {
        "k": ParamSpec(self_shape, axes, init="zeros", dtype=cfg.dtype),
        "v": ParamSpec(self_shape, axes, init="zeros", dtype=cfg.dtype),
        "ck": ParamSpec(cross_shape, axes, init="zeros", dtype=cfg.dtype),
        "cv": ParamSpec(cross_shape, axes, init="zeros", dtype=cfg.dtype),
        "pos": ParamSpec((), (), init="zeros", dtype="int32"),
    }
