"""Unified model facade: family dispatch + input specs per assigned shape.

``Model`` wraps a family module behind one interface used by the train loop,
the serve engine, the dry-run launcher, and the benchmarks:

    m = get_model(cfg)
    params = m.init(key)                     # or m.abstract() for dry-runs
    loss, metrics = m.loss(params, batch)
    logits, cache = m.prefill(params, batch)
    logits, cache = m.decode(params, cache, tokens)
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp

from repro.config import (DECODE, ENCDEC, HYBRID, MOE, PREFILL, SSM, TRAIN,
                          ModelConfig, ShapeConfig)
from repro.models import encdec, mamba2, rglru, transformer
from repro.models import params as PT
from repro.models.sharding import logical_to_pspec

_FAMILY_MODULES = {
    "dense": transformer,
    MOE: transformer,
    SSM: mamba2,
    HYBRID: rglru,
    ENCDEC: encdec,
}


@dataclass(frozen=True)
class Model:
    cfg: ModelConfig

    @property
    def mod(self):
        return _FAMILY_MODULES[self.cfg.family]

    # -- parameters ---------------------------------------------------------
    def specs(self):
        return self.mod.specs(self.cfg)

    def init(self, key: jax.Array):
        return PT.init_params(self.specs(), key)

    def abstract(self):
        return PT.abstract_params(self.specs())

    def pspecs(self, mesh, overrides=None):
        return PT.param_pspecs(self.specs(), mesh, overrides)

    def shardings(self, mesh, overrides=None):
        return PT.param_shardings(self.specs(), mesh, overrides)

    def param_count(self) -> int:
        return PT.param_count_tree(self.specs())

    # -- compute ------------------------------------------------------------
    def loss(self, params, batch, remat: str = "none"):
        return self.mod.loss_fn(self.cfg, params, batch, remat=remat)

    def prefill(self, params, batch, pad_to: int = 0):
        return self.mod.prefill(self.cfg, params, batch, pad_to=pad_to)

    def decode(self, params, cache, tokens):
        return self.mod.decode_step(self.cfg, params, cache, tokens)

    # -- caches --------------------------------------------------------------
    def cache_specs(self, batch: int, max_seq: int):
        return self.mod.cache_specs(self.cfg, batch, max_seq)

    def abstract_cache(self, batch: int, max_seq: int):
        return PT.abstract_params(self.cache_specs(batch, max_seq))

    def init_cache(self, batch: int, max_seq: int, key: Optional[jax.Array] = None):
        key = key if key is not None else jax.random.PRNGKey(0)
        return PT.init_params(self.cache_specs(batch, max_seq), key)

    def cache_pspecs(self, batch: int, max_seq: int, mesh, overrides=None):
        return PT.param_pspecs(self.cache_specs(batch, max_seq), mesh, overrides)

    def cache_shardings(self, batch: int, max_seq: int, mesh, overrides=None):
        return PT.param_shardings(self.cache_specs(batch, max_seq), mesh,
                                  overrides)

    # -- inputs ---------------------------------------------------------------
    def input_specs(self, shape: ShapeConfig) -> dict:
        """ShapeDtypeStruct stand-ins for every model input of this shape."""
        B, S = shape.global_batch, shape.seq_len
        tok = lambda *sh: jax.ShapeDtypeStruct(sh, jnp.int32)
        if shape.kind == TRAIN:
            out = {"tokens": tok(B, S), "labels": tok(B, S)}
        elif shape.kind == PREFILL:
            out = {"tokens": tok(B, S)}
        elif shape.kind == DECODE:
            out = {"tokens": tok(B)}
        else:
            raise ValueError(shape.kind)
        if self.cfg.family == ENCDEC and shape.kind in (TRAIN, PREFILL):
            out["frames"] = jax.ShapeDtypeStruct(
                (B, self.cfg.enc_seq, self.cfg.d_model), jnp.dtype(self.cfg.dtype))
        return out

    def input_axes(self, shape: ShapeConfig) -> dict:
        if shape.kind == TRAIN:
            out = {"tokens": ("batch", "seq"), "labels": ("batch", "seq")}
        elif shape.kind == PREFILL:
            out = {"tokens": ("batch", "seq")}
        else:
            out = {"tokens": ("batch",)}
        if self.cfg.family == ENCDEC and shape.kind in (TRAIN, PREFILL):
            out["frames"] = ("batch", "seq", None)
        return out

    def input_pspecs(self, shape: ShapeConfig, mesh, overrides=None) -> dict:
        specs = self.input_specs(shape)
        axes = self.input_axes(shape)
        return {k: logical_to_pspec(axes[k], specs[k].shape, mesh, overrides)
                for k in specs}


def get_model(cfg: ModelConfig) -> Model:
    return Model(cfg)
