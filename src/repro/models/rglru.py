"""RecurrentGemma / Griffin hybrid: RG-LRU recurrent blocks + local attention.

Layer pattern (rec, rec, attn) scanned as "superlayers" (12 for the 9B) plus
trailing rec layers (2 for the 9B: 38 = 12*3 + 2). Every temporal-mixing
block is followed by its own GeGLU MLP residual block (Griffin structure).

Decode state is O(1) in sequence length: RG-LRU hidden + conv history per
recurrent block, and a ring-buffer KV cache of `local_window` per attention
block — this is why the arch runs the long_500k shape.
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.kernels import ops
from repro.models import layers as L
from repro.models.cache import hybrid_cache_specs
from repro.models.params import ParamSpec, stack_specs
from repro.models.sharding import constrain
from repro.models.transformer import chunked_ce_loss, embed_tokens, maybe_remat, unembed


def _counts(cfg: ModelConfig) -> tuple:
    n_super = cfg.n_layers // len(cfg.block_pattern)
    n_trail = cfg.n_layers - n_super * len(cfg.block_pattern)
    return n_super, n_trail


def rec_block_specs(cfg: ModelConfig) -> dict:
    d, lw, w = cfg.d_model, cfg.lru_width, cfg.conv_width
    return {
        "ln1": L.norm_specs(d),
        "wx": ParamSpec((d, lw), ("fsdp", "tp"), init="scaled"),
        "wy": ParamSpec((d, lw), ("fsdp", "tp"), init="scaled"),
        "conv_w": ParamSpec((w, lw), (None, "tp"), init="normal", scale=0.1),
        "conv_b": ParamSpec((lw,), ("tp",), init="zeros"),
        "wr": ParamSpec((lw, lw), ("fsdp", "tp"), init="scaled"),
        "br": ParamSpec((lw,), ("tp",), init="zeros"),
        "wi": ParamSpec((lw, lw), ("fsdp", "tp"), init="scaled"),
        "bi": ParamSpec((lw,), ("tp",), init="zeros"),
        "lam": ParamSpec((lw,), ("tp",), init="lru_lambda"),
        "wo": ParamSpec((lw, d), ("tp", "fsdp"), init="scaled"),
        "ln2": L.norm_specs(d),
        "mlp": L.mlp_specs(cfg),
    }


def attn_block_specs(cfg: ModelConfig) -> dict:
    return {
        "ln1": L.norm_specs(cfg.d_model),
        "attn": L.attention_specs(cfg),
        "ln2": L.norm_specs(cfg.d_model),
        "mlp": L.mlp_specs(cfg),
    }


def specs(cfg: ModelConfig) -> dict:
    n_super, n_trail = _counts(cfg)
    super_specs = {
        "rec1": rec_block_specs(cfg),
        "rec2": rec_block_specs(cfg),
        "attn": attn_block_specs(cfg),
    }
    out = {
        "embed": ParamSpec((cfg.vocab_size, cfg.d_model), ("tp", "fsdp"), init="normal"),
        "final_norm": L.norm_specs(cfg.d_model),
        "super": stack_specs(n_super, super_specs),
    }
    if n_trail:
        out["trail"] = stack_specs(n_trail, rec_block_specs(cfg))
    if not cfg.tie_embeddings:
        out["unembed"] = ParamSpec((cfg.d_model, cfg.vocab_size), ("fsdp", "tp"),
                                   init="scaled")
    return out


# ---------------------------------------------------------------------------
# Blocks (sequence mode)
# ---------------------------------------------------------------------------

def rec_block_seq(cfg: ModelConfig, bp: dict, x: jax.Array, state=None):
    dtype = x.dtype
    h = L.apply_norm(x, bp["ln1"], cfg.norm_eps)
    u = h @ bp["wx"].astype(dtype)
    gate = jax.nn.gelu((h @ bp["wy"].astype(dtype)).astype(jnp.float32)).astype(dtype)
    u = constrain(u, ("batch", "seq", "tp"))
    conv_in = state["conv"] if state else None
    h_in = state["h"] if state else None
    uc, conv_state = ops.causal_conv1d(u, bp["conv_w"], bp["conv_b"], conv_in)
    r = uc @ bp["wr"].astype(dtype) + bp["br"].astype(dtype)
    i = uc @ bp["wi"].astype(dtype) + bp["bi"].astype(dtype)
    hs, h_last = ops.rglru(uc, r, i, bp["lam"], h0=h_in)
    out = (hs * gate) @ bp["wo"].astype(dtype)
    x = x + out
    x = x + L.mlp(L.apply_norm(x, bp["ln2"], cfg.norm_eps), bp["mlp"],
                  cfg.mlp_variant, dtype)
    x = constrain(x, ("batch", "seq", None))
    return x, {"h": h_last, "conv": conv_state}


def attn_block_seq(cfg: ModelConfig, bp: dict, x: jax.Array, positions,
                   want_cache: bool = False):
    dtype = x.dtype
    h = L.apply_norm(x, bp["ln1"], cfg.norm_eps)
    q, k, v = L.qkv_project(cfg, bp["attn"], h, positions)
    o = L.attention(q, k, v, causal=True, window=cfg.local_window, impl=cfg.attn_impl)
    x = x + L.output_project(cfg, bp["attn"], o)
    x = x + L.mlp(L.apply_norm(x, bp["ln2"], cfg.norm_eps), bp["mlp"],
                  cfg.mlp_variant, dtype)
    x = constrain(x, ("batch", "seq", None))
    if not want_cache:
        return x, None
    # ring cache: slot(p) = p % W holds the last W positions
    B, S = x.shape[0], k.shape[1]
    W = cfg.local_window
    kt, vt = k.swapaxes(1, 2), v.swapaxes(1, 2)           # (B,Hkv,S,Dh)
    start = max(0, S - W)
    slots = np.arange(start, S) % W
    ck = jnp.zeros((B, cfg.n_kv_heads, W, cfg.head_dim), dtype)
    cv = jnp.zeros_like(ck)
    ck = ck.at[:, :, slots].set(kt[:, :, start:S])
    cv = cv.at[:, :, slots].set(vt[:, :, start:S])
    return x, (ck, cv)


# ---------------------------------------------------------------------------
# Blocks (single-token decode mode)
# ---------------------------------------------------------------------------

def rec_block_step(cfg: ModelConfig, bp: dict, x: jax.Array, state: dict):
    dtype = x.dtype
    h = L.apply_norm(x[:, None, :], bp["ln1"], cfg.norm_eps)[:, 0]
    u = h @ bp["wx"].astype(dtype)
    gate = jax.nn.gelu((h @ bp["wy"].astype(dtype)).astype(jnp.float32)).astype(dtype)
    uc, conv_state = ops.conv1d_decode_step(u, bp["conv_w"], bp["conv_b"], state["conv"])
    r = uc @ bp["wr"].astype(dtype) + bp["br"].astype(dtype)
    i = uc @ bp["wi"].astype(dtype) + bp["bi"].astype(dtype)
    hs, h_new = ops.rglru_decode_step(uc, r, i, bp["lam"], state["h"])
    x = x + (hs * gate) @ bp["wo"].astype(dtype)
    x = x + L.mlp(L.apply_norm(x[:, None, :], bp["ln2"], cfg.norm_eps), bp["mlp"],
                  cfg.mlp_variant, dtype)[:, 0]
    return x, {"h": h_new, "conv": conv_state}


def attn_block_step(cfg: ModelConfig, bp: dict, x: jax.Array, ck, cv, pos):
    dtype = x.dtype
    W = cfg.local_window
    h = L.apply_norm(x[:, None, :], bp["ln1"], cfg.norm_eps)
    q, k, v = L.qkv_project(cfg, bp["attn"], h, jnp.reshape(pos, (1,)))
    slot = pos % W
    ck = jax.lax.dynamic_update_slice(ck, k.swapaxes(1, 2).astype(ck.dtype),
                                      (0, 0, slot, 0))
    cv = jax.lax.dynamic_update_slice(cv, v.swapaxes(1, 2).astype(cv.dtype),
                                      (0, 0, slot, 0))
    # absolute position held by each ring slot (unwritten slots -> future)
    s = jnp.arange(W)
    kv_pos = pos - ((pos - s) % W)
    kv_pos = jnp.where(kv_pos >= 0, kv_pos, pos + 1)
    o = L.attention(q, ck.swapaxes(1, 2), cv.swapaxes(1, 2), causal=True,
                    q_offset=pos, kv_positions=kv_pos)
    x = x + L.output_project(cfg, bp["attn"], o)[:, 0]
    x = x + L.mlp(L.apply_norm(x[:, None, :], bp["ln2"], cfg.norm_eps), bp["mlp"],
                  cfg.mlp_variant, dtype)[:, 0]
    return x, (ck, cv)


# ---------------------------------------------------------------------------
# Train / prefill / decode
# ---------------------------------------------------------------------------

def forward(cfg: ModelConfig, params: dict, tokens: jax.Array, remat: str = "none"):
    B, S = tokens.shape
    x = embed_tokens(cfg, params, tokens)
    positions = jnp.arange(S)

    def super_body(x, lp):
        x, _ = rec_block_seq(cfg, lp["rec1"], x)
        x, _ = rec_block_seq(cfg, lp["rec2"], x)
        x, _ = attn_block_seq(cfg, lp["attn"], x, positions)
        x = constrain(x, L.residual_axes(cfg))
        return x, jnp.zeros((), jnp.float32)

    def trail_body(x, lp):
        x, _ = rec_block_seq(cfg, lp, x)
        x = constrain(x, L.residual_axes(cfg))
        return x, jnp.zeros((), jnp.float32)

    sup = L.cast_tree(params["super"], cfg.dtype) if cfg.cast_weights else params["super"]
    x, _ = L.scan_layers(cfg, maybe_remat(super_body, remat), x, sup)
    if "trail" in params:
        tr = L.cast_tree(params["trail"], cfg.dtype) if cfg.cast_weights else params["trail"]
        x, _ = L.scan_layers(cfg, maybe_remat(trail_body, remat), x, tr)
    x = L.apply_norm(x, params["final_norm"], cfg.norm_eps)
    return x, jnp.zeros((), jnp.float32)


def loss_fn(cfg: ModelConfig, params: dict, batch: dict, remat: str = "none"):
    x, _ = forward(cfg, params, batch["tokens"], remat=remat)
    loss = chunked_ce_loss(cfg, params, x, batch["labels"])
    return loss, {"ce_loss": loss}


def prefill(cfg: ModelConfig, params: dict, batch: dict,
            pad_to: int = 0):  # state is O(1): pad_to unused
    tokens = batch["tokens"]
    B, S = tokens.shape
    x = embed_tokens(cfg, params, tokens)
    positions = jnp.arange(S)

    def super_body(x, lp):
        x, s1 = rec_block_seq(cfg, lp["rec1"], x)
        x, s2 = rec_block_seq(cfg, lp["rec2"], x)
        x, kv = attn_block_seq(cfg, lp["attn"], x, positions, want_cache=True)
        return x, (s1, s2, kv)

    def trail_body(x, lp):
        x, s = rec_block_seq(cfg, lp, x)
        return x, s

    sup = L.cast_tree(params["super"], cfg.dtype) if cfg.cast_weights else params["super"]
    x, (s1, s2, (ck, cv)) = L.scan_layers(cfg, super_body, x, sup)
    cache = {"super": {"rec1": s1, "rec2": s2,
                       "k": constrain(ck, ("layers", "batch", None, "kv_seq", None)),
                       "v": constrain(cv, ("layers", "batch", None, "kv_seq", None))},
             "pos": jnp.asarray(S, jnp.int32)}
    if "trail" in params:
        tr = L.cast_tree(params["trail"], cfg.dtype) if cfg.cast_weights else params["trail"]
        x, st = L.scan_layers(cfg, trail_body, x, tr)
        cache["trail"] = st
    x = L.apply_norm(x, params["final_norm"], cfg.norm_eps)
    logits = unembed(cfg, params, x[:, -1:, :])[:, 0]
    return logits, cache


def decode_step(cfg: ModelConfig, params: dict, cache: dict, tokens: jax.Array):
    pos = cache["pos"]
    x = embed_tokens(cfg, params, tokens[:, None])[:, 0]

    def super_body(x, xs):
        lp, s1, s2, ck, cv = xs
        x, s1 = rec_block_step(cfg, lp["rec1"], x, s1)
        x, s2 = rec_block_step(cfg, lp["rec2"], x, s2)
        x, (ck, cv) = attn_block_step(cfg, lp["attn"], x, ck, cv, pos)
        return x, (s1, s2, ck, cv)

    def trail_body(x, xs):
        lp, s = xs
        x, s = rec_block_step(cfg, lp, x, s)
        return x, s

    sc = cache["super"]
    n_super, _ = _counts(cfg)
    sup = L.cast_tree(params["super"], cfg.dtype) if cfg.cast_weights else params["super"]
    x, (s1, s2, ck, cv) = L.scan_layers(
        cfg, super_body, x,
        (sup, sc["rec1"], sc["rec2"], sc["k"], sc["v"]),
        length=n_super)
    out_cache = {"super": {"rec1": s1, "rec2": s2, "k": ck, "v": cv},
                 "pos": pos + 1}
    if "trail" in params:
        tr2 = L.cast_tree(params["trail"], cfg.dtype) if cfg.cast_weights else params["trail"]
        x, st = L.scan_layers(cfg, trail_body, x,
                              (tr2, cache["trail"]),
                              length=_counts(cfg)[1])
        out_cache["trail"] = st
    x = L.apply_norm(x, params["final_norm"], cfg.norm_eps)
    logits = unembed(cfg, params, x[:, None, :])[:, 0]
    return logits, out_cache


def cache_specs(cfg: ModelConfig, batch: int, max_seq: int) -> dict:
    del max_seq  # O(1)-in-seq state (window-bounded KV)
    return hybrid_cache_specs(cfg, batch)
