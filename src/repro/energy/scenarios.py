"""Scenario stress matrix: named supply/fleet stress cells over the sweep.

Each cell is a named scenario — fleet churn (arrivals/departures), grid
outages, correlated intensity shocks, migration failures injected
through `repro.distributed.fault`, straggler-delayed suspend/resume via
`repro.distributed.stragglers`, demand bursts replayed through
`repro.workload.replay`, and signal-plane faults (telemetry blackout,
flapping carbon feed, migration storms) injected through
`repro.robustness` — executed as one `SweepSpec` sweep with the
virtual energy supply enabled, on both array backends, with invariant
checks:

  - energy conservation: solar_used + battery + grid == supplied
    (max per-epoch error <= 1e-6 W);
  - zero virtual-cap violations (demand never draws past the supply);
  - battery state of charge within [0, capacity];
  - fleet <-> jax parity <= 1e-6 on every aggregate row metric,
    including the energy accounting.

Every scenario reuses the same solar/battery configuration and the
same array shapes, so the jax backend compiles its scan once and the
whole matrix replays through it; scenario variation lives entirely in
the event tensors and the demand shaping.

Run with `make scenarios` (or `python -m repro.energy.scenarios`);
exits non-zero if any invariant fails. `tests/test_scenarios.py` runs
the same matrix at small shapes as a parameterized table in the fast
lane.
"""
from __future__ import annotations

import argparse
import sys
from dataclasses import dataclass, field
from typing import Callable, Optional

import numpy as np

from repro.cluster.placement import PlacementConfig
from repro.cluster.slices import paper_family
from repro.core.policy import CarbonAgnosticPolicy, CarbonContainerPolicy
from repro.core.simulator import SimConfig
from repro.core.spec import SweepSpec, SweepResult
from repro.energy.supply import EnergyConfig, GridEventConfig
from repro.robustness import (CarbonFeedFaults, FaultPlan, MigrationFaults,
                              PowerTelemetryFaults)

CONSERVATION_TOL_W = 1e-6
PARITY_TOL = 1e-6


@dataclass
class Scenario:
    """One stress cell: an event layer plus optional demand shaping.

    `shape_demand(traces, interval_s)` returns the stressed (T, n)
    demand matrix (and may record scenario metadata in `meta`).
    `faults` (a `repro.robustness.FaultPlan`) additionally degrades the
    signal plane — stale/missing carbon telemetry, power-meter gaps,
    failed migrations — through the sweep's fault injection."""
    name: str
    description: str
    energy: EnergyConfig
    shape_demand: Optional[Callable] = None
    meta: dict = field(default_factory=dict)
    faults: Optional[object] = None


# ---------------------------------------------------------------------------
# Demand-shaping stressors (each drives one dormant subsystem)
# ---------------------------------------------------------------------------

def churn_mask(T: int, n: int, seed: int = 11) -> np.ndarray:
    """Fleet churn: a third of the fleet arrives late, a third departs
    early (containers outside their [arrival, departure) window demand
    nothing)."""
    rng = np.random.default_rng(seed)
    arrive = np.zeros(n, dtype=int)
    depart = np.full(n, T, dtype=int)
    late = rng.choice(n, size=n // 3, replace=False)
    arrive[late] = rng.integers(1, max(2, T // 4), size=late.size)
    rest = np.setdiff1d(np.arange(n), late)
    early = rng.choice(rest, size=n // 3, replace=False)
    depart[early] = rng.integers(3 * T // 4, T, size=early.size)
    t = np.arange(T)[:, None]
    return ((t >= arrive[None, :]) & (t < depart[None, :])).astype(float)


def failure_mask(T: int, n: int, interval_s: float,
                 n_hosts: int = 8) -> tuple:
    """Migration failures via `repro.distributed.fault`: hosts die on the
    `FailureInjector` schedule and stop heartbeating; the clock-injected
    `HeartbeatMonitor` flags them after its timeout, at which point the
    checkpoint-restore path brings their containers back (elastic
    recovery). Containers on a dead host serve nothing from the failure
    until one epoch after detection. Returns (mask, meta)."""
    from repro.distributed.fault import FailureInjector, HeartbeatMonitor
    hosts = [f"h{i}" for i in range(n_hosts)]
    host_of = np.arange(n) % n_hosts
    injector = FailureInjector(schedule={T // 3: 2, (2 * T) // 3: 1})
    now = [0.0]
    monitor = HeartbeatMonitor(timeout_s=2.5 * interval_s,
                               clock=lambda: now[0])
    mask = np.ones((T, n))
    live = list(hosts)
    pending: dict = {}                      # host -> failure epoch
    episodes: list = []
    for t in range(T):
        now[0] = t * interval_s
        lost = injector.check(t)
        if lost:
            for h in live[-lost:]:
                pending[h] = t
            live = live[:-lost]
        for h in live:
            monitor.beat(h)
        # a pending host serves nothing this epoch (including the
        # detection epoch — restore lands at its end)
        for h in pending:
            mask[t, host_of == hosts.index(h)] = 0.0
        for h in monitor.dead_hosts():
            if h in pending:                # detected: checkpoint restore
                episodes.append({"host": h, "failed_at": pending.pop(h),
                                 "detected_at": t})
                live.append(h)
    meta = {"failed_at": {e["host"]: e["failed_at"] for e in episodes},
            "detected_at": {e["host"]: e["detected_at"] for e in episodes},
            "detect_delay_epochs": {e["host"]: e["detected_at"]
                                    - e["failed_at"] for e in episodes},
            "episodes": episodes}
    return mask, meta


def straggler_mask(T: int, n: int, seed: int = 13) -> tuple:
    """Straggler-delayed suspend/resume via `repro.distributed.stragglers`:
    one container's synchronous steps slow by `factor` mid-trace, cutting
    its served demand to 1/factor until the `StragglerDetector` fires
    "migrate" (the mitigation path), after which it runs at full speed
    on the new slice. Returns (mask, meta)."""
    from repro.distributed.stragglers import StragglerDetector
    rng = np.random.default_rng(seed)
    base = np.clip(rng.normal(1.0, 0.03, size=T), 0.9, 1.1)
    onset, factor, col = T // 3, 2.6, 0
    det = StragglerDetector()
    mask = np.ones((T, n))
    migrated_at = None
    for t in range(T):
        slow = migrated_at is None and t >= onset
        act = det.observe(base[t] * (factor if slow else 1.0))
        if slow:
            mask[t, col] = 1.0 / factor
            if act == "migrate":
                migrated_at = t
    meta = {"onset": onset, "migrated_at": migrated_at,
            "straggle_epochs": (migrated_at - onset + 1
                                if migrated_at is not None else T - onset)}
    return mask, meta


def burst_profile(T: int, interval_s: float) -> tuple:
    """Demand burst replayed through `repro.workload.replay`: a midday
    burst multiplier is driven through the `ReplayHarness` against a
    quantized actuator (1/64 duty steps) and the *achieved* profile is
    what stresses the fleet — the harness verifies the tracking bound
    on the way. Returns (multiplier (T,), meta)."""
    from repro.workload.replay import ReplayHarness
    t = np.arange(T)
    target = 1.0 + 1.2 * np.exp(-((t - 0.55 * T) / (0.04 * T + 1e-9)) ** 2)
    harness = ReplayHarness(interval_s=interval_s, tolerance=0.05)
    rep = harness.replay(target, lambda u: round(u * 64.0) / 64.0)
    meta = {"ma_max_err": rep["ma_max_err"],
            "within_tolerance": rep["within_tolerance"]}
    return np.asarray(rep["achieved"]), meta


# ---------------------------------------------------------------------------
# The matrix
# ---------------------------------------------------------------------------

def build_matrix(T: int, interval_s: float = 300.0) -> list:
    """The named scenario cells (shared solar/battery; events + demand
    shaping vary)."""
    calm = GridEventConfig()

    def churn(traces, dt):
        return traces * churn_mask(*traces.shape), {}

    def failures(traces, dt):
        mask, meta = failure_mask(traces.shape[0], traces.shape[1], dt)
        return traces * mask, meta

    def stragglers(traces, dt):
        mask, meta = straggler_mask(*traces.shape)
        return traces * mask, meta

    def burst(traces, dt):
        mult, meta = burst_profile(traces.shape[0], dt)
        return traces * mult[:, None], meta

    return [
        Scenario("baseline", "steady fleet, calm grid", EnergyConfig()),
        Scenario("fleet_churn", "arrivals/departures churn the fleet",
                 EnergyConfig(events=calm), churn),
        Scenario("grid_outage", "regional grid outages force "
                 "solar/battery islanding",
                 EnergyConfig(events=GridEventConfig(
                     outages=((0, T // 4, max(3, T // 24)),
                              (1, T // 2, max(3, T // 18)))))),
        Scenario("intensity_shock", "correlated cross-region intensity "
                 "spike + one regional shock",
                 EnergyConfig(events=GridEventConfig(
                     shocks=((-1, int(0.4 * T), max(6, T // 12), 2.5),
                             (2, int(0.7 * T), max(6, T // 16), 1.8))))),
        Scenario("migration_failures", "hosts fail mid-sweep; heartbeat "
                 "detection + checkpoint restore",
                 EnergyConfig(events=calm), failures),
        Scenario("stragglers", "straggler-delayed suspend/resume until "
                 "mitigation migrates the job",
                 EnergyConfig(events=calm), stragglers),
        Scenario("demand_burst", "replayed demand burst at solar peak",
                 EnergyConfig(events=calm), burst),
        Scenario("telemetry_blackout", "carbon feed goes dark for a "
                 "stretch + the power meter drops epochs; the "
                 "degradation ladder rides hold -> prior -> floor",
                 EnergyConfig(events=calm),
                 faults=FaultPlan(
                     carbon=CarbonFeedFaults(
                         blackouts=((-1, T // 3, max(4, T // 8)),)),
                     power=PowerTelemetryFaults(
                         gaps=((T // 2, max(3, T // 16)),)),
                     seed=23)),
        Scenario("flapping_feed", "carbon telemetry flaps: random "
                 "dropouts + a noisy window degrade every controller "
                 "decision",
                 EnergyConfig(events=calm),
                 faults=FaultPlan(
                     carbon=CarbonFeedFaults(
                         dropout_prob=0.25,
                         noise_windows=((-1, T // 4, max(6, T // 6),
                                         0.2),)),
                     seed=29)),
        Scenario("migration_storm", "planned migrations fail in bulk; "
                 "capped backoff must keep retries from thrashing",
                 EnergyConfig(events=calm),
                 faults=FaultPlan(
                     migration=MigrationFaults(fail_prob=0.5,
                                               backoff_base=1,
                                               backoff_cap=8),
                     seed=31)),
    ]


def _shared_inputs(T: int, n_tr: int, seed: int = 5) -> tuple:
    """Deterministic base demand + (T, R) region-intensity matrix shared
    by every cell (so jax compiles one scan for the whole matrix)."""
    rng = np.random.default_rng(seed)
    t = np.arange(T)
    diurnal = 0.9 + 0.5 * np.sin(2 * np.pi * t / max(T, 1))[:, None]
    traces = np.clip(diurnal + rng.normal(0.0, 0.2, size=(T, n_tr)),
                     0.05, 2.0)
    phases = (0.0, 1.7, 3.1)
    regions = np.stack([230 + 160 * np.sin(2 * np.pi * t / max(T, 1) + p)
                        for p in phases], axis=1) + 40.0
    return traces, regions


def run_scenario(sc: Scenario, T: int = 288, n_tr: int = 24,
                 targets=(40.0, 80.0),
                 backends=("fleet", "jax")) -> dict:
    """Run one cell on every backend and evaluate the invariants."""
    traces, regions = _shared_inputs(T, n_tr)
    dt = 300.0
    if sc.shape_demand is not None:
        traces, meta = sc.shape_demand(traces, dt)
        sc.meta.update(meta)
    policies = {"cc": lambda: CarbonContainerPolicy(),
                "agnostic": lambda: CarbonAgnosticPolicy()}
    results: dict = {}
    for backend in backends:
        spec = SweepSpec(policies=policies, family=paper_family(),
                         traces=traces, targets=list(targets),
                         sim=SimConfig(target_rate=0.0, interval_s=dt),
                         backend=backend,
                         placement=PlacementConfig(capacity=max(2, n_tr)),
                         regions=regions, energy=sc.energy,
                         faults=sc.faults)
        results[backend] = spec.run()
    first: SweepResult = results[backends[0]]
    checks = {
        "conservation_max_err_w": float(
            first.col("energy_conservation_max_err_w").max()),
        "cap_violations": float(first.col("energy_cap_violations").max()),
        "soc_violations": float(first.col("energy_soc_violations").max()),
    }
    if len(backends) > 1:
        checks["backend_parity"] = max(
            results[backends[0]].parity(results[b])
            for b in backends[1:])
    ok = (checks["conservation_max_err_w"] <= CONSERVATION_TOL_W
          and checks["cap_violations"] == 0
          and checks["soc_violations"] == 0
          and checks.get("backend_parity", 0.0) <= PARITY_TOL)
    return {"name": sc.name, "ok": ok, "checks": checks,
            "meta": sc.meta, "results": results,
            "unmet_frac": float(first.col("energy_unmet_frac").max()),
            "outage_epochs": float(first.col("energy_outage_epochs").max())}


def run_matrix(T: int = 288, n_tr: int = 24, targets=(40.0, 80.0),
               backends=("fleet", "jax")) -> list:
    return [run_scenario(sc, T=T, n_tr=n_tr, targets=targets,
                         backends=backends)
            for sc in build_matrix(T)]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="Run the energy scenario stress matrix")
    ap.add_argument("--fast", action="store_true",
                    help="small shapes (T=96, n=8) for quick checks")
    ap.add_argument("--backends", default="fleet,jax",
                    help="comma-separated backends (default fleet,jax)")
    args = ap.parse_args(argv)
    T, n_tr = (96, 8) if args.fast else (288, 24)
    backends = tuple(b for b in args.backends.split(",") if b)
    rows = run_matrix(T=T, n_tr=n_tr, backends=backends)
    wid = max(len(r["name"]) for r in rows)
    print(f"{'scenario':<{wid}}  ok    conserv(W)  capv  socv  parity    "
          f"unmet  outages")
    bad = 0
    for r in rows:
        c = r["checks"]
        bad += not r["ok"]
        print(f"{r['name']:<{wid}}  {'ok' if r['ok'] else 'FAIL':4}  "
              f"{c['conservation_max_err_w']:.2e}  "
              f"{int(c['cap_violations']):4d}  {int(c['soc_violations']):4d}"
              f"  {c.get('backend_parity', 0.0):.2e}  "
              f"{r['unmet_frac']:.3f}  {int(r['outage_epochs']):d}")
    if bad:
        print(f"{bad} scenario(s) violated invariants")
        return 1
    print(f"all {len(rows)} scenarios hold: conservation <= "
          f"{CONSERVATION_TOL_W} W, zero cap/SoC violations, backend "
          f"parity <= {PARITY_TOL}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
