"""Per-region virtual energy supply: solar + battery + (perturbed) grid.

Ecovisor ("A Virtual Energy System for Carbon-Efficient Applications")
virtualizes the energy system: applications see a software-defined
supply — solar partitions, battery partitions with charge/discharge
limits, and a grid connection — instead of the physical one, and adapt
to supply signals rather than the other way round. This module is that
supply side for the sweep substrate:

  - `solar_series` generates per-region solar traces (time-zone-shifted
    clear-sky arc x a seeded AR(1) weather factor);
  - `event_matrices` generates the grid-event layer: outage windows
    (grid draw forced to zero) and multiplicative carbon-intensity
    shocks, either scheduled explicitly or sampled from a seed —
    region -1 addresses *all* regions at once (a correlated spike);
  - `supply_step_np` advances one epoch of the supply for all R regions
    (the battery state of charge is the only carry), producing the two
    signals the demand side consumes: `cap_frac`, the virtual power cap
    as a fraction of the region's offered flexible load, and `c_eff`,
    the delivered mix's effective carbon intensity (solar and battery
    draw are zero-carbon; grid draw carries the grid intensity);
  - `simulate_supply` scans the step over T epochs into a
    `SupplyResult` ledger with the sweep's invariant metrics: energy
    conservation (solar_used + battery + grid == supplied), zero
    virtual-cap violations, battery SoC within [0, capacity].

Metering model: the virtual partition meters the fleet's *flexible*
(demand-proportional) power at the baseline slice, ``p_flex =
span_b / mult_b * demand`` per container — linear in demand, so
enforcing the cap by scaling demand with `cap_frac` lands the enforced
load exactly on the supplied power (violations are zero by
construction; the check catches coding errors, same philosophy as the
placement capacity and elastic budget gates). Idle power sits outside
the partition and is billed at the effective mix intensity.

`repro.energy.supply_jax.energy_step` mirrors `supply_step_np` term for
term on (R,)-shaped jnp arrays so the fleet scan can fold the supply
step into its epoch step with an (R,) SoC carry only (no (T, N)
intermediates at the N=1M scale gate).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import NamedTuple, Optional

import numpy as np


@dataclass(frozen=True)
class SolarConfig:
    """Per-region solar array sized relative to the fleet.

    `peak_w_per_container` scales the array with the fleet (each
    region's peak is ``peak_w_per_container * n_containers / R``), so
    scenarios are fleet-size invariant. `tz_offset_h` shifts each
    region's solar day (None: evenly spread over 24 h, matching the
    traffic population's default); the clear-sky arc is a half-sine
    between `sunrise_h` and `sunset_h`, scaled by a seeded AR(1)
    weather factor (clouds).
    """
    peak_w_per_container: float = 150.0
    tz_offset_h: Optional[tuple] = None
    sunrise_h: float = 6.0
    sunset_h: float = 18.0
    weather_rho: float = 0.9
    weather_sigma: float = 0.1
    seed: int = 0


@dataclass(frozen=True)
class BatteryConfig:
    """Per-region battery partition, sized per container like solar.

    `eta_charge` is the one-way charge efficiency (losses land in the
    SoC ledger at charge time; discharge delivers 1:1 from the SoC, so
    conservation on the *delivered* side is exact).
    """
    capacity_wh_per_container: float = 40.0
    max_charge_w_per_container: float = 60.0
    max_discharge_w_per_container: float = 60.0
    eta_charge: float = 0.9
    soc0_frac: float = 0.5


@dataclass(frozen=True)
class GridEventConfig:
    """Grid events perturbing the supply and the carbon inputs.

    `outages` are explicit ``(region, start_epoch, n_epochs)`` windows
    (region -1 = every region: a correlated blackout); during an outage
    the region's grid draw is forced to zero, so the fleet rides on
    solar + battery and the virtual cap clamps whatever they cannot
    cover. `shocks` are explicit ``(region, start_epoch, n_epochs,
    factor)`` multiplicative carbon-intensity spikes (region -1 = all
    regions: a correlated regional spike); the perturbed intensity is
    what the placement planner, traffic router, and elasticity layer
    all consume. `n_random_outages` / `n_random_shocks` add seeded
    random windows on top (deterministic per seed).
    """
    outages: tuple = ()
    shocks: tuple = ()
    n_random_outages: int = 0
    outage_len: tuple = (3, 12)
    n_random_shocks: int = 0
    shock_len: tuple = (6, 24)
    shock_factor: tuple = (1.5, 3.0)
    seed: int = 0


@dataclass(frozen=True)
class EnergyConfig:
    """The energy layer's sweep sub-spec (``energy=`` / SweepSpec.energy)."""
    solar: SolarConfig = field(default_factory=SolarConfig)
    battery: BatteryConfig = field(default_factory=BatteryConfig)
    events: GridEventConfig = field(default_factory=GridEventConfig)


class EnergySpec(NamedTuple):
    """Hashable fleet-scaled supply constants, shared by the NumPy step
    and the JAX fold (jit static arg — keep scenario variation in the
    trace/event *arrays*, not here, so one compile covers a matrix)."""
    cap_wh: float            # per-region battery capacity
    max_charge_w: float
    max_discharge_w: float
    eta_c: float
    soc0_wh: float
    load_coef: float         # flexible W per unit demand (span_b/mult_b)
    solar_peak_w: float      # per-region array peak
    dt: float

    @classmethod
    def from_config(cls, cfg: EnergyConfig, n_containers: int,
                    n_regions: int, interval_s: float,
                    flex_w_per_unit: float) -> "EnergySpec":
        per_r = float(n_containers) / float(n_regions)
        b = cfg.battery
        return cls(
            cap_wh=b.capacity_wh_per_container * per_r,
            max_charge_w=b.max_charge_w_per_container * per_r,
            max_discharge_w=b.max_discharge_w_per_container * per_r,
            eta_c=float(b.eta_charge),
            soc0_wh=b.capacity_wh_per_container * per_r * float(b.soc0_frac),
            load_coef=float(flex_w_per_unit),
            solar_peak_w=cfg.solar.peak_w_per_container * per_r,
            dt=float(interval_s))


def flex_w_per_unit(family) -> float:
    """Flexible (demand-proportional) W per unit demand on the family's
    baseline slice: span_b / mult_b."""
    t = family.tables()
    b = t.baseline_idx
    return float((t.peak_w[b] - t.base_w[b]) / t.multiple[b])


def solar_series(cfg: SolarConfig, T: int, n_regions: int,
                 interval_s: float, peak_w: float) -> np.ndarray:
    """(T, R) solar generation in W: clear-sky half-sine arc per region
    (time-zone shifted) x seeded AR(1) weather factor."""
    R = n_regions
    tz = cfg.tz_offset_h
    if tz is None:
        tz = tuple(24.0 * r / R for r in range(R))
    if len(tz) != R:
        raise ValueError(f"tz_offset_h has {len(tz)} entries for "
                         f"{R} regions")
    h = (np.arange(T, dtype=np.float64) * interval_s / 3600.0)[:, None] \
        + np.asarray(tz, dtype=np.float64)[None, :]
    h = np.mod(h, 24.0)
    daylen = cfg.sunset_h - cfg.sunrise_h
    arc = np.sin(np.pi * (h - cfg.sunrise_h) / daylen)
    arc = np.where((h >= cfg.sunrise_h) & (h <= cfg.sunset_h),
                   np.maximum(arc, 0.0), 0.0)
    rng = np.random.default_rng(cfg.seed)
    x = np.zeros(R)
    weather = np.empty((T, R))
    for t in range(T):
        x = cfg.weather_rho * x + cfg.weather_sigma * rng.standard_normal(R)
        weather[t] = np.clip(0.85 + x, 0.0, 1.0)
    return peak_w * arc * weather


def event_matrices(cfg: GridEventConfig, T: int, n_regions: int):
    """Materialize the grid events as ``(shock_mult (T, R) f64,
    grid_up (T, R) f64 in {0, 1})``; deterministic per seed."""
    R = n_regions
    mult = np.ones((T, R), dtype=np.float64)
    up = np.ones((T, R), dtype=np.float64)
    rng = np.random.default_rng(cfg.seed)

    def _regions(r):
        return range(R) if int(r) < 0 else (int(r),)

    events = [(r, s, n, None) for (r, s, n) in cfg.outages]
    for _ in range(cfg.n_random_outages):
        events.append((int(rng.integers(0, R)),
                       int(rng.integers(0, max(T - 1, 1))),
                       int(rng.integers(cfg.outage_len[0],
                                        cfg.outage_len[1] + 1)), None))
    for ev in cfg.shocks:
        events.append(ev)
    for _ in range(cfg.n_random_shocks):
        events.append((int(rng.integers(0, R)),
                       int(rng.integers(0, max(T - 1, 1))),
                       int(rng.integers(cfg.shock_len[0],
                                        cfg.shock_len[1] + 1)),
                       float(rng.uniform(*cfg.shock_factor))))
    for r, start, n, factor in events:
        lo = max(0, int(start))
        hi = min(T, int(start) + int(n))
        if hi <= lo:
            continue
        for rr in _regions(r):
            if factor is None:
                up[lo:hi, rr] = 0.0
            else:
                mult[lo:hi, rr] *= float(factor)
    return mult, up


# Drained-battery snap: when a discharge empties the battery, the exact
# algebra leaves SoC at 0 but the rounding of soc - (soc*(3600/dt))*
# (dt/3600) (and XLA's FMA contraction of the same expression) can leave
# a ~1e-13 Wh residue. During an outage that residue discharges as a
# femto-watt `supplied`, flipping the supplied>0 branch of c_eff from
# "idle at grid intensity" to "100% battery, zero carbon" — a last-bit
# difference amplified into a full billing change. Snapping sub-nano-Wh
# SoC to zero in every step implementation keeps the branch (and the
# cross-backend parity) robust.
SOC_SNAP_WH = 1e-9


def supply_step_np(spec: EnergySpec, soc, load, solar, grid_c, up):
    """One epoch of the supply for all R regions (NumPy (R,) arrays).

    Feed-forward dispatch order: solar first, surplus charges the
    battery (rate/headroom-bounded, charge losses to the SoC ledger),
    deficit discharges the battery (rate/SoC-bounded), the remainder
    draws grid — zero during an outage, leaving the cap short of the
    load. Returns ``(soc1, (solar_used, charge, discharge, grid,
    supplied, cap_frac, c_eff))``. The JAX `energy_step` mirrors this
    term for term; keep the two in lockstep.
    """
    use_solar = np.minimum(load, solar)
    surplus = solar - use_solar
    head_w = (spec.cap_wh - soc) * (3600.0 / spec.dt) / spec.eta_c
    charge = np.maximum(
        np.minimum(np.minimum(surplus, spec.max_charge_w), head_w), 0.0)
    deficit = load - use_solar
    avail_w = soc * (3600.0 / spec.dt)
    discharge = np.maximum(
        np.minimum(np.minimum(deficit, spec.max_discharge_w), avail_w), 0.0)
    grid = (deficit - discharge) * up
    supplied = use_solar + discharge + grid
    soc1 = soc + (charge * spec.eta_c - discharge) * (spec.dt / 3600.0)
    soc1 = np.where(soc1 < SOC_SNAP_WH, 0.0, soc1)
    load_pos = load > 0.0
    cap_frac = np.where(
        load_pos,
        np.minimum(supplied / np.where(load_pos, load, 1.0), 1.0), 1.0)
    sup_pos = supplied > 0.0
    c_eff = grid_c * np.where(
        sup_pos, grid / np.where(sup_pos, supplied, 1.0), 1.0)
    return soc1, (use_solar, charge, discharge, grid, supplied, cap_frac,
                  c_eff)


def supply_step_scalar(spec: EnergySpec, soc: float, load: float,
                       solar: float, grid_c: float, up: float):
    """Pure-float reference for one region (anchors the parity chain:
    scalar <-> NumPy bit-identical, NumPy <-> JAX <= 1e-9)."""
    use_solar = min(load, solar)
    surplus = solar - use_solar
    head_w = (spec.cap_wh - soc) * (3600.0 / spec.dt) / spec.eta_c
    charge = max(min(min(surplus, spec.max_charge_w), head_w), 0.0)
    deficit = load - use_solar
    avail_w = soc * (3600.0 / spec.dt)
    discharge = max(min(min(deficit, spec.max_discharge_w), avail_w), 0.0)
    grid = (deficit - discharge) * up
    supplied = use_solar + discharge + grid
    soc1 = soc + (charge * spec.eta_c - discharge) * (spec.dt / 3600.0)
    soc1 = 0.0 if soc1 < SOC_SNAP_WH else soc1
    cap_frac = min(supplied / load, 1.0) if load > 0.0 else 1.0
    c_eff = grid_c * (grid / supplied if supplied > 0.0 else 1.0)
    return soc1, (use_solar, charge, discharge, grid, supplied, cap_frac,
                  c_eff)


@dataclass
class SupplyResult:
    """(T, R) supply ledger + the sweep's invariant metrics."""
    load: np.ndarray             # offered flexible load (W)
    solar_gen: np.ndarray        # available solar (W)
    solar_used: np.ndarray
    charge: np.ndarray
    discharge: np.ndarray
    grid: np.ndarray
    supplied: np.ndarray
    cap_frac: np.ndarray
    c_eff: np.ndarray
    soc: np.ndarray              # end-of-epoch state of charge (Wh)
    grid_up: np.ndarray
    spec: EnergySpec

    _TOL = 1e-9

    @property
    def unmet(self) -> np.ndarray:
        return self.load - self.supplied

    @property
    def conservation_max_err_w(self) -> float:
        """max |solar_used + battery + grid - supplied| over (t, r)."""
        err = self.solar_used + self.discharge + self.grid - self.supplied
        return float(np.max(np.abs(err))) if err.size else 0.0

    @property
    def cap_violations(self) -> int:
        """Epochs where the *enforced* load (load x cap_frac) exceeds
        the supplied power: zero by construction; nonzero = bug."""
        scale = max(float(np.max(self.load, initial=0.0)), 1.0)
        bad = (self.load * self.cap_frac
               > self.supplied + self._TOL * scale)
        return int(np.sum(bad))

    @property
    def soc_violations(self) -> int:
        tol = self._TOL * max(self.spec.cap_wh, 1.0)
        bad = (self.soc < -tol) | (self.soc > self.spec.cap_wh + tol)
        return int(np.sum(bad))

    def summary(self) -> dict:
        wh = self.spec.dt / 3600.0
        sup = max(float(self.supplied.sum()) * wh, 1e-12)
        load_wh = max(float(self.load.sum()) * wh, 1e-12)
        return {
            "energy_solar_wh": float(self.solar_used.sum()) * wh,
            "energy_battery_wh": float(self.discharge.sum()) * wh,
            "energy_grid_wh": float(self.grid.sum()) * wh,
            "energy_supplied_wh": float(self.supplied.sum()) * wh,
            "energy_unmet_frac": float(self.unmet.sum()) * wh / load_wh,
            "energy_solar_frac": float(self.solar_used.sum()) * wh / sup,
            "energy_grid_frac": float(self.grid.sum()) * wh / sup,
            "energy_cap_frac_min": (float(self.cap_frac.min())
                                    if self.cap_frac.size else 1.0),
            "energy_outage_epochs": int(np.sum(self.grid_up <= 0.0)),
            "energy_conservation_max_err_w": self.conservation_max_err_w,
            "energy_cap_violations": self.cap_violations,
            "energy_soc_violations": self.soc_violations,
        }


def simulate_supply(load, solar, grid_c, grid_up,
                    spec: EnergySpec) -> SupplyResult:
    """Scan `supply_step_np` over T epochs; all inputs (T, R)."""
    load = np.asarray(load, dtype=np.float64)
    solar = np.asarray(solar, dtype=np.float64)
    grid_c = np.asarray(grid_c, dtype=np.float64)
    grid_up = np.asarray(grid_up, dtype=np.float64)
    if not (load.shape == solar.shape == grid_c.shape == grid_up.shape):
        raise ValueError(f"supply inputs disagree: load {load.shape}, "
                         f"solar {solar.shape}, grid {grid_c.shape}, "
                         f"up {grid_up.shape}")
    T, R = load.shape
    # scalar inner loop: T x R pure-float steps beat T numpy calls on
    # (R,)-wide arrays by ~10x (this sim is most of the energy layer's
    # overhead at the bench gate); supply_step_scalar is pinned
    # bit-identical to supply_step_np by the test suite, so the ledger
    # is unchanged down to the last bit
    outs = np.empty((8, T, R), dtype=np.float64)
    ld, sl, gc, gu = (load.tolist(), solar.tolist(), grid_c.tolist(),
                      grid_up.tolist())
    soc_r = [spec.soc0_wh] * R
    buf = outs.reshape(8, T * R)
    for r in range(R):
        soc = soc_r[r]
        for t in range(T):
            soc, step = supply_step_scalar(spec, soc, ld[t][r], sl[t][r],
                                           gc[t][r], gu[t][r])
            i = t * R + r
            (buf[0][i], buf[1][i], buf[2][i], buf[3][i], buf[4][i],
             buf[5][i], buf[6][i]) = step
            buf[7][i] = soc
    (solar_used, charge, discharge, grid, supplied, cap_frac,
     c_eff, soc_tr) = outs
    return SupplyResult(load=load, solar_gen=solar, solar_used=solar_used,
                        charge=charge, discharge=discharge, grid=grid,
                        supplied=supplied, cap_frac=cap_frac, c_eff=c_eff,
                        soc=soc_tr, grid_up=grid_up, spec=spec)
