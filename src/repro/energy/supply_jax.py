"""JAX supply step: the virtual energy system as one pure scan step.

`energy_step` is `repro.energy.supply.supply_step_np` term for term on
(R,)-shaped jnp arrays with a static `EnergySpec` — small enough to
fold straight into the fleet backend's `lax.scan` epoch step
(`repro.core.fleet_jax._fleet_scan`), which is how
`sweep_population(..., backend="jax", energy=...)` keeps the N=1M
placed sweep free of (T, N) intermediates: the scan carries only the
(R,) battery state-of-charge extra, the per-epoch solar/outage rows
ride in xs, and the virtual-cap and effective-intensity signals are
R-way selects over (R,) rows.

`simulate_supply_jax` scans the same step standalone and returns the
usual `SupplyResult` — parity with the NumPy ledger is pinned <= 1e-9
by tests/test_energy.py.
"""
from __future__ import annotations

import numpy as np

from repro.energy.supply import SOC_SNAP_WH, EnergySpec, SupplyResult

try:
    import jax
    import jax.numpy as jnp
    from jax import lax
    from jax.experimental import enable_x64
    HAS_JAX = True
except ImportError:                                    # pragma: no cover
    HAS_JAX = False
    jax = jnp = lax = enable_x64 = None


def energy_step(spec: EnergySpec, soc, load, solar, grid_c, up):
    """One supply epoch on (R,) jnp arrays; mirrors `supply_step_np`
    (keep the two in lockstep — the cross-backend sweep parity tests
    pin them through the fleet scan). Pure; trace-safe inside any
    surrounding scan."""
    use_solar = jnp.minimum(load, solar)
    surplus = solar - use_solar
    head_w = (spec.cap_wh - soc) * (3600.0 / spec.dt) / spec.eta_c
    charge = jnp.maximum(
        jnp.minimum(jnp.minimum(surplus, spec.max_charge_w), head_w), 0.0)
    deficit = load - use_solar
    avail_w = soc * (3600.0 / spec.dt)
    discharge = jnp.maximum(
        jnp.minimum(jnp.minimum(deficit, spec.max_discharge_w), avail_w),
        0.0)
    grid = (deficit - discharge) * up
    supplied = use_solar + discharge + grid
    soc1 = soc + (charge * spec.eta_c - discharge) * (spec.dt / 3600.0)
    # drained-battery snap (see supply.SOC_SNAP_WH): without it, XLA's
    # FMA contraction of the drain epoch leaves a ~1e-13 Wh residue
    # whose femto-watt discharge flips the supplied>0 branch of c_eff
    # during outages — a last-bit difference billed as a 100% change
    soc1 = jnp.where(soc1 < SOC_SNAP_WH, 0.0, soc1)
    load_pos = load > 0.0
    cap_frac = jnp.where(
        load_pos,
        jnp.minimum(supplied / jnp.where(load_pos, load, 1.0), 1.0), 1.0)
    sup_pos = supplied > 0.0
    c_eff = grid_c * jnp.where(
        sup_pos, grid / jnp.where(sup_pos, supplied, 1.0), 1.0)
    return soc1, (use_solar, charge, discharge, grid, supplied, cap_frac,
                  c_eff)


def simulate_supply_jax(load, solar, grid_c, grid_up,
                        spec: EnergySpec) -> SupplyResult:
    """Standalone scan of `energy_step` over all T epochs (float64)."""
    if not HAS_JAX:
        raise ImportError("simulate_supply_jax requires jax; use "
                          "repro.energy.supply.simulate_supply")
    load = np.asarray(load, dtype=np.float64)
    solar = np.asarray(solar, dtype=np.float64)
    grid_c = np.asarray(grid_c, dtype=np.float64)
    grid_up = np.asarray(grid_up, dtype=np.float64)
    T, R = load.shape

    def step(soc, x):
        soc1, outs = energy_step(spec, soc, *x)
        return soc1, outs + (soc1,)

    with enable_x64():
        soc0 = jnp.full(R, spec.soc0_wh, dtype=jnp.float64)
        _, ys = jax.jit(lambda xs: lax.scan(step, soc0, xs))(
            (jnp.asarray(load), jnp.asarray(solar), jnp.asarray(grid_c),
             jnp.asarray(grid_up)))
        (solar_used, charge, discharge, grid, supplied, cap_frac, c_eff,
         soc_tr) = (np.asarray(y) for y in ys)
    return SupplyResult(load=load, solar_gen=solar, solar_used=solar_used,
                        charge=charge, discharge=discharge, grid=grid,
                        supplied=supplied, cap_frac=cap_frac, c_eff=c_eff,
                        soc=soc_tr, grid_up=grid_up, spec=spec)
