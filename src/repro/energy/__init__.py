"""Virtual energy supply layer (Ecovisor-style) + scenario stress matrix.

`repro.energy.supply` models a per-region energy supply — solar
generation, a battery, and the (event-perturbed) grid — and turns it
into two signals the demand-side layers consume: a per-region *virtual
power cap* fraction (software-defined cap on the flexible fleet load)
and the *effective* carbon intensity of the delivered mix.
`repro.energy.scenarios` runs named stress scenarios (fleet churn, grid
outages, migration failures, stragglers, demand bursts) as
`sweep_population` entries on both array backends with invariant checks.
"""
from repro.energy.supply import (BatteryConfig, EnergyConfig, EnergySpec,
                                 GridEventConfig, SolarConfig, SupplyResult,
                                 event_matrices, simulate_supply,
                                 solar_series, supply_step_np)

__all__ = [
    "BatteryConfig", "EnergyConfig", "EnergySpec", "GridEventConfig",
    "SolarConfig", "SupplyResult", "event_matrices", "simulate_supply",
    "solar_series", "supply_step_np",
]
