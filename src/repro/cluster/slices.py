"""Slice (server) families: homogeneous capacities at fixed multiples.

The paper assumes a family of general-purpose servers at 0.25×/0.5×/1×/2×/4×
the baseline capacity, with base/peak power proportional to capacity
(§5.1.2: baseline 100 W base, 200 W peak). ``paper_family`` reproduces that
exactly for the simulator; ``tpu_v5e_family`` is the TPU mapping: slices of
16…256 chips, per-chip idle/peak power plus per-host overhead.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Sequence

from repro.power.model import LinearPowerModel


@dataclass(frozen=True)
class Slice:
    name: str
    multiple: float            # capacity relative to the baseline slice
    power: LinearPowerModel
    chips: int = 0             # 0 for the paper's abstract servers
    state_bw_gbps: float = 1.0  # checkpoint/migration path bandwidth (GB/s)

    def capacity(self) -> float:
        return self.multiple


class SliceFamily:
    """Ordered catalog (smallest -> largest) with availability tracking."""

    def __init__(self, slices: Sequence[Slice], baseline_idx: int):
        self.slices = sorted(slices, key=lambda s: s.multiple)
        self.baseline_idx = next(
            i for i, s in enumerate(self.slices)
            if s.multiple == sorted(slices, key=lambda x: x.multiple)[baseline_idx].multiple)
        # availability: the paper's policy drops unavailable servers and
        # re-evaluates (§3.2.1); tests toggle this.
        self.available = [True] * len(self.slices)

    def __len__(self):
        return len(self.slices)

    def __getitem__(self, i: int) -> Slice:
        return self.slices[i]

    @property
    def baseline(self) -> Slice:
        return self.slices[self.baseline_idx]

    def next_smaller(self, i: int) -> Optional[int]:
        for j in range(i - 1, -1, -1):
            if self.available[j]:
                return j
        return None

    def next_larger(self, i: int) -> Optional[int]:
        for j in range(i + 1, len(self.slices)):
            if self.available[j]:
                return j
        return None

    def smallest(self) -> int:
        return next(i for i, a in enumerate(self.available) if a)


def paper_family() -> SliceFamily:
    """The paper's AWS-like family: 0.25x..4x, 100/200 W baseline."""
    base = LinearPowerModel(100.0, 200.0)
    slices = [Slice(f"x{m:g}", m, base.scale(m)) for m in
              (0.25, 0.5, 1.0, 2.0, 4.0)]
    return SliceFamily(slices, baseline_idx=2)


def tpu_v5e_family(chip_idle_w: float = 75.0, chip_peak_w: float = 200.0,
                   host_w: float = 150.0, chips_per_host: int = 8,
                   baseline_chips: int = 64) -> SliceFamily:
    """TPU v5e slices 16..256 chips; power = chips·(idle..peak) + hosts."""
    slices = []
    for chips in (16, 32, 64, 128, 256):
        hosts = chips // chips_per_host
        pm = LinearPowerModel(chips * chip_idle_w + hosts * host_w,
                              chips * chip_peak_w + hosts * host_w)
        slices.append(Slice(f"v5e-{chips}", chips / baseline_chips, pm,
                            chips=chips, state_bw_gbps=2.0 * hosts))
    fam = SliceFamily(slices, baseline_idx=2)
    return fam
