"""Slice (server) families: homogeneous capacities at fixed multiples.

The paper assumes a family of general-purpose servers at 0.25×/0.5×/1×/2×/4×
the baseline capacity, with base/peak power proportional to capacity
(§5.1.2: baseline 100 W base, 200 W peak). ``paper_family`` reproduces that
exactly for the simulator; ``tpu_v5e_family`` is the TPU mapping: slices of
16…256 chips, per-chip idle/peak power plus per-host overhead.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Sequence

import numpy as np

from repro.power.model import LinearPowerModel


@dataclass(frozen=True)
class Slice:
    name: str
    multiple: float            # capacity relative to the baseline slice
    power: LinearPowerModel
    chips: int = 0             # 0 for the paper's abstract servers
    state_bw_gbps: float = 1.0  # checkpoint/migration path bandwidth (GB/s)

    def capacity(self) -> float:
        return self.multiple


class SliceFamily:
    """Ordered catalog (smallest -> largest) with availability tracking."""

    def __init__(self, slices: Sequence[Slice], baseline_idx: int):
        self.slices = sorted(slices, key=lambda s: s.multiple)
        self.baseline_idx = next(
            i for i, s in enumerate(self.slices)
            if s.multiple == sorted(slices, key=lambda x: x.multiple)[baseline_idx].multiple)
        # availability: the paper's policy drops unavailable servers and
        # re-evaluates (§3.2.1); tests toggle this.
        self.available = [True] * len(self.slices)

    def __len__(self):
        return len(self.slices)

    def __getitem__(self, i: int) -> Slice:
        return self.slices[i]

    @property
    def baseline(self) -> Slice:
        return self.slices[self.baseline_idx]

    def next_smaller(self, i: int) -> Optional[int]:
        for j in range(i - 1, -1, -1):
            if self.available[j]:
                return j
        return None

    def next_larger(self, i: int) -> Optional[int]:
        for j in range(i + 1, len(self.slices)):
            if self.available[j]:
                return j
        return None

    def smallest(self) -> int:
        return next(i for i, a in enumerate(self.available) if a)

    def tables(self) -> "FamilyTables":
        """Snapshot the family as flat arrays for the vectorized fleet path.

        Power curves become per-slice (base_w, peak_w) lookup tables;
        availability-aware neighbor scans (`next_smaller`/`next_larger`)
        are precomputed per index (-1 = none) so the batch decision kernel
        never walks the slice list at simulation time. The snapshot is
        taken once — later `available` mutations do not propagate.
        """
        n = len(self.slices)
        ns = np.array([(-1 if (j := self.next_smaller(i)) is None else j)
                       for i in range(n)], dtype=np.int64)
        nl = np.array([(-1 if (j := self.next_larger(i)) is None else j)
                       for i in range(n)], dtype=np.int64)
        return FamilyTables(
            base_w=np.array([s.power.base_w for s in self.slices]),
            peak_w=np.array([s.power.peak_w for s in self.slices]),
            multiple=np.array([s.multiple for s in self.slices]),
            bw_gbps=np.array([s.state_bw_gbps for s in self.slices]),
            next_smaller=ns,
            next_larger=nl,
            smallest=self.smallest(),
            baseline_idx=self.baseline_idx,
            names=tuple(s.name for s in self.slices),
            well_formed=bool(all(s.power.peak_w > s.power.base_w
                                 for s in self.slices)),
        )


@dataclass(frozen=True)
class FamilyTables:
    """Flat-array view of a SliceFamily for vectorized (fleet) simulation.

    All arrays are indexed by slice position (smallest -> largest); a
    container's state indexes into them with `np.take`-style gathers.
    """
    base_w: np.ndarray       # (S,) idle power per slice
    peak_w: np.ndarray       # (S,) full-utilization power
    multiple: np.ndarray     # (S,) capacity relative to baseline
    bw_gbps: np.ndarray      # (S,) migration-path bandwidth
    next_smaller: np.ndarray  # (S,) index of next available smaller; -1 none
    next_larger: np.ndarray   # (S,) index of next available larger; -1 none
    smallest: int
    baseline_idx: int
    names: tuple
    well_formed: bool = True  # every slice has peak_w > base_w (lets the
    #                           kernels elide the degenerate-curve fixups)


def paper_family() -> SliceFamily:
    """The paper's AWS-like family: 0.25x..4x, 100/200 W baseline."""
    base = LinearPowerModel(100.0, 200.0)
    slices = [Slice(f"x{m:g}", m, base.scale(m)) for m in
              (0.25, 0.5, 1.0, 2.0, 4.0)]
    return SliceFamily(slices, baseline_idx=2)


def tpu_v5e_family(chip_idle_w: float = 75.0, chip_peak_w: float = 200.0,
                   host_w: float = 150.0, chips_per_host: int = 8,
                   baseline_chips: int = 64) -> SliceFamily:
    """TPU v5e slices 16..256 chips; power = chips·(idle..peak) + hosts."""
    slices = []
    for chips in (16, 32, 64, 128, 256):
        hosts = chips // chips_per_host
        pm = LinearPowerModel(chips * chip_idle_w + hosts * host_w,
                              chips * chip_peak_w + hosts * host_w)
        slices.append(Slice(f"v5e-{chips}", chips / baseline_chips, pm,
                            chips=chips, state_bw_gbps=2.0 * hosts))
    fam = SliceFamily(slices, baseline_idx=2)
    return fam
