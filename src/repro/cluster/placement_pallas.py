"""Pallas admission kernel for the placement preference rounds.

One preference round of the capacity-admission step (see
`repro.cluster.placement`) is a *sequential contention loop*: container i
is admitted to its best region r iff fewer than ``remaining[r]`` wanters
of r precede it in container-index order. The XLA port ranks wanters
with a global ``lax.associative_scan`` over the full (N, R) one-hot
matrix — a multi-pass O(N R log N) tree that materializes rank
intermediates and defeats fusion on CPU (see the `placement_jax` module
docstring). This is exactly the shape Pallas exists for: the whole round
is a *single streaming pass* when per-region "wanters seen so far"
counters ride along the container axis.

Kernel layout (``admission_round``):

  - grid over container blocks, sequential (``dimension_semantics=
    ("arbitrary",)``) so scratch carries across blocks;
  - per-region wanter counters in SMEM scratch — the only cross-block
    state, (R,) int32;
  - per block: recompute the round's argmax-preference from the epoch's
    (B, R) net tile and the packed strike bitmask, rank each wanter as
    ``seen[r] + in-block prefix count``, admit iff rank <=
    ``remaining[r]`` (the round-start snapshot — identical to the NumPy
    kernel, which decrements per region *after* each region's cumsum),
    and strike denied choices into the bitmask;
  - the per-round carry is two packed int32 vectors (dst, struck) — no
    (N, R) tensor survives the round.

The denial/early-exit bookkeeping needs only the per-region wanter
totals: admitted(r) == min(want_total[r], remaining[r]) because
admission takes exactly the first ``remaining[r]`` wanters. The final
block publishes the SMEM counters as the (R,) ``want_total`` output.

dtype is taken from ``net``: float64 under `enable_x64` on CPU (the
parity-anchored interpret path), float32 on TPU/GPU where f64 is
unavailable — the accelerator path trades the 1e-6 parity anchor for
bit-exact *assignment* parity at f32-safe nets, like the rest of the
kernels in `repro.kernels`. ``interpret=None`` resolves to interpret
mode unless the default JAX backend is an accelerator, mirroring the
flash_attention/ssd_scan CPU-fallback idiom.
"""
from __future__ import annotations

import functools

try:
    import jax
    import jax.numpy as jnp
    from jax.experimental import pallas as pl
    from jax.experimental.pallas import tpu as pltpu
    HAS_PALLAS = True
except ImportError:                                    # pragma: no cover
    HAS_PALLAS = False
    jax = jnp = pl = pltpu = None

DEFAULT_BLOCK = 8192     # containers per grid step (f64 net tile: 192KB at R=3)


def _compiler_params(dimension_semantics):
    """Version-portable pltpu compiler params (the class was renamed
    across jax releases); shared with the model kernels."""
    from repro.kernels.pallas_compat import compiler_params
    return compiler_params(dimension_semantics)


def _round_kernel(net_ref, assign_ref, elig_ref, dst_ref, struck_ref,
                  remaining_ref, dst_out_ref, struck_out_ref, want_out_ref,
                  seen_ref, *, R: int, B: int, N: int, NB: int):
    b = pl.program_id(0)

    @pl.when(b == 0)
    def _init():
        seen_ref[...] = jnp.zeros_like(seen_ref)

    net = net_ref[...]                       # (B, R) epoch net, round-invariant
    assign = assign_ref[...]                 # (B,)  current region
    elig = elig_ref[...] > 0                 # (B,)  dwell >= min_dwell
    dst = dst_ref[...]                       # (B,)  -1 = still unplaced
    struck = struck_ref[...]                 # (B,)  denied-region bitmask
    remaining = remaining_ref[...]           # (R,)  round-start free slots

    rows = jax.lax.broadcasted_iota(jnp.int32, (B, R), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (B, R), 1)
    valid = (b * B + rows[:, 0]) < N         # mask the ragged last block

    # argmax preference over un-struck regions; strict > keeps the first
    # max on ties, matching np.argmax (R is small and static)
    neg = jnp.asarray(-jnp.inf, net.dtype)
    net_eff = jnp.where(((struck[:, None] >> cols) & 1) > 0, neg, net)
    best = jnp.zeros(assign.shape, jnp.int32)
    net_best = net_eff[:, 0]
    for r in range(1, R):
        m = net_eff[:, r] > net_best
        best = jnp.where(m, r, best)
        net_best = jnp.where(m, net_eff[:, r], net_best)

    want = valid & elig & (dst < 0) & (net_best > 0.0) & (best != assign)
    onehot = want[:, None] & (best[:, None] == cols)
    # ranked admission: global inclusive rank = carried wanter count +
    # in-block prefix count; the first `remaining[r]` wanters win
    prefix = jnp.cumsum(onehot.astype(jnp.int32), axis=0)
    seen = seen_ref[...]
    admit = onehot & (seen[None, :] + prefix <= remaining[None, :])
    admitted = admit.any(axis=1)
    dst_out_ref[...] = jnp.where(admitted, best, dst)
    denied = want & ~admitted
    struck_out_ref[...] = jnp.where(denied, struck | (1 << best), struck)
    seen_ref[...] = seen + prefix[-1]

    @pl.when(b == NB - 1)
    def _publish():
        want_out_ref[...] = seen_ref[...]


def default_interpret() -> bool:
    """Interpret (CPU-fallback) mode unless running on an accelerator."""
    return jax.default_backend() not in ("tpu", "gpu", "cuda", "rocm")


def admission_round(net, assign, eligible, dst, struck, remaining, *,
                    block_n: int = DEFAULT_BLOCK, interpret=None):
    """One capacity-admission preference round as a single streaming pass.

    Inputs: ``net`` (N, R) epoch net-saving table; ``assign``/(N,) i32
    current regions; ``eligible`` (N,) i32/bool dwell gate; ``dst`` (N,)
    i32 round carry (-1 = unplaced); ``struck`` (N,) i32 denied-region
    bitmask carry; ``remaining`` (R,) i32 round-start free slots.

    Returns ``(dst', struck', want_total)`` with ``want_total`` (R,) i32
    the number of containers that requested each region this round —
    enough for the caller to update ``remaining`` (admitted ==
    min(want_total, remaining)) and evaluate the NumPy kernel's
    early-exit rule without touching (N, R) state.
    """
    N, R = net.shape
    if interpret is None:
        interpret = default_interpret()
    B = min(block_n, max(N, 1))
    NB = max(1, -(-N // B))
    kernel = functools.partial(_round_kernel, R=R, B=B, N=N, NB=NB)
    elig_i = eligible.astype(jnp.int32)
    return pl.pallas_call(
        kernel,
        grid=(NB,),
        in_specs=[
            pl.BlockSpec((B, R), lambda b: (b, 0)),
            pl.BlockSpec((B,), lambda b: (b,)),
            pl.BlockSpec((B,), lambda b: (b,)),
            pl.BlockSpec((B,), lambda b: (b,)),
            pl.BlockSpec((B,), lambda b: (b,)),
            pl.BlockSpec((R,), lambda b: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((B,), lambda b: (b,)),
            pl.BlockSpec((B,), lambda b: (b,)),
            pl.BlockSpec((R,), lambda b: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((N,), jnp.int32),      # dst'
            jax.ShapeDtypeStruct((N,), jnp.int32),      # struck'
            jax.ShapeDtypeStruct((R,), jnp.int32),      # want_total
        ],
        scratch_shapes=[pltpu.SMEM((R,), jnp.int32)],
        compiler_params=_compiler_params(("arbitrary",)),
        interpret=interpret,
    )(net, assign, elig_i, dst, struck, remaining)
