"""Migration cost model (paper §4.1 / Fig. 7: time linear in state bytes).

The paper measures LXC/CRIU stop-and-copy on CloudLab: suspend/resume,
compress/decompress, and transfer all scale linearly with the memory
footprint, with transfer-of-uncompressed dominating; a 7 GB container
migrates in < 2 minutes. The TPU analogue is checkpoint → (reshard) →
restore, with state = params + optimizer (+ KV/SSM state when serving),
moving at the slice's checkpoint bandwidth.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass(frozen=True)
class MigrationCostModel:
    # linear coefficients (seconds + seconds/GB), Fig. 7 calibration
    suspend_base_s: float = 0.4
    suspend_per_gb_s: float = 2.0
    resume_base_s: float = 0.5
    resume_per_gb_s: float = 2.2
    compress_per_gb_s: float = 3.5
    decompress_per_gb_s: float = 2.5
    compression_ratio: float = 8.0
    transfer_gbps: float = 1.0          # GB/s uncompressed path
    restore_extra_s: float = 0.0        # e.g. compile-cache miss penalty

    def suspend_time(self, state_gb: float) -> float:
        return self.suspend_base_s + self.suspend_per_gb_s * state_gb

    def resume_time(self, state_gb: float) -> float:
        return self.resume_base_s + self.resume_per_gb_s * state_gb

    def stop_and_copy_time(self, state_gb: float, compressed: bool = True,
                           transfer_gbps: float = 0.0) -> float:
        """Total downtime of a stop-and-copy migration (paper Fig. 7)."""
        bw = transfer_gbps or self.transfer_gbps
        t = self.suspend_time(state_gb) + self.resume_time(state_gb)
        if compressed:
            t += (self.compress_per_gb_s + self.decompress_per_gb_s) * state_gb
            t += (state_gb / self.compression_ratio) / bw
        else:
            t += state_gb / bw
        return t + self.restore_extra_s

    def stop_and_copy_time_batch(self, state_gb, transfer_gbps):
        """Vectorized `stop_and_copy_time` (compressed path) over arrays.

        Mirrors the scalar term order exactly — including the
        `transfer_gbps or self.transfer_gbps` zero-bandwidth fallback — so
        the fleet simulator stays bit-compatible with the scalar path.
        """
        bw = np.where(transfer_gbps == 0.0, self.transfer_gbps,
                      transfer_gbps)
        t = ((self.suspend_base_s + self.suspend_per_gb_s * state_gb)
             + (self.resume_base_s + self.resume_per_gb_s * state_gb))
        t = t + (self.compress_per_gb_s + self.decompress_per_gb_s) * state_gb
        t = t + (state_gb / self.compression_ratio) / bw
        return t + self.restore_extra_s

    def live_migration_overlap_s(self, state_gb: float,
                                 transfer_gbps: float = 0.0) -> float:
        """Both-servers-powered overlap of a live migration (downtime ~0)."""
        bw = transfer_gbps or self.transfer_gbps
        return 1.10 * state_gb / bw      # ~10% dirty-page re-copy


def training_state_gb(n_params: int, optimizer: str = "adamw",
                      param_bytes: int = 4) -> float:
    mult = {"adamw": 3, "sgd": 2}.get(optimizer, 3)   # params + m [+ v]
    return n_params * param_bytes * mult / 1e9
