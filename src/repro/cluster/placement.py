"""Multi-region placement layer on top of the vectorized fleet substrate.

The paper's Carbon Containers enforce a per-container g·CO2e/hr cap with
vertical scaling, suspend/resume and *migration* (§3.2); CarbonScaler and
CASPER extend the idea across regions, moving work toward cleaner grids.
This module adds that cross-region dimension above `FleetSimulator`
(`repro.core.fleet`): each monitoring epoch a `PlacementEngine` assigns
every container in an (N,) fleet to one of R regions (stacked carbon
traces), deciding migrate/stay by weighing the projected carbon saving
over an amortization horizon against the `MigrationCostModel`
stop-and-copy cost, with hysteresis and per-region capacity limits.

Decision model (identical in the scalar reference and the batch kernel)
----------------------------------------------------------------------
At epoch n, container i currently in region a with demand d:

    p_est   = base_b + (peak_b - base_b) * min(d / mult_b, 1)   [W]
    save(r) = p_est * (c[a] - c[r]) / 1000 * H_hr               [g, horizon]
    cost(r) = 2*base_b * mig_s / 3600 * 0.5*(c[a]+c[r]) / 1000  [g, one move]
    net(r)  = save(r) - (1 + hysteresis) * cost(r)

`p_est` is a persistence forecast on the baseline slice (the placement
layer is policy-agnostic: it cannot see which slice the enforcement
policy will pick, so it prices the move at baseline power — conservative
on both sides of the ledger). `mig_s` is the Fig.-7 stop-and-copy time at
the cross-region link bandwidth; during it both endpoints idle
(`2*base_b`) at the mean of the two grids' intensities. A container
requests the argmax-net region when `net > 0` and its dwell since the
last placement move is at least `min_dwell` (hysteresis + dwell kill
oscillation on flat or noisy traces).

Capacity uses two-phase admission in preference rounds: occupancy is
snapshotted at epoch start; round k admits each still-unplaced
requester's k-th surviving choice in container-index order while
`capacity[r] - occupancy[r]` slots remain (a denied choice is struck and
the container falls through toward its next-cleanest positive-net
region, mirroring the policy layer's fall-through idiom); slots freed by
departures become available next epoch. This keeps the greedy scalar
reference and the cumsum-masked batch kernel bit-identical
(`tests/test_placement.py` pins parity to 1e-9) and guarantees no region
ever exceeds capacity.

The planned assignment gathers per-container carbon traces
(`PlacementPlan.carbon_matrix`) that feed straight into
`FleetSimulator.run`, so the enforcement policies simulate unchanged on
the region each container actually occupies; placement stop-and-copy
overhead is accounted separately (`PlacementPlan.overhead_g`).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Sequence

import numpy as np

from repro.cluster.migration import MigrationCostModel
from repro.cluster.slices import SliceFamily


@dataclass(frozen=True)
class PlacementConfig:
    """Knobs of the migrate/stay decision (see module docstring)."""
    horizon_intervals: int = 12      # amortize one move over H epochs
    hysteresis: float = 0.10         # saving must beat (1+h) * cost
    min_dwell: int = 6               # epochs pinned after a placement move
    link_gbps: float = 0.25          # cross-region (WAN) state bandwidth
    capacity: Optional[object] = None  # per-region container cap: int | (R,)

    def capacity_vector(self, n_regions: int) -> Optional[np.ndarray]:
        if self.capacity is None:
            return None
        raw = np.broadcast_to(np.asarray(self.capacity), (n_regions,))
        cap = raw.astype(np.int64)
        if (cap != np.asarray(raw, dtype=np.float64)).any():
            raise ValueError(f"per-region capacity must be integral, got "
                             f"{raw!r}")
        if (cap < 1).any():
            raise ValueError("per-region capacity must be >= 1")
        return cap.copy()


@dataclass
class PlacementPlan:
    """Epoch-by-epoch region assignment for an (N,) fleet.

    `assign[n, i]` is container i's region during epoch n (post-decision:
    a move decided at epoch n serves epoch n from the destination, with
    the stop-and-copy downtime priced into `overhead_g`/`downtime_s`).
    """
    assign: np.ndarray               # (T, N) int64 region index
    migrations: np.ndarray           # (N,) placement moves per container
    overhead_g: np.ndarray           # (N,) stop-and-copy emissions (g)
    downtime_s: np.ndarray           # (N,) stop-and-copy downtime (s)
    region_intensity: np.ndarray     # (T, R) g/kWh per region per epoch
    region_names: tuple
    initial: np.ndarray              # (N,) pre-epoch-0 region index
    failed_migrations: Optional[np.ndarray] = None   # (N,) failed attempts

    @property
    def n_regions(self) -> int:
        return self.region_intensity.shape[1]

    def carbon_matrix(self) -> np.ndarray:
        """(T, N) per-container intensity under the planned assignment."""
        T = self.assign.shape[0]
        return self.region_intensity[np.arange(T)[:, None], self.assign]

    def occupancy(self) -> np.ndarray:
        """(T, R) containers per region per epoch."""
        T, _ = self.assign.shape
        R = self.n_regions
        out = np.zeros((T, R), dtype=np.int64)
        for r in range(R):
            out[:, r] = (self.assign == r).sum(axis=1)
        return out


@dataclass
class PlacementResult:
    """A placed fleet run: the inner FleetResult plus the plan that drove
    it. Total emissions add the placement stop-and-copy overhead."""
    plan: PlacementPlan
    fleet: object                    # repro.core.fleet.FleetResult
    static_fleet: object = None      # optional no-migration baseline

    @property
    def total_emissions_g(self) -> np.ndarray:
        return self.fleet.emissions_g + self.plan.overhead_g

    @property
    def carbon_efficiency(self) -> np.ndarray:
        """Work done per kg CO2e, overhead included (paper's merit figure)."""
        kg = np.maximum(self.total_emissions_g / 1000.0, 1e-12)
        return self.fleet.work_done / kg

    @property
    def saving_vs_static_pct(self) -> float:
        """Fleet-total emissions saving vs the no-migration baseline."""
        if self.static_fleet is None:
            raise ValueError("run with compare_static=True to populate "
                             "the static baseline")
        stat = float(self.static_fleet.emissions_g.sum())
        moved = float(self.total_emissions_g.sum())
        return 100.0 * (stat - moved) / max(stat, 1e-12)


class PlacementEngine:
    """Assign an (N,) fleet across R regions, one decision per epoch.

    Usage::

        eng = PlacementEngine(paper_family(), providers, config=cfg)
        plan = eng.plan(demand)                       # (T, N) assignment
        res = eng.run(policy, demand, targets=45.0)   # placed fleet run

    `regions` is either a (T, R) intensity matrix or a sequence of
    providers exposing `intensity_series` (see repro.carbon.intensity).
    """

    def __init__(self, family: SliceFamily, regions,
                 interval_s: float = 300.0,
                 migration: Optional[MigrationCostModel] = None,
                 config: Optional[PlacementConfig] = None,
                 region_names: Optional[Sequence[str]] = None):
        self.family = family
        self.tables = family.tables()
        self.regions = regions
        self.interval_s = float(interval_s)
        self.mig = migration or MigrationCostModel()
        self.config = config or PlacementConfig()
        if isinstance(regions, np.ndarray):
            n_regions = regions.shape[1]
        else:
            n_regions = len(regions)
        if n_regions < 1:
            raise ValueError("need at least one region")
        if region_names is None:
            region_names = tuple(f"r{i}" for i in range(n_regions))
        if len(region_names) != n_regions:
            raise ValueError("region_names length does not match regions")
        self.region_names = tuple(region_names)
        self.n_regions = n_regions

    # -- inputs -----------------------------------------------------------

    def _region_matrix(self, T: int) -> np.ndarray:
        """(T, R) intensity at each epoch start."""
        if isinstance(self.regions, np.ndarray):
            m = np.asarray(self.regions, dtype=np.float64)
            if m.ndim != 2 or m.shape[1] != self.n_regions:
                raise ValueError(f"region matrix shape {m.shape}; expected "
                                 f"(T, {self.n_regions})")
            if m.shape[0] < T:
                raise ValueError(f"region matrix covers {m.shape[0]} epochs; "
                                 f"demand needs {T}")
            return m[:T]
        t = np.arange(T, dtype=np.float64) * self.interval_s
        return np.stack([p.intensity_series(t) for p in self.regions],
                        axis=1)

    def _initial_assignment(self, N: int, initial,
                            cap: Optional[np.ndarray]) -> np.ndarray:
        R = self.n_regions
        if cap is not None and int(cap.sum()) < N:
            raise ValueError(f"total capacity {int(cap.sum())} < fleet "
                             f"size {N}")
        if initial is None:
            if cap is None:
                assign = np.arange(N, dtype=np.int64) % R  # round-robin
            else:
                # capacity-aware round-robin: cycle regions, skipping
                # full ones, so uneven capacity vectors stay feasible
                rep_r = np.repeat(np.arange(R, dtype=np.int64), cap)
                rep_k = np.concatenate([np.arange(c) for c in cap])
                assign = rep_r[np.lexsort((rep_r, rep_k))][:N]
        else:
            assign = np.asarray(initial, dtype=np.int64).copy()
            if assign.shape != (N,):
                raise ValueError(f"initial assignment shape {assign.shape}; "
                                 f"expected ({N},)")
            if assign.size and (assign.min() < 0 or assign.max() >= R):
                raise ValueError("initial assignment region out of range")
        if cap is not None:
            occ = np.bincount(assign, minlength=R)
            if (occ > cap).any():
                raise ValueError("initial assignment exceeds region capacity")
        return assign

    def _prep(self, demand, state_gb, initial):
        demand = np.asarray(demand, dtype=np.float64)
        if demand.ndim == 1:
            demand = demand[:, None]
        if demand.ndim != 2:
            raise ValueError("demand must be (T,) or (T, N)")
        if demand.size and demand.min() < 0.0:
            raise ValueError("placement demand must be non-negative")
        T, N = demand.shape
        cmat = self._region_matrix(T)
        cap = self.config.capacity_vector(self.n_regions)
        assign = self._initial_assignment(N, initial, cap)
        state_gb = np.broadcast_to(
            np.asarray(state_gb, dtype=np.float64), (N,))
        # per-container stop-and-copy time & idle-power gram coefficient,
        # hoisted: state size and link bandwidth are epoch-invariant
        mig_s = self.mig.stop_and_copy_time_batch(
            state_gb, np.broadcast_to(self.config.link_gbps, (N,)))
        base_b = float(self.tables.base_w[self.tables.baseline_idx])
        cost0 = 2.0 * base_b * mig_s / 3600.0
        return demand, cmat, cap, assign, mig_s, cost0

    # -- vectorized planner (the production path) -------------------------

    def plan(self, demand, state_gb: float = 1.0,
             initial=None, faults=None) -> PlacementPlan:
        """(N, R)-vectorized plan; bit-compatible with `plan_scalar`.

        `faults` (a `repro.robustness.FaultPlan`) injects seeded
        migration failures: a failed attempt pays the full stop-and-copy
        cost (overhead grams + downtime) but the container stays put,
        then waits `min(backoff_base * 2**(k-1), backoff_cap)` epochs
        after its k-th consecutive failure before becoming eligible
        again (capped exponential backoff). A successful move resets
        the failure streak. Failed attempts land in
        `PlacementPlan.failed_migrations`.
        """
        from repro.robustness.faults import migration_failure_mask
        demand, cmat, cap, assign, mig_s, cost0 = self._prep(
            demand, state_gb, initial)
        T, N = demand.shape
        R = self.n_regions
        t = self.tables
        b = t.baseline_idx
        base_b = float(t.base_w[b])
        span_b = float(t.peak_w[b]) - base_b
        mult_b = float(t.multiple[b])
        h_hr = self.config.horizon_intervals * self.interval_s / 3600.0
        hk = 1.0 + self.config.hysteresis
        min_dwell = self.config.min_dwell
        fail_mat = migration_failure_mask(faults, T, N)
        if fail_mat is not None:
            bb = int(faults.migration.backoff_base)
            bc = int(faults.migration.backoff_cap)
            fail_cnt = np.zeros(N, dtype=np.int64)
            retry_at = np.zeros(N, dtype=np.int64)
        failed_migrations = (np.zeros(N, dtype=np.int64)
                             if fail_mat is not None else None)

        dwell = np.full(N, 10 ** 6, dtype=np.int64)   # first move is free
        migrations = np.zeros(N, dtype=np.int64)
        overhead_g = np.zeros(N, dtype=np.float64)
        downtime_s = np.zeros(N, dtype=np.float64)
        assign_mat = np.empty((T, N), dtype=np.int64)
        assign0 = assign.copy()
        occ = np.bincount(assign, minlength=R) if cap is not None else None
        rows = np.arange(N)

        for n in range(T):
            c_row = cmat[n]                            # (R,)
            p_est = base_b + span_b * np.minimum(demand[n] / mult_b, 1.0)
            c_cur = c_row[assign]                      # (N,)
            save = (p_est[:, None] * (c_cur[:, None] - c_row[None, :])
                    / 1000.0 * h_hr)
            cost = (cost0[:, None] * (0.5 * (c_cur[:, None] + c_row[None, :]))
                    / 1000.0)
            net = save - hk * cost                     # (N, R)
            eligible = dwell >= min_dwell
            if fail_mat is not None:
                eligible = eligible & (n >= retry_at)
            dst = np.full(N, -1, dtype=np.int64)

            if cap is None:
                best = np.argmax(net, axis=1)
                net_best = net[rows, best]
                m = eligible & (net_best > 0.0) & (best != assign)
                np.copyto(dst, best, where=m)
            else:
                # preference rounds: a denied choice is struck and the
                # container falls through to its next positive-net region
                remaining = cap - occ
                for _ in range(R):
                    best = np.argmax(net, axis=1)
                    net_best = net[rows, best]
                    want = (eligible & (dst < 0) & (net_best > 0.0)
                            & (best != assign))
                    if not np.count_nonzero(want):
                        break
                    denied_any = False
                    for r in range(R):
                        m = want & (best == r)
                        cnt = np.count_nonzero(m)
                        if not cnt:
                            continue
                        if remaining[r] <= 0:
                            net[m, r] = -np.inf
                            denied_any = True
                            continue
                        adm = m & (np.cumsum(m) <= remaining[r])
                        n_adm = np.count_nonzero(adm)
                        remaining[r] -= n_adm
                        dst[adm] = r
                        if n_adm < cnt:
                            net[m & ~adm, r] = -np.inf
                            denied_any = True
                    if not denied_any:
                        break

            attempted = dst >= 0
            if fail_mat is None:
                moved = attempted
            else:
                failed = attempted & fail_mat[n]
                moved = attempted & ~failed
            if np.count_nonzero(attempted):
                # every attempt — failed or not — pays stop-and-copy:
                # the container was checkpointed and (partially) copied
                # before the destination rejected it
                src = assign[attempted]
                dst_a = dst[attempted]
                overhead_g[attempted] += (cost0[attempted]
                                          * (0.5 * (c_row[src]
                                                    + c_row[dst_a]))
                                          / 1000.0)
                downtime_s[attempted] += mig_s[attempted]
                migrations[moved] += 1
                if fail_mat is not None:
                    failed_migrations[failed] += 1
                    fail_cnt[failed] += 1
                    fail_cnt[moved] = 0
                    if np.count_nonzero(failed):
                        k = np.minimum(fail_cnt[failed] - 1, 20)
                        retry_at[failed] = n + 1 + np.minimum(
                            bb * (2 ** k), bc)
                if occ is not None and np.count_nonzero(moved):
                    np.subtract.at(occ, assign[moved], 1)
                    np.add.at(occ, dst[moved], 1)
                assign = np.where(moved, dst, assign)
            dwell += 1
            dwell[moved] = 0
            assign_mat[n] = assign

        return PlacementPlan(assign=assign_mat, migrations=migrations,
                             overhead_g=overhead_g, downtime_s=downtime_s,
                             region_intensity=cmat,
                             region_names=self.region_names,
                             initial=assign0,
                             failed_migrations=failed_migrations)

    # -- greedy scalar reference (parity oracle) --------------------------

    def plan_scalar(self, demand, state_gb: float = 1.0,
                    initial=None, faults=None) -> PlacementPlan:
        """Pure-Python greedy reference; every float expression mirrors
        `plan` term-for-term, so the two agree bit-for-bit (including
        the migration-failure + capped-backoff retry state)."""
        from repro.robustness.faults import migration_failure_mask
        demand, cmat, cap, assign0, mig_s, cost0 = self._prep(
            demand, state_gb, initial)
        T, N = demand.shape
        R = self.n_regions
        t = self.tables
        b = t.baseline_idx
        base_b = float(t.base_w[b])
        span_b = float(t.peak_w[b]) - base_b
        mult_b = float(t.multiple[b])
        h_hr = self.config.horizon_intervals * self.interval_s / 3600.0
        hk = 1.0 + self.config.hysteresis
        min_dwell = self.config.min_dwell

        assign = [int(a) for a in assign0]
        dwell = [10 ** 6] * N
        migrations = np.zeros(N, dtype=np.int64)
        overhead_g = np.zeros(N, dtype=np.float64)
        downtime_s = np.zeros(N, dtype=np.float64)
        assign_mat = np.empty((T, N), dtype=np.int64)
        occ = ([int(x) for x in np.bincount(assign0, minlength=R)]
               if cap is not None else None)
        fail_mat = migration_failure_mask(faults, T, N)
        if fail_mat is not None:
            bb = int(faults.migration.backoff_base)
            bc = int(faults.migration.backoff_cap)
            fail_cnt = [0] * N
            retry_at = [0] * N
        failed_migrations = (np.zeros(N, dtype=np.int64)
                             if fail_mat is not None else None)

        for n in range(T):
            c_row = [float(x) for x in cmat[n]]
            # per-container nets are epoch-constant (moves apply at epoch
            # end), so compute the (N, R) table once, as `plan` does
            nets = []
            for i in range(N):
                a = assign[i]
                d = float(demand[n, i])
                u = d / mult_b
                if u > 1.0:
                    u = 1.0
                p_est = base_b + span_b * u
                c_a = c_row[a]
                row = []
                for r in range(R):
                    save = p_est * (c_a - c_row[r]) / 1000.0 * h_hr
                    cost = (float(cost0[i]) * (0.5 * (c_a + c_row[r]))
                            / 1000.0)
                    row.append(save - hk * cost)
                nets.append(row)
            dst = [-1] * N
            remaining = ([int(cap[r]) - occ[r] for r in range(R)]
                         if occ is not None else None)
            rounds = R if remaining is not None else 1
            for _ in range(rounds):
                any_want = False
                denied_any = False
                # argmax snapshot at round start: strikes this round only
                # touch a container's own row, after its own argmax
                for i in range(N):
                    if dst[i] >= 0 or dwell[i] < min_dwell:
                        continue
                    if fail_mat is not None and n < retry_at[i]:
                        continue               # backing off after a failure
                    row = nets[i]
                    best, net_best = 0, row[0]
                    for r in range(1, R):
                        if row[r] > net_best:
                            best, net_best = r, row[r]
                    if not (net_best > 0.0 and best != assign[i]):
                        continue
                    any_want = True
                    if remaining is not None:
                        if remaining[best] <= 0:
                            row[best] = -np.inf       # fall through next round
                            denied_any = True
                            continue
                        remaining[best] -= 1
                    dst[i] = best
                if not any_want or not denied_any:
                    break
            moved = [False] * N
            for i in range(N):
                if dst[i] < 0:
                    continue
                a = assign[i]
                # every attempt pays stop-and-copy, failed or not
                overhead_g[i] += (float(cost0[i])
                                  * (0.5 * (c_row[a] + c_row[dst[i]]))
                                  / 1000.0)
                downtime_s[i] += float(mig_s[i])
                if fail_mat is not None and fail_mat[n, i]:
                    failed_migrations[i] += 1
                    fail_cnt[i] += 1
                    k = min(fail_cnt[i] - 1, 20)
                    retry_at[i] = n + 1 + min(bb * (2 ** k), bc)
                    continue                   # pays the cost, stays put
                migrations[i] += 1
                if fail_mat is not None:
                    fail_cnt[i] = 0
                if occ is not None:
                    occ[a] -= 1
                    occ[dst[i]] += 1
                assign[i] = dst[i]
                moved[i] = True
            for i in range(N):
                dwell[i] = 0 if moved[i] else dwell[i] + 1
            assign_mat[n] = assign

        return PlacementPlan(assign=assign_mat, migrations=migrations,
                             overhead_g=overhead_g, downtime_s=downtime_s,
                             region_intensity=cmat,
                             region_names=self.region_names,
                             initial=assign0.copy(),
                             failed_migrations=failed_migrations)

    # -- placed fleet runs -------------------------------------------------

    def run(self, policy, demand, targets, epsilon: float = 0.05,
            state_gb=1.0, demand_scale=1.0, initial=None,
            record: bool = False, plan: Optional[PlacementPlan] = None,
            compare_static: bool = False) -> PlacementResult:
        """Plan placement, then advance the fleet on the planned regions.

        `plan` reuses a precomputed `PlacementPlan` (must come from this
        engine's `plan`/`plan_scalar` on the same scaled demand) instead
        of re-planning. With `compare_static=True` the same fleet is
        also run frozen on the plan's own initial assignment (the
        no-migration baseline), populating
        `PlacementResult.saving_vs_static_pct`.
        """
        from repro.core.fleet import FleetSimulator
        demand = np.asarray(demand, dtype=np.float64)
        if demand.ndim == 1:
            demand = demand[:, None]
        scaled = demand
        if demand_scale is not None and np.any(
                np.asarray(demand_scale) != 1.0):
            scaled = demand * demand_scale
        if plan is None:
            plan = self.plan(scaled, state_gb=state_gb, initial=initial)
        elif plan.assign.shape != scaled.shape:
            raise ValueError(f"plan covers {plan.assign.shape}, demand is "
                             f"{scaled.shape}")
        sim = FleetSimulator(self.family, interval_s=self.interval_s,
                             migration=self.mig)
        fleet = sim.run(policy, scaled, plan.carbon_matrix(), targets,
                        epsilon=epsilon, state_gb=state_gb, record=record)
        static_fleet = None
        if compare_static:
            # baseline from the plan's own initial assignment, so a
            # precomputed plan compares against the start it was built on
            cmat = plan.region_intensity[:, plan.initial]
            static_fleet = sim.run(policy, scaled, cmat, targets,
                                   epsilon=epsilon, state_gb=state_gb,
                                   record=record)
        return PlacementResult(plan=plan, fleet=fleet,
                               static_fleet=static_fleet)
