"""Slice family + migration cost models (the server-catalog substrate)."""
from repro.cluster.slices import Slice, SliceFamily, paper_family, tpu_v5e_family
from repro.cluster.migration import MigrationCostModel

__all__ = ["Slice", "SliceFamily", "paper_family", "tpu_v5e_family",
           "MigrationCostModel"]
