"""Slice family, migration cost model and multi-region placement (the
server-catalog substrate)."""
from repro.cluster.slices import Slice, SliceFamily, paper_family, tpu_v5e_family
from repro.cluster.migration import MigrationCostModel
from repro.cluster.placement import (PlacementConfig, PlacementEngine,
                                     PlacementPlan, PlacementResult)

__all__ = ["Slice", "SliceFamily", "paper_family", "tpu_v5e_family",
           "MigrationCostModel", "PlacementConfig", "PlacementEngine",
           "PlacementPlan", "PlacementResult"]
