"""JAX port of the multi-region placement planner: jit/scan, device-resident.

`repro.cluster.placement.PlacementEngine.plan` advances an (N,) fleet's
region assignment epoch by epoch with NumPy array state — fast enough
for hundreds of containers, but the per-epoch Python round-trip caps
fleet-scale what-if sweeps. This module runs the same decision model as
one `jax.lax.scan` over epochs:

  - the (N, R) migrate/stay kernel (horizon-amortized saving vs
    stop-and-copy cost, hysteresis + min-dwell) evaluates per epoch on
    device, float64 end-to-end (`enable_x64`, scoped);
  - capacity admission runs the same preference rounds as the NumPy
    kernel inside a `lax.while_loop` bounded at R rounds, with the NumPy
    loop's early exit (a round that wants nothing or denies nothing ends
    the loop — further rounds would be no-ops) and a `lax.cond` fast
    path that skips rank materialization when every request fits; note
    the data-dependent trip count means the planner is not
    reverse-differentiable as-is — switch to a fixed-trip fori_loop
    first if you need gradients through admission;
  - one host->device push of (cmat, demand, cost0, mig_s), one pull of
    the final carry + the (T, N) assignment matrix.

The result is the same `PlacementPlan` dataclass; parity against the
NumPy planner is pinned to 1e-6 (assignments equal epoch-by-epoch) by
`tests/test_placement_jax.py`, and the NumPy planner stays pinned
bit-compatible to the greedy scalar reference, anchoring the chain.
"""
from __future__ import annotations

from functools import partial

import numpy as np

from repro.cluster.placement import PlacementPlan

try:
    import jax
    import jax.numpy as jnp
    from jax import lax
    from jax.experimental import enable_x64
    HAS_JAX = True
except ImportError:                                    # pragma: no cover
    HAS_JAX = False
    jax = jnp = lax = enable_x64 = None


def _require_jax():
    if not HAS_JAX:
        raise ImportError("plan_jax requires jax; install jax[cpu] or use "
                          "PlacementEngine.plan")


def _sel_region(c_row, idx, R: int):
    """(N,) gather of the (R,) epoch intensities at per-container region
    indices, as a select chain (R is small and static)."""
    out = jnp.full(idx.shape, c_row[0], dtype=jnp.float64)
    for r in range(1, R):
        out = jnp.where(idx == r, c_row[r], out)
    return out


@partial(jax.jit if HAS_JAX else lambda f, **kw: f,
         static_argnames=("R", "min_dwell", "has_cap", "base_b", "span_b",
                          "mult_b", "h_hr", "hk"))
def _plan_scan(cmat, demand, assign0, occ0, cap, cost0, mig_s, *, R: int,
               min_dwell: int, has_cap: bool, base_b: float, span_b: float,
               mult_b: float, h_hr: float, hk: float):
    """One XLA computation for the whole planning horizon. Mirrors
    `PlacementEngine.plan` term-for-term (see its docstring for the
    decision model)."""
    N = demand.shape[1]
    rows_r = jnp.arange(R, dtype=jnp.int32)

    def step(st, x):
        assign, dwell, migrations, overhead_g, downtime_s, occ = st
        c_row, d = x
        p_est = base_b + span_b * jnp.minimum(d / mult_b, 1.0)
        c_cur = _sel_region(c_row, assign, R)
        save = (p_est[:, None] * (c_cur[:, None] - c_row[None, :])
                / 1000.0 * h_hr)
        cost = (cost0[:, None] * (0.5 * (c_cur[:, None] + c_row[None, :]))
                / 1000.0)
        net = save - hk * cost                     # (N, R)
        eligible = dwell >= min_dwell

        if not has_cap:
            best = jnp.argmax(net, axis=1).astype(jnp.int32)
            net_best = jnp.max(net, axis=1)
            m = eligible & (net_best > 0.0) & (best != assign)
            dst = jnp.where(m, best, -1)
        else:
            # preference rounds, bounded at R like the NumPy kernel and
            # with its early exit (a round with nothing wanted or
            # nothing denied ends the loop — extra rounds would be
            # no-ops). Ranks are only materialized when some region
            # actually overflows; the common all-admitted epoch skips
            # the prefix scan entirely.
            remaining0 = cap - occ

            def round_cond(rst):
                _, _, _, rnd, cont = rst
                return cont & (rnd < R)

            def round_body(rst):
                net_r, dst_r, remaining_r, rnd, _ = rst
                best = jnp.argmax(net_r, axis=1).astype(jnp.int32)
                net_best = jnp.max(net_r, axis=1)
                want = (eligible & (dst_r < 0) & (net_best > 0.0)
                        & (best != assign))
                onehot = want[:, None] & (best[:, None] == rows_r[None, :])
                counts = onehot.sum(axis=0, dtype=jnp.int32)

                def admit_all(_):
                    return onehot

                def admit_ranked(_):
                    rank = lax.associative_scan(
                        jnp.add, onehot.astype(jnp.int32), axis=0)
                    return onehot & (rank <= remaining_r[None, :])

                adm = lax.cond(jnp.all(counts <= remaining_r),
                               admit_all, admit_ranked, None)
                admitted = adm.any(axis=1)
                dst_r = jnp.where(admitted, best, dst_r)
                remaining_r = remaining_r - adm.sum(axis=0,
                                                    dtype=jnp.int32)
                denied = want & ~admitted
                net_r = jnp.where(onehot & denied[:, None], -jnp.inf,
                                  net_r)
                cont = jnp.any(want) & jnp.any(denied)
                return (net_r, dst_r, remaining_r, rnd + 1, cont)

            dst0 = jnp.full(N, -1, dtype=jnp.int32)
            net, dst, remaining, _, _ = lax.while_loop(
                round_cond, round_body,
                (net, dst0, remaining0, jnp.int32(0), jnp.bool_(True)))

        moved = dst >= 0
        dst_c = jnp.where(moved, dst, 0)
        c_dst = _sel_region(c_row, dst_c, R)
        overhead_g = overhead_g + jnp.where(
            moved, cost0 * (0.5 * (c_cur + c_dst)) / 1000.0, 0.0)
        downtime_s = downtime_s + jnp.where(moved, mig_s, 0.0)
        migrations = migrations + moved
        if has_cap:
            src_oh = moved[:, None] & (assign[:, None] == rows_r[None, :])
            dst_oh = moved[:, None] & (dst_c[:, None] == rows_r[None, :])
            occ = (occ - src_oh.sum(axis=0, dtype=jnp.int32)
                   + dst_oh.sum(axis=0, dtype=jnp.int32))
        assign = jnp.where(moved, dst, assign)
        dwell = jnp.where(moved, 0, dwell + 1)
        return (assign, dwell, migrations, overhead_g, downtime_s,
                occ), assign

    N_ = demand.shape[1]
    carry0 = (assign0,
              jnp.full(N_, 10 ** 6, dtype=jnp.int32),    # first move free
              jnp.zeros(N_, dtype=jnp.int32),
              jnp.zeros(N_, dtype=jnp.float64),
              jnp.zeros(N_, dtype=jnp.float64),
              occ0)
    carry, assign_mat = lax.scan(step, carry0, (cmat, demand))
    return carry, assign_mat


def plan_jax(engine, demand, state_gb: float = 1.0,
             initial=None) -> PlacementPlan:
    """Device-resident counterpart of `PlacementEngine.plan`: same
    inputs, same `PlacementPlan` out, one jit-compiled scan per shape.
    Parity with the NumPy planner is pinned to 1e-6 (and the planner to
    the scalar reference at 1e-9) by the test suite."""
    _require_jax()
    demand, cmat, cap, assign0, mig_s, cost0 = engine._prep(
        demand, state_gb, initial)
    T, N = demand.shape
    R = engine.n_regions
    t = engine.tables
    b = t.baseline_idx
    base_b = float(t.base_w[b])
    span_b = float(t.peak_w[b]) - base_b
    mult_b = float(t.multiple[b])
    cfg = engine.config
    h_hr = cfg.horizon_intervals * engine.interval_s / 3600.0
    hk = 1.0 + cfg.hysteresis

    has_cap = cap is not None
    occ_host = (np.bincount(assign0, minlength=R).astype(np.int32)
                if has_cap else np.zeros(R, dtype=np.int32))
    cap_host = (cap.astype(np.int32) if has_cap
                else np.zeros(R, dtype=np.int32))

    with enable_x64():
        carry, assign_mat = _plan_scan(
            jnp.asarray(cmat), jnp.asarray(demand),
            jnp.asarray(assign0.astype(np.int32)),
            jnp.asarray(occ_host), jnp.asarray(cap_host),
            jnp.asarray(cost0), jnp.asarray(mig_s),
            R=R, min_dwell=int(cfg.min_dwell), has_cap=has_cap,
            base_b=base_b, span_b=span_b, mult_b=mult_b,
            h_hr=float(h_hr), hk=float(hk))
        (_, _, migrations, overhead_g, downtime_s, _) = jax.device_get(carry)
        assign_mat = jax.device_get(assign_mat)

    return PlacementPlan(assign=assign_mat.astype(np.int64),
                         migrations=migrations.astype(np.int64),
                         overhead_g=overhead_g,
                         downtime_s=downtime_s,
                         region_intensity=cmat,
                         region_names=engine.region_names,
                         initial=assign0.copy())
