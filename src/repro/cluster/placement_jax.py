"""JAX port of the multi-region placement planner: jit/scan, device-resident.

`repro.cluster.placement.PlacementEngine.plan` advances an (N,) fleet's
region assignment epoch by epoch with NumPy array state — fast enough
for hundreds of containers, but the per-epoch Python round-trip caps
fleet-scale what-if sweeps. This module runs the same decision model as
one `jax.lax.scan` over epochs:

  - the (N, R) migrate/stay kernel (horizon-amortized saving vs
    stop-and-copy cost, hysteresis + min-dwell) evaluates per epoch on
    device, float64 end-to-end (`enable_x64`, scoped);
  - capacity admission runs the same preference rounds as the NumPy
    kernel inside a `lax.while_loop` bounded at R rounds, with the NumPy
    loop's early exit (a round that wants nothing or denies nothing ends
    the loop — further rounds would be no-ops); the round carry is two
    packed int32 vectors (dst + a denied-region strike bitmask) plus the
    (R,) free-slot counters, so no (N, R) tensor outlives a round; note
    the data-dependent trip count means the planner is not
    reverse-differentiable as-is — switch to a fixed-trip fori_loop
    first if you need gradients through admission;
  - one host->device push of (cmat, demand, cost0, mig_s), one pull of
    the final carry + the (T, N) int32 assignment matrix.

Why the ranked admission is the one hot path XLA handles badly
--------------------------------------------------------------
Admission is a *sequential contention loop*: container i wins region r
iff fewer than ``remaining[r]`` wanters of r precede it in index order.
The pure-XLA rendering (``admission_impl="xla"``) ranks wanters with a
global ``lax.associative_scan`` over the (N, R) one-hot request matrix —
an O(N R log N) multi-pass tree whose log N intermediate (N, R) stages
each round-trip through memory; on XLA:CPU (no multi-output loop
fusion, see `repro.core.fleet_jax`) the surrounding argmax/strike chain
is then re-materialized per stage, and a ``lax.cond`` fast path that
skips ranking when every request fits only helps uncontended epochs.
The Pallas kernel (``admission_impl="pallas"``,
`repro.cluster.placement_pallas`) instead streams container blocks
through a grid with per-region "wanters seen so far" counters in SMEM —
rank becomes counter + in-block prefix count, and the whole round is
one O(N R) pass with the argmax, ranking, admission, and strike fused
in a single kernel. ``"auto"`` picks pallas on TPU/GPU and the XLA
rendering on CPU, where pallas runs in interpret mode (correct and
parity-tested, but built from the same XLA ops it is meant to replace).

The result is the same `PlacementPlan` dataclass; parity against the
NumPy planner is pinned to 1e-6 (assignments equal epoch-by-epoch) by
`tests/test_placement_jax.py` for both admission impls (pallas in
interpret mode), and the NumPy planner stays pinned bit-compatible to
the greedy scalar reference, anchoring the chain.

Degenerate shapes short-circuit before tracing: an empty fleet (N=0), a
single region (R=1, where no container can ever move), or an empty
horizon (T=0) return the trivial plan without compiling the scan.
"""
from __future__ import annotations

from functools import partial

import numpy as np

from repro.cluster.placement import PlacementPlan

try:
    import jax
    import jax.numpy as jnp
    from jax import lax
    from jax.experimental import enable_x64
    HAS_JAX = True
except ImportError:                                    # pragma: no cover
    HAS_JAX = False
    jax = jnp = lax = enable_x64 = None

ADMISSION_IMPLS = ("auto", "xla", "pallas")


def _require_jax():
    if not HAS_JAX:
        raise ImportError("plan_jax requires jax; install jax[cpu] or use "
                          "PlacementEngine.plan")


def _sel_region(c_row, idx, R: int):
    """(N,) gather of the (R,) epoch intensities at per-container region
    indices, as a select chain (R is small and static)."""
    out = jnp.full(idx.shape, c_row[0], dtype=jnp.float64)
    for r in range(1, R):
        out = jnp.where(idx == r, c_row[r], out)
    return out


def _admission_round_xla(net, assign, eligible, dst, struck, remaining,
                         rows_r):
    """One preference round, pure-XLA: associative-scan ranking with a
    `lax.cond` fast path for uncontended rounds. Same (dst, struck,
    want_total) contract as `placement_pallas.admission_round`."""
    cols = rows_r[None, :]
    net_eff = jnp.where(((struck[:, None] >> cols) & 1) > 0, -jnp.inf, net)
    best = jnp.argmax(net_eff, axis=1).astype(jnp.int32)
    net_best = jnp.max(net_eff, axis=1)
    want = eligible & (dst < 0) & (net_best > 0.0) & (best != assign)
    onehot = want[:, None] & (best[:, None] == cols)
    counts = onehot.sum(axis=0, dtype=jnp.int32)

    def admit_all(_):
        return onehot

    def admit_ranked(_):
        rank = lax.associative_scan(jnp.add, onehot.astype(jnp.int32),
                                    axis=0)
        return onehot & (rank <= remaining[None, :])

    adm = lax.cond(jnp.all(counts <= remaining), admit_all, admit_ranked,
                   None)
    admitted = adm.any(axis=1)
    dst = jnp.where(admitted, best, dst)
    denied = want & ~admitted
    struck = jnp.where(denied, struck | (1 << best), struck)
    return dst, struck, counts


@partial(jax.jit if HAS_JAX else lambda f, **kw: f,
         static_argnames=("R", "min_dwell", "has_cap", "base_b", "span_b",
                          "mult_b", "h_hr", "hk", "admission_impl",
                          "block_n", "interpret", "has_faults", "bb", "bc"))
def _plan_scan(cmat, demand, assign0, occ0, cap, cost0, mig_s,
               fail_mat=None, *, R: int,
               min_dwell: int, has_cap: bool, base_b: float, span_b: float,
               mult_b: float, h_hr: float, hk: float,
               admission_impl: str = "xla", block_n: int = 8192,
               interpret: bool = True, has_faults: bool = False,
               bb: int = 1, bc: int = 16):
    """One XLA computation for the whole planning horizon. Mirrors
    `PlacementEngine.plan` term-for-term (see its docstring for the
    decision model). `admission_impl` here is already resolved to
    "xla" or "pallas" (`plan_jax` resolves "auto"). With
    `has_faults`, `fail_mat` is the shared (T, N) failed-migration
    mask and the carry gains the retry state (fail streak + earliest
    retry epoch, capped exponential backoff `min(bb * 2**k, bc)`)."""
    N = demand.shape[1]
    rows_r = jnp.arange(R, dtype=jnp.int32)
    T = demand.shape[0]
    t_vec = jnp.arange(T, dtype=jnp.int64)

    def step(st, x):
        if has_faults:
            (assign, dwell, migrations, overhead_g, downtime_s, occ,
             fail_cnt, retry_at, failed_migrations) = st
            c_row, d, fail_row, t_i = x
        else:
            assign, dwell, migrations, overhead_g, downtime_s, occ = st
            c_row, d = x
        p_est = base_b + span_b * jnp.minimum(d / mult_b, 1.0)
        c_cur = _sel_region(c_row, assign, R)
        save = (p_est[:, None] * (c_cur[:, None] - c_row[None, :])
                / 1000.0 * h_hr)
        cost = (cost0[:, None] * (0.5 * (c_cur[:, None] + c_row[None, :]))
                / 1000.0)
        net = save - hk * cost                     # (N, R)
        eligible = dwell >= min_dwell
        if has_faults:
            eligible = eligible & (t_i >= retry_at)

        if not has_cap:
            best = jnp.argmax(net, axis=1).astype(jnp.int32)
            net_best = jnp.max(net, axis=1)
            m = eligible & (net_best > 0.0) & (best != assign)
            dst = jnp.where(m, best, -1)
        else:
            # preference rounds, bounded at R like the NumPy kernel and
            # with its early exit (a round with nothing wanted or
            # nothing denied ends the loop — extra rounds would be
            # no-ops). The round carry is packed int32 (dst + strike
            # bitmask); `net` stays round-invariant and denied choices
            # accumulate in the bitmask, so admitted(r) ==
            # min(want_total[r], remaining[r]) closes the counters.
            remaining0 = cap - occ

            def round_cond(rst):
                _, _, _, rnd, cont = rst
                return cont & (rnd < R)

            def round_body(rst):
                dst_r, struck_r, remaining_r, rnd, _ = rst
                if admission_impl == "pallas":
                    from repro.cluster.placement_pallas import \
                        admission_round
                    dst_r, struck_r, want_tot = admission_round(
                        net, assign, eligible, dst_r, struck_r,
                        remaining_r, block_n=block_n, interpret=interpret)
                else:
                    dst_r, struck_r, want_tot = _admission_round_xla(
                        net, assign, eligible, dst_r, struck_r,
                        remaining_r, rows_r)
                admitted_tot = jnp.minimum(want_tot, remaining_r)
                remaining_n = remaining_r - admitted_tot
                cont = (jnp.any(want_tot > 0)
                        & jnp.any(want_tot > admitted_tot))
                return (dst_r, struck_r, remaining_n, rnd + 1, cont)

            dst0 = jnp.full(N, -1, dtype=jnp.int32)
            struck0 = jnp.zeros(N, dtype=jnp.int32)
            dst, _, remaining, _, _ = lax.while_loop(
                round_cond, round_body,
                (dst0, struck0, remaining0, jnp.int32(0), jnp.bool_(True)))

        attempted = dst >= 0
        if has_faults:
            failed = attempted & fail_row
            moved = attempted & ~failed
        else:
            moved = attempted
        dst_c = jnp.where(attempted, dst, 0)
        c_dst = _sel_region(c_row, dst_c, R)
        # every attempt — failed or not — pays stop-and-copy: the
        # container was checkpointed and (partially) copied before the
        # destination rejected it
        overhead_g = overhead_g + jnp.where(
            attempted, cost0 * (0.5 * (c_cur + c_dst)) / 1000.0, 0.0)
        downtime_s = downtime_s + jnp.where(attempted, mig_s, 0.0)
        migrations = migrations + moved
        if has_faults:
            failed_migrations = failed_migrations + failed
            fail_cnt = jnp.where(failed, fail_cnt + 1,
                                 jnp.where(moved, 0, fail_cnt))
            k = jnp.minimum(fail_cnt - 1, 20)
            delay = jnp.minimum(bb * (2 ** jnp.maximum(k, 0)), bc)
            retry_at = jnp.where(failed, t_i + 1 + delay, retry_at)
        if has_cap:
            src_oh = moved[:, None] & (assign[:, None] == rows_r[None, :])
            dst_oh = moved[:, None] & (dst_c[:, None] == rows_r[None, :])
            occ = (occ - src_oh.sum(axis=0, dtype=jnp.int32)
                   + dst_oh.sum(axis=0, dtype=jnp.int32))
        assign = jnp.where(moved, dst, assign)
        dwell = jnp.where(moved, 0, dwell + 1)
        if has_faults:
            return (assign, dwell, migrations, overhead_g, downtime_s,
                    occ, fail_cnt, retry_at, failed_migrations), assign
        return (assign, dwell, migrations, overhead_g, downtime_s,
                occ), assign

    N_ = demand.shape[1]
    carry0 = (assign0,
              jnp.full(N_, 10 ** 6, dtype=jnp.int32),    # first move free
              jnp.zeros(N_, dtype=jnp.int32),
              jnp.zeros(N_, dtype=jnp.float64),
              jnp.zeros(N_, dtype=jnp.float64),
              occ0)
    if has_faults:
        carry0 = carry0 + (jnp.zeros(N_, dtype=jnp.int64),   # fail_cnt
                           jnp.zeros(N_, dtype=jnp.int64),   # retry_at
                           jnp.zeros(N_, dtype=jnp.int64))   # failed count
        xs = (cmat, demand, fail_mat, t_vec)
    else:
        xs = (cmat, demand)
    carry, assign_mat = lax.scan(step, carry0, xs)
    return carry, assign_mat


def _trivial_plan(engine, cmat, assign0, has_faults=False) -> PlacementPlan:
    """Plan for shapes where no move is ever possible (N=0, R=1, T=0):
    every epoch keeps the initial assignment, zero overhead."""
    T = cmat.shape[0]
    N = assign0.shape[0]
    return PlacementPlan(
        assign=np.broadcast_to(assign0, (T, N)).copy(),
        migrations=np.zeros(N, dtype=np.int64),
        overhead_g=np.zeros(N, dtype=np.float64),
        downtime_s=np.zeros(N, dtype=np.float64),
        region_intensity=cmat,
        region_names=engine.region_names,
        initial=assign0.copy(),
        failed_migrations=np.zeros(N, dtype=np.int64) if has_faults
        else None)


def plan_jax(engine, demand, state_gb: float = 1.0, initial=None,
             admission_impl: str = "auto",
             block_n: int = 8192, faults=None) -> PlacementPlan:
    """Device-resident counterpart of `PlacementEngine.plan`: same
    inputs, same `PlacementPlan` out, one jit-compiled scan per shape.

    `admission_impl` selects the capacity-admission kernel: `"xla"`
    (associative-scan ranking), `"pallas"` (streaming Pallas kernel,
    interpret mode on CPU; `block_n` containers per grid step), or
    `"auto"` — pallas on TPU/GPU, xla on CPU (see module docstring).
    Both are pinned to the NumPy planner by the parity suite (and the
    planner to the scalar reference at 1e-9).

    `faults` (a `repro.robustness.FaultPlan`) injects the same seeded
    migration-failure mask as `PlacementEngine.plan` — failed attempts
    pay stop-and-copy but stay put and retry under capped exponential
    backoff; parity with the NumPy planner is preserved because the
    mask derivation is shared.
    """
    _require_jax()
    if admission_impl not in ADMISSION_IMPLS:
        raise ValueError(f"admission_impl must be one of {ADMISSION_IMPLS}, "
                         f"got {admission_impl!r}")
    from repro.robustness.faults import migration_failure_mask
    demand, cmat, cap, assign0, mig_s, cost0 = engine._prep(
        demand, state_gb, initial)
    T, N = demand.shape
    R = engine.n_regions
    fail_mat = migration_failure_mask(faults, T, N)
    if N == 0 or R == 1 or T == 0:
        # nothing can ever move: N=0 has no containers, R=1 has no
        # destination (argmax == current region always), T=0 no epochs —
        # skip tracing/compiling the round loop entirely
        return _trivial_plan(engine, cmat, assign0,
                             has_faults=fail_mat is not None)
    if admission_impl == "auto":
        from repro.cluster.placement_pallas import default_interpret
        admission_impl = "xla" if default_interpret() else "pallas"
    t = engine.tables
    b = t.baseline_idx
    base_b = float(t.base_w[b])
    span_b = float(t.peak_w[b]) - base_b
    mult_b = float(t.multiple[b])
    cfg = engine.config
    h_hr = cfg.horizon_intervals * engine.interval_s / 3600.0
    hk = 1.0 + cfg.hysteresis

    has_cap = cap is not None
    occ_host = (np.bincount(assign0, minlength=R).astype(np.int32)
                if has_cap else np.zeros(R, dtype=np.int32))
    cap_host = (cap.astype(np.int32) if has_cap
                else np.zeros(R, dtype=np.int32))

    interpret = True
    if admission_impl == "pallas":
        from repro.cluster.placement_pallas import default_interpret
        interpret = default_interpret()

    has_faults = fail_mat is not None
    fault_kw = {}
    if has_faults:
        fault_kw = dict(has_faults=True,
                        bb=int(faults.migration.backoff_base),
                        bc=int(faults.migration.backoff_cap))

    with enable_x64():
        carry, assign_mat = _plan_scan(
            jnp.asarray(cmat), jnp.asarray(demand),
            jnp.asarray(assign0.astype(np.int32)),
            jnp.asarray(occ_host), jnp.asarray(cap_host),
            jnp.asarray(cost0), jnp.asarray(mig_s),
            jnp.asarray(fail_mat) if has_faults else None,
            R=R, min_dwell=int(cfg.min_dwell), has_cap=has_cap,
            base_b=base_b, span_b=span_b, mult_b=mult_b,
            h_hr=float(h_hr), hk=float(hk),
            admission_impl=admission_impl, block_n=int(block_n),
            interpret=interpret, **fault_kw)
        carry = jax.device_get(carry)
        migrations, overhead_g, downtime_s = carry[2], carry[3], carry[4]
        failed_migrations = (carry[8].astype(np.int64) if has_faults
                             else None)
        assign_mat = jax.device_get(assign_mat)

    return PlacementPlan(assign=assign_mat.astype(np.int64),
                         migrations=migrations.astype(np.int64),
                         overhead_g=overhead_g,
                         downtime_s=downtime_s,
                         region_intensity=cmat,
                         region_names=engine.region_names,
                         initial=assign0.copy(),
                         failed_migrations=failed_migrations)
