"""Carbon-intensity providers (the electricityMap-API role, offline).

Providers expose ``intensity(t_seconds)`` in g·CO₂e/kWh. Consistent with the
paper (§3.1.2), intensity is piecewise-constant per hour: LXCC polls the API
hourly because grid generator mixes change slowly.
"""
from __future__ import annotations

from typing import Protocol, Sequence

import numpy as np

from repro.carbon.traces import fill_gaps, synth_trace


class CarbonIntensityProvider(Protocol):
    def intensity(self, t_seconds: float) -> float: ...


class ConstantProvider:
    def __init__(self, value: float):
        self.value = float(value)

    def intensity(self, t_seconds: float) -> float:
        return self.value

    def intensity_series(self, t_seconds: np.ndarray) -> np.ndarray:
        """Vectorized lookup for the fleet simulator: one value per time."""
        return np.full(np.shape(t_seconds), self.value, dtype=np.float64)


class TraceProvider:
    """Hourly trace, piecewise constant, wraps around at the end.

    `gap_policy` guards against NaN gaps in the source trace (a missed
    API sample): "raise" (default) rejects them at construction —
    before they can propagate into emissions totals silently — while
    "interpolate"/"hold" repair them via `repro.carbon.traces.fill_gaps`.
    """

    def __init__(self, hourly: Sequence[float], start_s: float = 0.0,
                 gap_policy: str = "raise"):
        self.hourly = np.asarray(hourly, dtype=np.float64)
        self.start_s = start_s
        if len(self.hourly) == 0:
            raise ValueError("empty carbon trace")
        self.hourly = fill_gaps(self.hourly, gap_policy)

    @classmethod
    def for_region(cls, region: str, hours: int = 24 * 30, seed: int = 0):
        return cls(synth_trace(region, hours, seed))

    def intensity(self, t_seconds: float) -> float:
        idx = int((t_seconds - self.start_s) // 3600.0) % len(self.hourly)
        return float(self.hourly[idx])

    def intensity_series(self, t_seconds: np.ndarray) -> np.ndarray:
        """Vectorized lookup: same piecewise-hourly floor-div as `intensity`."""
        t = np.asarray(t_seconds, dtype=np.float64)
        idx = ((t - self.start_s) // 3600.0).astype(np.int64) % len(self.hourly)
        return self.hourly[idx]
