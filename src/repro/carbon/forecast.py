"""Causal forecasters for carbon-intensity and demand series.

The elasticity layer (`repro.core.elasticity`) allocates per-container
capacity each epoch from *estimates* of that epoch's carbon intensity
and demand. These forecasters turn the trailing observations into those
estimates, strictly causally: the forecast for epoch t reads only
x[0..t-1] (epoch 0 uses x[0] itself — the epoch-start reading, which is
observable when the decision is made).

Three estimators, ordered by how much trace structure they exploit:

  - `persistence(x)`       — last observation carried forward. The
    baseline every mode improves on; exact whenever the signal is a
    step function (e.g. hourly carbon traces sampled at 5-min epochs).
  - `ar1_mean(x, rho)`     — causal running mean + AR(1) residual:
    x̂_t = μ_{t-1} + ρ·(x_{t-1} − μ_{t-1}). Matches the AR(1) noise
    process of the Azure-like demand generator.
  - `diurnal_ar1(x, period_steps, rho)` — online per-slot diurnal
    profile + AR(1) residual: x̂_t = μ_slot(t) + ρ·(x_{t-1} −
    μ_slot(t−1)), each μ_slot a running mean of past observations in
    that slot-of-day. Matches the known diurnal + AR(1, ρ=0.9)
    structure of `repro.carbon.traces.synth_trace` exactly, so after
    one observed cycle its error collapses to the AR innovation.

All three clamp predictions at >= 0 (carbon and demand are
non-negative) and accept (T,) or (T, C) arrays (columns independent).
Every accumulation is a sequential left fold, so the vectorized NumPy
forms are bit-identical to a per-step online implementation — the JAX
elasticity scan (`repro.core.elasticity_jax`) consumes these exact
host-precomputed series as scan inputs and relies on this.

`window_mean_forecast` is the *horizon* companion: the forecaster's
estimate, at each epoch, of the mean of the next full period. It is
what separates structure-aware forecasting from persistence — a
persistence forecaster believes the signal stays flat, so its window
mean equals its nowcast and any now-vs-rest-of-day comparison
degenerates to 1. The elasticity layer uses that ratio to shape a
fleet carbon budget into forecasted-green hours
(`repro.core.elasticity.shaped_budget_series`).
"""
from __future__ import annotations

import numpy as np

_MODES = ("oracle", "persistence", "ar1_mean", "diurnal_ar1")


def _as2d(x):
    x = np.asarray(x, dtype=np.float64)
    if x.ndim == 1:
        return x[:, None], True
    if x.ndim != 2:
        raise ValueError(f"forecast input must be (T,) or (T, C); "
                         f"got shape {x.shape}")
    return x, False


def persistence(x) -> np.ndarray:
    """x̂_t = x_{t-1} (x̂_0 = x_0): last observation carried forward."""
    x2, squeeze = _as2d(x)
    out = np.empty_like(x2)
    if x2.shape[0]:
        out[0] = x2[0]
        out[1:] = x2[:-1]
    return out[:, 0] if squeeze else out


def ar1_mean(x, rho: float = 0.9) -> np.ndarray:
    """x̂_t = μ_{t-1} + ρ·(x_{t-1} − μ_{t-1}), μ the causal running mean."""
    x2, squeeze = _as2d(x)
    T = x2.shape[0]
    out = np.empty_like(x2)
    run = np.zeros(x2.shape[1], dtype=np.float64)
    for t in range(T):
        if t == 0:
            out[0] = x2[0]
        else:
            mu = run / t
            out[t] = np.maximum(0.0, mu + rho * (x2[t - 1] - mu))
        run = run + x2[t]
    return out[:, 0] if squeeze else out


def diurnal_ar1(x, period_steps: int, rho: float = 0.9) -> np.ndarray:
    """Online per-slot diurnal profile + AR(1) residual (see module doc).

    `period_steps` is the diurnal period in epochs (24*3600/interval_s).
    Slots with no past observation yet fall back to the global running
    mean, so the first cycle degrades gracefully to `ar1_mean`.
    """
    if period_steps < 1:
        raise ValueError("period_steps must be >= 1")
    x2, squeeze = _as2d(x)
    T, C = x2.shape
    out = np.empty_like(x2)
    slot_sum = np.zeros((period_steps, C), dtype=np.float64)
    slot_cnt = np.zeros(period_steps, dtype=np.int64)
    run = np.zeros(C, dtype=np.float64)
    for t in range(T):
        if t == 0:
            out[0] = x2[0]
        else:
            glob = run / t
            s, sp = t % period_steps, (t - 1) % period_steps
            mu_s = slot_sum[s] / slot_cnt[s] if slot_cnt[s] else glob
            mu_sp = slot_sum[sp] / slot_cnt[sp] if slot_cnt[sp] else glob
            out[t] = np.maximum(0.0, mu_s + rho * (x2[t - 1] - mu_sp))
        slot_sum[t % period_steps] += x2[t]
        slot_cnt[t % period_steps] += 1
        run = run + x2[t]
    return out[:, 0] if squeeze else out


def window_mean_forecast(x, mode: str, period_steps: int = 24,
                         rho: float = 0.9) -> np.ndarray:
    """Causal forecast of mean(x[t : t+period_steps]) for a (T,) series.

      - "oracle"       — the true forward-window mean (truncated at the
        end of the series).
      - "persistence"  — x_{t-1}: a flat-signal belief, so the window
        mean *is* the nowcast (x̂_0 = x_0).
      - "ar1_mean"     — the causal running mean μ_{t-1} (the AR term
        decays to μ over the window).
      - "diurnal_ar1"  — the mean of the learned per-slot diurnal
        profile so far (a full window visits every slot once); slots
        not yet observed fall back to the global running mean.

    All modes read only x[0..t-1] except "oracle" (epoch 0 uses x[0]).
    """
    x1 = np.asarray(x, dtype=np.float64)
    if x1.ndim != 1:
        raise ValueError(f"window_mean_forecast input must be (T,); "
                         f"got shape {x1.shape}")
    if period_steps < 1:
        raise ValueError("period_steps must be >= 1")
    T = x1.shape[0]
    out = np.empty(T, dtype=np.float64)
    if mode == "oracle":
        for t in range(T):
            out[t] = x1[t:t + period_steps].mean()
        return out
    if mode == "persistence":
        return persistence(x1)
    if mode == "ar1_mean":
        run = 0.0
        for t in range(T):
            out[t] = x1[0] if t == 0 else run / t
            run += x1[t]
        return np.maximum(0.0, out)
    if mode == "diurnal_ar1":
        slot_sum = np.zeros(period_steps, dtype=np.float64)
        slot_cnt = np.zeros(period_steps, dtype=np.int64)
        run = 0.0
        for t in range(T):
            if t == 0:
                out[0] = x1[0]
            else:
                glob = run / t
                mu = np.where(slot_cnt > 0,
                              slot_sum / np.maximum(slot_cnt, 1), glob)
                out[t] = mu.mean()
            slot_sum[t % period_steps] += x1[t]
            slot_cnt[t % period_steps] += 1
            run += x1[t]
        return np.maximum(0.0, out)
    raise ValueError(f"unknown forecast mode {mode!r}; expected one of "
                     f"{_MODES}")


def forecast_series(x, mode: str, period_steps: int = 24,
                    rho: float = 0.9) -> np.ndarray:
    """Dispatch one of the causal estimators ("oracle" returns x)."""
    if mode == "oracle":
        x2, squeeze = _as2d(x)
        return (x2[:, 0] if squeeze else x2).copy()
    if mode == "persistence":
        return persistence(x)
    if mode == "ar1_mean":
        return ar1_mean(x, rho)
    if mode == "diurnal_ar1":
        return diurnal_ar1(x, period_steps, rho)
    raise ValueError(f"unknown forecast mode {mode!r}; expected one of "
                     f"{_MODES}")
