"""Region carbon statistics (paper Fig. 1: 27 regions, avg + CoV).

electricityMap is unreachable offline, so the table encodes annual
average carbon-intensity (g·CO₂e/kWh) and daily-CoV values consistent with
the paper's reported aggregates, which our benchmarks verify:

  - >500× spread between lowest and highest average intensity,
  - ~1/3 of regions with CoV < 0.05 (tier thresholds 0.05 / 0.15),
  - tier means ≈ 551 (low-CoV) / 344 (mid) / 189 (high-CoV),
  - the paper's three exemplars: Poland (low), Netherlands (mid),
    California (high variability).
"""
from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class RegionStats:
    name: str
    avg: float      # g CO2e/kWh, annual average
    cov: float      # daily coefficient of variation (hourly readings)
    diurnal_phase_h: float = 14.0   # hour of minimum intensity (solar dip)


# ordered by increasing CoV (as the paper's Fig. 1 x-axis)
REGIONS: dict[str, RegionStats] = {r.name: r for r in [
    # --- lowest-CoV third (tier mean 551: coal grids barely vary; the
    #     hydro/nuclear regions are the paper's "notable exceptions") ---
    RegionStats("IS", 1.6, 0.010),       # Iceland: geothermal/hydro
    RegionStats("NO", 26.0, 0.015),      # Norway: hydro
    RegionStats("SE", 45.0, 0.018),      # Sweden: hydro+nuclear
    RegionStats("PL", 760.0, 0.028),     # Poland: coal (paper's low-CoV case)
    RegionStats("IN-WB", 820.0, 0.030),  # West Bengal: coal
    RegionStats("ZA", 830.0, 0.032),     # South Africa: coal
    RegionStats("ID", 800.0, 0.035),     # Indonesia: coal
    RegionStats("KZ", 840.0, 0.040),     # Kazakhstan: coal
    RegionStats("XK", 836.0, 0.045),     # Kosovo: lignite
    # --- middle third (tier mean 344) ---
    RegionStats("QC", 33.0, 0.052),      # Québec: hydro
    RegionStats("FR", 85.0, 0.055),      # France: nuclear
    RegionStats("JP", 478.0, 0.060),     # Japan
    RegionStats("SG", 470.0, 0.065),     # Singapore
    RegionStats("KR", 495.0, 0.070),     # South Korea
    RegionStats("TW", 560.0, 0.080),     # Taiwan
    RegionStats("NZ", 120.0, 0.100),     # New Zealand: hydro+geo
    RegionStats("NL", 400.0, 0.110),     # Netherlands (paper's mid case)
    RegionStats("TX", 410.0, 0.120),     # Texas (ERCOT)
    # --- highest third (tier mean 189: renewables push CoV up, avg down) ---
    RegionStats("GB", 240.0, 0.155),     # Great Britain: wind
    RegionStats("DK", 160.0, 0.160),     # Denmark: wind
    RegionStats("GR", 280.0, 0.165),     # Greece: solar
    RegionStats("ES", 175.0, 0.170),     # Spain: solar+wind
    RegionStats("UY", 95.0, 0.180),      # Uruguay: wind+hydro
    RegionStats("PT", 185.0, 0.185),     # Portugal
    RegionStats("CL", 190.0, 0.200),     # Chile: solar
    RegionStats("CAISO", 230.0, 0.240),  # California (paper's high case)
    RegionStats("SA", 150.0, 0.350),     # South Australia: rooftop solar
]}


def tier_of(cov: float) -> str:
    """Paper's Fig. 1 thirds: CoV thresholds 0.05 and 0.15."""
    if cov < 0.05:
        return "low"
    if cov < 0.15:
        return "mid"
    return "high"


def tier_means() -> dict:
    """Average carbon-intensity per CoV tier (paper: 551 / 344 / 189)."""
    sums: dict[str, list] = {"low": [], "mid": [], "high": []}
    for r in REGIONS.values():
        sums[tier_of(r.cov)].append(r.avg)
    return {k: sum(v) / len(v) for k, v in sums.items()}
