"""Synthetic hourly carbon-intensity traces calibrated to region statistics.

c(t) = avg · max(floor, 1 + a·sin-diurnal(t-φ) + AR(1) noise)

The diurnal amplitude and noise scale are solved from the target CoV
(CoV² ≈ a²/2 + σ², sinusoid and AR(1) independent), so the generated trace
reproduces each region's (avg, CoV) — tested in tests/test_carbon.py.
"""
from __future__ import annotations

import zlib

import numpy as np

from repro.carbon.regions import REGIONS, RegionStats


def synth_trace(region: str | RegionStats, hours: int = 24 * 30,
                seed: int = 0) -> np.ndarray:
    """Hourly g·CO₂e/kWh array of length `hours`."""
    r = REGIONS[region] if isinstance(region, str) else region
    # stable per-region salt: Python's str hash() is salted per process
    # (PYTHONHASHSEED), which made traces differ across runs
    rng = np.random.default_rng(seed + (zlib.crc32(r.name.encode()) % 100003))
    t = np.arange(hours, dtype=np.float64)
    # split target variance: 2/3 diurnal, 1/3 AR noise
    a = np.sqrt(2.0 * (r.cov ** 2) * 2.0 / 3.0)
    sigma = np.sqrt((r.cov ** 2) / 3.0)
    diurnal = -a * np.sin(2 * np.pi * (t - r.diurnal_phase_h + 6.0) / 24.0)
    rho = 0.9
    eps = rng.normal(0, sigma * np.sqrt(1 - rho ** 2), hours)
    ar = np.zeros(hours)
    for i in range(1, hours):
        ar[i] = rho * ar[i - 1] + eps[i]
    series = r.avg * np.maximum(0.05, 1.0 + diurnal + ar)
    return series


def trace_cov(series: np.ndarray) -> float:
    return float(np.std(series) / np.mean(series))
