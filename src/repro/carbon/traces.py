"""Synthetic hourly carbon-intensity traces calibrated to region statistics.

c(t) = avg · max(floor, 1 + a·sin-diurnal(t-φ) + AR(1) noise)

The diurnal amplitude and noise scale are solved from the target CoV
(CoV² ≈ a²/2 + σ², sinusoid and AR(1) independent), so the generated trace
reproduces each region's (avg, CoV) — tested in tests/test_carbon.py.
"""
from __future__ import annotations

import zlib

import numpy as np

from repro.carbon.regions import REGIONS, RegionStats


def synth_trace(region: str | RegionStats, hours: int = 24 * 30,
                seed: int = 0) -> np.ndarray:
    """Hourly g·CO₂e/kWh array of length `hours`."""
    r = REGIONS[region] if isinstance(region, str) else region
    # stable per-region salt: Python's str hash() is salted per process
    # (PYTHONHASHSEED), which made traces differ across runs
    rng = np.random.default_rng(seed + (zlib.crc32(r.name.encode()) % 100003))
    t = np.arange(hours, dtype=np.float64)
    # split target variance: 2/3 diurnal, 1/3 AR noise
    a = np.sqrt(2.0 * (r.cov ** 2) * 2.0 / 3.0)
    sigma = np.sqrt((r.cov ** 2) / 3.0)
    diurnal = -a * np.sin(2 * np.pi * (t - r.diurnal_phase_h + 6.0) / 24.0)
    rho = 0.9
    eps = rng.normal(0, sigma * np.sqrt(1 - rho ** 2), hours)
    ar = np.zeros(hours)
    for i in range(1, hours):
        ar[i] = rho * ar[i - 1] + eps[i]
    series = r.avg * np.maximum(0.05, 1.0 + diurnal + ar)
    return series


def trace_cov(series: np.ndarray) -> float:
    return float(np.std(series) / np.mean(series))


def fill_gaps(series, gap_policy: str = "raise") -> np.ndarray:
    """Guard a carbon trace against NaN gaps (missing API samples).

    gap_policy "raise" rejects any NaN with the gap positions named —
    a gap that slips through multiplies straight into emissions totals
    as NaN, silently. "interpolate" fills interior gaps linearly
    between the surrounding real samples and holds the nearest real
    sample at the edges; "hold" forward-fills the last real sample
    (leading gaps take the first real one). An all-NaN series is
    rejected under every policy.
    """
    s = np.asarray(series, dtype=np.float64)
    nan = np.isnan(s)
    if not nan.any():
        return s
    if gap_policy == "raise":
        where = np.flatnonzero(nan)
        head = ", ".join(str(i) for i in where[:8])
        more = f" (+{where.size - 8} more)" if where.size > 8 else ""
        raise ValueError(f"carbon trace has {where.size} NaN gap(s) at "
                         f"indices [{head}]{more}; pass "
                         f"gap_policy='interpolate' or 'hold' to fill")
    if nan.all():
        raise ValueError("carbon trace is all-NaN; nothing to fill from")
    idx = np.arange(s.size, dtype=np.float64)
    good = ~nan
    if gap_policy == "interpolate":
        # np.interp clamps to the edge values, so leading/trailing gaps
        # hold the nearest real sample
        return np.interp(idx, idx[good], s[good])
    if gap_policy == "hold":
        # forward-fill via the running index of the last real sample;
        # leading gaps back-fill from the first one
        last = np.maximum.accumulate(np.where(good, np.arange(s.size), -1))
        first = int(np.flatnonzero(good)[0])
        return s[np.where(last >= 0, last, first)]
    raise ValueError(f"unknown gap_policy {gap_policy!r}; expected "
                     f"'raise', 'interpolate' or 'hold'")
