"""Carbon-intensity data: region statistics, providers, synthetic traces."""
from repro.carbon.intensity import (CarbonIntensityProvider, ConstantProvider,
                                    TraceProvider)
from repro.carbon.regions import REGIONS, RegionStats, tier_of
from repro.carbon.traces import synth_trace

__all__ = ["CarbonIntensityProvider", "ConstantProvider", "TraceProvider",
           "REGIONS", "RegionStats", "tier_of", "synth_trace"]
