"""Graceful degradation: turn the true (T, R) signal into the observed one.

`observe_intensity` walks the epochs once with (R,)-shaped state and
applies the `DegradeConfig` ladder per (epoch, region):

    tier 0  fresh sample arrived (possibly noise-corrupted)
    tier 1  hold-last-sample, while its age <= ttl_epochs
    tier 2  causal diurnal prior — the per-slot running means of
            `repro.carbon.forecast.diurnal_ar1`, accumulated only over
            *received* samples, so a region that stops reporting keeps
            a sane day-shaped estimate — while age <= prior_ttl_epochs
    tier 3  conservative floor: assume the worst intensity `c_max`

The result is an ordinary host array: every backend (scalar loop,
NumPy fleet, JAX scan) consumes the identical floats, so enabling a
`FaultPlan` cannot open a parity gap between backends.

Safety property (pinned by tests/test_robustness.py): under
mode="conservative" with noise-free faults and traces bounded by
`c_max`, the observed intensity never *under*-states the true one, so
a budget-respecting policy's per-epoch gram rate — billed at the true
intensity — never exceeds the target:

    power <= (1 - eps) * target * 1000 / c_obs  and  c_obs >= c_true
    =>  grams/hr = power * c_true / 1000 <= (1 - eps) * target
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.robustness.faults import FaultPlan, carbon_fault_masks

TIER_FRESH, TIER_HOLD, TIER_PRIOR, TIER_FLOOR = 0, 1, 2, 3

# "never sampled" age sentinel: larger than any prior_ttl_epochs but
# safely below int32 overflow even after += T increments
_NEVER = 1_000_000


@dataclass
class ObservedSignal:
    """Degraded (T, R) carbon signal + per-sample provenance."""
    observed: np.ndarray        # (T, R) f64: what the controller sees
    true: np.ndarray            # (T, R) f64: what emissions are billed at
    fresh: np.ndarray           # (T, R) bool: a sample arrived
    age: np.ndarray             # (T, R) int32: epochs since last sample
    tier: np.ndarray            # (T, R) int8: TIER_* used per sample

    def summary(self) -> dict:
        """Flat `fault_*` metrics for sweep rows / benchmark JSON."""
        n = max(self.tier.size, 1)
        tr = np.where(self.true > 0.0, self.true, 1.0)
        rel = np.abs(self.observed - self.true) / tr
        return {
            "fault_stale_frac": float(np.count_nonzero(self.tier > 0) / n),
            "fault_hold_frac": float(
                np.count_nonzero(self.tier == TIER_HOLD) / n),
            "fault_prior_frac": float(
                np.count_nonzero(self.tier == TIER_PRIOR) / n),
            "fault_floor_frac": float(
                np.count_nonzero(self.tier == TIER_FLOOR) / n),
            "fault_max_age": int(np.minimum(self.age,
                                            self.age.shape[0]).max()
                                 if self.age.size else 0),
            "fault_obs_rel_err_mean": float(rel.mean()) if rel.size else 0.0,
            "fault_obs_rel_err_max": float(rel.max()) if rel.size else 0.0,
        }


def observe_intensity(true_mat, plan: FaultPlan,
                      interval_s: float) -> ObservedSignal:
    """Apply the plan's carbon-feed faults + degradation ladder to the
    true (T, R) region-intensity matrix. Strictly causal: the estimate
    at epoch t only reads samples received at epochs <= t (the fresh
    sample at t itself is used at t, matching the epoch-start reading
    convention of `repro.carbon.forecast`)."""
    true_mat = np.asarray(true_mat, dtype=np.float64)
    if true_mat.ndim != 2:
        raise ValueError(f"true intensity matrix must be (T, R); got "
                         f"{true_mat.shape}")
    T, R = true_mat.shape
    deg = plan.degrade
    if deg.mode not in ("ladder", "hold", "conservative"):
        raise ValueError(f"unknown degrade mode {deg.mode!r}; expected "
                         f"'ladder', 'hold' or 'conservative'")
    fresh, noise = carbon_fault_masks(plan, T, R)
    sample = true_mat * noise
    period = max(1, int(round(24 * 3600.0 / float(interval_s))))
    c_max = float(deg.c_max)

    observed = np.empty((T, R), dtype=np.float64)
    tier = np.empty((T, R), dtype=np.int8)
    age = np.empty((T, R), dtype=np.int32)

    last = np.zeros(R, dtype=np.float64)        # last received sample
    age_r = np.full(R, _NEVER, dtype=np.int64)
    slot_sum = np.zeros((period, R), dtype=np.float64)
    slot_cnt = np.zeros((period, R), dtype=np.int64)
    run_sum = np.zeros(R, dtype=np.float64)
    run_cnt = np.zeros(R, dtype=np.int64)

    for t in range(T):
        f = fresh[t]
        age_r = np.where(f, 0, np.minimum(age_r + 1, _NEVER))
        have = run_cnt > 0
        if deg.mode == "conservative":
            est = np.full(R, c_max)
            est_tier = np.full(R, TIER_FLOOR, dtype=np.int8)
        elif deg.mode == "hold":
            est = np.where(have, last, c_max)
            est_tier = np.where(have, TIER_HOLD, TIER_FLOOR).astype(np.int8)
        else:                                    # ladder
            s = t % period
            glob = run_sum / np.maximum(run_cnt, 1)
            mu_slot = np.where(slot_cnt[s] > 0,
                               slot_sum[s] / np.maximum(slot_cnt[s], 1),
                               glob)
            prior_ok = have & (age_r <= deg.prior_ttl_epochs)
            est = np.where(prior_ok, mu_slot, c_max)
            est_tier = np.where(prior_ok, TIER_PRIOR,
                                TIER_FLOOR).astype(np.int8)
            hold_ok = have & (age_r <= deg.ttl_epochs)
            est = np.where(hold_ok, last, est)
            est_tier = np.where(hold_ok, TIER_HOLD, est_tier)
        observed[t] = np.where(f, sample[t], est)
        tier[t] = np.where(f, TIER_FRESH, est_tier)
        age[t] = age_r
        # fold the received samples into the causal state *after* use
        last = np.where(f, sample[t], last)
        s = t % period
        slot_sum[s] += np.where(f, sample[t], 0.0)
        slot_cnt[s] += f
        run_sum += np.where(f, sample[t], 0.0)
        run_cnt += f
    return ObservedSignal(observed=observed, true=true_mat, fresh=fresh,
                          age=age, tier=tier)


def budget_violations(power_series, true_cmat, targets, interval_s: float,
                      rtol: float = 1e-9) -> int:
    """Count (epoch, container) cells whose true gram *rate* exceeds the
    container's target. `power_series` is the recorded (T, N) power
    matrix, `true_cmat` the (T,) or (T, N) TRUE intensity it is billed
    at. The conservative degrade mode must drive this to exactly zero."""
    power = np.asarray(power_series, dtype=np.float64)
    c = np.asarray(true_cmat, dtype=np.float64)
    c2 = c if c.ndim == 2 else c[:, None]
    tg = np.asarray(targets, dtype=np.float64)
    rate = power * c2 / 1000.0
    return int(np.count_nonzero(rate > tg[None, :] * (1.0 + rtol) + 1e-12))
