"""Frozen fault-plan declarations + deterministic mask materializers.

A `FaultPlan` is declared once on a `SweepSpec` and materialized
host-side into plain NumPy masks, keyed only on `(plan.seed, shape)` —
the same plan always produces the same dropouts, gaps, and migration
failures on every backend, so the scalar/fleet/jax parity chain holds
with faults enabled.

Every dataclass here is frozen: plans are values, safe to share across
backends and to use as nested defaults. Windows are declared as plain
tuples (hashable, reprs cleanly into benchmark JSON):

    CarbonFeedFaults(dropout_prob=0.2,
                     blackouts=((-1, 100, 30),),        # all regions
                     noise_windows=((2, 50, 20, 0.3),)) # region 2

Region index ``-1`` means "every region". All windows are
``[start, start + n)`` in epochs.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional, Tuple

import numpy as np

# independent PCG64 streams per fault class, all derived from the one
# plan seed (salts keep e.g. the dropout pattern stable when a noise
# window is added to the plan)
_SALT_DROPOUT = 0x5EED_01
_SALT_NOISE = 0x5EED_02
_SALT_MIG = 0x5EED_03
_SALT_GAP = 0x5EED_04


@dataclass(frozen=True)
class CarbonFeedFaults:
    """Carbon-intensity feed faults, per (epoch, region) sample.

    dropout_prob   i.i.d. probability a sample is lost
    blackouts      ((region | -1, start, n), ...) windows with no samples
    stale_every    only every k-th epoch delivers a sample (k=1: all)
    noise_windows  ((region | -1, start, n, sigma), ...): delivered
                   samples inside the window are multiplied by
                   exp(sigma * z), z ~ N(0, 1) — the feed reports a
                   wrong-but-plausible value, it does not go missing
    """
    dropout_prob: float = 0.0
    blackouts: Tuple[Tuple[int, int, int], ...] = ()
    stale_every: int = 1
    noise_windows: Tuple[Tuple[int, int, int, float], ...] = ()


@dataclass(frozen=True)
class PowerTelemetryFaults:
    """Power-metering gaps: emissions still physically happen during a
    gap epoch (billing is unchanged) but the metered sample is lost —
    the sweep reports the affected grams as `unmetered_g` so operators
    can see how much of the ledger rests on interpolated power."""
    gap_prob: float = 0.0
    gaps: Tuple[Tuple[int, int], ...] = ()     # ((start, n), ...)


@dataclass(frozen=True)
class MigrationFaults:
    """Actuation-plane faults: each attempted placement migration fails
    i.i.d. with `fail_prob`. A failed attempt pays the full stop-and-copy
    cost (overhead grams + downtime) but the container stays put; the
    planner then backs off `min(backoff_base * 2**(k-1), backoff_cap)`
    epochs after the k-th consecutive failure before retrying."""
    fail_prob: float = 0.0
    backoff_base: int = 1
    backoff_cap: int = 16


@dataclass(frozen=True)
class DegradeConfig:
    """Graceful-degradation ladder for missing carbon samples.

    mode "ladder" (the default) falls through four tiers per (epoch,
    region): fresh sample -> hold-last while `age <= ttl_epochs` ->
    causal diurnal prior (the per-slot running means of
    `repro.carbon.forecast.diurnal_ar1`, fed only with *received*
    samples) while `age <= prior_ttl_epochs` -> conservative `c_max`
    floor. mode "hold" holds the last sample forever (the naive
    baseline whose overshoot is unbounded); mode "conservative" jumps
    straight to `c_max` for any non-fresh epoch, which makes the gram
    budget unconditionally safe (see `observe_intensity`).
    """
    mode: str = "ladder"                 # "ladder" | "hold" | "conservative"
    ttl_epochs: int = 3
    prior_ttl_epochs: int = 288
    c_max: float = 1000.0


@dataclass(frozen=True)
class FaultPlan:
    """One frozen declaration of every signal/actuation-plane fault,
    attached to `SweepSpec.faults`. `seed` drives all stochastic masks."""
    carbon: CarbonFeedFaults = field(default_factory=CarbonFeedFaults)
    power: PowerTelemetryFaults = field(default_factory=PowerTelemetryFaults)
    migration: MigrationFaults = field(default_factory=MigrationFaults)
    degrade: DegradeConfig = field(default_factory=DegradeConfig)
    seed: int = 0


def _window_cols(region: int, R: int):
    return slice(None) if region < 0 else slice(region, region + 1)


def carbon_fault_masks(plan: FaultPlan, T: int, R: int):
    """Materialize the carbon-feed faults as `(fresh (T, R) bool,
    noise_mult (T, R) f64)`. `fresh[t, r]` is True iff a sample arrives
    for region r at epoch t; delivered samples are `true * noise_mult`.
    Deterministic in `(plan.seed, T, R)`."""
    c = plan.carbon
    fresh = np.ones((T, R), dtype=bool)
    if c.stale_every > 1:
        fresh &= (np.arange(T) % int(c.stale_every) == 0)[:, None]
    if c.dropout_prob > 0.0:
        rng = np.random.default_rng(plan.seed + _SALT_DROPOUT)
        fresh &= rng.random((T, R)) >= float(c.dropout_prob)
    for region, start, n in c.blackouts:
        fresh[max(0, start):start + n, _window_cols(region, R)] = False
    noise = np.ones((T, R), dtype=np.float64)
    if c.noise_windows:
        rng = np.random.default_rng(plan.seed + _SALT_NOISE)
        for region, start, n, sigma in c.noise_windows:
            lo, hi = max(0, start), min(T, start + n)
            cols = _window_cols(region, R)
            z = rng.standard_normal((hi - lo, noise[lo:hi, cols].shape[1]))
            noise[lo:hi, cols] *= np.exp(float(sigma) * z)
    return fresh, noise


def migration_failure_mask(plan: Optional[FaultPlan], T: int,
                           N: int) -> Optional[np.ndarray]:
    """(T, N) bool: True where an attempted migration at (epoch, container)
    fails. None when the plan declares no migration faults. Drawn in
    row chunks to keep the transient f64 uniform buffer small at fleet
    scale (PCG64 `random` fills C-order sequentially, so the chunked
    draw is bit-identical to a one-shot (T, N) draw)."""
    if plan is None or plan.migration.fail_prob <= 0.0:
        return None
    p = float(plan.migration.fail_prob)
    rng = np.random.default_rng(plan.seed + _SALT_MIG)
    out = np.empty((T, N), dtype=bool)
    chunk = max(1, 4_000_000 // max(N, 1))
    for lo in range(0, T, chunk):
        hi = min(T, lo + chunk)
        out[lo:hi] = rng.random((hi - lo, N)) < p
    return out


def power_gap_vector(plan: Optional[FaultPlan],
                     T: int) -> Optional[np.ndarray]:
    """(T,) f64 in {0, 1}: 1 where the epoch's power sample is lost.
    None when the plan declares no telemetry gaps."""
    if plan is None:
        return None
    p = plan.power
    if p.gap_prob <= 0.0 and not p.gaps:
        return None
    gap = np.zeros(T, dtype=bool)
    if p.gap_prob > 0.0:
        rng = np.random.default_rng(plan.seed + _SALT_GAP)
        gap |= rng.random(T) < float(p.gap_prob)
    for start, n in p.gaps:
        gap[max(0, start):start + n] = True
    return gap.astype(np.float64)
