"""Signal-plane fault injection + graceful degradation.

The enforcement loop everywhere else in this repo assumes a perfect
signal plane: carbon-intensity telemetry is always fresh, power
metering never drops samples, and every planned migration succeeds.
This package makes those assumptions explicit and breakable — a frozen,
seeded `FaultPlan` declares carbon-feed dropouts/staleness/noise
windows, power-telemetry gaps, and migration failures, and the
degradation ladder in `degrade` turns the true (T, R) region-intensity
matrix into the *observed* signal the controller actually gets to see.

Degraded signals are materialized host-side once, as plain NumPy
arrays, so the scalar / NumPy-fleet / JAX backends consume identical
floats (parity by construction). Emissions are always billed at the
TRUE intensity; decisions run on the OBSERVED one — the gap between
the two is the measurable overshoot cost of a degraded signal plane.
"""
from repro.robustness.faults import (CarbonFeedFaults, DegradeConfig,
                                     FaultPlan, MigrationFaults,
                                     PowerTelemetryFaults,
                                     carbon_fault_masks,
                                     migration_failure_mask,
                                     power_gap_vector)
from repro.robustness.degrade import (ObservedSignal, budget_violations,
                                      observe_intensity)

__all__ = [
    "CarbonFeedFaults", "PowerTelemetryFaults", "MigrationFaults",
    "DegradeConfig", "FaultPlan", "carbon_fault_masks",
    "migration_failure_mask", "power_gap_vector", "ObservedSignal",
    "observe_intensity", "budget_violations",
]
