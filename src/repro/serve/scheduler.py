"""Carbon-aware request scheduler.

Serving is where the paper's workload-intensity argument bites: request
rates swing on minutes-scale (Azure-like CoV ≫ carbon CoV), so the
scheduler feeds the Carbon Container demand signal with the queue-implied
utilization and applies the resulting duty/slice decisions — batching
requests up to the capacity the carbon policy allows.
"""
from __future__ import annotations

import heapq
from dataclasses import dataclass, field
from typing import Optional

import numpy as np


@dataclass(order=True)
class Request:
    arrival_s: float
    rid: int = field(compare=False)
    prompt_len: int = field(compare=False, default=128)
    max_new: int = field(compare=False, default=64)
    done_s: Optional[float] = field(compare=False, default=None)


@dataclass
class CarbonAwareScheduler:
    """Queue + admission control driven by the carbon policy's duty."""

    capacity_tok_s: float            # decode throughput at duty=1 on slice 1x
    max_batch: int = 32
    interval_s: float = 300.0        # default epoch for demand/run_interval
    queue: list = field(default_factory=list)
    completed: list = field(default_factory=list)
    t: float = 0.0
    _next_rid: int = 0

    def offer(self, arrival_s: float, prompt_len: int = 128,
              max_new: int = 64) -> Request:
        r = Request(arrival_s, self._next_rid, prompt_len, max_new)
        self._next_rid += 1
        heapq.heappush(self.queue, r)
        return r

    def demand(self, window_s: Optional[float] = None) -> float:
        """Queue-implied utilization (baseline-capacity units) over the
        scheduler's interval (or an explicit `window_s`)."""
        if window_s is None:
            window_s = self.interval_s
        backlog_tokens = sum(r.max_new for r in self.queue)
        return backlog_tokens / max(self.capacity_tok_s * window_s, 1e-9)

    def run_interval(self, duty: float, slice_multiple: float,
                     interval_s: Optional[float] = None) -> dict:
        """Serve as many requests as the allowed capacity covers."""
        if interval_s is None:
            interval_s = self.interval_s
        budget_tokens = self.capacity_tok_s * slice_multiple * duty * interval_s
        served = 0
        tokens = 0
        while self.queue and tokens + self.queue[0].max_new <= budget_tokens:
            r = heapq.heappop(self.queue)
            if r.arrival_s > self.t + interval_s:
                heapq.heappush(self.queue, r)
                break
            tokens += r.max_new
            # completion can't precede arrival: a request arriving
            # mid-interval is served in the remainder of the interval
            r.done_s = max(r.arrival_s, self.t + interval_s
                           * min(1.0, tokens / max(budget_tokens, 1e-9)))
            self.completed.append(r)
            served += 1
        self.t += interval_s
        # utilization of the *baseline* capacity: budget_tokens already
        # carries the duty * slice_multiple scaling, so dividing served
        # tokens by it and multiplying by duty * slice_multiple again
        # (as earlier revisions did) double-counted the allocation
        return {"served": served, "tokens": tokens,
                "backlog": len(self.queue),
                "util": tokens / max(self.capacity_tok_s * interval_s, 1e-9)}

    def latency_stats(self) -> dict:
        lat = [r.done_s - r.arrival_s for r in self.completed
               if r.done_s is not None]
        if not lat:
            return {"p50_s": 0.0, "p95_s": 0.0, "n": 0}
        return {"p50_s": float(np.percentile(lat, 50)),
                "p95_s": float(np.percentile(lat, 95)), "n": len(lat)}


def poisson_arrivals(rate_per_s: float, duration_s: float,
                     seed: int = 0, chunk: int = 4096) -> list:
    """Arrival times of a homogeneous Poisson process on [0, duration_s].

    Vectorized: draws inter-arrival gaps in chunks and integrates them
    with one `cumsum` per chunk instead of one Python-loop iteration per
    event (~50x at serving-scale rates). Chunked array draws consume the
    generator stream exactly as repeated scalar draws do, so the output
    is bit-identical to the sequential reference for any chunk size
    (pinned by tests/test_scheduler_replay.py).
    """
    rng = np.random.default_rng(seed)
    scale = 1.0 / max(rate_per_s, 1e-9)
    out: list = []
    carry = 0.0
    while True:
        gaps = rng.exponential(scale, chunk)
        t = np.cumsum(np.concatenate(([carry], gaps)))[1:]
        keep = t[t <= duration_s]
        out.extend(keep.tolist())
        if keep.size < chunk:
            return out
        carry = float(t[-1])
