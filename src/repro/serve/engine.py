"""Batched serving engine: shared prefill + synchronized decode.

One jitted prefill and one jitted decode_step per (model, batch shape);
decode batches are aligned (shared position counter), matching the cache
layout the dry-run lowers (seq-sharded KV / O(1) SSM state). The carbon
layer throttles the engine via `duty` (decode-rate cap) — vertical scaling
for inference.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.api import Model


@dataclass
class ServeEngine:
    model: Model
    params: Optional[dict] = None

    def __post_init__(self):
        self._prefill = jax.jit(
            lambda p, b, n: self.model.prefill(p, b, pad_to=n),
            static_argnums=(2,))
        self._decode = jax.jit(lambda p, c, t: self.model.decode(p, c, t))
        self.stats = {"prefill_tokens": 0, "decode_tokens": 0,
                      "prefill_s": 0.0, "decode_s": 0.0}

    def load(self, key: Optional[jax.Array] = None):
        self.params = self.model.init(key if key is not None
                                      else jax.random.PRNGKey(0))
        return self

    def generate(self, prompts: np.ndarray, max_new_tokens: int,
                 greedy: bool = True, duty: float = 1.0,
                 key: Optional[jax.Array] = None,
                 eos_id: int = -1) -> dict:
        """prompts: (B, S) int32 -> generated (B, max_new_tokens)."""
        assert self.params is not None, "call load() first"
        B, S = prompts.shape
        batch = {"tokens": jnp.asarray(prompts, jnp.int32)}
        if self.model.cfg.family == "encdec":
            batch["frames"] = jnp.zeros(
                (B, self.model.cfg.enc_seq, self.model.cfg.d_model),
                jnp.dtype(self.model.cfg.dtype))
        t0 = time.perf_counter()
        logits, cache = self._prefill(self.params, batch, S + max_new_tokens)
        logits.block_until_ready()
        self.stats["prefill_s"] += time.perf_counter() - t0
        self.stats["prefill_tokens"] += B * S

        out = np.zeros((B, max_new_tokens), np.int32)
        key = key if key is not None else jax.random.PRNGKey(0)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        done = np.zeros((B,), bool)
        for i in range(max_new_tokens):
            out[:, i] = np.asarray(tok)
            if eos_id >= 0:
                done |= out[:, i] == eos_id
                if done.all():
                    out = out[:, :i + 1]
                    break
            t0 = time.perf_counter()
            logits, cache = self._decode(self.params, cache, tok)
            if greedy:
                tok = jnp.argmax(logits, -1).astype(jnp.int32)
            else:
                key, sub = jax.random.split(key)
                tok = jax.random.categorical(sub, logits).astype(jnp.int32)
            tok.block_until_ready()
            dt = time.perf_counter() - t0
            self.stats["decode_s"] += dt
            self.stats["decode_tokens"] += B
            if duty < 1.0:            # vertical scaling: decode-rate cap
                time.sleep(dt * (1.0 / max(duty, 1e-2) - 1.0))
        return {"tokens": out, "stats": dict(self.stats)}


def throughput_tokens_per_s(stats: dict) -> dict:
    return {
        "prefill_tok_s": stats["prefill_tokens"] / max(stats["prefill_s"], 1e-9),
        "decode_tok_s": stats["decode_tokens"] / max(stats["decode_s"], 1e-9),
    }
