"""Serving stack: prefill/decode engine + carbon-aware request scheduler."""
from repro.serve.engine import ServeEngine
from repro.serve.scheduler import CarbonAwareScheduler, Request

__all__ = ["ServeEngine", "CarbonAwareScheduler", "Request"]
