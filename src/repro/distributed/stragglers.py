"""Straggler mitigation from step-time telemetry.

A straggling host inflates every synchronous step (the collective waits for
the slowest participant). Detection: robust z-score of recent step times
against the rolling median; mitigation: the Carbon Containers migration
machinery (move the job off the slow slice), which is why the detector
emits the same Action vocabulary as the carbon policy.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Deque, Optional

import numpy as np


@dataclass
class StragglerDetector:
    window: int = 32
    threshold: float = 1.8          # step slower than 1.8x median -> flag
    patience: int = 4               # consecutive flags before acting
    _times: Deque[float] = field(default_factory=deque)
    _flags: int = 0

    def observe(self, step_time_s: float) -> Optional[str]:
        self._times.append(step_time_s)
        if len(self._times) > self.window:
            self._times.popleft()
        if len(self._times) < max(8, self.window // 4):
            return None
        med = float(np.median(self._times))
        if step_time_s > self.threshold * med:
            self._flags += 1
        else:
            self._flags = 0
        if self._flags >= self.patience:
            self._flags = 0
            return "migrate"        # recommend moving off the slow slice
        return None

    def slowdown(self) -> float:
        """Current step time relative to the window median."""
        if len(self._times) < 2:
            return 1.0
        med = float(np.median(self._times))
        return float(self._times[-1]) / max(med, 1e-9)
