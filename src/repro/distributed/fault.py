"""Fault tolerance: heartbeat monitoring + checkpoint/restart recovery.

At production scale (1000+ nodes) failures are routine; the recovery path
reuses the elastic migration machinery: detect -> restore the latest
checkpoint on the surviving slice (possibly smaller) -> continue. Failures
here are injected (single-host environment); the detection/recovery logic
is the deployable part.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Optional


@dataclass
class HeartbeatMonitor:
    """Tracks per-host heartbeats; flags hosts silent for > timeout_s.

    `clock` supplies the current time (defaults to `time.monotonic`) —
    inject a virtual clock to drive detection deterministically in
    scenarios and tests, without sleeps. Explicit `t`/`now` arguments
    still override per call."""

    timeout_s: float = 30.0
    last_seen: dict = field(default_factory=dict)
    clock: Callable[[], float] = time.monotonic

    def beat(self, host: str, t: Optional[float] = None):
        self.last_seen[host] = t if t is not None else self.clock()

    def dead_hosts(self, now: Optional[float] = None) -> list:
        now = now if now is not None else self.clock()
        return [h for h, t in self.last_seen.items() if now - t > self.timeout_s]


@dataclass
class FailureInjector:
    """Deterministic failure schedule for tests/examples: {step: n_lost}."""

    schedule: dict = field(default_factory=dict)

    def check(self, step: int) -> int:
        # one-shot: recovery rolls back to the last checkpoint and replays
        # through this step; the same failure must not re-fire
        return self.schedule.pop(step, 0)


def run_with_recovery(job, data_iter, n_steps: int, devices: list,
                      injector: Optional[FailureInjector] = None,
                      checkpoint_every: int = 20,
                      min_devices: int = 1) -> dict:
    """Train with periodic checkpoints; on (injected) failure, shrink the
    device set and resume from the latest checkpoint (elastic recovery)."""
    it = iter(data_iter)
    recoveries = []
    live = list(devices)
    step = job.step_idx
    while step < n_steps:
        lost = injector.check(step) if injector else 0
        if lost:
            survivors = live[:-lost]
            # power-of-two shrink so the mesh stays well-formed
            n = 1
            while n * 2 <= len(survivors):
                n *= 2
            survivors = survivors[:n]
            if len(survivors) < min_devices:
                raise RuntimeError("insufficient survivors")
            resumed = job.recover_after_failure(survivors)
            recoveries.append({"at_step": step, "lost": lost,
                               "resumed": resumed})
            live = survivors
            step = job.step_idx
            continue
        job.train_step(next(it))
        step = job.step_idx
        if checkpoint_every and step % checkpoint_every == 0:
            job.checkpoint()
    return {"recoveries": recoveries, "final_step": step,
            "devices_left": len(live)}
