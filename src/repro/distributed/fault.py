"""Fault tolerance: heartbeat monitoring + checkpoint/restart recovery.

At production scale (1000+ nodes) failures are routine; the recovery path
reuses the elastic migration machinery: detect -> restore the latest
checkpoint on the surviving slice (possibly smaller) -> continue. Failures
here are injected (single-host environment); the detection/recovery logic
is the deployable part.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Optional


@dataclass
class HeartbeatMonitor:
    """Tracks per-host heartbeats; flags hosts silent for > timeout_s.

    `clock` supplies the current time (defaults to `time.monotonic`) —
    inject a virtual clock to drive detection deterministically in
    scenarios and tests, without sleeps. Explicit `t`/`now` arguments
    still override per call."""

    timeout_s: float = 30.0
    last_seen: dict = field(default_factory=dict)
    clock: Callable[[], float] = time.monotonic

    def beat(self, host: str, t: Optional[float] = None):
        self.last_seen[host] = t if t is not None else self.clock()

    def dead_hosts(self, now: Optional[float] = None) -> list:
        now = now if now is not None else self.clock()
        return [h for h, t in self.last_seen.items() if now - t > self.timeout_s]


@dataclass
class FailureInjector:
    """Deterministic failure schedule for tests/examples: {step: n_lost}.

    `persistent=True` re-arms the schedule instead of popping it — the
    same failure fires on every replay through its step, modelling a
    fault the recovery path cannot clear (a bad host that keeps
    rejoining, a corrupt shard). Use with `run_with_recovery`'s
    `max_retries` to exercise the exhaustion path.
    """

    schedule: dict = field(default_factory=dict)
    persistent: bool = False

    def check(self, step: int) -> int:
        # one-shot by default: recovery rolls back to the last checkpoint
        # and replays through this step; the same failure must not re-fire
        if self.persistent:
            return self.schedule.get(step, 0)
        return self.schedule.pop(step, 0)


def run_with_recovery(job, data_iter, n_steps: int, devices: list,
                      injector: Optional[FailureInjector] = None,
                      checkpoint_every: int = 20,
                      min_devices: int = 1,
                      max_retries: Optional[int] = None,
                      backoff_base_s: float = 0.0,
                      backoff_cap_s: float = 60.0,
                      sleep_fn: Callable[[float], None] = time.sleep) -> dict:
    """Train with periodic checkpoints; on (injected) failure, shrink the
    device set and resume from the latest checkpoint (elastic recovery).

    A failure that keeps firing at the same step used to loop forever.
    `max_retries` bounds *consecutive* recoveries that fail to advance
    past the failing step; each retry k first backs off
    `min(backoff_base_s * 2**(k-1), backoff_cap_s)` seconds (capped
    exponential; `sleep_fn` is injectable so tests pass a recorder
    instead of sleeping). On exhaustion — or when fewer than
    `min_devices` survive — the run aborts *gracefully*: it returns the
    partial results accumulated so far with `aborted=True` and an
    `abort_reason`, instead of raising away the completed work.
    """
    it = iter(data_iter)
    recoveries = []
    live = list(devices)
    step = job.step_idx
    consec = 0
    last_fail_step = -1

    def _partial(reason: str) -> dict:
        return {"recoveries": recoveries, "final_step": job.step_idx,
                "devices_left": len(live), "aborted": True,
                "abort_reason": reason}

    while step < n_steps:
        lost = injector.check(step) if injector else 0
        if lost:
            # consecutive = no forward progress past the last failing step
            consec = consec + 1 if step <= last_fail_step else 1
            last_fail_step = step
            if max_retries is not None and consec > max_retries:
                return _partial(f"max_retries={max_retries} exhausted at "
                                f"step {step}")
            if backoff_base_s > 0.0 and consec > 1:
                sleep_fn(min(backoff_base_s * 2.0 ** (consec - 2),
                             backoff_cap_s))
            survivors = live[:-lost] if lost < len(live) else []
            # power-of-two shrink so the mesh stays well-formed
            n = 1
            while n * 2 <= len(survivors):
                n *= 2
            survivors = survivors[:n]
            if len(survivors) < min_devices:
                return _partial(f"insufficient survivors at step {step}: "
                                f"{len(survivors)} < min_devices="
                                f"{min_devices}")
            resumed = job.recover_after_failure(survivors)
            recoveries.append({"at_step": step, "lost": lost,
                               "resumed": resumed})
            live = survivors
            step = job.step_idx
            continue
        job.train_step(next(it))
        step = job.step_idx
        if checkpoint_every and step % checkpoint_every == 0:
            job.checkpoint()
    return {"recoveries": recoveries, "final_step": step,
            "devices_left": len(live), "aborted": False}
