"""Distributed runtime concerns: fault tolerance, stragglers, overlap."""
from repro.distributed.fault import FailureInjector, HeartbeatMonitor, run_with_recovery
from repro.distributed.stragglers import StragglerDetector

__all__ = ["FailureInjector", "HeartbeatMonitor", "run_with_recovery",
           "StragglerDetector"]
