"""Mamba2-2.7B [arXiv:2405.21060; unverified] — SSD (state-space duality), attn-free.

Runs long_500k: decode state is O(1) in sequence length (conv + SSD state).
"""
from repro.config import ArchSpec, ModelConfig, SSM

FULL = ModelConfig(
    name="mamba2-2.7b",
    family=SSM,
    n_layers=64,
    d_model=2560,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab_size=50280,
    use_rope=False,
    tie_embeddings=True,
    ssm_state=128,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_conv_width=4,
    ssm_chunk=256,
    ssm_n_groups=1,
)

SMOKE = ModelConfig(
    name="mamba2-2.7b-smoke",
    family=SSM,
    n_layers=2,
    d_model=64,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab_size=256,
    use_rope=False,
    ssm_state=16,
    ssm_expand=2,
    ssm_head_dim=32,
    ssm_conv_width=4,
    ssm_chunk=16,
    ssm_n_groups=1,
)

SPEC = ArchSpec(
    arch_id="mamba2-2.7b",
    full=FULL,
    smoke=SMOKE,
    source="arXiv:2405.21060; unverified",
    skip_shapes={},
)
