"""StarCoder2-7B [arXiv:2402.19173; hf] — dense, GQA(kv=4), RoPE, GELU MLP."""
from repro.config import ArchSpec, ModelConfig, DENSE, GELU

FULL = ModelConfig(
    name="starcoder2-7b",
    family=DENSE,
    n_layers=32,
    d_model=4608,
    n_heads=36,
    n_kv_heads=4,
    d_ff=18432,
    vocab_size=49152,
    mlp_variant=GELU,
    use_rope=True,
)

SMOKE = ModelConfig(
    name="starcoder2-7b-smoke",
    family=DENSE,
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=256,
    vocab_size=256,
    mlp_variant=GELU,
    use_rope=True,
)

SPEC = ArchSpec(
    arch_id="starcoder2-7b",
    full=FULL,
    smoke=SMOKE,
    source="arXiv:2402.19173; hf",
    skip_shapes={"long_500k": "pure full-attention arch: quadratic attention at 524k "
                              "tokens has no sub-quadratic path (skip per assignment)"},
)
