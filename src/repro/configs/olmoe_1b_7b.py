"""OLMoE-1B-7B [arXiv:2409.02060; hf] — MoE, 64 experts top-8, d_ff=1024 per expert."""
from repro.config import ArchSpec, ModelConfig, MOE, SWIGLU

FULL = ModelConfig(
    name="olmoe-1b-7b",
    family=MOE,
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1024,
    vocab_size=50304,
    mlp_variant=SWIGLU,
    use_rope=True,
    n_experts=64,
    top_k=8,
)

SMOKE = ModelConfig(
    name="olmoe-1b-7b-smoke",
    family=MOE,
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=64,
    vocab_size=256,
    mlp_variant=SWIGLU,
    use_rope=True,
    n_experts=8,
    top_k=2,
)

SPEC = ArchSpec(
    arch_id="olmoe-1b-7b",
    full=FULL,
    smoke=SMOKE,
    source="arXiv:2409.02060; hf",
    skip_shapes={"long_500k": "pure full-attention arch: quadratic attention at 524k "
                              "tokens has no sub-quadratic path (skip per assignment)"},
)
