"""Chameleon-34B [arXiv:2405.09818; unverified] — early-fusion VLM backbone.

Early fusion: VQ image tokens share the text vocabulary, so the modality
frontend stub is the identity on token ids (``input_specs()`` supplies
token ids mixing text + image codes). Backbone uses qk-norm per the paper.
"""
from repro.config import ArchSpec, ModelConfig, DENSE, SWIGLU

FULL = ModelConfig(
    name="chameleon-34b",
    family=DENSE,
    n_layers=48,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=22016,
    vocab_size=65536,
    mlp_variant=SWIGLU,
    use_rope=True,
    qk_norm=True,
)

SMOKE = ModelConfig(
    name="chameleon-34b-smoke",
    family=DENSE,
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=192,
    vocab_size=256,
    mlp_variant=SWIGLU,
    use_rope=True,
    qk_norm=True,
)

SPEC = ArchSpec(
    arch_id="chameleon-34b",
    full=FULL,
    smoke=SMOKE,
    source="arXiv:2405.09818; unverified",
    skip_shapes={"long_500k": "pure full-attention arch: quadratic attention at 524k "
                              "tokens has no sub-quadratic path (skip per assignment)"},
)
