"""RecurrentGemma-9B [arXiv:2402.19427; unverified] — RG-LRU + local attention, 1:2.

Pattern (rec, rec, attn) over 38 layers = 12 full superlayers + 2 trailing
recurrent layers. MQA (kv=1), head_dim 256, GeGLU MLP, local window 2048.
Runs long_500k: state = RG-LRU hidden + bounded local-attn KV window.
"""
from repro.config import ArchSpec, ModelConfig, HYBRID, GEGLU

FULL = ModelConfig(
    name="recurrentgemma-9b",
    family=HYBRID,
    n_layers=38,
    d_model=4096,
    n_heads=16,
    n_kv_heads=1,
    head_dim=256,
    d_ff=12288,
    vocab_size=256000,
    mlp_variant=GEGLU,
    use_rope=True,
    block_pattern=("rec", "rec", "attn"),
    local_window=2048,
    lru_width=4096,
    conv_width=4,
    tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="recurrentgemma-9b-smoke",
    family=HYBRID,
    n_layers=3,
    d_model=64,
    n_heads=4,
    n_kv_heads=1,
    head_dim=16,
    d_ff=128,
    vocab_size=256,
    mlp_variant=GEGLU,
    use_rope=True,
    block_pattern=("rec", "rec", "attn"),
    local_window=16,
    lru_width=64,
    conv_width=4,
    tie_embeddings=True,
)

SPEC = ArchSpec(
    arch_id="recurrentgemma-9b",
    full=FULL,
    smoke=SMOKE,
    source="arXiv:2402.19427; unverified",
    skip_shapes={},
)
