"""Whisper-base [arXiv:2212.04356; unverified] — enc-dec; conv frontend is a STUB:
``input_specs()`` supplies precomputed frame embeddings (B, 1500, d_model)."""
from repro.config import ArchSpec, ModelConfig, ENCDEC, GELU

FULL = ModelConfig(
    name="whisper-base",
    family=ENCDEC,
    n_layers=6,                # decoder layers
    n_enc_layers=6,
    enc_seq=1500,              # 30s audio -> 1500 frames after conv stub
    d_model=512,
    n_heads=8,
    n_kv_heads=8,
    d_ff=2048,
    vocab_size=51865,
    mlp_variant=GELU,
    use_rope=False,            # whisper uses sinusoidal positions
    norm_kind="layer",
    tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="whisper-base-smoke",
    family=ENCDEC,
    n_layers=2,
    n_enc_layers=2,
    enc_seq=32,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=128,
    vocab_size=256,
    mlp_variant=GELU,
    use_rope=False,
)

SPEC = ArchSpec(
    arch_id="whisper-base",
    full=FULL,
    smoke=SMOKE,
    source="arXiv:2212.04356; unverified",
    skip_shapes={"long_500k": "full-attention enc-dec: quadratic attention at 524k "
                              "tokens has no sub-quadratic path (skip per assignment)"},
)
