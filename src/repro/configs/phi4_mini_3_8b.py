"""Phi-4-mini 3.8B [arXiv:2412.08905; hf] — dense, RoPE, SwiGLU, GQA(kv=8), 200k vocab."""
from repro.config import ArchSpec, ModelConfig, DENSE, SWIGLU

FULL = ModelConfig(
    name="phi4-mini-3.8b",
    family=DENSE,
    n_layers=32,
    d_model=3072,
    n_heads=24,
    n_kv_heads=8,
    d_ff=8192,
    vocab_size=200064,
    mlp_variant=SWIGLU,
    use_rope=True,
    tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="phi4-mini-3.8b-smoke",
    family=DENSE,
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=192,
    vocab_size=512,
    mlp_variant=SWIGLU,
    use_rope=True,
)

SPEC = ArchSpec(
    arch_id="phi4-mini-3.8b",
    full=FULL,
    smoke=SMOKE,
    source="arXiv:2412.08905; hf",
    skip_shapes={"long_500k": "pure full-attention arch: quadratic attention at 524k "
                              "tokens has no sub-quadratic path (skip per assignment)"},
)
