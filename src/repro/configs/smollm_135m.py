"""SmolLM-135M [hf:HuggingFaceTB/SmolLM-135M; hf] — llama-arch small, GQA(kv=3), SwiGLU."""
from repro.config import ArchSpec, ModelConfig, DENSE, SWIGLU

FULL = ModelConfig(
    name="smollm-135m",
    family=DENSE,
    n_layers=30,
    d_model=576,
    n_heads=9,
    n_kv_heads=3,
    d_ff=1536,
    vocab_size=49152,
    mlp_variant=SWIGLU,
    use_rope=True,
    tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="smollm-135m-smoke",
    family=DENSE,
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=128,
    vocab_size=256,
    mlp_variant=SWIGLU,
    use_rope=True,
    tie_embeddings=True,
)

SPEC = ArchSpec(
    arch_id="smollm-135m",
    full=FULL,
    smoke=SMOKE,
    source="hf:HuggingFaceTB/SmolLM-135M; hf",
    skip_shapes={"long_500k": "pure full-attention arch: quadratic attention at 524k "
                              "tokens has no sub-quadratic path (skip per assignment)"},
)
