"""DBRX-132B [hf:databricks/dbrx-base; unverified] — MoE, 16 experts top-4, fine-grained."""
from repro.config import ArchSpec, ModelConfig, MOE, SWIGLU

FULL = ModelConfig(
    name="dbrx-132b",
    family=MOE,
    n_layers=40,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=10752,
    vocab_size=100352,
    mlp_variant=SWIGLU,
    use_rope=True,
    n_experts=16,
    top_k=4,
)

SMOKE = ModelConfig(
    name="dbrx-132b-smoke",
    family=MOE,
    n_layers=2,
    d_model=96,
    n_heads=6,
    n_kv_heads=2,
    d_ff=96,
    vocab_size=256,
    mlp_variant=SWIGLU,
    use_rope=True,
    n_experts=4,
    top_k=2,
)

SPEC = ArchSpec(
    arch_id="dbrx-132b",
    full=FULL,
    smoke=SMOKE,
    source="hf:databricks/dbrx-base; unverified",
    skip_shapes={"long_500k": "pure full-attention arch: quadratic attention at 524k "
                              "tokens has no sub-quadratic path (skip per assignment)"},
)
