"""Registry of assigned architectures (10 archs, 40 arch×shape cells)."""
from __future__ import annotations

from repro.config import ArchSpec, SHAPES

from repro.configs import (  # noqa: E402
    starcoder2_7b,
    starcoder2_15b,
    smollm_135m,
    phi4_mini_3_8b,
    whisper_base,
    olmoe_1b_7b,
    dbrx_132b,
    chameleon_34b,
    mamba2_2_7b,
    recurrentgemma_9b,
)

_MODULES = (
    starcoder2_7b,
    starcoder2_15b,
    smollm_135m,
    phi4_mini_3_8b,
    whisper_base,
    olmoe_1b_7b,
    dbrx_132b,
    chameleon_34b,
    mamba2_2_7b,
    recurrentgemma_9b,
)

ARCHS: dict[str, ArchSpec] = {m.SPEC.arch_id: m.SPEC for m in _MODULES}


def get_arch(arch_id: str) -> ArchSpec:
    try:
        return ARCHS[arch_id]
    except KeyError:
        raise KeyError(f"unknown arch {arch_id!r}; choose from {sorted(ARCHS)}") from None


def list_archs() -> list[str]:
    return sorted(ARCHS)


def all_cells() -> list[tuple[str, str, str]]:
    """All 40 (arch, shape, status) cells; status is 'run' or the skip reason."""
    cells = []
    for aid, spec in sorted(ARCHS.items()):
        for sname in SHAPES:
            status = spec.skip_shapes.get(sname, "run")
            cells.append((aid, sname, status))
    return cells
