"""StarCoder2-15B [arXiv:2402.19173; hf] — dense, GQA(kv=4), RoPE, GELU MLP."""
from repro.config import ArchSpec, ModelConfig, DENSE, GELU

FULL = ModelConfig(
    name="starcoder2-15b",
    family=DENSE,
    n_layers=40,
    d_model=6144,
    n_heads=48,
    n_kv_heads=4,
    d_ff=24576,
    vocab_size=49152,
    mlp_variant=GELU,
    use_rope=True,
)

SMOKE = ModelConfig(
    name="starcoder2-15b-smoke",
    family=DENSE,
    n_layers=2,
    d_model=96,
    n_heads=6,
    n_kv_heads=2,
    d_ff=384,
    vocab_size=256,
    mlp_variant=GELU,
    use_rope=True,
)

SPEC = ArchSpec(
    arch_id="starcoder2-15b",
    full=FULL,
    smoke=SMOKE,
    source="arXiv:2402.19173; hf",
    skip_shapes={"long_500k": "pure full-attention arch: quadratic attention at 524k "
                              "tokens has no sub-quadratic path (skip per assignment)"},
)
