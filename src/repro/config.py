"""Configuration system for the repro framework.

Plain frozen dataclasses + a tiny CLI override layer (``--key value`` /
``--key.subkey value``), so launchers stay dependency-free. Every assigned
architecture gets a ``ModelConfig`` (full) + a reduced smoke variant in
``repro.configs.<arch>``; shapes live in ``SHAPES`` below.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Mapping, Sequence

# ---------------------------------------------------------------------------
# Model families
# ---------------------------------------------------------------------------

DENSE = "dense"
MOE = "moe"
SSM = "ssm"
HYBRID = "hybrid"
ENCDEC = "encdec"

FAMILIES = (DENSE, MOE, SSM, HYBRID, ENCDEC)

# MLP variants
SWIGLU = "swiglu"  # 3-matrix, silu gate
GEGLU = "geglu"    # 3-matrix, gelu gate
GELU = "gelu"      # 2-matrix, gelu


@dataclass(frozen=True)
class ModelConfig:
    """Architecture hyperparameters (exact numbers from the assignment)."""

    name: str
    family: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                  # 0 -> d_model // n_heads
    mlp_variant: str = SWIGLU
    use_rope: bool = True
    rope_theta: float = 10_000.0
    tie_embeddings: bool = False
    qk_norm: bool = False              # chameleon-style qk layernorm
    norm_kind: str = "rms"             # rms | layer (whisper)
    norm_eps: float = 1e-5
    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    router_jitter: float = 0.0
    # --- SSM (mamba2 / SSD) ---
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_conv_width: int = 4
    ssm_chunk: int = 256
    ssm_n_groups: int = 1
    # --- hybrid (recurrentgemma) ---
    block_pattern: tuple = ()          # e.g. ("rec","rec","attn")
    local_window: int = 2048
    lru_width: int = 0                 # 0 -> d_model
    conv_width: int = 4                # temporal conv in recurrent block
    # --- enc-dec (whisper) ---
    n_enc_layers: int = 0
    enc_seq: int = 0                   # precomputed frame embeddings length
    # --- numerics ---
    dtype: str = "bfloat16"            # activation dtype
    param_dtype: str = "float32"       # master params
    logit_dtype: str = "float32"
    # --- lowering knobs (dry-run / flops probes) ---
    scan_unroll: bool = False          # unroll layer scans (accurate HLO flops)
    attn_impl: str = "auto"            # auto | ref | chunked | pallas
    seq_shard: bool = True             # sequence-parallel residual stream (train)
    cast_weights: bool = True          # cast params to bf16 before the layer
                                       # scan (FSDP gathers move bf16 not f32)

    def __post_init__(self):
        if self.family not in FAMILIES:
            raise ValueError(f"unknown family {self.family!r}")
        if self.head_dim == 0 and self.n_heads > 0:
            object.__setattr__(self, "head_dim", self.d_model // max(self.n_heads, 1))
        if self.family == HYBRID and not self.block_pattern:
            object.__setattr__(self, "block_pattern", ("rec", "rec", "attn"))
        if self.family == HYBRID and self.lru_width == 0:
            object.__setattr__(self, "lru_width", self.d_model)

    # -- derived quantities -------------------------------------------------
    @property
    def d_inner(self) -> int:
        """SSM inner width."""
        return self.ssm_expand * self.d_model

    @property
    def ssm_n_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    def param_count(self) -> int:
        """Analytic parameter count (used by power/migration cost models)."""
        d, f, v = self.d_model, self.d_ff, self.vocab_size
        kvd = self.n_kv_heads * self.head_dim
        qd = self.n_heads * self.head_dim
        emb = v * d * (1 if self.tie_embeddings else 2)
        if self.family == SSM:
            di, ns = self.d_inner, self.ssm_state
            nh = self.ssm_n_heads
            # in_proj: d -> 2*di + 2*groups*state + nheads ; out_proj: di -> d
            per = d * (2 * di + 2 * self.ssm_n_groups * ns + nh) + di * d
            per += self.ssm_conv_width * (di + 2 * self.ssm_n_groups * ns)
            per += 2 * nh + di + 2 * d  # A, D, norm, layer norms
            return self.n_layers * per + emb + d
        attn = d * qd + 2 * d * kvd + qd * d + 2 * d  # q,k,v,o + norms
        if self.mlp_variant in (SWIGLU, GEGLU):
            mlp = 3 * d * f
        else:
            mlp = 2 * d * f
        if self.family == MOE:
            mlp = self.n_experts * mlp + d * self.n_experts  # experts + router
        per = attn + mlp + 2 * d
        if self.family == HYBRID:
            # recurrent block: in/out proj (2*d*lru), conv, gates (2*lru*lru branch)
            lw = self.lru_width
            rec = 2 * d * lw + lw * d + self.conv_width * lw + 2 * lw * lw + 3 * lw + 2 * d
            n_attn = sum(1 for i in range(self.n_layers)
                         if self.block_pattern[i % len(self.block_pattern)] == "attn")
            n_rec = self.n_layers - n_attn
            mlp_all = self.n_layers * (mlp + 2 * d)
            return n_attn * attn + n_rec * rec + mlp_all + emb + d
        total = self.n_layers * per + emb + d
        if self.family == ENCDEC:
            # encoder layers + decoder cross-attention
            total += self.n_enc_layers * per
            total += self.n_layers * (2 * d * kvd + d * qd + qd * d + d)
        return total

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top_k of n_experts)."""
        if self.family != MOE:
            return self.param_count()
        d, f = self.d_model, self.d_ff
        dense_mlp = 3 * d * f if self.mlp_variant in (SWIGLU, GEGLU) else 2 * d * f
        unused = (self.n_experts - self.top_k) * dense_mlp * self.n_layers
        return self.param_count() - unused


# ---------------------------------------------------------------------------
# Input shapes (assigned)
# ---------------------------------------------------------------------------

TRAIN = "train"
PREFILL = "prefill"
DECODE = "decode"


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode

    @property
    def tokens_per_step(self) -> int:
        if self.kind == DECODE:
            return self.global_batch  # one new token per sequence
        return self.seq_len * self.global_batch


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, TRAIN),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, PREFILL),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, DECODE),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, DECODE),
}


@dataclass(frozen=True)
class ArchSpec:
    """An assigned architecture: full config, smoke config, applicable shapes."""

    arch_id: str
    full: ModelConfig
    smoke: ModelConfig
    source: str
    skip_shapes: Mapping[str, str] = field(default_factory=dict)  # name -> reason

    def shapes(self) -> list[ShapeConfig]:
        return [s for n, s in SHAPES.items() if n not in self.skip_shapes]


# ---------------------------------------------------------------------------
# Training / mesh / carbon configs
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class OptimizerConfig:
    name: str = "adamw"
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 1_000
    schedule: str = "cosine"           # cosine | linear | constant
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    # gradient compression across the pod (pure-DP) axis
    compression: str = "none"          # none | int8 | topk
    topk_ratio: float = 0.05


@dataclass(frozen=True)
class TrainConfig:
    seq_len: int = 1024
    global_batch: int = 8
    microbatch: int = 0                # 0 -> no grad accumulation
    steps: int = 100
    seed: int = 0
    remat: str = "none"                # none | full | dots
    optimizer: OptimizerConfig = field(default_factory=OptimizerConfig)
    checkpoint_dir: str = ""
    checkpoint_every: int = 0          # 0 -> only final
    async_checkpoint: bool = True
    log_every: int = 10


@dataclass(frozen=True)
class MeshConfig:
    data: int = 1
    model: int = 1
    pod: int = 1

    @property
    def n_devices(self) -> int:
        return self.data * self.model * self.pod

    def axis_names(self) -> tuple:
        return ("pod", "data", "model") if self.pod > 1 else ("data", "model")

    def shape(self) -> tuple:
        return (self.pod, self.data, self.model) if self.pod > 1 else (self.data, self.model)


@dataclass(frozen=True)
class CarbonConfig:
    """Carbon Containers knobs (paper §3.1.1)."""

    target_rate: float = 100.0         # C_target, g·CO2e/hr
    epsilon: float = 0.05              # fraction of target (paper's ε threshold)
    policy: str = "energy"             # energy | performance  (paper §3.2.2/3.2.3)
    region: str = "NL"                 # carbon-intensity trace region
    interval_s: float = 300.0          # monitoring interval (paper: 5 min)
    carbon_update_s: float = 3600.0    # carbon-intensity granularity (hourly)
    min_duty: float = 0.0              # lowest duty cycle before suspend
    suspend_on_floor: bool = True


# ---------------------------------------------------------------------------
# CLI override helpers
# ---------------------------------------------------------------------------

def _coerce(val: str, like: Any) -> Any:
    if isinstance(like, bool):
        return val.lower() in ("1", "true", "yes", "on")
    if isinstance(like, int):
        return int(val)
    if isinstance(like, float):
        return float(val)
    if isinstance(like, tuple):
        return tuple(val.split(","))
    return val


def apply_overrides(cfg: Any, overrides: Mapping[str, str]) -> Any:
    """Return a copy of dataclass ``cfg`` with dotted-key overrides applied."""
    for key, val in overrides.items():
        parts = key.split(".")
        cfg = _apply_one(cfg, parts, val)
    return cfg


def _apply_one(cfg: Any, parts: Sequence[str], val: str) -> Any:
    name = parts[0]
    if not dataclasses.is_dataclass(cfg) or name not in {f.name for f in dataclasses.fields(cfg)}:
        raise KeyError(f"no config field {'.'.join(parts)!r} on {type(cfg).__name__}")
    cur = getattr(cfg, name)
    if len(parts) == 1:
        return dataclasses.replace(cfg, **{name: _coerce(val, cur)})
    return dataclasses.replace(cfg, **{name: _apply_one(cur, parts[1:], val)})


def parse_cli(argv: Sequence[str]) -> dict:
    """``--a.b v --flag true`` -> {'a.b': 'v', 'flag': 'true'}"""
    out: dict[str, str] = {}
    i = 0
    while i < len(argv):
        tok = argv[i]
        if not tok.startswith("--"):
            raise SystemExit(f"unexpected arg {tok!r}")
        key = tok[2:]
        if "=" in key:
            key, val = key.split("=", 1)
        elif i + 1 >= len(argv) or argv[i + 1].startswith("--"):
            val = "true"                   # bare flag
        else:
            i += 1
            val = argv[i]
        out[key] = val
        i += 1
    return out
