"""Power models + telemetry (the Power Containers substrate)."""
from repro.power.model import LinearPowerModel, calibrate_linear
from repro.power.telemetry import StepTelemetry, mfu_utilization

__all__ = ["LinearPowerModel", "calibrate_linear", "StepTelemetry",
           "mfu_utilization"]
