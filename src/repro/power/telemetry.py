"""Step telemetry -> utilization (the per-container monitoring feed).

On a TPU slice the job owns every chip, so attribution is exact (unlike the
shared-server case Power Containers had to solve): utilization is MFU
derived from step timing + the analytic FLOPs of the step.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass
from typing import Deque


@dataclass
class StepTelemetry:
    t: float                # wall-clock (or sim-clock) seconds
    step_time_s: float
    tokens: int
    flops: float            # analytic model FLOPs for the step
    duty: float = 1.0


def mfu_utilization(flops: float, step_time_s: float, n_chips: int,
                    peak_flops: float) -> float:
    if step_time_s <= 0:
        return 0.0
    return min(1.0, flops / (step_time_s * n_chips * peak_flops))


class TelemetryWindow:
    """Rolling window of step telemetry, aggregated per monitoring interval."""

    def __init__(self, window_s: float = 300.0):
        self.window_s = window_s
        self.steps: Deque[StepTelemetry] = deque()

    def record(self, t: StepTelemetry):
        self.steps.append(t)
        cutoff = t.t - self.window_s
        while self.steps and self.steps[0].t < cutoff:
            self.steps.popleft()

    def utilization(self, n_chips: int, peak_flops: float) -> float:
        if not self.steps:
            return 0.0
        span = max(self.steps[-1].t - self.steps[0].t
                   + self.steps[-1].step_time_s, 1e-9)
        total_flops = sum(s.flops for s in self.steps)
        return min(1.0, total_flops / (span * n_chips * peak_flops))

    def throughput_tokens_s(self) -> float:
        if not self.steps:
            return 0.0
        span = max(self.steps[-1].t - self.steps[0].t
                   + self.steps[-1].step_time_s, 1e-9)
        return sum(s.tokens for s in self.steps) / span
