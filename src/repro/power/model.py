"""Power models (paper §3.1.2 / Fig. 6).

The paper validates a *linear* model on real servers — base power at idle
plus a marginal component tracking CPU utilization, with memory/disk/net
contributing little dynamic range (their Fig. 6), and cubic/ML models adding
no accuracy. We keep the same linear form:

    P(util) = P_base + (P_peak − P_base) · util

For TPU slices, ``util`` is MFU (achieved/peak FLOP/s): systolic arrays
idle cheaply, so chip power tracks issued MXU work near-linearly — the same
structural assumption the paper makes for CPUs, adapted to the accelerator.
For MoE architectures MFU is computed from *active* parameters
(6·N_active·D), since only routed experts consume MXU issue slots.

``calibrate_linear`` reproduces the paper's calibration workflow: fit
(base, peak) from (utilization, watts) samples by least squares.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Sequence

import numpy as np


@dataclass(frozen=True)
class LinearPowerModel:
    base_w: float
    peak_w: float

    def power(self, util: float) -> float:
        u = min(max(util, 0.0), 1.0)
        return self.base_w + (self.peak_w - self.base_w) * u

    def util_for_power(self, watts: float) -> float:
        """Inverse model: utilization quota that caps power at `watts`."""
        if watts <= self.base_w:
            return 0.0
        if self.peak_w <= self.base_w:
            return 1.0
        return min(1.0, (watts - self.base_w) / (self.peak_w - self.base_w))

    def scale(self, m: float) -> "LinearPowerModel":
        """Proportional family member (paper §5.1.2: power ∝ capacity)."""
        return LinearPowerModel(self.base_w * m, self.peak_w * m)


def calibrate_linear(utils: Sequence[float], watts: Sequence[float]) -> tuple:
    """Least-squares (base, peak) + R² from measurements (paper Fig. 6)."""
    u = np.asarray(utils, dtype=np.float64)
    w = np.asarray(watts, dtype=np.float64)
    A = np.stack([np.ones_like(u), u], axis=1)
    coef, *_ = np.linalg.lstsq(A, w, rcond=None)
    base, slope = float(coef[0]), float(coef[1])
    pred = A @ coef
    ss_res = float(np.sum((w - pred) ** 2))
    ss_tot = float(np.sum((w - np.mean(w)) ** 2))
    r2 = 1.0 - ss_res / max(ss_tot, 1e-12)
    return LinearPowerModel(base, base + slope), r2


# --- representative component sweeps (paper Fig. 6 reproduction) -----------

def component_power_sweep(model: LinearPowerModel, seed: int = 0) -> dict:
    """Measured-power-vs-utilization per component, Fig.6-shaped:
    CPU dominates the dynamic range; memory/disk/net contribute little."""
    rng = np.random.default_rng(seed)
    utils = np.linspace(0, 1, 11)
    spread = model.peak_w - model.base_w
    out = {"util": utils.tolist()}
    out["cpu"] = (model.base_w + spread * utils
                  + rng.normal(0, 0.01 * spread, 11)).tolist()
    # other components measured with CPU pinned at 100% (as in the paper)
    for comp, frac in (("memory", 0.05), ("disk", 0.03), ("network", 0.02)):
        out[comp] = (model.peak_w + frac * spread * utils
                     + rng.normal(0, 0.01 * spread, 11)).tolist()
    return out
