"""Data pipeline: synthetic LM streams with host-side sharded feeding."""
from repro.data.pipeline import SyntheticLM, markov_stream, shard_batch

__all__ = ["SyntheticLM", "markov_stream", "shard_batch"]
