"""Synthetic LM data pipeline.

Two generators:
  - ``SyntheticLM``: iid tokens — for lowering/throughput tests.
  - ``markov_stream``: order-1 Markov chain with low-entropy transitions —
    learnable structure, so example training runs show real loss decrease.

``shard_batch`` places host numpy batches onto a mesh with the model's
logical batch sharding (the host feed for multi-pod runs; per-process
slicing would plug in here under multi-controller JAX).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterator, Optional

import numpy as np

import jax
from jax.sharding import Mesh, NamedSharding

from repro.models.sharding import logical_to_pspec


@dataclass
class SyntheticLM:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0

    def __iter__(self) -> Iterator[dict]:
        rng = np.random.default_rng(self.seed)
        while True:
            tok = rng.integers(0, self.vocab_size,
                               (self.global_batch, self.seq_len + 1), dtype=np.int32)
            yield {"tokens": tok[:, :-1], "labels": tok[:, 1:]}


def markov_stream(vocab_size: int, seq_len: int, global_batch: int,
                  seed: int = 0, temperature: float = 0.3) -> Iterator[dict]:
    """Order-1 Markov chain over `vocab_size` states (learnable structure)."""
    rng = np.random.default_rng(seed)
    logits = rng.normal(0, 1, (vocab_size, vocab_size)) / max(temperature, 1e-3)
    probs = np.exp(logits - logits.max(-1, keepdims=True))
    probs /= probs.sum(-1, keepdims=True)
    cumprobs = np.cumsum(probs, axis=-1)
    while True:
        tok = np.zeros((global_batch, seq_len + 1), dtype=np.int32)
        tok[:, 0] = rng.integers(0, vocab_size, global_batch)
        u = rng.random((global_batch, seq_len))
        for t in range(seq_len):
            tok[:, t + 1] = (cumprobs[tok[:, t]] < u[:, t:t + 1]).sum(-1)
        yield {"tokens": tok[:, :-1], "labels": tok[:, 1:]}


def shard_batch(batch: dict, mesh: Optional[Mesh]) -> dict:
    """Place a host batch on the mesh with ('batch','seq') sharding."""
    if mesh is None or mesh.empty:
        return {k: jax.numpy.asarray(v) for k, v in batch.items()}
    out = {}
    for k, v in batch.items():
        axes = ("batch",) + (None,) * (v.ndim - 1)
        if v.ndim >= 2:
            axes = ("batch", "seq") + (None,) * (v.ndim - 2)
        sh = NamedSharding(mesh, logical_to_pspec(axes, v.shape, mesh))
        out[k] = jax.device_put(v, sh)
    return out
