"""CI benchmark-regression gate.

Reads the JSON report written by ``benchmarks.run --json`` and enforces
floor/ceiling constraints on its ``derived`` metrics, e.g.::

    python -m benchmarks.check_regression benchmarks/out/ci.json \\
        --min fleet_sweep.speedup_x=10 \\
        --min placement_sweep.speedup_x=3 \\
        --max placement_sweep.parity_max_abs_diff=1e-9

A dotted path ``entry.metric`` resolves through the entry's ``derived``
dict transparently (booleans coerce to 0/1, so ``--min x.assign_equal=1``
pins a flag). Exits 1 when any constraint is violated and 2 when a
referenced entry or metric is missing from the report — or present but
not a number — so a silently skipped benchmark also fails the job.
Every constraint is evaluated before exiting, so one missing entry does
not mask other regressions in the same run.
"""

from __future__ import annotations

import argparse
import json
import sys


class GateError(Exception):
    """A constraint that cannot be evaluated (missing entry/metric,
    non-numeric value). Carries the message the gate prints."""


def lookup(report: dict, dotted: str) -> float:
    node = report
    seen = []
    for part in dotted.split("."):
        derived = node.get("derived") if isinstance(node, dict) else None
        if isinstance(node, dict) and part in node:
            node = node[part]
        elif isinstance(derived, dict) and part in derived:
            node = derived[part]
        else:
            where = ".".join(seen) if seen else "report"
            have = sorted(node) if isinstance(node, dict) else []
            if isinstance(derived, dict):
                have = sorted(set(have) | set(derived))
            hint = f"; {where} has: {', '.join(have)}" if have else ""
            raise GateError(
                f"MISSING {dotted}: no {part!r} under {where}{hint}")
        seen.append(part)
    try:
        return float(node)
    except (TypeError, ValueError):
        raise GateError(f"NOT NUMERIC {dotted}: value {node!r} cannot be "
                        f"gated") from None


def parse_constraint(spec: str) -> tuple[str, float]:
    if "=" not in spec:
        raise argparse.ArgumentTypeError(f"expected key.path=value, got {spec!r}")
    path, _, value = spec.partition("=")
    return path, float(value)


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("report", help="JSON written by benchmarks.run --json")
    ap.add_argument(
        "--min",
        action="append",
        default=[],
        type=parse_constraint,
        metavar="PATH=FLOOR",
        help="fail when metric < floor (repeatable)",
    )
    ap.add_argument(
        "--max",
        action="append",
        default=[],
        type=parse_constraint,
        metavar="PATH=CEIL",
        help="fail when metric > ceiling (repeatable)",
    )
    args = ap.parse_args(argv)

    try:
        with open(args.report) as f:
            report = json.load(f)
    except OSError as e:
        print(f"UNREADABLE {args.report}: {e}")
        return 2
    except json.JSONDecodeError as e:
        print(f"INVALID JSON {args.report}: {e}")
        return 2

    failures = missing = 0
    for bound, specs in (("floor", args.min), ("ceiling", args.max)):
        for path, limit in specs:
            try:
                value = lookup(report, path)
            except GateError as e:
                print(e)
                missing += 1
                continue
            ok = value >= limit if bound == "floor" else value <= limit
            print(f"{'PASS' if ok else 'FAIL'} {path} = {value:g} "
                  f"({bound} {limit:g})")
            failures += not ok

    if missing:
        print(f"{missing} gated metric(s) missing from {args.report}"
              + (f"; {failures} constraint(s) violated" if failures else ""))
        return 2
    if failures:
        print(f"{failures} benchmark constraint(s) violated")
        return 1
    print("all benchmark constraints satisfied")
    return 0


if __name__ == "__main__":
    sys.exit(main())
