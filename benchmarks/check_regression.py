"""CI benchmark-regression gate.

Reads the JSON report written by ``benchmarks.run --json`` and enforces
floor/ceiling constraints on its ``derived`` metrics, e.g.::

    python -m benchmarks.check_regression benchmarks/out/ci.json \\
        --min fleet_sweep.speedup_x=10 \\
        --min placement_sweep.speedup_x=3 \\
        --max placement_sweep.parity_max_abs_diff=1e-9

A dotted path ``entry.metric`` resolves through the entry's ``derived``
dict transparently (booleans coerce to 0/1, so ``--min x.assign_equal=1``
pins a flag). Exits 1 when any constraint is violated and 2 when a
referenced entry or metric is missing from the report, so a silently
skipped benchmark also fails the job.
"""

from __future__ import annotations

import argparse
import json
import sys


def lookup(report: dict, dotted: str) -> float:
    node = report
    for part in dotted.split("."):
        if isinstance(node, dict) and part in node:
            node = node[part]
        elif isinstance(node, dict) and part in node.get("derived", {}):
            node = node["derived"][part]
        else:
            raise KeyError(dotted)
    return float(node)


def parse_constraint(spec: str) -> tuple[str, float]:
    if "=" not in spec:
        raise argparse.ArgumentTypeError(f"expected key.path=value, got {spec!r}")
    path, _, value = spec.partition("=")
    return path, float(value)


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("report", help="JSON written by benchmarks.run --json")
    ap.add_argument(
        "--min",
        action="append",
        default=[],
        type=parse_constraint,
        metavar="PATH=FLOOR",
        help="fail when metric < floor (repeatable)",
    )
    ap.add_argument(
        "--max",
        action="append",
        default=[],
        type=parse_constraint,
        metavar="PATH=CEIL",
        help="fail when metric > ceiling (repeatable)",
    )
    args = ap.parse_args(argv)

    with open(args.report) as f:
        report = json.load(f)

    failures = 0
    for path, floor in args.min:
        try:
            value = lookup(report, path)
        except KeyError:
            print(f"MISSING {path}: not in {args.report}")
            return 2
        ok = value >= floor
        print(f"{'PASS' if ok else 'FAIL'} {path} = {value:g} (floor {floor:g})")
        failures += not ok
    for path, ceil in args.max:
        try:
            value = lookup(report, path)
        except KeyError:
            print(f"MISSING {path}: not in {args.report}")
            return 2
        ok = value <= ceil
        print(f"{'PASS' if ok else 'FAIL'} {path} = {value:g} (ceiling {ceil:g})")
        failures += not ok

    if failures:
        print(f"{failures} benchmark constraint(s) violated")
        return 1
    print("all benchmark constraints satisfied")
    return 0


if __name__ == "__main__":
    sys.exit(main())
