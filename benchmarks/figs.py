"""Benchmark implementations: one function per paper table/figure.

Each returns (rows, derived) where rows are CSV-able dicts and `derived`
is the headline number validated against the paper's claim.
"""
from __future__ import annotations

import time

import numpy as np


def _timed(fn, *args, **kw):
    t0 = time.perf_counter()
    out = fn(*args, **kw)
    return out, (time.perf_counter() - t0) * 1e6


# ---------------------------------------------------------------------------
# Fig 1: 27-region average carbon-intensity + CoV, tier structure
# ---------------------------------------------------------------------------

def fig1_regions():
    from repro.carbon.regions import REGIONS, tier_means, tier_of
    rows = [{"region": r.name, "avg_g_kwh": r.avg, "cov": r.cov,
             "tier": tier_of(r.cov)}
            for r in sorted(REGIONS.values(), key=lambda x: x.cov)]
    means = tier_means()
    avgs = [r.avg for r in REGIONS.values()]
    derived = {
        "n_regions": len(rows),
        "spread_x": max(avgs) / min(avgs),                  # paper: >500x
        "frac_low_cov": np.mean([r.cov < 0.05 for r in REGIONS.values()]),
        "tier_mean_low": means["low"],                      # paper: 551
        "tier_mean_mid": means["mid"],                      # paper: 344
        "tier_mean_high": means["high"],                    # paper: 189
    }
    return rows, derived


# ---------------------------------------------------------------------------
# Fig 2: representative region traces (low/mid/high CoV over 96 h)
# ---------------------------------------------------------------------------

def fig2_traces():
    from repro.carbon.traces import synth_trace, trace_cov
    from repro.carbon.regions import REGIONS
    rows = []
    derived = {}
    for name in ("PL", "NL", "CAISO"):
        tr = synth_trace(name, hours=96, seed=0)
        for h, v in enumerate(tr):
            rows.append({"region": name, "hour": h, "g_kwh": float(v)})
        derived[f"{name}_cov"] = trace_cov(synth_trace(name, hours=24 * 365))
        derived[f"{name}_target_cov"] = REGIONS[name].cov
    return rows, derived


# ---------------------------------------------------------------------------
# Fig 3: Azure-like VM population CoV mixture
# ---------------------------------------------------------------------------

def fig3_workload(n_vms: int = 300):
    from repro.workload.azure_like import population_stats, sample_population
    pop = sample_population(n_vms, days=3, seed=0)
    stats = population_stats(pop)
    rows = [{"vm": i, "mean_util": t.mean, "cov": t.cov}
            for i, t in enumerate(pop)]
    # paper: 8% below 0.25, >50% above 0.4, 30% above 1.0, 43% mean<10%
    return rows, stats


# ---------------------------------------------------------------------------
# Fig 6: power-model linearity + calibration
# ---------------------------------------------------------------------------

def fig6_power():
    from repro.power.model import (LinearPowerModel, calibrate_linear,
                                   component_power_sweep)
    truth = LinearPowerModel(100.0, 200.0)
    sweep = component_power_sweep(truth, seed=0)
    model, r2 = calibrate_linear(sweep["util"], sweep["cpu"])
    rows = [{"util": u, **{c: sweep[c][i] for c in
                           ("cpu", "memory", "disk", "network")}}
            for i, u in enumerate(sweep["util"])]
    dyn_range = {c: max(sweep[c]) - min(sweep[c])
                 for c in ("cpu", "memory", "disk", "network")}
    return rows, {"fit_base_w": model.base_w, "fit_peak_w": model.peak_w,
                  "r2": r2, **{f"dyn_range_{k}": v for k, v in dyn_range.items()}}


# ---------------------------------------------------------------------------
# Fig 7: migration time vs state size — measured on our checkpoint path
# ---------------------------------------------------------------------------

def fig7_migration():
    import os
    os.environ.setdefault("XLA_FLAGS", "")
    import tempfile
    import jax
    from repro.train import checkpoint as CKPT

    rows = []
    sizes_mb = [8, 32, 128]
    times = []
    for mb in sizes_mb:
        n = mb * 1024 * 1024 // 4
        state = {"w": jax.numpy.arange(n, dtype=jax.numpy.float32)}
        with tempfile.TemporaryDirectory() as d:
            info = CKPT.save(d, state, step=0)
            t0 = time.perf_counter()
            CKPT.load(d, {"w": jax.ShapeDtypeStruct((n,), jax.numpy.float32)})
            restore_s = time.perf_counter() - t0
        rows.append({"state_mb": mb, "save_s": info["total_s"],
                     "restore_s": restore_s,
                     "total_s": info["total_s"] + restore_s})
        times.append(info["total_s"] + restore_s)
    # linearity check (paper: all curves linear in footprint)
    x = np.array(sizes_mb, dtype=float)
    y = np.array(times)
    slope, intercept = np.polyfit(x, y, 1)
    pred = slope * x + intercept
    r2 = 1 - np.sum((y - pred) ** 2) / max(np.sum((y - y.mean()) ** 2), 1e-12)
    # model-side numbers (paper's 7 GB < 2 min claim)
    from repro.cluster.migration import MigrationCostModel
    m = MigrationCostModel()
    return rows, {"linear_r2": r2, "s_per_gb_measured": slope * 1024,
                  "model_7gb_stop_copy_s": m.stop_and_copy_time(7.0)}


# ---------------------------------------------------------------------------
# Fig 10: prototype timeseries (single container, EE policy)
# ---------------------------------------------------------------------------

def fig10_prototype():
    from repro.carbon.intensity import ConstantProvider
    from repro.cluster.slices import paper_family
    from repro.core.policy import CarbonContainerPolicy
    from repro.core.simulator import SimConfig, simulate

    fam = paper_family()
    # ~1 h at 1-min intervals; carbon steady (as in the paper's Fig 10 run)
    t = np.arange(60)
    demand = 0.45 + 0.25 * np.sin(2 * np.pi * t / 40.0) * (t > 10)
    cfg = SimConfig(target_rate=45.0, interval_s=60.0, record_series=True,
                    state_gb=0.5)
    res = simulate(CarbonContainerPolicy(variant="energy"), fam, demand,
                   ConstantProvider(300.91), cfg)
    s = res.series
    rows = [{"t_min": s["t"][i] / 60.0, "carbon_rate": s["carbon_rate"][i],
             "slice": str(s["slice"][i]), "duty": s["duty"][i],
             "util": s["util"][i], "demand": s["demand"][i]}
            for i in range(len(s["t"]))]
    return rows, {"avg_rate": res.avg_carbon_rate, "target": 45.0,
                  "migrations": res.migrations,
                  "under_target": res.avg_carbon_rate <= 45.0}


# ---------------------------------------------------------------------------
# Figs 11-14: policy comparison across targets (high / medium variability)
# ---------------------------------------------------------------------------

def _policy_sweep(region: str, n_jobs: int, targets, days=7,
                  backend="fleet"):
    from repro.carbon.intensity import TraceProvider
    from repro.cluster.slices import paper_family
    from repro.core.policy import (CarbonAgnosticPolicy,
                                   CarbonContainerPolicy,
                                   SuspendResumePolicy, VScaleOnlyPolicy)
    from repro.core.simulator import SimConfig, sweep_population
    from repro.workload.azure_like import sample_population

    fam = paper_family()
    carbon = TraceProvider.for_region(region, hours=24 * days, seed=1)
    traces = [t.util for t in sample_population(n_jobs, days=days, seed=2)]
    policies = {
        "carbon_agnostic": CarbonAgnosticPolicy,
        "suspend_resume": SuspendResumePolicy,
        "vscale_only": lambda: VScaleOnlyPolicy(),
        "carbon_containers": lambda: CarbonContainerPolicy(variant="energy"),
    }
    rows = sweep_population(policies, fam, traces, carbon, targets,
                            SimConfig(target_rate=0.0), backend=backend)
    return rows


def fig11_12_highvar(n_jobs: int = 40):
    targets = [20.0, 35.0, 50.0, 65.0, 80.0]
    rows = _policy_sweep("CAISO", n_jobs, targets)
    cc = [r for r in rows if r["policy"] == "carbon_containers"]
    sr = [r for r in rows if r["policy"] == "suspend_resume"]
    derived = {
        "cc_all_under_target": all(r["carbon_rate_mean"] <= r["target"] for r in cc),
        "cc_throttle_mean": np.mean([r["throttle_mean"] for r in cc]),
        "sr_throttle_mean": np.mean([r["throttle_mean"] for r in sr]),
        "cc_beats_sr_throttle": all(
            c["throttle_mean"] <= s["throttle_mean"] + 0.1
            for c, s in zip(cc, sr)),
    }
    return rows, derived


def fig13_14_medvar(n_jobs: int = 40):
    targets = [20.0, 35.0, 50.0, 65.0, 80.0]
    rows = _policy_sweep("NL", n_jobs, targets)
    cc = [r for r in rows if r["policy"] == "carbon_containers"]
    vs = [r for r in rows if r["policy"] == "vscale_only"]
    derived = {
        "cc_all_under_target": all(r["carbon_rate_mean"] <= r["target"] for r in cc),
        "cc_vs_vscale_throttle": [
            (c["target"], c["throttle_mean"], v["throttle_mean"])
            for c, v in zip(cc, vs)],
        "cc_beats_vscale": all(
            c["throttle_mean"] <= v["throttle_mean"] + 0.5 for c, v in zip(cc, vs)),
    }
    return rows, derived


# ---------------------------------------------------------------------------
# Figs 15-17: energy-efficiency vs performance variants + slice residency
# ---------------------------------------------------------------------------

def fig15_16_variants(n_jobs: int = 30):
    from repro.carbon.intensity import TraceProvider
    from repro.cluster.slices import paper_family
    from repro.core.policy import CarbonContainerPolicy
    from repro.core.simulator import SimConfig, sweep_population
    from repro.workload.azure_like import sample_population

    fam = paper_family()
    targets = [25.0, 45.0, 65.0, 85.0]
    out_rows = []
    derived = {}
    for region in ("CAISO", "NL"):
        carbon = TraceProvider.for_region(region, hours=24 * 7, seed=1)
        traces = [t.util for t in sample_population(n_jobs, days=7, seed=2)]
        rows = sweep_population(
            {"energy": lambda: CarbonContainerPolicy(variant="energy"),
             "performance": lambda: CarbonContainerPolicy(variant="performance")},
            fam, traces, carbon, targets, SimConfig(target_rate=0.0),
            backend="fleet")
        for r in rows:
            r["region"] = region
        out_rows.extend(rows)
        en = [r for r in rows if r["policy"] == "energy"]
        pf = [r for r in rows if r["policy"] == "performance"]
        derived[f"{region}_perf_emits_more"] = all(
            p["carbon_rate_mean"] >= e["carbon_rate_mean"] - 1e-9
            for p, e in zip(pf, en))
        derived[f"{region}_both_under_target"] = all(
            r["carbon_rate_mean"] <= r["target"] * 1.02 for r in rows)
    return out_rows, derived


# ---------------------------------------------------------------------------
# fleet_sweep: vectorized fleet simulator vs looped simulate() (perf record)
# ---------------------------------------------------------------------------

def _best_of_interleaved(fast_fn, slow_fn, rounds: int = 5,
                         fast_reps: int = 2):
    """Fair fast-vs-slow timing: interleave rounds so host load drift
    hits both sides alike, keep going until neither best-of improves
    (max `rounds`; the cheap vectorized side gets `fast_reps` per
    round). Returns (fast_out, fast_s, slow_out, slow_s)."""
    fast_s = slow_s = float("inf")
    fast_out = slow_out = None
    for _ in range(rounds):
        improved = False
        for _ in range(fast_reps):
            t0 = time.perf_counter()
            out = fast_fn()
            s = time.perf_counter() - t0
            if s < fast_s:
                fast_out, fast_s, improved = out, s, True
        t0 = time.perf_counter()
        out = slow_fn()
        s = time.perf_counter() - t0
        if s < slow_s:
            slow_out, slow_s, improved = out, s, True
        if not improved:
            break
    return fast_out, fast_s, slow_out, slow_s


def fleet_sweep(n_traces: int = 64, n_targets: int = 4, days: int = 3):
    """64-trace x 4-target x 3-policy sweep, scalar vs fleet backend.

    Headline numbers: `speedup_x` (wall-clock, best-of-N each) and
    `parity_max_abs_diff` (row-level agreement between backends; the fleet
    path is bit-compatible, so this is expected to be 0.0).

    Notes — `FleetSimulator._loop` temporary preallocation (PR 5): the
    `_LoopScratch` buffers took the CC-energy fleet run at T=576 from
    ~0.77s to ~0.70s at N=5040 (~6-8%) and were neutral at N=420
    (best-of-4, alternated A/B on an otherwise idle 2-vCPU host). NumPy's
    small-block cache already amortizes most temporary allocation: only
    single-pass ufunc-`out=` rewrites pay, `np.take(..., out=)` needs
    mode="clip" to match fancy indexing's fast path, and splitting a
    `np.where` into fill+masked-copy regressed ~8% and was reverted.
    """
    from repro.carbon.intensity import TraceProvider
    from repro.cluster.slices import paper_family
    from repro.core.policy import (CarbonAgnosticPolicy,
                                   CarbonContainerPolicy,
                                   SuspendResumePolicy)
    from repro.core.simulator import SimConfig, sweep_population
    from repro.workload.azure_like import sample_population

    fam = paper_family()
    carbon = TraceProvider.for_region("CAISO", hours=24 * days, seed=1)
    traces = [t.util for t in sample_population(n_traces, days=days, seed=2)]
    targets = list(np.linspace(20.0, 80.0, n_targets))
    policies = {
        "carbon_agnostic": CarbonAgnosticPolicy,
        "suspend_resume": SuspendResumePolicy,
        "carbon_containers": lambda: CarbonContainerPolicy(variant="energy"),
    }
    cfg = SimConfig(target_rate=0.0)

    def _backend(backend):
        return lambda: sweep_population(policies, fam, traces, carbon,
                                        targets, cfg, backend=backend)

    rows_fleet, fleet_s, rows_scalar, scalar_s = _best_of_interleaved(
        _backend("fleet"), _backend("scalar"))
    keys = ("carbon_rate_mean", "carbon_rate_std", "throttle_mean",
            "throttle_std", "migrations_mean", "suspended_frac_mean")
    parity = max(abs(a[k] - b[k])
                 for a, b in zip(rows_scalar, rows_fleet) for k in keys)
    rows = [{"backend": "scalar", "wall_s": scalar_s, **{
             k: r[k] for k in ("policy", "target") + keys}}
            for r in rows_scalar]
    rows += [{"backend": "fleet", "wall_s": fleet_s, **{
              k: r[k] for k in ("policy", "target") + keys}}
             for r in rows_fleet]
    n_sims = n_traces * n_targets * len(policies)
    derived = {
        "n_sims": n_sims,
        "n_intervals": n_sims * len(traces[0]),
        "scalar_s": scalar_s,
        "fleet_s": fleet_s,
        "speedup_x": scalar_s / fleet_s,
        "parity_max_abs_diff": parity,
        "speedup_ge_20x": scalar_s / fleet_s >= 20.0,
    }
    return rows, derived


# ---------------------------------------------------------------------------
# placement_sweep: multi-region placement planner, scalar vs batch (perf
# record) + carbon saving of the placed fleet over the static baseline
# ---------------------------------------------------------------------------

def placement_sweep(n_containers: int = 192, days: int = 3):
    """Scalar greedy reference vs vectorized (N, R) placement planner.

    Headline numbers: `speedup_x` (wall-clock, best-of interleaved reps),
    `parity_max_abs_diff` (overhead/downtime agreement; the batch kernel
    is bit-compatible so this is expected to be 0.0), `assign_equal`
    (epoch-by-epoch region assignments identical), and
    `saving_vs_static_pct` (fleet emissions saved vs the no-migration
    baseline, stop-and-copy overhead included).
    """
    from repro.carbon.intensity import TraceProvider
    from repro.cluster.placement import PlacementConfig, PlacementEngine
    from repro.cluster.slices import paper_family
    from repro.core.policy import CarbonContainerPolicy
    from repro.workload.azure_like import sample_population

    fam = paper_family()
    regions = ("PL", "NL", "CAISO")
    provs = [TraceProvider.for_region(r, hours=24 * days, seed=1)
             for r in regions]
    traces = [t.util for t in sample_population(n_containers, days=days,
                                                seed=2)]
    demand = np.stack(traces, axis=1)
    rng = np.random.default_rng(3)
    state_gb = rng.choice([0.25, 1.0, 4.0], size=n_containers)
    cap = int(np.ceil(0.6 * n_containers))
    eng = PlacementEngine(
        fam, provs, region_names=regions,
        config=PlacementConfig(capacity=cap, min_dwell=6, hysteresis=0.10))

    plan_v, vec_s, plan_s, scalar_s = _best_of_interleaved(
        lambda: eng.plan(demand, state_gb=state_gb),
        lambda: eng.plan_scalar(demand, state_gb=state_gb))

    assign_equal = bool((plan_v.assign == plan_s.assign).all())
    parity = max(float(np.abs(plan_v.overhead_g - plan_s.overhead_g).max()),
                 float(np.abs(plan_v.downtime_s - plan_s.downtime_s).max()),
                 float(np.abs(plan_v.migrations - plan_s.migrations).max()))
    occ = plan_v.occupancy()
    over_cap = int((occ > cap).sum())

    res = eng.run(CarbonContainerPolicy("energy"), demand, targets=45.0,
                  state_gb=state_gb, plan=plan_v, compare_static=True)

    rows = [{"backend": b, "wall_s": s, "n_containers": n_containers,
             "n_epochs": demand.shape[0], "migrations":
             int(p.migrations.sum()), "overhead_g":
             float(p.overhead_g.sum())}
            for b, s, p in (("scalar", scalar_s, plan_s),
                            ("batch", vec_s, plan_v))]
    derived = {
        "n_containers": n_containers,
        "n_epochs": demand.shape[0],
        "scalar_s": scalar_s,
        "vec_s": vec_s,
        "speedup_x": scalar_s / vec_s,
        "parity_max_abs_diff": parity,
        "assign_equal": assign_equal,
        "over_capacity_epochs": over_cap,
        "placement_migrations": int(plan_v.migrations.sum()),
        "saving_vs_static_pct": res.saving_vs_static_pct,
        **{f"occ_end_{name}": int(occ[-1, r])
           for r, name in enumerate(regions)},
    }
    return rows, derived


# ---------------------------------------------------------------------------
# fleet_sweep_jax / placement_sweep_jax: the jit/scan device-resident JAX
# backend vs the NumPy fleet/placement kernels (perf record; compile time
# is reported separately from steady state so regression floors never see
# it)
# ---------------------------------------------------------------------------

def _steady_vs_numpy(jax_fn, numpy_fn, reps: int = 8):
    """Warm the jax side once (timed: includes jit compile), then
    interleave steady-state reps against the NumPy side so host load
    drift hits both alike. Returns (jax_out, warmup_s, steady_s,
    numpy_out, numpy_s)."""
    t0 = time.perf_counter()
    jax_out = jax_fn()
    warmup_s = time.perf_counter() - t0
    steady_s = numpy_s = float("inf")
    numpy_out = None
    for _ in range(reps):
        t0 = time.perf_counter()
        jax_out = jax_fn()
        steady_s = min(steady_s, time.perf_counter() - t0)
        t0 = time.perf_counter()
        numpy_out = numpy_fn()
        numpy_s = min(numpy_s, time.perf_counter() - t0)
    return jax_out, warmup_s, steady_s, numpy_out, numpy_s


def fleet_sweep_jax(n_traces: int = 420, n_targets: int = 12,
                    days: int = 3):
    """Carbon Containers (energy) sweep over n_traces x n_targets =
    5040 containers with mixed-region stacked carbon traces: NumPy fleet
    backend vs the jit/scan JAX backend (`sweep_population` both ways).

    Headline numbers: `speedup_x` = fleet_s / steady_s (steady state:
    best-of interleaved reps after the warmup call), `warmup_s` (first
    call, includes jit compile — reported separately so it never
    pollutes regression floors), and `parity_max_abs_diff` across all
    aggregate row metrics (ceiling 1e-6; the NumPy backend itself stays
    pinned to the scalar loop at 1e-9, anchoring the chain).

    Requires jax; the CPU-tuned XLA flags (legacy runtime + 4 host
    devices for container-sharding) are set by benchmarks/run.py before
    jax initializes.
    """
    import jax
    from repro.carbon.intensity import TraceProvider
    from repro.cluster.slices import paper_family
    from repro.core.policy import CarbonContainerPolicy
    from repro.core.simulator import SimConfig, sweep_population
    from repro.workload.azure_like import sample_population

    fam = paper_family()
    regions = ("PL", "NL", "CAISO")
    provs = [TraceProvider.for_region(r, hours=24 * days, seed=1)
             for r in regions]
    traces = [t.util for t in sample_population(n_traces, days=days,
                                                seed=2)]
    T = len(traces[0])
    tvec = np.arange(T) * 300.0
    region_mat = np.stack([p.intensity_series(tvec) for p in provs], axis=1)
    # container i lives in region i % R: a (T, n_traces) stacked-trace
    # matrix, tiled across the target axis like the demand matrix
    cmat_tr = region_mat[:, np.arange(n_traces) % len(regions)]
    carbon = np.tile(cmat_tr, (1, n_targets))
    targets = list(np.linspace(20.0, 80.0, n_targets))
    policies = {"carbon_containers":
                lambda: CarbonContainerPolicy(variant="energy")}
    cfg = SimConfig(target_rate=0.0)

    def _backend(backend):
        return lambda: sweep_population(policies, fam, traces, carbon,
                                        targets, cfg, backend=backend)

    rows_jax, warmup_s, steady_s, rows_fleet, fleet_s = _steady_vs_numpy(
        _backend("jax"), _backend("fleet"))
    keys = ("carbon_rate_mean", "carbon_rate_std", "throttle_mean",
            "throttle_std", "migrations_mean", "suspended_frac_mean")
    parity = max(abs(a[k] - b[k])
                 for a, b in zip(rows_fleet, rows_jax) for k in keys)
    rows = [{"backend": b, "wall_s": s, **{k: r[k]
             for k in ("policy", "target") + keys}}
            for b, s, rws in (("fleet", fleet_s, rows_fleet),
                              ("jax", steady_s, rows_jax))
            for r in rws]
    n_containers = n_traces * n_targets
    derived = {
        "n_containers": n_containers,
        "n_epochs": T,
        "n_devices": len(jax.devices()),
        "fleet_s": fleet_s,
        "warmup_s": warmup_s,
        "steady_s": steady_s,
        "speedup_x": fleet_s / steady_s,
        "parity_max_abs_diff": parity,
        "speedup_ge_5x": fleet_s / steady_s >= 5.0,
    }
    return rows, derived


def placement_sweep_jax(n_containers: int = 2000, days: int = 3):
    """Multi-region placement planner at fleet scale: NumPy (N, R) batch
    kernel vs the jit/scan JAX planner (`plan_jax`), heterogeneous state
    sizes, per-region capacity.

    Headline numbers: `speedup_x` = numpy_s / steady_s (compile time in
    `warmup_s`, reported separately), `assign_equal` (epoch-by-epoch
    region assignments identical), `parity_max_abs_diff` on
    overhead/downtime/migrations (ceiling 1e-6; the NumPy planner stays
    bit-compatible with the greedy scalar reference), and
    `over_capacity_epochs` (must be 0).
    """
    from repro.carbon.intensity import TraceProvider
    from repro.cluster.placement import PlacementConfig, PlacementEngine
    from repro.cluster.placement_jax import plan_jax
    from repro.cluster.slices import paper_family
    from repro.workload.azure_like import sample_population

    fam = paper_family()
    regions = ("PL", "NL", "CAISO")
    provs = [TraceProvider.for_region(r, hours=24 * days, seed=1)
             for r in regions]
    traces = [t.util for t in sample_population(n_containers, days=days,
                                                seed=2)]
    demand = np.stack(traces, axis=1)
    rng = np.random.default_rng(3)
    state_gb = rng.choice([0.25, 1.0, 4.0], size=n_containers)
    cap = int(np.ceil(0.6 * n_containers))
    eng = PlacementEngine(
        fam, provs, region_names=regions,
        config=PlacementConfig(capacity=cap, min_dwell=6, hysteresis=0.10))

    plan_j, warmup_s, steady_s, plan_np, numpy_s = _steady_vs_numpy(
        lambda: plan_jax(eng, demand, state_gb=state_gb),
        lambda: eng.plan(demand, state_gb=state_gb))

    assign_equal = bool((plan_j.assign == plan_np.assign).all())
    parity = max(float(np.abs(plan_j.overhead_g - plan_np.overhead_g).max()),
                 float(np.abs(plan_j.downtime_s - plan_np.downtime_s).max()),
                 float(np.abs(plan_j.migrations - plan_np.migrations).max()))
    occ = plan_j.occupancy()
    rows = [{"backend": b, "wall_s": s, "n_containers": n_containers,
             "n_epochs": demand.shape[0],
             "migrations": int(p.migrations.sum()),
             "overhead_g": float(p.overhead_g.sum())}
            for b, s, p in (("numpy", numpy_s, plan_np),
                            ("jax", steady_s, plan_j))]
    derived = {
        "n_containers": n_containers,
        "n_epochs": demand.shape[0],
        "numpy_s": numpy_s,
        "warmup_s": warmup_s,
        "steady_s": steady_s,
        "speedup_x": numpy_s / steady_s,
        "parity_max_abs_diff": parity,
        "assign_equal": assign_equal,
        "over_capacity_epochs": int((occ > cap).sum()),
    }
    return rows, derived


def placement_sweep_pallas(n_containers: int = 384, days: int = 2):
    """Pallas admission-kernel dispatch check: `plan_jax` with
    `admission_impl="pallas"` (interpret mode on CPU — the same kernel
    Mosaic compiles on TPU/GPU) vs the NumPy planner, tight capacity so
    every epoch exercises the ranked-admission rounds.

    Headline numbers: `assign_equal` / `parity_max_abs_diff` /
    `over_capacity_epochs` (the parity chain, same ceilings as
    placement_sweep_jax) and `speedup_x` vs NumPy. The regression floor
    is interpret-safe (~0.05x): interpret mode runs the kernel through
    XLA op-by-op, so the floor gates "not pathologically slow /
    parity intact", not kernel throughput — that needs the real
    accelerator path.
    """
    from repro.carbon.intensity import TraceProvider
    from repro.cluster.placement import PlacementConfig, PlacementEngine
    from repro.cluster.placement_jax import plan_jax
    from repro.cluster.slices import paper_family
    from repro.workload.azure_like import sample_population_matrix

    fam = paper_family()
    regions = ("PL", "NL", "CAISO")
    provs = [TraceProvider.for_region(r, hours=24 * days, seed=1)
             for r in regions]
    demand = sample_population_matrix(n_containers, days=days, seed=2)
    rng = np.random.default_rng(3)
    state_gb = rng.choice([0.25, 1.0, 4.0], size=n_containers)
    cap = int(np.ceil(0.55 * n_containers))
    eng = PlacementEngine(
        fam, provs, region_names=regions,
        config=PlacementConfig(capacity=cap, min_dwell=6, hysteresis=0.10))

    plan_p, warmup_s, steady_s, plan_np, numpy_s = _steady_vs_numpy(
        lambda: plan_jax(eng, demand, state_gb=state_gb,
                         admission_impl="pallas"),
        lambda: eng.plan(demand, state_gb=state_gb), reps=3)

    assign_equal = bool((plan_p.assign == plan_np.assign).all())
    parity = max(float(np.abs(plan_p.overhead_g - plan_np.overhead_g).max()),
                 float(np.abs(plan_p.downtime_s - plan_np.downtime_s).max()),
                 float(np.abs(plan_p.migrations - plan_np.migrations).max()))
    occ = plan_p.occupancy()
    rows = [{"backend": b, "wall_s": s, "n_containers": n_containers,
             "n_epochs": demand.shape[0],
             "migrations": int(p.migrations.sum()),
             "overhead_g": float(p.overhead_g.sum())}
            for b, s, p in (("numpy", numpy_s, plan_np),
                            ("pallas", steady_s, plan_p))]
    derived = {
        "n_containers": n_containers,
        "n_epochs": demand.shape[0],
        "numpy_s": numpy_s,
        "warmup_s": warmup_s,
        "steady_s": steady_s,
        "speedup_x": numpy_s / steady_s,
        "parity_max_abs_diff": parity,
        "assign_equal": assign_equal,
        "over_capacity_epochs": int((occ > cap).sum()),
    }
    return rows, derived


def jax_sweep_scale(n_traces: int = 100_000, n_targets: int = 10,
                    days: int = 1):
    """The N=1M placed fleet sweep: n_traces x n_targets containers
    (1,000,000 at the defaults), one day at 5-minute epochs, through the
    full jax path — vectorized trace generation, the capacity-planned
    region schedule (`plan_jax`), and the memory-lean indexed-carbon
    fleet scan (compact demand + in-step target tiling; no (T, N) array
    on host or device) — with the carbon-aware traffic subsystem folded
    in: a 1M-user request population is routed and autoscaled per epoch
    and modulates every container's demand, the virtual energy supply
    layer runs the host supply ledger on the compact fleet (solar +
    battery + grid with a mid-day regional outage; cap_frac applied on
    host, carbon billed at the delivered mix through the indexed
    (c_eff, codes) layout so no (T, N) carbon matrix appears), and the
    per-container elasticity layer runs its own compact-width scan (the
    (N·K,) marginal-allocation argsort per epoch, under a shaped fleet
    carbon budget) whose served demand feeds the fleet scan. A
    signal-plane fault plan is enabled throughout: carbon-feed dropouts
    plus a fleet-wide blackout window degraded through the
    hold/prior/floor ladder, power-meter gaps (unmetered emissions
    surfaced per row), and seeded migration failures with capped
    exponential backoff in the planner. The 4 GB RSS ceiling holds with
    all three layers AND the fault plan enabled, and the energy
    invariants (conservation, zero cap/SoC violations) gate alongside
    the throughput floor.

    Headline numbers: `container_epochs_per_s` = N * T / steady_s
    (steady state: second sweep call, jit cache warm), `warmup_s`
    (first call, includes compile AND the placement plan),
    `over_capacity_epochs` (the plan is recomputed once outside the
    timed region for the invariant check — plans are deterministic, so
    it is the same plan the sweep used). NumPy comparison is deliberately
    absent: the fleet backend needs the ~2.3 GB tiled matrices and tens
    of minutes at this N — parity is pinned at 50k by
    tests/test_placement_scale.py instead.
    """
    from repro.carbon.intensity import TraceProvider
    from repro.cluster.placement import PlacementConfig, PlacementEngine
    from repro.cluster.placement_jax import plan_jax
    from repro.cluster.slices import paper_family
    from repro.core.elasticity import ElasticityConfig
    from repro.core.policy import CarbonContainerPolicy
    from repro.core.simulator import SimConfig, sweep_population
    from repro.energy import EnergyConfig, GridEventConfig
    from repro.robustness import (CarbonFeedFaults, DegradeConfig,
                                  FaultPlan, MigrationFaults,
                                  PowerTelemetryFaults)
    from repro.traffic import TrafficConfig, UserPopulation
    from repro.traffic.autoscale import ReplicaConfig
    from repro.workload.azure_like import sample_population_matrix

    fam = paper_family()
    regions = ("PL", "NL", "CAISO")
    provs = [TraceProvider.for_region(r, hours=24 * days, seed=1)
             for r in regions]
    t0 = time.perf_counter()
    demand = sample_population_matrix(n_traces, days=days, seed=2)
    gen_s = time.perf_counter() - t0
    cap = int(np.ceil(0.6 * n_traces))
    eng = PlacementEngine(
        fam, provs, region_names=regions,
        config=PlacementConfig(capacity=cap, min_dwell=6, hysteresis=0.10))
    targets = list(np.linspace(20.0, 80.0, n_targets))
    policies = {"carbon_containers":
                lambda: CarbonContainerPolicy(variant="energy")}
    cfg = SimConfig(target_rate=0.0)
    traffic = TrafficConfig(
        population=UserPopulation(n_users=1_000_000, n_regions=3, seed=3),
        replicas=ReplicaConfig(max_replicas=8, max_step=4))
    # mildly-binding shaped budget: ~2.5 g/epoch per trace keeps the
    # (N*K,) greedy genuinely selective without starving the fleet
    elastic = ElasticityConfig(k_levels=4, unit_capacity=0.3,
                               budget_g_per_epoch=2.5 * n_traces,
                               forecast="forecast", shape_budget=True)
    T_ep = 288 * days
    energy = EnergyConfig(events=GridEventConfig(
        outages=((1, T_ep // 3, T_ep // 24),),
        shocks=((-1, T_ep // 2, T_ep // 12, 1.6),)))
    # non-trivial fault plan: the throughput floor and RSS ceiling must
    # hold with the signal plane degraded (the observed (T, R) feed and
    # the (T,) gap vector are the only extra arrays — nothing (T, N))
    flt = FaultPlan(
        carbon=CarbonFeedFaults(dropout_prob=0.2,
                                blackouts=((-1, T_ep // 3, T_ep // 12),)),
        power=PowerTelemetryFaults(gap_prob=0.05),
        migration=MigrationFaults(fail_prob=0.2, backoff_cap=8),
        degrade=DegradeConfig(mode="ladder", ttl_epochs=3),
        seed=11)

    def _sweep():
        return sweep_population(policies, fam, demand, None, targets, cfg,
                                backend="jax", placement=eng,
                                traffic=traffic, elasticity=elastic,
                                energy=energy, faults=flt)

    t0 = time.perf_counter()
    rows_w = _sweep()
    warmup_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    rows_jax = _sweep()
    steady_s = time.perf_counter() - t0

    # invariant-check plan, recomputed the way the sweep built it:
    # grid shocks applied to the TRUE feed first (physical), then the
    # degrade ladder on top — the planner only ever saw the observed
    # signal, and threads the same seeded migration-failure mask
    import copy as _copy

    from repro.energy.supply import event_matrices
    from repro.robustness.degrade import observe_intensity
    shock_mult, _ = event_matrices(energy.events, T_ep, eng.n_regions)
    true_reg = eng._region_matrix(T_ep) * shock_mult
    eng_chk = _copy.copy(eng)
    eng_chk.regions = observe_intensity(true_reg, flt,
                                        eng.interval_s).observed
    plan = plan_jax(eng_chk, demand, state_gb=cfg.state_gb, faults=flt)
    occ = plan.occupancy()
    n_containers = n_traces * n_targets
    T = demand.shape[0]
    rows = [{"backend": "jax", "wall_s": steady_s,
             "n_containers": n_containers, "n_epochs": T,
             **{k: r[k] for k in ("policy", "target", "carbon_rate_mean",
                                  "throttle_mean", "migrations_mean")}}
            for r in rows_jax]
    derived = {
        "n_containers": n_containers,
        "n_traces": n_traces,
        "n_targets": n_targets,
        "n_epochs": T,
        "gen_s": gen_s,
        "warmup_s": warmup_s,
        "steady_s": steady_s,
        "container_epochs_per_s": n_containers * T / steady_s,
        "placement_migrations": int(plan.migrations.sum()),
        "over_capacity_epochs": int((occ > cap).sum()),
        "rows_match_warmup": rows_jax == rows_w,
        "traffic_n_users": traffic.population.n_users,
        "traffic_served": rows_jax[0]["traffic_served"],
        "traffic_violation_rate": rows_jax[0]["traffic_violation_rate"],
        "traffic_carbon_per_request_g":
            rows_jax[0]["traffic_carbon_per_request_g"],
        "elastic_served_frac": rows_jax[0]["elastic_served_frac"],
        "elastic_level_epochs": rows_jax[0]["elastic_level_epochs"],
        "elastic_cap_violations": rows_jax[0]["elastic_cap_violations"],
        "energy_conservation_max_err_w":
            rows_jax[0]["energy_conservation_max_err_w"],
        "energy_cap_violations": int(rows_jax[0]["energy_cap_violations"]),
        "energy_soc_violations": int(rows_jax[0]["energy_soc_violations"]),
        "energy_outage_epochs": int(rows_jax[0]["energy_outage_epochs"]),
        "energy_solar_frac": rows_jax[0]["energy_solar_frac"],
        "energy_unmet_frac": rows_jax[0]["energy_unmet_frac"],
        "fault_stale_frac": rows_jax[0]["fault_stale_frac"],
        "fault_prior_frac": rows_jax[0]["fault_prior_frac"],
        "fault_floor_frac": rows_jax[0]["fault_floor_frac"],
        "fault_failed_migrations_mean":
            rows_jax[0]["fault_failed_migrations_mean"],
        "fault_unmetered_g_mean": rows_jax[0]["fault_unmetered_g_mean"],
    }
    return rows, derived


def fig17_server_time(n_jobs: int = 30):
    rows, _ = fig15_16_variants(n_jobs)
    out = []
    for r in rows:
        if r["region"] != "CAISO":
            continue
        for sl, frac in sorted(r["time_on_slice"].items()):
            out.append({"policy": r["policy"], "target": r["target"],
                        "slice": sl, "frac": frac})
    big = {}
    for r in rows:
        if r["region"] != "CAISO":
            continue
        large = sum(v for k, v in r["time_on_slice"].items() if k in ("x2", "x4"))
        big.setdefault(r["policy"], []).append(large)
    derived = {"perf_more_time_on_large": float(np.mean(big.get("performance", [0])))
               >= float(np.mean(big.get("energy", [0])))}
    return out, derived


# ---------------------------------------------------------------------------
# Carbon-aware traffic subsystem: routing speedup, carbon-vs-latency
# headline, end-to-end sweep parity
# ---------------------------------------------------------------------------

def traffic_sweep(n_users: int = 1_000_000, days: int = 1,
                  n_traces: int = 16):
    """The traffic subsystem's benchmark-gate entry.

    Three claims in one scenario (a 1M-user population across three
    regions 8 time-zone-hours apart, so every pair is SLO-feasible at
    the 200 ms bound and both routing policies violate nothing):

      - `speedup_x` / `parity_max_abs_diff`: the vectorized router vs
        the pure-Python reference on the same (T, R) request tensor
        (expected bit-identical — both fold admission sums left to
        right).
      - `cpr_ratio`: carbon routing must beat latency routing on
        carbon-per-request at an equal (zero) SLO-violation rate
        (`viol_rate_delta`); `over_capacity_epochs` pins the router's
        capacity invariant.
      - `sweep_parity_max_abs_diff`: `sweep_population(..., traffic=)`
        through the fleet backend (NumPy demand modulation) vs the jax
        backend (routing + autoscaling folded into the fleet scan),
        including the traffic_* row metrics.
    """
    from repro.carbon.intensity import TraceProvider
    from repro.cluster.placement import PlacementConfig, PlacementEngine
    from repro.cluster.slices import paper_family
    from repro.core.policy import CarbonContainerPolicy
    from repro.core.simulator import SimConfig, sweep_population
    from repro.traffic import (RoutingConfig, TrafficConfig, UserPopulation,
                               request_matrix, route, route_scalar,
                               simulate_traffic)
    from repro.traffic.autoscale import ReplicaConfig
    from repro.workload.azure_like import sample_population

    T = 288 * days
    regions = ("PL", "NL", "CAISO")
    provs = [TraceProvider.for_region(r, hours=24 * days, seed=1)
             for r in regions]
    epochs_s = np.arange(T) * 300.0
    intensity = np.stack([p.intensity_series(epochs_s) for p in provs],
                         axis=1)
    pop = UserPopulation(n_users=n_users, n_regions=3,
                         tz_offset_h=(0.0, 8.0, 16.0), seed=3)
    reps = ReplicaConfig(throughput_rps=100.0, max_replicas=8, max_step=4)
    slo_ms = 200.0                  # all pairs at 140 ms: zero violations

    t0 = time.perf_counter()
    arr = request_matrix(pop, T, 300.0)
    gen_s = time.perf_counter() - t0
    cap = reps.max_capacity(300.0)
    lat = TrafficConfig(population=pop).latency_matrix()
    rcfg = RoutingConfig(slo_ms=slo_ms, policy="carbon")

    rt_vec, vec_s, rt_scl, scl_s = _best_of_interleaved(
        lambda: route(arr.requests, cap, intensity, lat, rcfg),
        lambda: route_scalar(arr.requests, cap, intensity, lat, rcfg),
        rounds=3)
    parity = max(float(np.max(np.abs(getattr(rt_vec, f)
                                     - getattr(rt_scl, f))))
                 for f in ("flows", "routed", "dropped", "violations"))

    # carbon vs latency routing, end to end through the autoscaler
    res_pol = {}
    for pol in ("carbon", "latency"):
        cfg_t = TrafficConfig(population=pop, replicas=reps,
                              routing=RoutingConfig(slo_ms=slo_ms,
                                                    policy=pol))
        res_pol[pol] = simulate_traffic(arr.requests, intensity, cfg_t)
    rc, rl = res_pol["carbon"], res_pol["latency"]
    over_cap = int(np.sum(rc.routed > cap * (1.0 + 1e-9)))

    # end-to-end sweep: fleet (NumPy modulation) vs jax (in-scan fold)
    fam = paper_family()
    traces = [t.util for t in sample_population(n_traces, days=days,
                                                seed=5)]
    eng = PlacementEngine(fam, provs, region_names=regions,
                          config=PlacementConfig(capacity=n_traces,
                                                 min_dwell=6))
    pols = {"carbon_containers":
            lambda: CarbonContainerPolicy(variant="energy")}
    cfg = SimConfig(target_rate=0.0)
    tc = TrafficConfig(population=pop, replicas=reps,
                       routing=RoutingConfig(slo_ms=slo_ms))
    sweep_kw = dict(placement=eng, traffic=tc)
    rows_f = sweep_population(pols, fam, traces, None, [30.0, 60.0], cfg,
                              backend="fleet", **sweep_kw)
    rows_j = sweep_population(pols, fam, traces, None, [30.0, 60.0], cfg,
                              backend="jax", **sweep_kw)
    keys = ("carbon_rate_mean", "throttle_mean", "migrations_mean",
            "traffic_served", "traffic_carbon_per_request_g",
            "traffic_slo_violations")
    sweep_parity = max(abs(a[k] - b[k]) / max(abs(a[k]), 1.0)
                       for a, b in zip(rows_f, rows_j) for k in keys)

    rows = [{"routing": pol, "offered": r.offered_total,
             "served": r.served_total, "dropped": r.dropped_total,
             "slo_violations": r.violation_total,
             "emissions_g": r.emissions_total_g,
             "carbon_per_request_g": r.carbon_per_request_g,
             "replica_epochs": float(r.replicas.sum())}
            for pol, r in res_pol.items()]
    derived = {
        "n_users": pop.n_users,
        "n_epochs": T,
        "gen_s": gen_s,
        "speedup_x": scl_s / vec_s,
        "parity_max_abs_diff": parity,
        "cpr_carbon_g": rc.carbon_per_request_g,
        "cpr_latency_g": rl.carbon_per_request_g,
        "cpr_ratio": rc.carbon_per_request_g / rl.carbon_per_request_g,
        "viol_rate_delta": abs(rc.violation_rate - rl.violation_rate),
        "over_capacity_epochs": over_cap,
        "sweep_parity_max_abs_diff": sweep_parity,
    }
    return rows, derived


# ---------------------------------------------------------------------------
# Per-container elasticity: greedy speedup, backend parity, cap
# invariant, oracle-vs-forecast-vs-persistence ablation
# ---------------------------------------------------------------------------

def elasticity_sweep(n_containers: int = 2000, days: int = 10):
    """The elasticity layer's benchmark-gate entry.

    Hourly epochs over multi-day synthetic region traces — the regime
    where the diurnal + AR(1) structure is actually learnable (at
    5-minute epochs the hourly carbon trace is a step function and
    persistence is nearly unbeatable). Four claims in one scenario:

      - `speedup_x` / `parity_max_abs_diff` / `levels_equal`: the
        vectorized (N, K) greedy vs the pure-Python reference on a
        shared column subset (level counts bit-equal).
      - `jax_parity_max_abs_diff` / `jax_levels_equal`: the jitted
        scan vs NumPy on the full fleet, indexed carbon layout.
      - `cap_violations`: the fleet-wide estimated-grams budget is
        never exceeded beyond the mandatory floor, any epoch, any mode.
      - the ablation: carbon per unit of served work for
        oracle/forecast/persistence with *budget shaping* — the same
        total gram budget, reallocated across epochs by each mode's
        now-vs-next-24h carbon forecast. Persistence believes carbon
        stays flat, so its shaped budget is uniform: the baseline is a
        degenerate case, not a separate code path.
        `forecast_savings_frac` = 1 - forecast/persistence must stay
        positive (the headline: knowing the diurnal *structure*
        recovers most of the oracle's advantage), `work_ratio` pins
        the near-equal-work footing.
      - `sweep_parity_max_abs_diff` / `sweep_levels_equal`: the full
        `sweep_population(..., elasticity=)` contract, fleet vs jax
        backends with placement + elasticity composed.
    """
    from repro.carbon.traces import synth_trace
    from repro.core.elasticity import ElasticityConfig, simulate_elastic
    from repro.core.elasticity_jax import simulate_elastic_jax

    T = 24 * days
    regions = ("PL", "NL", "CAISO")
    region_mat = np.stack([synth_trace(r, hours=T, seed=11)
                           for r in regions], axis=1)
    n = n_containers
    rng = np.random.default_rng(7)
    phase = rng.uniform(0.0, 1.0, (1, n))
    base = 2.0 + np.sin(2.0 * np.pi * (np.arange(T)[:, None] / 24.0 + phase))
    # AR(1) residual on top of the diurnal base: the exact structure
    # the "forecast" mode's diurnal_ar1 estimator models
    eps = rng.normal(0.0, 0.3, (T, n))
    noise = np.zeros((T, n))
    for t in range(1, T):
        noise[t] = 0.9 * noise[t - 1] + eps[t]
    demand = np.abs(base + noise)
    codes = np.tile(np.arange(n, dtype=np.int32) % 3, (T, 1))
    carbon = region_mat[np.arange(T)[:, None], codes]

    mk = lambda mode, budget, shape=False: ElasticityConfig(
        k_levels=4, unit_capacity=1.0, base_w=50.0, peak_w=200.0,
        min_level=1, max_step=4, budget_g_per_epoch=budget, forecast=mode,
        shape_budget=shape)

    # budget: 60% of the uncapped oracle's mean estimated grams/epoch,
    # so the greedy genuinely chooses between containers every epoch
    free = simulate_elastic(demand, carbon, mk("oracle", None), 3600.0)
    budget = 0.6 * free.est_emissions_g / T

    # vectorized vs pure-Python reference on a shared subset (the
    # scalar loop walks N*K dict entries per epoch — pure overhead)
    n_par = min(n, 300)
    dsub, csub = demand[:, :n_par], carbon[:, :n_par]
    cfg_par = mk("forecast", budget * n_par / n)
    res_v, vec_s, res_s, scl_s = _best_of_interleaved(
        lambda: simulate_elastic(dsub, csub, cfg_par, 3600.0,
                                 backend="numpy"),
        lambda: simulate_elastic(dsub, csub, cfg_par, 3600.0,
                                 backend="scalar"),
        rounds=3)
    parity = float(np.max(np.abs(res_v.served_w - res_s.served_w)))
    levels_equal = bool(np.array_equal(res_v.levels, res_s.levels))

    # ablation at full width + jax parity on the indexed layout: same
    # total gram budget per mode, shaped by each mode's own forecaster
    cpw, work, viol = {}, {}, 0
    jax_parity = 0.0
    jax_levels_equal = True
    for mode in ("oracle", "forecast", "persistence"):
        cfg_m = mk(mode, budget, shape=True)
        res = simulate_elastic(demand, carbon, cfg_m, 3600.0)
        s = res.summary()
        cpw[mode] = s["elastic_emissions_g"] / max(s["elastic_served_work"],
                                                   1e-12)
        work[mode] = s["elastic_served_work"]
        viol += s["elastic_cap_violations"]
        rj = simulate_elastic_jax(demand, (region_mat, codes), cfg_m,
                                  3600.0, record=True)
        jax_levels_equal &= bool(np.array_equal(res.levels, rj.levels))
        scale = max(float(np.max(np.abs(res.served_w))), 1.0)
        jax_parity = max(jax_parity,
                         float(np.max(np.abs(res.served_w - rj.served_w)))
                         / scale)
        viol += rj.cap_violations

    # end-to-end sweep contract: fleet vs jax with placement+elasticity
    from repro.carbon.intensity import TraceProvider
    from repro.cluster.placement import PlacementConfig, PlacementEngine
    from repro.cluster.slices import paper_family
    from repro.core.policy import CarbonContainerPolicy
    from repro.core.simulator import SimConfig, sweep_population
    from repro.workload.azure_like import sample_population
    fam = paper_family()
    traces = [t.util for t in sample_population(16, days=1, seed=5)]
    provs = [TraceProvider.for_region(r, hours=24, seed=1)
             for r in regions]
    ec = ElasticityConfig(k_levels=4, unit_capacity=0.3,
                          budget_g_per_epoch=100.0, forecast="forecast",
                          shape_budget=True)
    pols = {"carbon_containers":
            lambda: CarbonContainerPolicy(variant="energy")}
    cfg_s = SimConfig(target_rate=0.0)
    mk_eng = lambda: PlacementEngine(
        fam, provs, region_names=regions,
        config=PlacementConfig(capacity=16, min_dwell=6))
    rows_f = sweep_population(pols, fam, traces, None, [30.0, 60.0],
                              cfg_s, backend="fleet", placement=mk_eng(),
                              elasticity=ec)
    rows_j = sweep_population(pols, fam, traces, None, [30.0, 60.0],
                              cfg_s, backend="jax", placement=mk_eng(),
                              elasticity=ec)
    keys = ("carbon_rate_mean", "throttle_mean", "migrations_mean",
            "elastic_served_work", "elastic_emissions_g",
            "elastic_served_frac")
    sweep_parity = max(abs(a[k] - b[k]) / max(abs(a[k]), 1.0)
                       for a, b in zip(rows_f, rows_j) for k in keys)
    sweep_levels_equal = all(
        a["elastic_level_epochs"] == b["elastic_level_epochs"]
        for a, b in zip(rows_f, rows_j))

    rows = [{"mode": m, "carbon_per_work_g": cpw[m], "served_work": work[m]}
            for m in ("oracle", "forecast", "persistence")]
    derived = {
        "n_containers": n,
        "n_epochs": T,
        "budget_g_per_epoch": budget,
        "speedup_x": scl_s / vec_s,
        "parity_max_abs_diff": parity,
        "levels_equal": int(levels_equal),
        "jax_parity_max_abs_diff": jax_parity,
        "jax_levels_equal": int(jax_levels_equal),
        "cap_violations": int(viol),
        "cpw_oracle_g": cpw["oracle"],
        "cpw_forecast_g": cpw["forecast"],
        "cpw_persistence_g": cpw["persistence"],
        "forecast_savings_frac": 1.0 - cpw["forecast"] / cpw["persistence"],
        "oracle_savings_frac": 1.0 - cpw["oracle"] / cpw["persistence"],
        "work_ratio": min(work.values()) / max(work.values()),
        "sweep_parity_max_abs_diff": sweep_parity,
        "sweep_levels_equal": int(sweep_levels_equal),
    }
    return rows, derived


def energy_sweep(n_containers: int = 400, days: int = 4):
    """The virtual energy supply layer's benchmark-gate entry.

    One placed fleet sweep run three ways through the declarative
    `SweepSpec` surface: energy off vs energy on (interleaved best-of
    timing, so `overhead_frac` — the cost of the supply ledger, the
    virtual-cap gather, and the delivered-mix billing — is measured
    under identical host load), then the energy-on sweep again on the
    jax backend. Gated claims:

      - `overhead_frac` <= 0.10: the energy layer costs at most 10% of
        the plain fleet sweep.
      - `energy_conservation_max_err_w` / `energy_cap_violations` /
        `energy_soc_violations`: the supply ledger balances to float
        precision and the software-defined caps and battery bounds hold
        by construction, under a mid-sweep outage and a correlated
        intensity spike.
      - `sweep_parity_max_rel_diff` <= 1e-6: fleet vs jax backends
        agree on every shared numeric row metric with the energy layer
        folded in (read off `SweepResult.parity`, the uniform accessor
        the gate exists to exercise).
    """
    from repro.carbon.intensity import TraceProvider
    from repro.cluster.placement import PlacementConfig
    from repro.cluster.slices import paper_family
    from repro.core.policy import CarbonContainerPolicy
    from repro.core.simulator import SimConfig
    from repro.core.spec import SweepSpec
    from repro.energy import EnergyConfig, GridEventConfig
    from repro.workload.azure_like import sample_population_matrix

    fam = paper_family()
    regions = ("PL", "NL", "CAISO")
    provs = [TraceProvider.for_region(r, hours=24 * days, seed=1)
             for r in regions]
    demand = sample_population_matrix(n_containers, days=days, seed=2)
    T = demand.shape[0]
    en = EnergyConfig(events=GridEventConfig(
        outages=((1, T // 4, T // 24),),
        shocks=((-1, T // 2, T // 12, 2.0),)))
    pols = {"carbon_containers":
            lambda: CarbonContainerPolicy(variant="energy")}

    def _spec(backend, energy):
        return SweepSpec(
            policies=pols, family=fam, traces=demand,
            targets=[30.0, 60.0], sim=SimConfig(target_rate=0.0),
            backend=backend,
            placement=PlacementConfig(
                capacity=int(np.ceil(0.6 * n_containers)), min_dwell=6),
            regions=provs, region_names=regions, energy=energy)

    res_off, off_s, res_on, on_s = _best_of_interleaved(
        lambda: _spec("fleet", None).run(),
        lambda: _spec("fleet", en).run(), rounds=3, fast_reps=1)
    res_jax = _spec("jax", en).run()

    r0 = res_on[0]
    derived = {
        "n_containers": n_containers,
        "n_epochs": T,
        "fleet_s": off_s,
        "fleet_energy_s": on_s,
        "overhead_frac": on_s / off_s - 1.0,
        "energy_conservation_max_err_w": r0["energy_conservation_max_err_w"],
        "energy_cap_violations": int(r0["energy_cap_violations"]),
        "energy_soc_violations": int(r0["energy_soc_violations"]),
        "energy_outage_epochs": int(r0["energy_outage_epochs"]),
        "energy_solar_frac": r0["energy_solar_frac"],
        "energy_unmet_frac": r0["energy_unmet_frac"],
        "energy_cap_frac_min": r0["energy_cap_frac_min"],
        "sweep_parity_max_rel_diff": res_on.parity(res_jax),
        "capped_vs_plain_carbon_delta":
            r0["carbon_rate_mean"] - res_off[0]["carbon_rate_mean"],
    }
    return list(res_on), derived


def robustness_sweep(n_traces: int = 96, n_targets: int = 3, days: int = 1):
    """The signal-plane fault-injection benchmark-gate entry.

    One placed fleet sweep run under a 20%-dropout carbon feed (plus a
    trough-anchored blackout, seeded migration failures, and power-
    telemetry gaps), once per degradation mode, on both array backends.
    Gated claims:

      - `ladder_excess_overshoot`: with the graceful-degradation ladder
        (hold -> causal diurnal prior -> conservative floor) the worst
        per-row overshoot of the carbon target stays within a pinned
        bound of the oracle (fault-free) sweep.
      - `hold_excess_overshoot`: naive hold-forever demonstrably blows
        through the target on the same fault plan (the floor pins the
        failure mode the ladder exists to prevent — the blackout lands
        at the intensity trough, so held samples flatter the budget
        precisely while the true grid gets dirtier).
      - `conservative_budget_violations` == 0: under mode
        "conservative" (noise-free faults, traces bounded by c_max) the
        recorded power series never exceeds the true-billed gram
        target, counted per (epoch, container) by
        `repro.robustness.budget_violations`.
      - `sweep_parity_max_rel_diff` <= 1e-6: fleet vs jax agree on
        every shared row metric with the full fault plan enabled
        (degraded feed, failed migrations, unmetered emissions).
    """
    from repro.cluster.placement import PlacementConfig
    from repro.cluster.slices import paper_family
    from repro.core.fleet import FleetSimulator
    from repro.core.policy import CarbonContainerPolicy
    from repro.core.simulator import SimConfig
    from repro.core.spec import SweepSpec
    from repro.robustness import (CarbonFeedFaults, DegradeConfig,
                                  FaultPlan, MigrationFaults,
                                  PowerTelemetryFaults, budget_violations,
                                  observe_intensity)
    from repro.workload.azure_like import sample_population_matrix

    fam = paper_family()
    T = 288 * days
    t = np.arange(T)
    # diurnal grids with a deep trough: the blackout opens at the trough
    # so hold-forever budgets on the day's cleanest reading while the
    # true intensity climbs toward the peak
    phases = (0.0, 1.9, 3.6)
    regions = np.stack([260.0 + 210.0 * np.sin(
        2 * np.pi * t / 288.0 + 2.6 + p) for p in phases], axis=1)
    # mid-day trough: fresh samples exist before the feed goes dark, so
    # hold-forever genuinely holds a flattering reading
    trough = int(np.argmin(regions[:, 0]))
    demand = sample_population_matrix(n_traces, days=days, seed=2)
    # low targets so the gram budget genuinely binds (the workload
    # draws ~7-10 g/hr unconstrained) - overshoot is then a real signal
    targets = list(np.linspace(3.0, 9.0, n_targets))
    policies = {"cc": lambda: CarbonContainerPolicy()}
    cfg = SimConfig(target_rate=0.0)

    def _plan(mode):
        return FaultPlan(
            carbon=CarbonFeedFaults(dropout_prob=0.2,
                                    blackouts=((-1, trough, T // 3),)),
            power=PowerTelemetryFaults(gap_prob=0.05),
            migration=MigrationFaults(fail_prob=0.3, backoff_cap=8),
            degrade=DegradeConfig(mode=mode, ttl_epochs=3,
                                  c_max=float(regions.max())),
            seed=17)

    def _spec(backend, faults):
        return SweepSpec(
            policies=policies, family=fam, traces=demand, targets=targets,
            sim=cfg, backend=backend,
            placement=PlacementConfig(
                capacity=int(np.ceil(0.6 * n_traces)), min_dwell=6),
            regions=regions, faults=faults)

    results = {}
    timings = {}
    for mode in ("oracle", "ladder", "hold", "conservative"):
        faults = None if mode == "oracle" else _plan(mode)
        t0 = time.perf_counter()
        results[mode] = _spec("fleet", faults).run()
        timings[mode] = time.perf_counter() - t0
    t0 = time.perf_counter()
    res_jax = _spec("jax", _plan("ladder")).run()
    jax_s = time.perf_counter() - t0

    # per-epoch overshoot certificate: small recorded runs billed at the
    # TRUE intensity (the sweep path never records (T, N) power at
    # scale). The budget binds per epoch, so the overshoot that matters
    # is max over (epoch, container) of rate/target - 1: hold-forever
    # keeps budgeting on the trough reading while the true grid climbs,
    # the ladder degrades to the prior/floor instead.
    n_small = min(16, n_traces)
    true_c = regions[:, 0]
    sim = FleetSimulator(fam)
    tgt_small = np.repeat(targets, n_small)
    dem_small = np.tile(demand[:, :n_small], (1, n_targets))

    # the first epochs pay the scale-down from the baseline slice -- an
    # actuation transient every mode (incl. the oracle) shares, so the
    # certificate starts once the actuator has settled
    settle = 4

    def _recorded_overshoot(mode):
        if mode == "oracle":
            obs = None
        else:
            sig = observe_intensity(true_c[:, None], _plan(mode), 300.0)
            obs = sig.observed[:, 0]
        rec = sim.run(CarbonContainerPolicy(), dem_small, true_c,
                      tgt_small, record=True, carbon_obs=obs)
        rate = rec.power_series[settle:] * true_c[settle:, None] / 1000.0
        over = float(np.max(rate / tgt_small[None, :] - 1.0))
        viol = budget_violations(rec.power_series[settle:],
                                 true_c[settle:], tgt_small, 300.0)
        return max(0.0, over), viol

    over = {}
    viols = {}
    for mode in ("oracle", "ladder", "hold", "conservative"):
        over[mode], viols[mode] = _recorded_overshoot(mode)
    viol = viols["conservative"]
    r0 = results["ladder"][0]
    rows = [{"mode": m, "overshoot": over[m], "wall_s": timings[m],
             **{k: r[k] for k in ("policy", "target", "carbon_rate_mean")}}
            for m in results for r in results[m]]
    derived = {
        "n_containers": n_traces * n_targets,
        "n_epochs": T,
        "dropout_prob": 0.2,
        "steady_s": timings["ladder"],
        "jax_s": jax_s,
        "oracle_overshoot": over["oracle"],
        "ladder_overshoot": over["ladder"],
        "hold_overshoot": over["hold"],
        "conservative_overshoot": over["conservative"],
        "ladder_excess_overshoot": over["ladder"] - over["oracle"],
        "hold_excess_overshoot": over["hold"] - over["oracle"],
        "conservative_budget_violations": viol,
        "fault_stale_frac": r0["fault_stale_frac"],
        "fault_failed_migrations_mean": r0["fault_failed_migrations_mean"],
        "fault_unmetered_g_mean": r0["fault_unmetered_g_mean"],
        "sweep_parity_max_rel_diff": results["ladder"].parity(res_jax),
    }
    return rows, derived
