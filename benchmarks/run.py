"""Benchmark harness: one entry per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (plus writes full row data to
benchmarks/out/ as CSV for plotting). Run:

    PYTHONPATH=src python -m benchmarks.run \
        [--only fleet_sweep,fleet_sweep_jax] [--fast true] [--json out.json]

``--only`` takes a comma-separated entry list; ``--json`` additionally
writes per-entry ``{us_per_call, wall_s, warmup_s, steady_s,
peak_rss_mb, derived}`` to the given path (the CI benchmark-regression
gate feeds this to benchmarks.check_regression). ``wall_s`` is the
entry's total wall-clock; entries that jit-compile (the ``*_jax`` ones)
report ``warmup_s`` (first call, includes compile) and ``steady_s``
(best steady-state call) separately, and their ``speedup_x`` metrics
are computed from steady state only — so jit compile time never
pollutes regression floors. ``peak_rss_mb`` is the process peak-RSS
high-water mark at entry end; memory gates (the jax-sweep target's
ceiling) run their entry with ``--only`` in a fresh process so the mark
is theirs alone.
"""
from __future__ import annotations

import csv
import json
import os
import resource
import sys
import time


def _peak_rss_mb() -> float:
    """Process peak RSS in MB (ru_maxrss is KB on Linux, bytes on
    macOS). A high-water mark: per-entry values are cumulative across
    the run, so memory gates should run their entry with ``--only`` in
    a fresh process (the Makefile's jax-sweep target does)."""
    rss = resource.getrusage(resource.RUSAGE_SELF).ru_maxrss
    if sys.platform == "darwin":                       # pragma: no cover
        return rss / 1e6
    return rss / 1024.0

def _ensure_xla_flags():
    """CPU-tuned XLA flags for the jax-backend entries (the shared
    helper appends them only when absent, so explicit user settings
    win); must run before the first jax backend initialization."""
    from repro.core.fleet_jax import ensure_cpu_xla_flags
    ensure_cpu_xla_flags()


def _rows_to_csv(name: str, rows: list):
    if not rows:
        return
    out_dir = os.path.join(os.path.dirname(__file__), "out")
    os.makedirs(out_dir, exist_ok=True)
    keys = list(rows[0].keys())
    with open(os.path.join(out_dir, f"{name}.csv"), "w", newline="") as f:
        w = csv.DictWriter(f, keys, extrasaction="ignore")
        w.writeheader()
        for r in rows:
            w.writerow(r)


def main() -> None:
    _ensure_xla_flags()
    args = {}
    argv = sys.argv[1:]
    for i in range(0, len(argv) - 1, 2):
        args[argv[i].lstrip("-")] = argv[i + 1]
    fast = args.get("fast", "false") == "true"

    from benchmarks import figs
    n_small = 10 if fast else 40
    entries = [
        ("fig1_regions", figs.fig1_regions, {}),
        ("fig2_traces", figs.fig2_traces, {}),
        ("fig3_workload", figs.fig3_workload, {"n_vms": 60 if fast else 300}),
        ("fig6_power", figs.fig6_power, {}),
        ("fig7_migration", figs.fig7_migration, {}),
        ("fig10_prototype", figs.fig10_prototype, {}),
        ("fig11_12_highvar", figs.fig11_12_highvar, {"n_jobs": n_small}),
        ("fig13_14_medvar", figs.fig13_14_medvar, {"n_jobs": n_small}),
        ("fig15_16_variants", figs.fig15_16_variants, {"n_jobs": max(n_small // 2, 6)}),
        ("fig17_server_time", figs.fig17_server_time, {"n_jobs": max(n_small // 2, 6)}),
        # vectorized fleet simulator vs looped simulate() (64x4x3 sweep);
        # fast mode shortens the traces, not the sweep shape
        ("fleet_sweep", figs.fleet_sweep, {"days": 2 if fast else 3}),
        # multi-region placement planner, scalar reference vs (N, R) batch
        ("placement_sweep", figs.placement_sweep,
         {"days": 2 if fast else 3}),
        # jit/scan JAX backend vs the NumPy fleet/placement kernels at
        # N >= 5000 containers (steady state vs compile split)
        ("fleet_sweep_jax", figs.fleet_sweep_jax,
         {"days": 2 if fast else 3}),
        ("placement_sweep_jax", figs.placement_sweep_jax,
         {"days": 2 if fast else 3}),
        # pallas admission kernel (interpret on CPU) parity + floor
        ("placement_sweep_pallas", figs.placement_sweep_pallas,
         {"n_containers": 256 if fast else 384, "days": 2}),
        # the N=1M placed sweep (fast mode: same path, 6k containers)
        ("jax_sweep_scale", figs.jax_sweep_scale,
         {"n_traces": 1500, "n_targets": 4} if fast
         else {"n_traces": 100_000, "n_targets": 10}),
        # carbon-aware traffic: 1M-user routing + autoscaling, carbon
        # vs latency routing, fleet-vs-jax sweep-with-traffic parity
        ("traffic_sweep", figs.traffic_sweep, {"n_users": 1_000_000}),
        # per-container elasticity: (N, K) greedy speedup + 3-backend
        # parity, shaped-budget oracle/forecast/persistence ablation
        ("elasticity_sweep", figs.elasticity_sweep,
         {"n_containers": 300, "days": 4} if fast
         else {"n_containers": 2000, "days": 10}),
        # virtual energy supply: overhead vs plain fleet sweep, supply
        # ledger invariants, fleet-vs-jax parity through SweepSpec
        ("energy_sweep", figs.energy_sweep,
         {"n_containers": 200, "days": 2} if fast
         else {"n_containers": 400, "days": 4}),
        # signal-plane fault injection: degradation-ladder overshoot vs
        # oracle/hold-forever, conservative zero-violation certificate,
        # fleet-vs-jax parity with the full fault plan enabled
        ("robustness_sweep", figs.robustness_sweep,
         {"n_traces": 48, "n_targets": 2} if fast
         else {"n_traces": 96, "n_targets": 3}),
    ]
    only = args.get("only")
    only_set = set(only.split(",")) if only else None
    if only_set:
        known = {name for name, _, _ in entries}
        unknown = only_set - known
        if unknown:
            raise SystemExit(f"unknown benchmark entries {sorted(unknown)}; "
                             f"known: {sorted(known)}")

    report = {}
    print("name,us_per_call,derived")
    for name, fn, kw in entries:
        if only_set and name not in only_set:
            continue
        t0 = time.perf_counter()
        rows, derived = fn(**kw)
        us = (time.perf_counter() - t0) * 1e6
        _rows_to_csv(name, rows)
        report[name] = {
            "us_per_call": us,
            "wall_s": us / 1e6,
            "warmup_s": derived.get("warmup_s"),
            "steady_s": derived.get("steady_s"),
            "peak_rss_mb": _peak_rss_mb(),
            "derived": derived,
        }
        compact = json.dumps({k: (round(v, 4) if isinstance(v, float) else v)
                              for k, v in derived.items()}, default=str)
        print(f"{name},{us:.0f},{compact}")
    if "json" in args:
        out_path = args["json"]
        d = os.path.dirname(out_path)
        if d:
            os.makedirs(d, exist_ok=True)
        with open(out_path, "w") as f:
            json.dump(report, f, indent=2, default=str)


if __name__ == "__main__":
    main()
