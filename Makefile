PY := python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: test-fast test bench-fleet bench

# Fast lane: carbon-core + fleet tests (seconds, no JAX model compiles)
test-fast:
	$(PY) -m pytest -q -m "not slow"

# Full tier-1 suite (multi-minute: JAX kernels, archs, training)
test:
	$(PY) -m pytest -x -q

# Fleet-vs-scalar sweep speedup entry (the perf trajectory record)
bench-fleet:
	$(PY) -m benchmarks.run --only fleet_sweep --fast true

bench:
	$(PY) -m benchmarks.run
