PY := python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: test-fast test bench-fleet bench bench-gate placement jax-sweep traffic elasticity scenarios

# Fast lane: carbon-core + fleet + placement tests (seconds, no JAX
# model compiles)
test-fast:
	$(PY) -m pytest -q -m "not slow"

# Full tier-1 suite (multi-minute: JAX kernels, archs, training)
test:
	$(PY) -m pytest -x -q

# Fleet-vs-scalar sweep speedup entry (the perf trajectory record)
bench-fleet:
	$(PY) -m benchmarks.run --only fleet_sweep --fast true

# CI benchmark-regression gate, runnable locally: fleet + placement
# sweeps (scalar vs NumPy, NumPy vs JAX) in fast mode, JSON report,
# pinned speedup floors + parity ceilings. The jax floors use
# steady-state timings only (jit compile is reported separately as
# warmup_s, never gated).
bench-gate:
	$(PY) -m benchmarks.run \
		--only fleet_sweep,placement_sweep,fleet_sweep_jax,placement_sweep_jax,placement_sweep_pallas,traffic_sweep,elasticity_sweep,energy_sweep,robustness_sweep \
		--fast true --json benchmarks/out/ci.json
	$(PY) -m benchmarks.check_regression benchmarks/out/ci.json \
		--min fleet_sweep.speedup_x=10 \
		--max fleet_sweep.parity_max_abs_diff=1e-9 \
		--min placement_sweep.speedup_x=3 \
		--max placement_sweep.parity_max_abs_diff=1e-9 \
		--min placement_sweep.assign_equal=1 \
		--max placement_sweep.over_capacity_epochs=0 \
		--min fleet_sweep_jax.speedup_x=2.5 \
		--max fleet_sweep_jax.parity_max_abs_diff=1e-6 \
		--min placement_sweep_jax.speedup_x=1.2 \
		--max placement_sweep_jax.parity_max_abs_diff=1e-6 \
		--min placement_sweep_jax.assign_equal=1 \
		--max placement_sweep_jax.over_capacity_epochs=0 \
		--min placement_sweep_pallas.speedup_x=0.3 \
		--max placement_sweep_pallas.parity_max_abs_diff=1e-6 \
		--min placement_sweep_pallas.assign_equal=1 \
		--max placement_sweep_pallas.over_capacity_epochs=0 \
		--min traffic_sweep.n_users=1000000 \
		--min traffic_sweep.speedup_x=3 \
		--max traffic_sweep.parity_max_abs_diff=1e-9 \
		--max traffic_sweep.cpr_ratio=0.9 \
		--max traffic_sweep.viol_rate_delta=0 \
		--max traffic_sweep.over_capacity_epochs=0 \
		--max traffic_sweep.sweep_parity_max_abs_diff=1e-6 \
		--min elasticity_sweep.speedup_x=3 \
		--max elasticity_sweep.parity_max_abs_diff=1e-9 \
		--min elasticity_sweep.levels_equal=1 \
		--max elasticity_sweep.jax_parity_max_abs_diff=1e-6 \
		--min elasticity_sweep.jax_levels_equal=1 \
		--max elasticity_sweep.cap_violations=0 \
		--min elasticity_sweep.forecast_savings_frac=0.005 \
		--min elasticity_sweep.oracle_savings_frac=0.01 \
		--min elasticity_sweep.work_ratio=0.9 \
		--max elasticity_sweep.sweep_parity_max_abs_diff=1e-6 \
		--min elasticity_sweep.sweep_levels_equal=1 \
		--max energy_sweep.overhead_frac=0.10 \
		--max energy_sweep.energy_conservation_max_err_w=1e-6 \
		--max energy_sweep.energy_cap_violations=0 \
		--max energy_sweep.energy_soc_violations=0 \
		--max energy_sweep.sweep_parity_max_rel_diff=1e-6 \
		--max robustness_sweep.ladder_excess_overshoot=1.5 \
		--min robustness_sweep.hold_excess_overshoot=3.0 \
		--max robustness_sweep.conservative_overshoot=0 \
		--max robustness_sweep.conservative_budget_violations=0 \
		--min robustness_sweep.fault_stale_frac=0.2 \
		--max robustness_sweep.sweep_parity_max_rel_diff=1e-6

# Multi-region placement demo: heterogeneous fleet migrating between
# low- and high-variability grids vs the frozen no-migration baseline
placement:
	$(PY) examples/simulate_regions.py --placement --fleet 120

# Carbon-aware traffic demo: 1M-user diurnal request population routed
# by carbon intensity under an SLO bound, replica fleets autoscaled
# under a carbon cap, demand modulation through the placed fleet sweep
traffic:
	$(PY) examples/traffic_demo.py

# The N=1M placed fleet sweep (100k traces x 10 targets, 1 day at
# 5-minute epochs) through the memory-lean jax path, gated: throughput
# floor on container-epochs/s, peak-RSS ceiling (the compact
# indexed-carbon path must never materialize a (T, N) matrix — a
# single tiled f64 matrix is ~2.3 GB, so the 4 GB ceiling catches the
# first one; measured honest peak is ~2.3 GB), and zero capacity
# violations. A non-trivial signal-plane fault plan (carbon dropouts +
# blackout, power gaps, seeded migration failures) is enabled, so the
# floors certify the degraded path too. Fresh process per run so
# peak_rss_mb measures this entry.
jax-sweep:
	$(PY) -m benchmarks.run --only jax_sweep_scale \
		--json benchmarks/out/jax_sweep.json
	$(PY) -m benchmarks.check_regression benchmarks/out/jax_sweep.json \
		--min jax_sweep_scale.n_containers=1000000 \
		--min jax_sweep_scale.container_epochs_per_s=1000000 \
		--max jax_sweep_scale.peak_rss_mb=4096 \
		--max jax_sweep_scale.over_capacity_epochs=0 \
		--max jax_sweep_scale.elastic_cap_violations=0 \
		--max jax_sweep_scale.energy_conservation_max_err_w=1e-6 \
		--max jax_sweep_scale.energy_cap_violations=0 \
		--max jax_sweep_scale.energy_soc_violations=0 \
		--min jax_sweep_scale.fault_stale_frac=0.1 \
		--min jax_sweep_scale.fault_failed_migrations_mean=0.001 \
		--min jax_sweep_scale.fault_unmetered_g_mean=0.1

# Per-container elasticity demo: K-level CarbonScaler marginal
# allocation under a shaped fleet carbon budget, with the
# oracle/forecast/persistence forecaster ablation
elasticity:
	$(PY) examples/elasticity_demo.py

# Scenario stress matrix: every named scenario (fleet churn, grid
# outage, correlated intensity shock, migration failures, stragglers,
# demand burst) as a full-shape sweep on BOTH array backends, with the
# energy invariants (conservation, zero cap/SoC violations) and
# fleet<->jax parity checked per cell. Exits non-zero on any violation.
# The fast-lane pytest table (tests/test_scenarios.py) runs the same
# matrix at small shapes.
scenarios:
	$(PY) -m repro.energy.scenarios

bench:
	$(PY) -m benchmarks.run
