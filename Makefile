PY := python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: test-fast test bench-fleet bench bench-gate placement

# Fast lane: carbon-core + fleet + placement tests (seconds, no JAX
# model compiles)
test-fast:
	$(PY) -m pytest -q -m "not slow"

# Full tier-1 suite (multi-minute: JAX kernels, archs, training)
test:
	$(PY) -m pytest -x -q

# Fleet-vs-scalar sweep speedup entry (the perf trajectory record)
bench-fleet:
	$(PY) -m benchmarks.run --only fleet_sweep --fast true

# CI benchmark-regression gate, runnable locally: fleet + placement
# sweeps in fast mode, JSON report, pinned speedup floors
bench-gate:
	$(PY) -m benchmarks.run --only fleet_sweep,placement_sweep \
		--fast true --json benchmarks/out/ci.json
	$(PY) -m benchmarks.check_regression benchmarks/out/ci.json \
		--min fleet_sweep.speedup_x=10 \
		--max fleet_sweep.parity_max_abs_diff=1e-9 \
		--min placement_sweep.speedup_x=3 \
		--max placement_sweep.parity_max_abs_diff=1e-9 \
		--min placement_sweep.assign_equal=1 \
		--max placement_sweep.over_capacity_epochs=0

# Multi-region placement demo: heterogeneous fleet migrating between
# low- and high-variability grids vs the frozen no-migration baseline
placement:
	$(PY) examples/simulate_regions.py --placement --fleet 120

bench:
	$(PY) -m benchmarks.run
