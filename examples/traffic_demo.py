"""Carbon-aware traffic demo (``make traffic``).

A 1M-user population spread over three regions eight time-zone-hours
apart offers a diurnal, bursty request stream. Requests are routed
per epoch by carbon intensity under an SLO latency bound (vs a
latency-only baseline), per-region replica fleets autoscale to the
routed load, and the resulting serving load modulates container demand
through the placed fleet sweep:

    user demand (requests) --> SLO-constrained routing --> replica
    autoscaling --> per-region serving load --> container demand
    modulation --> placed fleet simulation

    PYTHONPATH=src python examples/traffic_demo.py [--users 1000000]
        [--days 1] [--budget <g/epoch>]
"""
import sys

import numpy as np

from repro.carbon.intensity import TraceProvider
from repro.cluster.placement import PlacementConfig, PlacementEngine
from repro.cluster.slices import paper_family
from repro.core.policy import CarbonContainerPolicy
from repro.core.simulator import SimConfig
from repro.core.spec import SweepSpec
from repro.traffic import (RoutingConfig, TrafficConfig, UserPopulation,
                           request_matrix, simulate_traffic)
from repro.traffic.autoscale import ReplicaConfig

INTERVAL_S = 300.0
REGIONS = ("PL", "NL", "CAISO")


def _arg(flag, default, cast):
    if flag in sys.argv:
        return cast(sys.argv[sys.argv.index(flag) + 1])
    return default


def main():
    n_users = _arg("--users", 1_000_000, int)
    days = _arg("--days", 1, int)
    budget = _arg("--budget", None, float)
    T = int(days * 86400 / INTERVAL_S)

    provs = [TraceProvider.for_region(r, hours=24 * days, seed=1)
             for r in REGIONS]
    intensity = np.stack(
        [p.intensity_series(np.arange(T) * INTERVAL_S) for p in provs],
        axis=1)
    pop = UserPopulation(n_users=n_users, n_regions=3,
                         tz_offset_h=(0.0, 8.0, 16.0), seed=3)
    reps = ReplicaConfig(max_replicas=8, max_step=4,
                         budget_g_per_epoch=budget)
    arr = request_matrix(pop, T, INTERVAL_S)
    print(f"population: {n_users:,} users, {arr.offered_total:,.0f} "
          f"requests over {days} day(s), regions {REGIONS}")

    print(f"\n{'routing':>10} {'served':>14} {'dropped':>12} "
          f"{'SLO viol':>10} {'g CO2/1k req':>13}")
    results = {}
    for pol in ("carbon", "latency"):
        cfg = TrafficConfig(population=pop, replicas=reps,
                            routing=RoutingConfig(slo_ms=200.0, policy=pol))
        res = simulate_traffic(arr.requests, intensity, cfg, INTERVAL_S)
        results[pol] = res
        print(f"{pol:>10} {res.served_total:>14,.0f} "
              f"{res.dropped_total:>12,.0f} {res.violation_total:>10,.0f} "
              f"{1000.0 * res.carbon_per_request_g:>13.3f}")
    rc, rl = results["carbon"], results["latency"]
    saved = 1.0 - rc.carbon_per_request_g / rl.carbon_per_request_g
    print(f"\ncarbon routing emits {100.0 * saved:.1f}% less per request "
          f"than latency routing at the same SLO-violation rate")

    # the same traffic driving the placed fleet sweep end to end
    from repro.workload.azure_like import sample_population
    fam = paper_family()
    traces = [t.util for t in sample_population(24, days=days, seed=5)]
    eng = PlacementEngine(fam, provs, region_names=REGIONS,
                          config=PlacementConfig(capacity=24, min_dwell=6))
    tc = TrafficConfig(population=pop, replicas=reps,
                       routing=RoutingConfig(slo_ms=200.0))
    rows = SweepSpec(
        policies={"carbon_containers":
                  lambda: CarbonContainerPolicy("energy")},
        family=fam, traces=traces, targets=[30.0, 60.0],
        sim=SimConfig(target_rate=0.0), backend="fleet", placement=eng,
        traffic=tc).run()
    print("\nplaced fleet sweep with traffic-modulated demand:")
    for r in rows:
        print(f"  target {r['target']:>5.1f}: carbon rate "
              f"{r['carbon_rate_mean']:.2f} g/h, throttle "
              f"{r['throttle_mean']:.2f}%, carbon/request "
              f"{1000.0 * r['traffic_carbon_per_request_g']:.3f} g/1k")


if __name__ == "__main__":
    main()
