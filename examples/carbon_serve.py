"""Carbon-aware serving: a request queue with minutes-scale load swings
(the paper's workload-intensity argument) served under a carbon cap.

The scheduler feeds queue-implied demand into the Carbon Container policy;
the policy answers with slice + duty decisions; real batched generation
runs on the engine at the allowed rate.

    PYTHONPATH=src python examples/carbon_serve.py
"""
import numpy as np

from repro.carbon.intensity import TraceProvider
from repro.cluster.slices import paper_family
from repro.configs import get_arch
from repro.core.container import ContainerState, PlantModel
from repro.core.policy import CarbonContainerPolicy
from repro.models import get_model
from repro.serve.engine import ServeEngine
from repro.serve.scheduler import CarbonAwareScheduler, poisson_arrivals


def main():
    spec = get_arch("smollm-135m")
    engine = ServeEngine(get_model(spec.smoke)).load()
    # calibrate capacity: measured decode throughput = duty-1.0 capacity
    prompts = np.zeros((4, 8), np.int32)
    engine.generate(prompts, 4)
    tok_s = engine.stats["decode_tokens"] / max(engine.stats["decode_s"], 1e-9)

    fam = paper_family()
    policy = CarbonContainerPolicy(variant="energy")
    state = ContainerState(slice_idx=fam.baseline_idx)
    carbon = TraceProvider.for_region("CAISO", hours=48, seed=3)
    sch = CarbonAwareScheduler(capacity_tok_s=tok_s)

    # bursty arrivals: lambda doubles mid-day
    target = 45.0
    interval = 300.0
    print(f"decode capacity {tok_s:.0f} tok/s; C_target {target} g/hr\n")
    print(f"  {'hour':>5s} {'c g/kWh':>8s} {'demand':>7s} {'slice':>6s} "
          f"{'duty':>5s} {'C g/hr':>7s} {'backlog':>7s}")
    rng = np.random.default_rng(0)
    emissions, hours_total = 0.0, 0.0
    for n in range(96):                       # 8 hours of 5-min intervals
        t = n * interval
        lam = 0.03 * (3.0 if 30 <= n < 60 else 1.0)
        for a in poisson_arrivals(lam, interval, seed=n):
            sch.offer(t + a, max_new=32)
        c = carbon.intensity(t)
        demand = min(sch.demand(interval), 4.0)
        state.observe_demand(demand)
        action = policy.decide(fam, state, demand, c, target, 0.05)
        if action.kind == "migrate":
            state.slice_idx = action.target_slice
            state.dwell = 0
        state.duty = action.duty if action.kind in ("stay", "migrate", "resume") else 0.0
        state.suspended = action.kind == "suspend"
        state.dwell += 1
        s = fam[state.slice_idx]
        res = sch.run_interval(state.duty if not state.suspended else 0.0,
                               s.multiple, interval)
        served_util = min(res["util"], s.multiple)
        power = 0.0 if state.suspended else s.power.power(
            min(served_util / s.multiple, 1.0))
        rate = PlantModel.rate(power, c)
        emissions += rate * interval / 3600.0
        hours_total += interval / 3600.0
        if n % 8 == 0:
            print(f"  {t/3600:5.1f} {c:8.0f} {demand:7.2f} {s.name:>6s} "
                  f"{state.duty:5.2f} {rate:7.1f} {res['backlog']:7d}")
    lat = sch.latency_stats()
    print(f"\navg C(t) = {emissions/hours_total:.1f} g/hr (target {target}); "
          f"served {lat['n']} requests, p95 latency {lat['p95_s']:.0f}s")


if __name__ == "__main__":
    main()
