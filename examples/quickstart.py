"""Quickstart: train a small model, checkpoint it, and serve from it.

    PYTHONPATH=src python examples/quickstart.py
"""
import tempfile

import numpy as np

from repro.config import OptimizerConfig, TrainConfig
from repro.configs import get_arch
from repro.data.pipeline import markov_stream
from repro.models import get_model
from repro.serve.engine import ServeEngine, throughput_tokens_per_s
from repro.train import checkpoint as CKPT
from repro.train import loop as TL


def main():
    # 1. pick an assigned architecture (reduced config for CPU)
    spec = get_arch("smollm-135m")
    model = get_model(spec.smoke)
    print(f"arch={spec.arch_id} (smoke): {model.param_count():,} params")

    # 2. train on a learnable synthetic stream
    tcfg = TrainConfig(seq_len=64, global_batch=8, steps=60, log_every=20,
                       optimizer=OptimizerConfig(lr=3e-3, warmup_steps=10,
                                                 total_steps=60))
    data = markov_stream(spec.smoke.vocab_size, tcfg.seq_len,
                         tcfg.global_batch, temperature=0.2)
    out = TL.run(model, tcfg, data)
    print(f"loss: {out['history'][0]['loss']:.3f} -> "
          f"{out['history'][-1]['loss']:.3f}")

    # 3. checkpoint + restore
    with tempfile.TemporaryDirectory() as d:
        info = CKPT.save(d, out["state"], step=tcfg.steps)
        print(f"checkpoint: {info['bytes']/1e6:.1f} MB in {info['total_s']*1e3:.0f} ms")

    # 4. serve a few generations from the trained params
    engine = ServeEngine(model)
    engine.params = out["state"]["params"]
    prompts = np.random.default_rng(0).integers(
        0, spec.smoke.vocab_size, (4, 16)).astype(np.int32)
    gen = engine.generate(prompts, 12)
    tp = throughput_tokens_per_s(gen["stats"])
    print(f"generated {gen['tokens'].shape}; decode {tp['decode_tok_s']:.0f} tok/s")
    print("sample:", gen["tokens"][0].tolist())


if __name__ == "__main__":
    main()
