"""Large-scale Carbon Containers simulation across regions (paper Figs 11-16
in miniature): per-region policy tables, a heterogeneous fleet — mixed
regions (stacked carbon traces), mixed targets, mixed demand scales — run
through the vectorized FleetSimulator, a multi-region *placement* demo
where the fleet migrates between low- and high-variability grids, and a
device-resident JAX sweep over a 10k-container placed fleet
(``--jax-sweep``, or ``make jax-sweep``).

    PYTHONPATH=src python examples/simulate_regions.py \
        [--jobs 20] [--backend fleet|scalar] [--fleet 120] [--placement] \
        [--jax-sweep]
"""
import sys
import time

import numpy as np

from repro.carbon.intensity import TraceProvider
from repro.cluster.placement import PlacementConfig, PlacementEngine
from repro.cluster.slices import paper_family
from repro.core.fleet import FleetSimulator
from repro.core.policy import (CarbonAgnosticPolicy, CarbonContainerPolicy,
                               SuspendResumePolicy, VScaleOnlyPolicy)
from repro.core.simulator import SimConfig, simulate
from repro.workload.azure_like import sample_population

DAYS = 5
INTERVAL_S = 300.0


def _arg(flag, default, cast):
    if flag in sys.argv:
        return cast(sys.argv[sys.argv.index(flag) + 1])
    return default


def per_region_tables(n_jobs: int, backend: str):
    """The original per-region policy comparison, now fleet-backed."""
    fam = paper_family()
    traces = [t.util for t in sample_population(n_jobs, days=DAYS, seed=2)]
    policies = [
        ("carbon-agnostic", CarbonAgnosticPolicy),
        ("suspend/resume", SuspendResumePolicy),
        ("vscale-only", lambda: VScaleOnlyPolicy()),
        ("CC (energy)", lambda: CarbonContainerPolicy("energy")),
        ("CC (performance)", lambda: CarbonContainerPolicy("performance")),
    ]
    target = 45.0
    print(f"{n_jobs} jobs x {DAYS} days, C_target = {target} g/hr "
          f"[backend={backend}]\n")
    for region in ("PL", "NL", "CAISO"):
        carbon = TraceProvider.for_region(region, hours=24 * DAYS, seed=1)
        print(f"--- region {region} ---")
        print(f"  {'policy':18s} {'g/hr':>8s} {'throttle%':>10s} "
              f"{'migs':>6s} {'susp%':>6s}")
        for name, mk in policies:
            if backend == "fleet":
                sim = FleetSimulator(fam, interval_s=INTERVAL_S)
                res = sim.run(mk(), np.stack(traces, axis=1), carbon, target,
                              state_gb=1.0)
                rates = res.avg_carbon_rate
                thr = res.avg_throttle_pct
                migs = res.migrations
                susp = res.suspended_frac
            else:
                rates, thr, migs, susp = [], [], [], []
                for tr in traces:
                    r = simulate(mk(), fam, tr, carbon,
                                 SimConfig(target_rate=target, state_gb=1.0))
                    rates.append(r.avg_carbon_rate)
                    thr.append(r.avg_throttle_pct)
                    migs.append(r.migrations)
                    susp.append(r.suspended_frac)
            print(f"  {name:18s} {np.mean(rates):8.2f} {np.mean(thr):10.2f} "
                  f"{np.mean(migs):6.1f} {100 * np.mean(susp):6.1f}")
        print()


def heterogeneous_fleet(n: int):
    """One batched run over a mixed fleet: container i gets a region, a
    carbon target and a demand scale of its own — the multi-tenant
    (Ecovisor-style energy partitioning / CarbonScaler elasticity) shape,
    expressed as stacked carbon traces + per-container target vectors."""
    rng = np.random.default_rng(7)
    fam = paper_family()
    regions = ("PL", "NL", "CAISO")
    provs = {r: TraceProvider.for_region(r, hours=24 * DAYS, seed=1)
             for r in regions}
    traces = [t.util for t in sample_population(n, days=DAYS, seed=3)]
    T = len(traces[0])
    tvec = np.arange(T) * INTERVAL_S

    assign = rng.integers(0, len(regions), size=n)
    cmat = np.stack([provs[regions[a]].intensity_series(tvec)
                     for a in assign], axis=1)
    targets = rng.choice([20.0, 35.0, 50.0, 80.0], size=n)
    demand_scale = rng.choice([0.5, 1.0, 2.0, 4.0], size=n)
    state_gb = rng.choice([0.25, 1.0, 4.0], size=n)

    sim = FleetSimulator(fam, interval_s=INTERVAL_S)
    res = sim.run(CarbonContainerPolicy("energy"), np.stack(traces, axis=1),
                  cmat, targets, state_gb=state_gb,
                  demand_scale=demand_scale)

    print(f"--- heterogeneous fleet: {n} containers, mixed "
          f"{'/'.join(regions)}, mixed targets/scales ---")
    print(f"  {'group':22s} {'n':>4s} {'g/hr':>8s} {'target':>7s} "
          f"{'throttle%':>10s} {'susp%':>6s}")
    for ri, region in enumerate(regions):
        m = assign == ri
        if not m.any():
            continue
        print(f"  region {region:15s} {int(m.sum()):4d} "
              f"{res.avg_carbon_rate[m].mean():8.2f} "
              f"{targets[m].mean():7.1f} "
              f"{res.avg_throttle_pct[m].mean():10.2f} "
              f"{100 * res.suspended_frac[m].mean():6.1f}")
    for tgt in np.unique(targets):
        m = targets == tgt
        print(f"  target {tgt:5.0f} g/hr     {int(m.sum()):4d} "
              f"{res.avg_carbon_rate[m].mean():8.2f} "
              f"{tgt:7.1f} "
              f"{res.avg_throttle_pct[m].mean():10.2f} "
              f"{100 * res.suspended_frac[m].mean():6.1f}")
    under = (res.avg_carbon_rate <= targets * 1.02).mean()
    print(f"\n  fleet emissions: {res.emissions_g.sum() / 1000.0:.1f} kg CO2e"
          f" | {100 * under:.0f}% of containers within 2% of target\n")


def multi_region_placement(n: int):
    """A heterogeneous fleet free to migrate between a dirty low-variability
    grid (PL: coal, flat) and cleaner high-variability ones (NL, CAISO):
    the PlacementEngine moves containers toward the cleanest region whose
    projected saving beats the amortized stop-and-copy cost, under
    per-region capacity, and the same fleet frozen on its initial regions
    is the no-migration baseline."""
    rng = np.random.default_rng(11)
    fam = paper_family()
    regions = ("PL", "NL", "CAISO")
    provs = [TraceProvider.for_region(r, hours=24 * DAYS, seed=1)
             for r in regions]
    traces = [t.util for t in sample_population(n, days=DAYS, seed=5)]
    demand = np.stack(traces, axis=1)
    targets = rng.choice([30.0, 45.0, 80.0], size=n)
    state_gb = rng.choice([0.25, 1.0, 4.0], size=n)

    cap = int(np.ceil(0.6 * n))          # no region may hold the whole fleet
    eng = PlacementEngine(
        fam, provs, interval_s=INTERVAL_S, region_names=regions,
        config=PlacementConfig(capacity=cap, min_dwell=6, hysteresis=0.10))
    res = eng.run(CarbonContainerPolicy("energy"), demand, targets,
                  state_gb=state_gb, compare_static=True)
    plan, fleet, static = res.plan, res.fleet, res.static_fleet

    occ = plan.occupancy()
    print(f"--- multi-region placement: {n} containers over "
          f"{'/'.join(regions)}, capacity {cap}/region ---")
    print(f"  {'region':10s} {'occ@start':>9s} {'occ@end':>8s} "
          f"{'avg g/kWh':>10s}")
    for r, name in enumerate(regions):
        print(f"  {name:10s} {occ[0, r]:9d} {occ[-1, r]:8d} "
              f"{plan.region_intensity[:, r].mean():10.0f}")
    moved_kg = res.total_emissions_g.sum() / 1000.0
    static_kg = static.emissions_g.sum() / 1000.0
    print(f"  placement moves: {int(plan.migrations.sum())} "
          f"(downtime {plan.downtime_s.sum():.0f} s, "
          f"overhead {plan.overhead_g.sum():.1f} g)")
    print(f"  emissions: placed {moved_kg:.1f} kg vs static {static_kg:.1f} "
          f"kg -> {res.saving_vs_static_pct:.1f}% saved")
    eff_m = float(res.carbon_efficiency.mean())
    eff_s = float((static.work_done
                   / np.maximum(static.emissions_g / 1000.0, 1e-12)).mean())
    print(f"  carbon-efficiency (work/kg CO2e): placed {eff_m:.0f} vs "
          f"static {eff_s:.0f} ({100.0 * (eff_m / eff_s - 1.0):+.1f}%)\n")


def jax_sweep(n_containers: int = 10080, n_targets: int = 12,
              days: int = 3):
    """A 10k-container placed fleet sweep, device-resident end-to-end:
    the JAX placement kernel assigns every trace column a region per
    epoch, then one jit/scan per policy sweeps all (target x trace)
    columns — against the same sweep on the NumPy fleet backend."""
    from repro.core.policy import CarbonContainerPolicy
    from repro.core.simulator import SimConfig
    from repro.core.spec import SweepSpec

    from repro.workload.azure_like import sample_population_matrix

    n_traces = n_containers // n_targets
    fam = paper_family()
    regions = ("PL", "NL", "CAISO")
    provs = [TraceProvider.for_region(r, hours=24 * days, seed=1)
             for r in regions]
    # (T, n_traces) matrix straight through the sweep — the vectorized
    # generator is what makes 100k-trace fleets feasible (make jax-sweep
    # runs this same path at N=1M via benchmarks.run)
    traces = sample_population_matrix(n_traces, days=days, seed=3)
    T = traces.shape[0]
    cap = int(np.ceil(0.6 * n_traces))
    eng = PlacementEngine(
        fam, provs, interval_s=INTERVAL_S, region_names=regions,
        config=PlacementConfig(capacity=cap, min_dwell=6, hysteresis=0.10))
    targets = list(np.linspace(20.0, 80.0, n_targets))
    policies = {"CC (energy)":
                lambda: CarbonContainerPolicy(variant="energy")}
    cfg = SimConfig(target_rate=0.0)
    n_total = n_traces * n_targets

    print(f"--- jax sweep: {n_total} placed containers "
          f"({n_traces} traces x {n_targets} targets, {T} epochs, "
          f"capacity {cap}/region) ---")
    spec = SweepSpec(policies=policies, family=fam, traces=traces,
                     targets=targets, sim=cfg, backend="jax", placement=eng)
    t0 = time.perf_counter()
    rows = spec.run()
    warm = time.perf_counter() - t0
    t0 = time.perf_counter()
    rows = spec.run()
    steady = time.perf_counter() - t0
    t0 = time.perf_counter()
    rows_np = SweepSpec(policies=policies, family=fam, traces=traces,
                        targets=targets, sim=cfg, backend="fleet",
                        placement=eng).run()
    numpy_s = time.perf_counter() - t0
    drift = max(abs(a["carbon_rate_mean"] - b["carbon_rate_mean"])
                for a, b in zip(rows, rows_np))
    rate = n_total * T / steady
    print(f"  jax:   first call {warm:.2f}s (jit compile), steady "
          f"{steady:.2f}s  ({rate/1e6:.1f}M container-epochs/s)")
    print(f"  numpy: {numpy_s:.2f}s  -> {numpy_s/steady:.1f}x steady-state "
          f"speedup (parity drift {drift:.1e})")
    print(f"\n  {'target':>7s} {'g/hr':>8s} {'throttle%':>10s} "
          f"{'migs':>6s} {'placement migs':>14s}")
    for r in rows:
        print(f"  {r['target']:7.1f} {r['carbon_rate_mean']:8.2f} "
              f"{r['throttle_mean']:10.2f} {r['migrations_mean']:6.1f} "
              f"{r['placement_migrations_mean']:14.1f}")
    print()


def main():
    n_jobs = _arg("--jobs", 20, int)
    backend = _arg("--backend", "fleet", str)
    if backend not in ("fleet", "scalar"):
        raise SystemExit(f"--backend must be 'fleet' or 'scalar', "
                         f"got {backend!r}")
    n_fleet = _arg("--fleet", 120, int)
    if "--jax-sweep" in sys.argv:        # jax demo only (make jax-sweep)
        # CPU-tuned XLA flags, set before jax initializes; explicit
        # user settings win
        from repro.core.fleet_jax import ensure_cpu_xla_flags
        ensure_cpu_xla_flags()
        jax_sweep(_arg("--containers", 10080, int))
        return
    if "--placement" in sys.argv:        # placement demo only (make placement)
        multi_region_placement(n_fleet)
        return
    per_region_tables(n_jobs, backend)
    heterogeneous_fleet(n_fleet)
    multi_region_placement(n_fleet)


if __name__ == "__main__":
    main()
