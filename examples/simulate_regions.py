"""Large-scale Carbon Containers simulation across regions (paper Figs 11-16
in miniature): 1000-VM-style population, all four policies, three regions.

    PYTHONPATH=src python examples/simulate_regions.py [--jobs 20]
"""
import sys

import numpy as np

from repro.carbon.intensity import TraceProvider
from repro.cluster.slices import paper_family
from repro.core.policy import (CarbonAgnosticPolicy, CarbonContainerPolicy,
                               SuspendResumePolicy, VScaleOnlyPolicy)
from repro.core.simulator import SimConfig, simulate
from repro.workload.azure_like import sample_population


def main():
    n_jobs = 20
    if "--jobs" in sys.argv:
        n_jobs = int(sys.argv[sys.argv.index("--jobs") + 1])
    fam = paper_family()
    traces = [t.util for t in sample_population(n_jobs, days=5, seed=2)]
    policies = [
        ("carbon-agnostic", CarbonAgnosticPolicy),
        ("suspend/resume", SuspendResumePolicy),
        ("vscale-only", lambda: VScaleOnlyPolicy()),
        ("CC (energy)", lambda: CarbonContainerPolicy("energy")),
        ("CC (performance)", lambda: CarbonContainerPolicy("performance")),
    ]
    target = 45.0
    print(f"{n_jobs} jobs x 5 days, C_target = {target} g/hr\n")
    for region in ("PL", "NL", "CAISO"):
        carbon = TraceProvider.for_region(region, hours=24 * 5, seed=1)
        print(f"--- region {region} ---")
        print(f"  {'policy':18s} {'g/hr':>8s} {'throttle%':>10s} "
              f"{'migs':>6s} {'susp%':>6s}")
        for name, mk in policies:
            rates, thr, migs, susp = [], [], [], []
            for tr in traces:
                r = simulate(mk(), fam, tr, carbon,
                             SimConfig(target_rate=target, state_gb=1.0))
                rates.append(r.avg_carbon_rate)
                thr.append(r.avg_throttle_pct)
                migs.append(r.migrations)
                susp.append(r.suspended_frac)
            print(f"  {name:18s} {np.mean(rates):8.2f} {np.mean(thr):10.2f} "
                  f"{np.mean(migs):6.1f} {100*np.mean(susp):6.1f}")
        print()


if __name__ == "__main__":
    main()
