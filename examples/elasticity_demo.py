"""Per-container elasticity demo (``make elasticity``).

A fleet of containers, each with K discrete resource levels, scaled
every epoch by the CarbonScaler marginal-allocation greedy: flatten
the (N, K) table of marginal work / marginal grams, admit levels in
descending carbon-efficiency order under a fleet-wide gram budget.
The budget itself is *shaped* — the same total grams reallocated
across the day by the forecaster's now-vs-next-24h carbon ratio — so
the quality of the forecast decides how much work lands in green
hours:

    demand + carbon traces --> forecasters (d-hat, c-hat, shaped
    budget) --> (N, K) marginal greedy --> levels, served work,
    deferred backlog --> emissions at the true intensity

Runs the oracle / forecast / persistence ablation (persistence
believes carbon stays flat, so its shaped budget degenerates to
uniform — the unshaped baseline), then the same layer composed with
placement inside the fleet sweep on both backends.

    PYTHONPATH=src python examples/elasticity_demo.py
        [--containers 2000] [--days 10] [--budget-frac 0.6]
"""
import sys

import numpy as np

from repro.carbon.traces import synth_trace
from repro.core.elasticity import ElasticityConfig, simulate_elastic

INTERVAL_S = 3600.0
REGIONS = ("PL", "NL", "CAISO")


def _arg(flag, default, cast):
    if flag in sys.argv:
        return cast(sys.argv[sys.argv.index(flag) + 1])
    return default


def main():
    n = _arg("--containers", 2000, int)
    days = _arg("--days", 10, int)
    frac = _arg("--budget-frac", 0.6, float)
    T = 24 * days

    region_mat = np.stack([synth_trace(r, hours=T, seed=11)
                           for r in REGIONS], axis=1)
    rng = np.random.default_rng(7)
    phase = rng.uniform(0.0, 1.0, (1, n))
    base = 2.0 + np.sin(2 * np.pi * (np.arange(T)[:, None] / 24.0 + phase))
    eps = rng.normal(0.0, 0.3, (T, n))
    noise = np.zeros((T, n))
    for t in range(1, T):
        noise[t] = 0.9 * noise[t - 1] + eps[t]
    demand = np.abs(base + noise)
    codes = np.tile(np.arange(n, dtype=np.int32) % 3, (T, 1))
    carbon = region_mat[np.arange(T)[:, None], codes]
    print(f"fleet: {n:,} containers x {T} hourly epochs, "
          f"K=4 levels, regions {REGIONS}")

    mk = lambda mode, budget, shape=False: ElasticityConfig(
        k_levels=4, unit_capacity=1.0, base_w=50.0, peak_w=200.0,
        max_step=4, budget_g_per_epoch=budget, forecast=mode,
        shape_budget=shape)
    free = simulate_elastic(demand, carbon, mk("oracle", None), INTERVAL_S)
    budget = frac * free.est_emissions_g / T
    print(f"budget: {budget:,.0f} g/epoch shaped "
          f"({frac:.0%} of the uncapped oracle estimate)")

    print(f"\n{'forecaster':>12} {'kg CO2':>10} {'g/unit work':>12} "
          f"{'served':>8} {'deferred':>9} {'cap viol':>9}")
    cpw = {}
    for mode in ("oracle", "forecast", "persistence"):
        s = simulate_elastic(demand, carbon, mk(mode, budget, True),
                             INTERVAL_S).summary()
        cpw[mode] = (s["elastic_emissions_g"]
                     / max(s["elastic_served_work"], 1e-12))
        print(f"{mode:>12} {s['elastic_emissions_g'] / 1e3:>10.1f} "
              f"{cpw[mode]:>12.5f} {s['elastic_served_frac']:>7.1%} "
              f"{s['elastic_deferred_work']:>9.0f} "
              f"{s['elastic_cap_violations']:>9d}")
    print(f"\nforecast saves {1 - cpw['forecast'] / cpw['persistence']:.2%} "
          f"carbon per unit work vs persistence "
          f"(oracle bound {1 - cpw['oracle'] / cpw['persistence']:.2%}): "
          f"knowing the diurnal shape moves the budget into green hours")

    # same layer composed with placement inside the sweep, both backends
    from repro.carbon.intensity import TraceProvider
    from repro.cluster.placement import PlacementConfig, PlacementEngine
    from repro.cluster.slices import paper_family
    from repro.core.policy import CarbonContainerPolicy
    from repro.core.simulator import SimConfig
    from repro.core.spec import SweepSpec
    from repro.workload.azure_like import sample_population

    fam = paper_family()
    traces = [t.util for t in sample_population(64, days=1, seed=5)]
    provs = [TraceProvider.for_region(r, hours=24, seed=1)
             for r in REGIONS]
    ec = ElasticityConfig(k_levels=4, unit_capacity=0.3,
                          budget_g_per_epoch=150.0, forecast="forecast",
                          shape_budget=True)
    pols = {"carbon_containers":
            lambda: CarbonContainerPolicy(variant="energy")}
    print(f"\nplaced sweep with elasticity (64 traces, both backends):")
    for backend in ("fleet", "jax"):
        try:
            rows = SweepSpec(policies=pols, family=fam, traces=traces,
                             targets=[40.0], sim=SimConfig(target_rate=0.0),
                             backend=backend,
                             placement=PlacementConfig(capacity=64,
                                                       min_dwell=6),
                             regions=provs, region_names=REGIONS,
                             elasticity=ec).run()
        except ImportError:
            print(f"  {backend:>6}: jax unavailable, skipped")
            continue
        r = rows[0]
        print(f"  {backend:>6}: carbon_rate={r['carbon_rate_mean']:.2f} "
              f"served={r['elastic_served_frac']:.1%} "
              f"level_epochs={r['elastic_level_epochs']} "
              f"cap_viol={r['elastic_cap_violations']}")


if __name__ == "__main__":
    main()
