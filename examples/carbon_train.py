"""End-to-end Carbon Containers demo: train a ~100M-param-class model (reduced
to CPU scale) for a few hundred steps under a carbon cap, with LIVE
enforcement — duty-cycling, elastic slice migration (real checkpoint ->
reshard -> restore between device subsets), and suspend/resume — while the
grid's carbon intensity follows a realistic diurnal trace.

    PYTHONPATH=src python examples/carbon_train.py [--steps 200]
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import sys
import tempfile

import jax

from repro.carbon.intensity import TraceProvider
from repro.cluster.slices import SliceFamily, Slice
from repro.config import CarbonConfig, OptimizerConfig, TrainConfig
from repro.configs import get_arch
from repro.core.carbon_aware_trainer import CarbonAwareTrainer
from repro.core.elastic import ElasticJob
from repro.data.pipeline import markov_stream
from repro.models import get_model
from repro.power.model import LinearPowerModel


def demo_family(n_devices: int) -> tuple:
    """Slice family over local devices: 1/2/4/8 chips, power ∝ chips."""
    sizes = [1, 2, 4, 8]
    sizes = [s for s in sizes if s <= n_devices]
    slices = [Slice(f"cpu-{s}", s / sizes[len(sizes)//2],
                    LinearPowerModel(40.0 * s, 110.0 * s), chips=s)
              for s in sizes]
    fam = SliceFamily(slices, baseline_idx=len(sizes) // 2)
    devs = jax.devices()
    slice_devs = [devs[:s.chips] for s in fam.slices]
    return fam, slice_devs


def main():
    steps = 200
    if "--steps" in sys.argv:
        steps = int(sys.argv[sys.argv.index("--steps") + 1])

    spec = get_arch("smollm-135m")
    model = get_model(spec.smoke)
    tcfg = TrainConfig(seq_len=64, global_batch=8, steps=steps,
                       optimizer=OptimizerConfig(lr=2e-3, warmup_steps=10,
                                                 total_steps=steps),
                       log_every=0)
    fam, slice_devs = demo_family(len(jax.devices()))
    ckpt = tempfile.mkdtemp(prefix="lxcc_")
    job = ElasticJob(model, tcfg, ckpt)
    job.start(slice_devs[fam.baseline_idx])

    ccfg = CarbonConfig(target_rate=45.0, policy="energy", region="NL",
                        interval_s=300.0)
    # each train step advances the sim clock by 90 s -> 200 steps ≈ 5 h of
    # grid variation; demand varies with the duty cycle the policy sets
    step_flops = 6.0 * model.param_count() * tcfg.seq_len * tcfg.global_batch
    # make MFU meaningful on fake 'chips': pretend peak = what we achieve
    trainer = CarbonAwareTrainer(
        job=job, family=fam, slice_devices=slice_devs,
        carbon=TraceProvider.for_region(ccfg.region, seed=4),
        cfg=ccfg, step_flops=step_flops,
        step_tokens=tcfg.seq_len * tcfg.global_batch,
        peak_flops_per_chip=step_flops / 60.0,   # demo: ~60 s/step at MFU=1
        sim_seconds_per_step=90.0)

    data = markov_stream(spec.smoke.vocab_size, tcfg.seq_len,
                         tcfg.global_batch, temperature=0.2)
    print(f"target C = {ccfg.target_rate} g/hr, region {ccfg.region}, "
          f"policy {ccfg.policy}")
    out = trainer.run(data, steps)
    print(f"\ncompleted {out['steps']} steps with "
          f"{len(out['migrations'])} live migrations")
    print("timeline (one row per monitoring interval):")
    for log in out["logs"][:: max(1, len(out["logs"]) // 12)]:
        bar = "#" * int(log.carbon_rate / 3)
        print(f"  t={log.t/3600:5.2f}h  c={log.carbon_intensity:4.0f} g/kWh  "
              f"slice={log.slice_name:6s} duty={log.duty:4.2f} "
              f"C={log.carbon_rate:6.1f} g/hr {bar}")
    rates = [l.carbon_rate for l in out["logs"]]
    print(f"\navg C(t) = {sum(rates)/len(rates):.1f} g/hr "
          f"(target {ccfg.target_rate}) — "
          f"{'ENFORCED' if sum(rates)/len(rates) <= ccfg.target_rate else 'EXCEEDED'}")


if __name__ == "__main__":
    main()
