"""SweepSpec / SweepResult: the declarative sweep surface and its shim.

The spec path must produce byte-identical rows to the legacy kwargs
path on every backend and layer combination — it is a surface change,
not a semantic one.
"""
import numpy as np
import pytest

from repro.cluster.placement import PlacementConfig, PlacementEngine
from repro.cluster.slices import paper_family
from repro.core.policy import CarbonAgnosticPolicy, CarbonContainerPolicy
from repro.core.simulator import SimConfig, sweep_population
from repro.core.spec import SweepResult, SweepSpec
from repro.energy import EnergyConfig, GridEventConfig

_POL = {"cc": lambda: CarbonContainerPolicy(),
        "agnostic": lambda: CarbonAgnosticPolicy()}


def _inputs(T=64, n_tr=12, seed=2):
    rng = np.random.default_rng(seed)
    traces = rng.uniform(0.2, 1.5, size=(T, n_tr))
    t = np.linspace(0, 4 * np.pi, T)
    regions = np.stack([220 + 140 * np.sin(t + p)
                        for p in (0.0, 2.0, 4.0)], axis=1) + 40.0
    return traces, regions


def test_spec_matches_kwargs_rows_exactly():
    traces, regions = _inputs()
    fam = paper_family()
    cfg = SimConfig(target_rate=0.0)
    pc = PlacementConfig(capacity=10)
    en = EnergyConfig(events=GridEventConfig(shocks=((-1, 20, 8, 2.0),)))
    res = SweepSpec(policies=_POL, family=fam, traces=traces,
                    targets=[40.0, 80.0], sim=cfg, backend="fleet",
                    placement=pc, regions=regions, energy=en).run()
    rows = sweep_population(_POL, fam, traces, None, [40.0, 80.0], cfg,
                            backend="fleet",
                            placement=PlacementEngine(
                                fam, regions, interval_s=cfg.interval_s,
                                config=pc),
                            energy=en)
    assert isinstance(res, SweepResult)
    assert isinstance(rows, list)           # the shim returns bare rows
    assert res.rows == rows


def test_sweep_population_accepts_spec_directly():
    traces, regions = _inputs()
    spec = SweepSpec(policies=_POL, family=paper_family(), traces=traces,
                     targets=[40.0], backend="fleet",
                     placement=PlacementConfig(capacity=10),
                     regions=regions)
    res = sweep_population(spec)
    assert isinstance(res, SweepResult)
    assert len(res) == 2 and res.backend == "fleet"
    with pytest.raises(TypeError, match="not both"):
        sweep_population(spec, paper_family())


def test_spec_scalar_backend_and_carbon_provider():
    from repro.carbon.intensity import TraceProvider
    rng = np.random.default_rng(0)
    traces = [rng.uniform(0.2, 1.5, size=48) for _ in range(3)]
    carbon = TraceProvider(200 + 100 * rng.uniform(size=48))
    res = SweepSpec(policies=_POL, family=paper_family(), traces=traces,
                    targets=[50.0], carbon=carbon, backend="scalar").run()
    rows = sweep_population(_POL, paper_family(), traces, carbon, [50.0],
                            SimConfig(target_rate=0.0), backend="scalar")
    assert res.rows == rows


def test_spec_placement_resolution_errors():
    traces, regions = _inputs()
    base = dict(policies=_POL, family=paper_family(), traces=traces,
                targets=[40.0])
    with pytest.raises(ValueError, match="regions"):
        SweepSpec(**base, placement=PlacementConfig(capacity=10)).run()
    with pytest.raises(ValueError, match="placement config"):
        SweepSpec(**base, regions=regions).run()
    eng = PlacementEngine(paper_family(), regions,
                          config=PlacementConfig(capacity=10))
    with pytest.raises(ValueError, match="not both"):
        SweepSpec(**base, placement=eng, regions=regions).run()
    # a pre-built engine passes through untouched
    assert SweepSpec(**base, placement=eng).resolve_placement() is eng


def test_spec_engine_built_on_sim_interval():
    traces, regions = _inputs()
    spec = SweepSpec(policies=_POL, family=paper_family(), traces=traces,
                     targets=[40.0],
                     sim=SimConfig(target_rate=0.0, interval_s=600.0),
                     placement=PlacementConfig(capacity=10),
                     regions=regions)
    assert spec.resolve_placement().interval_s == 600.0


def test_sweep_result_accessors():
    traces, regions = _inputs()
    res = SweepSpec(policies=_POL, family=paper_family(), traces=traces,
                    targets=[40.0, 80.0], backend="fleet",
                    placement=PlacementConfig(capacity=10), regions=regions,
                    energy=EnergyConfig()).run()
    # sequence protocol
    assert len(res) == 4
    assert [r["policy"] for r in res] == [r["policy"] for r in res.rows]
    assert res[0] is res.rows[0]
    # uniform metric access
    assert res.col("carbon_rate_mean").shape == (4,)
    assert "carbon_rate_mean" in res.keys()
    assert "policy" not in res.keys()
    v = res.violations
    assert v["energy_cap_violations"] == 0.0
    assert v["energy_soc_violations"] == 0.0
    # self-parity is exactly zero; a perturbed copy is not
    assert res.parity(res) == 0.0
    import copy
    other = copy.deepcopy(res)
    other.rows[0]["carbon_rate_mean"] *= 1.01
    assert res.parity(other) > 1e-3


def test_sweep_result_parity_row_mismatch():
    traces, regions = _inputs()
    res = SweepSpec(policies=_POL, family=paper_family(), traces=traces,
                    targets=[40.0], backend="fleet",
                    placement=PlacementConfig(capacity=10),
                    regions=regions).run()
    short = SweepResult(rows=res.rows[:1], backend="fleet")
    with pytest.raises(ValueError, match="row count"):
        res.parity(short)
