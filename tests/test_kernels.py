"""Per-kernel validation: Pallas (interpret=True) vs pure-jnp oracles,
swept over shapes/dtypes, plus the custom-VJP flash gradients."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref as R
from repro.kernels.flash_attention import flash_attention
from repro.kernels.rglru_scan import rglru_pallas
from repro.kernels.ssd_scan import ssd_pallas

pytestmark = pytest.mark.slow  # JAX model/kernel suite: excluded from the fast lane

KEY = jax.random.PRNGKey(7)


def _qkv(B, S, Hq, Hkv, Dh, dtype):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, S, Hq, Dh), dtype)
    k = jax.random.normal(ks[1], (B, S, Hkv, Dh), dtype)
    v = jax.random.normal(ks[2], (B, S, Hkv, Dh), dtype)
    return q, k, v


ATTN_CASES = [
    # B, S, Hq, Hkv, Dh, causal, window, bq, bk
    (2, 128, 4, 2, 32, True, 0, 32, 32),
    (1, 64, 2, 1, 16, True, 24, 16, 32),
    (2, 128, 4, 4, 64, False, 0, 64, 64),
    (1, 96, 8, 2, 32, True, 0, 32, 48),   # uneven blocks (pad path)
]


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("case", ATTN_CASES)
def test_flash_attention_pallas_vs_ref(case, dtype):
    B, S, Hq, Hkv, Dh, causal, window, bq, bk = case
    q, k, v = _qkv(B, S, Hq, Hkv, Dh, dtype)
    ref = R.attention_ref(q, k, v, causal=causal, window=window)
    out = flash_attention(q, k, v, causal=causal, window=window,
                          block_q=bq, block_kv=bk, interpret=True)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol, rtol=tol)


@pytest.mark.parametrize("case", ATTN_CASES[:3])
def test_flash_custom_vjp_grads(case):
    B, S, Hq, Hkv, Dh, causal, window, bq, bk = case
    q, k, v = _qkv(B, S, Hq, Hkv, Dh, jnp.float32)

    def loss_ref(q, k, v):
        return jnp.sum(R.attention_ref(q, k, v, causal=causal,
                                       window=window) ** 2)

    def loss_flash(q, k, v):
        return jnp.sum(R.attention_flash(q, k, v, causal=causal,
                                         window=window, q_block=bq,
                                         kv_block=bk) ** 2)

    gr = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
    gf = jax.grad(loss_flash, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(gr, gf):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=2e-3, rtol=1e-3)


def test_chunked_matches_ref_with_offsets():
    q, k, v = _qkv(2, 40, 4, 2, 16, jnp.float32)
    q1 = q[:, 30:32]
    ref = R.attention_ref(q1, k, v, causal=True, q_offset=30, kv_len=37)
    chk = R.attention_chunked(q1, k, v, causal=True, q_offset=30, kv_len=37,
                              q_block=2, kv_block=16)
    np.testing.assert_allclose(np.asarray(chk), np.asarray(ref), atol=2e-5)


SSD_CASES = [
    # B, S, H, P, N, chunk, bh
    (2, 64, 4, 16, 32, 16, 2),
    (1, 128, 8, 32, 64, 32, 4),
    (2, 96, 4, 64, 16, 32, 4),
]


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("case", SSD_CASES)
def test_ssd_pallas_vs_sequential_ref(case, dtype):
    B, S, H, P, N, Q, bh = case
    ks = jax.random.split(KEY, 5)
    x = jax.random.normal(ks[0], (B, S, H, P), dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H))).astype(jnp.float32)
    a_log = jax.random.uniform(ks[2], (H,), minval=0.0, maxval=1.5)
    b = jax.random.normal(ks[3], (B, S, 1, N), dtype)
    c = jax.random.normal(ks[4], (B, S, 1, N), dtype)
    d = jnp.ones((H,))
    y_ref, h_ref = R.ssd_ref(x, dt, a_log, b, c, d)
    y, h = ssd_pallas(x, dt, a_log, b, c, d, chunk=Q, block_heads=bh,
                      interpret=True)
    tol = 5e-3 if dtype == jnp.float32 else 1e-1
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(y_ref, np.float32), atol=tol, rtol=tol)
    np.testing.assert_allclose(np.asarray(h), np.asarray(h_ref),
                               atol=5e-3, rtol=5e-3)


def test_ssd_chunked_matches_ref_with_state():
    B, S, H, P, N = 2, 48, 4, 8, 16
    ks = jax.random.split(KEY, 6)
    x = jax.random.normal(ks[0], (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    a_log = jax.random.uniform(ks[2], (H,), minval=0.0, maxval=1.0)
    b = jax.random.normal(ks[3], (B, S, 2, N))
    c = jax.random.normal(ks[4], (B, S, 2, N))
    d = jnp.zeros((H,))
    h0 = jax.random.normal(ks[5], (B, H, P, N))
    y_ref, h_ref = R.ssd_ref(x, dt, a_log, b, c, d, h0=h0)
    y, h = R.ssd_chunked(x, dt, a_log, b, c, d, h0=h0, chunk=16)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               atol=2e-3, rtol=2e-3)
    np.testing.assert_allclose(np.asarray(h), np.asarray(h_ref),
                               atol=2e-3, rtol=2e-3)


RGLRU_CASES = [(2, 64, 128), (1, 128, 256), (3, 32, 512)]


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("case", RGLRU_CASES)
def test_rglru_pallas_vs_ref(case, dtype):
    B, S, W = case
    ks = jax.random.split(KEY, 4)
    x = jax.random.normal(ks[0], (B, S, W), dtype)
    r = jax.random.normal(ks[1], (B, S, W), dtype)
    i = jax.random.normal(ks[2], (B, S, W), dtype)
    lam = jax.random.normal(ks[3], (W,))
    y_ref, h_ref = R.rglru_ref(x, r, i, lam)
    y, h = rglru_pallas(x, r, i, lam, interpret=True)
    tol = 1e-5 if dtype == jnp.float32 else 3e-2
    htol = 1e-4 if dtype == jnp.float32 else 1e-2
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(y_ref, np.float32), atol=tol, rtol=tol)
    np.testing.assert_allclose(np.asarray(h), np.asarray(h_ref), atol=htol,
                               rtol=htol)


def test_rglru_assoc_matches_ref_with_state():
    B, S, W = 2, 40, 32
    ks = jax.random.split(KEY, 5)
    x = jax.random.normal(ks[0], (B, S, W))
    r = jax.random.normal(ks[1], (B, S, W))
    i = jax.random.normal(ks[2], (B, S, W))
    lam = jax.random.normal(ks[3], (W,))
    h0 = jax.random.normal(ks[4], (B, W))
    y_ref, hf_ref = R.rglru_ref(x, r, i, lam, h0=h0)
    y, hf = R.rglru_assoc(x, r, i, lam, h0=h0)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=1e-5)
    np.testing.assert_allclose(np.asarray(hf), np.asarray(hf_ref), atol=1e-5)


def test_conv1d_seq_and_step_agree():
    B, S, C, K = 2, 16, 8, 4
    ks = jax.random.split(KEY, 3)
    x = jax.random.normal(ks[0], (B, S, C))
    w = jax.random.normal(ks[1], (K, C))
    b = jax.random.normal(ks[2], (C,))
    from repro.kernels import ops
    y_seq, state = R.causal_conv1d_ref(x, w, b)
    state_i = jnp.zeros((B, K - 1, C))
    outs = []
    for t in range(S):
        y_t, state_i = ops.conv1d_decode_step(x[:, t], w, b, state_i)
        outs.append(y_t)
    y_step = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(y_step), np.asarray(y_seq), atol=1e-5)
    np.testing.assert_allclose(np.asarray(state_i), np.asarray(state), atol=1e-6)
