"""JAX placement planner: parity against the NumPy (N, R) batch kernel.

`plan_jax` must reproduce `PlacementEngine.plan` — which is itself
pinned bit-compatible to the greedy scalar reference — to 1e-6, with
epoch-by-epoch region assignments exactly equal (a single divergent
move would cascade through occupancy and dwell state). The tight-cap
case forces the ranked-admission path (preference rounds with denials);
the loose-cap case exercises the all-admitted fast path.
"""
import numpy as np
import pytest

pytest.importorskip("jax")

from repro.carbon.intensity import TraceProvider  # noqa: E402
from repro.cluster.placement import PlacementConfig, PlacementEngine  # noqa: E402
from repro.cluster.placement_jax import plan_jax  # noqa: E402
from repro.cluster.slices import paper_family  # noqa: E402
from repro.workload.azure_like import sample_population  # noqa: E402

TOL = 1e-6
DAYS = 1
REGIONS = ("PL", "NL", "CAISO")


def _inputs(n, seed=5):
    provs = [TraceProvider.for_region(r, hours=24 * DAYS, seed=1)
             for r in REGIONS]
    traces = [t.util for t in sample_population(n, days=DAYS, seed=seed)]
    demand = np.stack(traces, axis=1)
    rng = np.random.default_rng(seed)
    state_gb = rng.choice([0.25, 1.0, 4.0], size=n)
    return provs, demand, state_gb


def _assert_plans_equal(p_np, p_j, ctx=""):
    assert (p_np.assign == p_j.assign).all(), f"{ctx}: assignments differ"
    assert (p_np.migrations == p_j.migrations).all(), ctx
    if p_np.migrations.size:
        assert float(np.abs(p_np.overhead_g - p_j.overhead_g).max()) <= TOL, ctx
        assert float(np.abs(p_np.downtime_s - p_j.downtime_s).max()) <= TOL, ctx
    assert (p_np.initial == p_j.initial).all(), ctx


@pytest.mark.parametrize("capacity", [None, 8],
                         ids=["uncapped", "tight-cap"])
def test_plan_jax_matches_numpy(capacity):
    n = 18
    provs, demand, state_gb = _inputs(n)
    eng = PlacementEngine(
        paper_family(), provs, region_names=REGIONS,
        config=PlacementConfig(capacity=capacity, min_dwell=4,
                               hysteresis=0.10))
    p_np = eng.plan(demand, state_gb=state_gb)
    p_j = plan_jax(eng, demand, state_gb=state_gb)
    _assert_plans_equal(p_np, p_j, ctx=f"cap={capacity}")
    if capacity is not None:
        assert int((p_j.occupancy() > capacity).sum()) == 0
    # the tight cap must actually exercise admission pressure somewhere
    if capacity is not None:
        assert p_j.migrations.sum() > 0


def test_plan_jax_respects_initial_assignment():
    n = 9
    provs, demand, state_gb = _inputs(n, seed=7)
    eng = PlacementEngine(paper_family(), provs, region_names=REGIONS,
                          config=PlacementConfig(min_dwell=2))
    initial = np.array([2, 2, 2, 1, 1, 1, 0, 0, 0])
    p_np = eng.plan(demand, state_gb=state_gb, initial=initial)
    p_j = plan_jax(eng, demand, state_gb=state_gb, initial=initial)
    _assert_plans_equal(p_np, p_j, ctx="initial")
    assert (p_j.initial == initial).all()


def test_plan_jax_empty_fleet():
    """N=0 short-circuits without tracing the round loop (regression:
    the scan used to trace (0, R) shapes and fall over inside argmax)."""
    provs = [TraceProvider.for_region(r, hours=24 * DAYS, seed=1)
             for r in REGIONS]
    eng = PlacementEngine(paper_family(), provs, region_names=REGIONS,
                          config=PlacementConfig(capacity=8, min_dwell=4))
    demand = np.zeros((288 * DAYS, 0))
    p_np = eng.plan(demand, state_gb=np.zeros(0))
    p_j = plan_jax(eng, demand, state_gb=np.zeros(0))
    _assert_plans_equal(p_np, p_j, ctx="N=0")
    assert p_j.assign.shape == (288 * DAYS, 0)
    assert p_j.migrations.shape == (0,)


def test_plan_jax_single_region():
    """R=1 short-circuits: with one region there is nothing to migrate
    to, so the plan is the frozen initial assignment."""
    provs = [TraceProvider.for_region("PL", hours=24 * DAYS, seed=1)]
    traces = [t.util for t in sample_population(7, days=DAYS, seed=11)]
    demand = np.stack(traces, axis=1)
    eng = PlacementEngine(paper_family(), provs, region_names=("PL",),
                          config=PlacementConfig(min_dwell=4))
    p_np = eng.plan(demand, state_gb=1.0)
    p_j = plan_jax(eng, demand, state_gb=1.0)
    _assert_plans_equal(p_np, p_j, ctx="R=1")
    assert int(p_j.migrations.sum()) == 0
    assert (p_j.assign == 0).all()


def test_plan_jax_rejects_unknown_admission_impl():
    provs, demand, state_gb = _inputs(4)
    eng = PlacementEngine(paper_family(), provs, region_names=REGIONS)
    with pytest.raises(ValueError, match="admission_impl"):
        plan_jax(eng, demand, state_gb=state_gb, admission_impl="cuda")


@pytest.mark.parametrize("impl", ["xla", "pallas"])
@pytest.mark.parametrize("block_n", [8192, 7],
                         ids=["one-block", "multi-block"])
def test_admission_impl_parity(impl, block_n):
    """The admission_impl dispatch: both backends must reproduce the
    NumPy planner exactly under tight capacity (every epoch runs denial
    rounds). block_n=7 forces the pallas grid across ragged blocks so
    the cross-block SMEM counter carry is exercised; the xla impl
    ignores block_n (same dispatch surface either way)."""
    if impl == "pallas":
        pytest.importorskip("jax.experimental.pallas")
    n = 18
    provs, demand, state_gb = _inputs(n, seed=13)
    eng = PlacementEngine(
        paper_family(), provs, region_names=REGIONS,
        config=PlacementConfig(capacity=7, min_dwell=4, hysteresis=0.10))
    p_np = eng.plan(demand, state_gb=state_gb)
    p_j = plan_jax(eng, demand, state_gb=state_gb,
                   admission_impl=impl, block_n=block_n)
    _assert_plans_equal(p_np, p_j, ctx=f"impl={impl} block={block_n}")
    assert int((p_j.occupancy() > 7).sum()) == 0


def test_plan_jax_carbon_matrix_feeds_fleet():
    """The planned carbon matrix drives a placed fleet run identically
    to the NumPy plan's (same plan => same matrix)."""
    n = 6
    provs, demand, state_gb = _inputs(n, seed=9)
    eng = PlacementEngine(paper_family(), provs, region_names=REGIONS,
                          config=PlacementConfig(capacity=4, min_dwell=4))
    p_np = eng.plan(demand, state_gb=state_gb)
    p_j = plan_jax(eng, demand, state_gb=state_gb)
    assert np.array_equal(p_np.carbon_matrix(), p_j.carbon_matrix())
