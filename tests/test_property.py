"""Property-based tests (hypothesis) on system invariants."""
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="hypothesis not installed (see requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

import jax

from repro.carbon.intensity import ConstantProvider
from repro.cluster.slices import paper_family
from repro.core.container import PlantModel
from repro.core.policy import CarbonContainerPolicy
from repro.core.simulator import SimConfig, simulate
from repro.kernels import ref as R
from repro.power.model import LinearPowerModel

FAM = paper_family()


@settings(max_examples=25, deadline=None)
@given(util=st.floats(0, 1), base=st.floats(10, 200), spread=st.floats(1, 300))
def test_power_model_bounds(util, base, spread):
    m = LinearPowerModel(base, base + spread)
    p = m.power(util)
    assert base - 1e-9 <= p <= base + spread + 1e-9
    # inverse is consistent
    assert abs(m.power(m.util_for_power(p)) - p) < 1e-6


@settings(max_examples=20, deadline=None)
@given(demand=st.floats(0.0, 4.0), duty=st.floats(0.0, 1.0),
       c=st.floats(1.0, 900.0))
def test_plant_model_invariants(demand, duty, c):
    s = FAM.baseline
    step = PlantModel.run(s, duty, demand, c)
    assert 0.0 <= step.served <= min(demand, s.multiple * duty) + 1e-12
    assert step.served + step.throttled == max(demand, step.served)
    assert step.power_w >= s.power.base_w - 1e-9
    assert step.carbon_rate >= 0.0


@settings(max_examples=10, deadline=None)
@given(target=st.floats(8.0, 120.0), demand=st.floats(0.05, 2.0),
       c=st.floats(50.0, 800.0))
def test_enforcement_never_exceeds_target_steady_state(target, demand, c):
    """For any constant (demand, carbon) the enforced rate stays at/below
    target whenever the floor (smallest slice suspended) permits."""
    trace = np.full(24 * 12, demand)
    res = simulate(CarbonContainerPolicy("energy"), FAM, trace,
                   ConstantProvider(c), SimConfig(target_rate=target,
                                                  state_gb=0.25))
    floor = 0.0  # suspend releases the slice -> 0 emissions possible
    # allow transient overshoot from the first interval + migrations
    assert res.avg_carbon_rate <= max(target, floor) * 1.10 + 0.5


@pytest.mark.slow
@settings(max_examples=15, deadline=None)
@given(st.integers(1, 4), st.integers(1, 3), st.integers(8, 32),
       st.booleans())
def test_attention_softmax_rows_sum_to_one(b, hkv, s, causal):
    """Flash output is a convex combination of V rows -> bounded by V."""
    key = jax.random.PRNGKey(b * 100 + s)
    ks = jax.random.split(key, 3)
    g = 2
    q = jax.random.normal(ks[0], (b, s, hkv * g, 16))
    k = jax.random.normal(ks[1], (b, s, hkv, 16))
    v = jax.random.normal(ks[2], (b, s, hkv, 16))
    out = R.attention_flash(q, k, v, causal=causal, q_block=8, kv_block=8)
    vmax = np.abs(np.asarray(v)).max()
    assert np.abs(np.asarray(out)).max() <= vmax + 1e-4


@pytest.mark.slow
@settings(max_examples=15, deadline=None)
@given(st.integers(1, 3), st.integers(8, 40), st.integers(4, 16))
def test_rglru_is_contraction(b, s, w):
    """|h_t| <= max(|h_{t-1}|, |gated input|): a in (0,1), beta<=1."""
    key = jax.random.PRNGKey(s * 10 + w)
    ks = jax.random.split(key, 4)
    x = jax.random.normal(ks[0], (b, s, w))
    r = jax.random.normal(ks[1], (b, s, w))
    i = jax.random.normal(ks[2], (b, s, w))
    lam = jax.random.normal(ks[3], (w,))
    y, hf = R.rglru_ref(x, r, i, lam)
    bound = np.abs(np.asarray(x)).max() + 1e-5
    assert np.abs(np.asarray(y)).max() <= bound * (1 + s)  # loose growth bound
    assert np.isfinite(np.asarray(hf)).all()


@pytest.mark.slow
@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_checkpoint_determinism(seed):
    """init_params is deterministic per (spec tree, key)."""
    from repro.configs import get_arch
    from repro.models import get_model
    m = get_model(get_arch("smollm-135m").smoke)
    k = jax.random.PRNGKey(seed % 1000)
    a = m.init(k)
    b = m.init(k)
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
