"""Scenario stress matrix as a parameterized fast-lane table.

Each cell of `repro.energy.scenarios.build_matrix` runs at small shapes
on both array backends and must hold the matrix invariants: energy
conservation, zero virtual-cap violations, battery SoC in bounds, and
fleet <-> jax row parity. `make scenarios` runs the same matrix at full
shape.
"""
import numpy as np
import pytest

from repro.energy import scenarios as sc

_T, _N = 64, 8
_NAMES = [s.name for s in sc.build_matrix(_T)]


def _cell(name):
    return next(s for s in sc.build_matrix(_T) if s.name == name)


@pytest.mark.parametrize("name", _NAMES)
def test_scenario_invariants_fleet(name):
    out = sc.run_scenario(_cell(name), T=_T, n_tr=_N, targets=(40.0,),
                          backends=("fleet",))
    assert out["ok"], out["checks"]
    c = out["checks"]
    assert c["conservation_max_err_w"] <= sc.CONSERVATION_TOL_W
    assert c["cap_violations"] == 0
    assert c["soc_violations"] == 0


@pytest.mark.parametrize("name", _NAMES)
def test_scenario_backend_parity(name):
    pytest.importorskip("jax")
    out = sc.run_scenario(_cell(name), T=_T, n_tr=_N, targets=(40.0,),
                          backends=("fleet", "jax"))
    assert out["ok"], out["checks"]
    assert out["checks"]["backend_parity"] <= sc.PARITY_TOL


def test_matrix_covers_required_stressors():
    assert {"fleet_churn", "grid_outage", "intensity_shock",
            "migration_failures", "stragglers", "demand_burst",
            "telemetry_blackout", "flapping_feed",
            "migration_storm"} <= set(_NAMES)


def test_telemetry_blackout_degrades_and_leaves_meter_blind():
    out = sc.run_scenario(_cell("telemetry_blackout"), T=_T, n_tr=_N,
                          targets=(40.0,), backends=("fleet",))
    rows = out["results"]["fleet"]
    assert rows.col("fault_stale_frac").max() > 0.0
    # a blackout longer than the hold TTL must push past tier-1 hold
    assert (rows.col("fault_prior_frac").max()
            + rows.col("fault_floor_frac").max()) > 0.0
    # the power-meter gap accrues unmetered emissions
    assert rows.col("fault_unmetered_g_mean").max() > 0.0


def test_migration_storm_fails_and_retries():
    out = sc.run_scenario(_cell("migration_storm"), T=_T, n_tr=_N,
                          targets=(40.0,), backends=("fleet",))
    rows = out["results"]["fleet"]
    assert rows.col("fault_failed_migrations_mean").max() > 0.0


def test_grid_outage_scenario_actually_islands():
    out = sc.run_scenario(_cell("grid_outage"), T=_T, n_tr=_N,
                          targets=(40.0,), backends=("fleet",))
    assert out["outage_epochs"] > 0


def test_failure_scenario_detects_with_injected_clock():
    out = sc.run_scenario(_cell("migration_failures"), T=_T, n_tr=_N,
                          targets=(40.0,), backends=("fleet",))
    meta = out["meta"]
    assert meta["failed_at"] and meta["detected_at"]
    # heartbeat timeout of 2.5 intervals -> declared dead on the 3rd
    # silent epoch (2 epochs after the failure epoch), deterministically
    assert set(meta["detect_delay_epochs"].values()) == {2}
    # every scheduled failure surfaces as its own detected episode
    assert len(meta["episodes"]) == 3


def test_straggler_scenario_migrates():
    out = sc.run_scenario(_cell("stragglers"), T=_T, n_tr=_N,
                          targets=(40.0,), backends=("fleet",))
    meta = out["meta"]
    assert meta["migrated_at"] is not None
    assert meta["straggle_epochs"] >= 4    # detector patience lower bound


def test_burst_scenario_tracks_within_tolerance():
    out = sc.run_scenario(_cell("demand_burst"), T=_T, n_tr=_N,
                          targets=(40.0,), backends=("fleet",))
    assert out["meta"]["within_tolerance"]
    assert out["meta"]["ma_max_err"] <= 0.05


def test_masks_are_deterministic():
    a = sc.churn_mask(_T, _N)
    assert np.array_equal(a, sc.churn_mask(_T, _N))
    m1, meta1 = sc.failure_mask(_T, _N, 300.0)
    m2, meta2 = sc.failure_mask(_T, _N, 300.0)
    assert np.array_equal(m1, m2) and meta1 == meta2
    s1, _ = sc.straggler_mask(_T, _N)
    s2, _ = sc.straggler_mask(_T, _N)
    assert np.array_equal(s1, s2)
