"""JAX traffic fold: parity with the NumPy pipeline and the fleet sweep.

`traffic_step` mirrors the NumPy router/autoscaler term for term; the
only float drift is XLA's reduction association, so standalone parity is
pinned <=1e-6 (replica counts bit-equal). The sweep test pins the real
contract: `sweep_population(..., backend="jax", traffic=...)` — routing
+ autoscaling folded into the fleet scan — must match the fleet
backend's pre-modulated run to the backend parity budget.
"""
import numpy as np
import pytest

jax = pytest.importorskip("jax")

from repro.core.fleet_jax import ensure_cpu_xla_flags  # noqa: E402

ensure_cpu_xla_flags()

from repro.carbon.intensity import TraceProvider  # noqa: E402
from repro.cluster.placement import PlacementConfig, PlacementEngine  # noqa: E402
from repro.cluster.slices import paper_family  # noqa: E402
from repro.core.policy import (CarbonAgnosticPolicy,  # noqa: E402
                               CarbonContainerPolicy)
from repro.core.simulator import SimConfig, sweep_population  # noqa: E402
from repro.traffic import (TrafficConfig, UserPopulation,  # noqa: E402
                           request_matrix, simulate_traffic)
from repro.traffic.autoscale import ReplicaConfig  # noqa: E402
from repro.traffic.sim_jax import simulate_traffic_jax  # noqa: E402
from repro.workload.azure_like import sample_population  # noqa: E402

TOL = 1e-6


@pytest.mark.parametrize("policy,budget", [("carbon", None),
                                           ("carbon", 6.0),
                                           ("latency", 6.0)])
def test_simulate_traffic_jax_matches_numpy(policy, budget):
    from repro.traffic.routing import RoutingConfig
    pop = UserPopulation(n_users=150_000, n_regions=3, seed=0)
    T = 96
    arr = request_matrix(pop, T, 300.0)
    rng = np.random.default_rng(11)
    carbon = 100.0 + 500.0 * rng.random((T, 3))
    cfg = TrafficConfig(population=pop,
                        routing=RoutingConfig(policy=policy),
                        replicas=ReplicaConfig(max_replicas=8, max_step=2,
                                               budget_g_per_epoch=budget))
    rn = simulate_traffic(arr.requests, carbon, cfg)
    rj = simulate_traffic_jax(arr.requests, carbon, cfg)
    np.testing.assert_array_equal(rn.replicas, rj.replicas)
    for f in ("routed", "served", "dropped_route", "dropped_cap",
              "violations", "emissions_g"):
        a, b = getattr(rn, f), getattr(rj, f)
        scale = max(float(np.max(np.abs(a))), 1.0)
        assert np.max(np.abs(a - b)) <= TOL * scale, f


def test_sweep_population_jax_with_traffic_matches_fleet():
    fam = paper_family()
    traces = [t.util for t in sample_population(6, days=1, seed=5)]
    provs = [TraceProvider.for_region(r, hours=24, seed=1)
             for r in ("PL", "NL", "CAISO")]
    eng = PlacementEngine(fam, provs,
                          config=PlacementConfig(capacity=4, min_dwell=4))
    pols = {"cc_energy": lambda: CarbonContainerPolicy("energy"),
            "carbon_agnostic": CarbonAgnosticPolicy}
    cfgb = SimConfig(target_rate=0.0)
    tc = TrafficConfig(
        population=UserPopulation(n_users=100_000, n_regions=3, seed=3),
        replicas=ReplicaConfig(max_replicas=8, max_step=2))
    rows_f = sweep_population(pols, fam, traces, None, [30.0, 60.0], cfgb,
                              backend="fleet", placement=eng, traffic=tc)
    rows_j = sweep_population(pols, fam, traces, None, [30.0, 60.0], cfgb,
                              backend="jax", placement=eng, traffic=tc)
    assert len(rows_f) == len(rows_j) == 4
    for a, b in zip(rows_f, rows_j):
        assert a["policy"] == b["policy"] and a["target"] == b["target"]
        for k in ("carbon_rate_mean", "throttle_mean", "migrations_mean",
                  "traffic_served", "traffic_emissions_g",
                  "traffic_carbon_per_request_g", "traffic_slo_violations"):
            d = abs(a[k] - b[k]) / max(abs(a[k]), 1e-9)
            assert d <= TOL, (k, a[k], b[k])


def test_jax_run_traffic_requires_indexed_carbon():
    from repro.core.fleet_jax import FleetSimulatorJax
    from repro.traffic.sim_jax import TrafficSpec
    fam = paper_family()
    sim = FleetSimulatorJax(fam)
    tc = TrafficConfig(population=UserPopulation(n_users=1000, n_regions=2))
    spec = TrafficSpec.from_config(tc, 300.0)
    demand = np.full((4, 2), 0.5)
    with pytest.raises(ValueError, match="indexed"):
        sim.run(CarbonAgnosticPolicy(), demand, np.full(4, 100.0),
                targets=0.0, traffic=(spec, np.zeros((4, 2))))
