"""Causal forecasters (`repro.carbon.forecast`).

Causality is the load-bearing property — the elasticity ablation is
meaningless if a forecaster peeks at the epoch it predicts — so every
estimator is tested by perturbing the future and asserting the past
predictions don't move.
"""
import numpy as np
import pytest

from repro.carbon.forecast import (ar1_mean, diurnal_ar1, forecast_series,
                                   persistence, window_mean_forecast)
from repro.carbon.traces import synth_trace

MODES = ["persistence", "ar1_mean", "diurnal_ar1"]


@pytest.mark.parametrize("mode", MODES)
def test_causality_future_perturbation_invariant(mode):
    rng = np.random.default_rng(0)
    x = np.abs(rng.normal(5.0, 2.0, (96, 3)))
    y = x.copy()
    y[60:] *= 17.0                       # rewrite the future
    a = forecast_series(x, mode, period_steps=24)
    b = forecast_series(y, mode, period_steps=24)
    # prediction at t reads x[0..t-1] only -> t <= 60 identical
    np.testing.assert_array_equal(a[:61], b[:61])
    assert np.any(a[61:] != b[61:])


def test_shapes_and_first_step():
    x1 = np.arange(10.0)
    x2 = np.arange(20.0).reshape(10, 2)
    for mode in ["oracle"] + MODES:
        a = forecast_series(x1, mode, period_steps=4)
        b = forecast_series(x2, mode, period_steps=4)
        assert a.shape == x1.shape and b.shape == x2.shape
        # epoch 0 uses the epoch-start reading
        assert a[0] == x1[0]
        np.testing.assert_array_equal(b[0], x2[0])


def test_persistence_is_shift():
    x = np.array([3.0, 1.0, 4.0, 1.0, 5.0])
    np.testing.assert_array_equal(persistence(x),
                                  np.array([3.0, 3.0, 1.0, 4.0, 1.0]))


def test_predictions_clamped_nonnegative():
    x = np.array([5.0, 0.0, 0.0, 0.0, 10.0, 0.0])
    for mode in MODES:
        assert forecast_series(x, mode, period_steps=3).min() >= 0.0


def test_ar1_mean_matches_online_definition():
    rng = np.random.default_rng(1)
    x = np.abs(rng.normal(3.0, 1.0, 40))
    out = ar1_mean(x, rho=0.7)
    for t in range(1, 40):
        mu = x[:t].mean()
        assert out[t] == pytest.approx(max(0.0, mu + 0.7 * (x[t - 1] - mu)),
                                       abs=1e-12)


def test_diurnal_beats_persistence_on_synth_trace():
    # hourly synth trace: known diurnal + AR(1, rho=0.9) structure.
    # After a warmup cycle the diurnal estimator must dominate.
    x = synth_trace("PL", hours=24 * 10, seed=3)

    def mae(mode):
        f = forecast_series(x, mode, period_steps=24, rho=0.9)
        return np.abs(f[24:] - x[24:]).mean()

    assert mae("diurnal_ar1") < mae("ar1_mean") < mae("persistence")


def test_diurnal_ar1_rejects_bad_period():
    with pytest.raises(ValueError):
        diurnal_ar1(np.arange(5.0), period_steps=0)


def test_unknown_mode_raises():
    with pytest.raises(ValueError):
        forecast_series(np.arange(4.0), "magic")


@pytest.mark.parametrize("mode", MODES)
def test_window_mean_causality(mode):
    rng = np.random.default_rng(4)
    x = np.abs(rng.normal(5.0, 2.0, 96))
    y = x.copy()
    y[60:] *= 17.0
    a = window_mean_forecast(x, mode, period_steps=24)
    b = window_mean_forecast(y, mode, period_steps=24)
    np.testing.assert_array_equal(a[:61], b[:61])


def test_window_mean_oracle_and_persistence():
    x = np.abs(300.0 + 100.0 * np.sin(2 * np.pi * np.arange(72) / 24.0))
    o = window_mean_forecast(x, "oracle", period_steps=24)
    # true forward-window mean, truncated at the end
    assert o[10] == pytest.approx(x[10:34].mean(), abs=1e-12)
    assert o[60] == pytest.approx(x[60:].mean(), abs=1e-12)
    # persistence believes the signal is flat: window mean == nowcast
    np.testing.assert_array_equal(window_mean_forecast(x, "persistence",
                                                       period_steps=24),
                                  persistence(x))


def test_window_mean_diurnal_learns_day_mean():
    x = synth_trace("NL", hours=24 * 8, seed=5)
    w = window_mean_forecast(x, "diurnal_ar1", period_steps=24)
    p = window_mean_forecast(x, "persistence", period_steps=24)
    truth = window_mean_forecast(x, "oracle", period_steps=24)
    # after a learned cycle the diurnal day-mean beats the flat belief
    sl = slice(24, -24)
    assert np.abs(w[sl] - truth[sl]).mean() \
        < np.abs(p[sl] - truth[sl]).mean()


def test_window_mean_rejects_bad_input():
    with pytest.raises(ValueError):
        window_mean_forecast(np.zeros((5, 2)), "oracle")
    with pytest.raises(ValueError):
        window_mean_forecast(np.arange(5.0), "oracle", period_steps=0)
    with pytest.raises(ValueError):
        window_mean_forecast(np.arange(5.0), "magic")
