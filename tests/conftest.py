"""Shared fixtures. NOTE: no XLA_FLAGS here — tests must see the real
device count (1 CPU); only dryrun.py forces 512 host devices."""
import jax
import pytest


@pytest.fixture(scope="session")
def rng_key():
    return jax.random.PRNGKey(0)
