"""JAX fleet backend: parity against the NumPy fleet backend.

The chain is anchored in two hops: the NumPy fleet backend is pinned
bit-compatible (1e-9) to the scalar loop by tests/test_fleet.py, and the
JAX backend is pinned here to 1e-6 against the NumPy backend (the jit
path reassociates loop-invariant scalings, so it is not bit-identical —
observed drift is ~1e-10). Discrete outcomes (migration counts) must
match exactly: a single flipped decision would diverge the whole
trajectory.

The fleets under test bake in the edge cases the closed-form suite also
covers: one zero-demand column and one budget-exhausted (tiny-target)
column ride along in every parity run.
"""
import numpy as np
import pytest

jax = pytest.importorskip("jax")

from repro.carbon.intensity import ConstantProvider, TraceProvider  # noqa: E402
from repro.cluster.placement import PlacementConfig, PlacementEngine  # noqa: E402
from repro.cluster.slices import paper_family, tpu_v5e_family  # noqa: E402
from repro.core.fleet import FleetSimulator  # noqa: E402
from repro.core import fleet_jax  # noqa: E402
from repro.core.fleet_jax import FleetSimulatorJax  # noqa: E402
from repro.core.policy import (CarbonAgnosticPolicy, CarbonContainerPolicy,  # noqa: E402
                               SuspendResumePolicy, VScaleOnlyPolicy)
from repro.core.simulator import SimConfig, sweep_population  # noqa: E402
from repro.workload.azure_like import sample_population  # noqa: E402

TOL = 1e-6
DAYS = 1

POLICIES = {
    "carbon_agnostic": CarbonAgnosticPolicy,
    "suspend_resume": SuspendResumePolicy,
    "vscale_only": lambda: VScaleOnlyPolicy(),
    "cc_energy": lambda: CarbonContainerPolicy("energy"),
    "cc_performance": lambda: CarbonContainerPolicy("performance"),
}

PARITY_FIELDS = ("emissions_g", "energy_wh", "work_done", "work_demanded",
                 "throttled_integral", "suspended_s", "elapsed_s")


def _fleet_inputs(n=6, days=DAYS, seed=2):
    """Heterogeneous fleet with the edge columns baked in: column 0 has
    zero demand everywhere, column 1 runs with a budget-exhausting tiny
    target."""
    traces = [t.util for t in sample_population(n, days=days, seed=seed)]
    demand = np.stack(traces, axis=1)
    demand[:, 0] = 0.0                          # zero-demand edge case
    targets = np.linspace(10.0, 80.0, n)
    targets[1] = 1e-6                           # budget exhaustion edge case
    sgb = (np.arange(n) % 4 + 1) * 0.5
    carbon = TraceProvider.for_region("CAISO", hours=24 * days, seed=1)
    return demand, targets, sgb, carbon


def _assert_close(rf, rj, ctx=""):
    for f in PARITY_FIELDS:
        diff = float(np.abs(getattr(rf, f) - getattr(rj, f)).max())
        assert diff <= TOL, f"{ctx}: {f} drifts {diff}"
    assert (rf.migrations == rj.migrations).all(), ctx
    assert float(np.abs(rf.time_on_slice_s - rj.time_on_slice_s).max()) \
        <= TOL, ctx
    assert rf.slice_names == rj.slice_names


@pytest.mark.parametrize("policy_name", sorted(POLICIES))
def test_jax_matches_fleet(policy_name):
    mk = POLICIES[policy_name]
    fam = paper_family()
    demand, targets, sgb, carbon = _fleet_inputs()
    rf = FleetSimulator(fam).run(mk(), demand, carbon, targets,
                                 state_gb=sgb)
    rj = FleetSimulatorJax(fam).run(mk(), demand, carbon, targets,
                                    state_gb=sgb)
    _assert_close(rf, rj, ctx=policy_name)


def test_jax_matches_fleet_hold_slice_and_mixed_regions():
    """suspend_releases_slice=False + a (T, N) per-container carbon
    matrix (mixed-region fleet) + TPU family in one run."""
    fam = tpu_v5e_family()
    demand, targets, sgb, _ = _fleet_inputs(n=4)
    T = demand.shape[0]
    tvec = np.arange(T) * 300.0
    provs = [TraceProvider.for_region(r, hours=24 * DAYS, seed=1)
             for r in ("PL", "NL", "CAISO")]
    cmat = np.stack([provs[i % 3].intensity_series(tvec)
                     for i in range(4)], axis=1)
    targets = targets * 40.0                    # TPU-scale targets
    mk = lambda: CarbonContainerPolicy("energy")
    rf = FleetSimulator(fam, suspend_releases_slice=False).run(
        mk(), demand, cmat, targets, state_gb=sgb)
    rj = FleetSimulatorJax(fam, suspend_releases_slice=False).run(
        mk(), demand, cmat, targets, state_gb=sgb)
    _assert_close(rf, rj, ctx="hold-slice mixed-region tpu")


def test_jax_record_series_matches_and_conserves():
    fam = paper_family()
    demand, targets, sgb, carbon = _fleet_inputs(n=4)
    mk = lambda: CarbonContainerPolicy("energy")
    rf = FleetSimulator(fam).run(mk(), demand, carbon, targets,
                                 state_gb=sgb, record=True)
    rj = FleetSimulatorJax(fam).run(mk(), demand, carbon, targets,
                                    state_gb=sgb, record=True)
    assert rj.power_series.shape == rf.power_series.shape
    assert float(np.abs(rf.power_series - rj.power_series).max()) <= TOL
    assert float(np.abs(rf.served_series - rj.served_series).max()) <= TOL
    # conservation on the jax side
    assert (rj.served_series >= 0.0).all()
    assert (rj.power_series >= 0.0).all()
    assert np.allclose(rj.work_done + rj.throttled_integral,
                       rj.work_demanded, rtol=1e-9, atol=1e-6)


def test_sweep_population_jax_matches_fleet():
    fam = paper_family()
    traces = [t.util for t in sample_population(4, days=DAYS, seed=2)]
    carbon = TraceProvider.for_region("CAISO", hours=24 * DAYS, seed=1)
    pols = {"carbon_agnostic": CarbonAgnosticPolicy,
            "suspend_resume": SuspendResumePolicy,
            "carbon_containers": lambda: CarbonContainerPolicy("energy")}
    targets = [25.0, 55.0]
    cfgb = SimConfig(target_rate=0.0)
    rows_f = sweep_population(pols, fam, traces, carbon, targets, cfgb,
                              backend="fleet")
    rows_j = sweep_population(pols, fam, traces, carbon, targets, cfgb,
                              backend="jax")
    assert len(rows_f) == len(rows_j)
    for a, b in zip(rows_f, rows_j):
        assert a["policy"] == b["policy"] and a["target"] == b["target"]
        for k in ("carbon_rate_mean", "carbon_rate_std", "throttle_mean",
                  "throttle_std", "migrations_mean", "suspended_frac_mean"):
            assert abs(a[k] - b[k]) <= TOL, (a["policy"], a["target"], k)
        for k in set(a["time_on_slice"]) | set(b["time_on_slice"]):
            assert abs(a["time_on_slice"].get(k, 0.0)
                       - b["time_on_slice"].get(k, 0.0)) <= TOL


def test_sweep_population_jax_with_placement_matches_fleet():
    fam = paper_family()
    traces = [t.util for t in sample_population(4, days=DAYS, seed=5)]
    provs = [TraceProvider.for_region(r, hours=24 * DAYS, seed=1)
             for r in ("PL", "NL", "CAISO")]
    eng = PlacementEngine(fam, provs,
                          config=PlacementConfig(capacity=3, min_dwell=4))
    pols = {"carbon_containers": lambda: CarbonContainerPolicy("energy")}
    cfgb = SimConfig(target_rate=0.0)
    rows_f = sweep_population(pols, fam, traces, None, [30.0, 60.0], cfgb,
                              backend="fleet", placement=eng)
    rows_j = sweep_population(pols, fam, traces, None, [30.0, 60.0], cfgb,
                              backend="jax", placement=eng)
    for a, b in zip(rows_f, rows_j):
        for k in ("carbon_rate_mean", "throttle_mean", "migrations_mean",
                  "placement_migrations_mean", "placement_overhead_g_mean"):
            assert abs(a[k] - b[k]) <= TOL, k


def test_jax_rejects_custom_policy():
    class Custom(CarbonContainerPolicy):
        pass

    fam = paper_family()
    with pytest.raises(TypeError):
        FleetSimulatorJax(fam).run(Custom(), np.ones((4, 2)),
                                   ConstantProvider(100.0), 45.0)


def test_jax_rejects_negative_demand_and_bad_carbon():
    fam = paper_family()
    with pytest.raises(ValueError):
        FleetSimulatorJax(fam).run(CarbonAgnosticPolicy(),
                                   np.array([[0.5], [-0.1]]),
                                   ConstantProvider(100.0), 45.0)
    with pytest.raises(ValueError):
        FleetSimulatorJax(fam).run(CarbonAgnosticPolicy(), np.ones((4, 2)),
                                   np.ones((3, 2)), 45.0)


@pytest.mark.skipif(not fleet_jax.HAS_JAX or len(jax.devices()) < 2,
                    reason="needs >= 2 XLA host devices "
                           "(XLA_FLAGS=--xla_force_host_platform_"
                           "device_count=2)")
def test_jax_sharded_matches_unsharded(monkeypatch):
    """Container-axis sharding concatenates bit-identically."""
    fam = paper_family()
    demand, targets, sgb, carbon = _fleet_inputs(n=6)
    mk = lambda: CarbonContainerPolicy("energy")
    r1 = FleetSimulatorJax(fam).run(mk(), demand, carbon, targets,
                                    state_gb=sgb)
    monkeypatch.setattr(fleet_jax, "_MIN_SHARD_COLS", 2)
    r2 = FleetSimulatorJax(fam).run(mk(), demand, carbon, targets,
                                    state_gb=sgb)
    for f in PARITY_FIELDS:
        assert (getattr(r1, f) == getattr(r2, f)).all(), f
    assert (r1.migrations == r2.migrations).all()
    assert (r1.time_on_slice_s == r2.time_on_slice_s).all()
