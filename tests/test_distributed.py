"""Fault tolerance + straggler mitigation: detection, recovery, delays.

All timing is driven through the injectable clock / pinned seeds — no
sleeps anywhere.
"""
import numpy as np
import pytest

from repro.distributed.fault import (FailureInjector, HeartbeatMonitor,
                                     run_with_recovery)
from repro.distributed.stragglers import StragglerDetector


# ---------------------------------------------------------------------------
# HeartbeatMonitor (clock injection)
# ---------------------------------------------------------------------------

def test_heartbeat_detects_silence_with_injected_clock():
    now = [0.0]
    mon = HeartbeatMonitor(timeout_s=30.0, clock=lambda: now[0])
    mon.beat("a")
    mon.beat("b")
    now[0] = 25.0
    mon.beat("b")
    assert mon.dead_hosts() == []          # a is 25s silent: within timeout
    now[0] = 31.0
    assert mon.dead_hosts() == ["a"]       # past timeout
    now[0] = 56.0
    assert sorted(mon.dead_hosts()) == ["a", "b"]
    mon.beat("a")
    assert mon.dead_hosts() == ["b"]       # a recovered


def test_heartbeat_explicit_times_override_clock():
    mon = HeartbeatMonitor(timeout_s=10.0,
                           clock=lambda: pytest.fail("clock consulted"))
    mon.beat("h", t=100.0)
    assert mon.dead_hosts(now=105.0) == []
    assert mon.dead_hosts(now=111.0) == ["h"]


def test_heartbeat_boundary_is_exclusive():
    now = [0.0]
    mon = HeartbeatMonitor(timeout_s=30.0, clock=lambda: now[0])
    mon.beat("h")
    now[0] = 30.0
    assert mon.dead_hosts() == []          # silent for exactly timeout: alive
    now[0] = 30.0 + 1e-9
    assert mon.dead_hosts() == ["h"]


def test_heartbeat_default_clock_is_monotonic():
    mon = HeartbeatMonitor(timeout_s=1e6)
    mon.beat("h")
    assert mon.dead_hosts() == []


# ---------------------------------------------------------------------------
# FailureInjector + checkpoint-restore recovery
# ---------------------------------------------------------------------------

def test_failure_injector_fires_once():
    inj = FailureInjector(schedule={5: 2})
    assert inj.check(4) == 0
    assert inj.check(5) == 2
    assert inj.check(5) == 0               # one-shot: replay must not re-fire


class _Job:
    """Minimal checkpointed trainer for the recovery loop."""

    def __init__(self):
        self.step_idx = 0
        self.ckpt_step = 0
        self.devices = None
        self.losses = []

    def train_step(self, batch):
        self.losses.append(batch)
        self.step_idx += 1

    def checkpoint(self):
        self.ckpt_step = self.step_idx

    def recover_after_failure(self, survivors):
        self.devices = list(survivors)
        # restore: roll back to the last checkpoint and replay from there
        self.step_idx = self.ckpt_step
        del self.losses[self.ckpt_step:]
        return {"resumed_at": self.step_idx, "devices": len(survivors)}


def test_run_with_recovery_restores_from_checkpoint():
    job = _Job()
    inj = FailureInjector(schedule={25: 3})
    out = run_with_recovery(job, iter(range(10_000)), n_steps=40,
                            devices=list(range(8)), injector=inj,
                            checkpoint_every=10)
    assert out["final_step"] == 40
    assert len(out["recoveries"]) == 1
    rec = out["recoveries"][0]
    assert rec["at_step"] == 25
    assert rec["resumed"]["resumed_at"] == 20     # last checkpoint
    # 8 devices, 3 lost -> 5 survivors -> power-of-two shrink to 4
    assert out["devices_left"] == 4
    assert job.step_idx == 40
    # replayed steps land exactly once in the restored history
    assert len(job.losses) == 40


def test_run_with_recovery_insufficient_survivors_aborts_gracefully():
    job = _Job()
    inj = FailureInjector(schedule={3: 7})
    out = run_with_recovery(job, iter(range(100)), n_steps=10,
                            devices=list(range(8)), injector=inj,
                            checkpoint_every=2, min_devices=2)
    # partial results, not an exception: the completed work survives
    assert out["aborted"] and "insufficient survivors" in out["abort_reason"]
    assert out["final_step"] == 3             # where the job actually stopped
    assert len(job.losses) == 3               # steps completed before abort


def test_run_with_recovery_no_failures():
    job = _Job()
    out = run_with_recovery(job, iter(range(100)), n_steps=12,
                            devices=list(range(4)), injector=None,
                            checkpoint_every=5)
    assert out == {"recoveries": [], "final_step": 12, "devices_left": 4,
                   "aborted": False}


def test_run_with_recovery_max_retries_exhaustion():
    """A persistent failure at one step aborts after max_retries
    consecutive recoveries, returning the partial results, with capped
    exponential backoff between retries (recorded, not slept)."""
    job = _Job()
    inj = FailureInjector(schedule={25: 2}, persistent=True)
    sleeps = []
    out = run_with_recovery(job, iter(range(10_000)), n_steps=40,
                            devices=list(range(16)), injector=inj,
                            checkpoint_every=10, max_retries=3,
                            backoff_base_s=1.0, backoff_cap_s=3.0,
                            sleep_fn=sleeps.append)
    assert out["aborted"] and "max_retries=3 exhausted" in out["abort_reason"]
    assert len(out["recoveries"]) == 3        # the allowed retries all ran
    assert out["final_step"] == 25            # parked at the failing step
    # capped exponential: 2nd retry 1s, 3rd 2s (4th would cap at 3s)
    assert sleeps == [1.0, 2.0]


def test_run_with_recovery_transient_failures_reset_retry_budget():
    """Distinct failing steps are separate incidents: each one-shot
    failure recovers and the run completes without tripping max_retries."""
    job = _Job()
    inj = FailureInjector(schedule={15: 1, 25: 1, 35: 1})
    out = run_with_recovery(job, iter(range(10_000)), n_steps=40,
                            devices=list(range(16)), injector=inj,
                            checkpoint_every=10, max_retries=1)
    assert not out["aborted"]
    assert out["final_step"] == 40
    assert len(out["recoveries"]) == 3
    assert len(job.losses) == 40


def test_failure_injector_persistent_refires():
    inj = FailureInjector(schedule={5: 2}, persistent=True)
    assert inj.check(5) == 2
    assert inj.check(5) == 2                  # re-arms on replay


# ---------------------------------------------------------------------------
# StragglerDetector (thresholds, patience, pinned-seed delays)
# ---------------------------------------------------------------------------

def test_straggler_detector_needs_warmup():
    det = StragglerDetector(window=32)
    for _ in range(7):
        assert det.observe(100.0) is None  # < max(8, window // 4) samples


def test_straggler_detector_threshold_and_patience():
    det = StragglerDetector(window=32, threshold=1.8, patience=4)
    for _ in range(10):
        assert det.observe(1.0) is None
    # 3 slow steps: flagged but below patience
    for _ in range(3):
        assert det.observe(2.0) is None
    # a healthy step resets the flag counter
    assert det.observe(1.0) is None
    acts = [det.observe(2.0) for _ in range(4)]
    assert acts[:3] == [None, None, None] and acts[3] == "migrate"
    # the action resets: the next slow step starts a fresh patience run
    assert det.observe(2.0) is None


def test_straggler_slowdown_ratio():
    det = StragglerDetector()
    for _ in range(9):
        det.observe(1.0)
    det.observe(2.5)
    assert det.slowdown() == pytest.approx(2.5)


def test_straggler_detection_delay_distribution_pinned_seed():
    """With noisy healthy steps (pinned seed), a 2.6x straggler is always
    caught, always after exactly `patience` slow steps (the threshold has
    margin over the noise), never before onset."""
    rng = np.random.default_rng(42)
    delays = []
    for _ in range(50):
        det = StragglerDetector(window=32, threshold=1.8, patience=4)
        base = np.clip(rng.normal(1.0, 0.05, size=200), 0.8, 1.2)
        onset = 60
        fired = None
        for t in range(200):
            s = base[t] * (2.6 if t >= onset else 1.0)
            if det.observe(s) == "migrate":
                fired = t
                break
        assert fired is not None and fired >= onset
        delays.append(fired - onset)
    # patience=4 consecutive flags -> detection on the 4th slow step
    assert set(delays) == {3}


def test_straggler_no_false_positives_on_noise():
    rng = np.random.default_rng(7)
    det = StragglerDetector(window=32, threshold=1.8, patience=4)
    for s in np.clip(rng.normal(1.0, 0.08, size=500), 0.7, 1.4):
        assert det.observe(float(s)) is None
