"""MoE shard_map-vs-local equivalence, the carbon-aware trainer loop, and
the serve scheduler's carbon coupling."""

import dataclasses
import tempfile

import numpy as np
import pytest

import jax

pytestmark = pytest.mark.slow  # JAX model/kernel suite: excluded from the fast lane


def test_moe_mesh_equals_local_when_no_drops():
    """With generous capacity both paths route identically -> same output."""
    from repro.configs import get_arch
    from repro.models import get_model

    cfg = dataclasses.replace(get_arch("olmoe-1b-7b").smoke,
                              capacity_factor=8.0)
    m = get_model(cfg)
    key = jax.random.PRNGKey(0)
    params = m.init(key)
    B, S = 4, 16
    batch = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size),
             "labels": jax.random.randint(key, (B, S), 0, cfg.vocab_size)}
    loss_local, _ = jax.jit(lambda p, b: m.loss(p, b))(params, batch)

    n = len(jax.devices())
    if n < 2:
        pytest.skip("needs >=2 devices for a mesh path")
    mesh = jax.make_mesh((1, min(n, cfg.n_experts)), ("data", "model"))
    sharded = jax.tree.map(jax.device_put, params, m.shardings(mesh))
    with mesh:
        loss_mesh, _ = jax.jit(lambda p, b: m.loss(p, b))(sharded, batch)
    np.testing.assert_allclose(float(loss_local), float(loss_mesh),
                               rtol=2e-2, atol=2e-2)


def test_moe_router_load_balance_loss_bounds():
    from repro.configs import get_arch
    from repro.models import get_model

    cfg = get_arch("dbrx-132b").smoke
    m = get_model(cfg)
    key = jax.random.PRNGKey(1)
    params = m.init(key)
    batch = {"tokens": jax.random.randint(key, (4, 32), 0, cfg.vocab_size),
             "labels": jax.random.randint(key, (4, 32), 0, cfg.vocab_size)}
    _, metrics = jax.jit(lambda p, b: m.loss(p, b))(params, batch)
    lb = float(metrics["lb_loss"])
    # Switch-style lb loss is ~n_layers at uniform routing, >= per layer 1.0
    assert cfg.n_layers * 0.5 < lb < cfg.n_layers * 4.0


def test_carbon_aware_trainer_enforces_and_migrates():
    """Run the live trainer with a virtual clock; the enforced carbon rate
    must respect the target and at least one enforcement action must fire."""
    from repro.carbon.intensity import TraceProvider
    from repro.cluster.slices import Slice, SliceFamily
    from repro.config import CarbonConfig, OptimizerConfig, TrainConfig
    from repro.configs import get_arch
    from repro.core.carbon_aware_trainer import CarbonAwareTrainer
    from repro.core.elastic import ElasticJob
    from repro.data.pipeline import SyntheticLM
    from repro.models import get_model
    from repro.power.model import LinearPowerModel

    cfg = get_arch("smollm-135m").smoke
    model = get_model(cfg)
    tcfg = TrainConfig(seq_len=16, global_batch=4,
                       optimizer=OptimizerConfig(warmup_steps=1, total_steps=100))
    devs = jax.devices()
    slices = [Slice("s1", 0.5, LinearPowerModel(30.0, 80.0), chips=1),
              Slice("s2", 1.0, LinearPowerModel(60.0, 160.0), chips=1)]
    fam = SliceFamily(slices, baseline_idx=1)
    with tempfile.TemporaryDirectory() as d:
        job = ElasticJob(model, tcfg, d)
        job.start(devs[:1])
        step_flops = 6.0 * model.param_count() * 16 * 4
        trainer = CarbonAwareTrainer(
            job=job, family=fam, slice_devices=[devs[:1], devs[:1]],
            carbon=TraceProvider([400.0] * 48),
            cfg=CarbonConfig(target_rate=40.0, interval_s=300.0),
            step_flops=step_flops, step_tokens=64,
            peak_flops_per_chip=step_flops / 120.0,
            sim_seconds_per_step=150.0)
        out = trainer.run(iter(SyntheticLM(cfg.vocab_size, 16, 4)), 30)
    assert out["steps"] == 30
    rates = [l.carbon_rate for l in out["logs"]]
    # enforced: the average rate respects the target (first interval may peak)
    assert sum(rates) / len(rates) <= 40.0 * 1.1
    assert any(l.action in ("migrate", "stay") and l.duty < 1.0
               for l in out["logs"]) or any(
        l.slice_name == "s1" for l in out["logs"])


def test_replay_harness_tracks_target():
    from repro.workload.replay import ReplayHarness

    h = ReplayHarness()
    res = h.replay([0.4] * 24, lambda u: u + np.random.default_rng(0).normal(0, 0.01))
    assert res["ma_max_err"] < 0.01   # paper Fig 9: within 1% on the MA


def test_elastic_mesh_over_shapes():
    from repro.core.elastic import mesh_over

    devs = jax.devices()
    m = mesh_over(devs[:1])
    assert dict(m.shape) == {"data": 1, "model": 1}
