"""Large-N smoke lane for the placed sweep (slow).

The fast-lane parity tests pin the admission semantics at N~20; this
lane re-checks them where the scale hardening actually matters — a
50k-container capacity-planned fleet — and then pushes the same fleet
through the memory-lean jax sweep end-to-end. Admission invariants:

  - occupancy never exceeds the configured per-region capacity, and
  - the jax planner's per-epoch admission counts (occupancy) match the
    NumPy planner's *exactly* — a single divergent admission would
    cascade through dwell and capacity state for the rest of the plan.
"""
import numpy as np
import pytest

pytest.importorskip("jax")

from repro.carbon.intensity import TraceProvider  # noqa: E402
from repro.cluster.placement import PlacementConfig, PlacementEngine  # noqa: E402
from repro.cluster.placement_jax import plan_jax  # noqa: E402
from repro.cluster.slices import paper_family  # noqa: E402
from repro.core.policy import CarbonContainerPolicy  # noqa: E402
from repro.core.simulator import SimConfig, sweep_population  # noqa: E402
from repro.workload.azure_like import sample_population_matrix  # noqa: E402

N_TRACES = 50_000
REGIONS = ("PL", "NL", "CAISO")


@pytest.fixture(scope="module")
def placed_50k():
    provs = [TraceProvider.for_region(r, hours=24, seed=1)
             for r in REGIONS]
    demand = sample_population_matrix(N_TRACES, days=1, seed=4)
    cap = int(np.ceil(0.6 * N_TRACES))
    eng = PlacementEngine(
        paper_family(), provs, region_names=REGIONS,
        config=PlacementConfig(capacity=cap, min_dwell=6, hysteresis=0.10))
    return eng, demand, cap


@pytest.mark.slow
def test_admission_counts_match_numpy_at_50k(placed_50k):
    eng, demand, cap = placed_50k
    p_np = eng.plan(demand, state_gb=1.0)
    p_j = plan_jax(eng, demand, state_gb=1.0)
    occ_np, occ_j = p_np.occupancy(), p_j.occupancy()
    assert (occ_j <= cap).all(), "admission exceeded capacity"
    assert np.array_equal(occ_np, occ_j), \
        "jax admission counts diverge from NumPy"
    # the full assignment matrix too — occupancy equality alone could
    # mask swapped containers
    assert np.array_equal(p_np.assign, p_j.assign)
    # a 50k fleet under 60% capacity must actually migrate
    assert int(p_j.migrations.sum()) > 0


@pytest.mark.slow
def test_placed_sweep_runs_memory_lean_at_50k(placed_50k):
    """The compact indexed-carbon sweep completes at N=50k and emits
    finite aggregates for every (policy, target) row."""
    eng, demand, _ = placed_50k
    cfg = SimConfig(target_rate=0.0)
    rows = sweep_population(
        {"cc": lambda: CarbonContainerPolicy(variant="energy")},
        paper_family(), demand, None, [30.0, 60.0], cfg,
        backend="jax", placement=eng)
    assert len(rows) == 2
    for r in rows:
        assert np.isfinite(r["carbon_rate_mean"])
        assert np.isfinite(r["throttle_mean"])
        assert r["placement_migrations_mean"] >= 0.0
