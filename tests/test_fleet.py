"""Vectorized fleet simulator: scalar-parity and conservation invariants.

The parity tests are what make the fleet refactor safe: an N=1
`FleetSimulator` run must reproduce `simulate()`'s SimResult fields to
1e-9 (in practice bit-for-bit) for every policy across (target, epsilon,
state_gb, suspend_releases_slice) combos, and an N-container batch must
equal N independent scalar runs. Conservation invariants then pin the
physics of both backends.
"""
import numpy as np
import pytest

from repro.carbon.intensity import ConstantProvider, TraceProvider
from repro.cluster.slices import paper_family, tpu_v5e_family
from repro.core.fleet import BlockPolicy, FleetSimulator
from repro.core.policy import (CarbonAgnosticPolicy, CarbonContainerPolicy,
                               SuspendResumePolicy, VScaleOnlyPolicy)
from repro.core.simulator import SimConfig, simulate, sweep_population
from repro.workload.azure_like import sample_population

PARITY_FIELDS = ("emissions_g", "work_done", "migrations", "suspended_frac",
                 "avg_throttle_pct", "avg_carbon_rate", "energy_kwh",
                 "work_demanded", "hours")

POLICIES = {
    "carbon_agnostic": CarbonAgnosticPolicy,
    "suspend_resume": SuspendResumePolicy,
    "vscale_only": lambda: VScaleOnlyPolicy(),
    "cc_energy": lambda: CarbonContainerPolicy("energy"),
    "cc_performance": lambda: CarbonContainerPolicy("performance"),
}

# (target g/hr, epsilon, state_gb, suspend_releases_slice)
COMBOS = [
    (10.0, 0.05, 1.0, True),     # floor-bound: forces suspends
    (45.0, 0.05, 0.5, True),     # paper's mid target
    (45.0, 0.10, 2.0, False),    # suspended slice stays powered
    (80.0, 0.00, 0.25, True),    # loose target, eps off, fast migrations
]


def _traces(n, days=3, seed=2):
    return [t.util for t in sample_population(n, days=days, seed=seed)]


def _carbon(days=3):
    return TraceProvider.for_region("CAISO", hours=24 * days, seed=1)


def _assert_result_close(rs, rf, tol=1e-9, ctx=""):
    for f in PARITY_FIELDS:
        a, b = getattr(rs, f), getattr(rf, f)
        assert abs(a - b) <= tol, f"{ctx}: {f} scalar={a!r} fleet={b!r}"
    keys = set(rs.time_on_slice) | set(rf.time_on_slice)
    for k in keys:
        a = rs.time_on_slice.get(k, 0.0)
        b = rf.time_on_slice.get(k, 0.0)
        assert abs(a - b) <= tol, f"{ctx}: time_on_slice[{k}] {a} vs {b}"


# ---------------------------------------------------------------------------
# N=1 parity: every policy x every config combo
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("policy_name", sorted(POLICIES))
@pytest.mark.parametrize("combo", COMBOS, ids=lambda c: f"t{c[0]:g}-e{c[1]:g}"
                         f"-g{c[2]:g}-{'rel' if c[3] else 'hold'}")
def test_fleet_n1_matches_scalar(policy_name, combo):
    target, eps, sgb, srs = combo
    mk = POLICIES[policy_name]
    fam = paper_family()
    carbon = _carbon()
    for ti, tr in enumerate(_traces(2)):
        cfg = SimConfig(target_rate=target, epsilon=eps, state_gb=sgb,
                        suspend_releases_slice=srs)
        rs = simulate(mk(), fam, tr, carbon, cfg)
        sim = FleetSimulator(fam, suspend_releases_slice=srs)
        rf = sim.run(mk(), np.asarray(tr)[:, None], carbon, target,
                     epsilon=eps, state_gb=sgb).result(0)
        _assert_result_close(rs, rf, ctx=f"{policy_name} {combo} trace{ti}")


def test_fleet_n1_constant_carbon_and_tpu_family():
    fam = tpu_v5e_family()
    tr = np.asarray(_traces(1, days=1)[0])
    for c in (50.0, 400.0, 800.0):
        cfg = SimConfig(target_rate=2000.0, state_gb=8.0)
        rs = simulate(CarbonContainerPolicy("energy"), fam, tr,
                      ConstantProvider(c), cfg)
        rf = FleetSimulator(fam).run(CarbonContainerPolicy("energy"),
                                     tr[:, None], ConstantProvider(c),
                                     2000.0, state_gb=8.0).result(0)
        _assert_result_close(rs, rf, ctx=f"tpu c={c}")


# ---------------------------------------------------------------------------
# Batch parity: N heterogeneous containers == N independent scalar runs
# ---------------------------------------------------------------------------

def test_fleet_batch_equals_independent_scalar_runs():
    fam = paper_family()
    days = 3
    traces = _traces(6, days=days)
    T = len(traces[0])
    regions = ["CAISO", "NL", "PL"]
    provs = [TraceProvider.for_region(r, hours=24 * days, seed=1)
             for r in regions]
    tvec = np.arange(T) * 300.0
    n = len(traces)
    cmat = np.stack([provs[i % 3].intensity_series(tvec) for i in range(n)],
                    axis=1)
    targets = np.array([15.0, 30.0, 45.0, 60.0, 80.0, 120.0])
    sgb = np.array([0.25, 0.5, 1.0, 2.0, 1.0, 0.5])
    dscale = np.array([1.0, 0.5, 2.0, 1.0, 1.5, 0.8])
    demand = np.stack(traces, axis=1)

    for name, mk in POLICIES.items():
        rf = FleetSimulator(fam).run(mk(), demand, cmat, targets,
                                     state_gb=sgb, demand_scale=dscale)
        for i in range(n):
            cfg = SimConfig(target_rate=float(targets[i]),
                            state_gb=float(sgb[i]))
            rs = simulate(mk(), fam, traces[i], provs[i % 3], cfg,
                          demand_scale=float(dscale[i]))
            _assert_result_close(rs, rf.result(i), ctx=f"{name} col{i}")


def test_block_policy_mixes_policies_without_interaction():
    fam = paper_family()
    traces = _traces(2)
    demand = np.concatenate([np.stack(traces, axis=1)] * 2, axis=1)
    carbon = _carbon()
    blocks = [(CarbonContainerPolicy("energy"), slice(0, 2)),
              (CarbonContainerPolicy("performance"), slice(2, 4))]
    rf = FleetSimulator(fam).run(BlockPolicy(blocks), demand, carbon, 45.0)
    for i, (mk, tr) in enumerate([("energy", traces[0]), ("energy", traces[1]),
                                  ("performance", traces[0]),
                                  ("performance", traces[1])]):
        rs = simulate(CarbonContainerPolicy(mk), fam, tr, carbon,
                      SimConfig(target_rate=45.0))
        _assert_result_close(rs, rf.result(i), ctx=f"block {mk} col{i}")


def test_sweep_population_backends_agree():
    fam = paper_family()
    traces = _traces(4, days=2)
    carbon = _carbon(days=2)
    pols = {"carbon_agnostic": CarbonAgnosticPolicy,
            "suspend_resume": SuspendResumePolicy,
            "carbon_containers": lambda: CarbonContainerPolicy("energy")}
    targets = [25.0, 55.0]
    cfgb = SimConfig(target_rate=0.0)
    rows_s = sweep_population(pols, fam, traces, carbon, targets, cfgb)
    rows_f = sweep_population(pols, fam, traces, carbon, targets, cfgb,
                              backend="fleet")
    assert len(rows_s) == len(rows_f)
    for a, b in zip(rows_s, rows_f):
        assert a["policy"] == b["policy"] and a["target"] == b["target"]
        for k in ("carbon_rate_mean", "carbon_rate_std", "throttle_mean",
                  "throttle_std", "migrations_mean", "suspended_frac_mean"):
            assert abs(a[k] - b[k]) <= 1e-9, (a["policy"], a["target"], k)
        for k in set(a["time_on_slice"]) | set(b["time_on_slice"]):
            assert abs(a["time_on_slice"].get(k, 0.0)
                       - b["time_on_slice"].get(k, 0.0)) <= 1e-9


def test_sweep_population_rejects_unknown_backend():
    with pytest.raises(ValueError):
        sweep_population({}, paper_family(), [], None, [],
                         SimConfig(target_rate=0.0), backend="quantum")


# ---------------------------------------------------------------------------
# Conservation invariants (both backends)
# ---------------------------------------------------------------------------

def _fleet_run_recorded(mk, fam, traces, carbon, target, srs=True):
    demand = np.stack([np.asarray(tr) for tr in traces], axis=1)
    sim = FleetSimulator(fam, suspend_releases_slice=srs)
    res = sim.run(mk(), demand, carbon, target, record=True)
    return demand, res


@pytest.mark.parametrize("policy_name", sorted(POLICIES))
def test_fleet_conservation_invariants(policy_name):
    mk = POLICIES[policy_name]
    fam = paper_family()
    carbon = _carbon(days=2)
    demand, res = _fleet_run_recorded(mk, fam, _traces(3, days=2), carbon,
                                      35.0)
    dt = 300.0
    # served <= demand per interval; both non-negative
    assert (res.served_series >= 0.0).all()
    assert (res.served_series <= demand + 1e-12).all()
    # power (hence energy and emissions increments) non-negative and
    # monotone accumulation
    assert (res.power_series >= 0.0).all()
    energy_check = res.power_series.sum(axis=0) * dt / 3600.0
    assert np.allclose(energy_check, res.energy_wh, rtol=1e-9, atol=1e-6)
    assert (res.emissions_g >= 0.0).all()
    assert (res.energy_wh >= 0.0).all()
    # time_on_slice fractions sum to ~1
    fracs = res.time_on_slice_s.sum(axis=1) / res.elapsed_s
    assert np.allclose(fracs, 1.0, atol=1e-9)
    # work conservation: work_done + throttled_integral == demand_integral
    assert np.allclose(res.work_done + res.throttled_integral,
                       res.work_demanded, rtol=1e-9, atol=1e-6)


@pytest.mark.parametrize("policy_name", sorted(POLICIES))
def test_scalar_conservation_invariants(policy_name):
    mk = POLICIES[policy_name]
    fam = paper_family()
    carbon = _carbon(days=2)
    tr = _traces(1, days=2)[0]
    cfg = SimConfig(target_rate=35.0, record_series=True)
    res = simulate(mk(), fam, tr, carbon, cfg)
    s = res.series
    served = np.asarray(s["served"])
    dem = np.asarray(s["demand"])
    assert (served >= 0.0).all() and (served <= dem + 1e-12).all()
    assert (np.asarray(s["carbon_rate"]) >= -1e-12).all()
    assert res.emissions_g >= 0.0 and res.energy_kwh >= 0.0
    assert abs(sum(res.time_on_slice.values()) - 1.0) < 1e-9
    # work conservation, via the throttle definition
    thr_integral = (res.avg_throttle_pct / 100.0 * (res.hours * 3600.0)
                    * fam.baseline.multiple)
    assert abs((res.work_done + thr_integral) - res.work_demanded) \
        <= 1e-6 * max(res.work_demanded, 1.0)


def test_fleet_emissions_monotone_over_time():
    fam = paper_family()
    carbon = _carbon(days=1)
    tr = np.asarray(_traces(1, days=1)[0])
    res = FleetSimulator(fam).run(CarbonContainerPolicy("energy"),
                                  tr[:, None], carbon, 45.0, record=True)
    co2_steps = res.power_series[:, 0]  # >= 0 -> cumulative emissions monotone
    assert (np.cumsum(co2_steps) >= -1e-12).all()
    assert (np.diff(np.cumsum(co2_steps)) >= -1e-12).all()


# ---------------------------------------------------------------------------
# Guard rails
# ---------------------------------------------------------------------------

def test_fleet_rejects_negative_demand():
    fam = paper_family()
    with pytest.raises(ValueError):
        FleetSimulator(fam).run(CarbonAgnosticPolicy(),
                                np.array([[0.5], [-0.1]]),
                                ConstantProvider(100.0), 45.0)


def test_fleet_rejects_unequal_trace_lengths():
    fam = paper_family()
    pols = {"cc": lambda: CarbonContainerPolicy("energy")}
    with pytest.raises(ValueError):
        sweep_population(pols, fam, [np.ones(10), np.ones(12)],
                         ConstantProvider(100.0), [45.0],
                         SimConfig(target_rate=0.0), backend="fleet")


def test_family_tables_snapshot_availability():
    fam = paper_family()
    t0 = fam.tables()
    assert t0.next_smaller[0] == -1
    assert t0.next_larger[len(fam) - 1] == -1
    assert t0.smallest == 0
    fam.available[0] = False
    t1 = fam.tables()
    assert t1.smallest == 1
    assert t1.next_smaller[1] == -1
    # the old snapshot is unchanged (tables() snapshots availability)
    assert t0.smallest == 0


def test_fleet_zero_bandwidth_falls_back_like_scalar():
    """Slices with state_bw_gbps=0 use the migration model's default
    bandwidth on both backends (scalar: `transfer_gbps or default`)."""
    from dataclasses import replace
    from repro.cluster.slices import SliceFamily
    fam0 = paper_family()
    fam = SliceFamily([replace(s, state_bw_gbps=0.0) for s in fam0.slices],
                      baseline_idx=fam0.baseline_idx)
    tr = _traces(1, days=2)[0]
    carbon = _carbon(days=2)
    cfg = SimConfig(target_rate=30.0, state_gb=1.0)
    rs = simulate(CarbonContainerPolicy("energy"), fam, tr, carbon, cfg)
    rf = FleetSimulator(fam).run(CarbonContainerPolicy("energy"),
                                 np.asarray(tr)[:, None], carbon,
                                 30.0, state_gb=1.0).result(0)
    assert rs.migrations > 0          # migrations actually exercised
    _assert_result_close(rs, rf, ctx="zero bandwidth")


def test_fleet_respects_slice_availability():
    """tables() snapshots availability; parity holds with a slice removed."""
    fam = paper_family()
    fam.available[0] = False
    tr = np.full(24 * 12, 0.2)
    cfg = SimConfig(target_rate=1000.0, state_gb=0.5)
    rs = simulate(CarbonContainerPolicy("energy"), fam, tr,
                  ConstantProvider(100.0), cfg)
    rf = FleetSimulator(fam).run(CarbonContainerPolicy("energy"),
                                 tr[:, None], ConstantProvider(100.0),
                                 1000.0, state_gb=0.5).result(0)
    _assert_result_close(rs, rf, ctx="unavailable slice")
    assert rf.time_on_slice.get("x0.25", 0.0) == 0.0


# ---------------------------------------------------------------------------
# Closed-form fast paths vs the stepping loop (edge cases)
# ---------------------------------------------------------------------------

class _LoopAgnostic(CarbonAgnosticPolicy):
    """Subclass defeats the exact-type closed-form dispatch, forcing the
    stepping loop while keeping decide/decide_batch behaviour."""


class _LoopSuspendResume(SuspendResumePolicy):
    pass


_CF_PAIRS = [("agnostic", CarbonAgnosticPolicy, _LoopAgnostic),
             ("suspend_resume", SuspendResumePolicy, _LoopSuspendResume)]

# (name, demand transform, target) edge cases: budget exhaustion (target
# ~0 forces suspend/resume into permanent suspension), zero demand
# (idle baseload only), and zero-carbon intensity via ConstantProvider
_CF_CASES = [
    ("normal", lambda d: d, 45.0),
    ("budget_exhausted", lambda d: d, 1e-9),
    ("zero_demand", lambda d: np.zeros_like(d), 45.0),
    ("zero_demand_exhausted", lambda d: np.zeros_like(d), 1e-9),
]

# FleetResult array fields (PARITY_FIELDS above names scalar SimResult
# fields; these are their per-container counterparts)
_CF_FIELDS = ("emissions_g", "energy_wh", "work_done", "work_demanded",
              "throttled_integral", "suspended_s", "elapsed_s",
              "migrations")


@pytest.mark.parametrize("case", _CF_CASES, ids=lambda c: c[0])
@pytest.mark.parametrize("pair", _CF_PAIRS, ids=lambda p: p[0])
@pytest.mark.parametrize("srs", [True, False], ids=["rel", "hold"])
def test_closed_form_matches_loop_under_edge_cases(pair, case, srs):
    """The closed-form whole-matrix path and `_loop` must agree exactly
    (the closed-form accumulates with the stepping loop's add order) —
    including when the budget is exhausted every interval and when
    demand is identically zero."""
    _, cf_policy, loop_policy = pair
    _, transform, target = case
    fam = paper_family()
    demand = transform(np.stack(_traces(3, days=1), axis=1))
    carbon = _carbon(days=1)
    kw = dict(epsilon=0.05, state_gb=0.5)
    sim = FleetSimulator(fam, suspend_releases_slice=srs)
    r_cf = sim.run(cf_policy(), demand, carbon, target, **kw)
    r_loop = sim.run(loop_policy(), demand, carbon, target, **kw)
    for f in _CF_FIELDS:
        a, b = getattr(r_cf, f), getattr(r_loop, f)
        assert np.abs(np.asarray(a, dtype=np.float64)
                      - np.asarray(b, dtype=np.float64)).max() <= 1e-9, f
    assert np.abs(r_cf.time_on_slice_s - r_loop.time_on_slice_s).max() \
        <= 1e-9


def test_closed_form_zero_carbon_intensity():
    """c = 0 means an infinite power budget: suspend/resume never
    suspends, and both paths agree bit-for-bit."""
    fam = paper_family()
    demand = np.stack(_traces(2, days=1), axis=1)
    carbon = ConstantProvider(0.0)
    sim = FleetSimulator(fam)
    r_cf = sim.run(SuspendResumePolicy(), demand, carbon, 45.0)
    r_loop = sim.run(_LoopSuspendResume(), demand, carbon, 45.0)
    assert (r_cf.suspended_s == 0.0).all()
    for f in _CF_FIELDS:
        a, b = getattr(r_cf, f), getattr(r_loop, f)
        assert np.abs(np.asarray(a, dtype=np.float64)
                      - np.asarray(b, dtype=np.float64)).max() <= 1e-9, f


def test_fleet_heterogeneous_regions_differ():
    """Mixed-region stacked carbon traces actually flow per-container."""
    fam = paper_family()
    tr = np.asarray(_traces(1, days=2)[0])
    T = len(tr)
    tvec = np.arange(T) * 300.0
    hi = TraceProvider.for_region("PL", hours=48, seed=1)    # dirty grid
    lo = TraceProvider.for_region("CAISO", hours=48, seed=1)
    cmat = np.stack([hi.intensity_series(tvec), lo.intensity_series(tvec)],
                    axis=1)
    demand = np.stack([tr, tr], axis=1)
    res = FleetSimulator(fam).run(CarbonContainerPolicy("energy"), demand,
                                  cmat, 45.0)
    # same demand + same target, dirtier grid => at least as much throttle
    # and the two containers must not be identical
    assert res.emissions_g[0] != res.emissions_g[1]
    assert res.avg_throttle_pct[0] >= res.avg_throttle_pct[1] - 1e-9
