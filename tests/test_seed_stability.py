"""Cross-process RNG seed stability (regression).

Both `repro.carbon.traces.synth_trace` and
`repro.models.params.init_params` used Python's `hash()` to derive
per-region / per-parameter-path salts. `str.__hash__` is salted per
process (PYTHONHASHSEED), so two runs of the same program generated
*different* carbon traces and parameter inits. The fix derives salts
from `zlib.crc32` instead; these tests pin concrete values so any
future drift back to an unstable digest (or an accidental change to
the salt formula, which silently invalidates every recorded benchmark
number) fails loudly.
"""
import numpy as np
import pytest

from repro.carbon.traces import synth_trace

# pinned against the crc32 salts (seed + crc32(name) % 100003)
TRACE_PINS = {
    "PL": (781.28, 751.7028384188773, 755.0008735220761,
           36423.42441028709),
    "NL": (444.00000000000006, 416.3211317714321, 380.2188895888865,
           20042.12321868904),
    "CAISO": (285.2, 251.25460011654013, 255.01356311774492,
              11426.162141202218),
}


@pytest.mark.parametrize("region", sorted(TRACE_PINS))
def test_synth_trace_pinned_values(region):
    tr = synth_trace(region, hours=48, seed=0)
    v0, v7, v33, vsum = TRACE_PINS[region]
    assert tr[0] == pytest.approx(v0, rel=0, abs=1e-9)
    assert tr[7] == pytest.approx(v7, rel=0, abs=1e-9)
    assert tr[33] == pytest.approx(v33, rel=0, abs=1e-9)
    assert tr.sum() == pytest.approx(vsum, rel=0, abs=1e-6)


def test_synth_trace_distinct_per_region_same_seed():
    # the whole point of the per-region salt: same seed, different
    # realizations (identical CoV-calibrated *statistics* are covered
    # by the carbon-core suite)
    a = synth_trace("PL", hours=48, seed=0)
    b = synth_trace("NL", hours=48, seed=0)
    assert not np.allclose(a / a.mean(), b / b.mean())


def test_init_params_pinned_values():
    jax = pytest.importorskip("jax")
    from repro.models.params import ParamSpec, init_params
    tree = {"w": ParamSpec((4, 3), ("a", "b")),
            "blk": {"b": ParamSpec((5,), ("a",), init="normal")}}
    p = init_params(tree, jax.random.PRNGKey(0))
    w = np.asarray(p["w"], dtype=np.float64)
    b = np.asarray(p["blk"]["b"], dtype=np.float64)
    # pinned against crc32("w") / crc32("blk/b") fold_in salts
    assert w.sum() == pytest.approx(0.029095228761434555, abs=1e-7)
    assert w[0, 0] == pytest.approx(-0.02740298956632614, abs=1e-7)
    assert b.sum() == pytest.approx(-0.012912587262690067, abs=1e-7)
    # per-path folding: distinct leaves draw distinct streams
    assert not np.allclose(w[:5].ravel()[: b.size], b)
