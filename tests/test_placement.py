"""Multi-region placement layer: scalar-reference parity, capacity and
hysteresis invariants, and MigrationCostModel edge cases.

The parity tests mirror the fleet suite's contract: the vectorized
(N, R) planner in `PlacementEngine.plan` must agree with the greedy
pure-Python reference `plan_scalar` to 1e-9 (in practice bit-for-bit) on
every field of the plan — epoch-by-epoch assignments, migration counts,
stop-and-copy overhead and downtime — across capacity regimes,
heterogeneous state sizes, and custom initial assignments.
"""
import numpy as np
import pytest

from repro.carbon.intensity import TraceProvider
from repro.cluster.migration import MigrationCostModel
from repro.cluster.placement import PlacementConfig, PlacementEngine
from repro.cluster.slices import paper_family
from repro.core.policy import CarbonContainerPolicy, SuspendResumePolicy
from repro.core.simulator import SimConfig, sweep_population
from repro.workload.azure_like import sample_population

REGIONS = ("PL", "NL", "CAISO")


def _providers(days=2, seed=1):
    return [TraceProvider.for_region(r, hours=24 * days, seed=seed)
            for r in REGIONS]


def _demand(n, days=2, seed=2):
    traces = [t.util for t in sample_population(n, days=days, seed=seed)]
    return np.stack(traces, axis=1)


def _assert_plans_equal(pv, ps, tol=1e-9, ctx=""):
    assert (pv.assign == ps.assign).all(), f"{ctx}: assignments diverge"
    assert (pv.migrations == ps.migrations).all(), f"{ctx}: migrations"
    assert np.abs(pv.overhead_g - ps.overhead_g).max() <= tol, \
        f"{ctx}: overhead_g"
    assert np.abs(pv.downtime_s - ps.downtime_s).max() <= tol, \
        f"{ctx}: downtime_s"


# ---------------------------------------------------------------------------
# Scalar-reference parity
# ---------------------------------------------------------------------------

CONFIGS = [
    PlacementConfig(),                                        # uncapped
    PlacementConfig(capacity=None, min_dwell=1, hysteresis=0.0),
    PlacementConfig(capacity=None, horizon_intervals=3, hysteresis=0.5),
]


@pytest.mark.parametrize("cfg", CONFIGS,
                         ids=["default", "eager", "short-horizon"])
def test_plan_matches_scalar_uncapped(cfg):
    eng = PlacementEngine(paper_family(), _providers(), config=cfg,
                          region_names=REGIONS)
    demand = _demand(24)
    pv = eng.plan(demand)
    ps = eng.plan_scalar(demand)
    _assert_plans_equal(pv, ps, ctx=str(cfg))
    assert pv.migrations.sum() > 0      # decisions actually exercised


@pytest.mark.parametrize("cap", [1, 2, 5, 40])
def test_plan_matches_scalar_capacitated(cap):
    """Tight caps force preference-round fall-through; parity must hold
    through denial/strike rounds, not just the happy path."""
    n = min(cap * len(REGIONS), 30)
    cfg = PlacementConfig(capacity=cap, min_dwell=2, hysteresis=0.05)
    eng = PlacementEngine(paper_family(), _providers(), config=cfg,
                          region_names=REGIONS)
    demand = _demand(n)
    pv = eng.plan(demand)
    ps = eng.plan_scalar(demand)
    _assert_plans_equal(pv, ps, ctx=f"cap={cap}")
    occ = pv.occupancy()
    assert (occ <= cap).all()


def test_plan_matches_scalar_heterogeneous_state_and_initial():
    n = 18
    rng = np.random.default_rng(7)
    state_gb = rng.choice([0.0, 0.25, 1.0, 4.0], size=n)
    initial = rng.integers(0, len(REGIONS), size=n)
    cfg = PlacementConfig(capacity=n, min_dwell=3)
    eng = PlacementEngine(paper_family(), _providers(), config=cfg,
                          region_names=REGIONS)
    demand = _demand(n)
    pv = eng.plan(demand, state_gb=state_gb, initial=initial)
    ps = eng.plan_scalar(demand, state_gb=state_gb, initial=initial)
    _assert_plans_equal(pv, ps, ctx="hetero")
    assert (pv.assign[0] != initial).any() or pv.migrations.sum() == 0


def test_plan_matches_scalar_single_region_and_matrix_input():
    """R=1 degenerates to no-op placement; a raw (T, R) matrix is accepted
    in place of providers."""
    T, n = 96, 8
    demand = _demand(n)[:T]
    one = PlacementEngine(paper_family(),
                          np.full((T, 1), 300.0), region_names=("only",))
    pv, ps = one.plan(demand), one.plan_scalar(demand)
    _assert_plans_equal(pv, ps, ctx="R=1")
    assert pv.migrations.sum() == 0 and (pv.assign == 0).all()

    tvec = np.arange(T) * 300.0
    cmat = np.stack([p.intensity_series(tvec) for p in _providers()], axis=1)
    eng = PlacementEngine(paper_family(), cmat, region_names=REGIONS)
    _assert_plans_equal(eng.plan(demand), eng.plan_scalar(demand),
                        ctx="matrix input")


# ---------------------------------------------------------------------------
# Capacity and hysteresis invariants
# ---------------------------------------------------------------------------

def test_no_region_ever_over_capacity():
    n, cap = 30, 12
    cfg = PlacementConfig(capacity=cap, min_dwell=1, hysteresis=0.0)
    eng = PlacementEngine(paper_family(), _providers(days=3), config=cfg,
                          region_names=REGIONS)
    plan = eng.plan(_demand(n, days=3))
    occ = plan.occupancy()
    assert (occ <= cap).all()
    assert (occ.sum(axis=1) == n).all()   # every container placed somewhere


def test_per_region_capacity_vector():
    """Uneven capacity vector with the *default* initial assignment:
    the capacity-aware round-robin fill must stay feasible."""
    cap = np.array([1, 2, 30])
    cfg = PlacementConfig(capacity=cap, min_dwell=1)
    eng = PlacementEngine(paper_family(), _providers(), config=cfg,
                          region_names=REGIONS)
    demand = _demand(12)
    plan = eng.plan(demand)
    _assert_plans_equal(plan, eng.plan_scalar(demand), ctx="cap vector")
    assert (plan.occupancy() <= cap[None, :]).all()
    # round-robin fill interleaves regions, skipping full ones:
    # 0,1,2, 1,2, 2,2,... for caps (1, 2, 30) and 12 containers
    occ0 = np.bincount(plan.assign[0], minlength=3)
    assert (occ0 <= cap).all() and occ0.sum() == 12


def test_no_oscillation_on_flat_traces():
    """Identical constant intensity everywhere: no move ever pays for its
    stop-and-copy cost, so a converged fleet must not oscillate."""
    T, n = 240, 10
    eng = PlacementEngine(paper_family(), np.full((T, 3), 350.0),
                          config=PlacementConfig(min_dwell=1,
                                                 hysteresis=0.0))
    plan = eng.plan(_demand(n)[:T])
    assert plan.migrations.sum() == 0
    assert (plan.assign == plan.assign[0][None, :]).all()


def test_dwell_pins_containers_between_moves():
    """No container moves twice within min_dwell epochs of a move."""
    cfg = PlacementConfig(min_dwell=6, hysteresis=0.0)
    eng = PlacementEngine(paper_family(), _providers(days=3), config=cfg,
                          region_names=REGIONS)
    plan = eng.plan(_demand(16, days=3))
    moves = plan.assign[1:] != plan.assign[:-1]    # (T-1, N)
    for i in range(moves.shape[1]):
        epochs = np.flatnonzero(moves[:, i])
        if len(epochs) > 1:
            assert np.diff(epochs).min() >= cfg.min_dwell
    assert plan.migrations.sum() > 0


def test_hysteresis_suppresses_marginal_moves():
    """Raising hysteresis can only reduce the number of placement moves."""
    demand = _demand(20)
    counts = []
    for h in (0.0, 0.5, 5.0, 1e9):
        eng = PlacementEngine(
            paper_family(), _providers(), region_names=REGIONS,
            config=PlacementConfig(hysteresis=h, min_dwell=1))
        counts.append(int(eng.plan(demand).migrations.sum()))
    assert counts == sorted(counts, reverse=True)
    assert counts[-1] == 0            # infinite hysteresis freezes the fleet


def test_carbon_matrix_gathers_assigned_regions():
    eng = PlacementEngine(paper_family(), _providers(), region_names=REGIONS)
    plan = eng.plan(_demand(9))
    cm = plan.carbon_matrix()
    T, N = plan.assign.shape
    for n in range(0, T, 37):
        for i in range(N):
            assert cm[n, i] == plan.region_intensity[n, plan.assign[n, i]]


# ---------------------------------------------------------------------------
# Placed fleet runs + sweep integration
# ---------------------------------------------------------------------------

def test_run_compare_static_populates_saving():
    n = 12
    eng = PlacementEngine(
        paper_family(), _providers(), region_names=REGIONS,
        config=PlacementConfig(capacity=n))
    demand = _demand(n)
    res = eng.run(CarbonContainerPolicy("energy"), demand, targets=45.0,
                  compare_static=True)
    assert res.static_fleet is not None
    assert np.isfinite(res.saving_vs_static_pct)
    assert (res.total_emissions_g
            >= res.fleet.emissions_g - 1e-12).all()
    assert (res.carbon_efficiency > 0.0).all()

    res2 = eng.run(SuspendResumePolicy(), demand, targets=45.0)
    with pytest.raises(ValueError):
        _ = res2.saving_vs_static_pct


def test_sweep_population_accepts_placement():
    fam = paper_family()
    days = 2
    traces = [t.util for t in sample_population(3, days=days, seed=4)]
    carbon = TraceProvider.for_region("CAISO", hours=24 * days, seed=1)
    pols = {"cc": lambda: CarbonContainerPolicy("energy"),
            "sr": SuspendResumePolicy}
    targets = [30.0, 60.0]
    eng = PlacementEngine(fam, _providers(days=days), region_names=REGIONS)
    rows = sweep_population(pols, fam, traces, carbon, targets,
                            SimConfig(target_rate=0.0), backend="fleet",
                            placement=eng)
    assert len(rows) == len(pols) * len(targets)
    for row in rows:
        assert "placement_migrations_mean" in row
        assert row["placement_overhead_g_mean"] >= 0.0

    with pytest.raises(ValueError):
        sweep_population(pols, fam, traces, carbon, targets,
                         SimConfig(target_rate=0.0), backend="scalar",
                         placement=eng)

    eng_1h = PlacementEngine(fam, _providers(days=days), interval_s=3600.0,
                             region_names=REGIONS)
    with pytest.raises(ValueError):     # engine/sweep interval mismatch
        sweep_population(pols, fam, traces, carbon, targets,
                         SimConfig(target_rate=0.0, interval_s=300.0),
                         backend="fleet", placement=eng_1h)


def test_sweep_placement_capacity_applies_to_real_fleet():
    """The sweep plans once over the n_tr real containers: a capacity
    that exactly fits the fleet must work regardless of how many targets
    duplicate the demand columns, and every target sees the same plan."""
    fam = paper_family()
    days = 2
    n_tr = 6
    traces = [t.util for t in sample_population(n_tr, days=days, seed=4)]
    carbon = TraceProvider.for_region("CAISO", hours=24 * days, seed=1)
    eng = PlacementEngine(fam, _providers(days=days), region_names=REGIONS,
                          config=PlacementConfig(capacity=2))  # 3*2 == n_tr
    rows = sweep_population({"cc": lambda: CarbonContainerPolicy("energy")},
                            fam, traces, carbon, [30.0, 60.0, 90.0],
                            SimConfig(target_rate=0.0), backend="fleet",
                            placement=eng)
    assert len(rows) == 3
    migs = {row["placement_migrations_mean"] for row in rows}
    assert len(migs) == 1               # one shared plan across targets


# ---------------------------------------------------------------------------
# Guard rails
# ---------------------------------------------------------------------------

def test_placement_input_validation():
    fam = paper_family()
    provs = _providers()
    eng = PlacementEngine(fam, provs, region_names=REGIONS)
    with pytest.raises(ValueError):
        eng.plan(np.array([[0.5], [-0.1]]))              # negative demand
    with pytest.raises(ValueError):
        eng.plan(np.ones((4, 2, 2)))                     # bad rank
    with pytest.raises(ValueError):
        eng.plan(np.ones((4, 2)), initial=np.array([0, 9]))  # bad region
    with pytest.raises(ValueError):
        eng.plan(np.ones((4, 2)), initial=np.array([0]))     # bad shape
    with pytest.raises(ValueError):
        PlacementEngine(fam, provs, region_names=("a",))     # name mismatch
    with pytest.raises(ValueError):
        PlacementEngine(fam, [])                             # no regions
    with pytest.raises(ValueError):
        PlacementEngine(fam, np.full((4, 2), 100.0),
                        region_names=REGIONS).plan(np.ones((4, 2)))
    with pytest.raises(ValueError):                      # matrix too short
        PlacementEngine(fam, np.full((4, 3), 100.0),
                        region_names=REGIONS).plan(np.ones((8, 2)))


def test_capacity_validation():
    fam = paper_family()
    provs = _providers()
    with pytest.raises(ValueError):                      # cap must be >= 1
        PlacementEngine(fam, provs,
                        config=PlacementConfig(capacity=0)).plan(
                            np.ones((4, 2)))
    with pytest.raises(ValueError):                      # fractional cap
        PlacementEngine(fam, provs,
                        config=PlacementConfig(capacity=2.7)).plan(
                            np.ones((4, 2)))
    cfg = PlacementConfig(capacity=1)
    eng = PlacementEngine(fam, provs, config=cfg)
    with pytest.raises(ValueError):                      # total cap < N
        eng.plan(np.ones((4, 9)))
    with pytest.raises(ValueError):                      # initial over cap
        eng.plan(np.ones((4, 2)), initial=np.array([0, 0]))


def test_run_accepts_precomputed_plan():
    """run(plan=...) reuses the plan instead of re-planning, and rejects
    a plan whose shape does not match the demand."""
    eng = PlacementEngine(paper_family(), _providers(), region_names=REGIONS)
    demand = _demand(6)
    plan = eng.plan(demand)
    res = eng.run(CarbonContainerPolicy("energy"), demand, targets=45.0,
                  plan=plan, compare_static=True)
    assert res.plan is plan
    res2 = eng.run(CarbonContainerPolicy("energy"), demand, targets=45.0,
                   compare_static=True)
    assert np.allclose(res.total_emissions_g, res2.total_emissions_g)
    assert np.allclose(res.static_fleet.emissions_g,
                       res2.static_fleet.emissions_g)
    with pytest.raises(ValueError):
        eng.run(CarbonContainerPolicy("energy"), demand[:, :3],
                targets=45.0, plan=plan)


def test_static_baseline_uses_plans_initial_assignment():
    """compare_static with a precomputed plan must freeze the fleet on
    the initial assignment the plan was built from, not a default."""
    eng = PlacementEngine(paper_family(), _providers(), region_names=REGIONS)
    demand = _demand(6)
    init = np.full(6, 2)                 # everyone starts in CAISO
    plan = eng.plan(demand, initial=init)
    assert (plan.initial == init).all()
    res_reused = eng.run(CarbonContainerPolicy("energy"), demand,
                         targets=45.0, plan=plan, compare_static=True)
    res_direct = eng.run(CarbonContainerPolicy("energy"), demand,
                         targets=45.0, initial=init, compare_static=True)
    assert np.allclose(res_reused.static_fleet.emissions_g,
                       res_direct.static_fleet.emissions_g)
    assert res_reused.saving_vs_static_pct == pytest.approx(
        res_direct.saving_vs_static_pct)


# ---------------------------------------------------------------------------
# MigrationCostModel edge cases
# ---------------------------------------------------------------------------

def test_migration_zero_state_size():
    """Zero-footprint state still pays the suspend/resume base latency."""
    m = MigrationCostModel()
    t0 = m.stop_and_copy_time(0.0)
    assert t0 == pytest.approx(m.suspend_base_s + m.resume_base_s
                               + m.restore_extra_s)
    assert t0 > 0.0
    tb = m.stop_and_copy_time_batch(np.zeros(3), np.array([0.0, 1.0, 10.0]))
    assert np.allclose(tb, t0, atol=1e-12)
    assert m.suspend_time(0.0) == m.suspend_base_s
    assert m.resume_time(0.0) == m.resume_base_s


def test_migration_bandwidth_limits():
    m = MigrationCostModel()
    # zero bandwidth falls back to the model default in both paths
    assert m.stop_and_copy_time(2.0, transfer_gbps=0.0) == \
        pytest.approx(m.stop_and_copy_time(2.0,
                                           transfer_gbps=m.transfer_gbps))
    tb = m.stop_and_copy_time_batch(np.full(2, 2.0), np.array([0.0, 1.0]))
    assert tb[0] == pytest.approx(tb[1])
    # downtime is monotone non-increasing in bandwidth...
    bws = np.array([0.01, 0.1, 1.0, 100.0])
    times = m.stop_and_copy_time_batch(np.full(4, 4.0), bws)
    assert (np.diff(times) <= 1e-12).all()
    # ...and floors at the bandwidth-independent suspend+compress terms
    floor = (m.suspend_time(4.0) + m.resume_time(4.0)
             + (m.compress_per_gb_s + m.decompress_per_gb_s) * 4.0
             + m.restore_extra_s)
    assert times[-1] == pytest.approx(floor, rel=1e-3)
    assert (times >= floor - 1e-12).all()


def test_migration_batch_matches_scalar_compressed():
    m = MigrationCostModel()
    sgb = np.array([0.0, 0.25, 1.0, 7.0])
    bw = np.array([0.0, 0.25, 1.0, 2.5])
    batch = m.stop_and_copy_time_batch(sgb, bw)
    for i in range(len(sgb)):
        assert batch[i] == pytest.approx(
            m.stop_and_copy_time(float(sgb[i]),
                                 transfer_gbps=float(bw[i])), abs=1e-12)
