"""Virtual energy supply layer: parity chain, invariants, sweep fold.

The supply model's parity chain mirrors the simulator's: the pure-float
scalar step anchors the NumPy step bit-for-bit, the JAX step tracks the
NumPy ledger <= 1e-9, and the full sweep with the energy layer enabled
holds the fleet <-> jax backend budget of 1e-6 (exact when the host
path applies the cap, i.e. with elasticity on).
"""
import numpy as np
import pytest

from repro.cluster.placement import PlacementConfig, PlacementEngine
from repro.cluster.slices import paper_family
from repro.core.policy import CarbonAgnosticPolicy, CarbonContainerPolicy
from repro.core.simulator import SimConfig, sweep_population
from repro.energy import (BatteryConfig, EnergyConfig, EnergySpec,
                          GridEventConfig, SolarConfig, event_matrices,
                          simulate_supply, solar_series)
from repro.energy.supply import (flex_w_per_unit, supply_step_np,
                                 supply_step_scalar)

SEED = 0


def _spec(n=50, R=3, dt=300.0):
    return EnergySpec.from_config(EnergyConfig(), n, R, dt,
                                  flex_w_per_unit(paper_family()))


def _streams(T=200, R=3, seed=SEED):
    rng = np.random.default_rng(seed)
    load = rng.uniform(0.0, 4000.0, size=(T, R))
    solar = rng.uniform(0.0, 3000.0, size=(T, R))
    grid_c = rng.uniform(20.0, 600.0, size=(T, R))
    up = (rng.uniform(size=(T, R)) > 0.1).astype(float)
    return load, solar, grid_c, up


def test_scalar_step_matches_numpy_bitwise():
    spec = _spec()
    load, solar, grid_c, up = _streams()
    soc = np.full(load.shape[1], spec.soc0_wh)
    for t in range(load.shape[0]):
        soc_np, outs_np = supply_step_np(spec, soc, load[t], solar[t],
                                         grid_c[t], up[t])
        for r in range(load.shape[1]):
            soc_s, outs_s = supply_step_scalar(
                spec, float(soc[r]), float(load[t, r]), float(solar[t, r]),
                float(grid_c[t, r]), float(up[t, r]))
            assert soc_s == soc_np[r]
            for a, b in zip(outs_s, (o[r] for o in outs_np)):
                assert a == b
        soc = soc_np


def test_supply_invariants_random_streams():
    spec = _spec()
    sres = simulate_supply(*_streams(), spec)
    assert sres.conservation_max_err_w <= 1e-6
    assert sres.cap_violations == 0
    assert sres.soc_violations == 0
    # physical ranges
    assert np.all(sres.cap_frac >= 0.0) and np.all(sres.cap_frac <= 1.0)
    assert np.all(sres.grid >= 0.0)
    # outage epochs draw nothing from the grid
    assert np.all(sres.grid[sres.grid_up == 0.0] == 0.0)
    # effective intensity never exceeds the grid's (solar/battery are
    # zero-carbon)
    assert np.all(sres.c_eff <= _streams()[2] + 1e-12)


def test_supply_summary_energy_conservation():
    spec = _spec()
    sres = simulate_supply(*_streams(), spec)
    s = sres.summary()
    assert s["energy_supplied_wh"] == pytest.approx(
        s["energy_solar_wh"] + s["energy_battery_wh"] + s["energy_grid_wh"],
        rel=1e-12)
    assert 0.0 <= s["energy_unmet_frac"] <= 1.0


def test_battery_charges_from_surplus_and_discharges_into_deficit():
    spec = EnergySpec.from_config(
        EnergyConfig(battery=BatteryConfig(capacity_wh_per_container=100.0,
                                           soc0_frac=0.0)),
        10, 1, 300.0, 100.0)
    T = 20
    load = np.concatenate([np.zeros(10), np.full(10, 500.0)])[:, None]
    solar = np.concatenate([np.full(10, 800.0), np.zeros(10)])[:, None]
    grid_c = np.full((T, 1), 300.0)
    up = np.zeros((T, 1))                      # islanded: battery or nothing
    sres = simulate_supply(load, solar, grid_c, up, spec)
    assert sres.soc[9, 0] > sres.soc[0, 0]     # charged from surplus
    assert sres.discharge[10:, 0].max() > 0.0  # then discharged
    assert np.all(sres.grid == 0.0)
    # zero-carbon wherever anything was actually supplied (islanded)
    assert np.all(sres.c_eff[sres.supplied > 0.0] == 0.0)


def test_event_matrices_deterministic_and_correlated():
    cfg = GridEventConfig(n_random_outages=3, n_random_shocks=2, seed=9)
    a_mult, a_up = event_matrices(cfg, 200, 3)
    b_mult, b_up = event_matrices(cfg, 200, 3)
    assert np.array_equal(a_mult, b_mult) and np.array_equal(a_up, b_up)
    assert a_up.min() == 0.0                   # outages actually landed
    # region -1 hits every region the same epoch (correlated spike)
    m, up = event_matrices(GridEventConfig(outages=((-1, 10, 5),),
                                           shocks=((-1, 30, 4, 2.0),)),
                           100, 3)
    assert np.all(up[10:15] == 0.0) and np.all(up[:10] == 1.0)
    assert np.all(m[30:34] == 2.0) and np.all(m[:30] == 1.0)


def test_solar_series_shape_and_night():
    cfg = SolarConfig(seed=3)
    s = solar_series(cfg, 288, 3, 300.0, 1000.0)
    assert s.shape == (288, 3)
    assert np.all(s >= 0.0) and s.max() <= 1000.0
    assert s.max() > 0.0
    # deterministic per seed
    assert np.array_equal(s, solar_series(cfg, 288, 3, 300.0, 1000.0))
    # every region has night epochs (regions are tz-spread by default,
    # so they are dark at *different* epochs)
    assert np.all(np.any(s == 0.0, axis=0))


def test_supply_jax_matches_numpy():
    pytest.importorskip("jax")
    from repro.energy.supply_jax import simulate_supply_jax
    spec = _spec()
    load, solar, grid_c, up = _streams()
    a = simulate_supply(load, solar, grid_c, up, spec)
    b = simulate_supply_jax(load, solar, grid_c, up, spec)
    for name in ("solar_used", "charge", "discharge", "grid", "supplied",
                 "cap_frac", "c_eff", "soc"):
        x, y = getattr(a, name), getattr(b, name)
        assert np.max(np.abs(x - y)) <= 1e-9, name
    assert b.conservation_max_err_w <= 1e-6
    assert b.cap_violations == 0 and b.soc_violations == 0


# ---------------------------------------------------------------------------
# The sweep fold
# ---------------------------------------------------------------------------

def _sweep_inputs(T=96, n_tr=30, seed=1):
    rng = np.random.default_rng(seed)
    traces = rng.uniform(0.2, 1.6, size=(T, n_tr))
    t = np.linspace(0, 4 * np.pi, T)
    regions = np.stack([200 + 150 * np.sin(t + p)
                        for p in (0.0, 1.5, 3.0)], axis=1) + 50.0
    return traces, regions


def _engine(regions):
    return PlacementEngine(paper_family(), regions, interval_s=300.0,
                           config=PlacementConfig(capacity=25))


_POL = {"cc": lambda: CarbonContainerPolicy(),
        "agnostic": lambda: CarbonAgnosticPolicy()}
_EN = EnergyConfig(events=GridEventConfig(outages=((1, 20, 6),),
                                          shocks=((-1, 50, 12, 2.0),)))


def test_energy_requires_placement():
    traces, _ = _sweep_inputs()
    with pytest.raises(ValueError, match="placement"):
        sweep_population(_POL, paper_family(), traces, None, [40.0],
                         SimConfig(target_rate=0.0), backend="fleet",
                         energy=_EN)
    with pytest.raises(ValueError, match="backend"):
        sweep_population(_POL, paper_family(),
                         [traces[:, 0]], None, [40.0],
                         SimConfig(target_rate=0.0), energy=_EN)


def test_sweep_energy_rows_and_invariants_fleet():
    traces, regions = _sweep_inputs()
    rows = sweep_population(_POL, paper_family(), traces, None,
                            [40.0, 80.0], SimConfig(target_rate=0.0),
                            backend="fleet", placement=_engine(regions),
                            energy=_EN)
    assert len(rows) == 4
    r0 = rows[0]
    assert r0["energy_cap_violations"] == 0
    assert r0["energy_soc_violations"] == 0
    assert r0["energy_conservation_max_err_w"] <= 1e-6
    assert r0["energy_outage_epochs"] == 6
    assert 0.0 < r0["energy_solar_frac"] < 1.0
    # the supply sim is shared across rows (one compact fleet)
    assert all(r["energy_grid_wh"] == r0["energy_grid_wh"] for r in rows)
    # shocked + capped sweep differs from the unperturbed one
    plain = sweep_population(_POL, paper_family(), traces, None,
                             [40.0, 80.0], SimConfig(target_rate=0.0),
                             backend="fleet", placement=_engine(regions))
    assert rows[0]["carbon_rate_mean"] != plain[0]["carbon_rate_mean"]


def _row_parity(rows_a, rows_b):
    keys = [k for k in rows_a[0]
            if isinstance(rows_a[0][k], (int, float))]
    return max(abs(a[k] - b[k]) / max(abs(a[k]), 1.0)
               for a, b in zip(rows_a, rows_b) for k in keys)


def test_sweep_energy_fleet_jax_parity():
    pytest.importorskip("jax")
    traces, regions = _sweep_inputs()
    kw = dict(cfg_base=SimConfig(target_rate=0.0), energy=_EN)
    rows_f = sweep_population(_POL, paper_family(), traces, None,
                              [40.0, 80.0], backend="fleet",
                              placement=_engine(regions), **kw)
    rows_j = sweep_population(_POL, paper_family(), traces, None,
                              [40.0, 80.0], backend="jax",
                              placement=_engine(regions), **kw)
    assert _row_parity(rows_f, rows_j) <= 1e-6


def test_sweep_all_four_layers_fleet_jax_parity():
    pytest.importorskip("jax")
    from repro.core.elasticity import ElasticityConfig
    from repro.traffic import TrafficConfig, UserPopulation
    traces, regions = _sweep_inputs(n_tr=24)
    tr = TrafficConfig(population=UserPopulation(n_users=5000, n_regions=3,
                                                 seed=3))
    el = ElasticityConfig(k_levels=4, unit_capacity=0.3,
                          budget_g_per_epoch=60.0, forecast="forecast",
                          shape_budget=True)
    kw = dict(cfg_base=SimConfig(target_rate=0.0), traffic=tr,
              elasticity=el, energy=_EN)
    rows_f = sweep_population(_POL, paper_family(), traces, None, [40.0],
                              backend="fleet", placement=_engine(regions),
                              **kw)
    rows_j = sweep_population(_POL, paper_family(), traces, None, [40.0],
                              backend="jax", placement=_engine(regions),
                              **kw)
    # host-applied cap + indexed c_eff: identical floats, not just 1e-6
    assert _row_parity(rows_f, rows_j) <= 1e-6
    assert rows_f[0]["energy_cap_violations"] == 0
    assert rows_f[0]["elastic_cap_violations"] == rows_j[0][
        "elastic_cap_violations"]


def test_energy_with_traffic_in_scan_parity():
    pytest.importorskip("jax")
    from repro.traffic import TrafficConfig, UserPopulation
    traces, regions = _sweep_inputs(n_tr=24)
    tr = TrafficConfig(population=UserPopulation(n_users=5000, n_regions=3,
                                                 seed=3))
    kw = dict(cfg_base=SimConfig(target_rate=0.0), traffic=tr, energy=_EN)
    rows_f = sweep_population(_POL, paper_family(), traces, None, [40.0],
                              backend="fleet", placement=_engine(regions),
                              **kw)
    rows_j = sweep_population(_POL, paper_family(), traces, None, [40.0],
                              backend="jax", placement=_engine(regions),
                              **kw)
    assert _row_parity(rows_f, rows_j) <= 1e-6
