"""Per-container elasticity (`repro.core.elasticity`).

The (N, K) CarbonScaler greedy is pinned to its pure-Python reference
(level counts identical, floats <=1e-9), and its two invariants — the
estimated-emissions cap and work conservation through the backlog —
are checked directly from first principles, not by re-running the
implementation's own ledger.
"""
import numpy as np
import pytest

from repro.carbon.traces import synth_trace
from repro.core.elasticity import (ElasticityConfig, allocate_epoch,
                                   allocate_epoch_scalar, simulate_elastic)


def _inputs(T=48, N=10, seed=0, zero_epochs=()):
    rng = np.random.default_rng(seed)
    demand = np.abs(rng.normal(3.0, 1.5, (T, N)))
    carbon = np.abs(rng.normal(300.0, 150.0, (T, N)))
    for t in zero_epochs:
        carbon[t] = 0.0
    return demand, carbon


CFG = dict(k_levels=4, unit_capacity=1.5, base_w=50.0, peak_w=200.0,
           min_level=1, max_step=1)


@pytest.mark.parametrize("budget", [None, 0.0, 2.0, np.inf])
@pytest.mark.parametrize("mode", ["oracle", "persistence", "forecast"])
def test_scalar_numpy_parity(budget, mode):
    demand, carbon = _inputs(zero_epochs=(5,))    # incl. zero-carbon epoch
    cfg = ElasticityConfig(budget_g_per_epoch=budget, forecast=mode, **CFG)
    a = simulate_elastic(demand, carbon, cfg, 300.0, backend="numpy")
    b = simulate_elastic(demand, carbon, cfg, 300.0, backend="scalar")
    np.testing.assert_array_equal(a.levels, b.levels)
    assert np.max(np.abs(a.served_w - b.served_w)) <= 1e-9
    assert abs(a.emissions_g - b.emissions_g) <= 1e-9 * max(
        abs(a.emissions_g), 1.0)
    assert a.cap_violations == b.cap_violations == 0


def test_allocate_epoch_parity_ties_and_zero_carbon():
    # equal wants + equal intensities force score ties: the stable sort
    # must break them identically; a zero-intensity container exercises
    # the free-level guard
    # budget sits above the ~8.33 g of mandatory levels but below the
    # first paid optional level, so only the free level can be admitted
    cfg = ElasticityConfig(budget_g_per_epoch=9.0, **CFG)
    want = np.array([4.0, 4.0, 4.0, 9.0]) * 300.0
    chat = np.array([200.0, 200.0, 0.0, 100.0])
    prev = np.array([1.0, 2.0, 1.0, 1.0])
    n_v, lo_v = allocate_epoch(want, chat, prev, cfg, 300.0)
    n_s, lo_s = allocate_epoch_scalar(want, chat, prev, cfg, 300.0)
    np.testing.assert_array_equal(n_v, n_s)
    np.testing.assert_array_equal(lo_v, lo_s)
    # the zero-carbon container's optional level is free -> admitted
    assert n_v[2] > lo_v[2]


def test_cap_never_exceeded_first_principles():
    demand, carbon = _inputs(T=96, N=16, seed=2)
    budget = 3.0
    cfg = ElasticityConfig(budget_g_per_epoch=budget, forecast="oracle",
                           **CFG)
    res = simulate_elastic(demand, carbon, cfg, 300.0)
    assert res.cap_violations == 0
    # recompute the estimated grams of every epoch's allocation from the
    # marginal table (closed form: sum_{k<=n} w(k) = min(want, n*capw))
    dt, capw = 300.0, cfg.capw(300.0)
    span = cfg.peak_w - cfg.base_w
    backlog = np.zeros(16)
    prev = np.full(16, 1.0)
    for t in range(96):
        want = demand[t] * dt + backlog         # oracle demand forecast
        n = res.levels[t].astype(float)
        lo = np.maximum(1.0, prev - cfg.max_step)
        est = ((n * cfg.base_w + span * np.minimum(want, n * capw) / capw)
               * dt / 3600.0 * carbon[t] / 1000.0).sum()
        mand = ((lo * cfg.base_w + span * np.minimum(want, lo * capw) / capw)
                * dt / 3600.0 * carbon[t] / 1000.0).sum()
        assert est <= max(budget, mand) + 1e-9
        srv = np.minimum(demand[t] * dt + backlog, n * capw)
        backlog = backlog + demand[t] * dt - srv
        prev = n


def test_work_conservation_and_deferral():
    demand, carbon = _inputs(T=60, N=8, seed=3)
    cfg = ElasticityConfig(budget_g_per_epoch=1.0, **CFG)
    res = simulate_elastic(demand, carbon, cfg, 300.0)
    offered = res.offered_w.sum()
    assert res.served_w.sum() + res.backlog.sum() == pytest.approx(
        offered, rel=1e-12)
    assert res.backlog.min() >= 0.0
    # the tight budget must actually defer work for this demand level
    assert res.backlog.sum() > 0.0
    # uncapped run serves everything it has capacity for
    res2 = simulate_elastic(demand, carbon,
                            ElasticityConfig(budget_g_per_epoch=None, **CFG),
                            300.0)
    assert res2.summary()["elastic_served_frac"] \
        > res.summary()["elastic_served_frac"]


def test_ramp_limit_respected():
    demand, carbon = _inputs(T=50, N=12, seed=4)
    demand[25:] *= 10.0                          # step change in load
    cfg = ElasticityConfig(**{**CFG, "max_step": 1})
    res = simulate_elastic(demand, carbon, cfg, 300.0)
    lev = res.levels.astype(int)
    assert np.abs(np.diff(lev, axis=0)).max() <= 1
    assert lev.min() >= cfg.min_level and lev.max() <= cfg.k_levels


def test_k1_budget0_budgetinf_edges():
    demand, carbon = _inputs()
    # K=1: every container pinned at the single level
    r1 = simulate_elastic(demand, carbon,
                          ElasticityConfig(**{**CFG, "k_levels": 1,
                                              "min_level": 1}), 300.0)
    assert (r1.levels == 1).all()
    # budget=0: nothing above the mandatory floor is ever admitted
    r0 = simulate_elastic(demand, carbon,
                          ElasticityConfig(budget_g_per_epoch=0.0, **CFG),
                          300.0)
    assert (r0.levels == 1).all() and r0.cap_violations == 0
    # budget=inf == uncapped
    ri = simulate_elastic(demand, carbon,
                          ElasticityConfig(budget_g_per_epoch=np.inf, **CFG),
                          300.0)
    rn = simulate_elastic(demand, carbon,
                          ElasticityConfig(budget_g_per_epoch=None, **CFG),
                          300.0)
    np.testing.assert_array_equal(ri.levels, rn.levels)


def test_forecast_vs_oracle_ablation_smoke():
    # hourly epochs on real synth traces, same total gram budget per
    # mode but *shaped* by each mode's own now-vs-next-24h forecast.
    # Persistence predicts a flat trace, so its shaped budget is
    # uniform; carbon-per-served-work must order
    # oracle <= forecast < persistence with real margin.
    T, N = 24 * 8, 64
    regions = ["PL", "NL", "CAISO"]
    carbon = np.stack([synth_trace(regions[i % 3], hours=T, seed=7 + i)
                       for i in range(N)], axis=1)
    rng = np.random.default_rng(9)
    phase = rng.uniform(0.0, 1.0, (1, N))
    base = 2.0 + np.sin(2 * np.pi * (np.arange(T)[:, None] / 24.0 + phase))
    eps = rng.normal(0.0, 0.3, (T, N))
    noise = np.zeros((T, N))
    for t in range(1, T):
        noise[t] = 0.9 * noise[t - 1] + eps[t]
    demand = np.abs(base + noise)
    mk = lambda mode, budget, shape=False: ElasticityConfig(
        k_levels=4, unit_capacity=1.0, max_step=4,
        budget_g_per_epoch=budget, forecast=mode, shape_budget=shape)
    free = simulate_elastic(demand, carbon, mk("oracle", None), 3600.0)
    budget = 0.6 * free.est_emissions_g / T
    out, work = {}, {}
    for mode in ("oracle", "persistence", "forecast"):
        res = simulate_elastic(demand, carbon, mk(mode, budget, True),
                               3600.0)
        s = res.summary()
        out[mode] = s["elastic_emissions_g"] / max(
            s["elastic_served_work"], 1e-12)
        work[mode] = s["elastic_served_work"]
    assert out["oracle"] <= out["forecast"] * (1 + 1e-6)
    # knowing the diurnal shape must beat the flat-belief baseline
    assert 1.0 - out["forecast"] / out["persistence"] > 0.005
    # ... at near-equal total served work
    assert min(work.values()) / max(work.values()) > 0.9


def test_shaped_budget_series_properties():
    from repro.core.elasticity import shaped_budget_series
    rng = np.random.default_rng(3)
    sig = np.abs(300.0 + 100.0 * np.sin(2 * np.pi * np.arange(96) / 24.0)
                 + rng.normal(0, 10, 96))
    for mode in ("oracle", "persistence", "forecast"):
        cfg = ElasticityConfig(budget_g_per_epoch=5.0, forecast=mode,
                               shape_budget=True, **CFG)
        bud = shaped_budget_series(sig, cfg, 3600.0)
        assert bud.shape == (96,) and (bud >= 0).all()
        # total grams preserved exactly
        assert bud.sum() == pytest.approx(5.0 * 96, rel=1e-12)
    # persistence believes the signal is flat -> uniform budget
    cfg_p = ElasticityConfig(budget_g_per_epoch=5.0, forecast="persistence",
                             shape_budget=True, **CFG)
    np.testing.assert_allclose(shaped_budget_series(sig, cfg_p, 3600.0),
                               5.0, rtol=1e-12)
    # oracle concentrates budget in below-day-mean epochs
    cfg_o = ElasticityConfig(budget_g_per_epoch=5.0, forecast="oracle",
                             shape_budget=True, **CFG)
    bud_o = shaped_budget_series(sig, cfg_o, 3600.0)
    assert bud_o.std() > 0.5


def test_config_validation():
    with pytest.raises(ValueError):
        ElasticityConfig(k_levels=0)
    with pytest.raises(ValueError):
        ElasticityConfig(min_level=5, k_levels=4)
    with pytest.raises(ValueError):
        ElasticityConfig(forecast="psychic")
    with pytest.raises(ValueError):
        ElasticityConfig(budget_g_per_epoch=-1.0)
    with pytest.raises(ValueError):
        ElasticityConfig(peak_w=10.0, base_w=20.0)
    with pytest.raises(ValueError):
        ElasticityConfig(shape_gamma=0.0)
    with pytest.raises(ValueError):      # nothing to shape
        ElasticityConfig(shape_budget=True, budget_g_per_epoch=None)


def test_sweep_integration_fleet_rows():
    from repro.carbon.intensity import TraceProvider
    from repro.cluster.placement import PlacementConfig, PlacementEngine
    from repro.cluster.slices import paper_family
    from repro.core.policy import CarbonContainerPolicy
    from repro.core.simulator import SimConfig, sweep_population
    from repro.workload.azure_like import sample_population

    fam = paper_family()
    traces = [t.util for t in sample_population(4, days=1, seed=5)]
    provs = [TraceProvider.for_region(r, hours=24, seed=1)
             for r in ("PL", "NL")]
    eng = PlacementEngine(fam, provs,
                          config=PlacementConfig(capacity=3, min_dwell=4))
    ec = ElasticityConfig(k_levels=3, unit_capacity=0.4,
                          budget_g_per_epoch=50.0)
    rows = sweep_population({"cc": lambda: CarbonContainerPolicy("energy")},
                            fam, traces, None, [40.0],
                            SimConfig(target_rate=0.0), backend="fleet",
                            placement=eng, elasticity=ec)
    assert len(rows) == 1
    for k in ("elastic_served_work", "elastic_emissions_g",
              "elastic_cap_violations", "elastic_served_frac",
              "elastic_level_epochs"):
        assert k in rows[0]
    assert rows[0]["elastic_cap_violations"] == 0


def test_sweep_rejects_bad_combinations():
    from repro.cluster.slices import paper_family
    from repro.core.policy import CarbonContainerPolicy
    from repro.core.simulator import SimConfig, sweep_population

    fam = paper_family()
    tr = [np.full(24, 0.5)]
    ec = ElasticityConfig()
    with pytest.raises(ValueError):      # scalar backend has no layer
        sweep_population({"cc": lambda: CarbonContainerPolicy("energy")},
                         fam, tr, np.full(24, 300.0), [40.0],
                         SimConfig(target_rate=0.0), backend="scalar",
                         elasticity=ec)
    with pytest.raises(ValueError):      # per-region layer needs a plan
        sweep_population({"cc": lambda: CarbonContainerPolicy("energy")},
                         fam, tr, np.full(24, 300.0), [40.0],
                         SimConfig(target_rate=0.0), backend="fleet",
                         elasticity=ec)
