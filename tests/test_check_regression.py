"""The benchmark-regression gate must fail *usefully*: a missing entry,
a missing metric, or a None/non-numeric value exits 2 with a message
naming the path (regression: these used to escape as KeyError /
TypeError tracebacks), and one missing entry must not mask real
constraint violations elsewhere in the same report."""
import json
import sys
from pathlib import Path

import pytest

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

from benchmarks.check_regression import GateError, lookup, main  # noqa: E402

REPORT = {
    "fleet_sweep": {
        "us_per_call": 1000.0,
        "warmup_s": None,
        "derived": {"speedup_x": 12.0, "assign_equal": True},
    },
}


def _write(tmp_path, obj):
    p = tmp_path / "report.json"
    p.write_text(json.dumps(obj))
    return str(p)


def test_lookup_resolves_through_derived():
    assert lookup(REPORT, "fleet_sweep.speedup_x") == 12.0
    assert lookup(REPORT, "fleet_sweep.us_per_call") == 1000.0
    assert lookup(REPORT, "fleet_sweep.assign_equal") == 1.0


def test_lookup_missing_entry_names_path():
    with pytest.raises(GateError, match="MISSING nope.speedup_x"):
        lookup(REPORT, "nope.speedup_x")


def test_lookup_missing_metric_lists_available():
    with pytest.raises(GateError, match="speedup_x"):
        lookup(REPORT, "fleet_sweep.nope")


def test_lookup_none_is_not_numeric():
    with pytest.raises(GateError, match="NOT NUMERIC"):
        lookup(REPORT, "fleet_sweep.warmup_s")


def test_pass_exit_0(tmp_path, capsys):
    rp = _write(tmp_path, REPORT)
    assert main([rp, "--min", "fleet_sweep.speedup_x=10"]) == 0
    assert "PASS" in capsys.readouterr().out


def test_violation_exit_1(tmp_path, capsys):
    rp = _write(tmp_path, REPORT)
    assert main([rp, "--min", "fleet_sweep.speedup_x=100"]) == 1
    assert "FAIL" in capsys.readouterr().out


def test_missing_entry_exit_2(tmp_path, capsys):
    rp = _write(tmp_path, REPORT)
    assert main([rp, "--min", "placement_sweep.speedup_x=1"]) == 2
    out = capsys.readouterr().out
    assert "MISSING placement_sweep.speedup_x" in out
    assert "Traceback" not in out


def test_none_metric_exit_2(tmp_path, capsys):
    rp = _write(tmp_path, REPORT)
    assert main([rp, "--max", "fleet_sweep.warmup_s=5"]) == 2
    assert "NOT NUMERIC fleet_sweep.warmup_s" in capsys.readouterr().out


def test_missing_does_not_mask_violations(tmp_path, capsys):
    rp = _write(tmp_path, REPORT)
    code = main([rp,
                 "--min", "gone.speedup_x=1",
                 "--min", "fleet_sweep.speedup_x=100"])
    assert code == 2
    out = capsys.readouterr().out
    assert "MISSING gone.speedup_x" in out
    assert "FAIL fleet_sweep.speedup_x" in out


def test_unreadable_report_exit_2(tmp_path, capsys):
    assert main([str(tmp_path / "absent.json"),
                 "--min", "a.b=1"]) == 2
    assert "UNREADABLE" in capsys.readouterr().out


def test_invalid_json_exit_2(tmp_path, capsys):
    p = tmp_path / "bad.json"
    p.write_text("{not json")
    assert main([str(p), "--min", "a.b=1"]) == 2
    assert "INVALID JSON" in capsys.readouterr().out
