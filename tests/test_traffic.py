"""Traffic subsystem: arrivals, routing, autoscaling, end-to-end sweeps.

The parity tests are the subsystem's safety net: the vectorized router
and autoscaler must reproduce their pure-Python references to 1e-9 (in
practice bit-for-bit — both compute threshold-feeding reductions as left
folds), and conservation invariants pin the request ledger: every
offered request is served, dropped at routing, or dropped at capacity.
"""
import numpy as np
import pytest

from repro.carbon.intensity import TraceProvider
from repro.cluster.slices import paper_family
from repro.cluster.placement import PlacementConfig, PlacementEngine
from repro.core.policy import CarbonContainerPolicy
from repro.core.simulator import SimConfig, sweep_population
from repro.traffic import (RoutingConfig, TrafficConfig, UserPopulation,
                           latency_from_timezones, request_matrix, route,
                           route_scalar, simulate_traffic)
from repro.traffic.autoscale import (ReplicaConfig, autoscale,
                                     autoscale_scalar)
from repro.workload.azure_like import sample_population

TOL = 1e-9


def _random_scenario(seed, T=48, R=4):
    rng = np.random.default_rng(seed)
    demand = rng.gamma(2.0, 40_000.0, (T, R))
    carbon = 100.0 + 500.0 * rng.random((T, R))
    lat = latency_from_timezones(rng.uniform(0.0, 24.0, R))
    capacity = rng.uniform(50_000.0, 150_000.0, R)
    return demand, carbon, lat, capacity


# ---------------------------------------------------------------------------
# arrivals
# ---------------------------------------------------------------------------

def test_population_user_counts_exact():
    pop = UserPopulation(n_users=1_000_003, n_regions=3,
                         region_weights=(0.5, 0.3, 0.2))
    counts = pop.user_counts()
    assert counts.sum() == 1_000_003          # largest-remainder: exact
    assert counts.min() > 0
    np.testing.assert_allclose(counts / counts.sum(), [0.5, 0.3, 0.2],
                               atol=1e-5)


def test_request_matrix_shapes_and_rates():
    pop = UserPopulation(n_users=300_000, n_regions=3, seed=1)
    T = 288
    arr = request_matrix(pop, T, interval_s=300.0)
    assert arr.requests.shape == (T, 3)
    assert arr.n_users == 300_000
    assert np.all(arr.requests >= 0.0)
    # normalized diurnal/noise factors preserve each region's daily
    # request budget: offered total == n_users * req_per_day * days
    days = T * 300.0 / 86400.0
    expect = arr.req_per_day.sum() * days
    np.testing.assert_allclose(arr.offered_total, expect, rtol=1e-9)


def test_request_matrix_timezone_peak_shift():
    # two regions 12h apart: their diurnal peaks must be ~12h apart
    pop = UserPopulation(n_users=200_000, n_regions=2, tz_offset_h=(0.0, 12.0),
                         cov=0.0, seed=2)
    arr = request_matrix(pop, 288, interval_s=300.0)
    p0 = int(np.argmax(arr.requests[:, 0]))
    p1 = int(np.argmax(arr.requests[:, 1]))
    shift = abs(p0 - p1) % 288
    shift = min(shift, 288 - shift) * 300.0 / 3600.0    # hours
    assert abs(shift - 12.0) < 1.5


# ---------------------------------------------------------------------------
# routing
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("policy", ["carbon", "latency"])
@pytest.mark.parametrize("spill", [True, False])
def test_route_matches_scalar(policy, spill):
    for seed in range(4):
        demand, carbon, lat, capacity = _random_scenario(seed)
        cfg = RoutingConfig(slo_ms=150.0, policy=policy, spill=spill)
        rv = route(demand, capacity, carbon, lat, cfg)
        rs = route_scalar(demand, capacity, carbon, lat, cfg)
        for f in ("flows", "routed", "dropped", "violations"):
            assert np.max(np.abs(getattr(rv, f) - getattr(rs, f))) <= TOL, f


def test_route_conservation_and_capacity():
    demand, carbon, lat, capacity = _random_scenario(7)
    res = route(demand, capacity, carbon, lat, RoutingConfig())
    # ledger: every offered request flows somewhere or is dropped
    np.testing.assert_allclose(res.flows.sum(axis=2) + res.dropped, demand,
                               rtol=1e-12)
    # serving regions never exceed capacity
    assert np.all(res.routed <= capacity[None, :] * (1 + 1e-12))
    np.testing.assert_allclose(res.routed, res.flows.sum(axis=1), rtol=1e-12)


def test_route_prefers_clean_regions_and_respects_slo():
    # source 0 can reach regions 0 (dirty) and 1 (clean) inside the SLO;
    # region 2 is cleanest but out of SLO
    lat = np.array([[20.0, 100.0, 500.0],
                    [100.0, 20.0, 500.0],
                    [500.0, 500.0, 20.0]])
    carbon = np.tile([300.0, 100.0, 10.0], (4, 1))
    demand = np.full((4, 3), 10.0)
    res = route(demand, 1e6, carbon, lat,
                RoutingConfig(slo_ms=150.0, policy="carbon", spill=False))
    # all of source 0's demand lands on region 1 (clean, SLO-feasible)
    np.testing.assert_allclose(res.flows[:, 0, 1], 10.0)
    np.testing.assert_allclose(res.flows[:, 0, 2], 0.0)
    assert res.violations.sum() == 0.0


def test_route_spill_counts_violations():
    # capacity forces spill into the out-of-SLO region
    lat = np.array([[20.0, 500.0], [500.0, 20.0]])
    carbon = np.tile([100.0, 100.0], (3, 1))
    demand = np.tile([30.0, 0.0], (3, 1))
    res = route(demand, 20.0, carbon, lat,
                RoutingConfig(slo_ms=150.0, spill=True))
    np.testing.assert_allclose(res.flows[:, 0, 0], 20.0)
    np.testing.assert_allclose(res.flows[:, 0, 1], 10.0)   # spilled
    np.testing.assert_allclose(res.violations[:, 0], 10.0)
    res_ns = route(demand, 20.0, carbon, lat,
                   RoutingConfig(slo_ms=150.0, spill=False))
    np.testing.assert_allclose(res_ns.dropped[:, 0], 10.0)
    assert res_ns.violations.sum() == 0.0


# ---------------------------------------------------------------------------
# autoscaling
# ---------------------------------------------------------------------------

def test_autoscale_matches_scalar():
    for seed, budget in [(0, None), (1, 8.0), (2, 3.0), (3, 1.0)]:
        rng = np.random.default_rng(seed)
        T, R = 48, 3
        routed = rng.gamma(2.0, 60_000.0, (T, R))
        carbon = 100.0 + 500.0 * rng.random((T, R))
        cfg = ReplicaConfig(max_replicas=8, max_step=2,
                            budget_g_per_epoch=budget)
        av = autoscale(routed, carbon, cfg)
        asr = autoscale_scalar(routed, carbon, cfg)
        np.testing.assert_array_equal(av.replicas, asr.replicas)
        for f in ("served", "dropped", "emissions_g"):
            assert np.max(np.abs(getattr(av, f) - getattr(asr, f))) <= TOL, f


def test_autoscale_ramp_and_bounds():
    T, R = 20, 2
    routed = np.full((T, R), 1e9)          # unbounded demand
    carbon = np.full((T, R), 100.0)
    cfg = ReplicaConfig(max_replicas=10, min_replicas=1, max_step=2)
    res = autoscale(routed, carbon, cfg)
    # ramps by max_step per epoch from min_replicas, saturates at max
    np.testing.assert_array_equal(res.replicas[:, 0][:6], [3, 5, 7, 9, 10, 10])
    assert np.all(res.replicas >= cfg.min_replicas)
    assert np.all(res.replicas <= cfg.max_replicas)
    np.testing.assert_allclose(res.served + res.dropped, routed)


def test_autoscale_budget_cap_binds():
    rng = np.random.default_rng(4)
    T, R = 30, 3
    routed = rng.gamma(2.0, 80_000.0, (T, R))
    carbon = 100.0 + 500.0 * rng.random((T, R))
    # min_replicas=0 + big max_step: every replica is optional, so the
    # greedy's admitted grams must sit under the cap every epoch
    budget = 4.0
    cfg = ReplicaConfig(max_replicas=8, min_replicas=0, max_step=8,
                        budget_g_per_epoch=budget)
    res = autoscale(routed, carbon, cfg)
    assert np.all(res.emissions_g.sum(axis=1) <= budget * (1 + 1e-12))
    # and the cap actually binds vs the uncapped run
    un = autoscale(routed, carbon, ReplicaConfig(max_replicas=8,
                                                 min_replicas=0, max_step=8))
    assert un.emissions_g.sum() > res.emissions_g.sum()


def test_autoscale_zero_intensity_parity():
    # a zero-carbon epoch makes every replica free: both backends must
    # admit the free entries first, agree exactly, and not trip numpy's
    # overflow warning (the old 1e-300 guard scored them ~1e300)
    import warnings
    rng = np.random.default_rng(6)
    T, R = 24, 3
    routed = rng.gamma(2.0, 60_000.0, (T, R))
    carbon = 100.0 + 500.0 * rng.random((T, R))
    carbon[5] = 0.0                       # whole epoch free
    carbon[11, 1] = 0.0                   # one free region among paid ones
    cfg = ReplicaConfig(max_replicas=8, min_replicas=0, max_step=8,
                        budget_g_per_epoch=2.0)
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        av = autoscale(routed, carbon, cfg)
        asr = autoscale_scalar(routed, carbon, cfg)
    np.testing.assert_array_equal(av.replicas, asr.replicas)
    for f in ("served", "dropped", "emissions_g"):
        assert np.max(np.abs(getattr(av, f) - getattr(asr, f))) <= TOL, f
    # free epoch: demand fully served up to capacity, zero grams booked
    assert np.all(av.emissions_g[5] == 0.0)
    assert np.all(av.replicas[5] == np.minimum(
        np.ceil(routed[5] / av.cap1), cfg.max_replicas))


def test_replica_config_validation():
    with pytest.raises(ValueError):
        ReplicaConfig(min_replicas=5, max_replicas=2)
    with pytest.raises(ValueError):
        ReplicaConfig(throughput_rps=0.0)
    with pytest.raises(ValueError):
        ReplicaConfig(max_step=-1)


# ---------------------------------------------------------------------------
# end-to-end pipeline
# ---------------------------------------------------------------------------

def _pipeline_scenario(seed=0, T=96, R=3):
    pop = UserPopulation(n_users=150_000, n_regions=R, seed=seed)
    arr = request_matrix(pop, T, 300.0)
    rng = np.random.default_rng(seed + 10)
    carbon = 100.0 + 500.0 * rng.random((T, R))
    return pop, arr, carbon


def test_pipeline_numpy_matches_scalar():
    pop, arr, carbon = _pipeline_scenario()
    cfg = TrafficConfig(population=pop,
                        replicas=ReplicaConfig(max_replicas=8, max_step=2,
                                               budget_g_per_epoch=6.0))
    rn = simulate_traffic(arr.requests, carbon, cfg, backend="numpy")
    rs = simulate_traffic(arr.requests, carbon, cfg, backend="scalar")
    np.testing.assert_array_equal(rn.replicas, rs.replicas)
    for f in ("routed", "served", "dropped_route", "dropped_cap",
              "violations", "emissions_g"):
        assert np.max(np.abs(getattr(rn, f) - getattr(rs, f))) <= TOL, f
    # ledger closes: offered == served + dropped (route + capacity)
    np.testing.assert_allclose(rn.served_total + rn.dropped_total,
                               rn.offered_total, rtol=1e-9)


def test_carbon_router_beats_latency_router():
    """The headline claim: at an SLO bound generous enough that both
    policies violate nothing, carbon routing serves the same traffic at
    lower carbon-per-request than latency routing."""
    pop, arr, carbon = _pipeline_scenario(seed=3)
    reps = ReplicaConfig(max_replicas=16, max_step=16)
    slo = 1000.0                 # everything feasible: violations == 0
    rc = simulate_traffic(arr.requests, carbon, TrafficConfig(
        population=pop, replicas=reps,
        routing=RoutingConfig(slo_ms=slo, policy="carbon")))
    rl = simulate_traffic(arr.requests, carbon, TrafficConfig(
        population=pop, replicas=reps,
        routing=RoutingConfig(slo_ms=slo, policy="latency")))
    assert rc.violation_total == 0.0 and rl.violation_total == 0.0
    assert rc.carbon_per_request_g < rl.carbon_per_request_g
    np.testing.assert_allclose(rc.served_total, rl.served_total, rtol=1e-6)


def test_simulate_traffic_input_validation():
    pop, arr, carbon = _pipeline_scenario()
    cfg = TrafficConfig(population=pop)
    with pytest.raises(ValueError):
        simulate_traffic(arr.requests[:, :2], carbon, cfg)
    with pytest.raises(ValueError):
        simulate_traffic(arr.requests, carbon, cfg, backend="bogus")
    with pytest.raises(ValueError):
        TrafficConfig(population=pop,
                      latency_ms=((1.0, 2.0),)).latency_matrix()


# ---------------------------------------------------------------------------
# sweep integration (fleet backend; the jax twin lives in
# tests/test_traffic_jax.py)
# ---------------------------------------------------------------------------

def _sweep_setup():
    fam = paper_family()
    traces = [t.util for t in sample_population(6, days=1, seed=5)]
    provs = [TraceProvider.for_region(r, hours=24, seed=1)
             for r in ("PL", "NL", "CAISO")]
    eng = PlacementEngine(fam, provs,
                          config=PlacementConfig(capacity=4, min_dwell=4))
    pols = {"cc_energy": lambda: CarbonContainerPolicy("energy")}
    cfgb = SimConfig(target_rate=0.0)
    tc = TrafficConfig(
        population=UserPopulation(n_users=100_000, n_regions=3, seed=3),
        replicas=ReplicaConfig(max_replicas=8, max_step=2))
    return fam, traces, eng, pols, cfgb, tc


def test_sweep_population_fleet_with_traffic():
    fam, traces, eng, pols, cfgb, tc = _sweep_setup()
    rows = sweep_population(pols, fam, traces, None, [30.0, 60.0], cfgb,
                            backend="fleet", placement=eng, traffic=tc)
    base = sweep_population(pols, fam, traces, None, [30.0, 60.0], cfgb,
                            backend="fleet", placement=eng)
    assert len(rows) == len(base) == 2
    for row in rows:
        assert row["traffic_offered"] > 0
        assert row["traffic_served"] > 0
        assert row["traffic_carbon_per_request_g"] > 0
        np.testing.assert_allclose(
            row["traffic_served"] + row["traffic_dropped"],
            row["traffic_offered"], rtol=1e-9)
    # the modulation actually feeds the fleet: rates differ from the
    # unmodulated sweep, and traffic metrics are row-invariant (one
    # shared plan ahead of the policy/target fan-out)
    assert rows[0]["carbon_rate_mean"] != base[0]["carbon_rate_mean"]
    assert (rows[0]["traffic_served"] == rows[1]["traffic_served"])


def test_sweep_traffic_requires_placement_and_vector_backend():
    fam, traces, eng, pols, cfgb, tc = _sweep_setup()
    carbon = TraceProvider.for_region("CAISO", hours=24, seed=1)
    with pytest.raises(ValueError, match="placement"):
        sweep_population(pols, fam, traces, carbon, [30.0], cfgb,
                         backend="fleet", traffic=tc)
    with pytest.raises(ValueError, match="backend"):
        sweep_population(pols, fam, traces, carbon, [30.0], cfgb, traffic=tc)
    bad = TrafficConfig(population=UserPopulation(n_users=1000, n_regions=2))
    with pytest.raises(ValueError, match="regions"):
        sweep_population(pols, fam, traces, None, [30.0], cfgb,
                         backend="fleet", placement=eng, traffic=bad)
