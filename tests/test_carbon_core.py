"""Carbon Containers core: policy invariants, simulator behaviour, and the
paper's headline claims (reproduced at test scale)."""
import numpy as np
import pytest

from repro.carbon.intensity import ConstantProvider, TraceProvider
from repro.carbon.regions import REGIONS, tier_means
from repro.carbon.traces import synth_trace, trace_cov
from repro.cluster.migration import MigrationCostModel
from repro.cluster.slices import paper_family, tpu_v5e_family
from repro.core.policy import (CarbonAgnosticPolicy, CarbonContainerPolicy,
                               SuspendResumePolicy, VScaleOnlyPolicy)
from repro.core.simulator import SimConfig, simulate
from repro.power.model import LinearPowerModel, calibrate_linear
from repro.workload.azure_like import population_stats, sample_population


# ---------------------------------------------------------------------------
# Data layers (paper §2 claims)
# ---------------------------------------------------------------------------

def test_region_table_matches_paper_aggregates():
    avgs = [r.avg for r in REGIONS.values()]
    assert len(REGIONS) == 27
    assert max(avgs) / min(avgs) > 500.0
    covs = [r.cov for r in REGIONS.values()]
    assert abs(np.mean([c < 0.05 for c in covs]) - 1 / 3) < 0.05
    means = tier_means()
    assert abs(means["low"] - 551) / 551 < 0.10
    assert abs(means["mid"] - 344) / 344 < 0.10
    assert abs(means["high"] - 189) / 189 < 0.10
    # low-CoV regions have ~2x the carbon of high-CoV regions (paper)
    assert means["low"] > 1.8 * means["high"]


@pytest.mark.parametrize("region", ["PL", "NL", "CAISO"])
def test_synthetic_traces_hit_target_cov(region):
    tr = synth_trace(region, hours=24 * 120, seed=0)
    assert (tr > 0).all()
    got, want = trace_cov(tr), REGIONS[region].cov
    assert abs(got - want) / want < 0.25, (got, want)


def test_workload_population_matches_azure_stats():
    stats = population_stats(sample_population(250, days=3, seed=0))
    assert abs(stats["frac_cov_below_0.25"] - 0.08) < 0.08
    assert stats["frac_cov_above_0.4"] > 0.5
    assert abs(stats["frac_cov_above_1.0"] - 0.30) < 0.10
    assert abs(stats["frac_mean_below_0.10"] - 0.43) < 0.12


def test_workload_matrix_generator_matches_azure_stats():
    """The (N,)-vectorized generator must hit the same calibration
    windows as the per-VM scalar one (it feeds the N=1M sweep, where
    the scalar generator's Python loops are infeasible)."""
    from repro.workload.azure_like import sample_population_matrix

    mat = sample_population_matrix(1000, days=3, seed=0)
    assert mat.shape == (3 * 288, 1000)
    assert mat.min() >= 0.0 and mat.max() <= 1.0
    stats = population_stats(mat)
    assert abs(stats["frac_cov_below_0.25"] - 0.08) < 0.08
    assert stats["frac_cov_above_0.4"] > 0.5
    assert abs(stats["frac_cov_above_1.0"] - 0.30) < 0.10
    assert abs(stats["frac_mean_below_0.10"] - 0.43) < 0.12


def test_power_model_calibration():
    truth = LinearPowerModel(100.0, 200.0)
    utils = np.linspace(0, 1, 20)
    watts = [truth.power(u) for u in utils]
    fit, r2 = calibrate_linear(utils, watts)
    assert r2 > 0.999
    assert abs(fit.base_w - 100) < 1 and abs(fit.peak_w - 200) < 1
    # inverse model
    assert abs(truth.util_for_power(150.0) - 0.5) < 1e-9
    assert truth.util_for_power(50.0) == 0.0


def test_migration_cost_linear_and_paper_scale():
    m = MigrationCostModel()
    t7 = m.stop_and_copy_time(7.0)
    assert t7 < 120.0, "paper: 7 GB stop-and-copy under 2 minutes"
    # linearity
    ts = [m.stop_and_copy_time(g) for g in (1.0, 2.0, 4.0)]
    assert abs((ts[2] - ts[1]) - 2 * (ts[1] - ts[0])) < 1e-6


# ---------------------------------------------------------------------------
# Policy unit behaviour
# ---------------------------------------------------------------------------

def _run(policy, demand, c_gkwh, target, hours=24, **kw):
    fam = kw.pop("family", paper_family())
    n = int(hours * 12)
    trace = np.full(n, demand)
    cfg = SimConfig(target_rate=target, state_gb=0.5, **kw)
    return simulate(policy, fam, trace, ConstantProvider(c_gkwh), cfg)


def test_enforcement_holds_target():
    # agnostic would emit 160W * 400 g/kWh = 64 g/hr; target 40
    res = _run(CarbonContainerPolicy("energy"), 0.6, 400.0, 40.0)
    assert res.avg_carbon_rate <= 40.0 * 1.02


def test_agnostic_exceeds_when_over_target():
    res = _run(CarbonAgnosticPolicy(), 0.6, 400.0, 40.0)
    assert res.avg_carbon_rate > 40.0


def test_ee_migrates_down_when_underutilized():
    # demand 0.2 fits the 0.25x slice; EE should end up there
    res = _run(CarbonContainerPolicy("energy"), 0.2, 100.0, 1000.0)
    assert res.time_on_slice.get("x0.25", 0) > 0.9
    assert res.avg_throttle_pct < 0.5


def test_performance_variant_holds_headroom():
    res_e = _run(CarbonContainerPolicy("energy"), 0.2, 100.0, 60.0)
    res_p = _run(CarbonContainerPolicy("performance"), 0.2, 100.0, 60.0)
    assert res_p.avg_carbon_rate >= res_e.avg_carbon_rate
    big_p = sum(v for k, v in res_p.time_on_slice.items() if k in ("x2", "x4"))
    big_e = sum(v for k, v in res_e.time_on_slice.items() if k in ("x2", "x4"))
    assert big_p >= big_e


def test_suspend_when_floor_exceeds_target():
    # smallest slice base = 25 W; at 800 g/kWh idle floor = 20 g/hr > target 10
    res = _run(CarbonContainerPolicy("energy"), 0.5, 800.0, 10.0)
    assert res.suspended_frac > 0.9
    assert res.avg_carbon_rate <= 10.0


def test_resume_when_carbon_drops():
    fam = paper_family()
    # first 12 h at 800 g/kWh (suspend), then 12 h at 50 (resume)
    hourly = [800.0] * 12 + [50.0] * 12
    trace = np.full(24 * 12, 0.3)
    res = simulate(CarbonContainerPolicy("energy"), fam, trace,
                   TraceProvider(hourly), SimConfig(target_rate=12.0))
    assert 0.2 < res.suspended_frac < 0.8
    assert res.avg_carbon_rate <= 12.0


def test_vscale_only_never_migrates():
    res = _run(VScaleOnlyPolicy(), 0.9, 500.0, 40.0)
    assert res.migrations == 0
    assert res.avg_carbon_rate <= 40.0


def test_suspend_resume_baseline_behaviour():
    res = _run(SuspendResumePolicy(), 0.6, 400.0, 40.0)
    assert res.suspended_frac == 1.0     # constant carbon: never resumes
    res2 = _run(SuspendResumePolicy(), 0.6, 100.0, 40.0)
    assert res2.suspended_frac == 0.0


def test_unavailable_slice_is_skipped():
    fam = paper_family()
    fam.available[0] = False             # 0.25x slice gone
    res = _run(CarbonContainerPolicy("energy"), 0.2, 100.0, 1000.0, family=fam)
    assert res.time_on_slice.get("x0.25", 0) == 0
    assert res.time_on_slice.get("x0.5", 0) > 0.9


# ---------------------------------------------------------------------------
# The paper's headline comparison (test-scale Figs 11-14)
# ---------------------------------------------------------------------------

def test_policy_ordering_reproduces_paper():
    fam = paper_family()
    carbon = TraceProvider.for_region("NL", hours=24 * 4, seed=1)
    traces = [t.util for t in sample_population(4, days=4, seed=2)]
    target = 45.0
    results = {}
    for name, mk in [("sr", SuspendResumePolicy),
                     ("vs", lambda: VScaleOnlyPolicy()),
                     ("cc", lambda: CarbonContainerPolicy("energy"))]:
        thr, rate = [], []
        for tr in traces:
            r = simulate(mk(), fam, tr, carbon, SimConfig(target_rate=target))
            thr.append(r.avg_throttle_pct)
            rate.append(r.avg_carbon_rate)
        results[name] = (np.mean(rate), np.mean(thr))
    # everything under target
    for rate, _ in results.values():
        assert rate <= target * 1.02
    # throttling: cc < vscale < suspend/resume (Fig 12/14 ordering)
    assert results["cc"][1] < results["vs"][1]
    assert results["vs"][1] < results["sr"][1]


def test_tpu_family_power_monotone():
    fam = tpu_v5e_family()
    bases = [s.power.base_w for s in fam.slices]
    peaks = [s.power.peak_w for s in fam.slices]
    assert bases == sorted(bases) and peaks == sorted(peaks)
    assert all(p > b for b, p in zip(bases, peaks))
