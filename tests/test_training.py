"""Training stack: optimizer math, grad accumulation, checkpoint round-trip
with resharding, compression error feedback, and loss-decrease integration."""
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import OptimizerConfig, TrainConfig
from repro.configs import get_arch
from repro.data.pipeline import markov_stream
from repro.models import get_model
from repro.train import checkpoint as CKPT
from repro.train import compression as COMP
from repro.train import loop as TL
from repro.train import optimizer as OPT

pytestmark = pytest.mark.slow  # JAX model/kernel suite: excluded from the fast lane

KEY = jax.random.PRNGKey(0)


def test_lr_schedule():
    cfg = OptimizerConfig(lr=1.0, warmup_steps=10, total_steps=110,
                          schedule="cosine")
    assert float(OPT.lr_at(cfg, jnp.asarray(0))) == 0.0
    assert abs(float(OPT.lr_at(cfg, jnp.asarray(10))) - 1.0) < 1e-6
    assert float(OPT.lr_at(cfg, jnp.asarray(110))) < 1e-6
    mid = float(OPT.lr_at(cfg, jnp.asarray(60)))
    assert 0.4 < mid < 0.6


def test_adamw_against_manual_step():
    cfg = OptimizerConfig(lr=0.1, b1=0.9, b2=0.99, eps=1e-8, weight_decay=0.0,
                          grad_clip=0.0, warmup_steps=0, total_steps=10,
                          schedule="constant")
    p = {"w": jnp.asarray([1.0, 2.0])}
    g = {"w": jnp.asarray([0.5, -0.5])}
    opt = OPT.adamw_init(p)
    new_p, new_opt, _ = OPT.adamw_update(cfg, g, opt, p, jnp.asarray(0))
    # first step of Adam with bias correction: delta = lr * sign-ish
    m = 0.1 * 0.5 / (1 - 0.9)
    v = 0.01 * 0.25 / (1 - 0.99)
    expect = 1.0 - 0.1 * (m / (np.sqrt(v) + 1e-8))
    np.testing.assert_allclose(float(new_p["w"][0]), expect, rtol=1e-5)


def test_grad_clip():
    g = {"w": jnp.asarray([3.0, 4.0])}      # norm 5
    clipped, norm = OPT.clip_by_global_norm(g, 1.0)
    assert abs(float(norm) - 5.0) < 1e-6
    np.testing.assert_allclose(np.asarray(clipped["w"]), [0.6, 0.8], rtol=1e-6)


def test_grad_accumulation_equivalence():
    cfg = get_arch("smollm-135m").smoke
    model = get_model(cfg)
    params = TL.init_state(model, OptimizerConfig(), KEY)
    batch = {"tokens": jax.random.randint(KEY, (8, 16), 0, cfg.vocab_size),
             "labels": jax.random.randint(KEY, (8, 16), 0, cfg.vocab_size)}
    t_full = TrainConfig(seq_len=16, global_batch=8, microbatch=0,
                         optimizer=OptimizerConfig(grad_clip=0.0))
    t_micro = TrainConfig(seq_len=16, global_batch=8, microbatch=2,
                          optimizer=OptimizerConfig(grad_clip=0.0))
    s1, m1 = jax.jit(TL.make_train_step(model, t_full))(params, batch)
    s2, m2 = jax.jit(TL.make_train_step(model, t_micro))(params, batch)
    # same data, averaged grads -> same update up to fp error
    for a, b in zip(jax.tree.leaves(s1["params"]), jax.tree.leaves(s2["params"])):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=2e-3)


def test_checkpoint_roundtrip_and_manifest():
    state = {"a": jnp.arange(10, dtype=jnp.float32),
             "b": {"c": jnp.ones((3, 4), jnp.bfloat16)},
             "step": jnp.asarray(7, jnp.int32)}
    with tempfile.TemporaryDirectory() as d:
        info = CKPT.save(d, state, step=7)
        assert info["bytes"] > 0
        man = CKPT.manifest(d)
        assert man["step"] == 7
        abstract = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state)
        restored = CKPT.load(d, abstract)
        for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a, np.float32),
                                          np.asarray(b, np.float32))


def test_checkpoint_manager_gc_and_latest():
    with tempfile.TemporaryDirectory() as d:
        mgr = CKPT.CheckpointManager(d, keep=2, async_save=False)
        state = {"w": jnp.zeros(4)}
        for s in (1, 2, 3, 4):
            mgr.save(s, state)
        assert mgr.all_steps() == [3, 4]
        assert mgr.latest_step() == 4


def test_async_checkpoint():
    with tempfile.TemporaryDirectory() as d:
        mgr = CKPT.CheckpointManager(d, keep=2, async_save=True)
        mgr.save(5, {"w": jnp.arange(1000, dtype=jnp.float32)})
        mgr.wait()
        assert mgr.latest_step() == 5


def test_save_returns_info_and_last_info_accessor():
    # sync save() returns the info dict; async returns None but
    # last_info() waits and exposes it — callers never need _last_info
    with tempfile.TemporaryDirectory() as d:
        mgr = CKPT.CheckpointManager(d, keep=2, async_save=False)
        info = mgr.save(1, {"w": jnp.arange(16, dtype=jnp.float32)})
        assert info is not None and info.get("bytes", 0) > 0
        assert mgr.last_info() == info
    with tempfile.TemporaryDirectory() as d:
        mgr = CKPT.CheckpointManager(d, keep=2, async_save=True)
        assert mgr.save(2, {"w": jnp.arange(16, dtype=jnp.float32)}) is None
        info = mgr.last_info()                 # waits for the writer
        assert info is not None and info.get("bytes", 0) > 0
        assert mgr.latest_step() == 2


def test_compression_error_feedback():
    g = {"w": jnp.asarray(np.linspace(-1, 1, 64), jnp.float32)}
    ef = COMP.ef_init(g)
    out, ef2 = COMP.compress_int8(g, ef)
    err1 = np.abs(np.asarray(out["w"]) - np.asarray(g["w"])).max()
    assert err1 < 0.02
    # error feedback: residual is carried, second pass re-injects it
    out2, ef3 = COMP.compress_int8(g, ef2)
    assert np.abs(np.asarray(ef3["w"])).mean() <= 0.02
    # topk keeps largest entries
    outk, _ = COMP.compress_topk(g, COMP.ef_init(g), ratio=0.25)
    kept = np.count_nonzero(np.asarray(outk["w"]))
    assert kept == 16


def test_training_reduces_loss_on_learnable_data():
    cfg = get_arch("smollm-135m").smoke
    model = get_model(cfg)
    tcfg = TrainConfig(seq_len=32, global_batch=8, steps=30, log_every=0,
                       optimizer=OptimizerConfig(lr=3e-3, warmup_steps=5,
                                                 total_steps=30))
    data = markov_stream(cfg.vocab_size, 32, 8, seed=0, temperature=0.2)
    out = TL.run(model, tcfg, data)
    losses = [h["loss"] for h in out["history"]]
    assert losses[-1] < losses[0] - 0.1, (losses[0], losses[-1])


def test_compressed_training_still_learns():
    cfg = get_arch("smollm-135m").smoke
    model = get_model(cfg)
    tcfg = TrainConfig(seq_len=32, global_batch=8, steps=25, log_every=0,
                       optimizer=OptimizerConfig(lr=3e-3, warmup_steps=5,
                                                 total_steps=25,
                                                 compression="int8"))
    data = markov_stream(cfg.vocab_size, 32, 8, seed=0, temperature=0.2)
    out = TL.run(model, tcfg, data)
    losses = [h["loss"] for h in out["history"]]
    assert losses[-1] < losses[0] - 0.05
