"""JAX elasticity scan: parity with the NumPy layer and the fleet sweep.

The scan mirrors `repro.core.elasticity` term for term (consuming the
same host-precomputed forecast and budget series), so allocated level
counts must be *identical* —
not merely close — on both the dense and indexed carbon layouts; float
streams get the backend parity budget (1e-6) though in practice they
agree to ~1e-13.
"""
import numpy as np
import pytest

jax = pytest.importorskip("jax")

from repro.core.fleet_jax import ensure_cpu_xla_flags  # noqa: E402

ensure_cpu_xla_flags()

from repro.core.elasticity import (ElasticityConfig,  # noqa: E402
                                   simulate_elastic)
from repro.core.elasticity_jax import simulate_elastic_jax  # noqa: E402

TOL = 1e-6
CFG = dict(k_levels=4, unit_capacity=1.5, base_w=50.0, peak_w=200.0,
           min_level=1, max_step=1)


def _inputs(T=48, N=12, R=3, seed=0):
    rng = np.random.default_rng(seed)
    demand = np.abs(rng.normal(3.0, 1.5, (T, N)))
    region_mat = np.abs(rng.normal(300.0, 150.0, (T, R)))
    region_mat[5] = 0.0                      # zero-intensity epoch
    codes = rng.integers(0, R, (T, N)).astype(np.int32)
    dense = region_mat[np.arange(T)[:, None], codes]
    return demand, region_mat, codes, dense


@pytest.mark.parametrize("budget", [None, 2.0])
@pytest.mark.parametrize("mode", ["oracle", "persistence", "forecast"])
def test_jax_matches_numpy_dense_and_indexed(mode, budget):
    demand, region_mat, codes, dense = _inputs()
    cfg = ElasticityConfig(budget_g_per_epoch=budget, forecast=mode, **CFG)
    a = simulate_elastic(demand, dense, cfg, 300.0)
    for carbon in (dense, (region_mat, codes)):
        b = simulate_elastic_jax(demand, carbon, cfg, 300.0, record=True)
        np.testing.assert_array_equal(a.levels, b.levels)
        scale = max(float(np.max(np.abs(a.served_w))), 1.0)
        assert np.max(np.abs(a.served_w - b.served_w)) <= TOL * scale
        assert abs(a.emissions_g - b.emissions_g) <= TOL * max(
            abs(a.emissions_g), 1.0)
        assert a.cap_violations == b.cap_violations
        assert a.summary()["elastic_level_epochs"] \
            == b.summary()["elastic_level_epochs"]


def test_record_false_summary_matches_record_true():
    demand, region_mat, codes, _ = _inputs(seed=2)
    cfg = ElasticityConfig(budget_g_per_epoch=1.5, **CFG)
    a = simulate_elastic_jax(demand, (region_mat, codes), cfg, 300.0,
                             record=True)
    b = simulate_elastic_jax(demand, (region_mat, codes), cfg, 300.0,
                             record=False)
    assert b.levels.shape[0] == 0
    sa, sb = a.summary(), b.summary()
    for k in sa:
        assert sa[k] == pytest.approx(sb[k], rel=1e-12), k


def test_sweep_population_jax_with_elasticity_matches_fleet():
    from repro.carbon.intensity import TraceProvider
    from repro.cluster.placement import PlacementConfig, PlacementEngine
    from repro.cluster.slices import paper_family
    from repro.core.policy import (CarbonAgnosticPolicy,
                                   CarbonContainerPolicy)
    from repro.core.simulator import SimConfig, sweep_population
    from repro.traffic import TrafficConfig, UserPopulation
    from repro.traffic.autoscale import ReplicaConfig
    from repro.workload.azure_like import sample_population

    fam = paper_family()
    traces = [t.util for t in sample_population(6, days=1, seed=5)]
    provs = [TraceProvider.for_region(r, hours=24, seed=1)
             for r in ("PL", "NL", "CAISO")]
    pols = {"cc_energy": lambda: CarbonContainerPolicy("energy"),
            "carbon_agnostic": CarbonAgnosticPolicy}
    cfgb = SimConfig(target_rate=0.0)
    ec = ElasticityConfig(k_levels=4, unit_capacity=0.3,
                          budget_g_per_epoch=100.0, forecast="forecast",
                          shape_budget=True)
    tc = TrafficConfig(
        population=UserPopulation(n_users=100_000, n_regions=3, seed=3),
        replicas=ReplicaConfig(max_replicas=8, max_step=2))
    for traffic in (None, tc):
        mk = lambda: PlacementEngine(
            fam, provs, config=PlacementConfig(capacity=4, min_dwell=4))
        rows_f = sweep_population(pols, fam, traces, None, [30.0, 60.0],
                                  cfgb, backend="fleet", placement=mk(),
                                  traffic=traffic, elasticity=ec)
        rows_j = sweep_population(pols, fam, traces, None, [30.0, 60.0],
                                  cfgb, backend="jax", placement=mk(),
                                  traffic=traffic, elasticity=ec)
        assert len(rows_f) == len(rows_j) == 4
        for a, b in zip(rows_f, rows_j):
            assert a["policy"] == b["policy"]
            # level-epoch totals are integer counts: exact on both paths
            assert a["elastic_level_epochs"] == b["elastic_level_epochs"]
            assert a["elastic_cap_violations"] \
                == b["elastic_cap_violations"] == 0
            for k in ("carbon_rate_mean", "throttle_mean",
                      "migrations_mean", "elastic_served_work",
                      "elastic_emissions_g", "elastic_served_frac"):
                scale = max(abs(a[k]), 1.0)
                assert abs(a[k] - b[k]) <= TOL * scale, k


def test_shaped_budget_levels_exact_across_backends():
    # budget shaping swaps the scalar cap for a per-epoch series; the
    # series is precomputed host-side from the same signal on both
    # backends, so level counts stay bit-equal, not merely close
    demand, region_mat, codes, dense = _inputs(T=72, N=20, seed=4)
    for mode in ("oracle", "persistence", "forecast"):
        cfg = ElasticityConfig(budget_g_per_epoch=2.0, forecast=mode,
                               shape_budget=True, **CFG)
        a = simulate_elastic(demand, dense, cfg, 3600.0)
        b = simulate_elastic_jax(demand, (region_mat, codes), cfg, 3600.0,
                                 record=True)
        np.testing.assert_array_equal(a.levels, b.levels)
        assert a.cap_violations == b.cap_violations == 0


def test_shape_validation():
    demand, region_mat, codes, _ = _inputs()
    cfg = ElasticityConfig(**CFG)
    with pytest.raises(ValueError):
        simulate_elastic_jax(demand[0], region_mat, cfg)
    with pytest.raises(ValueError):
        simulate_elastic_jax(demand, (region_mat[:10], codes), cfg)
    with pytest.raises(ValueError):
        simulate_elastic_jax(demand, np.zeros((4, 4)), cfg)
