"""Sharding rules (divisibility dropping), HLO collective parser, and the
elastic/serve integration paths that fit on 1 CPU device."""
import numpy as np
import pytest

import jax
from jax.sharding import Mesh

from repro.launch import hlo_analysis as HLO
from repro.models.sharding import logical_to_pspec

pytestmark = pytest.mark.slow  # JAX model/kernel suite: excluded from the fast lane


def _fake_mesh(shape=(2, 4), axes=("data", "model")):
    devs = np.array([jax.devices()[0]] * int(np.prod(shape))).reshape(shape)
    return Mesh(devs, axes)


def test_logical_to_pspec_divisibility_drop():
    mesh = _fake_mesh()
    # divisible: keeps axes
    p = logical_to_pspec(("batch", "tp"), (8, 12), mesh)
    assert p == jax.sharding.PartitionSpec("data", "model")
    # batch=1: drops data
    p = logical_to_pspec(("batch", "tp"), (1, 12), mesh)
    assert p == jax.sharding.PartitionSpec(None, "model")
    # heads=3 not divisible by 4: drops model
    p = logical_to_pspec(("batch", "tp"), (8, 3), mesh)
    assert p == jax.sharding.PartitionSpec("data", None)


def test_logical_to_pspec_no_axis_reuse():
    mesh = _fake_mesh()
    p = logical_to_pspec(("tp", "tp"), (8, 8), mesh)
    assert p == jax.sharding.PartitionSpec("model", None)


def test_pod_axis_multiplies_batch():
    mesh = _fake_mesh((2, 2, 2), ("pod", "data", "model"))
    p = logical_to_pspec(("batch", None), (8, 4), mesh)
    assert p == jax.sharding.PartitionSpec(("pod", "data"), None)
    # batch=2: keeps pod only (single mesh axes are unwrapped to the
    # bare name, so compare against the unwrapped form)
    p = logical_to_pspec(("batch", None), (2, 4), mesh)
    assert p == jax.sharding.PartitionSpec("pod", None)


HLO_SAMPLE = """
HloModule jit_step

%body.1 (arg: (f32[8], s32[])) -> (f32[8], s32[]) {
  %ar = f32[8]{0} all-reduce(f32[8]{0} %x), replica_groups=[2,8]<=[16], to_apply=%add
  %cp = f32[4]{0} collective-permute(f32[4]{0} %y), source_target_pairs={{0,1}}
  ROOT %t = tuple(...)
}

ENTRY %main.2 (p0: f32[8]) -> f32[8] {
  %w = (f32[8], s32[]) while((f32[8], s32[]) %init), condition=%cond.1, body=%body.1, backend_config={"known_trip_count":{"n":"10"}}
  %ag = f32[64]{0} all-gather(f32[8]{0} %z), replica_groups={{0,1,2,3,4,5,6,7}}, dimensions={0}
  ROOT %r = f32[8]{0} get-tuple-element(%w), index=0
}
"""


def test_hlo_collective_parser_trip_counts():
    out = HLO.analyze_collectives(HLO_SAMPLE)
    ar = out["per_kind"]["all-reduce"]
    # 10 iterations x 32 bytes, ring cost 2*(n-1)/n with n=8
    assert ar["count"] == 10
    np.testing.assert_allclose(ar["wire_bytes"], 10 * 2 * 32 * 7 / 8)
    ag = out["per_kind"]["all-gather"]
    assert ag["count"] == 1
    np.testing.assert_allclose(ag["wire_bytes"], 256 * 7 / 8)
    cp = out["per_kind"]["collective-permute"]
    assert cp["count"] == 10
    assert out["total_wire_bytes"] > 0


def test_shape_bytes_tuples():
    assert HLO._shape_bytes("f32[8]") == 32
    assert HLO._shape_bytes("(bf16[4,2], s32[3])") == 16 + 12
    assert HLO._shape_bytes("pred[16]") == 16


def test_elastic_migration_preserves_state():
    import tempfile
    from repro.config import OptimizerConfig, TrainConfig
    from repro.configs import get_arch
    from repro.core.elastic import ElasticJob
    from repro.data.pipeline import SyntheticLM
    from repro.models import get_model

    cfg = get_arch("smollm-135m").smoke
    model = get_model(cfg)
    tcfg = TrainConfig(seq_len=16, global_batch=4,
                       optimizer=OptimizerConfig(lr=1e-3, warmup_steps=1,
                                                 total_steps=20))
    devs = jax.devices()
    with tempfile.TemporaryDirectory() as d:
        job = ElasticJob(model, tcfg, d)
        job.start(devs[:1])
        data = iter(SyntheticLM(cfg.vocab_size, 16, 4))
        job.train_step(next(data))
        w_before = np.asarray(
            jax.tree.leaves(job.state["params"])[0], np.float32).copy()
        step_before = int(job.state["step"])
        job.migrate(devs[:1])          # same size (1 CPU) but full round-trip
        w_after = np.asarray(
            jax.tree.leaves(job.state["params"])[0], np.float32)
        np.testing.assert_array_equal(w_before, w_after)
        assert int(job.state["step"]) == step_before


def test_serve_engine_generates():
    from repro.configs import get_arch
    from repro.models import get_model
    from repro.serve.engine import ServeEngine

    cfg = get_arch("smollm-135m").smoke
    engine = ServeEngine(get_model(cfg)).load()
    prompts = np.random.default_rng(0).integers(
        0, cfg.vocab_size, (2, 8)).astype(np.int32)
    out = engine.generate(prompts, 4)
    assert out["tokens"].shape == (2, 4)
    assert (out["tokens"] >= 0).all() and (out["tokens"] < cfg.vocab_size).all()


def test_scheduler_backpressure():
    from repro.serve.scheduler import CarbonAwareScheduler

    sch = CarbonAwareScheduler(capacity_tok_s=10.0)
    for i in range(20):
        sch.offer(arrival_s=i * 10.0, max_new=100)
    d_full = sch.demand()
    assert d_full > 0
    r1 = sch.run_interval(duty=1.0, slice_multiple=1.0)
    r2 = sch.run_interval(duty=0.25, slice_multiple=1.0)
    assert r1["tokens"] >= r2["tokens"]
    assert sch.latency_stats()["n"] == len(sch.completed)


def test_straggler_detector():
    from repro.distributed.stragglers import StragglerDetector

    det = StragglerDetector(window=16, threshold=1.5, patience=3)
    action = None
    for _ in range(16):
        action = det.observe(1.0)
    assert action is None
    for _ in range(3):
        action = det.observe(2.5)
    assert action == "migrate"


def test_dryrun_cell_builds_on_local_mesh():
    """The launch path end-to-end at CI scale: build+lower+compile a smoke
    config train cell on a 1-device mesh and parse its artifacts."""
    import dataclasses
    from repro.configs import get_arch
    from repro.launch import dryrun_lib as DL

    mesh = _real_mesh()
    cfg = get_arch("smollm-135m").smoke
    compiled, meta = DL.lower_and_compile(
        "smollm-135m", "train_4k", mesh,
        cfg=dataclasses.replace(cfg, n_layers=2), remat="full")
    assert meta["compile_s"] > 0
    mem = HLO.memory_stats(compiled)
    assert mem["peak_bytes"] > 0
    cost = HLO.cost_stats(compiled)
    assert cost["flops"] > 0
    colls = HLO.analyze_collectives(compiled.as_text())
    assert colls["total_wire_bytes"] == 0.0   # 1 device: no collectives


def _real_mesh():
    import jax
    import numpy as np
    from jax.sharding import Mesh
    return Mesh(np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))


def test_roofline_row_math():
    from repro.launch.roofline import roofline_row
    res = {"status": "ok", "arch": "x", "shape": "train_4k", "devices": 256,
           "cost_probed": {"flops": 197e12, "bytes_accessed": 819e9},
           "cost_raw": {"flops": 1.0, "bytes_accessed": 1.0},
           "collectives": {"total_wire_bytes": 100e9},
           "model_flops_global": 197e12 * 128,
           "memory": {"peak_bytes": 8e9}}
    row = roofline_row(res)
    assert abs(row["compute_s"] - 1.0) < 1e-9
    assert abs(row["memory_s"] - 1.0) < 1e-9
    assert abs(row["collective_s"] - 2.0) < 1e-9
    assert row["dominant"] == "collective"
    assert abs(row["useful_ratio"] - 0.5) < 1e-9
    assert row["fits_hbm"]
