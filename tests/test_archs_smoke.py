"""Per-assigned-architecture smoke tests: reduced config, one forward/train
step + prefill/decode round-trip on CPU; asserts shapes + finiteness, and
that decode-with-cache agrees with full-sequence forward (incremental
consistency)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.configs.registry import all_cells
from repro.models import get_model

pytestmark = pytest.mark.slow  # JAX model/kernel suite: excluded from the fast lane

KEY = jax.random.PRNGKey(0)


def _batch(cfg, B=2, S=16):
    ks = jax.random.split(KEY, 3)
    batch = {"tokens": jax.random.randint(ks[0], (B, S), 0, cfg.vocab_size),
             "labels": jax.random.randint(ks[1], (B, S), 0, cfg.vocab_size)}
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(
            ks[2], (B, cfg.enc_seq, cfg.d_model), jnp.bfloat16)
    return batch


@pytest.mark.parametrize("arch_id", sorted(ARCHS))
def test_train_step_smoke(arch_id):
    cfg = ARCHS[arch_id].smoke
    m = get_model(cfg)
    params = m.init(KEY)
    batch = _batch(cfg)
    loss, metrics = jax.jit(lambda p, b: m.loss(p, b))(params, batch)
    assert np.isfinite(float(loss)), f"{arch_id}: non-finite loss"
    assert float(loss) > 0
    # grads flow to every leaf
    grads = jax.jit(jax.grad(lambda p, b: m.loss(p, b)[0]))(params, batch)
    for leaf in jax.tree.leaves(grads):
        assert np.isfinite(np.asarray(leaf, np.float32)).all(), arch_id


@pytest.mark.parametrize("arch_id", sorted(ARCHS))
def test_prefill_decode_shapes(arch_id):
    cfg = ARCHS[arch_id].smoke
    m = get_model(cfg)
    params = m.init(KEY)
    B, S = 2, 16
    batch = _batch(cfg, B, S)
    batch.pop("labels")
    logits, cache = jax.jit(lambda p, b: m.prefill(p, b))(params, batch)
    assert logits.shape == (B, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    logits2, cache2 = jax.jit(lambda p, c, t: m.decode(p, c, t))(params, cache, tok)
    assert logits2.shape == (B, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits2, np.float32)).all()
    assert int(cache2["pos"]) == int(cache["pos"]) + 1


@pytest.mark.parametrize("arch_id", ["smollm-135m", "mamba2-2.7b",
                                     "recurrentgemma-9b", "whisper-base",
                                     "olmoe-1b-7b"])
def test_decode_consistent_with_forward(arch_id):
    """logits(prefill S tokens; decode token S) == logits(prefill S+1)."""
    import dataclasses
    cfg = ARCHS[arch_id].smoke
    if cfg.family == "moe":
        # capacity drops depend on the token population; a generous factor
        # makes routing deterministic so incremental == full-sequence
        cfg = dataclasses.replace(cfg, capacity_factor=8.0)
    m = get_model(cfg)
    params = m.init(KEY)
    B, S = 2, 12
    full = _batch(cfg, B, S + 1)
    full.pop("labels")
    pre = {k: (v[:, :S] if k == "tokens" else v) for k, v in full.items()}
    logits_pre, cache = m.prefill(params, pre, pad_to=S + 4)
    step_tok = full["tokens"][:, S]
    logits_dec, _ = m.decode(params, cache, step_tok)
    logits_full, _ = m.prefill(params, full)
    np.testing.assert_allclose(np.asarray(logits_dec, np.float32),
                               np.asarray(logits_full, np.float32),
                               atol=0.1, rtol=0.05)


def test_cell_accounting():
    cells = all_cells()
    assert len(cells) == 40
    runnable = [c for c in cells if c[2] == "run"]
    assert len(runnable) == 32
    # long_500k runs exactly for the sub-quadratic archs
    long_runners = {a for a, s, st in cells if s == "long_500k" and st == "run"}
    assert long_runners == {"mamba2-2.7b", "recurrentgemma-9b"}


@pytest.mark.parametrize("arch_id", sorted(ARCHS))
def test_param_specs_consistent(arch_id):
    """Analytic count ≈ spec-tree count (guards config/impl drift)."""
    spec = ARCHS[arch_id]
    m = get_model(spec.full)
    tree_n = m.param_count()
    analytic = spec.full.param_count()
    assert abs(tree_n - analytic) / analytic < 0.02, (tree_n, analytic)
