"""Serving scheduler + replay harness coverage (previously untested).

Pins the scheduler's request ledger (arrival order, backlog
conservation, the baseline-capacity `util` semantics), the vectorized
`poisson_arrivals` bit-parity against a sequential reference, and the
replay harness's tracking-tolerance verdict including the empty-trace
edge case.
"""
import numpy as np
import pytest

from repro.serve.scheduler import CarbonAwareScheduler, poisson_arrivals
from repro.workload.replay import ReplayHarness


def _sequential_poisson(rate_per_s, duration_s, seed=0):
    # the pre-vectorization reference implementation, kept here as the
    # seeded-parity oracle
    rng = np.random.default_rng(seed)
    t, out = 0.0, []
    while True:
        t += rng.exponential(1.0 / max(rate_per_s, 1e-9))
        if t > duration_s:
            return out
        out.append(t)


# ---------------------------------------------------------------------------
# scheduler
# ---------------------------------------------------------------------------

def test_scheduler_serves_in_arrival_order():
    sch = CarbonAwareScheduler(capacity_tok_s=10.0, interval_s=100.0)
    # offered out of order; the heap must serve by arrival time
    for a in (50.0, 10.0, 30.0):
        sch.offer(a, max_new=100)
    res = sch.run_interval(duty=1.0, slice_multiple=1.0)
    assert res["served"] == 3
    done = [r.arrival_s for r in sch.completed]
    assert done == sorted(done) == [10.0, 30.0, 50.0]
    lat = [r.done_s - r.arrival_s for r in sch.completed]
    assert all(v >= 0 for v in lat)      # completion never precedes arrival


def test_scheduler_backlog_conservation():
    sch = CarbonAwareScheduler(capacity_tok_s=10.0, interval_s=100.0)
    n = 12
    for i in range(n):
        sch.offer(float(i), max_new=300)    # 300 tok each, budget 1000/ival
    served_total = 0
    for _ in range(6):
        res = sch.run_interval(duty=1.0, slice_multiple=1.0)
        assert res["served"] + res["backlog"] + served_total == n
        served_total += res["served"]
    assert served_total == n


def test_scheduler_util_is_baseline_capacity_fraction():
    # one 250-token request against a 10 tok/s * 100 s baseline: util
    # must be 0.25 regardless of the duty/slice allocation that served
    # it (the old expression multiplied duty * slice_multiple back in,
    # double-counting the allocation)
    for duty, mult in [(1.0, 1.0), (0.5, 2.0), (1.0, 4.0)]:
        sch = CarbonAwareScheduler(capacity_tok_s=10.0, interval_s=100.0)
        sch.offer(0.0, max_new=250)
        res = sch.run_interval(duty=duty, slice_multiple=mult)
        assert res["served"] == 1
        assert res["util"] == pytest.approx(0.25)


def test_scheduler_demand_uses_configured_interval():
    sch = CarbonAwareScheduler(capacity_tok_s=10.0, interval_s=100.0)
    sch.offer(0.0, max_new=500)
    assert sch.demand() == pytest.approx(0.5)        # 500 / (10 * 100)
    assert sch.demand(window_s=50.0) == pytest.approx(1.0)
    # unthrottled next interval drains it
    res = sch.run_interval(duty=1.0, slice_multiple=1.0)
    assert res["served"] == 1 and sch.demand() == 0.0


def test_scheduler_zero_duty_serves_nothing():
    sch = CarbonAwareScheduler(capacity_tok_s=10.0, interval_s=100.0)
    sch.offer(0.0, max_new=10)
    res = sch.run_interval(duty=0.0, slice_multiple=1.0)
    assert res["served"] == 0 and res["backlog"] == 1
    assert res["util"] == 0.0


def test_scheduler_latency_percentiles():
    sch = CarbonAwareScheduler(capacity_tok_s=10.0, interval_s=100.0)
    assert sch.latency_stats() == {"p50_s": 0.0, "p95_s": 0.0, "n": 0}
    for a in poisson_arrivals(0.2, 300.0, seed=1):
        sch.offer(a, max_new=50)
    for _ in range(4):
        sch.run_interval(duty=1.0, slice_multiple=1.0)
    stats = sch.latency_stats()
    assert stats["n"] == len(sch.completed) > 0
    assert 0.0 <= stats["p50_s"] <= stats["p95_s"]
    assert stats["p95_s"] > 0.0          # the backlog makes some requests wait


# ---------------------------------------------------------------------------
# poisson_arrivals
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("rate,duration", [(0.5, 600.0), (20.0, 600.0),
                                           (3.0, 10_000.0)])
def test_poisson_arrivals_matches_sequential_reference(rate, duration):
    ref = _sequential_poisson(rate, duration, seed=7)
    for chunk in (1, 3, 4096):
        vec = poisson_arrivals(rate, duration, seed=7, chunk=chunk)
        assert vec == ref                      # bit-identical, any chunking


def test_poisson_arrivals_statistics():
    out = np.asarray(poisson_arrivals(5.0, 20_000.0, seed=2))
    assert np.all(np.diff(out) > 0) and out.max() <= 20_000.0
    # event count within 5 sigma of rate * duration
    assert abs(len(out) - 100_000) < 5 * np.sqrt(100_000)
    assert poisson_arrivals(5.0, 0.0, seed=2) == []


# ---------------------------------------------------------------------------
# replay harness
# ---------------------------------------------------------------------------

def test_replay_within_tolerance_verdict():
    h = ReplayHarness(tolerance=0.05)
    trace = 0.5 + 0.3 * np.sin(np.linspace(0, 4 * np.pi, 96))
    rng = np.random.default_rng(0)
    res = h.replay(trace, lambda u: u + rng.normal(0.0, 0.01))
    assert res["within_tolerance"] and res["ma_max_err"] <= 0.05
    assert len(h.history) == 96
    bad = ReplayHarness(tolerance=0.05).replay(trace, lambda u: u + 0.2)
    assert not bad["within_tolerance"]
    assert bad["mean_abs_err"] == pytest.approx(0.2)


def test_replay_empty_trace_is_trivially_tracking():
    h = ReplayHarness()
    res = h.replay([], lambda u: u)
    assert res == {"mean_abs_err": 0.0, "ma_max_err": 0.0,
                   "within_tolerance": True, "achieved": []}
    assert h.history == []


def test_replay_short_trace_uses_short_kernel():
    # shorter than the 12-interval window: kernel shrinks, no nan
    h = ReplayHarness()
    res = h.replay([0.2, 0.4, 0.6], lambda u: u)
    assert res["ma_max_err"] == 0.0 and res["within_tolerance"]
