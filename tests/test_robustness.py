"""Signal-plane fault injection + graceful degradation.

Pins the `repro.robustness` contracts: the degrade ladder's tier
progression and strict causality, determinism of every seeded fault
mask, the conservative mode's never-understate safety property (as a
hypothesis property over arbitrary dropout masks/seeds, plus its
per-epoch gram-budget corollary on a recorded fleet run), the
scalar <-> fleet <-> jax parity of a faulted sweep, the 3-impl planner
parity of seeded migration failures with capped exponential backoff,
and the carbon-trace NaN gap guard (`fill_gaps` / `TraceProvider`).
"""
import numpy as np
import pytest

from repro.carbon.intensity import TraceProvider
from repro.carbon.traces import fill_gaps
from repro.cluster.placement import PlacementConfig, PlacementEngine
from repro.cluster.slices import paper_family
from repro.core.fleet import FleetSimulator
from repro.core.policy import CarbonContainerPolicy
from repro.core.simulator import SimConfig, simulate
from repro.core.spec import SweepSpec
from repro.robustness import (CarbonFeedFaults, DegradeConfig, FaultPlan,
                              MigrationFaults, PowerTelemetryFaults)
from repro.robustness.degrade import (TIER_FLOOR, TIER_FRESH, TIER_HOLD,
                                      TIER_PRIOR, budget_violations,
                                      observe_intensity)
from repro.robustness.faults import (carbon_fault_masks,
                                     migration_failure_mask,
                                     power_gap_vector)

FAM = paper_family()
DT = 300.0


def _diurnal(T, R=1, base=260.0, amp=180.0):
    t = np.arange(T, dtype=np.float64)[:, None]
    ph = np.linspace(0.0, 2.0, R)[None, :]
    return base + amp * np.sin(2 * np.pi * t / 288.0 + ph)


# ---------------------------------------------------------------- ladder

def test_ladder_tier_progression_through_blackout():
    """hold while age<=ttl, then diurnal prior, then the c_max floor."""
    T = 400
    plan = FaultPlan(
        carbon=CarbonFeedFaults(blackouts=((0, 100, 300),)),
        degrade=DegradeConfig(mode="ladder", ttl_epochs=3,
                              prior_ttl_epochs=50, c_max=900.0))
    true = _diurnal(T)
    sig = observe_intensity(true, plan, DT)
    tiers = sig.tier[:, 0]
    assert (tiers[:100] == TIER_FRESH).all()
    assert (tiers[100:103] == TIER_HOLD).all()          # age 1..3 holds
    assert (sig.observed[100:103, 0] == true[99, 0]).all()
    assert (tiers[103:150] == TIER_PRIOR).all()         # age 4..50 prior
    assert (tiers[150:400] == TIER_FLOOR).all()         # past prior TTL
    assert (sig.observed[150:400, 0] == 900.0).all()
    s = sig.summary()
    assert s["fault_stale_frac"] == pytest.approx(300 / 400)
    assert s["fault_floor_frac"] > s["fault_hold_frac"]


def test_ladder_prior_is_strictly_causal():
    """The estimate at epoch t only reads samples received at <= t:
    perturbing the future true signal cannot change the prefix."""
    T = 600
    plan = FaultPlan(
        carbon=CarbonFeedFaults(dropout_prob=0.3),
        degrade=DegradeConfig(mode="ladder", ttl_epochs=2), seed=5)
    true = _diurnal(T)
    cut = 350
    bumped = true.copy()
    bumped[cut:] *= 3.0
    a = observe_intensity(true, plan, DT)
    b = observe_intensity(bumped, plan, DT)
    assert np.array_equal(a.observed[:cut], b.observed[:cut])
    assert np.array_equal(a.tier[:cut], b.tier[:cut])


def test_hold_mode_holds_forever_and_floors_before_first_sample():
    T = 64
    plan = FaultPlan(
        carbon=CarbonFeedFaults(blackouts=((0, 0, 10), (0, 20, 44))),
        degrade=DegradeConfig(mode="hold", c_max=777.0))
    true = _diurnal(T)
    sig = observe_intensity(true, plan, DT)
    # nothing ever received during the leading blackout -> floor
    assert (sig.observed[:10, 0] == 777.0).all()
    assert (sig.tier[:10, 0] == TIER_FLOOR).all()
    # hold-forever: the t=19 sample is held to the end, no TTL
    assert (sig.observed[20:, 0] == true[19, 0]).all()
    assert (sig.tier[20:, 0] == TIER_HOLD).all()


def test_noise_windows_corrupt_fresh_samples_only():
    T = 96
    plan = FaultPlan(
        carbon=CarbonFeedFaults(noise_windows=((0, 30, 40, 0.3),)),
        degrade=DegradeConfig(mode="ladder"), seed=9)
    true = _diurnal(T)
    sig = observe_intensity(true, plan, DT)
    assert (sig.tier == TIER_FRESH).all()          # no dropouts configured
    assert np.array_equal(sig.observed[:30], true[:30])
    assert np.array_equal(sig.observed[70:], true[70:])
    assert not np.array_equal(sig.observed[30:70], true[30:70])


def test_fault_masks_deterministic_and_seed_sensitive():
    T, N, R = 128, 40, 3
    p = FaultPlan(carbon=CarbonFeedFaults(dropout_prob=0.4,
                                          noise_windows=((-1, 0, T, 0.2),)),
                  power=PowerTelemetryFaults(gap_prob=0.2),
                  migration=MigrationFaults(fail_prob=0.5), seed=3)
    f1, n1 = carbon_fault_masks(p, T, R)
    f2, n2 = carbon_fault_masks(p, T, R)
    assert np.array_equal(f1, f2) and np.array_equal(n1, n2)
    m1 = migration_failure_mask(p, T, N)
    assert np.array_equal(m1, migration_failure_mask(p, T, N))
    g1 = power_gap_vector(p, T)
    assert np.array_equal(g1, power_gap_vector(p, T))
    p2 = FaultPlan(carbon=p.carbon, power=p.power, migration=p.migration,
                   seed=4)
    assert not np.array_equal(f1, carbon_fault_masks(p2, T, R)[0])
    assert not np.array_equal(m1, migration_failure_mask(p2, T, N))


# ------------------------------------------------- conservative safety

def test_conservative_never_understates_hypothesis():
    """For ANY dropout mask / blackout layout / seed (noise-free) with
    traces bounded by c_max, the conservative observed intensity never
    under-states the true one — the signal-level safety property."""
    hyp = pytest.importorskip(
        "hypothesis",
        reason="hypothesis not installed (see requirements-dev.txt)")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=40, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1), drop=st.floats(0.0, 1.0),
           start=st.integers(0, 250), n=st.integers(0, 300),
           amp=st.floats(0.0, 400.0))
    def prop(seed, drop, start, n, amp):
        T = 288
        c_max = 900.0
        true = np.clip(_diurnal(T, R=2, base=400.0, amp=amp), 0.0, c_max)
        plan = FaultPlan(
            carbon=CarbonFeedFaults(dropout_prob=drop,
                                    blackouts=((-1, start, n),)),
            degrade=DegradeConfig(mode="conservative", c_max=c_max),
            seed=seed)
        sig = observe_intensity(true, plan, DT)
        assert (sig.observed >= sig.true - 1e-12).all()

    prop()


def test_conservative_never_understates_seeded_grid():
    """Deterministic fallback for the hypothesis property (hypothesis
    is optional): a seeded grid over dropout rates, blackout layouts
    and seeds exercises the same never-understate invariant."""
    T, c_max = 288, 900.0
    for seed in (0, 1, 7, 23, 101):
        for drop in (0.0, 0.2, 0.6, 1.0):
            for start, n in ((0, 0), (0, T), (96, 48), (250, 300)):
                true = np.clip(_diurnal(T, R=2, base=400.0, amp=300.0),
                               0.0, c_max)
                plan = FaultPlan(
                    carbon=CarbonFeedFaults(dropout_prob=drop,
                                            blackouts=((-1, start, n),)),
                    degrade=DegradeConfig(mode="conservative", c_max=c_max),
                    seed=seed)
                sig = observe_intensity(true, plan, DT)
                assert (sig.observed >= sig.true - 1e-12).all()


def test_conservative_budget_corollary_zero_violations():
    """power <= (1-eps)*target*1000/c_obs and c_obs >= c_true imply the
    per-epoch gram rate billed at TRUE intensity stays within target —
    modulo the startup actuation transient (the fleet initializes on
    the baseline slice and pays the scale-down transition), hence the
    settle window."""
    T, N = 288, 12
    settle = 4
    true = np.clip(_diurnal(T), 0.0, 900.0)[:, 0]
    plan = FaultPlan(
        carbon=CarbonFeedFaults(dropout_prob=0.5,
                                blackouts=((0, 96, 96),)),
        degrade=DegradeConfig(mode="conservative", c_max=900.0), seed=7)
    sig = observe_intensity(true[:, None], plan, DT)
    rng = np.random.default_rng(0)
    demand = rng.uniform(0.2, 1.5, size=(T, N))
    targets = np.full(N, 6.0)
    sim = FleetSimulator(FAM, interval_s=DT)
    res = sim.run(CarbonContainerPolicy(), demand, true, targets,
                  record=True, carbon_obs=sig.observed[:, 0])
    assert budget_violations(res.power_series[settle:], true[settle:],
                             targets, DT) == 0


def test_power_gap_accrues_unmetered_but_still_bills():
    T, N = 96, 6
    true = np.full(T, 300.0)
    demand = np.full((T, N), 0.8)
    targets = np.full(N, 50.0)
    gap = np.zeros(T)
    gap[30:40] = 1.0
    sim = FleetSimulator(FAM, interval_s=DT)
    res = sim.run(CarbonContainerPolicy(), demand, true, targets,
                  power_gap=gap)
    base = sim.run(CarbonContainerPolicy(), demand, true, targets)
    assert res.unmetered_g is not None and res.unmetered_g.sum() > 0.0
    # the gap blinds the meter, it does not change physics
    np.testing.assert_allclose(res.emissions_g, base.emissions_g)


# ------------------------------------------------------------- parity

def test_scalar_fleet_parity_with_observed_split():
    """One container: the scalar loop and the fleet kernel consume the
    same degraded feed and bill the same true intensity."""
    T = 288
    true = np.clip(_diurnal(T), 1.0, 900.0)[:, 0]
    plan = FaultPlan(
        carbon=CarbonFeedFaults(dropout_prob=0.3,
                                blackouts=((0, 100, 60),)),
        degrade=DegradeConfig(mode="ladder", ttl_epochs=3), seed=13)
    sig = observe_intensity(true[:, None], plan, DT)
    obs = sig.observed[:, 0]
    rng = np.random.default_rng(1)
    demand = rng.uniform(0.1, 1.2, size=T)

    class _Arr:
        def __init__(self, h):
            self.h = h

        def intensity(self, t):
            return float(self.h[int(t // DT) % len(self.h)])

    cfg = SimConfig(target_rate=25.0)
    res_s = simulate(CarbonContainerPolicy(), FAM, demand, _Arr(true), cfg,
                     carbon_obs=obs)
    sim = FleetSimulator(FAM, interval_s=DT)
    res_f = sim.run(CarbonContainerPolicy(), demand[:, None], true,
                    np.array([25.0]), carbon_obs=obs)
    assert abs(res_s.emissions_g - res_f.emissions_g[0]) <= 1e-9 * max(
        1.0, abs(res_s.emissions_g))
    assert abs(res_s.work_done - res_f.work_done[0]) <= 1e-9 * max(
        1.0, abs(res_s.work_done))


def _fault_spec(backend, n_tr=10, days=1):
    T = 288 * days
    rng = np.random.default_rng(2)
    traces = rng.uniform(0.1, 1.4, size=(T, n_tr))
    regions = ("PL", "NL", "CAISO")
    provs = [TraceProvider.for_region(r, hours=24 * days, seed=1)
             for r in regions]
    eng = PlacementEngine(
        FAM, provs, region_names=regions, interval_s=DT,
        config=PlacementConfig(capacity=n_tr, min_dwell=2,
                               hysteresis=0.05))
    flt = FaultPlan(
        carbon=CarbonFeedFaults(dropout_prob=0.25,
                                blackouts=((-1, T // 3, T // 8),)),
        power=PowerTelemetryFaults(gap_prob=0.1),
        migration=MigrationFaults(fail_prob=0.4, backoff_cap=8),
        degrade=DegradeConfig(mode="ladder", ttl_epochs=3), seed=17)
    return SweepSpec(
        policies={"cc": lambda: CarbonContainerPolicy(variant="energy")},
        family=FAM, traces=traces, targets=(20.0, 45.0),
        sim=SimConfig(target_rate=0.0), backend=backend,
        placement=eng, faults=flt)


def test_fleet_jax_sweep_parity_with_fault_plan():
    pytest.importorskip("jax")
    res_f = _fault_spec("fleet").run()
    res_j = _fault_spec("jax").run()
    assert res_f.parity(res_j) <= 1e-6
    assert res_f.col("fault_stale_frac").max() > 0.0
    assert res_f.col("fault_failed_migrations_mean").max() > 0.0
    assert res_f.col("fault_unmetered_g_mean").max() > 0.0


def test_planner_three_impl_failed_migration_parity():
    """plan_scalar / plan / plan_jax share the seeded failure mask and
    the capped-backoff retry state bit-identically."""
    pytest.importorskip("jax")
    from repro.cluster.placement_jax import plan_jax
    T, n_tr = 288, 16
    regions = ("PL", "NL", "CAISO")
    provs = [TraceProvider.for_region(r, hours=24, seed=1)
             for r in regions]
    eng = PlacementEngine(
        FAM, provs, region_names=regions, interval_s=DT,
        config=PlacementConfig(capacity=n_tr, min_dwell=2,
                               hysteresis=0.05))
    rng = np.random.default_rng(3)
    demand = rng.uniform(0.1, 1.4, size=(T, n_tr))
    flt = FaultPlan(migration=MigrationFaults(fail_prob=0.5,
                                              backoff_base=1,
                                              backoff_cap=8), seed=19)
    p_vec = eng.plan(demand, faults=flt)
    p_sca = eng.plan_scalar(demand, faults=flt)
    p_jax = plan_jax(eng, demand, faults=flt)
    assert np.array_equal(p_vec.assign, p_sca.assign)
    assert np.array_equal(p_vec.assign, p_jax.assign)
    assert np.array_equal(p_vec.failed_migrations, p_sca.failed_migrations)
    assert np.array_equal(p_vec.failed_migrations, p_jax.failed_migrations)
    assert p_vec.failed_migrations.sum() > 0
    # a no-fault plan must migrate at least as eagerly
    assert eng.plan(demand).migrations.sum() >= p_vec.migrations.sum()


# -------------------------------------------------- carbon gap guard

def test_fill_gaps_raise_names_positions():
    s = np.array([100.0, np.nan, 120.0, np.nan])
    with pytest.raises(ValueError, match=r"2 NaN gap\(s\) at indices \[1, 3\]"):
        fill_gaps(s)


def test_fill_gaps_interpolate_and_hold():
    s = np.array([np.nan, 100.0, np.nan, np.nan, 130.0, np.nan])
    interp = fill_gaps(s, gap_policy="interpolate")
    np.testing.assert_allclose(interp, [100.0, 100.0, 110.0, 120.0,
                                        130.0, 130.0])
    hold = fill_gaps(s, gap_policy="hold")
    np.testing.assert_allclose(hold, [100.0, 100.0, 100.0, 100.0,
                                      130.0, 130.0])
    with pytest.raises(ValueError, match="all-NaN"):
        fill_gaps(np.full(4, np.nan), gap_policy="hold")
    with pytest.raises(ValueError, match="unknown gap_policy"):
        fill_gaps(s, gap_policy="zero")


def test_trace_provider_gap_policy():
    hourly = [100.0, np.nan, 140.0]
    with pytest.raises(ValueError, match="NaN gap"):
        TraceProvider(hourly)
    p = TraceProvider(hourly, gap_policy="interpolate")
    assert p.intensity(3600.0) == pytest.approx(120.0)
    assert not np.isnan(p.intensity_series(
        np.arange(6) * 3600.0)).any()
